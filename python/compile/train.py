"""Build-time training of the demo networks (float32, pure jnp SGD) and
Delphi-style quantization of the result.

Runs once inside ``make artifacts``; the quantized weights are dumped to
``weights.bin``/``weights_mlp.bin`` for the Rust side and baked into the
accuracy HLO artifacts' parameter lists.
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import data
from .model import ACT_SCALE, CNN_SHAPES, INPUT_SCALE, MLP_DIMS, WEIGHT_SCALE

QUANT_MAX = (1 << 14) - 1  # 15-bit signed, matches rust field::fixed


# --------------------------------------------------------------------------
# Float reference models (training only).
# --------------------------------------------------------------------------

def _conv_f(x, w, b, stride, pad):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def cnn_forward_f(params, x):
    w1, b1, w2, b2, w3, b3 = params
    c = CNN_SHAPES
    x = jax.nn.relu(_conv_f(x, w1, b1, c["conv1"]["stride"], c["conv1"]["pad"]))
    x = jax.nn.relu(_conv_f(x, w2, b2, c["conv2"]["stride"], c["conv2"]["pad"]))
    x = x.reshape(x.shape[0], -1)
    return x @ w3.T + b3


def mlp_forward_f(params, x):
    w1, b1, w2, b2, w3, b3 = params
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ w1.T + b1)
    x = jax.nn.relu(x @ w2.T + b2)
    return x @ w3.T + b3


def _init_cnn(rng):
    c = CNN_SHAPES
    k1 = (c["conv1"]["out_c"], c["conv1"]["in_c"], 3, 3)
    k2 = (c["conv2"]["out_c"], c["conv2"]["in_c"], 3, 3)
    k3 = (c["dense"]["out_dim"], c["dense"]["in_dim"])
    def he(shape, fan_in):
        return jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / fan_in), shape), jnp.float32
        )
    return [
        he(k1, 9), jnp.zeros(k1[0], jnp.float32),
        he(k2, 72), jnp.zeros(k2[0], jnp.float32),
        he(k3, k3[1]), jnp.zeros(k3[0], jnp.float32),
    ]


def _init_mlp(rng):
    d = MLP_DIMS
    def he(shape, fan_in):
        return jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / fan_in), shape), jnp.float32
        )
    return [
        he((d[1], d[0]), d[0]), jnp.zeros(d[1], jnp.float32),
        he((d[2], d[1]), d[1]), jnp.zeros(d[2], jnp.float32),
        he((d[3], d[2]), d[2]), jnp.zeros(d[3], jnp.float32),
    ]


def _loss(forward, params, x, y):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train(forward, params, train_set, steps, lr=0.05, batch=128, seed=0):
    """Plain SGD with momentum 0.9 (no optax in this environment)."""
    xs, ys = train_set
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.int32)
    momentum = [jnp.zeros_like(p) for p in params]
    grad_fn = jax.jit(jax.grad(lambda p, x, y: _loss(forward, p, x, y)))
    rng = np.random.default_rng(seed)
    n = xs.shape[0]
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        g = grad_fn(params, xs[idx], ys[idx])
        momentum = [0.9 * m + gi for m, gi in zip(momentum, g)]
        params = [p - lr * m for p, m in zip(params, momentum)]
    return params


def accuracy_f(forward, params, test_set):
    xs, ys = test_set
    logits = forward(params, jnp.asarray(xs, jnp.float32))
    return float(jnp.mean(jnp.argmax(logits, axis=1) == jnp.asarray(ys)))


# --------------------------------------------------------------------------
# Quantization (Delphi-style 15-bit, §4.1).
# --------------------------------------------------------------------------

def quantize_params(params):
    """Float params -> int32: weights at 2^WEIGHT_SCALE (15-bit clamped),
    biases at 2^ACT_SCALE (accumulator scale — clamped only by the field
    headroom, not the 15-bit operand bound)."""
    BIAS_MAX = 1 << 28  # well under p/2, far above any trained bias
    out = []
    for i, p in enumerate(params):
        if i % 2 == 0:
            q = np.clip(np.round(np.asarray(p) * (1 << WEIGHT_SCALE)), -QUANT_MAX, QUANT_MAX)
        else:
            q = np.clip(np.round(np.asarray(p) * (1 << ACT_SCALE)), -BIAS_MAX, BIAS_MAX)
        out.append(q.astype(np.int32))
    return out


def train_demo_models(n_train=6000, n_test=2000, steps=1200, seed=7):
    """Train + quantize both demo nets. Returns a dict of results."""
    train_set, test_set = data.train_test_split(n_train, n_test, seed)

    cnn_p = _init_cnn(np.random.default_rng(seed))
    cnn_p = train(cnn_forward_f, cnn_p, train_set, steps, seed=seed)
    cnn_acc = accuracy_f(cnn_forward_f, cnn_p, test_set)

    mlp_p = _init_mlp(np.random.default_rng(seed + 1))
    mlp_p = train(mlp_forward_f, mlp_p, train_set, steps, seed=seed + 1)
    mlp_acc = accuracy_f(mlp_forward_f, mlp_p, test_set)

    return dict(
        cnn_params=quantize_params(cnn_p),
        mlp_params=quantize_params(mlp_p),
        cnn_float_acc=cnn_acc,
        mlp_float_acc=mlp_acc,
        test_images=test_set[0],
        test_labels=test_set[1],
        input_scale=INPUT_SCALE,
    )

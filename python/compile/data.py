"""Deterministic synthetic image dataset (DESIGN.md §5 substitution).

CIFAR/TinyImageNet are not available offline, and the accuracy claims
under test (Fig. 4's flat-then-cliff accuracy-vs-k, PosZero vs NegPass)
depend on the *activation distribution relative to 2^k*, not on natural
images. This generator produces a 10-class 16x16 grayscale task that a
small CNN learns to >90%: each class is a smoothed random template with
per-sample amplitude jitter, additive noise, and random shifts.
"""

import numpy as np

N_CLASSES = 10
HW = 16


def _smooth(img):
    """3x3 box blur (keeps templates low-frequency => learnable)."""
    out = img.copy()
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            out += np.roll(np.roll(img, dy, 0), dx, 1)
    return out / 9.0


def make_dataset(n, seed):
    """Return (images [n,1,HW,HW] float32 in [0, ~1.5], labels [n] int32)."""
    rng = np.random.default_rng(seed)
    templates = np.stack(
        [_smooth(_smooth(rng.normal(0.0, 1.0, (HW, HW)))) for _ in range(N_CLASSES)]
    )
    # Normalize templates to unit peak so classes share a scale.
    templates /= np.abs(templates).max(axis=(1, 2), keepdims=True)

    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    amp = rng.uniform(0.5, 1.4, size=(n, 1, 1)).astype(np.float32)
    noise = rng.normal(0.0, 0.55, size=(n, HW, HW)).astype(np.float32)
    imgs = amp * templates[labels] + noise
    # Random +-2 pixel shifts for translation variance.
    shifts = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        imgs[i] = np.roll(np.roll(imgs[i], shifts[i, 0], 0), shifts[i, 1], 1)
    # ReLU-like clamp into a non-negative input range (images are
    # non-negative in the paper's pipelines too).
    imgs = np.clip(imgs + 0.5, 0.0, 1.5).astype(np.float32)
    return imgs[:, None, :, :], labels


def train_test_split(n_train, n_test, seed):
    imgs, labels = make_dataset(n_train + n_test, seed)
    return (
        (imgs[:n_train], labels[:n_train]),
        (imgs[n_train:], labels[n_train:]),
    )

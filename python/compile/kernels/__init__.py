"""Layer-1 Pallas kernels for Circa.

``stochastic_sign`` is the paper's compute hot-spot: the truncated
stochastic sign test over secret shares (Eq. 2/3), applied as
``ReLU_k(x) = x * sign_k(x)``. ``field_matmul`` is the exact int matmul
used by the quantized linear layers. ``ref`` holds the pure-jnp oracles
the kernels are pytest/hypothesis-checked against.

All kernels lower with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness (not TPU wallclock) is what the
artifact path needs. See DESIGN.md §Hardware-Adaptation for the real-TPU
mapping (VMEM block schedule, VPU elementwise sign, MXU limb-decomposed
matmul).
"""

"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

These implement the *normative* semantics (bit-exact to the Rust GC
comparator in ``rust/src/simfault``): pytest/hypothesis checks the Pallas
kernels against these, and the Rust integration tests check the PJRT-run
artifacts against the Rust fault model, closing the loop
GC ⇔ rust model ⇔ jnp ref ⇔ pallas kernel.
"""

import jax.numpy as jnp

# The paper's 31-bit prime (§4.1).
PRIME = 2_138_816_513
# Positive/negative encoding boundary: x is negative iff raw >= HALF.
HALF = PRIME // 2

# Fault modes (0/1 match rust circuits::spec::FaultMode; 2 = exact ReLU).
MODE_POSZERO = 0
MODE_NEGPASS = 1
MODE_EXACT = 2


def to_field(x):
    """Signed int -> canonical field representative in [0, p)."""
    x = jnp.asarray(x, jnp.int64)
    return jnp.where(x >= 0, x, x + PRIME)


def stoch_sign_bit(x, t, k, mode):
    """The stochastic sign bit exactly as the GC computes it.

    x: signed activations (int32/int64, |x| < p/2)
    t: uniform field elements in [0, p) (int32 raw < 2^31)
    k: truncation bits (scalar)
    mode: MODE_POSZERO / MODE_NEGPASS / MODE_EXACT
    Returns int32 1 where the computed sign is non-negative.
    """
    x = jnp.asarray(x, jnp.int64)
    t = jnp.asarray(t, jnp.int64)
    raw = to_field(x)
    xs = (raw + t) % PRIME          # server share <x>_s = x + t mod p
    a = xs >> k                     # truncated comparands
    b = t >> k                      # p - <x>_c = t, truncated
    is_neg_stoch = jnp.where(mode == MODE_NEGPASS, a < b, a <= b)
    exact_nonneg = x >= 0
    nonneg = jnp.where(mode == MODE_EXACT, exact_nonneg, ~is_neg_stoch)
    return nonneg.astype(jnp.int32)


def stoch_relu(x, t, k, mode):
    """ReLU_k(x) = x * sign_k(x); returns (y, fault) both int32.

    ``fault`` flags sign decisions that differ from the exact sign (for
    x == 0 the PosZero path always "faults" in sign but not in value —
    matching rust simfault::fault_prob).
    """
    x = jnp.asarray(x, jnp.int32)
    s = stoch_sign_bit(x, t, k, mode)
    y = jnp.where(s == 1, x, 0).astype(jnp.int32)
    fault = (s != (x >= 0).astype(jnp.int32)).astype(jnp.int32)
    return y, fault


def int_matmul(a, b):
    """Exact (a @ b) for quantized ints in int64.

    With |a|, |b| < 2^15 and K <= 2^16 the int64 accumulation is exact and
    equals the signed decode of the mod-p product — the regime every
    quantized layer here operates in.
    """
    return jnp.matmul(jnp.asarray(a, jnp.int64), jnp.asarray(b, jnp.int64))

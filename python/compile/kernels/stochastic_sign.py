"""Pallas kernel: the truncated stochastic ReLU (Circa's hot-spot).

The kernel streams activation/randomness blocks HBM->VMEM and applies the
share-comparison sign test elementwise on the VPU:

    raw = x mod p                       (signed -> field encode)
    <x>_s = raw + t mod p               (server share)
    sign  = !( <x>_s >> k  <=/<  t >> k )
    y     = sign ? x : 0

Block schedule: 1-D grid over ``BLOCK``-sized row blocks; four live
buffers per block (x, t, y, fault) at int32 = 16 B/elem -> a 64 Ki block
costs 1 MiB VMEM, comfortably double-bufferable within a 16 MiB budget
(DESIGN.md §Perf). ``k``/``mode`` ride along as (1,1) SMEM-like operands.

Lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU this kernel is pure VPU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MODE_NEGPASS, MODE_EXACT, PRIME

# Default element block; callers may override for small shapes.
BLOCK = 65536


def _kernel(x_ref, t_ref, k_ref, mode_ref, y_ref, fault_ref):
    x = x_ref[...].astype(jnp.int64)
    t = t_ref[...].astype(jnp.int64)
    k = k_ref[0]
    mode = mode_ref[0]

    raw = jnp.where(x >= 0, x, x + PRIME)
    xs = raw + t
    xs = jnp.where(xs >= PRIME, xs - PRIME, xs)  # single conditional sub
    a = jax.lax.shift_right_logical(xs, k.astype(jnp.int64))
    b = jax.lax.shift_right_logical(t, k.astype(jnp.int64))
    is_neg_stoch = jnp.where(mode == MODE_NEGPASS, a < b, a <= b)
    exact_nonneg = x >= 0
    nonneg = jnp.where(mode == MODE_EXACT, exact_nonneg, ~is_neg_stoch)

    y_ref[...] = jnp.where(nonneg, x, 0).astype(jnp.int32)
    fault_ref[...] = (nonneg != exact_nonneg).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def stoch_relu(x, t, k, mode, block=BLOCK):
    """Apply the truncated stochastic ReLU elementwise.

    x:    int32 signed activations, any shape (flattened internally)
    t:    int32 uniform field elements in [0, p), same shape
    k:    int32 scalar — truncation bits
    mode: int32 scalar — 0 PosZero / 1 NegPass / 2 exact
    Returns (y, fault) with x's shape, both int32.
    """
    shape = x.shape
    xf = x.reshape(-1)
    tf = t.reshape(-1)
    n = xf.shape[0]
    blk = min(block, n)
    # Pad to a whole number of blocks (padding lane: x=0, t=0 is inert).
    pad = (-n) % blk
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad,), jnp.int32)])
        tf = jnp.concatenate([tf, jnp.zeros((pad,), jnp.int32)])
    grid = xf.shape[0] // blk

    k_arr = jnp.asarray(k, jnp.int32).reshape(1)
    mode_arr = jnp.asarray(mode, jnp.int32).reshape(1)

    y, fault = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xf.shape, jnp.int32),
            jax.ShapeDtypeStruct(xf.shape, jnp.int32),
        ],
        interpret=True,
    )(xf, tf, k_arr, mode_arr)

    if pad:
        y = y[:n]
        fault = fault[:n]
    return y.reshape(shape), fault.reshape(shape)


def vmem_bytes(block=BLOCK):
    """Estimated live VMEM per grid step (4 int32 buffers + int64 temps).

    Used by the §Perf notes: int32 in/out (4 bufs) plus the int64
    intermediates the compiler keeps live (~2 bufs worst case).
    """
    return block * (4 * 4 + 2 * 8)

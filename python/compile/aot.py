"""AOT artifact builder: train the demo nets, quantize, dump weights +
dataset in the Rust binary format, and lower the L2 model to HLO *text*
(NOT ``.serialize()`` — the image's xla_extension 0.5.1 rejects jax>=0.5
64-bit-id protos; the text parser reassigns ids. See
/opt/xla-example/README.md).

Outputs (``make artifacts`` -> artifacts/):
  demo_cnn.hlo.txt   forward_cnn(images,t1,t2,k,mode,w1,b1,w2,b2,w3,b3)
  demo_mlp.hlo.txt   forward_mlp(...)
  stoch_relu.hlo.txt standalone batched kernel (x,t,k,mode) -> (y,faults)
  weights.bin        quantized CNN parameters      (magic CIRCAW01)
  weights_mlp.bin    quantized MLP parameters
  dataset.bin        quantized eval set            (magic CIRCAD01)
  manifest.json      human-readable summary + float/quantized accuracy

Python runs ONCE, at build time; the Rust binary is self-contained
afterwards.
"""

import argparse
import json
import os
import struct
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train as train_mod
from .model import (
    CNN_SHAPES,
    INPUT_SCALE,
    MLP_DIMS,
    RESCALE,
    forward_cnn,
    forward_mlp,
    quantize_input,
)

BATCH = 128
RELU_N = 1 << 16  # standalone kernel size


# --------------------------------------------------------------------------
# Binary writers (mirrors rust util::bytes little-endian framing).
# --------------------------------------------------------------------------

def _w_u8(buf, v):
    buf.append(struct.pack("<B", v))


def _w_u32(buf, v):
    buf.append(struct.pack("<I", v))


def _w_u64(buf, v):
    buf.append(struct.pack("<Q", v))


def _w_string(buf, s):
    raw = s.encode()
    _w_u64(buf, len(raw))
    buf.append(raw)


def _w_i32_vec(buf, arr):
    arr = np.asarray(arr, np.int32).reshape(-1)
    _w_u64(buf, arr.size)
    buf.append(arr.tobytes())


def write_weights(path, name, layers):
    """layers: list of ('conv', dims..., w, b, rescale) / ('dense', ...)."""
    buf = [b"CIRCAW01"]
    _w_string(buf, name)
    _w_u32(buf, len(layers))
    for layer in layers:
        if layer[0] == "conv":
            (_, in_c, in_h, in_w, out_c, k, stride, pad, w, b, rescale) = layer
            _w_u8(buf, 0)
            for v in (in_c, in_h, in_w, out_c, k, stride, pad):
                _w_u32(buf, v)
            _w_i32_vec(buf, w)
            _w_i32_vec(buf, b)
            _w_u32(buf, rescale)
        else:
            (_, in_dim, out_dim, w, b, rescale) = layer
            _w_u8(buf, 1)
            _w_u32(buf, in_dim)
            _w_u32(buf, out_dim)
            _w_i32_vec(buf, w)
            _w_i32_vec(buf, b)
            _w_u32(buf, rescale)
    with open(path, "wb") as f:
        f.write(b"".join(buf))


def write_dataset(path, images_q, labels):
    n, dim = images_q.shape[0], int(np.prod(images_q.shape[1:]))
    buf = [b"CIRCAD01"]
    _w_u32(buf, n)
    _w_u32(buf, dim)
    _w_u32(buf, int(labels.max()) + 1)
    _w_i32_vec(buf, images_q.reshape(n, dim))
    for y in labels:
        _w_u32(buf, int(y))
    with open(path, "wb") as f:
        f.write(b"".join(buf))


# --------------------------------------------------------------------------
# HLO lowering (text interchange — see module docstring).
# --------------------------------------------------------------------------

def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_cnn():
    c = CNN_SHAPES
    specs = (
        _i32((BATCH, 1, 16, 16)),                 # images
        _i32((BATCH, 8, 8, 8)),                   # t1
        _i32((BATCH, 16, 4, 4)),                  # t2
        _i32(()),                                 # k
        _i32(()),                                 # mode
        _i32((c["conv1"]["out_c"], 1, 3, 3)),     # w1
        _i32((c["conv1"]["out_c"],)),             # b1
        _i32((c["conv2"]["out_c"], c["conv2"]["in_c"], 3, 3)),  # w2
        _i32((c["conv2"]["out_c"],)),             # b2
        _i32((c["dense"]["out_dim"], c["dense"]["in_dim"])),    # w3
        _i32((c["dense"]["out_dim"],)),           # b3
    )
    return to_hlo_text(jax.jit(forward_cnn).lower(*specs))


def lower_mlp():
    d = MLP_DIMS
    specs = (
        _i32((BATCH, d[0])),
        _i32((BATCH, d[1])),
        _i32((BATCH, d[2])),
        _i32(()),
        _i32(()),
        _i32((d[1], d[0])),
        _i32((d[1],)),
        _i32((d[2], d[1])),
        _i32((d[2],)),
        _i32((d[3], d[2])),
        _i32((d[3],)),
    )
    return to_hlo_text(jax.jit(forward_mlp).lower(*specs))


def lower_stoch_relu():
    from .kernels.stochastic_sign import stoch_relu

    def fn(x, t, k, mode):
        return stoch_relu(x, t, k, mode)

    specs = (_i32((RELU_N,)), _i32((RELU_N,)), _i32(()), _i32(()))
    return to_hlo_text(jax.jit(fn).lower(*specs))


# --------------------------------------------------------------------------
# Main.
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1200)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    print("[aot] training demo models ...", flush=True)
    res = train_mod.train_demo_models(steps=args.steps)
    cw = res["cnn_params"]
    mw = res["mlp_params"]

    c = CNN_SHAPES
    write_weights(
        os.path.join(args.out, "weights.bin"),
        "demo_cnn",
        [
            ("conv", 1, 16, 16, c["conv1"]["out_c"], 3, 2, 1, cw[0], cw[1], RESCALE),
            ("conv", c["conv2"]["in_c"], 8, 8, c["conv2"]["out_c"], 3, 2, 1, cw[2], cw[3], RESCALE),
            ("dense", c["dense"]["in_dim"], c["dense"]["out_dim"], cw[4], cw[5], 0),
        ],
    )
    d = MLP_DIMS
    write_weights(
        os.path.join(args.out, "weights_mlp.bin"),
        "demo_mlp",
        [
            ("dense", d[0], d[1], mw[0], mw[1], RESCALE),
            ("dense", d[1], d[2], mw[2], mw[3], RESCALE),
            ("dense", d[2], d[3], mw[4], mw[5], 0),
        ],
    )

    imgs_q = np.asarray(quantize_input(jnp.asarray(res["test_images"])))
    write_dataset(os.path.join(args.out, "dataset.bin"), imgs_q, res["test_labels"])

    # Quantized exact-ReLU accuracy (the Tables 1/2 "Baseline Acc" at
    # demo scale), computed through the same jitted path rust will run.
    qs = [jnp.asarray(x) for x in cw]
    zt1 = jnp.zeros((imgs_q.shape[0], 8, 8, 8), jnp.int32)
    zt2 = jnp.zeros((imgs_q.shape[0], 16, 4, 4), jnp.int32)
    logits, _ = forward_cnn(jnp.asarray(imgs_q), zt1, zt2, 0, 2, *qs)
    q_acc = float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(res["test_labels"])))

    print("[aot] lowering HLO artifacts ...", flush=True)
    for name, text in [
        ("demo_cnn.hlo.txt", lower_cnn()),
        ("demo_mlp.hlo.txt", lower_mlp()),
        ("stoch_relu.hlo.txt", lower_stoch_relu()),
    ]:
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        print(f"[aot]   {name}: {len(text)} chars")

    manifest = dict(
        version="circa-artifacts-1",
        batch=BATCH,
        relu_n=RELU_N,
        input_scale=INPUT_SCALE,
        rescale=RESCALE,
        cnn_float_acc=res["cnn_float_acc"],
        mlp_float_acc=res["mlp_float_acc"],
        cnn_quantized_acc=q_acc,
        n_test=int(imgs_q.shape[0]),
        train_steps=args.steps,
        entries=dict(
            demo_cnn="forward_cnn(images[B,1,16,16], t1[B,8,8,8], t2[B,16,4,4], k, mode, w1,b1,w2,b2,w3,b3) -> (logits[B,10], faults[2])",
            demo_mlp="forward_mlp(images[B,256], t1[B,128], t2[B,64], k, mode, w1,b1,w2,b2,w3,b3) -> (logits[B,10], faults[2])",
            stoch_relu="stoch_relu(x[N], t[N], k, mode) -> (y[N], faults[N])",
        ),
    )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    print(
        f"[aot] done in {time.time() - t0:.1f}s — float acc "
        f"cnn={res['cnn_float_acc']:.3f} mlp={res['mlp_float_acc']:.3f}, "
        f"quantized cnn={q_acc:.3f}"
    )


if __name__ == "__main__":
    main()

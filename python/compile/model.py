"""Layer-2: the quantized field-arithmetic model with Circa's stochastic
ReLU, written in JAX and calling the Pallas kernel.

Two demo networks (the accuracy workloads of Figs. 3/4 and the accuracy
columns of Tables 1/2 at demo scale):

* ``forward_cnn`` — conv(1->8, s2) / ReLU_k / conv(8->16, s2) / ReLU_k /
  dense(256->10) on 16x16 inputs;
* ``forward_mlp`` — 256 -> 128 / ReLU_k / 64 / ReLU_k / 10 (the "second
  architecture" of Fig. 4's bottom row).

Fixed-point scheme (matches rust nn::weights and DESIGN.md §4):
inputs at scale 2^INPUT_SCALE, weights at 2^WEIGHT_SCALE, so every ReLU
sees activations at scale 2^ACT_SCALE = 2^(INPUT+WEIGHT); after the ReLU
the activations are rescaled back by RESCALE = WEIGHT_SCALE bits.
Truncation k therefore bites values below 2^k at ACT scale — the same
regime the paper's Fig. 3 histogram shows.

``k`` and ``mode`` are runtime scalars, so ONE lowered artifact serves
every point of the Fig. 4 sweep (mode 2 = exact ReLU baseline).
"""

import jax
import jax.numpy as jnp

from .kernels.stochastic_sign import stoch_relu

INPUT_SCALE = 7
WEIGHT_SCALE = 8
ACT_SCALE = INPUT_SCALE + WEIGHT_SCALE  # 15, as Delphi's 15-bit scheme
RESCALE = WEIGHT_SCALE

# Architecture constants shared with train.py / aot.py / rust.
CNN_SHAPES = dict(
    conv1=dict(in_c=1, out_c=8, k=3, stride=2, pad=1, in_hw=16),
    conv2=dict(in_c=8, out_c=16, k=3, stride=2, pad=1, in_hw=8),
    dense=dict(in_dim=16 * 4 * 4, out_dim=10),
)
MLP_DIMS = (256, 128, 64, 10)


def conv2d_int(x, w, b, stride, pad):
    """Exact integer conv (NCHW / OIHW), int64 accumulation."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.int64),
        w.astype(jnp.int64),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b.astype(jnp.int64)[None, :, None, None]


def _relu_rescale(x, t, k, mode):
    """Stochastic ReLU at ACT scale, then arithmetic-shift rescale.

    Returns (y_rescaled int32, fault_count int64).
    """
    y, fault = stoch_relu(x.astype(jnp.int32), t, k, mode)
    y = jnp.right_shift(y.astype(jnp.int32), RESCALE)
    return y, jnp.sum(fault.astype(jnp.int64))


def forward_cnn(images, t1, t2, k, mode, w1, b1, w2, b2, w3, b3):
    """Quantized CNN forward with stochastic-ReLU fault injection.

    images: int32 [B,1,16,16] at scale 2^INPUT_SCALE
    t1:     int32 [B,8,8,8]   uniform field randomness for ReLU layer 1
    t2:     int32 [B,16,4,4]  — for ReLU layer 2
    k,mode: int32 scalars (mode 0/1/2 = PosZero/NegPass/exact)
    w*/b*:  quantized int32 parameters (weights 2^WEIGHT_SCALE,
            biases 2^ACT_SCALE)
    Returns (logits int32 [B,10] at scale 2^ACT_SCALE, faults int64 [2]).
    """
    c = CNN_SHAPES
    x = conv2d_int(images, w1, b1, c["conv1"]["stride"], c["conv1"]["pad"])
    x, f1 = _relu_rescale(x, t1, k, mode)
    x = conv2d_int(x, w2, b2, c["conv2"]["stride"], c["conv2"]["pad"])
    x, f2 = _relu_rescale(x, t2, k, mode)
    x = x.reshape(x.shape[0], -1)
    logits = jnp.matmul(x.astype(jnp.int64), w3.astype(jnp.int64).T) + b3.astype(jnp.int64)
    return logits.astype(jnp.int32), jnp.stack([f1, f2])


def forward_mlp(images, t1, t2, k, mode, w1, b1, w2, b2, w3, b3):
    """Quantized MLP forward (same conventions as ``forward_cnn``).

    images: int32 [B,256]; t1: int32 [B,128]; t2: int32 [B,64].
    """
    x = images.astype(jnp.int64)
    x = jnp.matmul(x, w1.astype(jnp.int64).T) + b1.astype(jnp.int64)
    x, f1 = _relu_rescale(x, t1, k, mode)
    x = jnp.matmul(x.astype(jnp.int64), w2.astype(jnp.int64).T) + b2.astype(jnp.int64)
    x, f2 = _relu_rescale(x, t2, k, mode)
    logits = jnp.matmul(x.astype(jnp.int64), w3.astype(jnp.int64).T) + b3.astype(jnp.int64)
    return logits.astype(jnp.int32), jnp.stack([f1, f2])


def quantize_input(images_f32):
    """Float images -> int32 at scale 2^INPUT_SCALE."""
    return jnp.asarray(
        jnp.round(images_f32 * (1 << INPUT_SCALE)), jnp.int32
    )


def relu_count_cnn(batch):
    """Per-layer ReLU element counts for a CNN batch (t tensor shapes)."""
    return [(batch, 8, 8, 8), (batch, 16, 4, 4)]


def relu_count_mlp(batch):
    return [(batch, 128), (batch, 64)]

"""Build-time compile path: JAX model + Pallas kernels + AOT lowering.

Never imported at runtime — the Rust binary consumes only the artifacts
this package writes (`make artifacts`).

x64 is enabled globally: the field arithmetic needs exact int64
(`raw + t` exceeds int32 for a 31-bit prime); float dtypes are kept
explicit (`float32`) throughout the training code.
"""

import jax

jax.config.update("jax_enable_x64", True)

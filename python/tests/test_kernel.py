"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, truncation levels, modes, and value regimes;
every case asserts bit-exact agreement (integer kernel — no tolerance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import compile  # noqa: F401  (enables x64)
from compile.kernels import ref
from compile.kernels.stochastic_sign import stoch_relu, vmem_bytes

PRIME = ref.PRIME


def _run_both(x, t, k, mode, block=256):
    y_ref, f_ref = ref.stoch_relu(x, t, k, mode)
    y_ker, f_ker = stoch_relu(jnp.asarray(x), jnp.asarray(t), k, mode, block=block)
    return (np.asarray(y_ref), np.asarray(f_ref), np.asarray(y_ker), np.asarray(f_ker))


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 2048),
    k=st.integers(0, 28),
    mode=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31 - 1),
    mag_bits=st.integers(1, 29),
)
def test_kernel_matches_ref(n, k, mode, seed, mag_bits):
    rng = np.random.default_rng(seed)
    lim = 1 << mag_bits
    x = rng.integers(-lim, lim, size=n).astype(np.int32)
    t = rng.integers(0, PRIME, size=n).astype(np.int32)
    y_ref, f_ref, y_ker, f_ker = _run_both(x, t, k, mode)
    np.testing.assert_array_equal(y_ref, y_ker)
    np.testing.assert_array_equal(f_ref, f_ker)


@settings(max_examples=20, deadline=None)
@given(
    block=st.sampled_from([64, 100, 256, 1000]),
    n=st.integers(1, 3000),
    seed=st.integers(0, 1000),
)
def test_block_size_invariance(block, n, seed):
    """Padding/blocking must not change results."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-(1 << 20), 1 << 20, size=n).astype(np.int32)
    t = rng.integers(0, PRIME, size=n).astype(np.int32)
    y_a, f_a = stoch_relu(jnp.asarray(x), jnp.asarray(t), 12, 0, block=block)
    y_b, f_b = stoch_relu(jnp.asarray(x), jnp.asarray(t), 12, 0, block=2048)
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_b))
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))


def test_exact_mode_is_relu():
    x = np.array([-5, -1, 0, 1, 7, -(2**29), 2**29], np.int32)
    t = np.full_like(x, 123456789)
    y, f = stoch_relu(jnp.asarray(x), jnp.asarray(t), 25, ref.MODE_EXACT)
    np.testing.assert_array_equal(np.asarray(y), np.maximum(x, 0))
    assert np.asarray(f).sum() == 0


def test_multidim_shapes_preserved():
    rng = np.random.default_rng(0)
    x = rng.integers(-1000, 1000, size=(4, 3, 5, 5)).astype(np.int32)
    t = rng.integers(0, PRIME, size=(4, 3, 5, 5)).astype(np.int32)
    y, f = stoch_relu(jnp.asarray(x), jnp.asarray(t), 8, 0)
    assert y.shape == x.shape and f.shape == x.shape


def test_thm31_fault_rate():
    """Sign-fault rate = |x|/p (Thm 3.1), k = 0."""
    n = 200_000
    mag = PRIME // 8
    rng = np.random.default_rng(1)
    x = np.full(n, mag, np.int32)
    t = rng.integers(0, PRIME, size=n).astype(np.int32)
    _, f = stoch_relu(jnp.asarray(x), jnp.asarray(t), 0, 0)
    rate = float(np.asarray(f).mean())
    assert abs(rate - 0.125) < 0.01, rate


def test_thm32_trunc_fault_rate():
    """Truncation-fault rate = (2^k - x)/2^k for 0 <= x < 2^k (Thm 3.2)."""
    k = 16
    n = 100_000
    x_val = (1 << k) // 4
    rng = np.random.default_rng(2)
    x = np.full(n, x_val, np.int32)
    t = rng.integers(0, PRIME, size=n).astype(np.int32)
    _, f = stoch_relu(jnp.asarray(x), jnp.asarray(t), k, 0)
    rate = float(np.asarray(f).mean())
    assert abs(rate - 0.75) < 0.01, rate


def test_poszero_vs_negpass_sides():
    """PosZero faults positives only; NegPass negatives only (|x| < 2^k,
    sign-fault term negligible)."""
    k = 14
    n = 50_000
    rng = np.random.default_rng(3)
    t = rng.integers(0, PRIME, size=n).astype(np.int32)
    pos = np.full(n, 100, np.int32)
    neg = np.full(n, -100, np.int32)
    _, f = stoch_relu(jnp.asarray(pos), jnp.asarray(t), k, ref.MODE_NEGPASS)
    assert np.asarray(f).sum() == 0
    _, f = stoch_relu(jnp.asarray(neg), jnp.asarray(t), k, ref.MODE_POSZERO)
    assert np.asarray(f).sum() == 0
    _, f = stoch_relu(jnp.asarray(neg), jnp.asarray(t), k, ref.MODE_NEGPASS)
    assert float(np.asarray(f).mean()) > 0.98


def test_negpass_passes_values_through():
    """A NegPass fault *passes* x (y = x), never zeroes it."""
    k = 14
    rng = np.random.default_rng(4)
    x = np.full(1000, -37, np.int32)
    t = rng.integers(0, PRIME, size=1000).astype(np.int32)
    y, f = stoch_relu(jnp.asarray(x), jnp.asarray(t), k, ref.MODE_NEGPASS)
    y = np.asarray(y)
    f = np.asarray(f)
    assert set(np.unique(y[f == 1])) == {-37}
    assert set(np.unique(y[f == 0])) <= {0}


def test_vmem_budget():
    """DESIGN.md §Perf: default block fits VMEM with double-buffer room."""
    assert vmem_bytes() <= 2 * 1024 * 1024

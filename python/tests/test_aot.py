"""AOT path: HLO lowering sanity and binary artifact framing."""

import json
import os
import struct

import numpy as np
import pytest

import compile  # noqa: F401
from compile import aot


def test_lower_stoch_relu_is_hlo_text():
    text = aot.lower_stoch_relu()
    assert "HloModule" in text
    assert "s32" in text  # int32 params
    # tuple return (return_tuple=True)
    assert "tuple" in text.lower()


def _entry_params(text):
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    out = []
    for line in lines[start:]:
        if "parameter(" in line:
            out.append(line.strip())
        if line.strip() == "}":
            break
    return out


def test_lower_cnn_has_all_params():
    text = aot.lower_cnn()
    assert "HloModule" in text
    # 11 ENTRY parameters: images, t1, t2, k, mode, 6 weight tensors.
    params = _entry_params(text)
    assert len(params) == 11, params
    assert "s32[128,1,16,16]" in params[0]


def test_weights_bin_roundtrip(tmp_path):
    w = np.arange(18, dtype=np.int32).reshape(2, 1, 3, 3)
    b = np.array([1, -2], np.int32)
    path = tmp_path / "w.bin"
    aot.write_weights(
        str(path), "t", [("conv", 1, 4, 4, 2, 3, 1, 1, w, b, 7)]
    )
    raw = path.read_bytes()
    assert raw[:8] == b"CIRCAW01"
    # name
    (nlen,) = struct.unpack_from("<Q", raw, 8)
    off = 16 + nlen
    (n_layers,) = struct.unpack_from("<I", raw, off)
    assert n_layers == 1
    off += 4
    assert raw[off] == 0  # conv kind
    dims = struct.unpack_from("<7I", raw, off + 1)
    assert dims == (1, 4, 4, 2, 3, 1, 1)


def test_dataset_bin_roundtrip(tmp_path):
    imgs = np.arange(8, dtype=np.int32).reshape(2, 4)
    labels = np.array([3, 1], np.int32)
    path = tmp_path / "d.bin"
    aot.write_dataset(str(path), imgs, labels)
    raw = path.read_bytes()
    assert raw[:8] == b"CIRCAD01"
    n, dim, classes = struct.unpack_from("<3I", raw, 8)
    assert (n, dim, classes) == (2, 4, 4)
    (veclen,) = struct.unpack_from("<Q", raw, 20)
    assert veclen == 8
    vals = struct.unpack_from("<8i", raw, 28)
    assert vals == tuple(range(8))
    y0, y1 = struct.unpack_from("<2I", raw, 28 + 32)
    assert (y0, y1) == (3, 1)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == "circa-artifacts-1"
    assert m["cnn_quantized_acc"] > 0.85, "demo CNN should be well-trained"
    for name in ("demo_cnn.hlo.txt", "demo_mlp.hlo.txt", "stoch_relu.hlo.txt",
                 "weights.bin", "weights_mlp.bin", "dataset.bin"):
        assert os.path.exists(os.path.join(root, name)), name

"""L2 correctness: the quantized JAX model vs a numpy oracle, plus the
fault-injection semantics the accuracy experiments rely on."""

import numpy as np
import jax.numpy as jnp
import pytest

import compile  # noqa: F401
from compile import data, model
from compile.kernels import ref


def _rand_params(rng):
    c = model.CNN_SHAPES
    w1 = rng.integers(-50, 50, (c["conv1"]["out_c"], 1, 3, 3)).astype(np.int32)
    b1 = rng.integers(-2000, 2000, (c["conv1"]["out_c"],)).astype(np.int32)
    w2 = rng.integers(-50, 50, (c["conv2"]["out_c"], c["conv2"]["in_c"], 3, 3)).astype(np.int32)
    b2 = rng.integers(-2000, 2000, (c["conv2"]["out_c"],)).astype(np.int32)
    w3 = rng.integers(-50, 50, (10, c["dense"]["in_dim"])).astype(np.int32)
    b3 = rng.integers(-2000, 2000, (10,)).astype(np.int32)
    return [jnp.asarray(v) for v in (w1, b1, w2, b2, w3, b3)]


def _np_conv(x, w, b, stride, pad):
    B, C, H, W = x.shape
    O, _, K, _ = w.shape
    oh = (H + 2 * pad - K) // stride + 1
    ow = (W + 2 * pad - K) // stride + 1
    xp = np.zeros((B, C, H + 2 * pad, W + 2 * pad), np.int64)
    xp[:, :, pad : pad + H, pad : pad + W] = x
    out = np.zeros((B, O, oh, ow), np.int64)
    for o in range(O):
        for yy in range(oh):
            for xx in range(ow):
                patch = xp[:, :, yy * stride : yy * stride + K, xx * stride : xx * stride + K]
                out[:, o, yy, xx] = np.einsum("bchw,chw->b", patch, w[o].astype(np.int64))
        out[:, o] += b[o]
    return out


def _np_forward_exact(params, images):
    w1, b1, w2, b2, w3, b3 = [np.asarray(p, np.int64) for p in params]
    x = _np_conv(images.astype(np.int64), w1, b1, 2, 1)
    x = np.maximum(x, 0) >> model.RESCALE
    x = _np_conv(x, w2, b2, 2, 1)
    x = np.maximum(x, 0) >> model.RESCALE
    x = x.reshape(x.shape[0], -1)
    return x @ w3.T + b3


def test_exact_mode_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    params = _rand_params(rng)
    images = jnp.asarray(rng.integers(0, 128, (4, 1, 16, 16)), jnp.int32)
    zt1 = jnp.zeros((4, 8, 8, 8), jnp.int32)
    zt2 = jnp.zeros((4, 16, 4, 4), jnp.int32)
    logits, faults = model.forward_cnn(images, zt1, zt2, 0, ref.MODE_EXACT, *params)
    want = _np_forward_exact(params, np.asarray(images))
    np.testing.assert_array_equal(np.asarray(logits, np.int64), want)
    assert np.asarray(faults).sum() == 0


def test_stochastic_agrees_with_exact_when_k_small():
    """k = 1 and comfortable magnitudes: faults are ~impossible, so the
    stochastic forward must equal the exact forward."""
    rng = np.random.default_rng(1)
    params = _rand_params(rng)
    images = jnp.asarray(rng.integers(64, 128, (4, 1, 16, 16)), jnp.int32)
    t1 = jnp.asarray(rng.integers(0, ref.PRIME, (4, 8, 8, 8)), jnp.int32)
    t2 = jnp.asarray(rng.integers(0, ref.PRIME, (4, 16, 4, 4)), jnp.int32)
    exact, _ = model.forward_cnn(images, t1, t2, 0, ref.MODE_EXACT, *params)
    stoch, faults = model.forward_cnn(images, t1, t2, 1, ref.MODE_POSZERO, *params)
    # Allow the rare activation that lands exactly in [0, 2): identical
    # in practice for this seed.
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(stoch))


def test_large_k_degrades_into_faults():
    rng = np.random.default_rng(2)
    params = _rand_params(rng)
    images = jnp.asarray(rng.integers(0, 128, (8, 1, 16, 16)), jnp.int32)
    t1 = jnp.asarray(rng.integers(0, ref.PRIME, (8, 8, 8, 8)), jnp.int32)
    t2 = jnp.asarray(rng.integers(0, ref.PRIME, (8, 16, 4, 4)), jnp.int32)
    _, faults = model.forward_cnn(images, t1, t2, 24, ref.MODE_POSZERO, *params)
    assert int(np.asarray(faults).sum()) > 100


def test_mlp_shapes_and_exact_mode():
    rng = np.random.default_rng(3)
    d = model.MLP_DIMS
    params = [
        jnp.asarray(rng.integers(-30, 30, (d[1], d[0])), jnp.int32),
        jnp.asarray(rng.integers(-500, 500, (d[1],)), jnp.int32),
        jnp.asarray(rng.integers(-30, 30, (d[2], d[1])), jnp.int32),
        jnp.asarray(rng.integers(-500, 500, (d[2],)), jnp.int32),
        jnp.asarray(rng.integers(-30, 30, (d[3], d[2])), jnp.int32),
        jnp.asarray(rng.integers(-500, 500, (d[3],)), jnp.int32),
    ]
    x = jnp.asarray(rng.integers(0, 128, (4, 256)), jnp.int32)
    t1 = jnp.zeros((4, 128), jnp.int32)
    t2 = jnp.zeros((4, 64), jnp.int32)
    logits, faults = model.forward_mlp(x, t1, t2, 0, ref.MODE_EXACT, *params)
    assert logits.shape == (4, 10)
    assert np.asarray(faults).shape == (2,)


def test_dataset_is_learnable_and_deterministic():
    a_imgs, a_labels = data.make_dataset(100, 42)
    b_imgs, b_labels = data.make_dataset(100, 42)
    np.testing.assert_array_equal(a_imgs, b_imgs)
    np.testing.assert_array_equal(a_labels, b_labels)
    assert a_imgs.shape == (100, 1, 16, 16)
    assert a_imgs.min() >= 0.0 and a_imgs.max() <= 1.5
    assert set(np.unique(a_labels)) <= set(range(10))


def test_quantize_input_scale():
    imgs = np.array([[[[0.0, 1.0], [0.5, 1.5]]]], np.float32)
    q = np.asarray(model.quantize_input(jnp.asarray(imgs)))
    s = 1 << model.INPUT_SCALE
    np.testing.assert_array_equal(q[0, 0], [[0, s], [s // 2, s + s // 2]])

//! Truncation sweep through the PJRT-compiled JAX model: how accuracy
//! and fault rate respond to `k` in both fault modes (the Fig. 4 shape,
//! interactive version).
//!
//! ```bash
//! make artifacts && cargo run --release --example sweep_truncation -- --batches 2
//! ```

use circa::field::{Fp, PRIME};
use circa::nn::weights::{accuracy, load_dataset};
use circa::runtime::model_exec::{MODE_EXACT, MODE_NEGPASS, MODE_POSZERO};
use circa::runtime::{ArtifactDir, CnnExecutable};
use circa::util::args::Args;
use circa::util::Rng;

fn main() {
    let args = Args::from_env();
    let n_batches = args.get_usize("batches", 2);
    let net = args.get_or("net", "cnn").to_string();

    let dir = ArtifactDir::discover().expect("run `make artifacts` first");
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let exe = if net == "mlp" {
        CnnExecutable::load_mlp(&client, &dir).unwrap()
    } else {
        CnnExecutable::load_cnn(&client, &dir).unwrap()
    };
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let b = exe.batch;
    let per_ex = exe.relus_per_example();
    let (n1, n2) = if per_ex == 768 { (512, 256) } else { (128, 64) };
    let mut rng = Rng::new(7);

    let mut point = |k: i32, mode: i32, rng: &mut Rng| -> (f64, f64) {
        let mut correct = 0.0;
        let mut faults = 0i64;
        for batch in 0..n_batches {
            let base = batch * b;
            let images: Vec<i32> = ds.images[base * ds.dim..(base + b) * ds.dim]
                .iter()
                .map(|f| f.to_i64() as i32)
                .collect();
            let t1: Vec<i32> = (0..b * n1).map(|_| rng.below(PRIME) as i32).collect();
            let t2: Vec<i32> = (0..b * n2).map(|_| rng.below(PRIME) as i32).collect();
            let out = exe.run(&images, &t1, &t2, k, mode).unwrap();
            let logits: Vec<Vec<Fp>> = (0..b)
                .map(|i| {
                    out.logits[i * 10..(i + 1) * 10]
                        .iter()
                        .map(|&v| Fp::from_i64(v as i64))
                        .collect()
                })
                .collect();
            correct += accuracy(&logits, &ds.labels[base..base + b]) * b as f64;
            faults += out.total_faults();
        }
        (correct / (n_batches * b) as f64, faults as f64 / (n_batches * b * per_ex) as f64)
    };

    let (exact_acc, _) = point(0, MODE_EXACT, &mut rng);
    println!(
        "net={net}  batches={n_batches}  baseline(exact) accuracy {:.2}%\n",
        exact_acc * 100.0
    );
    println!("{:>4}  {:>9} {:>8}   {:>9} {:>8}", "k", "PZ acc%", "PZ fr", "NP acc%", "NP fr");
    for k in (8..=24).step_by(2) {
        let (pa, pf) = point(k, MODE_POSZERO, &mut rng);
        let (na, nf) = point(k, MODE_NEGPASS, &mut rng);
        let marker = if exact_acc - pa <= 0.01 { " <= within 1%" } else { "" };
        println!(
            "{k:>4}  {:>8.2} {:>8.3}   {:>8.2} {:>8.3}{marker}",
            pa * 100.0,
            pf,
            na * 100.0,
            nf
        );
    }
}

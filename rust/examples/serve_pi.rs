//! End-to-end serving driver (EXPERIMENTS.md §E2E): load the *trained*
//! demo CNN from `artifacts/`, stand up **one multi-model PI serving
//! coordinator** registering two models over the same weights — Circa's
//! truncated stochastic ReLU and the baseline ReLU GC — push the real
//! test set through the full 2-party protocol against both, and report
//! a per-model table: accuracy, latency percentiles, throughput,
//! communication, bank depths, and dealing counters.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_pi -- --requests 64 --k 12
//! ```
//!
//! With `--dealer HOST:PORT` the material pool refills both models from
//! a standalone dealer over one TCP connection; that dealer must have
//! both plans registered (weight digests included) or the handshake is
//! rejected.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{ModelConfig, ModelSnapshot, PiService, ServiceConfig};

use circa::nn::weights::{load_dataset, load_weights};
use circa::protocol::server::NetworkPlan;
use circa::runtime::ArtifactDir;
use circa::util::args::Args;
use circa::util::Timer;
use std::sync::Arc;

/// Per-model client-side tallies (the service's metrics keep the
/// protocol-level view; accuracy needs the labels).
struct ModelReport {
    name: String,
    fingerprint: u64,
    requests: usize,
    correct: usize,
    latencies_ms: Vec<f64>,
    bytes: u64,
}

fn print_model_table(reports: &[ModelReport], rows: &[ModelSnapshot]) {
    println!("\n=== per-model serving report ===");
    for rep in reports {
        let row = rows.iter().find(|r| r.fingerprint == rep.fingerprint);
        println!("\n  model: {} (fingerprint {:#018x})", rep.name, rep.fingerprint);
        println!("    requests          : {}", rep.requests);
        println!(
            "    accuracy (private): {:.2}%",
            100.0 * rep.correct as f64 / rep.requests.max(1) as f64
        );
        println!(
            "    latency ms        : p50 {:.1}  p99 {:.1}  mean {:.1}",
            circa::util::stats::percentile(&rep.latencies_ms, 50.0),
            circa::util::stats::percentile(&rep.latencies_ms, 99.0),
            circa::util::stats::mean(&rep.latencies_ms)
        );
        println!("    online bytes/req  : {}", rep.bytes / rep.requests.max(1) as u64);
        let Some(row) = row else { continue };
        println!(
            "    served / dry      : {} completed, {} dry leases",
            row.completed, row.pool_dry_events
        );
        if row.deal_relus > 0 {
            println!(
                "    deal throughput   : {:.0} ReLUs/s per dealer slot ({} ReLUs dealt)",
                row.deal_relus_per_s, row.deal_relus
            );
        }
        if row.remote_refills > 0 {
            println!(
                "    remote refill     : {} fetches, {} layer units, {} sessions' worth, \
                 {:.2} MB on wire",
                row.remote_refills,
                row.layer_entries,
                row.remote_sessions,
                row.bytes_offline_wire as f64 / 1e6
            );
        }
        if !row.bank_depths.is_empty() {
            println!(
                "    bank depths       : spine {} | relu layers {:?}",
                row.bank_depths[0],
                &row.bank_depths[1..]
            );
        }
    }
}

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 48);
    let workers = args.get_usize("workers", 4);
    // Threads each inline deal's garble/triple columns fan out across
    // (material is identical for any value — see the column-wise offline
    // schedule).
    let deal_threads = args.get_usize("deal-threads", 1);
    let k = args.get_u64("k", 12) as u32;
    // Optional standalone dealer (see examples/dealer_serve.rs): the
    // material pool then refills over TCP instead of dealing inline —
    // the dealer must serve *both* registered models.
    let dealer_addr = args.get("dealer").map(|s| s.to_string());

    let dir = ArtifactDir::discover().expect("run `make artifacts` first");
    let net = load_weights(&dir.path("weights.bin")).expect("weights");
    let ds = load_dataset(&dir.path("dataset.bin")).expect("dataset");
    println!(
        "loaded {}: {} linear layers, {} ReLUs/inference, {} test images",
        net.name,
        net.layers.len(),
        net.total_relus(),
        ds.n
    );
    let q_acc = dir.manifest_f64("cnn_quantized_acc").unwrap_or(0.0);
    println!("plaintext quantized accuracy (exact ReLU): {:.2}%", q_acc * 100.0);

    // Two models over the same trained weights: Circa's truncated
    // stochastic sign and the baseline ReLU GC. One coordinator, one
    // material pool (per-model shards), one worker fabric.
    let circa_plan = Arc::new(NetworkPlan {
        linears: net.linears(),
        variant: ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
        rescale_bits: net.rescale_bits(),
    });
    let base_plan = Arc::new(NetworkPlan {
        linears: net.linears(),
        variant: ReluVariant::BaselineRelu,
        rescale_bits: net.rescale_bits(),
    });
    let svc = PiService::start_multi(
        vec![
            (circa_plan, ModelConfig::default()),
            (base_plan, ModelConfig::default()),
        ],
        ServiceConfig {
            workers,
            pool_target: 2 * n_requests.min(64),
            pool_dealers: workers,
            deal_threads,
            dealer_addr,
            ..Default::default()
        },
    )
    .expect("start multi-model service");
    let models = svc.models();
    let names =
        [format!("Circa ~sign_k (k={k}, PosZero)"), "baseline ReLU GC (Delphi/Gazelle)".into()];
    eprintln!("warming material banks (both models) ...");
    svc.warmup(n_requests.min(16));

    let t = Timer::new();
    // Interleave submissions across the two models — one fleet, mixed
    // traffic — and tally per model.
    let rxs: Vec<(usize, usize, _)> = (0..2 * n_requests)
        .map(|i| {
            let m = i % 2;
            let idx = (i / 2) % ds.n;
            (m, idx, svc.submit_to(models[m], ds.image(idx).to_vec()).expect("known model"))
        })
        .collect();
    let mut reports: Vec<ModelReport> = models
        .iter()
        .zip(names)
        .map(|(&fingerprint, name)| ModelReport {
            name,
            fingerprint,
            requests: 0,
            correct: 0,
            latencies_ms: Vec::new(),
            bytes: 0,
        })
        .collect();
    for (m, idx, rx) in rxs {
        let resp = rx.recv().expect("response");
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.to_i64())
            .map(|(c, _)| c as u32)
            .unwrap();
        let rep = &mut reports[m];
        rep.requests += 1;
        if pred == ds.labels[idx] {
            rep.correct += 1;
        }
        rep.latencies_ms.push((resp.queue_us + resp.online_us) as f64 / 1e3);
        rep.bytes += resp.bytes;
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();

    println!(
        "\nserved {} inferences across {} models in {:.2} s ({:.1} inf/s aggregate)",
        2 * n_requests,
        models.len(),
        wall,
        2.0 * n_requests as f64 / wall
    );
    println!(
        "fleet: produced {} sessions, dry leases {}, mis-tagged units dropped {}",
        svc.pool.produced(),
        snap.pool_dry_events,
        snap.fp_mismatch_drops
    );
    if snap.remote_refills > 0 {
        println!(
            "fleet remote refill: {} fetches, {:.2} MB on wire, fetch ms mean {:.1} p99 {:.1}",
            snap.remote_refills,
            snap.bytes_offline_wire as f64 / 1e6,
            snap.remote_refill_mean_us / 1e3,
            snap.remote_refill_p99_us as f64 / 1e3
        );
    }
    print_model_table(&reports, &snap.models);
    svc.shutdown();
}

//! End-to-end serving driver (EXPERIMENTS.md §E2E): stand up **one
//! multi-model PI serving coordinator** registering two models — Circa's
//! truncated stochastic ReLU and the baseline ReLU GC — and either
//! drive it in-process or expose it on a socket.
//!
//! ```bash
//! # In-process drive over the trained demo CNN (requires `make artifacts`):
//! cargo run --release --example serve_pi -- --requests 64 --k 12
//!
//! # Network serving tier (net::Reactor) over synthetic models — no
//! # artifacts needed; drive it with examples/pi_client.rs:
//! cargo run --release --example serve_pi -- --synthetic --listen 127.0.0.1:7117 --serve-secs 20
//! ```
//!
//! Flags: `--synthetic` swaps the artifact CNN for small random plans
//! built in-process (same two variants); `--listen ADDR` starts the
//! nonblocking reactor with bank-depth admission control instead of the
//! in-process driver (`--serve-secs N` bounds the run, 0 = until
//! killed; `--max-conns`, `--low-watermark`, `--high-watermark` tune
//! the edge). With `--dealer HOST:PORT[,HOST:PORT...]` the material
//! pool refills both models from a standalone dealer fleet — claims
//! partitioned and work-stolen across the live links; `--psk <32 hex
//! chars>` authenticates every link (AES-128-CMAC, shared with the
//! dealers).

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{ModelConfig, ModelSnapshot, PiService, ServiceConfig};
use circa::field::Fp;
use circa::net::{AdmitConfig, Reactor, ReactorConfig};
use circa::nn::weights::{load_dataset, load_weights};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::NetworkPlan;
use circa::runtime::ArtifactDir;
use circa::util::args::Args;
use circa::util::{Rng, Timer};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Per-model client-side tallies (the service's metrics keep the
/// protocol-level view; accuracy needs the labels).
struct ModelReport {
    name: String,
    fingerprint: u64,
    requests: usize,
    /// `None` when labels don't exist (synthetic inputs).
    correct: Option<usize>,
    latencies_ms: Vec<f64>,
    bytes: u64,
}

fn print_model_table(reports: &[ModelReport], rows: &[ModelSnapshot]) {
    println!("\n=== per-model serving report ===");
    for rep in reports {
        let row = rows.iter().find(|r| r.fingerprint == rep.fingerprint);
        println!("\n  model: {} (fingerprint {:#018x})", rep.name, rep.fingerprint);
        println!("    requests          : {}", rep.requests);
        match rep.correct {
            Some(correct) => println!(
                "    accuracy (private): {:.2}%",
                100.0 * correct as f64 / rep.requests.max(1) as f64
            ),
            None => println!("    accuracy (private): n/a (synthetic inputs)"),
        }
        if !rep.latencies_ms.is_empty() {
            println!(
                "    latency ms        : p50 {:.1}  p99 {:.1}  mean {:.1}",
                circa::util::stats::percentile(&rep.latencies_ms, 50.0),
                circa::util::stats::percentile(&rep.latencies_ms, 99.0),
                circa::util::stats::mean(&rep.latencies_ms)
            );
            println!("    online bytes/req  : {}", rep.bytes / rep.requests.max(1) as u64);
        }
        let Some(row) = row else { continue };
        println!(
            "    served / dry      : {} completed, {} dry leases, {} shed busy",
            row.completed, row.pool_dry_events, row.sheds
        );
        if row.deal_relus > 0 {
            println!(
                "    deal throughput   : {:.0} ReLUs/s per dealer slot ({} ReLUs dealt)",
                row.deal_relus_per_s, row.deal_relus
            );
        }
        if row.remote_refills > 0 {
            println!(
                "    remote refill     : {} fetches, {} layer units, {} sessions' worth, \
                 {:.2} MB on wire",
                row.remote_refills,
                row.layer_entries,
                row.remote_sessions,
                row.bytes_offline_wire as f64 / 1e6
            );
        }
        if !row.bank_depths.is_empty() {
            println!(
                "    bank depths       : spine {} | relu layers {:?}",
                row.bank_depths[0],
                &row.bank_depths[1..]
            );
        }
    }
}

/// Two small random plans over shared weights (Circa truncated sign +
/// baseline ReLU GC) for artifact-free runs.
fn synthetic_models(k: u32) -> (Vec<(Arc<NetworkPlan>, ModelConfig)>, usize) {
    let mut rng = Rng::new(0x5EED);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(12, 16, 10, &mut rng)),
        Arc::new(Matrix::random(10, 12, 10, &mut rng)),
    ];
    let in_dim = linears[0].in_dim();
    let circa_plan = Arc::new(NetworkPlan::unscaled(
        linears.clone(),
        ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
    ));
    let base_plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
    (
        vec![(circa_plan, ModelConfig::default()), (base_plan, ModelConfig::default())],
        in_dim,
    )
}

/// Serve on a socket: reactor + admission control, periodic status
/// lines, final per-model table with connection/shed/queue-depth rows.
fn run_listen(svc: Arc<PiService>, addr: &str, names: &[String], args: &Args) {
    let admit = AdmitConfig {
        low_watermark: args.get_usize("low-watermark", 1),
        high_watermark: args.get_usize("high-watermark", 2),
        ..AdmitConfig::default()
    };
    let cfg = ReactorConfig {
        max_connections: args.get_usize("max-conns", 1024),
        admit,
        ..ReactorConfig::default()
    };
    let reactor = Reactor::spawn(addr, svc.clone(), cfg).expect("bind serving address");
    println!("serving on {} (reactor up, admission control armed)", reactor.local_addr());
    let serve_secs = args.get_u64("serve-secs", 0);

    let t = Timer::new();
    let mut tick = 0u64;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        tick += 1;
        if tick % 5 == 0 {
            let s = &reactor.stats;
            println!(
                "[{:>4}s] conns open {} (accepted {}, over-cap {}), frames rx/tx {}/{}, \
                 shed {}, queue depth {}",
                t.elapsed_s() as u64,
                s.open.load(Ordering::Relaxed),
                s.accepted.load(Ordering::Relaxed),
                s.rejected_over_cap.load(Ordering::Relaxed),
                s.frames_rx.load(Ordering::Relaxed),
                s.frames_tx.load(Ordering::Relaxed),
                s.sheds.load(Ordering::Relaxed),
                svc.metrics.ingress_depth.load(Ordering::Relaxed)
            );
        }
        if serve_secs > 0 && t.elapsed_s() >= serve_secs as f64 {
            break;
        }
    }

    let s = &reactor.stats;
    println!(
        "\nreactor: {} accepted, {} over-cap rejects, {} closed ({} idle), {} proto errors, \
         {} shed busy",
        s.accepted.load(Ordering::Relaxed),
        s.rejected_over_cap.load(Ordering::Relaxed),
        s.closed.load(Ordering::Relaxed),
        s.idle_closed.load(Ordering::Relaxed),
        s.proto_errors.load(Ordering::Relaxed),
        s.sheds.load(Ordering::Relaxed),
    );
    let snap = svc.metrics.snapshot();
    println!(
        "fleet: {} completed, queue depth {}, {} shed, {} dry leases",
        snap.completed, snap.ingress_queue_depth, snap.sheds, snap.pool_dry_events
    );
    let reports: Vec<ModelReport> = svc
        .models()
        .iter()
        .zip(names)
        .map(|(&fingerprint, name)| {
            let row = snap.models.iter().find(|r| r.fingerprint == fingerprint);
            ModelReport {
                name: name.clone(),
                fingerprint,
                requests: row.map(|r| r.completed as usize).unwrap_or(0),
                correct: None,
                latencies_ms: Vec::new(),
                bytes: 0,
            }
        })
        .collect();
    print_model_table(&reports, &snap.models);
    reactor.shutdown();
    if let Ok(svc) = Arc::try_unwrap(svc) {
        svc.shutdown();
    }
}

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 48);
    let workers = args.get_usize("workers", 4);
    // Threads each inline deal's garble/triple columns fan out across
    // (material is identical for any value — see the column-wise offline
    // schedule).
    let deal_threads = args.get_usize("deal-threads", 1);
    let k = args.get_u64("k", 12) as u32;
    let synthetic = args.flag("synthetic");
    // Optional standalone dealer fleet (see examples/dealer_serve.rs):
    // the material pool then refills over TCP instead of dealing inline
    // — every dealer must serve *both* registered models.
    let dealer_addrs: Vec<String> = args
        .get("dealer")
        .map(|list| {
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
        })
        .unwrap_or_default();
    let dealer_psk = args
        .get("psk")
        .map(|s| circa::wire::parse_psk_hex(s).expect("--psk must be 32 hex chars"));

    // Model set + input source: the trained demo CNN from artifacts/, or
    // small in-process random plans (--synthetic, no artifacts needed).
    let (models_cfg, dataset) = if synthetic {
        let (models, in_dim) = synthetic_models(k);
        println!(
            "synthetic mode: 2 random plans ({} → … → 10), no artifacts",
            in_dim
        );
        (models, None)
    } else {
        let dir = ArtifactDir::discover().expect("run `make artifacts` (or pass --synthetic)");
        let net = load_weights(&dir.path("weights.bin")).expect("weights");
        let ds = load_dataset(&dir.path("dataset.bin")).expect("dataset");
        println!(
            "loaded {}: {} linear layers, {} ReLUs/inference, {} test images",
            net.name,
            net.layers.len(),
            net.total_relus(),
            ds.n
        );
        let q_acc = dir.manifest_f64("cnn_quantized_acc").unwrap_or(0.0);
        println!("plaintext quantized accuracy (exact ReLU): {:.2}%", q_acc * 100.0);
        // Two models over the same trained weights: Circa's truncated
        // stochastic sign and the baseline ReLU GC. One coordinator, one
        // material pool (per-model shards), one worker fabric.
        let circa_plan = Arc::new(NetworkPlan {
            linears: net.linears(),
            variant: ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
            rescale_bits: net.rescale_bits(),
        });
        let base_plan = Arc::new(NetworkPlan {
            linears: net.linears(),
            variant: ReluVariant::BaselineRelu,
            rescale_bits: net.rescale_bits(),
        });
        (
            vec![
                (circa_plan, ModelConfig::default()),
                (base_plan, ModelConfig::default()),
            ],
            Some(ds),
        )
    };
    let in_dim = models_cfg[0].0.linears[0].in_dim();

    let svc = Arc::new(
        PiService::start_multi(models_cfg, ServiceConfig {
            workers,
            pool_target: 2 * n_requests.min(64),
            pool_dealers: workers,
            deal_threads,
            dealer_addrs,
            dealer_psk,
            ..Default::default()
        })
        .expect("start multi-model service"),
    );
    let models = svc.models();
    let names =
        vec![format!("Circa ~sign_k (k={k}, PosZero)"), "baseline ReLU GC (Delphi/Gazelle)".into()];
    eprintln!("warming material banks (both models) ...");
    svc.warmup(n_requests.min(16));

    if let Some(addr) = args.get("listen") {
        run_listen(svc, addr, &names, &args);
        return;
    }

    // In-process drive: interleave submissions across the two models —
    // one fleet, mixed traffic — and tally per model.
    let mut rng = Rng::new(7);
    let input_for = |i: usize, rng: &mut Rng| -> Vec<Fp> {
        match &dataset {
            Some(ds) => ds.image(i % ds.n).to_vec(),
            None => (0..in_dim).map(|_| Fp::from_i64(rng.below(4000) as i64 - 2000)).collect(),
        }
    };
    let t = Timer::new();
    let rxs: Vec<(usize, usize, _)> = (0..2 * n_requests)
        .map(|i| {
            let m = i % 2;
            let idx = i / 2;
            let input = input_for(idx, &mut rng);
            (m, idx, svc.submit_to(models[m], input).expect("known model"))
        })
        .collect();
    let mut reports: Vec<ModelReport> = models
        .iter()
        .zip(names)
        .map(|(&fingerprint, name)| ModelReport {
            name,
            fingerprint,
            requests: 0,
            correct: dataset.as_ref().map(|_| 0),
            latencies_ms: Vec::new(),
            bytes: 0,
        })
        .collect();
    for (m, idx, rx) in rxs {
        let resp = rx.recv().expect("response");
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.to_i64())
            .map(|(c, _)| c as u32)
            .unwrap();
        let rep = &mut reports[m];
        rep.requests += 1;
        if let (Some(ds), Some(correct)) = (&dataset, &mut rep.correct) {
            if pred == ds.labels[idx % ds.n] {
                *correct += 1;
            }
        }
        rep.latencies_ms.push((resp.queue_us + resp.online_us) as f64 / 1e3);
        rep.bytes += resp.bytes;
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();

    println!(
        "\nserved {} inferences across {} models in {:.2} s ({:.1} inf/s aggregate)",
        2 * n_requests,
        models.len(),
        wall,
        2.0 * n_requests as f64 / wall
    );
    println!(
        "fleet: produced {} sessions, dry leases {}, mis-tagged units dropped {}, \
         queue depth {}, shed {}",
        svc.pool.produced(),
        snap.pool_dry_events,
        snap.fp_mismatch_drops,
        snap.ingress_queue_depth,
        snap.sheds
    );
    if snap.remote_refills > 0 {
        println!(
            "fleet remote refill: {} fetches, {:.2} MB on wire, fetch ms mean {:.1} p99 {:.1}",
            snap.remote_refills,
            snap.bytes_offline_wire as f64 / 1e6,
            snap.remote_refill_mean_us / 1e3,
            snap.remote_refill_p99_us as f64 / 1e3
        );
    }
    print_model_table(&reports, &snap.models);
    if let Ok(svc) = Arc::try_unwrap(svc) {
        svc.shutdown();
    }
}

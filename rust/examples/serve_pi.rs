//! End-to-end serving driver (EXPERIMENTS.md §E2E): load the *trained*
//! demo CNN from `artifacts/`, stand up the PI serving coordinator
//! (offline-material bank + batcher + worker pool), push the real test
//! set through the full 2-party protocol, and report accuracy,
//! latency percentiles, throughput, and communication — for baseline
//! ReLU GCs vs Circa's truncated stochastic ReLUs.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_pi -- --requests 64 --k 12
//! ```

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{PiService, ServiceConfig};

use circa::nn::weights::{load_dataset, load_weights};
use circa::protocol::server::NetworkPlan;
use circa::runtime::ArtifactDir;
use circa::util::args::Args;
use circa::util::Timer;
use std::sync::Arc;

#[allow(clippy::too_many_arguments)]
fn run_variant(
    name: &str,
    variant: ReluVariant,
    rescale_bits: Vec<u32>,
    linears: Vec<Arc<dyn circa::protocol::linear::LinearOp>>,
    dataset: &circa::nn::weights::Dataset,
    n_requests: usize,
    workers: usize,
    deal_threads: usize,
    dealer_addr: Option<String>,
) {
    println!("\n=== serving with {name} ===");
    let plan = Arc::new(NetworkPlan { linears, variant, rescale_bits });
    let svc = PiService::start(
        plan,
        ServiceConfig {
            workers,
            pool_target: 2 * n_requests.min(64),
            pool_dealers: workers,
            deal_threads,
            dealer_addr,
            ..Default::default()
        },
    );
    eprintln!("warming material bank ...");
    svc.warmup(n_requests.min(16));

    let t = Timer::new();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            let idx = i % dataset.n;
            svc.submit(dataset.image(idx).to_vec())
        })
        .collect();
    let mut correct = 0;
    let mut latencies = Vec::new();
    let mut bytes = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.to_i64())
            .map(|(c, _)| c as u32)
            .unwrap();
        if pred == dataset.labels[i % dataset.n] {
            correct += 1;
        }
        latencies.push((resp.queue_us + resp.online_us) as f64 / 1e3);
        bytes += resp.bytes;
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();

    println!("  requests          : {n_requests}");
    println!("  accuracy (private): {:.2}%", 100.0 * correct as f64 / n_requests as f64);
    println!("  throughput        : {:.1} inf/s", n_requests as f64 / wall);
    println!(
        "  latency ms        : p50 {:.1}  p99 {:.1}  mean {:.1}",
        circa::util::stats::percentile(&latencies, 50.0),
        circa::util::stats::percentile(&latencies, 99.0),
        circa::util::stats::mean(&latencies)
    );
    println!("  online bytes/req  : {}", bytes / n_requests as u64);
    println!(
        "  bank: produced {} sessions, dry leases {}",
        svc.pool.produced(),
        snap.pool_dry_events
    );
    if snap.deal_relus > 0 {
        println!(
            "  deal throughput   : {:.0} ReLUs/s per dealer slot ({} ReLUs dealt locally)",
            snap.deal_relus_per_s, snap.deal_relus
        );
    }
    if snap.pool_dry_events > 0 {
        println!(
            "  dry inline-deal ms: mean {:.1}  p99 {:.1}",
            snap.dry_deal_mean_us / 1e3,
            snap.dry_deal_p99_us as f64 / 1e3
        );
    }
    if snap.remote_refills > 0 {
        println!(
            "  remote refill     : {} fetches, {} layer units, {} sessions' worth, \
             {:.2} MB on wire",
            snap.remote_refills,
            snap.layer_entries,
            snap.remote_sessions,
            snap.bytes_offline_wire as f64 / 1e6
        );
        println!(
            "  refill fetch ms   : mean {:.1}  p99 {:.1}",
            snap.remote_refill_mean_us / 1e3,
            snap.remote_refill_p99_us as f64 / 1e3
        );
    }
    if !snap.bank_depths.is_empty() {
        println!(
            "  bank depths       : spine {} | relu layers {:?}",
            snap.bank_depths[0],
            &snap.bank_depths[1..]
        );
    }
    svc.shutdown();
}

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 48);
    let workers = args.get_usize("workers", 4);
    // Threads each inline deal's garble columns fan out across (material
    // is identical for any value — see the column-wise offline schedule).
    let deal_threads = args.get_usize("deal-threads", 1);
    let k = args.get_u64("k", 12) as u32;
    // Optional standalone dealer (see examples/dealer_serve.rs): the
    // material pool then refills over TCP instead of dealing inline.
    let dealer_addr = args.get("dealer").map(|s| s.to_string());

    let dir = ArtifactDir::discover().expect("run `make artifacts` first");
    let net = load_weights(&dir.path("weights.bin")).expect("weights");
    let ds = load_dataset(&dir.path("dataset.bin")).expect("dataset");
    println!(
        "loaded {}: {} linear layers, {} ReLUs/inference, {} test images",
        net.name,
        net.layers.len(),
        net.total_relus(),
        ds.n
    );
    let q_acc = dir.manifest_f64("cnn_quantized_acc").unwrap_or(0.0);
    println!("plaintext quantized accuracy (exact ReLU): {:.2}%", q_acc * 100.0);

    run_variant(
        &format!("Circa ~sign_k (k={k}, PosZero)"),
        ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
        net.rescale_bits(),
        net.linears(),
        &ds,
        n_requests,
        workers,
        deal_threads,
        dealer_addr.clone(),
    );
    run_variant(
        "baseline ReLU GC (Delphi/Gazelle)",
        ReluVariant::BaselineRelu,
        net.rescale_bits(),
        net.linears(),
        &ds,
        n_requests,
        workers,
        deal_threads,
        // The dealer serves one plan; the baseline pass deals inline.
        None,
    );
}

//! Load generator for the network serving tier (`serve_pi --listen`).
//!
//! Opens `--conns` concurrent connections, learns the served model set
//! from the hello advertisement (input dims included — no out-of-band
//! plan knowledge), and drives `--requests` pipelined inferences per
//! connection round-robin across the advertised models. Reports
//! throughput, latency percentiles, and the shed (`Busy`) rate.
//!
//! ```bash
//! cargo run --release --example serve_pi -- --synthetic --listen 127.0.0.1:7117 &
//! cargo run --release --example pi_client -- --addr 127.0.0.1:7117 --conns 8 --requests 64
//! ```
//!
//! Flags: `--depth` bounds in-flight requests per connection;
//! `--connect-retries` retries `Busy`-at-capacity connects (the
//! reactor's connection cap is an explicit signal, not an error).

use circa::field::Fp;
use circa::net::{Outcome, PiClient};
use circa::util::args::Args;
use circa::util::{Rng, Timer};
use std::collections::HashMap;
use std::time::{Duration, Instant};

#[derive(Default)]
struct Tally {
    ok: u64,
    shed: u64,
    latencies_ms: Vec<f64>,
    bytes: u64,
    from_bank: u64,
}

fn drive(addr: &str, conn_id: u64, requests: usize, depth: usize, retries: usize) -> Tally {
    let mut client = None;
    for attempt in 0..=retries {
        match PiClient::connect(addr) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(e) if attempt < retries && e.to_string().contains("busy") => {
                std::thread::sleep(Duration::from_millis(50 << attempt));
            }
            Err(e) => {
                eprintln!("conn {conn_id}: connect failed: {e}");
                return Tally::default();
            }
        }
    }
    let Some(mut client) = client else { return Tally::default() };
    let ads: Vec<_> = client.models().to_vec();
    assert!(!ads.is_empty(), "server advertised no models");

    let mut rng = Rng::new(0xC11E27 ^ conn_id);
    let mut tally = Tally::default();
    let mut in_flight: HashMap<u64, Instant> = HashMap::new();
    let mut sent = 0usize;
    let mut done = 0usize;
    while done < requests {
        // Keep the pipeline full, then block for one response.
        while sent < requests && in_flight.len() < depth {
            let ad = ads[sent % ads.len()];
            let input: Vec<Fp> = (0..ad.in_dim)
                .map(|_| Fp::from_i64(rng.below(4000) as i64 - 2000))
                .collect();
            match client.send_infer(ad.fingerprint, &input) {
                Ok(req_id) => {
                    in_flight.insert(req_id, Instant::now());
                    sent += 1;
                }
                Err(e) => {
                    eprintln!("conn {conn_id}: send failed: {e}");
                    return tally;
                }
            }
        }
        let outcome = match client.recv_outcome() {
            Ok(o) => o,
            Err(e) => {
                eprintln!("conn {conn_id}: recv failed: {e}");
                return tally;
            }
        };
        done += 1;
        match outcome {
            Outcome::Logits(l) => {
                if let Some(t0) = in_flight.remove(&l.req_id) {
                    tally.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
                tally.ok += 1;
                tally.bytes += l.stats.bytes;
                tally.from_bank += l.stats.served_from_bank as u64;
            }
            Outcome::Busy(b) => {
                in_flight.remove(&b.req_id);
                tally.shed += 1;
            }
        }
    }
    let _ = client.bye();
    tally
}

fn main() {
    let args = Args::from_env();
    let addr = args.get_or("addr", "127.0.0.1:7117").to_string();
    let conns = args.get_usize("conns", 8);
    let requests = args.get_usize("requests", 32);
    let depth = args.get_usize("depth", 4).max(1);
    let retries = args.get_usize("connect-retries", 3);

    println!(
        "driving {addr}: {conns} connections × {requests} requests (pipeline depth {depth})"
    );
    let t = Timer::new();
    let handles: Vec<_> = (0..conns)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || drive(&addr, c as u64, requests, depth, retries))
        })
        .collect();
    let mut total = Tally::default();
    for h in handles {
        let t = h.join().expect("client thread");
        total.ok += t.ok;
        total.shed += t.shed;
        total.bytes += t.bytes;
        total.from_bank += t.from_bank;
        total.latencies_ms.extend(t.latencies_ms);
    }
    let wall = t.elapsed_s();

    let answered = total.ok + total.shed;
    println!(
        "\n{} answered in {:.2} s ({:.1} resp/s): {} served, {} shed busy ({:.1}%)",
        answered,
        wall,
        answered as f64 / wall.max(1e-9),
        total.ok,
        total.shed,
        100.0 * total.shed as f64 / answered.max(1) as f64
    );
    if !total.latencies_ms.is_empty() {
        println!(
            "latency ms: p50 {:.2}  p99 {:.2}  mean {:.2}",
            circa::util::stats::percentile(&total.latencies_ms, 50.0),
            circa::util::stats::percentile(&total.latencies_ms, 99.0),
            circa::util::stats::mean(&total.latencies_ms)
        );
    }
    if total.ok > 0 {
        println!(
            "online bytes/req: {}; served from bank: {}/{}",
            total.bytes / total.ok,
            total.from_bank,
            total.ok
        );
    }
    // A fully-shed run still exits 0: Busy is the protocol working as
    // designed. Transport-level failures already printed per connection.
    if answered == 0 {
        eprintln!("no responses at all — is the server up?");
        std::process::exit(1);
    }
}

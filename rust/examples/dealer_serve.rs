//! Two-process private inference: offline material produced by a
//! standalone dealer and streamed to the serving coordinator over the
//! wire codec — the deployment split the paper's storage numbers are
//! about (the dealer owns the offline phase; the server only spends).
//! The coordinator's material pool refills **layer by layer** (seq-
//! addressed `RequestLayers` rounds into per-layer banks), so the
//! largest frame on the wire is one layer batch, never a whole session.
//!
//! Modes:
//!
//! ```bash
//! # One-process demo: in-memory channel, then a real TCP socket on
//! # localhost with a self-spawned dealer.
//! cargo run --release --example dealer_serve
//!
//! # Two real processes:
//! cargo run --release --example dealer_serve -- --listen 127.0.0.1:7700   # dealer
//! cargo run --release --example dealer_serve -- --dealer 127.0.0.1:7700   # coordinator
//! ```
//!
//! Both processes derive the same demo plan from `--plan-seed` (default
//! 0xC1CA): the manifest handshake verifies the structure (variant, layer
//! dims, rescale schedule); weight equality comes from the shared seed.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{PiService, ServiceConfig};
use circa::field::Fp;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{run_inference, NetworkPlan};
use circa::util::args::Args;
use circa::util::{Rng, Timer};
use circa::wire::dealer::{deal_session, spawn_mem_dealer, spawn_tcp_dealer, RemoteDealer};
use circa::wire::SessionManifest;
use std::sync::Arc;

/// The shared demo plan: a tiny CNN-shaped stack (6 → 5 → relu → 5 → 4 →
/// relu → 4 → 3) with Circa's truncated stochastic sign. Both processes
/// must build it from the same seed.
fn demo_plan(plan_seed: u64, k: u32) -> Arc<NetworkPlan> {
    let mut rng = Rng::new(plan_seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
    ))
}

/// Exact-ReLU plaintext oracle over the same field arithmetic.
fn oracle(plan: &NetworkPlan, input: &[Fp]) -> Vec<Fp> {
    let mut y = input.to_vec();
    for (i, op) in plan.linears.iter().enumerate() {
        y = op.apply(&y);
        if i + 1 < plan.linears.len() {
            y = y.iter().map(|&v| circa::field::relu_exact(v)).collect();
        }
    }
    y
}

fn demo_input(i: usize) -> Vec<Fp> {
    (0..6).map(|j| Fp::from_i64(1000 + (37 * i + 13 * j) as i64)).collect()
}

/// Phase 1: dealer behind an in-memory duplex channel, and proof that
/// wire-delivered material is bit-equivalent to the inline deal.
fn mem_channel_demo(plan: &Arc<NetworkPlan>, dealer_seed: u64, deal_threads: usize) {
    println!("\n--- phase 1: in-memory channel ({deal_threads} deal threads) ---");
    let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), dealer_seed, deal_threads);
    let mut dealer = RemoteDealer::connect(chan, plan.clone()).expect("mem handshake");
    let n = 3;
    let t = Timer::new();
    let sessions = dealer.fetch(n).expect("fetch sessions");
    let fetch_s = t.elapsed_s();
    let wire_bytes = dealer.bytes_received();
    println!(
        "fetched {n} sessions in {:.1} ms ({} B on wire, {} B/session)",
        fetch_s * 1e3,
        wire_bytes,
        wire_bytes / n as u64
    );

    // Same dealer seed replayed inline (single-threaded) ⇒ the wire path
    // must reproduce the inline path bit for bit, down to the inference
    // transcript — whatever thread count the dealer used (the column-wise
    // RNG schedule makes deals thread-count-invariant).
    let mut inline_rng = Rng::new(dealer_seed);
    let mut identical = 0;
    for (i, session) in sessions.iter().enumerate() {
        let inline = deal_session(plan, &mut inline_rng);
        let input = demo_input(i);
        let (wire_logits, _) = run_inference(&session.client, &session.server, &input);
        let (inline_logits, _) = run_inference(&inline.client, &inline.server, &input);
        assert_eq!(wire_logits, inline_logits, "wire vs inline session {i}");
        identical += 1;
    }
    println!("wire-delivered material == inline deal: {identical}/{n} sessions bit-identical");
    dealer.close();
    let _ = dealer_thread.join();
}

/// Phase 2: the serving coordinator pointed at a dealer address — the
/// material pool refills over a real TCP socket.
fn tcp_serving_demo(plan: &Arc<NetworkPlan>, addr: &str, n_requests: usize) {
    println!("\n--- phase 2: coordinator against dealer at {addr} ---");
    let svc = PiService::start(
        plan.clone(),
        ServiceConfig {
            workers: 2,
            pool_target: 8,
            pool_dealers: 2,
            dealer_addr: Some(addr.to_string()),
            ..Default::default()
        },
    );
    svc.warmup(4);
    println!("material bank warmed from remote dealer ({} sessions banked)", svc.pool.banked());

    let t = Timer::new();
    let rxs: Vec<_> = (0..n_requests).map(|i| svc.submit(demo_input(i))).collect();
    let mut exact = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        if resp.logits == oracle(plan, &demo_input(i)) {
            exact += 1;
        }
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();
    let rate = n_requests as f64 / wall;
    println!("served {n_requests} inferences in {wall:.2} s ({rate:.1} inf/s)");
    println!("matches exact-ReLU oracle: {exact}/{n_requests} (Circa faults only |x| < 2^k)");
    println!(
        "remote refill: {} fetches, {} layer units ({} sessions' worth), \
         {:.2} MB offline material on wire",
        snap.remote_refills,
        snap.layer_entries,
        snap.remote_sessions,
        snap.bytes_offline_wire as f64 / 1e6
    );
    println!(
        "refill fetch ms: mean {:.1}  p99 {:.1}   (pool dry leases: {})",
        snap.remote_refill_mean_us / 1e3,
        snap.remote_refill_p99_us as f64 / 1e3,
        snap.pool_dry_events
    );
    if !snap.bank_depths.is_empty() {
        println!(
            "bank depths after serving: spine {} | relu layers {:?}",
            snap.bank_depths[0],
            &snap.bank_depths[1..]
        );
    }
    svc.shutdown();
}

fn main() {
    let args = Args::from_env();
    let plan_seed = args.get_u64("plan-seed", 0xC1CA);
    let dealer_seed = args.get_u64("dealer-seed", 0xDEA1);
    let k = args.get_u64("k", 4) as u32;
    let n_requests = args.get_usize("requests", 16);
    // Threads each dealt session's garble columns fan out across.
    let deal_threads = args.get_usize("deal-threads", 4);
    let plan = demo_plan(plan_seed, k);
    let manifest = SessionManifest::of_plan(&plan);
    println!(
        "demo plan: {} linears, variant {}, manifest fingerprint {:#018x}",
        plan.linears.len(),
        plan.variant.name(),
        manifest.fingerprint
    );

    if let Some(addr) = args.get("listen") {
        // Dealer process: serve until killed.
        let handle = spawn_tcp_dealer(addr, plan, dealer_seed, deal_threads).expect("bind dealer");
        println!(
            "dealer listening on {} ({deal_threads} deal threads; ctrl-c to stop)",
            handle.addr()
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    if let Some(addr) = args.get("dealer") {
        // Coordinator process against an external dealer.
        tcp_serving_demo(&plan, addr, n_requests);
        return;
    }

    // Default: full single-process walkthrough — in-memory channel first,
    // then a self-spawned dealer on a real localhost TCP socket.
    mem_channel_demo(&plan, dealer_seed, deal_threads);
    let handle = spawn_tcp_dealer("127.0.0.1:0", plan.clone(), dealer_seed, deal_threads)
        .expect("bind dealer");
    let addr = handle.addr().to_string();
    println!("\nspawned TCP dealer on {addr}");
    tcp_serving_demo(&plan, &addr, n_requests);
    handle.stop();
    println!("\ndone: private inference served end-to-end with material from another process.");
}

//! Two-process **multi-model** private inference: offline material for
//! two architectures produced by one standalone dealer and streamed to
//! the serving coordinator over the wire codec — the deployment split
//! the paper's storage numbers are about (the dealer owns the offline
//! phase; the server only spends). The coordinator's material pool
//! refills **layer by layer, per model** (fingerprint-addressed
//! `RequestLayers` rounds into per-model, per-layer banks), so one
//! connection feeds every registered model and the largest frame on the
//! wire is one layer batch, never a whole session.
//!
//! Modes:
//!
//! ```bash
//! # One-process demo: in-memory channel, then a two-dealer fleet on
//! # real localhost TCP sockets serving both demo models.
//! cargo run --release --example dealer_serve
//!
//! # Real processes (a fleet: N dealers + one coordinator):
//! cargo run --release --example dealer_serve -- --listen 127.0.0.1:7700   # dealer 1
//! cargo run --release --example dealer_serve -- --listen 127.0.0.1:7701   # dealer 2
//! cargo run --release --example dealer_serve -- \
//!     --dealer 127.0.0.1:7700,127.0.0.1:7701                              # coordinator
//! ```
//!
//! Add `--psk <32 hex chars>` to both sides for AES-128-CMAC
//! authenticated dealer links (key disagreement fails the handshake).
//! The coordinator partitions refill claims across all live dealers,
//! steals stale claims onto idle links, and hands a dead dealer's
//! claims off to the survivors — kill one dealer mid-run and the run
//! completes from the rest.
//!
//! Both processes derive the same demo registry from `--plan-seed`
//! (default 0xC1CA): the manifest-set handshake verifies every model's
//! structure *and* weight digest; per-model dealing base seeds are
//! derived with [`model_base_seed`] from `--dealer-seed`, so the two
//! models' seq spaces never collide.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{model_base_seed, ModelConfig, ModelRegistry, PiService, ServiceConfig};
use circa::field::Fp;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{run_inference, NetworkPlan};
use circa::util::args::Args;
use circa::util::{Rng, Timer};
use circa::wire::dealer::{
    deal_session, spawn_mem_dealer_multi, spawn_tcp_dealer_multi_psk, RemoteDealer,
};
use circa::wire::{parse_psk_hex, SessionManifest};
use std::sync::Arc;

/// Demo model 1: a tiny CNN-shaped stack (6 → 5 → relu → 5 → 4 → relu →
/// 4 → 3) with Circa's truncated stochastic sign.
fn demo_plan(plan_seed: u64, k: u32) -> Arc<NetworkPlan> {
    let mut rng = Rng::new(plan_seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
    ))
}

/// Demo model 2: a shallower stack (6 → 4 → relu → 4 → 3) with k=0
/// (exact stochastic sign) — a second architecture the same dealer and
/// coordinator serve concurrently.
fn demo_plan_2(plan_seed: u64) -> Arc<NetworkPlan> {
    let mut rng = Rng::new(plan_seed ^ 0x5EC0);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(4, 6, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 0, mode: FaultMode::PosZero },
    ))
}

/// Both processes build this registry identically from the shared
/// seeds: fingerprints come from the plans, per-model dealing base
/// seeds from `model_base_seed(dealer_seed, fingerprint)`.
fn demo_registry(plan_seed: u64, dealer_seed: u64, k: u32) -> Arc<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    for plan in [demo_plan(plan_seed, k), demo_plan_2(plan_seed)] {
        let manifest = SessionManifest::of_plan(&plan);
        let seed = model_base_seed(dealer_seed, manifest.fingerprint);
        reg.register_with(plan, manifest, seed, 1.0).expect("register demo plan");
    }
    Arc::new(reg)
}

/// Exact-ReLU plaintext oracle over the same field arithmetic.
fn oracle(plan: &NetworkPlan, input: &[Fp]) -> Vec<Fp> {
    let mut y = input.to_vec();
    for (i, op) in plan.linears.iter().enumerate() {
        y = op.apply(&y);
        if i + 1 < plan.linears.len() {
            y = y.iter().map(|&v| circa::field::relu_exact(v)).collect();
        }
    }
    y
}

fn demo_input(i: usize) -> Vec<Fp> {
    (0..6).map(|j| Fp::from_i64(1000 + (37 * i + 13 * j) as i64)).collect()
}

/// Phase 1: dealer behind an in-memory duplex channel, and proof that
/// wire-delivered material is bit-equivalent to the inline deal —
/// fetched per model over one connection.
fn mem_channel_demo(registry: &Arc<ModelRegistry>, dealer_seed: u64, deal_threads: usize) {
    println!("\n--- phase 1: in-memory channel ({deal_threads} deal threads) ---");
    let (chan, dealer_thread) = spawn_mem_dealer_multi(registry.clone(), dealer_seed, deal_threads);
    let mut dealer = RemoteDealer::connect(chan, registry.clone()).expect("mem handshake");
    let fp1 = registry.fingerprints()[0];
    let plan1 = registry.get(fp1).unwrap().plan.clone();
    let n = 3;
    let t = Timer::new();
    let sessions = dealer.fetch(fp1, n).expect("fetch sessions");
    let fetch_s = t.elapsed_s();
    let wire_bytes = dealer.bytes_received();
    println!(
        "fetched {n} sessions of model {fp1:#018x} in {:.1} ms ({} B on wire, {} B/session)",
        fetch_s * 1e3,
        wire_bytes,
        wire_bytes / n as u64
    );

    // Same dealer seed replayed inline (single-threaded) ⇒ the wire path
    // must reproduce the inline path bit for bit, down to the inference
    // transcript — whatever thread count the dealer used (the column-wise
    // RNG schedule makes deals thread-count-invariant).
    let mut inline_rng = Rng::new(dealer_seed);
    let mut identical = 0;
    for (i, session) in sessions.iter().enumerate() {
        let inline = deal_session(&plan1, &mut inline_rng);
        let input = demo_input(i);
        let (wire_logits, _) = run_inference(&session.client, &session.server, &input);
        let (inline_logits, _) = run_inference(&inline.client, &inline.server, &input);
        assert_eq!(wire_logits, inline_logits, "wire vs inline session {i}");
        identical += 1;
    }
    println!("wire-delivered material == inline deal: {identical}/{n} sessions bit-identical");
    dealer.close();
    let _ = dealer_thread.join();
}

/// Phase 2: the serving coordinator pointed at a dealer fleet — both
/// models' material pools refill over the live TCP links, claims
/// partitioned and work-stolen across them.
fn tcp_serving_demo(
    registry: &Arc<ModelRegistry>,
    addrs: &[String],
    psk: Option<[u8; 16]>,
    n_requests: usize,
) {
    println!("\n--- phase 2: multi-model coordinator against dealer fleet {addrs:?} ---");
    let models: Vec<(Arc<NetworkPlan>, ModelConfig)> = registry
        .entries()
        .iter()
        .map(|e| {
            (e.plan.clone(), ModelConfig { base_seed: Some(e.base_seed), demand: e.demand })
        })
        .collect();
    let svc = PiService::start_multi(models, ServiceConfig {
        workers: 2,
        pool_target: 8,
        pool_dealers: 2,
        dealer_addrs: addrs.to_vec(),
        dealer_psk: psk,
        ..Default::default()
    })
    .expect("start multi-model service");
    svc.warmup(4);
    let fps = svc.models();
    println!(
        "material banks warmed from remote dealer ({} models, {} sessions banked each min)",
        fps.len(),
        svc.pool.banked()
    );

    let t = Timer::new();
    // Mixed traffic: alternate requests across the two models.
    let rxs: Vec<(usize, usize, _)> = (0..n_requests)
        .map(|i| {
            let m = i % fps.len();
            (m, i, svc.submit_to(fps[m], demo_input(i)).expect("known model"))
        })
        .collect();
    let mut exact = vec![0usize; fps.len()];
    let mut served = vec![0usize; fps.len()];
    for (m, i, rx) in rxs {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.model, fps[m], "response routed back with its model");
        served[m] += 1;
        let plan = &svc.pool.registry().get(fps[m]).unwrap().plan;
        if resp.logits == oracle(plan, &demo_input(i)) {
            exact[m] += 1;
        }
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();
    let rate = n_requests as f64 / wall;
    println!("served {n_requests} inferences in {wall:.2} s ({rate:.1} inf/s, mixed traffic)");
    for (m, fp) in fps.iter().enumerate() {
        let row = snap.models.iter().find(|r| r.fingerprint == *fp);
        println!(
            "  model {fp:#018x}: {}/{} match exact-ReLU oracle (Circa faults only |x| < 2^k)",
            exact[m], served[m]
        );
        if let Some(row) = row {
            println!(
                "    {} completed, {} layer units fetched, {:.2} MB on wire, bank depths {:?}",
                row.completed,
                row.layer_entries,
                row.bytes_offline_wire as f64 / 1e6,
                row.bank_depths
            );
        }
    }
    println!(
        "fleet remote refill: {} fetches, fetch ms mean {:.1} p99 {:.1} (dry leases {}, \
         mis-tagged drops {})",
        snap.remote_refills,
        snap.remote_refill_mean_us / 1e3,
        snap.remote_refill_p99_us as f64 / 1e3,
        snap.pool_dry_events,
        snap.fp_mismatch_drops
    );
    svc.shutdown();
}

fn main() {
    let args = Args::from_env();
    let plan_seed = args.get_u64("plan-seed", 0xC1CA);
    let dealer_seed = args.get_u64("dealer-seed", 0xDEA1);
    let k = args.get_u64("k", 4) as u32;
    let n_requests = args.get_usize("requests", 16);
    // Threads each dealt session's garble/triple columns fan out across.
    let deal_threads = args.get_usize("deal-threads", 4);
    let psk = args.get("psk").map(|s| parse_psk_hex(s).expect("--psk must be 32 hex chars"));
    let registry = demo_registry(plan_seed, dealer_seed, k);
    println!("demo registry ({} models):", registry.len());
    for e in registry.entries() {
        println!(
            "  {:#018x}: {} linears, variant {}, base seed {:#018x}",
            e.fingerprint(),
            e.plan.linears.len(),
            e.plan.variant.name(),
            e.base_seed
        );
    }

    if let Some(addr) = args.get("listen") {
        // Dealer process: serve until killed.
        let handle = spawn_tcp_dealer_multi_psk(addr, registry, dealer_seed, deal_threads, psk)
            .expect("bind dealer");
        println!(
            "dealer listening on {} ({deal_threads} deal threads, psk {}; ctrl-c to stop)",
            handle.addr(),
            if psk.is_some() { "on" } else { "off" }
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    if let Some(list) = args.get("dealer") {
        // Coordinator process against an external dealer fleet
        // (comma-separated addresses).
        let addrs: Vec<String> =
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
        assert!(!addrs.is_empty(), "--dealer needs at least one address");
        tcp_serving_demo(&registry, &addrs, psk, n_requests);
        return;
    }

    // Default: full single-process walkthrough — in-memory channel first,
    // then a self-spawned two-dealer fleet on real localhost TCP sockets.
    mem_channel_demo(&registry, dealer_seed, deal_threads);
    let handles: Vec<_> = (0..2)
        .map(|_| {
            spawn_tcp_dealer_multi_psk(
                "127.0.0.1:0",
                registry.clone(),
                dealer_seed,
                deal_threads,
                psk,
            )
            .expect("bind dealer")
        })
        .collect();
    let addrs: Vec<String> = handles.iter().map(|h| h.addr().to_string()).collect();
    println!("\nspawned TCP dealer fleet on {addrs:?}");
    tcp_serving_demo(&registry, &addrs, psk, n_requests);
    for handle in handles {
        handle.stop();
    }
    println!(
        "\ndone: two models privately served end-to-end with material from a dealer fleet."
    );
}

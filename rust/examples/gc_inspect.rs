//! Inspect the four ReLU circuit generations: gate composition, input
//! layout, garbled sizes, and a live garble/evaluate trace of one ReLU.
//!
//! ```bash
//! cargo run --release --example gc_inspect -- --k 12
//! ```

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::circuits::stoch_sign_gc;
use circa::field::Fp;
use circa::gc::size::CircuitCost;
use circa::ss::SharePair;
use circa::util::args::Args;
use circa::util::{Rng, Timer};

fn main() {
    let args = Args::from_env();
    let k = args.get_u64("k", 12) as u32;

    println!("Circa circuit inspector (k = {k})\n");
    let variants = [
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
    ];

    println!(
        "{:<22} {:>6} {:>6} {:>8} {:>8} {:>10} {:>10}",
        "variant", "ANDs", "XORs", "cli-in", "srv-in", "table B", "total B"
    );
    for v in variants {
        let spec = v.spec();
        let c = spec.circuit();
        let cost = CircuitCost::of(&c);
        let srv_base = spec.server_input_base();
        println!(
            "{:<22} {:>6} {:>6} {:>8} {:>8} {:>10} {:>10}",
            v.name(),
            cost.n_and,
            cost.n_xor,
            srv_base,
            cost.n_inputs - srv_base,
            cost.table_bytes(),
            cost.total_bytes()
        );
    }

    // Before/after the material squeeze: the seed's naive build vs the
    // CSE-built + optimized template each deal actually garbles.
    println!(
        "\n{:<22} {:>11} {:>11} {:>11} {:>11} {:>11} {:>9}",
        "optimizer", "ANDs b/a", "XORs b/a", "NOTs b/a", "gates b/a", "bytes b/a", "saved B"
    );
    for v in variants {
        let spec = v.spec();
        let before = CircuitCost::of(&spec.build_circuit_naive());
        let after = CircuitCost::of(&spec.build_circuit());
        println!(
            "{:<22} {:>5}/{:<5} {:>5}/{:<5} {:>5}/{:<5} {:>5}/{:<5} {:>5}/{:<5} {:>9}",
            v.name(),
            before.n_and,
            after.n_and,
            before.n_xor,
            after.n_xor,
            before.n_not,
            after.n_not,
            before.n_gates(),
            after.n_gates(),
            before.total_bytes(),
            after.total_bytes(),
            before.total_bytes() - after.total_bytes()
        );
    }
    let ts = circa::circuits::template::stats();
    println!(
        "\ntemplate cache: {} hits / {} misses (hit rate {:.2})",
        ts.hits,
        ts.misses,
        ts.hit_rate()
    );

    // Live trace: garble + evaluate one truncated stochastic sign.
    println!("\n--- live garble/evaluate trace (~sign_{k}, x = -5000) ---");
    let mut rng = Rng::new(7);
    let circuit = stoch_sign_gc::build_truncated(k, FaultMode::PosZero);
    let t = Timer::new();
    let (gc, enc) = circa::gc::garble(&circuit, &mut rng);
    println!("garble     : {:>8.1} us ({} table bytes)", t.elapsed_us() as f64, gc.table_bytes());

    let x = Fp::from_i64(-5000);
    let tt = circa::field::random_fp(&mut rng);
    let shares = SharePair::share_with_t(x, tt);
    let r = circa::field::random_fp(&mut rng);
    let inputs = stoch_sign_gc::encode_inputs(shares.client, shares.server, r, k);
    let labels = enc.encode_all(&inputs);

    let t = Timer::new();
    let out = circa::gc::evaluate(&circuit, &gc, &labels);
    println!("evaluate   : {:>8.1} us", t.elapsed_us() as f64);

    let decoded = gc.decode(&out);
    let vs = circa::circuits::spec::bits_fp(&decoded);
    let v = vs + r;
    println!("sign share : {} -> v = {} (x = {}, exact sign {})",
        vs.to_i64(), v.to_i64(), x.to_i64(), x.is_nonneg() as i64);
    println!("\n(the multiply x*v then runs on Beaver triples — outside the GC)");
}

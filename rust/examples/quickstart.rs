//! Quickstart: one private inference with Circa's truncated stochastic
//! ReLU on a tiny network, printing what each optimization buys.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use circa::bench_harness::relu_cost;
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::circuits::{relu_gc, stoch_sign_gc};
use circa::field::Fp;
use circa::gc::size::CircuitCost;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{offline_network, run_inference, NetworkPlan};
use circa::util::Rng;
use std::sync::Arc;

fn main() {
    println!("Circa quickstart — stochastic ReLUs for private inference\n");
    let mut rng = Rng::new(1);

    // 1. What the garbled circuits look like.
    let baseline = CircuitCost::of(&relu_gc::build());
    let circa = CircuitCost::of(&stoch_sign_gc::build_truncated(12, FaultMode::PosZero));
    println!("per-ReLU garbled circuit:");
    println!("  baseline ReLU GC : {baseline}");
    println!("  Circa ~sign_12   : {circa}");
    println!(
        "  -> {:.1}x smaller tables\n",
        baseline.table_bytes() as f64 / circa.table_bytes() as f64
    );

    // 2. Measured per-ReLU cost of both variants (real protocol).
    let base_cost = relu_cost(ReluVariant::BaselineRelu, 512, &mut rng);
    let circa_cost = relu_cost(
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        512,
        &mut rng,
    );
    println!("measured online cost per ReLU:");
    println!("  baseline: {:.2} us", base_cost.online_s * 1e6);
    println!(
        "  Circa   : {:.2} us  ({:.1}x faster)\n",
        circa_cost.online_s * 1e6,
        base_cost.online_s / circa_cost.online_s
    );

    // 3. A full 2-party private inference on a small MLP.
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(16, 8, 50, &mut rng)),
        Arc::new(Matrix::random(8, 16, 50, &mut rng)),
        Arc::new(Matrix::random(4, 8, 50, &mut rng)),
    ];
    let plan = NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 6, mode: FaultMode::PosZero },
    );
    let (client_net, server_net, offline_bytes) = offline_network(&plan, &mut rng);
    let input: Vec<Fp> = (0..8).map(|i| Fp::from_i64(2000 + 37 * i)).collect();
    let (logits, stats) = run_inference(&client_net, &server_net, &input);

    // Plaintext check.
    let mut want = input.clone();
    for (i, op) in plan.linears.iter().enumerate() {
        want = op.apply(&want);
        if i + 1 < plan.linears.len() {
            want = want.iter().map(|&v| circa::field::relu_exact(v)).collect();
        }
    }
    println!("2-party inference on an 8->16->8->4 MLP (24 stochastic ReLUs):");
    println!("  logits (private) : {:?}", logits.iter().map(|v| v.to_i64()).collect::<Vec<_>>());
    println!("  logits (plain)   : {:?}", want.iter().map(|v| v.to_i64()).collect::<Vec<_>>());
    println!("  online time      : {:.2} ms", stats.online_s * 1e3);
    println!(
        "  online traffic   : {} B down / {} B up",
        stats.bytes_to_client, stats.bytes_to_server
    );
    println!("  offline material : {offline_bytes} B (garbled circuits + OT + triples + HE)");
    assert_eq!(logits, want, "stochastic faults are ~impossible at these magnitudes");
    println!("\nOK — private result matches plaintext.");
}

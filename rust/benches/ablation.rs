//! Ablation bench — isolates the design choices DESIGN.md calls out:
//!
//! 1. truncation level k vs online cost (where does the paper's chosen
//!    k = 12–15 sit on the cost curve?);
//! 2. PosZero vs NegPass (must be cost-identical — the mode only flips a
//!    comparator's strictness);
//! 3. AES batching (§Perf iterations 1–2): pipelined `hash4`/`hash2` vs
//!    scalar hashing;
//! 4. where Circa's online win comes from: GC evaluation vs the extra
//!    Beaver round it introduces.

use circa::bench_harness::{relu_cost, write_csv};
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::prf::{GarbleHash, Label};
use circa::util::{Rng, Timer};

fn main() {
    let mut rng = Rng::new(0xAB1A7E);
    let sample = 3000;

    // 1. k sweep.
    println!("=== ablation 1: online cost vs truncation k ===");
    let mut rows = Vec::new();
    for k in [0u32, 4, 8, 12, 16, 20, 24] {
        let c = relu_cost(
            ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
            sample,
            &mut rng,
        );
        println!(
            "  k={k:>2}: online {:>5.2} us/ReLU, {:>4.0} B, storage {:>5.0} B",
            c.online_s * 1e6,
            c.online_bytes,
            c.storage_bytes
        );
        rows.push(format!("{k},{},{},{}", c.online_s, c.online_bytes, c.storage_bytes));
    }
    write_csv("ablation_k_sweep.csv", "k,online_s,online_bytes,storage_bytes", &rows);

    // 2. Fault-mode parity.
    println!("\n=== ablation 2: PosZero vs NegPass cost parity ===");
    let pz =
        relu_cost(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }, sample, &mut rng);
    let np =
        relu_cost(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass }, sample, &mut rng);
    println!("  PosZero: {:.2} us   NegPass: {:.2} us", pz.online_s * 1e6, np.online_s * 1e6);
    let ratio = pz.online_s / np.online_s;
    assert!(
        (0.7..1.4).contains(&ratio),
        "fault modes should cost the same: ratio {ratio}"
    );

    // 3. AES batching.
    println!("\n=== ablation 3: scalar vs pipelined AES hashing ===");
    let h = GarbleHash::shared();
    let labels: Vec<Label> = (0..4096).map(|_| Label::random(&mut rng)).collect();
    let iters = 2000;
    let t = Timer::new();
    let mut acc = 0u128;
    for it in 0..iters {
        for (i, &l) in labels.iter().enumerate() {
            acc ^= h.hash(l, (it * 4096 + i) as u64).0;
        }
    }
    let scalar = t.elapsed_s() / (iters * labels.len()) as f64;
    let t = Timer::new();
    for it in 0..iters {
        for (i, chunk) in labels.chunks_exact(4).enumerate() {
            let tw = (it * 4096 + 4 * i) as u64;
            let out = h.hash4(
                [chunk[0], chunk[1], chunk[2], chunk[3]],
                [tw, tw + 1, tw + 2, tw + 3],
            );
            acc ^= out[0].0 ^ out[1].0 ^ out[2].0 ^ out[3].0;
        }
    }
    let batched = t.elapsed_s() / (iters * labels.len()) as f64;
    std::hint::black_box(acc);
    println!(
        "  scalar {:.2} ns/hash, pipelined {:.2} ns/hash ({:.2}x)",
        scalar * 1e9,
        batched * 1e9,
        scalar / batched
    );

    // 4. Decompose Circa's online cost: GC-only (drop Beaver by using the
    // naive-sign GC at truncated width? not expressible) — approximate by
    // comparing StochasticSign (m-bit compare + Beaver) vs BaselineRelu
    // (8m-gate GC, no Beaver).
    println!("\n=== ablation 4: GC shrink vs Beaver overhead ===");
    let base = relu_cost(ReluVariant::BaselineRelu, sample, &mut rng);
    let stoch = relu_cost(
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        sample,
        &mut rng,
    );
    println!(
        "  baseline (233-AND GC, no Beaver): {:.2} us",
        base.online_s * 1e6
    );
    println!(
        "  ~sign    ( 62-AND GC, + Beaver) : {:.2} us  -> the Beaver round costs \
         far less than the 171 ANDs it displaces",
        stoch.online_s * 1e6
    );
    assert!(stoch.online_s < base.online_s);
}

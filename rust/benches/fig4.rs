//! Fig. 4: accuracy and fault rate vs truncated bits, NegPass & PosZero,
//! for two architectures (demo CNN = "vanilla" row, demo MLP = the
//! second-architecture row) — executed through the AOT-compiled JAX
//! model on the PJRT runtime (one compilation, k/mode as runtime
//! scalars).

use circa::bench_harness::write_csv;
use circa::field::{Fp, PRIME};
use circa::nn::weights::{accuracy, load_dataset, Dataset};
use circa::runtime::model_exec::{MODE_EXACT, MODE_NEGPASS, MODE_POSZERO};
use circa::runtime::{ArtifactDir, CnnExecutable};
use circa::util::Rng;

struct SweepResult {
    acc: f64,
    fault_rate: f64,
}

fn sweep_point(
    exe: &CnnExecutable,
    ds: &Dataset,
    n_batches: usize,
    k: i32,
    mode: i32,
    rng: &mut Rng,
) -> SweepResult {
    let b = exe.batch;
    let relus = exe.relus_per_example() * b;
    let (t1_n, t2_n) = match exe.relus_per_example() {
        768 => (b * 512, b * 256), // CNN
        192 => (b * 128, b * 64),  // MLP
        other => panic!("unexpected relu count {other}"),
    };
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut faults = 0i64;
    for batch in 0..n_batches {
        let base = batch * b;
        if base + b > ds.n {
            break;
        }
        let images: Vec<i32> = ds.images[base * ds.dim..(base + b) * ds.dim]
            .iter()
            .map(|f| f.to_i64() as i32)
            .collect();
        let t1: Vec<i32> = (0..t1_n).map(|_| rng.below(PRIME) as i32).collect();
        let t2: Vec<i32> = (0..t2_n).map(|_| rng.below(PRIME) as i32).collect();
        let out = exe.run(&images, &t1, &t2, k, mode).expect("exec");
        let logits: Vec<Vec<Fp>> = (0..b)
            .map(|i| {
                out.logits[i * 10..(i + 1) * 10].iter().map(|&v| Fp::from_i64(v as i64)).collect()
            })
            .collect();
        correct += (accuracy(&logits, &ds.labels[base..base + b]) * b as f64).round() as usize;
        total += b;
        faults += out.total_faults();
    }
    SweepResult {
        acc: correct as f64 / total as f64,
        fault_rate: faults as f64 / (relus * n_batches) as f64,
    }
}

fn run_net(name: &str, exe: &CnnExecutable, ds: &Dataset, n_batches: usize) {
    let mut rng = Rng::new(0xF16_4);
    let exact = sweep_point(exe, ds, n_batches, 0, MODE_EXACT, &mut rng);
    println!("\n--- {name}: baseline (exact ReLU) accuracy {:.2}% ---", exact.acc * 100.0);
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12}",
        "k", "PosZero acc", "PZ faults", "NegPass acc", "NP faults"
    );
    let mut rows = vec![format!("{name},exact,-,{:.4},0", exact.acc)];
    for k in 6..=24 {
        let pz = sweep_point(exe, ds, n_batches, k, MODE_POSZERO, &mut rng);
        let np = sweep_point(exe, ds, n_batches, k, MODE_NEGPASS, &mut rng);
        println!(
            "{k:>4} {:>11.2}% {:>11.4} {:>11.2}% {:>11.4}",
            pz.acc * 100.0,
            pz.fault_rate,
            np.acc * 100.0,
            np.fault_rate
        );
        rows.push(format!("{name},poszero,{k},{:.4},{:.4}", pz.acc, pz.fault_rate));
        rows.push(format!("{name},negpass,{k},{:.4},{:.4}", np.acc, np.fault_rate));
    }
    write_csv(
        &format!("fig4_{}.csv", name),
        "net,mode,k,accuracy,fault_rate",
        &rows,
    );

    // The paper's claim: some k in 12..=19 keeps accuracy within 1% of
    // baseline at a ≥5% fault rate.
    let mut best_k = 0;
    for k in (6..=24).rev() {
        let mut rng2 = Rng::new(0xF16_4 ^ k as u64);
        let pz = sweep_point(exe, ds, n_batches, k, MODE_POSZERO, &mut rng2);
        if exact.acc - pz.acc <= 0.01 {
            best_k = k;
            break;
        }
    }
    println!(
        "  -> max PosZero k within 1% of baseline: {best_k} (paper: 11–16 across nets/datasets)"
    );
}

fn main() {
    let dir = ArtifactDir::discover().expect("run `make artifacts` first");
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let n_batches = std::env::var("FIG4_BATCHES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize);

    println!("=== Fig. 4: accuracy & fault rate vs truncation (PJRT sweep) ===");
    println!("batches of 128 per point: {n_batches}");

    let cnn = CnnExecutable::load_cnn(&client, &dir).unwrap();
    run_net("demo_cnn", &cnn, &ds, n_batches);

    let mlp = CnnExecutable::load_mlp(&client, &dir).unwrap();
    run_net("demo_mlp", &mlp, &ds, n_batches);
}

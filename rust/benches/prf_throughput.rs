//! Offline-dealing throughput trajectory: raw fixed-key AES blocks/s per
//! backend (scalar soft vs pipelined soft vs AES-NI), `hash_many`
//! throughput, half-gates garbling gates/s per backend, and end-to-end
//! layer-deal ReLUs/s vs dealer thread count under the column-wise
//! offline schedule. Results land in `BENCH_prf_throughput.json` — the
//! first PRF perf baseline of the repo.
//!
//! ```bash
//! cargo bench --bench prf_throughput
//! # AES-NI path requires a native build: RUSTFLAGS="-C target-cpu=native"
//! ```

use circa::bench_harness::print_row;
use circa::bench_harness::tables::write_bench_json;
use circa::field::{random_fp, Fp};
use circa::gc::garble::garble_into_with;
use circa::prf::backend::{Backend, BatchCipher};
use circa::prf::{GarbleHash, Label};
use circa::protocol::offline::{circa_variant, offline_relu_layer_mt};
use circa::util::{Rng, Timer};

const KEY: [u8; 16] = *b"CIRCA-PIgarble01";

/// Raw ECB blocks/s of one backend over a resident buffer.
fn raw_blocks_per_s(cipher: &BatchCipher, reps: usize) -> f64 {
    let mut rng = Rng::new(0xB10C);
    let mut blocks: Vec<u128> = (0..(1 << 14)).map(|_| rng.next_u128()).collect();
    let t = Timer::new();
    for _ in 0..reps {
        cipher.encrypt_many(&mut blocks);
    }
    (blocks.len() * reps) as f64 / t.elapsed_s()
}

/// `hash_many` GB/s (16 B per block) on the given hasher.
fn hash_many_gb_per_s(hash: &GarbleHash, reps: usize) -> f64 {
    let mut rng = Rng::new(0x4A54);
    let mut blocks: Vec<u128> = (0..(1 << 14)).map(|_| rng.next_u128()).collect();
    let t = Timer::new();
    for _ in 0..reps {
        hash.hash_many(&mut blocks);
    }
    (blocks.len() * reps * 16) as f64 / t.elapsed_s() / 1e9
}

/// Half-gates garbling gates/s of the Circa k=12 template through a
/// forced backend (the real offline hot loop, gather-then-hash included).
fn garble_gates_per_s(hash: &GarbleHash, n_instances: usize) -> f64 {
    let spec = circa_variant(12).spec();
    let circuit = spec.build_circuit();
    let n_and = circuit.n_and();
    let mut table = vec![[Label::ZERO; 2]; n_and];
    let mut inputs = vec![Label::ZERO; circuit.n_inputs as usize];
    let mut decode = vec![false; circuit.outputs.len()];
    let mut scratch = Vec::new();
    let mut rng = Rng::new(0x6A12);
    let t = Timer::new();
    for _ in 0..n_instances {
        let _ = garble_into_with(
            hash,
            &circuit,
            &mut rng,
            &mut scratch,
            &mut table,
            &mut inputs,
            &mut decode,
        );
    }
    (n_and * n_instances) as f64 / t.elapsed_s()
}

/// End-to-end layer deal (garble + OT + triples, column schedule),
/// ReLUs/s at a given garble-column thread count.
fn deal_relus_per_s(threads: usize, n: usize) -> f64 {
    let mut rng = Rng::new(0xD0E);
    let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng)).collect();
    let t = Timer::new();
    let _ = offline_relu_layer_mt(circa_variant(12), &xc, &mut rng, threads);
    n as f64 / t.elapsed_s()
}

fn main() {
    println!("PRF / offline-dealing throughput (fixed-key AES backends)");
    println!("detected backend: {}", Backend::detect().name());
    let widths = [22, 16, 14];
    print_row(
        &["path".into(), "blocks/s".into(), "gates/s".into()],
        &widths,
    );

    let mut json: Vec<(&str, f64)> = Vec::new();
    let backends = [
        ("soft_scalar", Backend::SoftScalar),
        ("soft_pipelined", Backend::SoftPipelined),
        ("aes_ni", Backend::AesNi),
    ];
    let mut blocks = [0.0f64; 3];
    let mut gates = [0.0f64; 3];
    for (i, (name, b)) in backends.iter().enumerate() {
        let (bps, gps) = match (BatchCipher::with_backend(KEY, *b), GarbleHash::with_backend(*b))
        {
            (Some(cipher), Some(hash)) => {
                // Scalar soft AES is ~an order of magnitude slower; fewer
                // reps keep the bench snappy without hurting stability.
                let reps = if *b == Backend::SoftScalar { 8 } else { 64 };
                (raw_blocks_per_s(&cipher, reps), garble_gates_per_s(&hash, 2000))
            }
            _ => (0.0, 0.0), // backend unavailable on this CPU
        };
        blocks[i] = bps;
        gates[i] = gps;
        print_row(
            &[(*name).into(), format!("{bps:.3e}"), format!("{gps:.3e}")],
            &widths,
        );
    }
    json.push(("aes_soft_scalar_blocks_per_s", blocks[0]));
    json.push(("aes_soft_pipelined_blocks_per_s", blocks[1]));
    json.push(("aes_ni_blocks_per_s", blocks[2]));
    json.push(("aes_ni_available", if blocks[2] > 0.0 { 1.0 } else { 0.0 }));
    json.push(("garble_gates_per_s_soft_scalar", gates[0]));
    json.push(("garble_gates_per_s_soft_pipelined", gates[1]));
    json.push(("garble_gates_per_s_aes_ni", gates[2]));
    json.push(("soft_pipeline_blocks_speedup", blocks[1] / blocks[0]));
    json.push(("soft_pipeline_garble_speedup", gates[1] / gates[0]));
    if blocks[2] > 0.0 {
        json.push(("aes_ni_blocks_speedup_vs_scalar", blocks[2] / blocks[0]));
    }

    let gbs = hash_many_gb_per_s(&GarbleHash::new(), 64);
    println!("\nhash_many ({}): {:.3} GB/s", Backend::detect().name(), gbs);
    json.push(("hash_many_gb_per_s", gbs));

    println!("\nlayer deal (Circa k=12, 4096 ReLUs, column schedule):");
    let mut t1 = 0.0;
    for threads in [1usize, 4, 8] {
        let rps = deal_relus_per_s(threads, 4096);
        if threads == 1 {
            t1 = rps;
        }
        println!("  {threads} threads: {rps:.0} ReLUs/s  ({:.2}x vs 1 thread)", rps / t1);
        match threads {
            1 => json.push(("deal_relus_per_s_t1", rps)),
            4 => json.push(("deal_relus_per_s_t4", rps)),
            _ => json.push(("deal_relus_per_s_t8", rps)),
        }
    }

    write_bench_json("BENCH_prf_throughput.json", &json);
}

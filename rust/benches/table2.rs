//! Table 2: Circa stacked on DeepReDuce-optimized models — the
//! "orthogonal to ReLU-count reduction" claim (extra 1.6–1.8×).

use circa::bench_harness::tables::table2;
use circa::bench_harness::{mac_cost, network_runtime_s, print_row, relu_cost, write_csv};
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::util::Rng;

fn main() {
    let mut rng = Rng::new(0x7AB1E2);
    let sample = std::env::var("RELU_SAMPLE").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    eprintln!("measuring per-ReLU costs (sample={sample}) ...");
    let base = relu_cost(ReluVariant::BaselineRelu, sample, &mut rng);
    let per_mac = mac_cost(&mut rng);

    println!("\n=== Table 2: Circa with DeepReDuce (ResNet18) models ===");
    let widths = [14, 9, 11, 11, 9, 11, 11, 8];
    print_row(
        &[
            "network", "#ReLUs K", "base s", "circa s", "speedup", "paper base", "paper circa",
            "paper x",
        ]
        .map(String::from),
        &widths,
    );

    let mut rows = Vec::new();
    for row in table2() {
        let spec = (row.spec)();
        let circa = relu_cost(
            ReluVariant::TruncatedSign { k: row.poszero_bits, mode: FaultMode::PosZero },
            sample,
            &mut rng,
        );
        let relus = spec.total_relus();
        let macs = spec.total_macs();
        let base_s = network_runtime_s(relus, macs, &base, per_mac);
        let circa_s = network_runtime_s(relus, macs, &circa, per_mac);
        let speedup = base_s / circa_s;
        print_row(
            &[
                row.name.to_string(),
                format!("{:.1}", relus as f64 / 1000.0),
                format!("{base_s:.2}"),
                format!("{circa_s:.2}"),
                format!("{speedup:.1}x"),
                format!("{:.2}", row.baseline_runtime_s),
                format!("{:.2}", row.circa_runtime_s),
                format!("{:.1}x", row.speedup),
            ],
            &widths,
        );
        rows.push(format!(
            "{},{relus},{macs},{base_s:.4},{circa_s:.4},{speedup:.3},{},{},{}",
            row.name, row.baseline_runtime_s, row.circa_runtime_s, row.speedup
        ));
    }
    write_csv(
        "table2.csv",
        "network,relus,macs,ours_base_s,ours_circa_s,ours_speedup,paper_base_s,paper_circa_s,paper_speedup",
        &rows,
    );

    // Pareto observation from the paper: DeepReD3+Circa beats DeepReD2
    // baseline on both axes (runtime via ReLU count here).
    println!(
        "\nPareto check (paper §4.2): Circa(DeepReD3) runtime < baseline(DeepReD2) runtime \
         while DeepReD3 has the higher accuracy."
    );
}

//! Fig. 5: garbled-circuit size per ReLU for each Circa optimization.
//!
//! Prints our measured half-gates byte counts next to the paper's
//! fancy-garbling numbers; the claim under test is the *multiplicative
//! ordering* (baseline > sign > s̃ign > s̃ign_k) and the headline
//! baseline→trunc-12 reduction (paper 4.7×).

use circa::bench_harness::tables::FIG5_PAPER;
use circa::bench_harness::{print_row, write_csv};
use circa::circuits::spec::FaultMode;
use circa::circuits::{relu_gc, sign_gc, stoch_sign_gc};
use circa::gc::size::CircuitCost;

fn main() {
    println!("=== Fig. 5: GC size per ReLU (31-bit field) ===\n");
    let variants: Vec<(&str, CircuitCost, f64)> = vec![
        ("ReLU (baseline)", CircuitCost::of(&relu_gc::build()), FIG5_PAPER.baseline_kb),
        ("Sign (naive)", CircuitCost::of(&sign_gc::build()), FIG5_PAPER.sign_kb),
        (
            "~Sign (stochastic)",
            CircuitCost::of(&stoch_sign_gc::build(FaultMode::PosZero)),
            FIG5_PAPER.stoch_kb,
        ),
        (
            "~Sign_k (k=12)",
            CircuitCost::of(&stoch_sign_gc::build_truncated(12, FaultMode::PosZero)),
            FIG5_PAPER.trunc12_kb,
        ),
    ];

    let widths = [20, 8, 10, 12, 12, 10, 10];
    print_row(
        &["variant", "ANDs", "table KB", "total KB", "ours ratio", "paper KB", "paper ratio"]
            .map(String::from),
        &widths,
    );
    let base_total = variants[0].1.total_bytes() as f64;
    let mut rows = Vec::new();
    for (name, cost, paper_kb) in &variants {
        let table_kb = cost.table_bytes() as f64 / 1024.0;
        let total_kb = cost.total_bytes() as f64 / 1024.0;
        let ratio = base_total / cost.total_bytes() as f64;
        let paper_ratio = FIG5_PAPER.baseline_kb / paper_kb;
        print_row(
            &[
                name.to_string(),
                format!("{}", cost.n_and),
                format!("{table_kb:.2}"),
                format!("{total_kb:.2}"),
                format!("{ratio:.1}x"),
                format!("{paper_kb:.2}"),
                format!("{paper_ratio:.1}x"),
            ],
            &widths,
        );
        rows.push(format!(
            "{name},{},{:.1},{:.1},{ratio:.3},{paper_kb},{paper_ratio:.3}",
            cost.n_and,
            cost.table_bytes() as f64 / 1024.0,
            total_kb
        ));
    }
    write_csv(
        "fig5_gc_size.csv",
        "variant,ands,table_kb,total_kb,ratio,paper_kb,paper_ratio",
        &rows,
    );

    // Table-only ratios (the garbled material itself, paper's storage story):
    let base_tbl = variants[0].1.table_bytes() as f64;
    println!("\ntable-only reductions vs baseline:");
    for (name, cost, _) in &variants[1..] {
        println!("  {name:<20} {:.1}x", base_tbl / cost.table_bytes() as f64);
    }

    // Also sweep truncation for the k-dependence curve.
    let mut rows = Vec::new();
    for k in [0u32, 4, 8, 12, 16, 20, 24] {
        let c = CircuitCost::of(&stoch_sign_gc::build_truncated(k, FaultMode::PosZero));
        rows.push(format!("{k},{},{}", c.table_bytes(), c.total_bytes()));
    }
    write_csv("fig5_k_sweep.csv", "k,table_bytes,total_bytes", &rows);

    // Client-side storage for ResNet-32 (the paper's ~5 GB figure).
    let n_relus = 303_104f64;
    let base_gb = n_relus * variants[0].1.total_bytes() as f64 / (1u64 << 30) as f64;
    let circa_gb = n_relus * variants[3].1.total_bytes() as f64 / (1u64 << 30) as f64;
    println!(
        "\nResNet-32 client storage: baseline {base_gb:.2} GB -> Circa(k=12) {circa_gb:.2} GB \
         (paper: ~5 GB -> ~1 GB at fancy-garbling sizes)"
    );

    // Cross-check the size model against *materialized* layer batches:
    // since the SoA refactor, per-ReLU storage is a buffer length divided
    // by n, not a per-object sum.
    use circa::circuits::spec::ReluVariant;
    use circa::protocol::offline::{circa_variant, offline_relu_layer};
    use circa::util::Rng;
    let mut rng = Rng::new(5);
    let n = 64usize;
    let xc: Vec<circa::Fp> = (0..n as i64).map(circa::Fp::from_i64).collect();
    println!("\nmaterialized layer batches (n = {n}) — bytes/ReLU from buffer lengths:");
    for (name, variant, cost) in [
        ("ReLU (baseline)", ReluVariant::BaselineRelu, &variants[0].1),
        ("~Sign_k (k=12)", circa_variant(12), &variants[3].1),
    ] {
        let (cm, _) = offline_relu_layer(variant, &xc, &mut rng);
        let per_relu_tables = cm.gc.table_bytes() / n;
        assert_eq!(per_relu_tables, cost.table_bytes(), "{name}: size model drift");
        println!(
            "  {name:<18} tables {per_relu_tables} B/ReLU, offline total {} B/ReLU",
            cm.offline_bytes as usize / n
        );
    }
}

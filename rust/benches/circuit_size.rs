//! Circuit material squeeze: per-variant gate counts and bytes-per-ReLU
//! before/after the hash-consing CSE build + `Circuit::optimize` pass,
//! template-cache economics (cold build vs memoized `Arc` lookup, hit
//! rate), and the dealer-side effect (offline deal ReLUs/s with cached
//! templates). Results land in `BENCH_circuit_size.json`.

use circa::bench_harness::print_row;
use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::circuits::template;
use circa::field::Fp;
use circa::gc::size::CircuitCost;
use circa::protocol::offline::{circa_variant, offline_relu_layer};
use circa::ss::SharePair;
use circa::util::{Rng, Timer};

const REPS: usize = 3;

fn variants() -> Vec<(String, ReluVariant)> {
    vec![
        ("baseline".into(), ReluVariant::BaselineRelu),
        ("naive_sign".into(), ReluVariant::NaiveSign),
        ("stoch_pz".into(), ReluVariant::StochasticSign { mode: FaultMode::PosZero }),
        ("circa_k0".into(), circa_variant(0)),
        ("circa_k8".into(), circa_variant(8)),
        ("circa_k12".into(), circa_variant(12)),
    ]
}

fn main() {
    println!("=== circuit material squeeze (naive seed build vs CSE + optimize) ===\n");
    let widths = [12, 10, 10, 8, 12, 12, 8];
    print_row(
        &["variant", "AND b/a", "gates b/a", "-AND%", "B/ReLU b", "B/ReLU a", "saved B"]
            .map(String::from),
        &widths,
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    for (name, v) in variants() {
        let spec = v.spec();
        let before = CircuitCost::of(&spec.build_circuit_naive());
        let after = CircuitCost::of(&spec.build_circuit());
        assert!(after.n_and <= before.n_and, "{name}: AND regression");
        assert!(after.n_gates() < before.n_gates(), "{name}: gate regression");
        let and_red = 100.0 * (before.n_and - after.n_and) as f64 / before.n_and as f64;
        print_row(
            &[
                name.clone(),
                format!("{}/{}", before.n_and, after.n_and),
                format!("{}/{}", before.n_gates(), after.n_gates()),
                format!("{and_red:.1}"),
                format!("{}", before.total_bytes()),
                format!("{}", after.total_bytes()),
                format!("{}", before.total_bytes() - after.total_bytes()),
            ],
            &widths,
        );
        for (key, val) in [
            ("and_naive", before.n_and as f64),
            ("and_opt", after.n_and as f64),
            ("gates_naive", before.n_gates() as f64),
            ("gates_opt", after.n_gates() as f64),
            ("bytes_per_relu_naive", before.total_bytes() as f64),
            ("bytes_per_relu_opt", after.total_bytes() as f64),
            ("and_reduction_pct", and_red),
        ] {
            results.push((format!("{name}.{key}"), val));
        }
    }

    // Template-cache economics: cold build (CSE + optimize) vs memoized
    // Arc lookup. build_circuit() bypasses the cache, so the loop above
    // left it cold — the first circuit() call below is the true miss.
    let spec = circa_variant(12).spec();
    let mut cold_s = f64::MAX;
    for _ in 0..REPS {
        let t = Timer::new();
        let c = spec.build_circuit();
        std::hint::black_box(&c);
        cold_s = cold_s.min(t.elapsed_s());
    }
    let _warm = spec.circuit();
    let lookups = 10_000usize;
    let t2 = Timer::new();
    for _ in 0..lookups {
        let c = spec.circuit();
        std::hint::black_box(&c);
    }
    let lookup_s = t2.elapsed_s() / lookups as f64;
    let ts = template::stats();
    println!(
        "\ntemplate cache: cold build {:.1} us, cached lookup {:.3} us ({:.0}x), \
         {} hits / {} misses (hit rate {:.4})",
        cold_s * 1e6,
        lookup_s * 1e6,
        cold_s / lookup_s.max(1e-12),
        ts.hits,
        ts.misses,
        ts.hit_rate()
    );
    results.push(("template_cold_build_us".into(), cold_s * 1e6));
    results.push(("template_cached_lookup_us".into(), lookup_s * 1e6));
    results.push(("template_cache_hit_rate".into(), ts.hit_rate()));

    // Dealer throughput with cached optimized templates: a full offline
    // ReLU-layer deal (garble + encode + triples bookkeeping).
    let n = std::env::var("SIZE_RELUS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024usize)
        .max(1);
    let mut rng = Rng::new(0x512E);
    let xc: Vec<Fp> = (0..n)
        .map(|i| SharePair::share(Fp::from_i64(500 + i as i64), &mut rng).client)
        .collect();
    let mut deal_s = f64::MAX;
    for _ in 0..REPS {
        let t = Timer::new();
        let (cm, sm) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        std::hint::black_box((&cm, &sm));
        deal_s = deal_s.min(t.elapsed_s());
    }
    let relus_per_s = n as f64 / deal_s;
    println!("offline deal (circa_k12, cached templates): {relus_per_s:.0} ReLUs/s (n = {n})");
    results.push(("deal_relus_per_s".into(), relus_per_s));
    results.push(("n_relus".into(), n as f64));

    let entries: Vec<(&str, f64)> = results.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_circuit_size.json", &entries);
    println!("\n(wrote bench_out/BENCH_circuit_size.json)");
}

//! Table 3 (Appendix): PI runtime per optimization stage — baseline ReLU,
//! naive sign, stochastic sign, truncated stochastic sign — showing the
//! three optimizations compose multiplicatively.

use circa::bench_harness::tables::table3;
use circa::bench_harness::{mac_cost, network_runtime_s, print_row, relu_cost, write_csv};
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::util::Rng;

fn main() {
    let mut rng = Rng::new(0x7AB1E3);
    let sample = std::env::var("RELU_SAMPLE").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    eprintln!("measuring per-ReLU costs for all four stages (sample={sample}) ...");
    let relu = relu_cost(ReluVariant::BaselineRelu, sample, &mut rng);
    let sign = relu_cost(ReluVariant::NaiveSign, sample, &mut rng);
    let stoch = relu_cost(
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        sample,
        &mut rng,
    );
    let per_mac = mac_cost(&mut rng);
    eprintln!(
        "  per-ReLU online us: relu {:.2}, sign {:.2}, ~sign {:.2}",
        relu.online_s * 1e6,
        sign.online_s * 1e6,
        stoch.online_s * 1e6
    );

    println!("\n=== Table 3: runtime (s) per optimization stage ===");
    let widths = [12, 9, 22, 22, 22, 22];
    print_row(
        &["network", "#ReLUs K", "ReLU ours(paper)", "Sign ours(paper)", "~Sign ours(paper)",
          "~Sign_k ours(paper)"]
            .map(String::from),
        &widths,
    );
    let mut rows = Vec::new();
    for row in table3() {
        let spec = (row.spec)();
        let trunc = relu_cost(
            ReluVariant::TruncatedSign { k: row.trunc_bits, mode: FaultMode::PosZero },
            sample,
            &mut rng,
        );
        let relus = spec.total_relus();
        let macs = spec.total_macs();
        let t_relu = network_runtime_s(relus, macs, &relu, per_mac);
        let t_sign = network_runtime_s(relus, macs, &sign, per_mac);
        let t_stoch = network_runtime_s(relus, macs, &stoch, per_mac);
        let t_trunc = network_runtime_s(relus, macs, &trunc, per_mac);
        print_row(
            &[
                row.name.to_string(),
                format!("{:.1}", relus as f64 / 1000.0),
                format!("{t_relu:.2} ({:.2})", row.relu_s),
                format!("{t_sign:.2} ({:.2})", row.sign_s),
                format!("{t_stoch:.2} ({:.2})", row.stoch_sign_s),
                format!("{t_trunc:.2} ({:.2})", row.trunc_sign_s),
            ],
            &widths,
        );
        rows.push(format!(
            "{},{relus},{t_relu:.4},{t_sign:.4},{t_stoch:.4},{t_trunc:.4},{},{},{},{}",
            row.name, row.relu_s, row.sign_s, row.stoch_sign_s, row.trunc_sign_s
        ));
        // Invariant from the paper: strictly decreasing stage runtimes.
        assert!(t_relu > t_sign && t_sign > t_stoch && t_stoch > t_trunc, "{}", row.name);
    }
    write_csv(
        "table3.csv",
        "network,relus,ours_relu,ours_sign,ours_stoch,ours_trunc,paper_relu,paper_sign,paper_stoch,paper_trunc",
        &rows,
    );
}

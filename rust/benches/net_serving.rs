//! Serving-tier bench: connection setup rate, request latency through
//! the reactor, and shed rate vs offered load when a model's material
//! bank runs dry. Emits `bench_out/BENCH_net_serving.json`.
//!
//! ```bash
//! cargo bench --bench net_serving
//! ```
//!
//! Everything runs on loopback over small in-process plans — the bench
//! measures the serving tier (reactor multiplexing, framing, admission
//! control), not the protocol's cryptography (fig3/table benches cover
//! that).

use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::ReluVariant;
use circa::coordinator::{PiService, ServiceConfig};
use circa::field::Fp;
use circa::net::{AdmitConfig, Outcome, PiClient, Reactor, ReactorConfig};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::NetworkPlan;
use circa::util::{Rng, Timer};
use std::sync::Arc;
use std::time::Duration;

fn service(pool_target: usize, max_queue: usize) -> Arc<PiService> {
    let mut rng = Rng::new(0xBE9C);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(8, 10, 10, &mut rng)),
        Arc::new(Matrix::random(4, 8, 10, &mut rng)),
    ];
    let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
    Arc::new(PiService::start(plan, ServiceConfig {
        workers: 4,
        pool_target,
        pool_dealers: 2,
        max_queue,
        ..Default::default()
    }))
}

fn main() {
    let mut entries: Vec<(String, f64)> = Vec::new();

    // --- 1. Connection setup rate (connect + hello + bye) -----------
    {
        let svc = service(8, 1024);
        svc.warmup(4);
        let reactor =
            Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default()).unwrap();
        let addr = reactor.local_addr().to_string();
        let n = 200;
        let t = Timer::new();
        for _ in 0..n {
            let client = PiClient::connect(&addr).expect("connect");
            let _ = client.bye();
        }
        let per_s = n as f64 / t.elapsed_s();
        println!("connection setup: {per_s:.0} conns/s ({n} sequential handshakes)");
        entries.push(("conns_per_s".to_string(), per_s));
        reactor.shutdown();
        if let Ok(svc) = Arc::try_unwrap(svc) {
            svc.shutdown();
        }
    }

    // --- 2. Request latency through the reactor ---------------------
    {
        let svc = service(64, 1024);
        svc.warmup(32);
        let reactor =
            Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default()).unwrap();
        let mut client = PiClient::connect(&reactor.local_addr().to_string()).unwrap();
        let ad = client.models()[0];
        let input: Vec<Fp> = (0..ad.in_dim as i64).map(|i| Fp::from_i64(500 + i)).collect();
        let n = 200;
        let mut lat_ms = Vec::with_capacity(n);
        for _ in 0..n {
            let t = Timer::new();
            match client.infer(ad.fingerprint, &input).expect("infer") {
                Outcome::Logits(_) => lat_ms.push(t.elapsed_s() * 1e3),
                Outcome::Busy(b) => panic!("warm bank shed: {}", b.reason),
            }
        }
        let p50 = circa::util::stats::percentile(&lat_ms, 50.0);
        let p99 = circa::util::stats::percentile(&lat_ms, 99.0);
        println!("request latency over loopback: p50 {p50:.3} ms  p99 {p99:.3} ms ({n} reqs)");
        entries.push(("latency_p50_ms".to_string(), p50));
        entries.push(("latency_p99_ms".to_string(), p99));
        let _ = client.bye();
        reactor.shutdown();
        if let Ok(svc) = Arc::try_unwrap(svc) {
            svc.shutdown();
        }
    }

    // --- 3. Shed rate vs offered load (dry bank) --------------------
    {
        let svc = service(4, 64);
        svc.warmup(2);
        // Freeze refill and drain the bank: every subsequent request
        // should shed, and shedding must be cheap (no dealing inline).
        svc.pool.stop();
        let model = svc.models()[0];
        let mut rng = Rng::new(1);
        while svc.pool.banked_model(model) > 0 {
            let _ = svc.pool.lease_model(model, &mut rng);
        }
        let cfg = ReactorConfig {
            admit: AdmitConfig {
                sample_interval: Duration::from_secs(0),
                ..AdmitConfig::default()
            },
            ..ReactorConfig::default()
        };
        let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), cfg).unwrap();
        let mut client = PiClient::connect(&reactor.local_addr().to_string()).unwrap();
        let ad = client.models()[0];
        let input: Vec<Fp> = (0..ad.in_dim as i64).map(|i| Fp::from_i64(500 + i)).collect();
        let n = 500;
        let t = Timer::new();
        let mut shed = 0u64;
        for _ in 0..n {
            if let Outcome::Busy(_) = client.infer(ad.fingerprint, &input).expect("answered") {
                shed += 1;
            }
        }
        let wall = t.elapsed_s();
        let rate = shed as f64 / n as f64;
        println!(
            "dry-bank overload: {n} offered in {wall:.2} s, shed rate {:.1}% \
             ({:.0} busy/s answered without blocking)",
            100.0 * rate,
            shed as f64 / wall
        );
        entries.push(("shed_rate_dry_bank".to_string(), rate));
        entries.push(("busy_answers_per_s".to_string(), shed as f64 / wall));
        let _ = client.bye();
        reactor.shutdown();
        if let Ok(svc) = Arc::try_unwrap(svc) {
            svc.shutdown();
        }
    }

    let refs: Vec<(&str, f64)> = entries.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_net_serving.json", &refs);
}

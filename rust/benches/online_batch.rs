//! Cross-request batched online phase: R concurrent inferences executed
//! as one strided walk (`run_inference_multi`) vs R independent
//! `run_inference` calls on the same leased sessions.
//!
//! Reported per variant at R ∈ {1, 4, 8}: GC throughput (AND gates/s
//! over all requests' ReLU evaluations) and request throughput
//! (inferences/s), for both paths, plus the batched-over-per-request
//! speedup. R = 8 gates/s above R = 1 is the acceptance line: the
//! cross-request flights keep the fixed-key cipher saturated where a
//! lone narrow request cannot. Results land in
//! `BENCH_online_batch.json` so the perf trajectory is tracked across
//! PRs.
//!
//! Material reuse: each timing iteration replays the same dealt
//! sessions. That would be insecure in deployment (single-use labels)
//! but is sound for timing — the online walk's work does not depend on
//! how often material was used.

use circa::bench_harness::print_row;
use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::ReluVariant;
use circa::field::Fp;
use circa::protocol::client::{ClientLayer, ClientNet};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::offline::circa_variant;
use circa::protocol::server::{
    offline_network_mt, run_inference, run_inference_multi, session_rng, NetworkPlan, ServerNet,
};
use circa::util::timer::bench_seconds_per_iter;
use circa::util::Rng;
use std::sync::Arc;

const R_POINTS: [usize; 3] = [1, 4, 8];
const MAX_R: usize = 8;

/// w → w → relu → w → w → relu → w → 16.
fn plan(variant: ReluVariant, width: usize) -> NetworkPlan {
    let mut rng = Rng::new(0xBA7C);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(width, width, 20, &mut rng)),
        Arc::new(Matrix::random(width, width, 20, &mut rng)),
        Arc::new(Matrix::random(16, width, 20, &mut rng)),
    ];
    NetworkPlan::unscaled(linears, variant)
}

/// AND gates one inference evaluates across its ReLU layers.
fn gates_per_inference(cn: &ClientNet) -> u64 {
    cn.layers
        .iter()
        .map(|l| match l {
            ClientLayer::Relu(m) => (m.gc.len() * m.gc.and_stride()) as u64,
            ClientLayer::Linear { .. } => 0,
        })
        .sum()
}

fn bench_variant(
    name: &str,
    variant: ReluVariant,
    width: usize,
    min_time_s: f64,
    results: &mut Vec<(String, f64)>,
) {
    let p = plan(variant, width);
    // One seq-addressed session per request slot, reused across R points
    // and timing iterations.
    let sessions: Vec<(ClientNet, ServerNet)> = (0..MAX_R)
        .map(|seq| {
            let (cn, sn, _) = offline_network_mt(&p, &mut session_rng(0xD0E, seq as u64), 1);
            (cn, sn)
        })
        .collect();
    let inputs: Vec<Vec<Fp>> = (0..MAX_R)
        .map(|r| (0..width).map(|j| Fp::from_i64(500 + 31 * r as i64 + j as i64)).collect())
        .collect();
    let gates = gates_per_inference(&sessions[0].0);

    for r_count in R_POINTS {
        let refs: Vec<(&ClientNet, &ServerNet)> =
            sessions[..r_count].iter().map(|(cn, sn)| (cn, sn)).collect();
        let in_refs: Vec<&[Fp]> = inputs[..r_count].iter().map(|v| v.as_slice()).collect();

        let per_req_s = bench_seconds_per_iter(min_time_s, 2, || {
            for ((cn, sn), input) in refs.iter().zip(&in_refs) {
                let (logits, _) = run_inference(cn, sn, input);
                std::hint::black_box(logits);
            }
        });
        let multi_s = bench_seconds_per_iter(min_time_s, 2, || {
            let (logits, _) = run_inference_multi(&refs, &in_refs, 1);
            std::hint::black_box(logits);
        });

        let batch_gates = (gates * r_count as u64) as f64;
        let per_req_gps = batch_gates / per_req_s;
        let multi_gps = batch_gates / multi_s;
        let per_req_rps = r_count as f64 / per_req_s;
        let multi_rps = r_count as f64 / multi_s;
        let speedup = per_req_s / multi_s;

        let widths = [12, 4, 14, 14, 12, 12, 8];
        print_row(
            &[
                name.to_string(),
                format!("{r_count}"),
                format!("{:.2}", per_req_gps / 1e6),
                format!("{:.2}", multi_gps / 1e6),
                format!("{per_req_rps:.1}"),
                format!("{multi_rps:.1}"),
                format!("{speedup:.2}x"),
            ],
            &widths,
        );
        for (key, v) in [
            ("per_request_gates_per_s", per_req_gps),
            ("multi_gates_per_s", multi_gps),
            ("per_request_requests_per_s", per_req_rps),
            ("multi_requests_per_s", multi_rps),
            ("speedup", speedup),
        ] {
            results.push((format!("{name}.R{r_count}.{key}"), v));
        }
    }
}

fn main() {
    let width = std::env::var("ONLINE_BATCH_WIDTH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64usize)
        .max(4);
    let min_time_s = std::env::var("ONLINE_BATCH_MIN_TIME_S")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5f64);
    println!("=== cross-request batched online phase (layer width = {width}) ===\n");
    let widths = [12, 4, 14, 14, 12, 12, 8];
    print_row(
        &["variant", "R", "Mgates/s (1x)", "Mgates/s (R)", "req/s (1x)", "req/s (R)", "x"]
            .map(String::from),
        &widths,
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    bench_variant("baseline", ReluVariant::BaselineRelu, width, min_time_s, &mut results);
    bench_variant("circa_k12", circa_variant(12), width, min_time_s, &mut results);
    results.push(("layer_width".to_string(), width as f64));

    let entries: Vec<(&str, f64)> = results.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_online_batch.json", &entries);
    println!("\n(wrote bench_out/BENCH_online_batch.json)");
}

//! Wire-codec throughput + size: encode/decode GB/s and bytes-per-ReLU
//! for one layer's offline material (client + server sides), per variant
//! and truncation level.
//!
//! Also cross-checks the codec against the byte ledger: the garbled-table
//! payload on the wire must equal `LayerGcBatch::table_bytes()` exactly
//! (the paper's storage metric), and the total wire size must track
//! `offline_bytes` (the wire ships labels at their 16 B at-rest size
//! while the ledger charges the 32 B OT-extension asymptote, so the
//! ratio hovers around 1). Results land in `BENCH_wire_codec.json`.

use circa::bench_harness::print_row;
use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::ReluVariant;
use circa::field::Fp;
use circa::gc::batch::{LayerEncodingBatch, LayerGcBatch};
use circa::protocol::offline::{circa_variant, offline_relu_layer};
use circa::ss::SharePair;
use circa::util::bytes::{Reader, Writer};
use circa::util::{Rng, Timer};
use circa::wire::codec;

const REPS: usize = 3;

fn bench_variant(name: &str, variant: ReluVariant, n: usize, results: &mut Vec<(String, f64)>) {
    let mut rng = Rng::new(0xC0DEC);
    let xc: Vec<Fp> = (0..n)
        .map(|i| SharePair::share(Fp::from_i64(1000 + i as i64), &mut rng).client)
        .collect();
    let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);

    // Encode (best of REPS).
    let mut buf = Vec::new();
    let mut enc_s = f64::MAX;
    for _ in 0..REPS {
        let t = Timer::new();
        let mut w = Writer::new();
        codec::put_client_relu(&mut w, &cm);
        codec::put_server_relu(&mut w, &sm);
        enc_s = enc_s.min(t.elapsed_s());
        buf = w.buf;
    }
    let wire_bytes = buf.len();

    // Decode (best of REPS), and verify the roundtrip is bit-identical.
    let mut dec_s = f64::MAX;
    for _ in 0..REPS {
        let t = Timer::new();
        let mut r = Reader::new(&buf);
        let c2 = codec::get_client_relu(&mut r).expect("client decode");
        let s2 = codec::get_server_relu(&mut r).expect("server decode");
        dec_s = dec_s.min(t.elapsed_s());
        assert_eq!(r.remaining(), 0);
        assert_eq!(c2.gc.tables(), cm.gc.tables());
        assert_eq!(c2.client_labels, cm.client_labels);
        assert_eq!(s2.encodings.label0(), sm.encodings.label0());
        assert_eq!(s2.output_decode, sm.output_decode);
    }

    // The table payload is the paper's storage metric — byte-exact.
    assert_eq!(cm.gc.tables().len() * 32, cm.gc.table_bytes());
    let wire_per_relu = wire_bytes as f64 / n as f64;
    let offline_per_relu = cm.offline_bytes as f64 / n as f64;
    let ratio = wire_per_relu / offline_per_relu;
    assert!(
        (0.5..2.0).contains(&ratio),
        "{name}: wire/offline ratio {ratio:.2} out of family \
         (wire {wire_per_relu:.0}, ledger {offline_per_relu:.0})"
    );

    let enc_gbps = wire_bytes as f64 / enc_s / 1e9;
    let dec_gbps = wire_bytes as f64 / dec_s / 1e9;
    let widths = [14, 12, 12, 14, 14, 8];
    print_row(
        &[
            name.to_string(),
            format!("{enc_gbps:.2}"),
            format!("{dec_gbps:.2}"),
            format!("{wire_per_relu:.0}"),
            format!("{offline_per_relu:.0}"),
            format!("{ratio:.2}"),
        ],
        &widths,
    );
    for (key, v) in [
        ("encode_gbps", enc_gbps),
        ("decode_gbps", dec_gbps),
        ("wire_bytes_per_relu", wire_per_relu),
        ("offline_bytes_per_relu", offline_per_relu),
        ("wire_to_offline_ratio", ratio),
        ("table_bytes_per_relu", cm.gc.table_bytes() as f64 / n as f64),
    ] {
        results.push((format!("{name}.{key}"), v));
    }
}

/// Dealer-side parallel garbling: the chunked stride loop at 1 vs N
/// threads (bit-identical output by construction; see
/// `LayerGcBatch::garble_chunked`).
fn bench_parallel_garble(n: usize, results: &mut Vec<(String, f64)>) {
    let spec = circa_variant(12).spec();
    let circuit = spec.circuit();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let time_with = |t: usize| {
        let mut rng = Rng::new(0x9A8B);
        let mut batch = LayerGcBatch::new(circuit.clone(), n);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, n);
        let timer = Timer::new();
        batch.garble_chunked(&mut enc, n, &mut rng, t);
        timer.elapsed_s()
    };
    let t1 = time_with(1);
    let tn = time_with(threads);
    println!(
        "\nparallel layer garbling (circa_k12): {:.2} us/ReLU @1 thread, \
         {:.2} us/ReLU @{} threads ({:.2}x)",
        t1 * 1e6 / n as f64,
        tn * 1e6 / n as f64,
        threads,
        t1 / tn
    );
    results.push(("garble_us_per_relu_1t".to_string(), t1 * 1e6 / n as f64));
    results.push(("garble_us_per_relu_nt".to_string(), tn * 1e6 / n as f64));
    results.push(("garble_parallel_speedup".to_string(), t1 / tn));
    results.push(("garble_threads".to_string(), threads as f64));
}

fn main() {
    let n = std::env::var("WIRE_RELUS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048usize)
        .max(1);
    println!("=== wire codec throughput + size (n = {n} ReLUs/layer) ===\n");
    let widths = [14, 12, 12, 14, 14, 8];
    print_row(
        &["variant", "enc GB/s", "dec GB/s", "wire B/ReLU", "ledger B/ReLU", "ratio"]
            .map(String::from),
        &widths,
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    bench_variant("baseline", ReluVariant::BaselineRelu, n, &mut results);
    bench_variant("circa_k0", circa_variant(0), n, &mut results);
    bench_variant("circa_k8", circa_variant(8), n, &mut results);
    bench_variant("circa_k12", circa_variant(12), n, &mut results);
    bench_parallel_garble(n, &mut results);
    results.push(("n_relus".to_string(), n as f64));

    let entries: Vec<(&str, f64)> = results.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_wire_codec.json", &entries);
    println!("\n(wrote bench_out/BENCH_wire_codec.json)");
}

//! Table 1: Circa accuracy + PI runtime on the baseline networks.
//!
//! Runtime: the real protocol's per-ReLU online cost is measured on a
//! sample (garble + label + evaluate + decode + Beaver, the same code
//! the serving path runs), then composed with each architecture's exact
//! ReLU/MAC counts. The paper's testbed numbers are printed alongside;
//! the claim under test is the *speedup column* (2.6–3.1×).

use circa::bench_harness::tables::table1;
use circa::bench_harness::{mac_cost, network_runtime_s, print_row, relu_cost, write_csv};
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::util::Rng;

fn main() {
    let mut rng = Rng::new(0x7AB1E1);
    let sample = std::env::var("RELU_SAMPLE").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    eprintln!("measuring per-ReLU costs (sample={sample}) ...");
    let base = relu_cost(ReluVariant::BaselineRelu, sample, &mut rng);
    let per_mac = mac_cost(&mut rng);
    eprintln!(
        "  baseline: online {:.2} us/ReLU, storage {:.0} B/ReLU; linear {:.2} ns/MAC",
        base.online_s * 1e6,
        base.storage_bytes,
        per_mac * 1e9
    );

    println!("\n=== Table 1: Circa on baseline networks ===");
    let widths = [14, 9, 11, 11, 9, 11, 11, 8, 8];
    print_row(
        &[
            "network", "#ReLUs K", "base s", "circa s", "speedup", "paper base", "paper circa",
            "paper x", "bits",
        ]
        .map(String::from),
        &widths,
    );

    let mut rows = Vec::new();
    for row in table1() {
        let spec = (row.spec)();
        let k = row.poszero_bits;
        let circa = relu_cost(
            ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
            sample,
            &mut rng,
        );
        let relus = spec.total_relus();
        let macs = spec.total_macs();
        let base_s = network_runtime_s(relus, macs, &base, per_mac);
        let circa_s = network_runtime_s(relus, macs, &circa, per_mac);
        let speedup = base_s / circa_s;
        print_row(
            &[
                row.name.to_string(),
                format!("{:.1}", spec.total_relus() as f64 / 1000.0),
                format!("{base_s:.2}"),
                format!("{circa_s:.2}"),
                format!("{speedup:.1}x"),
                format!("{:.2}", row.baseline_runtime_s),
                format!("{:.2}", row.circa_runtime_s),
                format!("{:.1}x", row.speedup),
                format!("{k}"),
            ],
            &widths,
        );
        rows.push(format!(
            "{},{},{},{base_s:.4},{circa_s:.4},{speedup:.3},{},{},{}",
            row.name, relus, macs, row.baseline_runtime_s, row.circa_runtime_s, row.speedup
        ));
    }
    write_csv(
        "table1.csv",
        "network,relus,macs,ours_base_s,ours_circa_s,ours_speedup,paper_base_s,paper_circa_s,paper_speedup",
        &rows,
    );
    println!(
        "\naccuracy columns: regenerated on the demo workload by `cargo bench --bench fig4` \
         (paper nets need CIFAR/Tiny — unavailable offline; see DESIGN.md §5)"
    );
}

//! Layer-batch ablation: per-ReLU heap objects vs the flat SoA layer
//! batches that now back the offline material.
//!
//! The legacy representation (a `Vec<GarbledCircuit>` +
//! `Vec<InputEncoding>` + `Vec<Vec<Label>>` forest, reconstructed here
//! from the low-level GC primitives) is timed against the batched path
//! ([`circa::gc::batch`]) on the same workload: offline garbling of one
//! layer and the online GC hot loop (label encode → evaluate → color
//! decode; the Beaver round is representation-independent and excluded
//! from both sides). Results land in `BENCH_layer_batch.json` so the perf
//! trajectory is tracked across PRs.

use circa::bench_harness::print_row;
use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::ReluVariant;
use circa::field::{random_fp, Fp};
use circa::gc::eval::evaluate_with_scratch;
use circa::gc::garble::{garble_with_scratch, GarbledCircuit, InputEncoding};
use circa::ot;
use circa::prf::Label;
use circa::protocol::offline::{circa_variant, offline_relu_layer};
use circa::protocol::online::{decode_server_shares, encode_server_labels};
use circa::ss::SharePair;
use circa::util::{Rng, Timer};

/// The seed-era per-ReLU object forest, kept as the bench baseline.
struct LegacyLayer {
    gcs: Vec<GarbledCircuit>,
    encodings: Vec<InputEncoding>,
    client_labels: Vec<Vec<Label>>,
}

fn legacy_offline(variant: ReluVariant, xc: &[Fp], rng: &mut Rng) -> LegacyLayer {
    let spec = variant.spec();
    let circuit = spec.build_circuit();
    let mut scratch = Vec::new();
    let mut gcs = Vec::new();
    let mut encodings = Vec::new();
    let mut client_labels = Vec::new();
    for &x in xc {
        let (gc, enc) = garble_with_scratch(&circuit, rng, &mut scratch);
        let rv = random_fp(rng);
        let rout = random_fp(rng);
        let bits = spec.client_bits(x, rv, rout);
        client_labels.push(ot::ot_choose(&enc, 0, &bits).labels);
        if spec.uses_beaver() {
            // Same dealer work as the batched offline path draws.
            let _ = circa::beaver::gen_triple(rng);
        }
        gcs.push(gc);
        encodings.push(enc);
    }
    LegacyLayer { gcs, encodings, client_labels }
}

fn legacy_online(variant: ReluVariant, layer: &LegacyLayer, xs: &[Fp]) -> Vec<bool> {
    let spec = variant.spec();
    let circuit = spec.build_circuit();
    let base = spec.server_input_base();
    let mut colors = Vec::with_capacity(xs.len() * spec.n_outputs);
    let mut eval_labels: Vec<Label> = Vec::new();
    let mut scratch: Vec<Label> = Vec::new();
    for (i, &x) in xs.iter().enumerate() {
        let bits = spec.server_bits(x);
        eval_labels.clear();
        eval_labels.extend_from_slice(&layer.client_labels[i]);
        eval_labels
            .extend(bits.iter().enumerate().map(|(j, &b)| layer.encodings[i].encode(base + j, b)));
        let out = evaluate_with_scratch(&circuit, &layer.gcs[i], &eval_labels, &mut scratch);
        colors.extend(out.iter().map(|l| l.color()));
    }
    colors
}

fn bench_variant(name: &str, variant: ReluVariant, n: usize, results: &mut Vec<(String, f64)>) {
    let mut rng = Rng::new(0x1A7E5);
    let shares: Vec<SharePair> = (0..n)
        .map(|i| SharePair::share(Fp::from_i64(1000 + i as i64), &mut rng))
        .collect();
    let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
    let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();

    // Legacy: per-ReLU heap objects.
    let t = Timer::new();
    let legacy = legacy_offline(variant, &xc, &mut rng);
    let legacy_off_us = t.elapsed_s() * 1e6 / n as f64;
    let t = Timer::new();
    let legacy_colors = legacy_online(variant, &legacy, &xs);
    let legacy_on_us = t.elapsed_s() * 1e6 / n as f64;

    // Batched: flat SoA layer material.
    let t = Timer::new();
    let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
    let batch_off_us = t.elapsed_s() * 1e6 / n as f64;
    let t = Timer::new();
    let labels = encode_server_labels(&sm, &xs);
    let mut batch_colors = Vec::with_capacity(legacy_colors.len());
    cm.gc.eval_layer_colors(&cm.client_labels, &labels, &mut batch_colors);
    let shares_out = decode_server_shares(&sm, &batch_colors);
    let batch_on_us = t.elapsed_s() * 1e6 / n as f64;
    assert_eq!(shares_out.len(), n);
    assert_eq!(batch_colors.len(), legacy_colors.len());

    let widths = [16, 12, 12, 12, 12, 8];
    print_row(
        &[
            name.to_string(),
            format!("{legacy_off_us:.2}"),
            format!("{batch_off_us:.2}"),
            format!("{legacy_on_us:.2}"),
            format!("{batch_on_us:.2}"),
            format!("{:.2}x", legacy_on_us / batch_on_us),
        ],
        &widths,
    );
    for (key, v) in [
        ("legacy_offline_us_per_relu", legacy_off_us),
        ("batch_offline_us_per_relu", batch_off_us),
        ("legacy_online_us_per_relu", legacy_on_us),
        ("batch_online_us_per_relu", batch_on_us),
        ("online_speedup", legacy_on_us / batch_on_us),
        ("offline_speedup", legacy_off_us / batch_off_us),
    ] {
        results.push((format!("{name}.{key}"), v));
    }
    results.push((format!("{name}.table_bytes_per_relu"), cm.gc.table_bytes() as f64 / n as f64));
}

fn main() {
    let n = std::env::var("BATCH_RELUS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096usize)
        .max(1);
    println!("=== layer batch vs per-ReLU objects (n = {n} ReLUs/layer) ===\n");
    let widths = [16, 12, 12, 12, 12, 8];
    print_row(
        &["variant", "off us (old)", "off us (new)", "on us (old)", "on us (new)", "on x"]
            .map(String::from),
        &widths,
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    bench_variant("baseline", ReluVariant::BaselineRelu, n, &mut results);
    bench_variant("circa_k12", circa_variant(12), n, &mut results);
    results.push(("n_relus".to_string(), n as f64));

    let entries: Vec<(&str, f64)> = results.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_layer_batch.json", &entries);
    println!("\n(wrote bench_out/BENCH_layer_batch.json)");
}

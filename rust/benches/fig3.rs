//! Fig. 3: validating the stochastic-ReLU fault model.
//!
//! (a) the closed-form fault-probability curve of `s̃ign_18` (PosZero)
//!     against the histogram of the demo CNN's first-layer activations
//!     (the paper uses ResNet-18's first conv — same experiment, demo
//!     substrate, see DESIGN.md §5);
//! (b) model-predicted vs Monte-Carlo-measured fault rates (total and
//!     positive-only) across truncation levels — measured through the
//!     same comparator rule the GC evaluates, which the integration
//!     tests verify against the *actual* garbled circuit.

use circa::bench_harness::write_csv;
use circa::circuits::spec::FaultMode;
use circa::field::Fp;
use circa::nn::weights::{load_dataset, load_weights};
use circa::runtime::ArtifactDir;
use circa::simfault::{self, montecarlo};
use circa::util::Rng;

fn main() {
    let dir = ArtifactDir::discover().expect("run `make artifacts` first");
    let net = load_weights(&dir.path("weights.bin")).unwrap();
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();

    // First-layer activations over a few hundred images.
    let mut acts: Vec<Fp> = Vec::new();
    for i in 0..256.min(ds.n) {
        acts.extend(net.layers[0].op.apply(ds.image(i)));
    }
    println!("=== Fig. 3(a): fault probability vs activation histogram ===");
    println!("activations: {} samples from conv1 over {} images", acts.len(), 256.min(ds.n));

    // Histogram in log2 magnitude buckets, split by sign.
    let mut hist_pos = [0u64; 32];
    let mut hist_neg = [0u64; 32];
    for a in &acts {
        let b = (64 - a.magnitude().max(1).leading_zeros() as usize - 1).min(31);
        if a.is_nonneg() {
            hist_pos[b] += 1;
        } else {
            hist_neg[b] += 1;
        }
    }
    let k = 18u32;
    let mut rows = Vec::new();
    println!("\n log2|x|   #pos     #neg     P_fault(PosZero,k=18)");
    for b in 0..28 {
        let x = Fp::from_i64(1i64 << b);
        let p = simfault::fault_prob(x, k, FaultMode::PosZero);
        println!("  {b:>6}  {:>7}  {:>7}   {p:.4}", hist_pos[b], hist_neg[b]);
        rows.push(format!("{b},{},{},{p}", hist_pos[b], hist_neg[b]));
    }
    write_csv("fig3a_hist_model.csv", "log2_mag,count_pos,count_neg,fault_prob_k18", &rows);

    // (b) model vs measured across k, on the real activation population.
    println!("\n=== Fig. 3(b): model vs measured fault rates (PosZero) ===");
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14}",
        "k", "total(meas)", "total(model)", "pos(meas)", "pos(model)"
    );
    let mut rng = Rng::new(42);
    let sample: Vec<Fp> = {
        let mut v = acts.clone();
        rng.shuffle(&mut v);
        v.truncate(20_000);
        v
    };
    let mut rows = Vec::new();
    for k in (6..=28).step_by(2) {
        let r = montecarlo::measure(&sample, k, FaultMode::PosZero, 4, &mut rng);
        println!(
            "{k:>4} {:>14.4} {:>14.4} {:>14.4} {:>14.4}",
            r.total_measured, r.total_model, r.positive_measured, r.positive_model
        );
        rows.push(format!(
            "{k},{},{},{},{}",
            r.total_measured, r.total_model, r.positive_measured, r.positive_model
        ));
        assert!(
            (r.total_measured - r.total_model).abs() < 0.02,
            "model diverges from implementation at k={k}"
        );
    }
    write_csv(
        "fig3b_model_vs_measured.csv",
        "k,total_measured,total_model,positive_measured,positive_model",
        &rows,
    );
    println!("\npaper check: with 28-bit truncation all positives fault; total < positive");
    let r = montecarlo::measure(&sample, 28, FaultMode::PosZero, 2, &mut rng);
    println!(
        "  k=28: positive rate {:.3} (paper: ~1.0), total rate {:.3} (paper: ~0.6)",
        r.positive_measured, r.total_measured
    );
}

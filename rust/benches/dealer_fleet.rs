//! Fleet-refill bench: bank-fill throughput vs dealer-fleet size, and
//! dealer-kill recovery time. Emits `bench_out/BENCH_dealer_fleet.json`.
//!
//! ```bash
//! cargo bench --bench dealer_fleet
//! ```
//!
//! Everything runs on loopback: N real TCP dealer processes-in-threads
//! feed one [`MaterialPool`] through the fleet scheduler (partitioned
//! claims, work stealing, failure handoff). The interesting numbers are
//! the fill-rate scaling from 1 → 2 → 4 dealers — seq-addressed dealing
//! purity means the partitioning is free of coordination rounds, so
//! scaling is bounded by the dealers' own garbling throughput — and how
//! long the fleet takes to refill after one dealer is killed mid-run.

use circa::bench_harness::tables::write_bench_json;
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{
    DealerEndpoint, MaterialPool, ModelRegistry, PoolTuning, RefillSource,
};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::NetworkPlan;
use circa::util::{Rng, Timer};
use circa::wire::dealer::{spawn_tcp_dealer_multi_psk, DealerHandle};
use std::sync::Arc;
use std::time::Duration;

/// A plan meaty enough that garbling dominates the wire round trips.
fn bench_plan() -> Arc<NetworkPlan> {
    let mut rng = Rng::new(0xF1EE7);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(24, 32, 10, &mut rng)),
        Arc::new(Matrix::random(16, 24, 10, &mut rng)),
        Arc::new(Matrix::random(10, 16, 10, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
    ))
}

fn registry() -> Arc<ModelRegistry> {
    ModelRegistry::single(bench_plan(), 0xDEA1)
}

fn spawn_fleet(registry: &Arc<ModelRegistry>, n: usize) -> (Vec<DealerHandle>, Vec<String>) {
    let handles: Vec<DealerHandle> = (0..n)
        .map(|i| {
            spawn_tcp_dealer_multi_psk(
                "127.0.0.1:0",
                registry.clone(),
                0xBE9C + i as u64,
                2,
                None,
            )
            .expect("bind dealer")
        })
        .collect();
    let addrs = handles.iter().map(|h| h.addr().to_string()).collect();
    (handles, addrs)
}

fn endpoints(registry: &Arc<ModelRegistry>, addrs: &[String]) -> Vec<DealerEndpoint> {
    addrs.iter().map(|a| DealerEndpoint::tcp(a, registry.clone(), None)).collect()
}

/// Fill an empty pool to `target` sessions over `n_dealers` TCP links;
/// returns sessions/s.
fn fill_rate(n_dealers: usize, target: usize) -> f64 {
    let registry = registry();
    let (handles, addrs) = spawn_fleet(&registry, n_dealers);
    let t = Timer::new();
    let pool = MaterialPool::start_multi(
        registry.clone(),
        target,
        n_dealers,
        RefillSource::remote(endpoints(&registry, &addrs), 4),
        None,
        1,
    );
    pool.wait_ready(target);
    let rate = target as f64 / t.elapsed_s();
    pool.shutdown();
    for h in handles {
        h.stop();
    }
    rate
}

fn main() {
    let mut entries: Vec<(String, f64)> = Vec::new();
    let target = 32;

    // --- 1. Fill throughput vs fleet size ---------------------------
    let mut base = 0.0;
    for n in [1usize, 2, 4] {
        let rate = fill_rate(n, target);
        println!("fleet of {n}: filled {target} sessions at {rate:.1} sessions/s");
        entries.push((format!("refill_rate_{n}_dealers_sessions_per_s"), rate));
        if n == 1 {
            base = rate;
        } else {
            let speedup = rate / base;
            println!("  speedup over 1 dealer: {speedup:.2}x");
            entries.push((format!("speedup_{n}x_dealers"), speedup));
        }
    }

    // --- 2. Dealer-kill recovery ------------------------------------
    // Fill with two dealers, kill one, drain the banks, and time how
    // long the survivor takes to refill to target — EOF handoff plus
    // work stealing against the severed link's claims.
    {
        let registry = registry();
        let (mut handles, addrs) = spawn_fleet(&registry, 2);
        let tuning = PoolTuning {
            steal_after: Duration::from_millis(200),
            demand_half_life: Duration::from_secs(10),
        };
        let pool = MaterialPool::start_multi_tuned(
            registry.clone(),
            target,
            2,
            RefillSource::remote(endpoints(&registry, &addrs), 4),
            None,
            1,
            tuning,
        );
        pool.wait_ready(target);
        handles.remove(1).kill();
        // Drain everything banked so the survivor has a full target of
        // deficit to cover while the dead link's claims hand off.
        let model = registry.entries()[0].fingerprint();
        let mut rng = Rng::new(7);
        for _ in 0..target {
            let _ = pool.lease_model(model, &mut rng);
        }
        let t = Timer::new();
        pool.wait_ready(target);
        let recovery_ms = t.elapsed_s() * 1e3;
        println!(
            "dealer-kill recovery: survivor refilled {target} sessions in {recovery_ms:.0} ms \
             ({} seqs re-issued, {} late units dropped, {} steals)",
            pool.reissued_seqs(),
            pool.late_drop_units(),
            pool.steals()
        );
        entries.push(("kill_recovery_ms".to_string(), recovery_ms));
        entries.push(("kill_reissued_seqs".to_string(), pool.reissued_seqs() as f64));
        entries.push(("kill_steals".to_string(), pool.steals() as f64));
        pool.shutdown();
        for h in handles {
            h.stop();
        }
    }

    let refs: Vec<(&str, f64)> = entries.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    write_bench_json("BENCH_dealer_fleet.json", &refs);
}

//! Garbler: free-XOR + point-and-permute + half-gates.
//!
//! Per AND gate the garbler emits two ciphertexts (`T_G`, `T_E`) — 32
//! bytes with 128-bit labels (Zahur–Rosulek–Evans 2015). XOR and NOT gates
//! are free. This is the engine behind every ReLU variant in
//! [`crate::circuits`], and the `32·#AND` size model behind Fig. 5.

use super::circuit::{Circuit, WireDef};
use crate::prf::{Delta, GarbleHash, Label};
use crate::util::Rng;

/// The garbler's secret encoding of the circuit inputs.
#[derive(Clone, Debug)]
pub struct InputEncoding {
    /// `label0[i]` encodes value 0 on input `i`; value 1 is `label0 ⊕ Δ`.
    pub label0: Vec<Label>,
    pub delta: Delta,
}

impl InputEncoding {
    /// Label for input `i` carrying value `v`.
    pub fn encode(&self, i: usize, v: bool) -> Label {
        if v {
            self.label0[i] ^ self.delta.0
        } else {
            self.label0[i]
        }
    }

    /// Encode a full input assignment.
    pub fn encode_all(&self, vals: &[bool]) -> Vec<Label> {
        assert_eq!(vals.len(), self.label0.len());
        vals.iter().enumerate().map(|(i, &v)| self.encode(i, v)).collect()
    }

    /// Borrowed view (the shape the layer-batched arenas hand out).
    pub fn view(&self) -> EncodingView<'_> {
        EncodingView { label0: &self.label0, delta: self.delta }
    }
}

/// A borrowed input encoding: one instance's `label0` stride inside a
/// layer arena ([`crate::gc::batch::LayerEncodingBatch`]) or a standalone
/// [`InputEncoding`]. All label-delivery paths (direct + OT) encode
/// through this, so they are agnostic to how the labels are stored.
#[derive(Clone, Copy, Debug)]
pub struct EncodingView<'a> {
    /// `label0[i]` encodes value 0 on input `i`.
    pub label0: &'a [Label],
    pub delta: Delta,
}

impl EncodingView<'_> {
    /// Label for input `i` carrying value `v`.
    #[inline]
    pub fn encode(&self, i: usize, v: bool) -> Label {
        if v {
            self.label0[i] ^ self.delta.0
        } else {
            self.label0[i]
        }
    }

    pub fn n_inputs(&self) -> usize {
        self.label0.len()
    }
}

/// The material sent to the evaluator (plus, separately, input labels).
#[derive(Clone, Debug)]
pub struct GarbledCircuit {
    /// Two ciphertexts per AND gate, in gate order.
    pub table: Vec<[Label; 2]>,
    /// Point-and-permute decode bits: color of the 0-label of each output.
    pub output_decode: Vec<bool>,
}

impl GarbledCircuit {
    /// Size in bytes of the garbled tables (the paper's "GC size" driver).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 32
    }

    /// Decode output labels to cleartext bits.
    pub fn decode(&self, labels: &[Label]) -> Vec<bool> {
        assert_eq!(labels.len(), self.output_decode.len());
        labels.iter().zip(&self.output_decode).map(|(l, &d)| l.color() ^ d).collect()
    }
}

/// Garble a circuit. Returns the evaluator material and the garbler's
/// input encoding (kept secret; labels are delivered directly for the
/// garbler's own inputs and via OT for the evaluator's inputs).
pub fn garble(circuit: &Circuit, rng: &mut Rng) -> (GarbledCircuit, InputEncoding) {
    let mut scratch = Vec::new();
    garble_with_scratch(circuit, rng, &mut scratch)
}

/// Allocation-free variant for standalone garbling (tests, OT
/// integration): the wire-label buffer is reused across calls. Delegates
/// to [`garble_append`] so it consumes the RNG identically to the
/// layer-batched path.
pub fn garble_with_scratch(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
) -> (GarbledCircuit, InputEncoding) {
    let mut table = Vec::with_capacity(circuit.n_and());
    let mut input_label0 = Vec::with_capacity(circuit.n_inputs as usize);
    let mut output_decode = Vec::with_capacity(circuit.outputs.len());
    let delta =
        garble_append(circuit, rng, scratch, &mut table, &mut input_label0, &mut output_decode);
    (GarbledCircuit { table, output_decode }, InputEncoding { label0: input_label0, delta })
}

/// Low-level garbling core for the layer-batched offline path (§Perf
/// it. 4 + the SoA refactor): appends this instance's garbled table,
/// input `label0`s, and output decode bits to caller-owned flat buffers —
/// one contiguous buffer per *layer*, not per ReLU — and returns the
/// instance's free-XOR delta.
///
/// RNG draw order is the contract that keeps every garbling path
/// bit-identical: delta first, then one label per input wire in wire
/// order.
pub fn garble_append(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
    table: &mut Vec<[Label; 2]>,
    input_label0: &mut Vec<Label>,
    output_decode: &mut Vec<bool>,
) -> Delta {
    let t_base = table.len();
    let in_base = input_label0.len();
    let out_base = output_decode.len();
    table.resize(t_base + circuit.n_and(), [Label::ZERO; 2]);
    input_label0.resize(in_base + circuit.n_inputs as usize, Label::ZERO);
    output_decode.resize(out_base + circuit.outputs.len(), false);
    garble_into(
        circuit,
        rng,
        scratch,
        &mut table[t_base..],
        &mut input_label0[in_base..],
        &mut output_decode[out_base..],
    )
}

/// Slice-writing garbling core: fills exactly-sized caller-owned slices
/// for one instance's table / input-`label0` / decode-bit strides. This
/// is what lets [`crate::gc::batch::LayerGcBatch::garble_chunked`] hand
/// *disjoint* strides of one layer buffer to parallel dealer threads.
///
/// Draws from `rng` in the canonical order (delta, then one label per
/// input wire in wire order), so it is bit-identical to [`garble_append`]
/// given the same RNG state.
pub fn garble_into(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
    table: &mut [[Label; 2]],
    input_label0: &mut [Label],
    output_decode: &mut [bool],
) -> Delta {
    assert_eq!(table.len(), circuit.n_and(), "table stride");
    assert_eq!(input_label0.len(), circuit.n_inputs as usize, "input stride");
    assert_eq!(output_decode.len(), circuit.outputs.len(), "decode stride");
    let hash = GarbleHash::shared();
    let delta = Delta::random(rng);
    scratch.clear();
    scratch.reserve(circuit.wires.len());
    let label0 = scratch;
    let mut and_idx: u64 = 0;

    for def in &circuit.wires {
        let l0 = match *def {
            WireDef::Input(k) => {
                let l = Label::random(rng);
                input_label0[k as usize] = l;
                l
            }
            WireDef::Xor(a, b) => label0[a as usize] ^ label0[b as usize],
            WireDef::Not(a) => label0[a as usize] ^ delta.0,
            WireDef::And(a, b) => {
                let wa0 = label0[a as usize];
                let wb0 = label0[b as usize];
                let wa1 = wa0 ^ delta.0;
                let wb1 = wb0 ^ delta.0;
                let pa = wa0.color();
                let pb = wb0.color();
                let j = 2 * and_idx;
                let jp = 2 * and_idx + 1;

                // One pipelined 4-block AES call per AND gate (§Perf it. 2).
                let [h_wa0, h_wa1, h_wb0, h_wb1] =
                    hash.hash4([wa0, wa1, wb0, wb1], [j, j, jp, jp]);

                // Garbler half-gate.
                let mut t_g = h_wa0 ^ h_wa1;
                if pb {
                    t_g = t_g ^ delta.0;
                }
                let mut w_g0 = h_wa0;
                if pa {
                    w_g0 = w_g0 ^ t_g;
                }
                // Evaluator half-gate.
                let t_e = h_wb0 ^ h_wb1 ^ wa0;
                let mut w_e0 = h_wb0;
                if pb {
                    w_e0 = w_e0 ^ t_e ^ wa0;
                }
                table[and_idx as usize] = [t_g, t_e];
                and_idx += 1;
                w_g0 ^ w_e0
            }
        };
        label0.push(l0);
    }

    for (slot, &o) in output_decode.iter_mut().zip(circuit.outputs.iter()) {
        *slot = label0[o as usize].color();
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::{bits_to_u64, u64_to_bits, Builder};
    use crate::gc::eval::evaluate;

    /// Garble+evaluate roundtrip must match plain evaluation.
    fn roundtrip(circuit: &Circuit, inputs: &[bool], rng: &mut Rng) -> Vec<bool> {
        let (gc, enc) = garble(circuit, rng);
        let in_labels = enc.encode_all(inputs);
        let out_labels = evaluate(circuit, &gc, &in_labels);
        gc.decode(&out_labels)
    }

    #[test]
    fn single_and_gate_all_inputs() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(1);
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(roundtrip(&c, &[x, y], &mut rng), vec![x & y], "{x} {y}");
        }
    }

    #[test]
    fn xor_not_free_gates() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let x = bld.xor(a, b);
        let n = bld.not(x);
        bld.output(x);
        bld.output(n);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (gc, _) = garble(&c, &mut rng);
        assert_eq!(gc.table_bytes(), 0, "xor/not must garble for free");
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(roundtrip(&c, &[x, y], &mut rng), vec![x ^ y, !(x ^ y)]);
        }
    }

    #[test]
    fn adder_roundtrip() {
        let mut bld = Builder::new();
        let a = bld.input_bus(16);
        let b = bld.input_bus(16);
        let (s, carry) = bld.add(&a, &b);
        bld.output_bus(&s);
        bld.output(carry);
        let c = bld.build();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let x = rng.below(1 << 16);
            let y = rng.below(1 << 16);
            let mut inputs = u64_to_bits(x, 16);
            inputs.extend(u64_to_bits(y, 16));
            let out = roundtrip(&c, &inputs, &mut rng);
            let got = bits_to_u64(&out[..16]) | ((out[16] as u64) << 16);
            assert_eq!(got, x + y);
        }
    }

    #[test]
    fn random_circuits_match_plain_eval() {
        // Property test: random DAGs of XOR/AND/NOT garble correctly.
        let mut rng = Rng::new(4);
        for trial in 0..30 {
            let n_in = 2 + rng.below_usize(6);
            let mut bld = Builder::new();
            let mut pool: Vec<_> = (0..n_in).map(|_| bld.input()).collect();
            for _ in 0..40 {
                let a = pool[rng.below_usize(pool.len())];
                let b = pool[rng.below_usize(pool.len())];
                let v = match rng.below(3) {
                    0 => bld.xor(a, b),
                    1 => bld.and(a, b),
                    _ => bld.not(a),
                };
                pool.push(v);
            }
            for _ in 0..4 {
                let o = pool[rng.below_usize(pool.len())];
                // Only output live wires (constants folded away are fine too)
                bld.output(o);
            }
            let c = bld.build();
            for _ in 0..8 {
                let inputs: Vec<bool> = (0..n_in).map(|_| rng.bool()).collect();
                let want = c.eval_plain(&inputs);
                let got = roundtrip(&c, &inputs, &mut rng);
                assert_eq!(got, want, "trial {trial}");
            }
        }
    }

    #[test]
    fn table_size_is_32_bytes_per_and() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let b = bld.input_bus(31);
        let r = bld.leq(&a, &b);
        bld.output(r);
        let c = bld.build();
        let mut rng = Rng::new(5);
        let (gc, _) = garble(&c, &mut rng);
        assert_eq!(gc.table_bytes(), c.n_and() * 32);
    }

    #[test]
    fn labels_leak_nothing_obvious() {
        // The two labels of a wire must differ in more than the color bit.
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(6);
        let (_, enc) = garble(&c, &mut rng);
        let l0 = enc.encode(0, false);
        let l1 = enc.encode(0, true);
        assert!((l0.0 ^ l1.0).count_ones() > 10);
    }

    #[test]
    fn fresh_garbling_gives_fresh_labels() {
        // GCs cannot be reused across inferences (paper footnote 2): two
        // garblings of the same circuit must produce unrelated material.
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(7);
        let (gc1, e1) = garble(&c, &mut rng);
        let (gc2, e2) = garble(&c, &mut rng);
        assert_ne!(gc1.table[0][0], gc2.table[0][0]);
        assert_ne!(e1.label0[0], e2.label0[0]);
    }
}

//! Garbler: free-XOR + point-and-permute + half-gates.
//!
//! Per AND gate the garbler emits two ciphertexts (`T_G`, `T_E`) — 32
//! bytes with 128-bit labels (Zahur–Rosulek–Evans 2015). XOR and NOT gates
//! are free. This is the engine behind every ReLU variant in
//! [`crate::circuits`], and the `32·#AND` size model behind Fig. 5.

use super::circuit::{Circuit, WireDef, WireId};
use crate::prf::{Delta, GarbleHash, Label};
use crate::util::Rng;

/// AND gates gathered per hash flight: 8 gates × 4 hashes fills four
/// [`crate::prf::backend::MAX_BATCH`]-block cipher calls back to back, so
/// the batched backend (AES-NI or the pipelined soft path) always sees
/// full pipelines on circuits with gate-level parallelism, and degrades
/// to per-gate hashing (never worse than the old loop) on serial chains.
const FLIGHT_GATES: usize = 8;

/// One gathered-but-not-yet-hashed AND gate of the garbling walk. Its
/// four hash pre-images sit in the flight buffer; everything else needed
/// to finish the half-gates arithmetic after hashing is recorded here.
#[derive(Clone, Copy)]
struct PendingAnd {
    /// Output wire — its `label0` slot holds a placeholder until flush.
    wire: WireId,
    /// Index into the instance's table stride.
    and_idx: usize,
    wa0: Label,
    pa: bool,
    pb: bool,
}

/// Is `wire` the still-unhashed output of an in-flight AND gate?
#[inline]
fn in_flight(pend: &[PendingAnd], wire: WireId) -> bool {
    pend.iter().any(|p| p.wire == wire)
}

/// Hash the gathered flight and scatter ciphertexts + output labels:
/// `blocks[4g..4g+4]` hold the pre-images of gate `g`'s four hashes
/// `H(wa0,j), H(wa1,j), H(wb0,j'), H(wb1,j')`.
fn flush_garble(
    hash: &GarbleHash,
    delta: Delta,
    blocks: &mut [u128],
    pend: &mut Vec<PendingAnd>,
    label0: &mut [Label],
    table: &mut [[Label; 2]],
) {
    if pend.is_empty() {
        return;
    }
    hash.hash_many(&mut blocks[..4 * pend.len()]);
    for (g, p) in pend.iter().enumerate() {
        let h_wa0 = Label(blocks[4 * g]);
        let h_wa1 = Label(blocks[4 * g + 1]);
        let h_wb0 = Label(blocks[4 * g + 2]);
        let h_wb1 = Label(blocks[4 * g + 3]);
        // Garbler half-gate.
        let mut t_g = h_wa0 ^ h_wa1;
        if p.pb {
            t_g = t_g ^ delta.0;
        }
        let mut w_g0 = h_wa0;
        if p.pa {
            w_g0 = w_g0 ^ t_g;
        }
        // Evaluator half-gate.
        let t_e = h_wb0 ^ h_wb1 ^ p.wa0;
        let mut w_e0 = h_wb0;
        if p.pb {
            w_e0 = w_e0 ^ t_e ^ p.wa0;
        }
        table[p.and_idx] = [t_g, t_e];
        label0[p.wire as usize] = w_g0 ^ w_e0;
    }
    pend.clear();
}

/// The garbler's secret encoding of the circuit inputs.
#[derive(Clone, Debug)]
pub struct InputEncoding {
    /// `label0[i]` encodes value 0 on input `i`; value 1 is `label0 ⊕ Δ`.
    pub label0: Vec<Label>,
    pub delta: Delta,
}

impl InputEncoding {
    /// Label for input `i` carrying value `v`.
    pub fn encode(&self, i: usize, v: bool) -> Label {
        if v {
            self.label0[i] ^ self.delta.0
        } else {
            self.label0[i]
        }
    }

    /// Encode a full input assignment.
    pub fn encode_all(&self, vals: &[bool]) -> Vec<Label> {
        assert_eq!(vals.len(), self.label0.len());
        vals.iter().enumerate().map(|(i, &v)| self.encode(i, v)).collect()
    }

    /// Borrowed view (the shape the layer-batched arenas hand out).
    pub fn view(&self) -> EncodingView<'_> {
        EncodingView { label0: &self.label0, delta: self.delta }
    }
}

/// A borrowed input encoding: one instance's `label0` stride inside a
/// layer arena ([`crate::gc::batch::LayerEncodingBatch`]) or a standalone
/// [`InputEncoding`]. All label-delivery paths (direct + OT) encode
/// through this, so they are agnostic to how the labels are stored.
#[derive(Clone, Copy, Debug)]
pub struct EncodingView<'a> {
    /// `label0[i]` encodes value 0 on input `i`.
    pub label0: &'a [Label],
    pub delta: Delta,
}

impl EncodingView<'_> {
    /// Label for input `i` carrying value `v`.
    #[inline]
    pub fn encode(&self, i: usize, v: bool) -> Label {
        if v {
            self.label0[i] ^ self.delta.0
        } else {
            self.label0[i]
        }
    }

    pub fn n_inputs(&self) -> usize {
        self.label0.len()
    }
}

/// The material sent to the evaluator (plus, separately, input labels).
#[derive(Clone, Debug)]
pub struct GarbledCircuit {
    /// Two ciphertexts per AND gate, in gate order.
    pub table: Vec<[Label; 2]>,
    /// Point-and-permute decode bits: color of the 0-label of each output.
    pub output_decode: Vec<bool>,
}

impl GarbledCircuit {
    /// Size in bytes of the garbled tables (the paper's "GC size" driver).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 32
    }

    /// Decode output labels to cleartext bits.
    pub fn decode(&self, labels: &[Label]) -> Vec<bool> {
        assert_eq!(labels.len(), self.output_decode.len());
        labels.iter().zip(&self.output_decode).map(|(l, &d)| l.color() ^ d).collect()
    }
}

/// Garble a circuit. Returns the evaluator material and the garbler's
/// input encoding (kept secret; labels are delivered directly for the
/// garbler's own inputs and via OT for the evaluator's inputs).
pub fn garble(circuit: &Circuit, rng: &mut Rng) -> (GarbledCircuit, InputEncoding) {
    let mut scratch = Vec::new();
    garble_with_scratch(circuit, rng, &mut scratch)
}

/// Allocation-free variant for standalone garbling (tests, OT
/// integration): the wire-label buffer is reused across calls. Delegates
/// to [`garble_append`] so it consumes the RNG identically to the
/// layer-batched path.
pub fn garble_with_scratch(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
) -> (GarbledCircuit, InputEncoding) {
    let mut table = Vec::with_capacity(circuit.n_and());
    let mut input_label0 = Vec::with_capacity(circuit.n_inputs as usize);
    let mut output_decode = Vec::with_capacity(circuit.outputs.len());
    let delta =
        garble_append(circuit, rng, scratch, &mut table, &mut input_label0, &mut output_decode);
    (GarbledCircuit { table, output_decode }, InputEncoding { label0: input_label0, delta })
}

/// Low-level garbling core for the layer-batched offline path (§Perf
/// it. 4 + the SoA refactor): appends this instance's garbled table,
/// input `label0`s, and output decode bits to caller-owned flat buffers —
/// one contiguous buffer per *layer*, not per ReLU — and returns the
/// instance's free-XOR delta.
///
/// RNG draw order is the contract that keeps every garbling path
/// bit-identical: delta first, then one label per input wire in wire
/// order.
pub fn garble_append(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
    table: &mut Vec<[Label; 2]>,
    input_label0: &mut Vec<Label>,
    output_decode: &mut Vec<bool>,
) -> Delta {
    let t_base = table.len();
    let in_base = input_label0.len();
    let out_base = output_decode.len();
    table.resize(t_base + circuit.n_and(), [Label::ZERO; 2]);
    input_label0.resize(in_base + circuit.n_inputs as usize, Label::ZERO);
    output_decode.resize(out_base + circuit.outputs.len(), false);
    garble_into(
        circuit,
        rng,
        scratch,
        &mut table[t_base..],
        &mut input_label0[in_base..],
        &mut output_decode[out_base..],
    )
}

/// Slice-writing garbling core: fills exactly-sized caller-owned slices
/// for one instance's table / input-`label0` / decode-bit strides. This
/// is what lets [`crate::gc::batch::LayerGcBatch::garble_chunked`] hand
/// *disjoint* strides of one layer buffer to parallel dealer threads.
///
/// Draws from `rng` in the canonical order (delta, then one label per
/// input wire in wire order), so it is bit-identical to [`garble_append`]
/// given the same RNG state.
pub fn garble_into(
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
    table: &mut [[Label; 2]],
    input_label0: &mut [Label],
    output_decode: &mut [bool],
) -> Delta {
    let hash = GarbleHash::shared();
    garble_into_with(hash, circuit, rng, scratch, table, input_label0, output_decode)
}

/// [`garble_into`] with an explicit hasher — the hook that lets benches
/// and cross-backend tests garble through a forced PRF backend. All
/// backends hash identically, so the material is the same either way.
///
/// The gate walk is *gather-then-hash*: AND-gate hash pre-images are
/// collected across gates into a flight buffer and hashed in
/// [`FLIGHT_GATES`]-gate batches through [`GarbleHash::hash_many`]; a
/// flight is flushed early the moment a wire reads an in-flight gate's
/// output, so dependency chains stay correct and the result is
/// bit-identical to hashing gate by gate (hash order doesn't feed back
/// into the material — only RNG draw order does, and that is untouched).
pub fn garble_into_with(
    hash: &GarbleHash,
    circuit: &Circuit,
    rng: &mut Rng,
    scratch: &mut Vec<Label>,
    table: &mut [[Label; 2]],
    input_label0: &mut [Label],
    output_decode: &mut [bool],
) -> Delta {
    assert_eq!(table.len(), circuit.n_and(), "table stride");
    assert_eq!(input_label0.len(), circuit.n_inputs as usize, "input stride");
    assert_eq!(output_decode.len(), circuit.outputs.len(), "decode stride");
    let delta = Delta::random(rng);
    scratch.clear();
    scratch.reserve(circuit.wires.len());
    let label0 = scratch;
    let mut and_idx: usize = 0;
    let mut blocks = [0u128; 4 * FLIGHT_GATES];
    let mut pend: Vec<PendingAnd> = Vec::with_capacity(FLIGHT_GATES);

    for (w, def) in circuit.wires.iter().enumerate() {
        let l0 = match *def {
            WireDef::Input(k) => {
                // Inputs never depend on gates, so they never force a
                // flush — RNG draw order is independent of flight state.
                let l = Label::random(rng);
                input_label0[k as usize] = l;
                l
            }
            WireDef::Xor(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_garble(hash, delta, &mut blocks, &mut pend, label0, table);
                }
                label0[a as usize] ^ label0[b as usize]
            }
            WireDef::Not(a) => {
                if in_flight(&pend, a) {
                    flush_garble(hash, delta, &mut blocks, &mut pend, label0, table);
                }
                label0[a as usize] ^ delta.0
            }
            WireDef::And(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_garble(hash, delta, &mut blocks, &mut pend, label0, table);
                }
                let wa0 = label0[a as usize];
                let wb0 = label0[b as usize];
                let j = 2 * and_idx as u64;
                let jp = j + 1;
                let g = pend.len();
                blocks[4 * g] = GarbleHash::input_block(wa0, j);
                blocks[4 * g + 1] = GarbleHash::input_block(wa0 ^ delta.0, j);
                blocks[4 * g + 2] = GarbleHash::input_block(wb0, jp);
                blocks[4 * g + 3] = GarbleHash::input_block(wb0 ^ delta.0, jp);
                pend.push(PendingAnd {
                    wire: w as WireId,
                    and_idx,
                    wa0,
                    pa: wa0.color(),
                    pb: wb0.color(),
                });
                and_idx += 1;
                Label::ZERO // placeholder, patched when the flight flushes
            }
        };
        label0.push(l0);
        if pend.len() == FLIGHT_GATES {
            flush_garble(hash, delta, &mut blocks, &mut pend, label0, table);
        }
    }
    flush_garble(hash, delta, &mut blocks, &mut pend, label0, table);

    for (slot, &o) in output_decode.iter_mut().zip(circuit.outputs.iter()) {
        *slot = label0[o as usize].color();
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::{bits_to_u64, u64_to_bits, Builder};
    use crate::gc::eval::evaluate;

    /// Garble+evaluate roundtrip must match plain evaluation.
    fn roundtrip(circuit: &Circuit, inputs: &[bool], rng: &mut Rng) -> Vec<bool> {
        let (gc, enc) = garble(circuit, rng);
        let in_labels = enc.encode_all(inputs);
        let out_labels = evaluate(circuit, &gc, &in_labels);
        gc.decode(&out_labels)
    }

    #[test]
    fn single_and_gate_all_inputs() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(1);
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(roundtrip(&c, &[x, y], &mut rng), vec![x & y], "{x} {y}");
        }
    }

    #[test]
    fn xor_not_free_gates() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let x = bld.xor(a, b);
        let n = bld.not(x);
        bld.output(x);
        bld.output(n);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (gc, _) = garble(&c, &mut rng);
        assert_eq!(gc.table_bytes(), 0, "xor/not must garble for free");
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(roundtrip(&c, &[x, y], &mut rng), vec![x ^ y, !(x ^ y)]);
        }
    }

    #[test]
    fn adder_roundtrip() {
        let mut bld = Builder::new();
        let a = bld.input_bus(16);
        let b = bld.input_bus(16);
        let (s, carry) = bld.add(&a, &b);
        bld.output_bus(&s);
        bld.output(carry);
        let c = bld.build();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let x = rng.below(1 << 16);
            let y = rng.below(1 << 16);
            let mut inputs = u64_to_bits(x, 16);
            inputs.extend(u64_to_bits(y, 16));
            let out = roundtrip(&c, &inputs, &mut rng);
            let got = bits_to_u64(&out[..16]) | ((out[16] as u64) << 16);
            assert_eq!(got, x + y);
        }
    }

    #[test]
    fn random_circuits_match_plain_eval() {
        // Property test: random DAGs of XOR/AND/NOT garble correctly.
        let mut rng = Rng::new(4);
        for trial in 0..30 {
            let n_in = 2 + rng.below_usize(6);
            let mut bld = Builder::new();
            let mut pool: Vec<_> = (0..n_in).map(|_| bld.input()).collect();
            for _ in 0..40 {
                let a = pool[rng.below_usize(pool.len())];
                let b = pool[rng.below_usize(pool.len())];
                let v = match rng.below(3) {
                    0 => bld.xor(a, b),
                    1 => bld.and(a, b),
                    _ => bld.not(a),
                };
                pool.push(v);
            }
            for _ in 0..4 {
                let o = pool[rng.below_usize(pool.len())];
                // Only output live wires (constants folded away are fine too)
                bld.output(o);
            }
            let c = bld.build();
            for _ in 0..8 {
                let inputs: Vec<bool> = (0..n_in).map(|_| rng.bool()).collect();
                let want = c.eval_plain(&inputs);
                let got = roundtrip(&c, &inputs, &mut rng);
                assert_eq!(got, want, "trial {trial}");
            }
        }
    }

    /// Per-gate garbling reference (the pre-flight hot loop, kept here as
    /// the oracle): hash4 per AND gate, no gathering.
    fn garble_per_gate(circuit: &Circuit, rng: &mut Rng) -> (GarbledCircuit, InputEncoding) {
        let hash = GarbleHash::shared();
        let delta = Delta::random(rng);
        let mut label0: Vec<Label> = Vec::with_capacity(circuit.wires.len());
        let mut table = vec![[Label::ZERO; 2]; circuit.n_and()];
        let mut input_label0 = vec![Label::ZERO; circuit.n_inputs as usize];
        let mut and_idx: u64 = 0;
        for def in &circuit.wires {
            let l0 = match *def {
                WireDef::Input(k) => {
                    let l = Label::random(rng);
                    input_label0[k as usize] = l;
                    l
                }
                WireDef::Xor(a, b) => label0[a as usize] ^ label0[b as usize],
                WireDef::Not(a) => label0[a as usize] ^ delta.0,
                WireDef::And(a, b) => {
                    let wa0 = label0[a as usize];
                    let wb0 = label0[b as usize];
                    let j = 2 * and_idx;
                    let jp = j + 1;
                    let [h_wa0, h_wa1, h_wb0, h_wb1] =
                        hash.hash4([wa0, wa0 ^ delta.0, wb0, wb0 ^ delta.0], [j, j, jp, jp]);
                    let mut t_g = h_wa0 ^ h_wa1;
                    if wb0.color() {
                        t_g = t_g ^ delta.0;
                    }
                    let mut w_g0 = h_wa0;
                    if wa0.color() {
                        w_g0 = w_g0 ^ t_g;
                    }
                    let t_e = h_wb0 ^ h_wb1 ^ wa0;
                    let mut w_e0 = h_wb0;
                    if wb0.color() {
                        w_e0 = w_e0 ^ t_e ^ wa0;
                    }
                    table[and_idx as usize] = [t_g, t_e];
                    and_idx += 1;
                    w_g0 ^ w_e0
                }
            };
            label0.push(l0);
        }
        let output_decode = circuit.outputs.iter().map(|&o| label0[o as usize].color()).collect();
        (GarbledCircuit { table, output_decode }, InputEncoding { label0: input_label0, delta })
    }

    #[test]
    fn flight_batching_matches_per_gate_reference() {
        // The gather-then-hash walk must be bit-identical to hashing one
        // gate at a time, including on random DAGs whose dependency
        // chains force early flushes at every flight size.
        let mut rng = Rng::new(0xF11);
        for trial in 0..20 {
            let n_in = 2 + rng.below_usize(6);
            let mut bld = Builder::new();
            let mut pool: Vec<_> = (0..n_in).map(|_| bld.input()).collect();
            for _ in 0..60 {
                let a = pool[rng.below_usize(pool.len())];
                let b = pool[rng.below_usize(pool.len())];
                let v = match rng.below(3) {
                    0 => bld.xor(a, b),
                    1 => bld.and(a, b),
                    _ => bld.not(a),
                };
                pool.push(v);
            }
            for _ in 0..4 {
                bld.output(pool[rng.below_usize(pool.len())]);
            }
            let c = bld.build();
            let seed = 0xBEEF + trial;
            let (gc_flight, enc_flight) = garble(&c, &mut Rng::new(seed));
            let (gc_ref, enc_ref) = garble_per_gate(&c, &mut Rng::new(seed));
            assert_eq!(gc_flight.table, gc_ref.table, "trial {trial}: tables");
            assert_eq!(gc_flight.output_decode, gc_ref.output_decode, "trial {trial}: decode");
            assert_eq!(enc_flight.label0, enc_ref.label0, "trial {trial}: label0");
            assert_eq!(enc_flight.delta.0, enc_ref.delta.0, "trial {trial}: delta");
        }
    }

    #[test]
    fn table_size_is_32_bytes_per_and() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let b = bld.input_bus(31);
        let r = bld.leq(&a, &b);
        bld.output(r);
        let c = bld.build();
        let mut rng = Rng::new(5);
        let (gc, _) = garble(&c, &mut rng);
        assert_eq!(gc.table_bytes(), c.n_and() * 32);
    }

    #[test]
    fn labels_leak_nothing_obvious() {
        // The two labels of a wire must differ in more than the color bit.
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(6);
        let (_, enc) = garble(&c, &mut rng);
        let l0 = enc.encode(0, false);
        let l1 = enc.encode(0, true);
        assert!((l0.0 ^ l1.0).count_ones() > 10);
    }

    #[test]
    fn fresh_garbling_gives_fresh_labels() {
        // GCs cannot be reused across inferences (paper footnote 2): two
        // garblings of the same circuit must produce unrelated material.
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(7);
        let (gc1, e1) = garble(&c, &mut rng);
        let (gc2, e2) = garble(&c, &mut rng);
        assert_ne!(gc1.table[0][0], gc2.table[0][0]);
        assert_ne!(e1.label0[0], e2.label0[0]);
    }
}

//! GC cost accounting — the substrate behind Fig. 5.
//!
//! The paper's "GC size" is the per-ReLU client-side storage: garbled
//! tables plus input-label material. With half-gates each AND costs two
//! 16-byte ciphertexts; each circuit input costs one 16-byte label
//! (delivered directly for garbler inputs, via OT for evaluator inputs —
//! the OT-extension asymptote is ~2 labels/bit, tracked separately in
//! [`crate::ot`]).
//!
//! The Fig. 5 storage gap is therefore exactly `32 × ΔAND + 16 × Δinputs`
//! per ReLU between variants — the stochastic sign drops the mod-p
//! reconstruction's AND columns, truncation `k` shaves `k` comparator
//! ANDs *and* `2k` input labels. Since the material-squeeze round these
//! counts are measured on the *post-optimizer* templates (hash-consing
//! CSE build + [`Circuit::optimize`] — see [`super::build`]): the
//! baseline ReLU sheds a couple of ANDs of structural duplication on top
//! of constant folding, while the lean stochastic circuits were already
//! duplicate-free, so the paper's relative storage ratios hold with the
//! absolute bytes a touch smaller. `benches/circuit_size.rs` records the
//! per-variant before/after counts.

use super::circuit::Circuit;

/// Byte/gate cost summary of one circuit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CircuitCost {
    pub n_inputs: usize,
    pub n_outputs: usize,
    pub n_and: usize,
    pub n_xor: usize,
    /// Free like XOR, but counted: NOTs are where the optimizer's
    /// dead-wire elimination shows up.
    pub n_not: usize,
}

/// Bytes per AND gate under half-gates garbling.
pub const BYTES_PER_AND: usize = 32;

/// Bytes per transferred wire label.
pub const BYTES_PER_LABEL: usize = 16;

impl CircuitCost {
    pub fn of(c: &Circuit) -> Self {
        Self {
            n_inputs: c.n_inputs as usize,
            n_outputs: c.outputs.len(),
            n_and: c.n_and(),
            n_xor: c.n_xor(),
            n_not: c.n_not(),
        }
    }

    /// Total gates (AND + XOR + NOT).
    pub fn n_gates(&self) -> usize {
        self.n_and + self.n_xor + self.n_not
    }

    /// Garbled-table bytes (the dominant, reuse-proof storage).
    pub fn table_bytes(&self) -> usize {
        self.n_and * BYTES_PER_AND
    }

    /// Input-label bytes (one label per input bit).
    pub fn label_bytes(&self) -> usize {
        self.n_inputs * BYTES_PER_LABEL
    }

    /// Total client-side storage per circuit instance.
    pub fn total_bytes(&self) -> usize {
        self.table_bytes() + self.label_bytes()
    }
}

impl std::fmt::Display for CircuitCost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} AND / {} XOR, {} in / {} out, table {} B, labels {} B, total {} B",
            self.n_and,
            self.n_xor,
            self.n_inputs,
            self.n_outputs,
            self.table_bytes(),
            self.label_bytes(),
            self.total_bytes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::Builder;

    #[test]
    fn cost_counts_match_circuit() {
        let mut bld = Builder::new();
        let a = bld.input_bus(8);
        let b = bld.input_bus(8);
        let (s, c) = bld.add(&a, &b);
        bld.output_bus(&s);
        bld.output(c);
        let circ = bld.build();
        let cost = CircuitCost::of(&circ);
        assert_eq!(cost.n_inputs, 16);
        assert_eq!(cost.n_and, 8);
        assert_eq!(cost.table_bytes(), 8 * 32);
        assert_eq!(cost.label_bytes(), 16 * 16);
        assert_eq!(cost.total_bytes(), 8 * 32 + 16 * 16);
    }

    #[test]
    fn display_formats() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let cost = CircuitCost::of(&bld.build());
        let s = format!("{cost}");
        assert!(s.contains("1 AND"));
    }
}

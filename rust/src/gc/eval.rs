//! Evaluator for half-gates garbled circuits.
//!
//! The evaluator holds exactly one label per input wire and walks the
//! circuit in topological order. XOR gates XOR labels, NOT gates pass the
//! label through (the garbler flipped the semantics), and AND gates apply
//! the two half-gate ciphertexts keyed by the labels' color bits.

use super::circuit::{Circuit, WireDef};
use super::garble::GarbledCircuit;
use crate::prf::{GarbleHash, Label};

/// Evaluate a garbled circuit on input labels; returns output labels.
///
/// Decode with [`GarbledCircuit::decode`] (or hand the labels back to the
/// garbler, which is what the PI protocol does — the *server* learns the
/// ReLU output share, not the client).
pub fn evaluate(circuit: &Circuit, gc: &GarbledCircuit, input_labels: &[Label]) -> Vec<Label> {
    let mut scratch = Vec::new();
    evaluate_with_scratch(circuit, gc, input_labels, &mut scratch)
}

/// Allocation-free variant for hot loops (§Perf iteration 3): the wire
/// buffer is borrowed from the caller and reused across circuits — the
/// online path evaluates one circuit per ReLU, thousands per inference.
pub fn evaluate_with_scratch(
    circuit: &Circuit,
    gc: &GarbledCircuit,
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
) -> Vec<Label> {
    let mut out = Vec::with_capacity(circuit.outputs.len());
    evaluate_append(circuit, &gc.table, input_labels, scratch, &mut out);
    out
}

/// Low-level evaluation core for the layer-batched online path: the
/// garbled table arrives as a raw ciphertext slice (one instance's stride
/// of a layer's contiguous table buffer) and the output labels are
/// appended to a caller-owned buffer. The batch walk calls this once per
/// ReLU with the *same* circuit template and reused scratch.
pub fn evaluate_append(
    circuit: &Circuit,
    table: &[[Label; 2]],
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
    out: &mut Vec<Label>,
) {
    assert_eq!(input_labels.len(), circuit.n_inputs as usize, "input label arity");
    let hash = GarbleHash::shared();
    scratch.clear();
    scratch.reserve(circuit.wires.len());
    let labels = scratch;
    let mut and_idx: u64 = 0;

    for def in &circuit.wires {
        let l = match *def {
            WireDef::Input(k) => input_labels[k as usize],
            WireDef::Xor(a, b) => labels[a as usize] ^ labels[b as usize],
            WireDef::Not(a) => labels[a as usize],
            WireDef::And(a, b) => {
                let wa = labels[a as usize];
                let wb = labels[b as usize];
                let [t_g, t_e] = table[and_idx as usize];
                let j = 2 * and_idx;
                let jp = 2 * and_idx + 1;
                and_idx += 1;
                let sa = wa.color();
                let sb = wb.color();
                // One pipelined 2-block AES call per AND gate (§Perf it. 2).
                let [mut w_g, mut w_e] = hash.hash2(wa, j, wb, jp);
                if sa {
                    w_g = w_g ^ t_g;
                }
                if sb {
                    w_e = w_e ^ t_e ^ wa;
                }
                w_g ^ w_e
            }
        };
        labels.push(l);
    }
    out.extend(circuit.outputs.iter().map(|&o| labels[o as usize]));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::Builder;
    use crate::gc::garble::garble;
    use crate::util::Rng;

    #[test]
    fn evaluator_never_sees_both_labels() {
        // Evaluate twice with different inputs: the labels observed for
        // the same wire must differ (they are the two distinct labels).
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(1);
        let (gc, enc) = garble(&c, &mut rng);
        let l_false = evaluate(&c, &gc, &[enc.encode(0, false)]);
        let l_true = evaluate(&c, &gc, &[enc.encode(0, true)]);
        assert_ne!(l_false[0], l_true[0]);
        assert_eq!(gc.decode(&l_false), vec![false]);
        assert_eq!(gc.decode(&l_true), vec![true]);
    }

    #[test]
    #[should_panic]
    fn wrong_label_count_panics() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (gc, enc) = garble(&c, &mut rng);
        evaluate(&c, &gc, &[enc.encode(0, false)]); // only one label
    }

    #[test]
    fn corrupted_table_changes_output_label() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(3);
        let (mut gc, enc) = garble(&c, &mut rng);
        let labels = enc.encode_all(&[true, true]);
        let good = evaluate(&c, &gc, &labels);
        gc.table[0][0] = Label(gc.table[0][0].0 ^ 0xFF00);
        let bad = evaluate(&c, &gc, &labels);
        assert_ne!(good[0], bad[0], "tampering must disturb the label");
    }
}

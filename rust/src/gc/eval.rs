//! Evaluator for half-gates garbled circuits.
//!
//! The evaluator holds exactly one label per input wire and walks the
//! circuit in topological order. XOR gates XOR labels, NOT gates pass the
//! label through (the garbler flipped the semantics), and AND gates apply
//! the two half-gate ciphertexts keyed by the labels' color bits.

use super::circuit::{Circuit, WireDef, WireId};
use super::garble::GarbledCircuit;
use crate::prf::{GarbleHash, Label};

/// AND gates gathered per hash flight (2 hashes each → one full
/// [`crate::prf::backend::MAX_BATCH`]-block cipher call per 4 gates).
const FLIGHT_GATES: usize = 8;

/// One gathered-but-not-yet-hashed AND gate of the evaluation walk; the
/// two hash pre-images sit in the flight buffer.
#[derive(Clone, Copy)]
struct PendingAnd {
    /// Output wire — its label slot holds a placeholder until flush.
    wire: WireId,
    wa: Label,
    sa: bool,
    sb: bool,
    t_g: Label,
    t_e: Label,
}

/// Is `wire` the still-unhashed output of an in-flight AND gate?
#[inline]
fn in_flight(pend: &[PendingAnd], wire: WireId) -> bool {
    pend.iter().any(|p| p.wire == wire)
}

/// Hash the gathered flight and scatter output labels: `blocks[2g]`,
/// `blocks[2g+1]` hold the pre-images of gate `g`'s `H(wa, j)`,
/// `H(wb, j')`.
fn flush_eval(
    hash: &GarbleHash,
    blocks: &mut [u128],
    pend: &mut Vec<PendingAnd>,
    labels: &mut [Label],
) {
    if pend.is_empty() {
        return;
    }
    hash.hash_many(&mut blocks[..2 * pend.len()]);
    for (g, p) in pend.iter().enumerate() {
        let mut w_g = Label(blocks[2 * g]);
        let mut w_e = Label(blocks[2 * g + 1]);
        if p.sa {
            w_g = w_g ^ p.t_g;
        }
        if p.sb {
            w_e = w_e ^ p.t_e ^ p.wa;
        }
        labels[p.wire as usize] = w_g ^ w_e;
    }
    pend.clear();
}

/// Evaluate a garbled circuit on input labels; returns output labels.
///
/// Decode with [`GarbledCircuit::decode`] (or hand the labels back to the
/// garbler, which is what the PI protocol does — the *server* learns the
/// ReLU output share, not the client).
pub fn evaluate(circuit: &Circuit, gc: &GarbledCircuit, input_labels: &[Label]) -> Vec<Label> {
    let mut scratch = Vec::new();
    evaluate_with_scratch(circuit, gc, input_labels, &mut scratch)
}

/// Allocation-free variant for hot loops (§Perf iteration 3): the wire
/// buffer is borrowed from the caller and reused across circuits — the
/// online path evaluates one circuit per ReLU, thousands per inference.
pub fn evaluate_with_scratch(
    circuit: &Circuit,
    gc: &GarbledCircuit,
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
) -> Vec<Label> {
    let mut out = Vec::with_capacity(circuit.outputs.len());
    evaluate_append(circuit, &gc.table, input_labels, scratch, &mut out);
    out
}

/// Low-level evaluation core for the layer-batched online path: the
/// garbled table arrives as a raw ciphertext slice (one instance's stride
/// of a layer's contiguous table buffer) and the output labels are
/// appended to a caller-owned buffer. The batch walk calls this once per
/// ReLU with the *same* circuit template and reused scratch.
///
/// The gate walk is *gather-then-hash* (mirror of
/// [`super::garble::garble_into_with`]): AND-gate hash pre-images are
/// gathered across gates and hashed in [`FLIGHT_GATES`]-gate flights via
/// [`GarbleHash::hash_many`], flushing early whenever a wire reads an
/// in-flight gate's output. Output labels are identical to per-gate
/// hashing — the hashes are independent, only their scheduling changes.
pub fn evaluate_append(
    circuit: &Circuit,
    table: &[[Label; 2]],
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
    out: &mut Vec<Label>,
) {
    assert_eq!(input_labels.len(), circuit.n_inputs as usize, "input label arity");
    let hash = GarbleHash::shared();
    scratch.clear();
    scratch.reserve(circuit.wires.len());
    let labels = scratch;
    let mut and_idx: usize = 0;
    let mut blocks = [0u128; 2 * FLIGHT_GATES];
    let mut pend: Vec<PendingAnd> = Vec::with_capacity(FLIGHT_GATES);

    for (w, def) in circuit.wires.iter().enumerate() {
        let l = match *def {
            WireDef::Input(k) => input_labels[k as usize],
            WireDef::Xor(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                labels[a as usize] ^ labels[b as usize]
            }
            WireDef::Not(a) => {
                if in_flight(&pend, a) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                labels[a as usize]
            }
            WireDef::And(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                let wa = labels[a as usize];
                let wb = labels[b as usize];
                let [t_g, t_e] = table[and_idx];
                let j = 2 * and_idx as u64;
                let jp = j + 1;
                let g = pend.len();
                blocks[2 * g] = GarbleHash::input_block(wa, j);
                blocks[2 * g + 1] = GarbleHash::input_block(wb, jp);
                pend.push(PendingAnd {
                    wire: w as WireId,
                    wa,
                    sa: wa.color(),
                    sb: wb.color(),
                    t_g,
                    t_e,
                });
                and_idx += 1;
                Label::ZERO // placeholder, patched when the flight flushes
            }
        };
        labels.push(l);
        if pend.len() == FLIGHT_GATES {
            flush_eval(hash, &mut blocks, &mut pend, labels);
        }
    }
    flush_eval(hash, &mut blocks, &mut pend, labels);
    out.extend(circuit.outputs.iter().map(|&o| labels[o as usize]));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::Builder;
    use crate::gc::garble::garble;
    use crate::util::Rng;

    #[test]
    fn evaluator_never_sees_both_labels() {
        // Evaluate twice with different inputs: the labels observed for
        // the same wire must differ (they are the two distinct labels).
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(1);
        let (gc, enc) = garble(&c, &mut rng);
        let l_false = evaluate(&c, &gc, &[enc.encode(0, false)]);
        let l_true = evaluate(&c, &gc, &[enc.encode(0, true)]);
        assert_ne!(l_false[0], l_true[0]);
        assert_eq!(gc.decode(&l_false), vec![false]);
        assert_eq!(gc.decode(&l_true), vec![true]);
    }

    #[test]
    #[should_panic]
    fn wrong_label_count_panics() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (gc, enc) = garble(&c, &mut rng);
        evaluate(&c, &gc, &[enc.encode(0, false)]); // only one label
    }

    #[test]
    fn corrupted_table_changes_output_label() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(3);
        let (mut gc, enc) = garble(&c, &mut rng);
        let labels = enc.encode_all(&[true, true]);
        let good = evaluate(&c, &gc, &labels);
        gc.table[0][0] = Label(gc.table[0][0].0 ^ 0xFF00);
        let bad = evaluate(&c, &gc, &labels);
        assert_ne!(good[0], bad[0], "tampering must disturb the label");
    }
}

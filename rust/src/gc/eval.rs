//! Evaluator for half-gates garbled circuits.
//!
//! The evaluator holds exactly one label per input wire and walks the
//! circuit in topological order. XOR gates XOR labels, NOT gates pass the
//! label through (the garbler flipped the semantics), and AND gates apply
//! the two half-gate ciphertexts keyed by the labels' color bits.

use super::circuit::{Circuit, WireDef, WireId};
use super::garble::GarbledCircuit;
use crate::prf::{GarbleHash, Label};

/// AND gates gathered per hash flight (2 hashes each → one full
/// [`crate::prf::backend::MAX_BATCH`]-block cipher call per 4 gates).
const FLIGHT_GATES: usize = 8;

/// Instances walked together by [`evaluate_group_colors`]: with 2 hash
/// pre-images per AND gate, a full group turns every gate position into
/// two full [`crate::prf::backend::MAX_BATCH`]-block cipher calls.
pub const GROUP_WIDTH: usize = 8;

/// One instance of a shared circuit template inside a group walk: its
/// stride of a garbled-table buffer plus its two input-label blocks
/// (client block first, then server block — the protocol layout).
#[derive(Clone, Copy)]
pub struct GroupInstance<'a> {
    pub table: &'a [[Label; 2]],
    pub client: &'a [Label],
    pub server: &'a [Label],
}

/// One gathered-but-not-yet-hashed AND gate of the evaluation walk; the
/// two hash pre-images sit in the flight buffer.
#[derive(Clone, Copy)]
struct PendingAnd {
    /// Output wire — its label slot holds a placeholder until flush.
    wire: WireId,
    wa: Label,
    sa: bool,
    sb: bool,
    t_g: Label,
    t_e: Label,
}

/// Is `wire` the still-unhashed output of an in-flight AND gate?
#[inline]
fn in_flight(pend: &[PendingAnd], wire: WireId) -> bool {
    pend.iter().any(|p| p.wire == wire)
}

/// Hash the gathered flight and scatter output labels: `blocks[2g]`,
/// `blocks[2g+1]` hold the pre-images of gate `g`'s `H(wa, j)`,
/// `H(wb, j')`.
fn flush_eval(
    hash: &GarbleHash,
    blocks: &mut [u128],
    pend: &mut Vec<PendingAnd>,
    labels: &mut [Label],
) {
    if pend.is_empty() {
        return;
    }
    hash.hash_many(&mut blocks[..2 * pend.len()]);
    for (g, p) in pend.iter().enumerate() {
        let mut w_g = Label(blocks[2 * g]);
        let mut w_e = Label(blocks[2 * g + 1]);
        if p.sa {
            w_g = w_g ^ p.t_g;
        }
        if p.sb {
            w_e = w_e ^ p.t_e ^ p.wa;
        }
        labels[p.wire as usize] = w_g ^ w_e;
    }
    pend.clear();
}

/// Evaluate a garbled circuit on input labels; returns output labels.
///
/// Decode with [`GarbledCircuit::decode`] (or hand the labels back to the
/// garbler, which is what the PI protocol does — the *server* learns the
/// ReLU output share, not the client).
pub fn evaluate(circuit: &Circuit, gc: &GarbledCircuit, input_labels: &[Label]) -> Vec<Label> {
    let mut scratch = Vec::new();
    evaluate_with_scratch(circuit, gc, input_labels, &mut scratch)
}

/// Allocation-free variant for hot loops (§Perf iteration 3): the wire
/// buffer is borrowed from the caller and reused across circuits — the
/// online path evaluates one circuit per ReLU, thousands per inference.
pub fn evaluate_with_scratch(
    circuit: &Circuit,
    gc: &GarbledCircuit,
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
) -> Vec<Label> {
    let mut out = Vec::with_capacity(circuit.outputs.len());
    evaluate_append(circuit, &gc.table, input_labels, scratch, &mut out);
    out
}

/// Low-level evaluation core for the layer-batched online path: the
/// garbled table arrives as a raw ciphertext slice (one instance's stride
/// of a layer's contiguous table buffer) and the output labels are
/// appended to a caller-owned buffer. The batch walk calls this once per
/// ReLU with the *same* circuit template and reused scratch.
///
/// The gate walk is *gather-then-hash* (mirror of
/// [`super::garble::garble_into_with`]): AND-gate hash pre-images are
/// gathered across gates and hashed in [`FLIGHT_GATES`]-gate flights via
/// [`GarbleHash::hash_many`], flushing early whenever a wire reads an
/// in-flight gate's output. Output labels are identical to per-gate
/// hashing — the hashes are independent, only their scheduling changes.
pub fn evaluate_append(
    circuit: &Circuit,
    table: &[[Label; 2]],
    input_labels: &[Label],
    scratch: &mut Vec<Label>,
    out: &mut Vec<Label>,
) {
    assert_eq!(input_labels.len(), circuit.n_inputs as usize, "input label arity");
    let hash = GarbleHash::shared();
    scratch.clear();
    scratch.reserve(circuit.wires.len());
    let labels = scratch;
    let mut and_idx: usize = 0;
    let mut blocks = [0u128; 2 * FLIGHT_GATES];
    let mut pend: Vec<PendingAnd> = Vec::with_capacity(FLIGHT_GATES);

    for (w, def) in circuit.wires.iter().enumerate() {
        let l = match *def {
            WireDef::Input(k) => input_labels[k as usize],
            WireDef::Xor(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                labels[a as usize] ^ labels[b as usize]
            }
            WireDef::Not(a) => {
                if in_flight(&pend, a) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                labels[a as usize]
            }
            WireDef::And(a, b) => {
                if in_flight(&pend, a) || in_flight(&pend, b) {
                    flush_eval(hash, &mut blocks, &mut pend, labels);
                }
                let wa = labels[a as usize];
                let wb = labels[b as usize];
                let [t_g, t_e] = table[and_idx];
                let j = 2 * and_idx as u64;
                let jp = j + 1;
                let g = pend.len();
                blocks[2 * g] = GarbleHash::input_block(wa, j);
                blocks[2 * g + 1] = GarbleHash::input_block(wb, jp);
                pend.push(PendingAnd {
                    wire: w as WireId,
                    wa,
                    sa: wa.color(),
                    sb: wb.color(),
                    t_g,
                    t_e,
                });
                and_idx += 1;
                Label::ZERO // placeholder, patched when the flight flushes
            }
        };
        labels.push(l);
        if pend.len() == FLIGHT_GATES {
            flush_eval(hash, &mut blocks, &mut pend, labels);
        }
    }
    flush_eval(hash, &mut blocks, &mut pend, labels);
    out.extend(circuit.outputs.iter().map(|&o| labels[o as usize]));
}

/// Evaluate up to [`GROUP_WIDTH`] independent instances of the same
/// circuit template in one wire-major walk, appending each instance's
/// output colors (instance-major, [`Circuit::outputs`] order within an
/// instance) to `colors`.
///
/// Where [`evaluate_append`] walks one instance and fills hash flights
/// across *gates* — flushing early whenever a wire reads an in-flight
/// gate's output — the group walk fills flights across *instances*: at
/// each AND gate position the instances' `2·G` pre-images are
/// independent by construction, so every flight is full and no
/// dependency tracking exists at all. The hashes are the same per-block
/// transforms with the same tweaks, so the output colors are
/// bit-identical to evaluating each instance alone.
///
/// The wire scratch is laid out wire-major (`scratch[w·G + j]` holds
/// instance `j`'s label of wire `w`) so the per-gate gather/scatter is
/// one contiguous row.
pub fn evaluate_group_colors(
    circuit: &Circuit,
    insts: &[GroupInstance<'_>],
    scratch: &mut Vec<Label>,
    colors: &mut Vec<bool>,
) {
    let g = insts.len();
    assert!(g > 0 && g <= GROUP_WIDTH, "group width {g}");
    let n_and = circuit.n_and();
    for inst in insts {
        assert_eq!(inst.table.len(), n_and, "table stride");
        assert_eq!(
            inst.client.len() + inst.server.len(),
            circuit.n_inputs as usize,
            "input label arity"
        );
    }
    let hash = GarbleHash::shared();
    scratch.clear();
    scratch.resize(circuit.wires.len() * g, Label::ZERO);
    let labels = &mut scratch[..];
    let mut blocks = [0u128; 2 * GROUP_WIDTH];
    let mut and_idx = 0usize;
    for (w, def) in circuit.wires.iter().enumerate() {
        let row = w * g;
        match *def {
            WireDef::Input(k) => {
                let k = k as usize;
                for (j, inst) in insts.iter().enumerate() {
                    labels[row + j] = if k < inst.client.len() {
                        inst.client[k]
                    } else {
                        inst.server[k - inst.client.len()]
                    };
                }
            }
            WireDef::Xor(a, b) => {
                let (a, b) = (a as usize * g, b as usize * g);
                for j in 0..g {
                    labels[row + j] = labels[a + j] ^ labels[b + j];
                }
            }
            WireDef::Not(a) => {
                let a = a as usize * g;
                for j in 0..g {
                    labels[row + j] = labels[a + j];
                }
            }
            WireDef::And(a, b) => {
                let (a, b) = (a as usize * g, b as usize * g);
                let j_g = 2 * and_idx as u64;
                let j_e = j_g + 1;
                for j in 0..g {
                    blocks[2 * j] = GarbleHash::input_block(labels[a + j], j_g);
                    blocks[2 * j + 1] = GarbleHash::input_block(labels[b + j], j_e);
                }
                hash.hash_many(&mut blocks[..2 * g]);
                for (j, inst) in insts.iter().enumerate() {
                    let wa = labels[a + j];
                    let wb = labels[b + j];
                    let [t_g, t_e] = inst.table[and_idx];
                    let mut w_g = Label(blocks[2 * j]);
                    let mut w_e = Label(blocks[2 * j + 1]);
                    if wa.color() {
                        w_g = w_g ^ t_g;
                    }
                    if wb.color() {
                        w_e = w_e ^ t_e ^ wa;
                    }
                    labels[row + j] = w_g ^ w_e;
                }
                and_idx += 1;
            }
        }
    }
    colors.reserve(g * circuit.outputs.len());
    for j in 0..g {
        colors.extend(circuit.outputs.iter().map(|&o| labels[o as usize * g + j].color()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::Builder;
    use crate::gc::garble::garble;
    use crate::util::Rng;

    #[test]
    fn evaluator_never_sees_both_labels() {
        // Evaluate twice with different inputs: the labels observed for
        // the same wire must differ (they are the two distinct labels).
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(1);
        let (gc, enc) = garble(&c, &mut rng);
        let l_false = evaluate(&c, &gc, &[enc.encode(0, false)]);
        let l_true = evaluate(&c, &gc, &[enc.encode(0, true)]);
        assert_ne!(l_false[0], l_true[0]);
        assert_eq!(gc.decode(&l_false), vec![false]);
        assert_eq!(gc.decode(&l_true), vec![true]);
    }

    #[test]
    #[should_panic]
    fn wrong_label_count_panics() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (gc, enc) = garble(&c, &mut rng);
        evaluate(&c, &gc, &[enc.encode(0, false)]); // only one label
    }

    #[test]
    fn group_eval_matches_per_instance_eval() {
        // The cross-request walk must be bit-identical to evaluating
        // each instance alone, for every group width (ragged tails
        // included) and for arbitrary client/server input splits.
        let mut bld = Builder::new();
        let a = bld.input_bus(6);
        let b = bld.input_bus(6);
        let (s, carry) = bld.add(&a, &b);
        let m = bld.and(s[0], carry);
        bld.output_bus(&s);
        bld.output(m);
        let c = bld.build();
        let mut rng = Rng::new(77);
        for g in [1usize, 2, 3, 7, 8] {
            let mut tables = Vec::new();
            let mut inputs = Vec::new();
            let mut want = Vec::new();
            let mut scratch = Vec::new();
            for i in 0..g {
                let (gc, enc) = garble(&c, &mut rng);
                let bits: Vec<bool> =
                    (0..c.n_inputs as usize).map(|j| (i + j) % 3 == 0).collect();
                let labels = enc.encode_all(&bits);
                let mut out = Vec::new();
                evaluate_append(&c, &gc.table, &labels, &mut scratch, &mut out);
                want.extend(out.iter().map(|l| l.color()));
                tables.push(gc.table);
                inputs.push(labels);
            }
            // Split each instance's labels at 5: "client" block + rest.
            let insts: Vec<GroupInstance<'_>> = (0..g)
                .map(|i| GroupInstance {
                    table: &tables[i],
                    client: &inputs[i][..5],
                    server: &inputs[i][5..],
                })
                .collect();
            let mut colors = Vec::new();
            evaluate_group_colors(&c, &insts, &mut scratch, &mut colors);
            assert_eq!(colors, want, "group width {g}");
        }
    }

    #[test]
    fn corrupted_table_changes_output_label() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.and(a, b);
        bld.output(o);
        let c = bld.build();
        let mut rng = Rng::new(3);
        let (mut gc, enc) = garble(&c, &mut rng);
        let labels = enc.encode_all(&[true, true]);
        let good = evaluate(&c, &gc, &labels);
        gc.table[0][0] = Label(gc.table[0][0].0 ^ 0xFF00);
        let bad = evaluate(&c, &gc, &labels);
        assert_ne!(good[0], bad[0], "tampering must disturb the label");
    }
}

//! Boolean circuit IR.
//!
//! Wires form a single id space in topological order: the definition of
//! wire `i` may only reference wires `< i`. Inputs are `Input(k)` wires
//! (with `k` the input position), so the IR is valid by construction.

/// Index of a wire in [`Circuit::wires`].
pub type WireId = u32;

/// Definition of one wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireDef {
    /// The `k`-th circuit input.
    Input(u32),
    /// XOR of two earlier wires (free under free-XOR garbling).
    Xor(WireId, WireId),
    /// AND of two earlier wires (costs one garbled table entry).
    And(WireId, WireId),
    /// Negation (free: label-semantics flip).
    Not(WireId),
}

/// A boolean circuit.
#[derive(Clone, Debug, Default)]
pub struct Circuit {
    pub wires: Vec<WireDef>,
    pub n_inputs: u32,
    pub outputs: Vec<WireId>,
}

impl Circuit {
    /// Number of AND gates (the garbling cost driver).
    pub fn n_and(&self) -> usize {
        self.wires.iter().filter(|w| matches!(w, WireDef::And(_, _))).count()
    }

    /// Number of XOR gates (free to garble, still counts toward build time).
    pub fn n_xor(&self) -> usize {
        self.wires.iter().filter(|w| matches!(w, WireDef::Xor(_, _))).count()
    }

    /// Plain (insecure) evaluation — the correctness oracle for the
    /// garbling engine and for the Fig. 2 circuits.
    pub fn eval_plain(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.n_inputs as usize, "input arity mismatch");
        let mut vals: Vec<bool> = Vec::with_capacity(self.wires.len());
        for w in &self.wires {
            let v = match *w {
                WireDef::Input(k) => inputs[k as usize],
                WireDef::Xor(a, b) => vals[a as usize] ^ vals[b as usize],
                WireDef::And(a, b) => vals[a as usize] & vals[b as usize],
                WireDef::Not(a) => !vals[a as usize],
            };
            vals.push(v);
        }
        self.outputs.iter().map(|&o| vals[o as usize]).collect()
    }

    /// Validate topological ordering and input numbering; used in tests
    /// and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen_inputs = 0u32;
        for (i, w) in self.wires.iter().enumerate() {
            let check = |x: WireId| -> Result<(), String> {
                if x as usize >= i {
                    Err(format!("wire {i} references later wire {x}"))
                } else {
                    Ok(())
                }
            };
            match *w {
                WireDef::Input(k) => {
                    if k != seen_inputs {
                        return Err(format!("input {k} out of order at wire {i}"));
                    }
                    seen_inputs += 1;
                }
                WireDef::Xor(a, b) | WireDef::And(a, b) => {
                    check(a)?;
                    check(b)?;
                }
                WireDef::Not(a) => check(a)?,
            }
        }
        if seen_inputs != self.n_inputs {
            return Err(format!("n_inputs {} != declared {}", seen_inputs, self.n_inputs));
        }
        for &o in &self.outputs {
            if o as usize >= self.wires.len() {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_and_circuit() -> Circuit {
        // out0 = (a ^ b), out1 = (a & b), out2 = !a
        Circuit {
            wires: vec![
                WireDef::Input(0),
                WireDef::Input(1),
                WireDef::Xor(0, 1),
                WireDef::And(0, 1),
                WireDef::Not(0),
            ],
            n_inputs: 2,
            outputs: vec![2, 3, 4],
        }
    }

    #[test]
    fn plain_eval_truth_table() {
        let c = xor_and_circuit();
        assert!(c.validate().is_ok());
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = c.eval_plain(&[a, b]);
            assert_eq!(out, vec![a ^ b, a & b, !a]);
        }
    }

    #[test]
    fn gate_counts() {
        let c = xor_and_circuit();
        assert_eq!(c.n_and(), 1);
        assert_eq!(c.n_xor(), 1);
    }

    #[test]
    fn validate_rejects_forward_reference() {
        let c = Circuit {
            wires: vec![WireDef::Input(0), WireDef::Xor(0, 2), WireDef::Input(1)],
            n_inputs: 2,
            outputs: vec![1],
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_output() {
        let c = Circuit {
            wires: vec![WireDef::Input(0)],
            n_inputs: 1,
            outputs: vec![5],
        };
        assert!(c.validate().is_err());
    }

    #[test]
    #[should_panic]
    fn eval_wrong_arity_panics() {
        xor_and_circuit().eval_plain(&[true]);
    }
}

//! Boolean circuit IR.
//!
//! Wires form a single id space in topological order: the definition of
//! wire `i` may only reference wires `< i`. Inputs are `Input(k)` wires
//! (with `k` the input position), so the IR is valid by construction.

/// Index of a wire in [`Circuit::wires`].
pub type WireId = u32;

/// Definition of one wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireDef {
    /// The `k`-th circuit input.
    Input(u32),
    /// XOR of two earlier wires (free under free-XOR garbling).
    Xor(WireId, WireId),
    /// AND of two earlier wires (costs one garbled table entry).
    And(WireId, WireId),
    /// Negation (free: label-semantics flip).
    Not(WireId),
}

/// A boolean circuit.
#[derive(Clone, Debug, Default)]
pub struct Circuit {
    pub wires: Vec<WireDef>,
    pub n_inputs: u32,
    pub outputs: Vec<WireId>,
}

impl Circuit {
    /// Number of AND gates (the garbling cost driver).
    pub fn n_and(&self) -> usize {
        self.wires.iter().filter(|w| matches!(w, WireDef::And(_, _))).count()
    }

    /// Number of XOR gates (free to garble, still counts toward build time).
    pub fn n_xor(&self) -> usize {
        self.wires.iter().filter(|w| matches!(w, WireDef::Xor(_, _))).count()
    }

    /// Number of NOT gates (free: label-semantics flip).
    pub fn n_not(&self) -> usize {
        self.wires.iter().filter(|w| matches!(w, WireDef::Not(_))).count()
    }

    /// Total gate count (everything that is not an input wire).
    pub fn n_gates(&self) -> usize {
        self.wires.len() - self.n_inputs as usize
    }

    /// Material-squeeze pass over a built circuit: output-reachability
    /// dead-wire elimination, duplicate-gate elimination (commutatively
    /// normalized — a safety net for circuits assembled outside the
    /// hash-consing builder), and topological compaction with a wire-id
    /// remap (outputs rewritten).
    ///
    /// All `Input` wires are kept in order regardless of liveness: the
    /// protocol's label encoders address inputs positionally, so the input
    /// layout is part of the circuit's external contract. `eval_plain` on
    /// the result is pointwise identical to the original and `validate()`
    /// holds whenever it held on the input.
    pub fn optimize(&self) -> Circuit {
        let n = self.wires.len();
        // 1. Liveness: everything reachable from an output.
        let mut live = vec![false; n];
        let mut stack: Vec<WireId> = self.outputs.clone();
        while let Some(w) = stack.pop() {
            let i = w as usize;
            if live[i] {
                continue;
            }
            live[i] = true;
            match self.wires[i] {
                WireDef::Input(_) => {}
                WireDef::Xor(a, b) | WireDef::And(a, b) => {
                    stack.push(a);
                    stack.push(b);
                }
                WireDef::Not(a) => stack.push(a),
            }
        }
        // 2. Forward pass: compact live wires (plus all inputs), dedup
        //    structurally identical gates, remap operand ids.
        let mut map: Vec<WireId> = vec![0; n];
        let mut wires: Vec<WireDef> = Vec::with_capacity(n);
        let mut seen: std::collections::HashMap<(u8, WireId, WireId), WireId> =
            std::collections::HashMap::new();
        for (i, def) in self.wires.iter().enumerate() {
            let is_input = matches!(def, WireDef::Input(_));
            if !live[i] && !is_input {
                continue;
            }
            let new_def = match *def {
                WireDef::Input(k) => WireDef::Input(k),
                WireDef::Xor(a, b) => {
                    let (a, b) = (map[a as usize], map[b as usize]);
                    WireDef::Xor(a.min(b), a.max(b))
                }
                WireDef::And(a, b) => {
                    let (a, b) = (map[a as usize], map[b as usize]);
                    WireDef::And(a.min(b), a.max(b))
                }
                WireDef::Not(a) => WireDef::Not(map[a as usize]),
            };
            let id = if is_input {
                let id = wires.len() as WireId;
                wires.push(new_def);
                id
            } else {
                let key = match new_def {
                    WireDef::Input(_) => unreachable!("inputs handled above"),
                    WireDef::Xor(a, b) => (1u8, a, b),
                    WireDef::And(a, b) => (2u8, a, b),
                    WireDef::Not(a) => (3u8, a, 0),
                };
                match seen.get(&key) {
                    Some(&e) => e,
                    None => {
                        let id = wires.len() as WireId;
                        wires.push(new_def);
                        seen.insert(key, id);
                        id
                    }
                }
            };
            map[i] = id;
        }
        let outputs = self.outputs.iter().map(|&o| map[o as usize]).collect();
        Circuit { wires, n_inputs: self.n_inputs, outputs }
    }

    /// Plain (insecure) evaluation — the correctness oracle for the
    /// garbling engine and for the Fig. 2 circuits.
    pub fn eval_plain(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.n_inputs as usize, "input arity mismatch");
        let mut vals: Vec<bool> = Vec::with_capacity(self.wires.len());
        for w in &self.wires {
            let v = match *w {
                WireDef::Input(k) => inputs[k as usize],
                WireDef::Xor(a, b) => vals[a as usize] ^ vals[b as usize],
                WireDef::And(a, b) => vals[a as usize] & vals[b as usize],
                WireDef::Not(a) => !vals[a as usize],
            };
            vals.push(v);
        }
        self.outputs.iter().map(|&o| vals[o as usize]).collect()
    }

    /// Validate topological ordering and input numbering; used in tests
    /// and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen_inputs = 0u32;
        for (i, w) in self.wires.iter().enumerate() {
            let check = |x: WireId| -> Result<(), String> {
                if x as usize >= i {
                    Err(format!("wire {i} references later wire {x}"))
                } else {
                    Ok(())
                }
            };
            match *w {
                WireDef::Input(k) => {
                    if k != seen_inputs {
                        return Err(format!("input {k} out of order at wire {i}"));
                    }
                    seen_inputs += 1;
                }
                WireDef::Xor(a, b) | WireDef::And(a, b) => {
                    check(a)?;
                    check(b)?;
                }
                WireDef::Not(a) => check(a)?,
            }
        }
        if seen_inputs != self.n_inputs {
            return Err(format!("n_inputs {} != declared {}", seen_inputs, self.n_inputs));
        }
        for &o in &self.outputs {
            if o as usize >= self.wires.len() {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_and_circuit() -> Circuit {
        // out0 = (a ^ b), out1 = (a & b), out2 = !a
        Circuit {
            wires: vec![
                WireDef::Input(0),
                WireDef::Input(1),
                WireDef::Xor(0, 1),
                WireDef::And(0, 1),
                WireDef::Not(0),
            ],
            n_inputs: 2,
            outputs: vec![2, 3, 4],
        }
    }

    #[test]
    fn plain_eval_truth_table() {
        let c = xor_and_circuit();
        assert!(c.validate().is_ok());
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = c.eval_plain(&[a, b]);
            assert_eq!(out, vec![a ^ b, a & b, !a]);
        }
    }

    #[test]
    fn gate_counts() {
        let c = xor_and_circuit();
        assert_eq!(c.n_and(), 1);
        assert_eq!(c.n_xor(), 1);
    }

    #[test]
    fn validate_rejects_forward_reference() {
        let c = Circuit {
            wires: vec![WireDef::Input(0), WireDef::Xor(0, 2), WireDef::Input(1)],
            n_inputs: 2,
            outputs: vec![1],
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_output() {
        let c = Circuit {
            wires: vec![WireDef::Input(0)],
            n_inputs: 1,
            outputs: vec![5],
        };
        assert!(c.validate().is_err());
    }

    #[test]
    #[should_panic]
    fn eval_wrong_arity_panics() {
        xor_and_circuit().eval_plain(&[true]);
    }

    #[test]
    fn optimize_drops_dead_wires_keeps_inputs() {
        // Dead: And(0,1) at 3 and the unused Input(2) must survive anyway.
        let c = Circuit {
            wires: vec![
                WireDef::Input(0),
                WireDef::Input(1),
                WireDef::Xor(0, 1),
                WireDef::And(0, 1),
                WireDef::Input(2),
                WireDef::Not(2),
            ],
            n_inputs: 3,
            outputs: vec![5],
        };
        let o = c.optimize();
        assert!(o.validate().is_ok());
        assert_eq!(o.n_inputs, 3);
        assert_eq!(o.n_and(), 0);
        assert_eq!(o.n_xor(), 0);
        assert_eq!(o.n_not(), 1);
        assert_eq!(o.n_gates(), 1);
        for bits in 0..8u32 {
            let inp: Vec<bool> = (0..3).map(|i| (bits >> i) & 1 == 1).collect();
            assert_eq!(c.eval_plain(&inp), o.eval_plain(&inp));
        }
    }

    #[test]
    fn optimize_dedups_commuted_gates() {
        let c = Circuit {
            wires: vec![
                WireDef::Input(0),
                WireDef::Input(1),
                WireDef::And(0, 1),
                WireDef::And(1, 0),
                WireDef::Xor(2, 3),
                WireDef::Xor(3, 2),
                WireDef::Xor(4, 5),
            ],
            n_inputs: 2,
            outputs: vec![2, 3, 6],
        };
        let o = c.optimize();
        assert!(o.validate().is_ok());
        assert_eq!(o.n_and(), 1, "commuted AND repeat must dedup");
        for bits in 0..4u32 {
            let inp: Vec<bool> = (0..2).map(|i| (bits >> i) & 1 == 1).collect();
            assert_eq!(c.eval_plain(&inp), o.eval_plain(&inp));
        }
    }

    #[test]
    fn optimize_is_idempotent() {
        let c = xor_and_circuit();
        let o1 = c.optimize();
        let o2 = o1.optimize();
        assert_eq!(o1.wires, o2.wires);
        assert_eq!(o1.outputs, o2.outputs);
    }
}

//! Circuit builder with constant folding and m-bit bus combinators.
//!
//! All arithmetic components use the 1-AND-per-bit constructions that the
//! free-XOR cost model rewards:
//!
//! * full adder: `s = a⊕b⊕c`, `c' = c ⊕ ((a⊕c)·(b⊕c))`
//! * full subtractor (borrow): `bw' = b ⊕ ((a⊕bw)·(b⊕bw))`
//! * 2:1 MUX: `out = b ⊕ (s·(a⊕b))`
//!
//! [`Bit`] carries compile-time constants so circuits that involve public
//! constants (the prime `p`, the threshold `p/2`, a constant-zero MUX arm)
//! shed AND gates automatically — this is where the baseline ReLU GC's
//! cost goes and where Circa's variants win.

use super::circuit::{Circuit, WireDef, WireId};

/// A bit during construction: either a public constant or a live wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bit {
    Const(bool),
    Wire(WireId),
}

/// A little-endian bus of bits.
pub type Bus = Vec<Bit>;

/// Incremental circuit builder.
#[derive(Default)]
pub struct Builder {
    circuit: Circuit,
}

impl Builder {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, def: WireDef) -> WireId {
        let id = self.circuit.wires.len() as WireId;
        self.circuit.wires.push(def);
        id
    }

    /// Allocate one input bit. Inputs must be allocated in order but may
    /// interleave with gates.
    pub fn input(&mut self) -> Bit {
        let k = self.circuit.n_inputs;
        self.circuit.n_inputs += 1;
        Bit::Wire(self.push(WireDef::Input(k)))
    }

    /// Allocate an m-bit little-endian input bus.
    pub fn input_bus(&mut self, m: usize) -> Bus {
        (0..m).map(|_| self.input()).collect()
    }

    /// A constant bus of width `m` from the low bits of `v`.
    pub fn const_bus(&self, v: u64, m: usize) -> Bus {
        (0..m).map(|i| Bit::Const((v >> i) & 1 == 1)).collect()
    }

    pub fn xor(&mut self, a: Bit, b: Bit) -> Bit {
        match (a, b) {
            (Bit::Const(x), Bit::Const(y)) => Bit::Const(x ^ y),
            (Bit::Const(false), w) | (w, Bit::Const(false)) => w,
            (Bit::Const(true), w) | (w, Bit::Const(true)) => self.not(w),
            (Bit::Wire(x), Bit::Wire(y)) => {
                if x == y {
                    Bit::Const(false)
                } else {
                    Bit::Wire(self.push(WireDef::Xor(x, y)))
                }
            }
        }
    }

    pub fn and(&mut self, a: Bit, b: Bit) -> Bit {
        match (a, b) {
            (Bit::Const(x), Bit::Const(y)) => Bit::Const(x & y),
            (Bit::Const(false), _) | (_, Bit::Const(false)) => Bit::Const(false),
            (Bit::Const(true), w) | (w, Bit::Const(true)) => w,
            (Bit::Wire(x), Bit::Wire(y)) => {
                if x == y {
                    Bit::Wire(x)
                } else {
                    Bit::Wire(self.push(WireDef::And(x, y)))
                }
            }
        }
    }

    pub fn not(&mut self, a: Bit) -> Bit {
        match a {
            Bit::Const(x) => Bit::Const(!x),
            Bit::Wire(w) => Bit::Wire(self.push(WireDef::Not(w))),
        }
    }

    pub fn or(&mut self, a: Bit, b: Bit) -> Bit {
        // a | b = ¬(¬a & ¬b); NOTs are free.
        let na = self.not(a);
        let nb = self.not(b);
        let n = self.and(na, nb);
        self.not(n)
    }

    /// 2:1 MUX: `s ? a : b` at one AND.
    pub fn mux(&mut self, s: Bit, a: Bit, b: Bit) -> Bit {
        let d = self.xor(a, b);
        let t = self.and(s, d);
        self.xor(t, b)
    }

    /// Bus MUX: `s ? a : b` element-wise.
    pub fn mux_bus(&mut self, s: Bit, a: &[Bit], b: &[Bit]) -> Bus {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.mux(s, x, y)).collect()
    }

    /// Ripple-carry addition; returns `(sum, carry_out)`.
    /// One AND per bit position (free-XOR full adder).
    pub fn add(&mut self, a: &[Bit], b: &[Bit]) -> (Bus, Bit) {
        assert_eq!(a.len(), b.len());
        let mut carry = Bit::Const(false);
        let mut out = Vec::with_capacity(a.len());
        for (&x, &y) in a.iter().zip(b) {
            let xc = self.xor(x, carry);
            let yc = self.xor(y, carry);
            let s = self.xor(xc, y);
            let t = self.and(xc, yc);
            carry = self.xor(carry, t);
            out.push(s);
        }
        (out, carry)
    }

    /// Ripple-borrow subtraction; returns `(diff, borrow_out)`.
    pub fn sub(&mut self, a: &[Bit], b: &[Bit]) -> (Bus, Bit) {
        assert_eq!(a.len(), b.len());
        let mut borrow = Bit::Const(false);
        let mut out = Vec::with_capacity(a.len());
        for (&x, &y) in a.iter().zip(b) {
            let xb = self.xor(x, borrow);
            let yb = self.xor(y, borrow);
            let d = self.xor(xb, y);
            let t = self.and(xb, yb);
            borrow = self.xor(y, t);
            out.push(d);
        }
        (out, borrow)
    }

    /// Unsigned `a >= b`: the complement of the subtraction borrow, at one
    /// AND per bit (no difference bits materialized).
    pub fn geq(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        let (_, borrow) = self.sub_borrow_only(a, b);
        self.not(borrow)
    }

    /// Unsigned `a > b` = ¬(b ≥ a).
    pub fn gt(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        let geq_ba = self.geq(b, a);
        self.not(geq_ba)
    }

    /// Unsigned `a <= b` = b ≥ a.
    pub fn leq(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        self.geq(b, a)
    }

    /// Borrow chain only (comparator core).
    fn sub_borrow_only(&mut self, a: &[Bit], b: &[Bit]) -> ((), Bit) {
        assert_eq!(a.len(), b.len());
        let mut borrow = Bit::Const(false);
        for (&x, &y) in a.iter().zip(b) {
            let xb = self.xor(x, borrow);
            let yb = self.xor(y, borrow);
            let t = self.and(xb, yb);
            borrow = self.xor(y, t);
        }
        ((), borrow)
    }

    /// Zero-extend a bus.
    pub fn zext(&self, a: &[Bit], m: usize) -> Bus {
        assert!(m >= a.len());
        let mut out = a.to_vec();
        out.resize(m, Bit::Const(false));
        out
    }

    /// Drop the `k` least-significant bits (the paper's `⌊·⌋_k`).
    pub fn truncate_low(&self, a: &[Bit], k: usize) -> Bus {
        a[k.min(a.len())..].to_vec()
    }

    /// Mark a bus as circuit output (constants are materialized through a
    /// NOT-NOT pair on a dummy anchor only if needed; in practice outputs
    /// are always live wires in our circuits).
    pub fn output_bus(&mut self, bus: &[Bit]) {
        for &b in bus {
            let w = self.materialize(b);
            self.circuit.outputs.push(w);
        }
    }

    pub fn output(&mut self, b: Bit) {
        let w = self.materialize(b);
        self.circuit.outputs.push(w);
    }

    /// Turn a Bit into a concrete wire id. Constant outputs need an anchor
    /// wire: we synthesize them from input 0 (x ⊕ x = 0) — valid because
    /// every real circuit here has at least one input.
    fn materialize(&mut self, b: Bit) -> WireId {
        match b {
            Bit::Wire(w) => w,
            Bit::Const(c) => {
                assert!(self.circuit.n_inputs > 0, "constant output in inputless circuit");
                // Find wire id of input 0: it is the first Input def.
                let w0 = self
                    .circuit
                    .wires
                    .iter()
                    .position(|w| matches!(w, WireDef::Input(0)))
                    .expect("input 0 exists") as WireId;
                let zero = self.push(WireDef::Xor(w0, w0));
                if c {
                    self.push(WireDef::Not(zero))
                } else {
                    zero
                }
            }
        }
    }

    /// Finish and return the circuit.
    pub fn build(self) -> Circuit {
        debug_assert!(self.circuit.validate().is_ok());
        self.circuit
    }
}

/// Decode a little-endian bool slice to u64.
pub fn bits_to_u64(bits: &[bool]) -> u64 {
    bits.iter().enumerate().fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
}

/// Encode the low `m` bits of `v` little-endian.
pub fn u64_to_bits(v: u64, m: usize) -> Vec<bool> {
    (0..m).map(|i| (v >> i) & 1 == 1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn eval2(c: &Circuit, a: u64, b: u64, m: usize) -> Vec<bool> {
        let mut inputs = u64_to_bits(a, m);
        inputs.extend(u64_to_bits(b, m));
        c.eval_plain(&inputs)
    }

    #[test]
    fn adder_exhaustive_4bit() {
        let mut bld = Builder::new();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let (sum, carry) = bld.add(&a, &b);
        bld.output_bus(&sum);
        bld.output(carry);
        let c = bld.build();
        assert_eq!(c.n_and(), 4);
        for x in 0..16u64 {
            for y in 0..16u64 {
                let out = eval2(&c, x, y, 4);
                let got = bits_to_u64(&out[..4]) | ((out[4] as u64) << 4);
                assert_eq!(got, x + y, "{x}+{y}");
            }
        }
    }

    #[test]
    fn subtractor_exhaustive_4bit() {
        let mut bld = Builder::new();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let (diff, borrow) = bld.sub(&a, &b);
        bld.output_bus(&diff);
        bld.output(borrow);
        let c = bld.build();
        for x in 0..16u64 {
            for y in 0..16u64 {
                let out = eval2(&c, x, y, 4);
                let got = bits_to_u64(&out[..4]);
                assert_eq!(got, x.wrapping_sub(y) & 0xF, "{x}-{y}");
                assert_eq!(out[4], x < y, "borrow {x}-{y}");
            }
        }
    }

    #[test]
    fn comparators_exhaustive_4bit() {
        let cases: [(&str, fn(&mut Builder, &[Bit], &[Bit]) -> Bit); 3] = [
            ("geq", Builder::geq),
            ("gt", Builder::gt),
            ("leq", Builder::leq),
        ];
        for (name, f) in cases {
            let mut bld = Builder::new();
            let a = bld.input_bus(4);
            let b = bld.input_bus(4);
            let r = f(&mut bld, &a, &b);
            bld.output(r);
            let c = bld.build();
            for x in 0..16u64 {
                for y in 0..16u64 {
                    let want = match name {
                        "geq" => x >= y,
                        "gt" => x > y,
                        _ => x <= y,
                    };
                    assert_eq!(eval2(&c, x, y, 4)[0], want, "{name} {x} {y}");
                }
            }
        }
    }

    #[test]
    fn comparator_cost_is_m_ands() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let b = bld.input_bus(31);
        let r = bld.leq(&a, &b);
        bld.output(r);
        assert_eq!(bld.build().n_and(), 31);
    }

    #[test]
    fn add_constant_costs_less() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let k = bld.const_bus(0x55aa55, 31);
        let (s, _) = bld.add(&a, &k);
        bld.output_bus(&s);
        let with_const = bld.build().n_and();
        assert!(with_const < 31, "constant folding failed: {with_const} ANDs");
    }

    #[test]
    fn mux_exhaustive() {
        let mut bld = Builder::new();
        let s = bld.input();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let o = bld.mux_bus(s, &a, &b);
        bld.output_bus(&o);
        let c = bld.build();
        assert_eq!(c.n_and(), 4);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let sv = rng.bool();
            let av = rng.below(16);
            let bv = rng.below(16);
            let mut inputs = vec![sv];
            inputs.extend(u64_to_bits(av, 4));
            inputs.extend(u64_to_bits(bv, 4));
            let out = c.eval_plain(&inputs);
            assert_eq!(bits_to_u64(&out), if sv { av } else { bv });
        }
    }

    #[test]
    fn mux_with_constant_zero_arm_is_cheaper() {
        // Baseline ReLU uses MUX(0, x): out = s ? x : 0 = s & x — still m
        // ANDs, but the XORs vanish. Verify semantic correctness.
        let mut bld = Builder::new();
        let s = bld.input();
        let x = bld.input_bus(8);
        let zero = bld.const_bus(0, 8);
        let o = bld.mux_bus(s, &x, &zero);
        bld.output_bus(&o);
        let c = bld.build();
        let mut inputs = vec![true];
        inputs.extend(u64_to_bits(0xA5, 8));
        assert_eq!(bits_to_u64(&c.eval_plain(&inputs)), 0xA5);
        let mut inputs = vec![false];
        inputs.extend(u64_to_bits(0xA5, 8));
        assert_eq!(bits_to_u64(&c.eval_plain(&inputs)), 0);
    }

    #[test]
    fn truncate_low_drops_bits() {
        let bld = Builder::new();
        let bus: Bus = (0..8).map(|i| Bit::Const(i % 2 == 0)).collect();
        let t = bld.truncate_low(&bus, 3);
        assert_eq!(t.len(), 5);
        assert_eq!(t[0], Bit::Const(false)); // original index 3
    }

    #[test]
    fn or_truth_table() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.or(a, b);
        bld.output(o);
        let c = bld.build();
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(c.eval_plain(&[x, y])[0], x | y);
        }
    }

    #[test]
    fn constant_output_materializes() {
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        bld.output(Bit::Const(true));
        bld.output(Bit::Const(false));
        let c = bld.build();
        assert_eq!(c.eval_plain(&[true]), vec![true, true, false]);
    }

    #[test]
    fn xor_self_folds_to_zero() {
        let mut bld = Builder::new();
        let a = bld.input();
        let z = bld.xor(a, a);
        assert_eq!(z, Bit::Const(false));
    }
}

//! Hash-consing circuit builder with constant folding and m-bit bus
//! combinators.
//!
//! All arithmetic components use the 1-AND-per-bit constructions that the
//! free-XOR cost model rewards:
//!
//! * full adder: `s = a⊕b⊕c`, `c' = c ⊕ ((a⊕c)·(b⊕c))`
//! * full subtractor (borrow): `bw' = b ⊕ ((a⊕bw)·(b⊕bw))`
//! * 2:1 MUX: `out = b ⊕ (s·(a⊕b))`
//!
//! [`Bit`] carries compile-time constants so circuits that involve public
//! constants (the prime `p`, the threshold `p/2`, a constant-zero MUX arm)
//! shed AND gates automatically — this is where the baseline ReLU GC's
//! cost goes and where Circa's variants win.
//!
//! # Common-subexpression elimination
//!
//! On top of constant folding the default builder hash-conses every gate:
//!
//! * every wire is normalized to a canonical `(base, parity)` pair, where
//!   `parity` records an odd number of NOTs over `base` — so `not` never
//!   duplicates a negation (`not(not(x))` folds back to `x` for free) and
//!   parity-aware folds fire where plain structural equality cannot:
//!   `and(x, ¬x) = 0`, `and(x, x) = x`, `xor(x, ¬x) = 1`;
//! * `xor`/`and` consult a structural cache keyed on the commutatively
//!   normalized operands (`min`, `max` of the canonical forms), so a
//!   repeated gate returns the existing wire instead of re-pushing — the
//!   ripple carry/borrow chains in [`Builder::add`]/[`Builder::sub`] and
//!   the per-bit MUX diffs share `x⊕c`-style subterms across positions;
//! * `xor` additionally cancels one shared leg: `(u⊕v)⊕u = v`, and
//!   `(u⊕v)⊕(u⊕t) = v⊕t` — this is what collapses the
//!   "subtract-then-MUX-the-difference" pattern in the Fig. 2 circuits,
//!   where `(z−p)_i ⊕ z_i` reduces to the borrow chain already built;
//! * `mux` folds a negated selector into an arm swap (`¬s ? a : b` =
//!   `s ? b : a`), so comparator outputs drive MUXes by their base wire
//!   and the intermediate NOT dies (reclaimed by [`Circuit::optimize`]).
//!
//! [`Builder::new_naive`] disables all of the above beyond the seed's
//! original constant folds; it exists so tests and benches can build the
//! pre-CSE reference circuit and prove `eval_plain` equivalence.
//!
//! [`Circuit::optimize`]: super::circuit::Circuit::optimize

use std::collections::HashMap;

use super::circuit::{Circuit, WireDef, WireId};

/// A bit during construction: either a public constant or a live wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bit {
    Const(bool),
    Wire(WireId),
}

/// A little-endian bus of bits.
pub type Bus = Vec<Bit>;

/// Incremental circuit builder.
pub struct Builder {
    circuit: Circuit,
    /// Hash-consing on (true, default) or seed-faithful naive mode (false).
    cse: bool,
    /// Canonical `(base wire, negation parity)` per wire id.
    norm: Vec<(WireId, bool)>,
    /// `(min base, max base)` → existing XOR wire.
    xor_cache: HashMap<(WireId, WireId), WireId>,
    /// Packed sorted `(base, parity)` operand pair → existing AND wire.
    and_cache: HashMap<(u64, u64), WireId>,
    /// base → its materialized NOT wire.
    not_cache: HashMap<WireId, WireId>,
    /// Wire id of input 0 (anchor for constant outputs).
    first_input: Option<WireId>,
    /// Cached constant-output anchors (`input0 ⊕ input0` and its NOT).
    const_zero: Option<WireId>,
    const_one: Option<WireId>,
}

impl Default for Builder {
    fn default() -> Self {
        Self::new()
    }
}

fn pack(base: WireId, parity: bool) -> u64 {
    ((base as u64) << 1) | parity as u64
}

impl Builder {
    /// Builder with hash-consing CSE enabled (the production default).
    pub fn new() -> Self {
        Self::with_cse(true)
    }

    /// Builder that replicates the seed's behavior exactly: constant
    /// folding and the `x⊕x`/`x·x` identities only, every other gate
    /// pushed verbatim. Reference point for equivalence and gate-count
    /// regression tests.
    pub fn new_naive() -> Self {
        Self::with_cse(false)
    }

    fn with_cse(cse: bool) -> Self {
        Self {
            circuit: Circuit::default(),
            cse,
            norm: Vec::new(),
            xor_cache: HashMap::new(),
            and_cache: HashMap::new(),
            not_cache: HashMap::new(),
            first_input: None,
            const_zero: None,
            const_one: None,
        }
    }

    fn push(&mut self, def: WireDef) -> WireId {
        let id = self.circuit.wires.len() as WireId;
        self.circuit.wires.push(def);
        // Maintain canonical forms even in naive mode (cheap, keeps the
        // invariant `norm.len() == wires.len()` unconditional).
        let n = match def {
            WireDef::Not(a) => {
                let (b, p) = self.norm[a as usize];
                (b, !p)
            }
            _ => (id, false),
        };
        self.norm.push(n);
        id
    }

    fn norm_of(&self, w: WireId) -> (WireId, bool) {
        self.norm[w as usize]
    }

    /// Wire carrying `base ⊕ parity`, materializing (and memoizing) a NOT
    /// wire only when the parity is set.
    fn wire_for(&mut self, base: WireId, parity: bool) -> WireId {
        if !parity {
            return base;
        }
        if let Some(&w) = self.not_cache.get(&base) {
            return w;
        }
        let w = self.push(WireDef::Not(base));
        self.not_cache.insert(base, w);
        w
    }

    /// Allocate one input bit. Inputs must be allocated in order but may
    /// interleave with gates.
    pub fn input(&mut self) -> Bit {
        let k = self.circuit.n_inputs;
        self.circuit.n_inputs += 1;
        let id = self.push(WireDef::Input(k));
        if self.first_input.is_none() {
            self.first_input = Some(id);
        }
        Bit::Wire(id)
    }

    /// Allocate an m-bit little-endian input bus.
    pub fn input_bus(&mut self, m: usize) -> Bus {
        (0..m).map(|_| self.input()).collect()
    }

    /// A constant bus of width `m` from the low bits of `v`.
    pub fn const_bus(&self, v: u64, m: usize) -> Bus {
        (0..m).map(|i| Bit::Const((v >> i) & 1 == 1)).collect()
    }

    pub fn xor(&mut self, a: Bit, b: Bit) -> Bit {
        match (a, b) {
            (Bit::Const(x), Bit::Const(y)) => Bit::Const(x ^ y),
            (Bit::Const(false), w) | (w, Bit::Const(false)) => w,
            (Bit::Const(true), w) | (w, Bit::Const(true)) => self.not(w),
            (Bit::Wire(x), Bit::Wire(y)) => {
                if !self.cse {
                    return if x == y {
                        Bit::Const(false)
                    } else {
                        Bit::Wire(self.push(WireDef::Xor(x, y)))
                    };
                }
                let (bx, px) = self.norm_of(x);
                let (by, py) = self.norm_of(y);
                let parity = px ^ py;
                if bx == by {
                    // x ⊕ x = 0, x ⊕ ¬x = 1.
                    return Bit::Const(parity);
                }
                match self.xor_bases(bx, by) {
                    Bit::Const(c) => Bit::Const(c ^ parity),
                    Bit::Wire(w) => {
                        let (bw, bp) = self.norm_of(w);
                        Bit::Wire(self.wire_for(bw, bp ^ parity))
                    }
                }
            }
        }
    }

    /// XOR of two distinct parity-free base wires: shared-leg cancellation
    /// first, then the structural cache.
    fn xor_bases(&mut self, bx: WireId, by: WireId) -> Bit {
        if let WireDef::Xor(u, v) = self.circuit.wires[bx as usize] {
            // (u ⊕ v) ⊕ u = v.
            if u == by {
                return Bit::Wire(v);
            }
            if v == by {
                return Bit::Wire(u);
            }
            if let WireDef::Xor(s, t) = self.circuit.wires[by as usize] {
                // (u ⊕ v) ⊕ (s ⊕ t) with one shared leg: recurse on the rest.
                if u == s {
                    return self.xor(Bit::Wire(v), Bit::Wire(t));
                }
                if u == t {
                    return self.xor(Bit::Wire(v), Bit::Wire(s));
                }
                if v == s {
                    return self.xor(Bit::Wire(u), Bit::Wire(t));
                }
                if v == t {
                    return self.xor(Bit::Wire(u), Bit::Wire(s));
                }
            }
        } else if let WireDef::Xor(s, t) = self.circuit.wires[by as usize] {
            if s == bx {
                return Bit::Wire(t);
            }
            if t == bx {
                return Bit::Wire(s);
            }
        }
        let key = if bx < by { (bx, by) } else { (by, bx) };
        if let Some(&w) = self.xor_cache.get(&key) {
            return Bit::Wire(w);
        }
        let w = self.push(WireDef::Xor(key.0, key.1));
        self.xor_cache.insert(key, w);
        Bit::Wire(w)
    }

    pub fn and(&mut self, a: Bit, b: Bit) -> Bit {
        match (a, b) {
            (Bit::Const(x), Bit::Const(y)) => Bit::Const(x & y),
            (Bit::Const(false), _) | (_, Bit::Const(false)) => Bit::Const(false),
            (Bit::Const(true), w) | (w, Bit::Const(true)) => w,
            (Bit::Wire(x), Bit::Wire(y)) => {
                if !self.cse {
                    return if x == y {
                        Bit::Wire(x)
                    } else {
                        Bit::Wire(self.push(WireDef::And(x, y)))
                    };
                }
                let (bx, px) = self.norm_of(x);
                let (by, py) = self.norm_of(y);
                if bx == by {
                    // x · x = x, x · ¬x = 0.
                    return if px == py {
                        Bit::Wire(self.wire_for(bx, px))
                    } else {
                        Bit::Const(false)
                    };
                }
                let (ka, kb) = (pack(bx, px), pack(by, py));
                let key = if ka < kb { (ka, kb) } else { (kb, ka) };
                if let Some(&w) = self.and_cache.get(&key) {
                    return Bit::Wire(w);
                }
                let wa = self.wire_for(bx, px);
                let wb = self.wire_for(by, py);
                let (lo, hi) = if wa < wb { (wa, wb) } else { (wb, wa) };
                let w = self.push(WireDef::And(lo, hi));
                self.and_cache.insert(key, w);
                Bit::Wire(w)
            }
        }
    }

    pub fn not(&mut self, a: Bit) -> Bit {
        match a {
            Bit::Const(x) => Bit::Const(!x),
            Bit::Wire(w) => {
                if !self.cse {
                    return Bit::Wire(self.push(WireDef::Not(w)));
                }
                let (b, p) = self.norm_of(w);
                Bit::Wire(self.wire_for(b, !p))
            }
        }
    }

    pub fn or(&mut self, a: Bit, b: Bit) -> Bit {
        // a | b = ¬(¬a & ¬b); NOTs are free.
        let na = self.not(a);
        let nb = self.not(b);
        let n = self.and(na, nb);
        self.not(n)
    }

    /// 2:1 MUX: `s ? a : b` at one AND.
    pub fn mux(&mut self, s: Bit, a: Bit, b: Bit) -> Bit {
        // ¬s ? a : b  ==  s ? b : a — folding the selector's negation into
        // an arm swap keeps the AND keyed on the base wire; the NOT it
        // replaces dies unless something else reads it.
        let (s, a, b) = match s {
            Bit::Wire(w) if self.cse => {
                let (bs, ps) = self.norm_of(w);
                if ps {
                    (Bit::Wire(bs), b, a)
                } else {
                    (s, a, b)
                }
            }
            _ => (s, a, b),
        };
        let d = self.xor(a, b);
        let t = self.and(s, d);
        self.xor(t, b)
    }

    /// Bus MUX: `s ? a : b` element-wise.
    pub fn mux_bus(&mut self, s: Bit, a: &[Bit], b: &[Bit]) -> Bus {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.mux(s, x, y)).collect()
    }

    /// Ripple-carry addition; returns `(sum, carry_out)`.
    /// One AND per bit position (free-XOR full adder).
    pub fn add(&mut self, a: &[Bit], b: &[Bit]) -> (Bus, Bit) {
        assert_eq!(a.len(), b.len());
        let mut carry = Bit::Const(false);
        let mut out = Vec::with_capacity(a.len());
        for (&x, &y) in a.iter().zip(b) {
            let xc = self.xor(x, carry);
            let yc = self.xor(y, carry);
            let s = self.xor(xc, y);
            let t = self.and(xc, yc);
            carry = self.xor(carry, t);
            out.push(s);
        }
        (out, carry)
    }

    /// Ripple-borrow subtraction; returns `(diff, borrow_out)`.
    pub fn sub(&mut self, a: &[Bit], b: &[Bit]) -> (Bus, Bit) {
        assert_eq!(a.len(), b.len());
        let mut borrow = Bit::Const(false);
        let mut out = Vec::with_capacity(a.len());
        for (&x, &y) in a.iter().zip(b) {
            let xb = self.xor(x, borrow);
            let yb = self.xor(y, borrow);
            let d = self.xor(xb, y);
            let t = self.and(xb, yb);
            borrow = self.xor(y, t);
            out.push(d);
        }
        (out, borrow)
    }

    /// Unsigned `a >= b`: the complement of the subtraction borrow, at one
    /// AND per bit (no difference bits materialized).
    pub fn geq(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        let (_, borrow) = self.sub_borrow_only(a, b);
        self.not(borrow)
    }

    /// Unsigned `a > b` = ¬(b ≥ a).
    pub fn gt(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        let geq_ba = self.geq(b, a);
        self.not(geq_ba)
    }

    /// Unsigned `a <= b` = b ≥ a.
    pub fn leq(&mut self, a: &[Bit], b: &[Bit]) -> Bit {
        self.geq(b, a)
    }

    /// Borrow chain only (comparator core).
    fn sub_borrow_only(&mut self, a: &[Bit], b: &[Bit]) -> ((), Bit) {
        assert_eq!(a.len(), b.len());
        let mut borrow = Bit::Const(false);
        for (&x, &y) in a.iter().zip(b) {
            let xb = self.xor(x, borrow);
            let yb = self.xor(y, borrow);
            let t = self.and(xb, yb);
            borrow = self.xor(y, t);
        }
        ((), borrow)
    }

    /// Zero-extend a bus.
    pub fn zext(&self, a: &[Bit], m: usize) -> Bus {
        assert!(m >= a.len());
        let mut out = a.to_vec();
        out.resize(m, Bit::Const(false));
        out
    }

    /// Drop the `k` least-significant bits (the paper's `⌊·⌋_k`).
    pub fn truncate_low(&self, a: &[Bit], k: usize) -> Bus {
        a[k.min(a.len())..].to_vec()
    }

    /// Mark a bus as circuit output (constants are materialized through a
    /// NOT-NOT pair on a dummy anchor only if needed; in practice outputs
    /// are always live wires in our circuits).
    pub fn output_bus(&mut self, bus: &[Bit]) {
        for &b in bus {
            let w = self.materialize(b);
            self.circuit.outputs.push(w);
        }
    }

    pub fn output(&mut self, b: Bit) {
        let w = self.materialize(b);
        self.circuit.outputs.push(w);
    }

    /// Turn a Bit into a concrete wire id. Constant outputs need an anchor
    /// wire: we synthesize them from input 0 (x ⊕ x = 0) — valid because
    /// every real circuit here has at least one input. The anchor and both
    /// constant wires are cached on first use, so repeated constant
    /// outputs share wires instead of re-scanning and re-pushing.
    fn materialize(&mut self, b: Bit) -> WireId {
        match b {
            Bit::Wire(w) => w,
            Bit::Const(c) => {
                let zero = match self.const_zero {
                    Some(z) => z,
                    None => {
                        assert!(
                            self.circuit.n_inputs > 0,
                            "constant output in inputless circuit"
                        );
                        let w0 = self.first_input.expect("input 0 exists");
                        let z = self.push(WireDef::Xor(w0, w0));
                        self.const_zero = Some(z);
                        z
                    }
                };
                if c {
                    match self.const_one {
                        Some(o) => o,
                        None => {
                            let o = self.push(WireDef::Not(zero));
                            self.const_one = Some(o);
                            o
                        }
                    }
                } else {
                    zero
                }
            }
        }
    }

    /// Finish and return the circuit.
    pub fn build(self) -> Circuit {
        debug_assert!(self.circuit.validate().is_ok());
        self.circuit
    }
}

/// Decode a little-endian bool slice to u64.
pub fn bits_to_u64(bits: &[bool]) -> u64 {
    bits.iter().enumerate().fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
}

/// Encode the low `m` bits of `v` little-endian.
pub fn u64_to_bits(v: u64, m: usize) -> Vec<bool> {
    (0..m).map(|i| (v >> i) & 1 == 1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn eval2(c: &Circuit, a: u64, b: u64, m: usize) -> Vec<bool> {
        let mut inputs = u64_to_bits(a, m);
        inputs.extend(u64_to_bits(b, m));
        c.eval_plain(&inputs)
    }

    #[test]
    fn adder_exhaustive_4bit() {
        let mut bld = Builder::new();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let (sum, carry) = bld.add(&a, &b);
        bld.output_bus(&sum);
        bld.output(carry);
        let c = bld.build();
        assert_eq!(c.n_and(), 4);
        for x in 0..16u64 {
            for y in 0..16u64 {
                let out = eval2(&c, x, y, 4);
                let got = bits_to_u64(&out[..4]) | ((out[4] as u64) << 4);
                assert_eq!(got, x + y, "{x}+{y}");
            }
        }
    }

    #[test]
    fn subtractor_exhaustive_4bit() {
        let mut bld = Builder::new();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let (diff, borrow) = bld.sub(&a, &b);
        bld.output_bus(&diff);
        bld.output(borrow);
        let c = bld.build();
        for x in 0..16u64 {
            for y in 0..16u64 {
                let out = eval2(&c, x, y, 4);
                let got = bits_to_u64(&out[..4]);
                assert_eq!(got, x.wrapping_sub(y) & 0xF, "{x}-{y}");
                assert_eq!(out[4], x < y, "borrow {x}-{y}");
            }
        }
    }

    #[test]
    fn comparators_exhaustive_4bit() {
        let cases: [(&str, fn(&mut Builder, &[Bit], &[Bit]) -> Bit); 3] = [
            ("geq", Builder::geq),
            ("gt", Builder::gt),
            ("leq", Builder::leq),
        ];
        for (name, f) in cases {
            let mut bld = Builder::new();
            let a = bld.input_bus(4);
            let b = bld.input_bus(4);
            let r = f(&mut bld, &a, &b);
            bld.output(r);
            let c = bld.build();
            for x in 0..16u64 {
                for y in 0..16u64 {
                    let want = match name {
                        "geq" => x >= y,
                        "gt" => x > y,
                        _ => x <= y,
                    };
                    assert_eq!(eval2(&c, x, y, 4)[0], want, "{name} {x} {y}");
                }
            }
        }
    }

    #[test]
    fn comparator_cost_is_m_ands() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let b = bld.input_bus(31);
        let r = bld.leq(&a, &b);
        bld.output(r);
        assert_eq!(bld.build().n_and(), 31);
    }

    #[test]
    fn add_constant_costs_less() {
        let mut bld = Builder::new();
        let a = bld.input_bus(31);
        let k = bld.const_bus(0x55aa55, 31);
        let (s, _) = bld.add(&a, &k);
        bld.output_bus(&s);
        let with_const = bld.build().n_and();
        assert!(with_const < 31, "constant folding failed: {with_const} ANDs");
    }

    #[test]
    fn mux_exhaustive() {
        let mut bld = Builder::new();
        let s = bld.input();
        let a = bld.input_bus(4);
        let b = bld.input_bus(4);
        let o = bld.mux_bus(s, &a, &b);
        bld.output_bus(&o);
        let c = bld.build();
        assert_eq!(c.n_and(), 4);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let sv = rng.bool();
            let av = rng.below(16);
            let bv = rng.below(16);
            let mut inputs = vec![sv];
            inputs.extend(u64_to_bits(av, 4));
            inputs.extend(u64_to_bits(bv, 4));
            let out = c.eval_plain(&inputs);
            assert_eq!(bits_to_u64(&out), if sv { av } else { bv });
        }
    }

    #[test]
    fn mux_with_constant_zero_arm_is_cheaper() {
        // Baseline ReLU uses MUX(0, x): out = s ? x : 0 = s & x — still m
        // ANDs, but the XORs vanish. Verify semantic correctness.
        let mut bld = Builder::new();
        let s = bld.input();
        let x = bld.input_bus(8);
        let zero = bld.const_bus(0, 8);
        let o = bld.mux_bus(s, &x, &zero);
        bld.output_bus(&o);
        let c = bld.build();
        let mut inputs = vec![true];
        inputs.extend(u64_to_bits(0xA5, 8));
        assert_eq!(bits_to_u64(&c.eval_plain(&inputs)), 0xA5);
        let mut inputs = vec![false];
        inputs.extend(u64_to_bits(0xA5, 8));
        assert_eq!(bits_to_u64(&c.eval_plain(&inputs)), 0);
    }

    #[test]
    fn truncate_low_drops_bits() {
        let bld = Builder::new();
        let bus: Bus = (0..8).map(|i| Bit::Const(i % 2 == 0)).collect();
        let t = bld.truncate_low(&bus, 3);
        assert_eq!(t.len(), 5);
        assert_eq!(t[0], Bit::Const(false)); // original index 3
    }

    #[test]
    fn or_truth_table() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let o = bld.or(a, b);
        bld.output(o);
        let c = bld.build();
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(c.eval_plain(&[x, y])[0], x | y);
        }
    }

    #[test]
    fn constant_output_materializes() {
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        bld.output(Bit::Const(true));
        bld.output(Bit::Const(false));
        let c = bld.build();
        assert_eq!(c.eval_plain(&[true]), vec![true, true, false]);
    }

    #[test]
    fn repeated_constant_outputs_share_anchor_wires() {
        let mut bld = Builder::new();
        let a = bld.input();
        bld.output(a);
        for _ in 0..8 {
            bld.output(Bit::Const(true));
            bld.output(Bit::Const(false));
        }
        let c = bld.build();
        // 1 input + 1 zero anchor + 1 NOT — not one anchor per constant.
        assert_eq!(c.wires.len(), 3);
        let mut want = vec![false];
        for _ in 0..8 {
            want.push(true);
            want.push(false);
        }
        assert_eq!(c.eval_plain(&[false]), want);
    }

    #[test]
    fn xor_self_folds_to_zero() {
        let mut bld = Builder::new();
        let a = bld.input();
        let z = bld.xor(a, a);
        assert_eq!(z, Bit::Const(false));
    }

    #[test]
    fn repeated_gates_are_hash_consed() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let x1 = bld.xor(a, b);
        let x2 = bld.xor(b, a); // commuted repeat
        assert_eq!(x1, x2);
        let t1 = bld.and(a, b);
        let t2 = bld.and(b, a);
        assert_eq!(t1, t2);
        bld.output(x1);
        bld.output(t1);
        let c = bld.build();
        assert_eq!(c.n_xor(), 1);
        assert_eq!(c.n_and(), 1);
    }

    #[test]
    fn double_negation_folds() {
        let mut bld = Builder::new();
        let a = bld.input();
        let n = bld.not(a);
        let nn = bld.not(n);
        assert_eq!(nn, a);
        // Repeated NOT of the same wire is also memoized.
        let n2 = bld.not(a);
        assert_eq!(n, n2);
    }

    #[test]
    fn parity_aware_folds() {
        let mut bld = Builder::new();
        let a = bld.input();
        let na = bld.not(a);
        assert_eq!(bld.and(a, na), Bit::Const(false));
        assert_eq!(bld.xor(a, na), Bit::Const(true));
        assert_eq!(bld.and(na, na), na);
    }

    #[test]
    fn xor_shared_leg_cancels() {
        let mut bld = Builder::new();
        let a = bld.input();
        let b = bld.input();
        let t = bld.input();
        let ab = bld.xor(a, b);
        // (a⊕b)⊕b = a, (a⊕b)⊕a = b: no new gate.
        assert_eq!(bld.xor(ab, b), a);
        assert_eq!(bld.xor(ab, a), b);
        // (a⊕b)⊕(a⊕t) = b⊕t.
        let at = bld.xor(a, t);
        let bt = bld.xor(b, t);
        assert_eq!(bld.xor(ab, at), bt);
    }

    #[test]
    fn negated_selector_mux_swaps_arms() {
        let mut bld = Builder::new();
        let s = bld.input();
        let a = bld.input();
        let b = bld.input();
        let ns = bld.not(s);
        let o = bld.mux(ns, a, b);
        bld.output(o);
        let c = bld.build().optimize();
        // The NOT was folded into an arm swap and then reclaimed.
        assert_eq!(c.n_and(), 1);
        assert!(!c.wires.iter().any(|w| matches!(w, WireDef::Not(_))));
        for s in [false, true] {
            for a in [false, true] {
                for b in [false, true] {
                    let out = c.eval_plain(&[s, a, b]);
                    assert_eq!(out[0], if !s { a } else { b }, "{s} {a} {b}");
                }
            }
        }
    }

    #[test]
    fn naive_builder_skips_cse() {
        let mut bld = Builder::new_naive();
        let a = bld.input();
        let b = bld.input();
        let x1 = bld.xor(a, b);
        let x2 = bld.xor(a, b);
        assert_ne!(x1, x2);
        bld.output(x1);
        bld.output(x2);
        let c = bld.build();
        assert_eq!(c.n_xor(), 2);
    }
}

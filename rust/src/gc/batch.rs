//! Layer-level SoA batches of garbled material.
//!
//! Circa's costs scale with the ReLU count (CryptoNAS makes ReLUs *the*
//! scaling axis), and every ReLU in a layer garbles the **same** circuit
//! template with fresh labels. Storing the layer's offline material as a
//! forest of per-ReLU heap objects (`Vec<GarbledCircuit>`,
//! `Vec<InputEncoding>`, `Vec<Vec<Label>>`) therefore pays O(#ReLU)
//! allocations and pointer chasing for material that is structurally one
//! buffer. This module flattens it:
//!
//! * [`LayerGcBatch`] — one shared `Arc<Circuit>` template plus one contiguous
//!   ciphertext buffer (`n × and_stride` table entries) and one
//!   contiguous decode-bit buffer, with strided per-ReLU views;
//! * [`LayerEncodingBatch`] — one contiguous `label0` arena
//!   (`n × n_inputs` labels, the label0 of input `j` of ReLU `i` at
//!   `i · stride + j`) plus one free-XOR delta per ReLU.
//!
//! Garbling and evaluation walk the shared circuit once per ReLU with an
//! outer stride loop, reusing one wire-label scratch buffer across the
//! whole layer — allocations drop from O(#ReLU) to O(#layer), and byte
//! accounting falls out of `buffer.len()`.

use std::sync::Arc;

use super::circuit::Circuit;
use super::eval;
use super::garble::{self, EncodingView};
use crate::prf::{Delta, Label};
use crate::util::error::{Error, Result};
use crate::util::Rng;

/// Instances per RNG fork in [`LayerGcBatch::garble_chunked`]. Fixed (not
/// derived from the thread count) so the garbled output is a function of
/// the seed alone — chunk `c` always draws from fork `c`, whether one
/// thread processes every chunk or eight threads split them.
pub const GARBLE_CHUNK: usize = 128;

/// One layer's garbled tables: a single [`Circuit`] template and one
/// contiguous table/decode buffer with fixed per-ReLU strides.
pub struct LayerGcBatch {
    /// The shared circuit template (one per layer, not per ReLU) —
    /// typically the process-wide memoized `Arc` from
    /// `circuits::template`, so batches across layers/sessions share one
    /// allocation instead of cloning the circuit per batch.
    pub circuit: Arc<Circuit>,
    /// AND gates per instance — the table stride.
    and_stride: usize,
    /// Output bits per instance — the decode stride.
    out_stride: usize,
    /// `n × and_stride` ciphertext pairs, ReLU-major.
    tables: Vec<[Label; 2]>,
    /// `n × out_stride` point-and-permute decode bits, ReLU-major.
    output_decode: Vec<bool>,
    /// Number of garbled instances.
    n: usize,
}

impl LayerGcBatch {
    /// An empty batch for `n` ReLUs of `circuit` (filled by
    /// [`LayerGcBatch::garble_next`]).
    pub fn new(circuit: Arc<Circuit>, n: usize) -> Self {
        let and_stride = circuit.n_and();
        let out_stride = circuit.outputs.len();
        Self {
            circuit,
            and_stride,
            out_stride,
            tables: Vec::with_capacity(n * and_stride),
            output_decode: Vec::with_capacity(n * out_stride),
            n: 0,
        }
    }

    /// Garble the next instance into this batch (and its input encoding
    /// into `enc`), reusing `scratch` for the wire labels. RNG draw order
    /// matches the standalone [`garble::garble_with_scratch`] exactly.
    pub fn garble_next(
        &mut self,
        enc: &mut LayerEncodingBatch,
        rng: &mut Rng,
        scratch: &mut Vec<Label>,
    ) {
        let delta = garble::garble_append(
            &self.circuit,
            rng,
            scratch,
            &mut self.tables,
            &mut enc.label0,
            &mut self.output_decode,
        );
        enc.deltas.push(delta);
        self.n += 1;
    }

    /// Garble `count` instances with the stride loop chunked across up to
    /// `n_threads` dealer threads. Each [`GARBLE_CHUNK`]-instance chunk
    /// garbles from its own [`Rng::fork`] into a disjoint range of the
    /// layer buffers, so the output is bit-identical for every thread
    /// count (including 1) under the same parent RNG state.
    pub fn garble_chunked(
        &mut self,
        enc: &mut LayerEncodingBatch,
        count: usize,
        rng: &mut Rng,
        n_threads: usize,
    ) {
        if count == 0 {
            return;
        }
        assert_eq!(enc.len(), self.n, "batch/encoding arity");
        let base = self.n;
        let and_stride = self.and_stride;
        let out_stride = self.out_stride;
        let in_stride = enc.stride;
        self.tables.resize((base + count) * and_stride, [Label::ZERO; 2]);
        self.output_decode.resize((base + count) * out_stride, false);
        enc.label0.resize((base + count) * in_stride, Label::ZERO);
        enc.deltas.resize(base + count, Delta(Label::ZERO));

        // Forks are drawn sequentially from the parent up front: the
        // stream assigned to chunk `c` never depends on scheduling.
        let n_chunks = count.div_ceil(GARBLE_CHUNK);
        let mut forks: Vec<Rng> = (0..n_chunks).map(|c| rng.fork(c as u64)).collect();
        let n_groups = n_threads.max(1).min(n_chunks);
        let chunks_per_group = n_chunks.div_ceil(n_groups);

        let circuit: &Circuit = &self.circuit;
        let mut tables = &mut self.tables[base * and_stride..];
        let mut decode = &mut self.output_decode[base * out_stride..];
        let mut label0 = &mut enc.label0[base * in_stride..];
        let mut deltas = &mut enc.deltas[base..];
        std::thread::scope(|scope| {
            let mut chunk0 = 0usize;
            while chunk0 < n_chunks {
                let g_chunks = chunks_per_group.min(n_chunks - chunk0);
                let lo = chunk0 * GARBLE_CHUNK;
                let hi = ((chunk0 + g_chunks) * GARBLE_CHUNK).min(count);
                let m = hi - lo;
                let g_forks: Vec<Rng> = forks.drain(..g_chunks).collect();
                let (t, rest) = std::mem::take(&mut tables).split_at_mut(m * and_stride);
                tables = rest;
                let (dc, rest) = std::mem::take(&mut decode).split_at_mut(m * out_stride);
                decode = rest;
                let (l0, rest) = std::mem::take(&mut label0).split_at_mut(m * in_stride);
                label0 = rest;
                let (dl, rest) = std::mem::take(&mut deltas).split_at_mut(m);
                deltas = rest;
                scope.spawn(move || {
                    let mut scratch: Vec<Label> = Vec::new();
                    let mut off = 0usize;
                    for mut frng in g_forks {
                        let c_count = GARBLE_CHUNK.min(m - off);
                        for i in off..off + c_count {
                            dl[i] = garble::garble_into(
                                circuit,
                                &mut frng,
                                &mut scratch,
                                &mut t[i * and_stride..(i + 1) * and_stride],
                                &mut l0[i * in_stride..(i + 1) * in_stride],
                                &mut dc[i * out_stride..(i + 1) * out_stride],
                            );
                        }
                        off += c_count;
                    }
                });
                chunk0 += g_chunks;
            }
        });
        self.n += count;
    }

    /// Rebuild a batch from its raw wire parts, validating every
    /// structural invariant (untrusted input — returns `Err`, never
    /// panics).
    pub fn from_parts(
        circuit: Arc<Circuit>,
        n: usize,
        tables: Vec<[Label; 2]>,
        output_decode: Vec<bool>,
    ) -> Result<Self> {
        circuit.validate().map_err(Error::msg)?;
        let and_stride = circuit.n_and();
        let out_stride = circuit.outputs.len();
        // `n` is untrusted: checked multiplies so absurd counts fail the
        // comparison instead of overflowing.
        let want_tables = n.checked_mul(and_stride).unwrap_or(usize::MAX);
        let want_decode = n.checked_mul(out_stride).unwrap_or(usize::MAX);
        crate::ensure!(
            tables.len() == want_tables,
            "table buffer {} != {n} x stride {and_stride}",
            tables.len()
        );
        crate::ensure!(
            output_decode.len() == want_decode,
            "decode buffer {} != {n} x stride {out_stride}",
            output_decode.len()
        );
        Ok(Self { circuit, and_stride, out_stride, tables, output_decode, n })
    }

    /// The whole layer's garbled tables (ReLU-major, stride
    /// [`LayerGcBatch::and_stride`]).
    pub fn tables(&self) -> &[[Label; 2]] {
        &self.tables
    }

    /// Number of garbled instances in the batch.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// This instance's stride of the contiguous table buffer.
    pub fn table_of(&self, i: usize) -> &[[Label; 2]] {
        &self.tables[i * self.and_stride..(i + 1) * self.and_stride]
    }

    /// This instance's stride of the contiguous decode-bit buffer.
    pub fn decode_of(&self, i: usize) -> &[bool] {
        &self.output_decode[i * self.out_stride..(i + 1) * self.out_stride]
    }

    /// The whole layer's decode bits (ReLU-major, stride
    /// [`LayerGcBatch::out_stride`]).
    pub fn output_decode(&self) -> &[bool] {
        &self.output_decode
    }

    pub fn and_stride(&self) -> usize {
        self.and_stride
    }

    pub fn out_stride(&self) -> usize {
        self.out_stride
    }

    /// Garbled-table bytes of the whole layer — the paper's storage
    /// metric, read straight off the buffer length.
    pub fn table_bytes(&self) -> usize {
        self.tables.len() * 32
    }

    /// Evaluate every instance against flat per-ReLU-major label arenas
    /// (client block then server block per instance) and append one
    /// output color per output bit to `colors`. One scratch + one input
    /// buffer serve the whole layer.
    pub fn eval_layer_colors(
        &self,
        client_labels: &[Label],
        server_labels: &[Label],
        colors: &mut Vec<bool>,
    ) {
        let n = self.n;
        if n == 0 {
            // Degenerate empty layer: nothing to evaluate (and no strides
            // to derive).
            assert!(client_labels.is_empty() && server_labels.is_empty(), "labels w/o batch");
            return;
        }
        assert_eq!(client_labels.len() % n, 0, "client label arena stride");
        assert_eq!(server_labels.len() % n, 0, "server label arena stride");
        let c_stride = client_labels.len() / n;
        let s_stride = server_labels.len() / n;
        assert_eq!(c_stride + s_stride, self.circuit.n_inputs as usize, "input arity");

        colors.reserve(n * self.out_stride);
        let mut inputs: Vec<Label> = Vec::with_capacity(c_stride + s_stride);
        let mut scratch: Vec<Label> = Vec::new();
        let mut out: Vec<Label> = Vec::with_capacity(self.out_stride);
        for i in 0..n {
            inputs.clear();
            inputs.extend_from_slice(&client_labels[i * c_stride..(i + 1) * c_stride]);
            inputs.extend_from_slice(&server_labels[i * s_stride..(i + 1) * s_stride]);
            out.clear();
            eval::evaluate_append(&self.circuit, self.table_of(i), &inputs, &mut scratch, &mut out);
            colors.extend(out.iter().map(|l| l.color()));
        }
    }
}

/// One request's evaluator-side material for a cross-request layer walk:
/// its garbled batch plus its two flat label arenas (client block first,
/// server block second — the protocol layout of
/// [`LayerGcBatch::eval_layer_colors`]).
#[derive(Clone, Copy)]
pub struct LayerEvalSource<'a> {
    pub gc: &'a LayerGcBatch,
    pub client_labels: &'a [Label],
    pub server_labels: &'a [Label],
}

/// Evaluate one ReLU layer across `R` concurrent requests' material in a
/// single strided walk. `colors[r]` is overwritten with request `r`'s
/// color stream, bit-identical to what
/// [`LayerGcBatch::eval_layer_colors`] would produce for that request
/// alone.
///
/// Every request must hold the same circuit template (same model, same
/// layer — the coordinator's model-homogeneous batches guarantee it;
/// strides and arity are asserted, deep template equality is
/// debug-asserted). The flattened `(instance, request)` axis is walked
/// instance-major in groups of [`eval::GROUP_WIDTH`], so
/// [`GarbleHash::hash_many`](crate::prf::GarbleHash::hash_many) flights
/// fill with the same gate position *across requests* — the online
/// mirror of [`LayerGcBatch::garble_chunked`]'s offline fan-out.
/// `scratch` is the wire-label buffer, reused across groups and layers.
pub fn eval_layer_colors_multi(
    reqs: &[LayerEvalSource<'_>],
    colors: &mut [Vec<bool>],
    scratch: &mut Vec<Label>,
) {
    let r_count = reqs.len();
    assert!(r_count > 0, "empty request group");
    assert_eq!(colors.len(), r_count, "one color stream per request");
    let tmpl = reqs[0].gc;
    let n = tmpl.n;
    let m = tmpl.out_stride;
    for (req, out) in reqs.iter().zip(colors.iter_mut()) {
        assert_eq!(req.gc.n, n, "request arity");
        assert_eq!(req.gc.and_stride, tmpl.and_stride, "shared template");
        assert_eq!(req.gc.out_stride, m, "shared template");
        // Memoized templates make this a pointer compare in the common
        // case; the structural checks remain for batches built elsewhere.
        if !Arc::ptr_eq(&req.gc.circuit, &tmpl.circuit) {
            assert_eq!(req.gc.circuit.n_inputs, tmpl.circuit.n_inputs, "shared template");
            assert_eq!(req.gc.circuit.wires.len(), tmpl.circuit.wires.len(), "shared template");
            debug_assert!(req.gc.circuit.wires == tmpl.circuit.wires, "shared template");
        }
        if n == 0 {
            assert!(
                req.client_labels.is_empty() && req.server_labels.is_empty(),
                "labels w/o batch"
            );
        } else {
            assert_eq!(req.client_labels.len() % n, 0, "client label arena stride");
            assert_eq!(req.server_labels.len() % n, 0, "server label arena stride");
            assert_eq!(
                req.client_labels.len(),
                reqs[0].client_labels.len(),
                "one input split per template"
            );
            assert_eq!(
                (req.client_labels.len() + req.server_labels.len()) / n,
                tmpl.circuit.n_inputs as usize,
                "input arity"
            );
        }
        out.clear();
        out.resize(n * m, false);
    }
    if n == 0 {
        return;
    }
    let c_stride = reqs[0].client_labels.len() / n;
    let s_stride = reqs[0].server_labels.len() / n;

    // Flattened (instance, request) axis, instance-major: consecutive
    // flight slots hold the same gate of *different* requests.
    let total = n * r_count;
    let mut insts: Vec<eval::GroupInstance<'_>> = Vec::with_capacity(eval::GROUP_WIDTH);
    let mut group_colors: Vec<bool> = Vec::with_capacity(eval::GROUP_WIDTH * m);
    let mut f0 = 0usize;
    while f0 < total {
        let g = eval::GROUP_WIDTH.min(total - f0);
        insts.clear();
        for f in f0..f0 + g {
            let (i, r) = (f / r_count, f % r_count);
            let req = &reqs[r];
            insts.push(eval::GroupInstance {
                table: req.gc.table_of(i),
                client: &req.client_labels[i * c_stride..(i + 1) * c_stride],
                server: &req.server_labels[i * s_stride..(i + 1) * s_stride],
            });
        }
        group_colors.clear();
        eval::evaluate_group_colors(&tmpl.circuit, &insts, scratch, &mut group_colors);
        for (j, f) in (f0..f0 + g).enumerate() {
            let (i, r) = (f / r_count, f % r_count);
            colors[r][i * m..(i + 1) * m].copy_from_slice(&group_colors[j * m..(j + 1) * m]);
        }
        f0 += g;
    }
}

/// One layer's input encodings: a contiguous `label0` arena with stride =
/// circuit inputs, plus one free-XOR delta per ReLU (labels must stay
/// single-use across inferences — paper footnote 2 — so deltas are per
/// instance, never per layer).
pub struct LayerEncodingBatch {
    /// Labels per instance (the arena stride).
    stride: usize,
    /// `n × stride` zero-labels, ReLU-major.
    label0: Vec<Label>,
    /// One delta per instance.
    deltas: Vec<Delta>,
}

impl LayerEncodingBatch {
    /// An empty arena for `n` instances of `stride` inputs each.
    pub fn new(stride: usize, n: usize) -> Self {
        Self { stride, label0: Vec::with_capacity(n * stride), deltas: Vec::with_capacity(n) }
    }

    /// Rebuild an arena from its raw wire parts, validating arity and the
    /// free-XOR color invariant (untrusted input — returns `Err`, never
    /// panics).
    pub fn from_parts(stride: usize, label0: Vec<Label>, deltas: Vec<Delta>) -> Result<Self> {
        let want = deltas.len().checked_mul(stride).unwrap_or(usize::MAX);
        crate::ensure!(
            label0.len() == want,
            "label arena {} != {} x stride {stride}",
            label0.len(),
            deltas.len()
        );
        // Every delta must carry the point-and-permute color bit; a
        // cleared bit would silently break evaluation downstream.
        crate::ensure!(deltas.iter().all(|d| d.0.color()), "delta missing color bit");
        Ok(Self { stride, label0, deltas })
    }

    /// The whole layer's zero-labels (ReLU-major, stride
    /// [`LayerEncodingBatch::stride`]).
    pub fn label0(&self) -> &[Label] {
        &self.label0
    }

    /// One free-XOR delta per instance.
    pub fn deltas(&self) -> &[Delta] {
        &self.deltas
    }

    /// Number of encoded instances.
    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Borrowed view of instance `i`'s encoding (same shape a standalone
    /// [`garble::InputEncoding`] exposes).
    pub fn view(&self, i: usize) -> EncodingView<'_> {
        EncodingView {
            label0: &self.label0[i * self.stride..(i + 1) * self.stride],
            delta: self.deltas[i],
        }
    }

    /// Label bytes held by the arena (16 B per label).
    pub fn label_bytes(&self) -> usize {
        self.label0.len() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::{u64_to_bits, Builder};
    use crate::gc::garble::garble_with_scratch;

    fn adder_circuit(m: usize) -> Arc<Circuit> {
        let mut bld = Builder::new();
        let a = bld.input_bus(m);
        let b = bld.input_bus(m);
        let (s, carry) = bld.add(&a, &b);
        bld.output_bus(&s);
        bld.output(carry);
        Arc::new(bld.build())
    }

    #[test]
    fn batch_matches_standalone_garbling_bit_for_bit() {
        // Same seed, same circuit: the batch path and the per-instance
        // path must produce identical tables, encodings, and decode bits.
        let circuit = adder_circuit(8);
        let n = 5;

        let mut rng_a = Rng::new(42);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), n);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, n);
        for _ in 0..n {
            batch.garble_next(&mut enc, &mut rng_a, &mut scratch);
        }

        let mut rng_b = Rng::new(42);
        for i in 0..n {
            let (gc, e) = garble_with_scratch(&circuit, &mut rng_b, &mut scratch);
            assert_eq!(batch.table_of(i), &gc.table[..], "tables i={i}");
            assert_eq!(batch.decode_of(i), &gc.output_decode[..], "decode i={i}");
            assert_eq!(enc.view(i).label0, &e.label0[..], "label0 i={i}");
            assert_eq!(enc.view(i).delta.0, e.delta.0, "delta i={i}");
        }
    }

    #[test]
    fn layer_eval_matches_plain_eval() {
        let circuit = adder_circuit(8);
        let n = 7;
        let mut rng = Rng::new(7);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), n);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, n);
        for _ in 0..n {
            batch.garble_next(&mut enc, &mut rng, &mut scratch);
        }

        // Treat the first 8 bits as the "client" block and the rest as the
        // "server" block, as the protocol does.
        let mut client_arena = Vec::new();
        let mut server_arena = Vec::new();
        let mut want = Vec::new();
        for i in 0..n {
            let a = rng.below(256);
            let b = rng.below(256);
            let mut bits = u64_to_bits(a, 8);
            bits.extend(u64_to_bits(b, 8));
            let view = enc.view(i);
            client_arena.extend((0..8).map(|j| view.encode(j, bits[j])));
            server_arena.extend((8..16).map(|j| view.encode(j, bits[j])));
            // Plain oracle: colors = plain value XOR decode bit.
            let plain = circuit.eval_plain(&bits);
            want.extend(plain.iter().zip(batch.decode_of(i)).map(|(&v, &d)| v ^ d));
        }

        let mut colors = Vec::new();
        batch.eval_layer_colors(&client_arena, &server_arena, &mut colors);
        assert_eq!(colors, want);
    }

    #[test]
    fn strides_and_byte_accounting() {
        let circuit = adder_circuit(4);
        let n_and = circuit.n_and();
        let n_out = circuit.outputs.len();
        let mut rng = Rng::new(3);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), 3);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, 3);
        for _ in 0..3 {
            batch.garble_next(&mut enc, &mut rng, &mut scratch);
        }
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.and_stride(), n_and);
        assert_eq!(batch.out_stride(), n_out);
        assert_eq!(batch.table_bytes(), 3 * n_and * 32);
        assert_eq!(enc.len(), 3);
        assert_eq!(enc.label_bytes(), 3 * circuit.n_inputs as usize * 16);
    }

    #[test]
    fn empty_layer_is_a_no_op() {
        let batch = LayerGcBatch::new(adder_circuit(4), 0);
        let mut colors = Vec::new();
        batch.eval_layer_colors(&[], &[], &mut colors);
        assert!(colors.is_empty());
    }

    /// Garble `n` instances of `circuit` and encode fresh pseudo-random
    /// inputs split 8/8 into client/server arenas.
    fn dealt_request(
        circuit: &Arc<Circuit>,
        n: usize,
        seed: u64,
    ) -> (LayerGcBatch, Vec<Label>, Vec<Label>) {
        let mut rng = Rng::new(seed);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), n);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, n);
        for _ in 0..n {
            batch.garble_next(&mut enc, &mut rng, &mut scratch);
        }
        let mut client_arena = Vec::new();
        let mut server_arena = Vec::new();
        for i in 0..n {
            let a = rng.below(256);
            let b = rng.below(256);
            let mut bits = u64_to_bits(a, 8);
            bits.extend(u64_to_bits(b, 8));
            let view = enc.view(i);
            client_arena.extend((0..8).map(|j| view.encode(j, bits[j])));
            server_arena.extend((8..16).map(|j| view.encode(j, bits[j])));
        }
        (batch, client_arena, server_arena)
    }

    #[test]
    fn multi_request_eval_matches_per_request_eval() {
        // The cross-request walk must reproduce each request's color
        // stream bit for bit, for R both below and above GROUP_WIDTH and
        // for n·R not a multiple of the group width.
        let circuit = adder_circuit(8);
        for r_count in [1usize, 2, 3, 8] {
            let n = 5; // n·R ∈ {5, 10, 15, 40}: ragged and full groups
            let dealt: Vec<_> = (0..r_count)
                .map(|r| dealt_request(&circuit, n, 1000 + r as u64))
                .collect();
            let mut want: Vec<Vec<bool>> = Vec::new();
            for (batch, ca, sa) in &dealt {
                let mut colors = Vec::new();
                batch.eval_layer_colors(ca, sa, &mut colors);
                want.push(colors);
            }
            let sources: Vec<LayerEvalSource<'_>> = dealt
                .iter()
                .map(|(batch, ca, sa)| LayerEvalSource {
                    gc: batch,
                    client_labels: ca,
                    server_labels: sa,
                })
                .collect();
            let mut got = vec![Vec::new(); r_count];
            let mut scratch = Vec::new();
            eval_layer_colors_multi(&sources, &mut got, &mut scratch);
            assert_eq!(got, want, "R = {r_count}");
        }
    }

    #[test]
    fn multi_request_eval_empty_layer_is_a_no_op() {
        let circuit = adder_circuit(4);
        let batches: Vec<LayerGcBatch> =
            (0..2).map(|_| LayerGcBatch::new(circuit.clone(), 0)).collect();
        let sources: Vec<LayerEvalSource<'_>> = batches
            .iter()
            .map(|b| LayerEvalSource { gc: b, client_labels: &[], server_labels: &[] })
            .collect();
        let mut colors = vec![Vec::new(); 2];
        eval_layer_colors_multi(&sources, &mut colors, &mut Vec::new());
        assert!(colors.iter().all(|c| c.is_empty()));
    }

    fn garble_chunked_with(
        circuit: &Arc<Circuit>,
        n: usize,
        threads: usize,
        seed: u64,
    ) -> (LayerGcBatch, LayerEncodingBatch) {
        let mut rng = Rng::new(seed);
        let mut batch = LayerGcBatch::new(circuit.clone(), n);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, n);
        batch.garble_chunked(&mut enc, n, &mut rng, threads);
        (batch, enc)
    }

    #[test]
    fn chunked_garbling_is_thread_count_invariant() {
        // The parallel-dealer contract: the same seed yields bit-identical
        // material whether the chunk loop runs on 1, 3, or 8 threads —
        // including a count that is not a multiple of the chunk size.
        let circuit = adder_circuit(8);
        let n = 2 * GARBLE_CHUNK + 37;
        let (b1, e1) = garble_chunked_with(&circuit, n, 1, 99);
        for threads in [2, 3, 8] {
            let (bt, et) = garble_chunked_with(&circuit, n, threads, 99);
            assert_eq!(bt.len(), n);
            assert_eq!(bt.tables(), b1.tables(), "{threads} threads: tables");
            assert_eq!(bt.output_decode(), b1.output_decode(), "{threads} threads: decode");
            assert_eq!(et.label0(), e1.label0(), "{threads} threads: label0");
            assert_eq!(
                et.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
                e1.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
                "{threads} threads: deltas"
            );
        }
    }

    #[test]
    fn chunked_garbling_evaluates_correctly() {
        // Chunk-forked RNG streams still have to produce *valid* garbled
        // material: evaluate every instance against the plain oracle.
        let circuit = adder_circuit(6);
        let n = GARBLE_CHUNK + 9;
        let (batch, enc) = garble_chunked_with(&circuit, n, 4, 7);
        let mut rng = Rng::new(1234);
        let mut client_arena = Vec::new();
        let mut server_arena = Vec::new();
        let mut want = Vec::new();
        for i in 0..n {
            let a = rng.below(64);
            let b = rng.below(64);
            let mut bits = u64_to_bits(a, 6);
            bits.extend(u64_to_bits(b, 6));
            let view = enc.view(i);
            client_arena.extend((0..6).map(|j| view.encode(j, bits[j])));
            server_arena.extend((6..12).map(|j| view.encode(j, bits[j])));
            let plain = circuit.eval_plain(&bits);
            want.extend(plain.iter().zip(batch.decode_of(i)).map(|(&v, &d)| v ^ d));
        }
        let mut colors = Vec::new();
        batch.eval_layer_colors(&client_arena, &server_arena, &mut colors);
        assert_eq!(colors, want);
    }

    #[test]
    fn from_parts_roundtrip_and_rejects_bad_arity() {
        let circuit = adder_circuit(4);
        let mut rng = Rng::new(21);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), 2);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, 2);
        for _ in 0..2 {
            batch.garble_next(&mut enc, &mut rng, &mut scratch);
        }

        let rebuilt = LayerGcBatch::from_parts(
            circuit.clone(),
            2,
            batch.tables().to_vec(),
            batch.output_decode().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt.tables(), batch.tables());
        assert_eq!(rebuilt.len(), 2);

        let wrong_n = LayerGcBatch::from_parts(
            circuit.clone(),
            3,
            batch.tables().to_vec(),
            batch.output_decode().to_vec(),
        );
        assert!(wrong_n.is_err());

        let rebuilt_enc = LayerEncodingBatch::from_parts(
            enc.stride(),
            enc.label0().to_vec(),
            enc.deltas().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt_enc.label0(), enc.label0());

        // Cleared delta color bit must be rejected.
        let bad = vec![Delta(Label::ZERO); 2];
        assert!(LayerEncodingBatch::from_parts(enc.stride(), enc.label0().to_vec(), bad).is_err());
    }

    #[test]
    fn fresh_labels_per_instance() {
        // Footnote 2: two instances of the same template must not share
        // material.
        let circuit = adder_circuit(6);
        let mut rng = Rng::new(11);
        let mut scratch = Vec::new();
        let mut batch = LayerGcBatch::new(circuit.clone(), 2);
        let mut enc = LayerEncodingBatch::new(circuit.n_inputs as usize, 2);
        batch.garble_next(&mut enc, &mut rng, &mut scratch);
        batch.garble_next(&mut enc, &mut rng, &mut scratch);
        assert_ne!(batch.table_of(0)[0][0], batch.table_of(1)[0][0]);
        assert_ne!(enc.view(0).label0[0], enc.view(1).label0[0]);
        assert_ne!(enc.view(0).delta.0, enc.view(1).delta.0);
    }
}

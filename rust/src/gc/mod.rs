//! Boolean garbled circuits: IR, combinators, optimizer, garbling engine.
//!
//! This is the substrate the paper's Fig. 2 circuits are built on:
//!
//! * [`circuit`] — topologically-ordered gate IR (`XOR`/`AND`/`NOT`) with a
//!   plain evaluator for testing, plus [`circuit::Circuit::optimize`]:
//!   output-reachability dead-wire elimination, duplicate-gate
//!   elimination, and topological compaction with an output remap —
//!   `eval_plain`-preserving by construction, pinned by
//!   `tests/circuit_opt.rs`.
//! * [`build`] — bus combinators (ripple adders/subtractors at 1 AND/bit,
//!   comparators, MUXes) with constant folding *and* hash-consing CSE:
//!   parity-normalized wires, commutatively keyed gate caches, one-level
//!   XOR cancellation — repeated subterms come back as existing wires
//!   instead of fresh gates, so circuits comparing against public
//!   constants (`p`, `p/2`) and sharing ripple-chain subterms get
//!   cheaper for free. `Builder::new_naive` keeps the seed's pre-CSE
//!   behavior as the test reference.
//! * [`garble`] / [`eval`] — free-XOR + point-and-permute + half-gates
//!   (2 ciphertexts = 32 bytes per AND gate; XOR and NOT are free).
//! * [`batch`] — layer-level SoA material: one shared `Arc<Circuit>`
//!   template (memoized per variant by `circuits::template`) + one
//!   contiguous table/label buffer per ReLU layer with strided per-ReLU
//!   views (the offline material's at-rest representation).
//! * [`size`] — byte accounting used for Fig. 5 (post-optimizer counts).

pub mod batch;
pub mod build;
pub mod circuit;
pub mod eval;
pub mod garble;
pub mod size;

pub use batch::{LayerEncodingBatch, LayerGcBatch};
pub use build::{Bit, Builder, Bus};
pub use circuit::{Circuit, WireDef, WireId};
pub use eval::evaluate;
pub use garble::{garble, EncodingView, GarbledCircuit, InputEncoding};
pub use size::CircuitCost;

//! Boolean garbled circuits: IR, combinators, garbling engine.
//!
//! This is the substrate the paper's Fig. 2 circuits are built on:
//!
//! * [`circuit`] — topologically-ordered gate IR (`XOR`/`AND`/`NOT`) with a
//!   plain evaluator for testing.
//! * [`build`] — bus combinators (ripple adders/subtractors at 1 AND/bit,
//!   comparators, MUXes) with automatic constant folding, so circuits that
//!   compare against public constants (`p`, `p/2`) get cheaper for free.
//! * [`garble`] / [`eval`] — free-XOR + point-and-permute + half-gates
//!   (2 ciphertexts = 32 bytes per AND gate; XOR and NOT are free).
//! * [`batch`] — layer-level SoA material: one circuit template + one
//!   contiguous table/label buffer per ReLU layer with strided per-ReLU
//!   views (the offline material's at-rest representation).
//! * [`size`] — byte accounting used for Fig. 5.

pub mod batch;
pub mod build;
pub mod circuit;
pub mod eval;
pub mod garble;
pub mod size;

pub use batch::{LayerEncodingBatch, LayerGcBatch};
pub use build::{Bit, Builder, Bus};
pub use circuit::{Circuit, WireDef, WireId};
pub use eval::evaluate;
pub use garble::{garble, EncodingView, GarbledCircuit, InputEncoding};
pub use size::CircuitCost;

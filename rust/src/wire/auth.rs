//! Pre-shared-key frame authentication for dealer links: AES-128-CMAC
//! (RFC 4493) built on the crate's own AES ([`crate::prf::softaes`]),
//! keeping the zero-dependency build.
//!
//! ## Why a MAC and not just the CRC
//!
//! The frame CRC catches *accidental* corruption; it is trivially
//! forgeable. Once a dealer link leaves the host (ROADMAP: N dealers
//! feeding one coordinator across machines), an on-path attacker who can
//! inject frames could feed the pool garbage material or tear the
//! protocol state machine. The keyed tag makes every frame
//! unforgeable without the PSK: it covers `MSG_TYPE | LEN | payload`
//! (the same bytes as the CRC), so neither the routing byte, the
//! framing length, nor the material itself can be altered or injected.
//!
//! ## Threat model (trusted dealer vs authenticated link)
//!
//! The PSK authenticates the **transport**, not the **party**: a peer
//! holding the PSK is assumed to run the honest protocol. The dealer
//! itself remains *trusted* for material correctness — it knows every
//! secret it deals (the paper's trusted-dealer deployment; see
//! [`crate::wire::dealer`] for the full note). CMAC gives integrity and
//! origin authentication per frame; it does **not** give
//! confidentiality (material is visible on the wire — acceptable for
//! dealer links on a private network, where the material is secret
//! *shares* and garbled tables, not plaintext inputs) and does not
//! prevent replay across connections (each connection's request/response
//! pairing makes replayed responses fail the seq/fingerprint checks at
//! staging).
//!
//! Tags are verified in constant time ([`tags_equal`]); a mismatch
//! surfaces as a transport error naming the PSK, which the handshake
//! turns into a connection failure — mismatched or missing keys fail
//! closed before any material is banked.

use crate::prf::softaes::Aes128;
use crate::util::error::Result;
use crate::{bail, ensure};

/// Bytes in a frame authentication tag (the full CMAC output).
pub const TAG_BYTES: usize = 16;

/// Doubling in GF(2^128) with the CMAC polynomial (x^128 + x^7 + x^2 +
/// x + 1): left shift by one bit, conditionally folding the carry back
/// as 0x87 in the low byte. Big-endian bit order per RFC 4493.
fn dbl(b: &[u8; 16]) -> [u8; 16] {
    let mut out = [0u8; 16];
    let mut carry = 0u8;
    for (o, &x) in out.iter_mut().zip(b.iter()).rev() {
        *o = (x << 1) | carry;
        carry = x >> 7;
    }
    if carry == 1 {
        if let Some(low) = out.last_mut() {
            *low ^= 0x87;
        }
    }
    out
}

/// AES-128-CMAC (RFC 4493): a keyed MAC with one key schedule and two
/// derived subkeys, reusable across frames.
pub struct Cmac {
    aes: Aes128,
    /// Subkey folded into a final block that is complete.
    k1: [u8; 16],
    /// Subkey folded into a final block that needed `10*` padding.
    k2: [u8; 16],
}

impl Cmac {
    pub fn new(key: [u8; 16]) -> Self {
        let aes = Aes128::new(key);
        let mut l = [0u8; 16];
        aes.encrypt_block(&mut l);
        let k1 = dbl(&l);
        let k2 = dbl(&k1);
        Self { aes, k1, k2 }
    }

    /// Tag of the concatenation of `parts` — lets the frame layer
    /// authenticate `header | payload` without copying them into one
    /// buffer.
    pub fn tag_parts(&self, parts: &[&[u8]]) -> [u8; 16] {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut state = [0u8; 16];
        let mut block = [0u8; 16];
        let mut fill = 0usize;
        let mut seen = 0usize;
        for part in parts {
            for &byte in *part {
                // `fill < 16` on entry: a full block is either flushed
                // below or is the final block, after which no byte follows.
                if let Some(slot) = block.get_mut(fill) {
                    *slot = byte;
                }
                fill += 1;
                seen += 1;
                // Flush every complete block except the final one (the
                // final block gets a subkey folded in below).
                if fill == 16 && seen < total {
                    for (s, b) in state.iter_mut().zip(&block) {
                        *s ^= *b;
                    }
                    self.aes.encrypt_block(&mut state);
                    fill = 0;
                }
            }
        }
        let mut last = [0u8; 16];
        if total > 0 && fill == 16 {
            for (l, (b, k)) in last.iter_mut().zip(block.iter().zip(&self.k1)) {
                *l = *b ^ *k;
            }
        } else {
            for (l, b) in last.iter_mut().zip(block.iter().take(fill)) {
                *l = *b;
            }
            if let Some(slot) = last.get_mut(fill) {
                *slot = 0x80;
            }
            for (l, k) in last.iter_mut().zip(&self.k2) {
                *l ^= *k;
            }
        }
        for (s, l) in state.iter_mut().zip(&last) {
            *s ^= *l;
        }
        self.aes.encrypt_block(&mut state);
        state
    }

    /// Tag of one contiguous message.
    pub fn tag(&self, msg: &[u8]) -> [u8; 16] {
        self.tag_parts(&[msg])
    }
}

/// Constant-time tag comparison (no early exit on the first differing
/// byte — a timing oracle on MAC verification is a classic forgery
/// primitive).
pub fn tags_equal(a: &[u8; 16], b: &[u8; 16]) -> bool {
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

/// Parse a 32-hex-char pre-shared key (the `--psk` CLI format). Works on
/// raw bytes so a multi-byte UTF-8 input can never land a slice on a
/// char boundary — non-hex bytes are an error, never a panic.
pub fn parse_psk_hex(s: &str) -> Result<[u8; 16]> {
    fn nibble(c: u8) -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            other => bail!("PSK is not hex (byte {other:#04x})"),
        }
    }
    let hex = s.trim().as_bytes();
    ensure!(
        hex.len() == 32,
        "PSK must be 32 hex chars (128 bits), got {} chars",
        hex.len()
    );
    let mut key = [0u8; 16];
    for (byte, pair) in key.iter_mut().zip(hex.chunks_exact(2)) {
        if let &[hi, lo] = pair {
            *byte = (nibble(hi)? << 4) | nibble(lo)?;
        }
    }
    Ok(key)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 4493 test key.
    const KEY: [u8; 16] = [
        0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, //
        0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf, 0x4f, 0x3c,
    ];

    const MSG64: [u8; 64] = [
        0x6b, 0xc1, 0xbe, 0xe2, 0x2e, 0x40, 0x9f, 0x96, //
        0xe9, 0x3d, 0x7e, 0x11, 0x73, 0x93, 0x17, 0x2a, //
        0xae, 0x2d, 0x8a, 0x57, 0x1e, 0x03, 0xac, 0x9c, //
        0x9e, 0xb7, 0x6f, 0xac, 0x45, 0xaf, 0x8e, 0x51, //
        0x30, 0xc8, 0x1c, 0x46, 0xa3, 0x5c, 0xe4, 0x11, //
        0xe5, 0xfb, 0xc1, 0x19, 0x1a, 0x0a, 0x52, 0xef, //
        0xf6, 0x9f, 0x24, 0x45, 0xdf, 0x4f, 0x9b, 0x17, //
        0xad, 0x2b, 0x41, 0x7b, 0xe6, 0x6c, 0x37, 0x10,
    ];

    #[test]
    fn rfc4493_known_answers() {
        let mac = Cmac::new(KEY);
        // Example 1: empty message.
        assert_eq!(
            mac.tag(&[]),
            [
                0xbb, 0x1d, 0x69, 0x29, 0xe9, 0x59, 0x37, 0x28, //
                0x7f, 0xa3, 0x7d, 0x12, 0x9b, 0x75, 0x67, 0x46
            ]
        );
        // Example 2: one full block.
        assert_eq!(
            mac.tag(&MSG64[..16]),
            [
                0x07, 0x0a, 0x16, 0xb4, 0x6b, 0x4d, 0x41, 0x44, //
                0xf7, 0x9b, 0xdd, 0x9d, 0xd0, 0x4a, 0x28, 0x7c
            ]
        );
        // Example 3: 40 bytes (padded final block).
        assert_eq!(
            mac.tag(&MSG64[..40]),
            [
                0xdf, 0xa6, 0x67, 0x47, 0xde, 0x9a, 0xe6, 0x30, //
                0x30, 0xca, 0x32, 0x61, 0x14, 0x97, 0xc8, 0x27
            ]
        );
        // Example 4: four full blocks.
        assert_eq!(
            mac.tag(&MSG64),
            [
                0x51, 0xf0, 0xbe, 0xbf, 0x7e, 0x3b, 0x9d, 0x92, //
                0xfc, 0x49, 0x74, 0x17, 0x79, 0x36, 0x3c, 0xfe
            ]
        );
    }

    #[test]
    fn tag_parts_matches_contiguous_tag() {
        let mac = Cmac::new(KEY);
        for split in [0usize, 1, 5, 16, 17, 39, 40] {
            let (a, b) = MSG64[..40].split_at(split);
            assert_eq!(mac.tag_parts(&[a, b]), mac.tag(&MSG64[..40]), "split {split}");
        }
        assert_eq!(mac.tag_parts(&[&[], &[], &[]]), mac.tag(&[]));
    }

    #[test]
    fn different_keys_different_tags() {
        let a = Cmac::new(KEY);
        let mut other = KEY;
        other[0] ^= 1;
        let b = Cmac::new(other);
        assert_ne!(a.tag(b"frame"), b.tag(b"frame"));
        assert!(tags_equal(&a.tag(b"frame"), &a.tag(b"frame")));
        assert!(!tags_equal(&a.tag(b"frame"), &b.tag(b"frame")));
    }

    #[test]
    fn psk_hex_parsing() {
        let key = parse_psk_hex("2b7e151628aed2a6abf7158809cf4f3c").unwrap();
        assert_eq!(key, KEY);
        assert_eq!(parse_psk_hex("  2B7E151628AED2A6ABF7158809CF4F3C\n").unwrap(), KEY);
        assert!(parse_psk_hex("abc").is_err(), "too short");
        assert!(parse_psk_hex("zz7e151628aed2a6abf7158809cf4f3c").is_err(), "not hex");
    }
}

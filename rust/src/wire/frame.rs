//! Framed message transport for the dealer↔coordinator link.
//!
//! A frame is `MSG_TYPE (1 B) | LEN (4 B le) | payload (LEN B) |
//! CRC32 (4 B le)` where the CRC (IEEE 802.3 polynomial) covers the
//! **header and payload** (`MSG_TYPE | LEN | payload`), so a corrupted
//! type byte cannot silently misroute an otherwise-valid payload and a
//! corrupted LEN cannot misframe the stream undetected. Framing is
//! otherwise deliberately dumb: versioning and identity live in the
//! handshake payload ([`super::codec::SessionManifest`]).
//!
//! **One-time format change (layer-streaming revision):** the CRC
//! originally covered the payload only; it now also covers the 5 header
//! bytes. The frame layer has no version field of its own, so old and
//! new endpoints reject each other's frames as CRC mismatches — the
//! codec `VERSION` was bumped in the same revision, making the break
//! explicit at the handshake for any peer that gets that far.
//!
//! **Authenticated frames (dealer links):** a [`Framed`] built with
//! [`Framed::with_psk`] appends a 16-byte AES-128-CMAC tag
//! ([`super::auth`]) after the CRC, keyed by a pre-shared key and
//! covering the same `MSG_TYPE | LEN | payload` bytes, and requires the
//! tag on every received frame. The two sides must agree: a keyed
//! sender talking to a plain receiver leaves 16 stray tag bytes in the
//! stream (the next header read lands inside them → type/CRC error),
//! and a plain sender talking to a keyed receiver has the next frame's
//! header consumed as a bogus tag (→ MAC mismatch naming the PSK).
//! Either way the link fails closed at the first frame — in practice
//! the handshake — rather than ever delivering unauthenticated
//! payloads. The client-facing serving tier ([`crate::net`]) stays
//! un-keyed; the PSK is a dealer-link control (see [`super::auth`] for
//! the threat model).
//!
//! The byte transport underneath is the [`Channel`] trait with two
//! implementations: [`MemChannel`] (in-process duplex over byte queues,
//! for tests and single-process demos) and [`TcpChannel`] (blocking
//! `std::net::TcpStream`, the real two-process deployment). Everything
//! received is treated as untrusted: unknown message types, oversized
//! LEN fields, short streams, and CRC mismatches all surface as
//! [`crate::util::error::Result`] errors — never panics.

use super::auth::{tags_equal, Cmac};
use crate::util::bytes::le_u32;
use crate::util::error::{Context, Error, Result};
use crate::{bail, ensure};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel as mpsc_channel, Receiver, Sender};

/// Hard upper bound on a frame payload (1 GiB). A LEN above this is
/// rejected before any allocation happens.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Frame header bytes (type + LEN) preceding the payload.
pub const FRAME_HEADER_BYTES: usize = 5;

/// Trailing CRC bytes following the payload.
pub const FRAME_CRC_BYTES: usize = 4;

/// Trailing MAC tag bytes on an authenticated ([`Framed::with_psk`])
/// link, appended after the CRC.
pub const FRAME_TAG_BYTES: usize = super::auth::TAG_BYTES;

/// Message types of the dealer protocol (see [`super::dealer`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgType {
    /// Handshake: payload is an encoded manifest *set* (one
    /// `SessionManifest` per model the sender serves).
    Hello = 1,
    /// Coordinator → dealer: payload is a model fingerprint (u64) and a
    /// u32 session count.
    Request = 2,
    /// Dealer → coordinator: payload is one encoded session.
    Session = 3,
    /// Orderly goodbye (empty payload).
    Bye = 4,
    /// Rejection: payload is a UTF-8 message. Fatal in the handshake;
    /// inside a round it reports an unknown model fingerprint and the
    /// connection survives.
    Error = 5,
    /// Coordinator → dealer: layer-granular work order (model
    /// fingerprint, kind, layer index, explicit session sequence
    /// numbers).
    RequestLayers = 6,
    /// Dealer → coordinator: one ReLU layer of one session of one
    /// model, both parties' halves.
    LayerBatch = 7,
    /// Dealer → coordinator: the linear-precompute spine of one session
    /// of one model.
    Spine = 8,
    /// Client ↔ serving tier ([`crate::net`]): protocol handshake.
    /// Client → server it is a version probe; server → client the reply
    /// advertises the registered model set (see `net::proto`).
    ClientHello = 9,
    /// Client → serving tier: one inference request (request id, model
    /// fingerprint, input vector).
    Infer = 10,
    /// Serving tier → client: one inference result (logits + serving
    /// stats).
    Logits = 11,
    /// Serving tier → client: admission control shed the request —
    /// payload carries a retry-after hint and a reason. The connection
    /// survives.
    Busy = 12,
}

impl MsgType {
    pub fn from_u8(v: u8) -> Result<MsgType> {
        match v {
            1 => Ok(MsgType::Hello),
            2 => Ok(MsgType::Request),
            3 => Ok(MsgType::Session),
            4 => Ok(MsgType::Bye),
            5 => Ok(MsgType::Error),
            6 => Ok(MsgType::RequestLayers),
            7 => Ok(MsgType::LayerBatch),
            8 => Ok(MsgType::Spine),
            9 => Ok(MsgType::ClientHello),
            10 => Ok(MsgType::Infer),
            11 => Ok(MsgType::Logits),
            12 => Ok(MsgType::Busy),
            other => bail!("unknown message type {other}"),
        }
    }
}

/// One received frame.
#[derive(Debug)]
pub struct Frame {
    pub msg_type: MsgType,
    pub payload: Vec<u8>,
}

/// A blocking byte pipe between two parties. Implementations only move
/// bytes; framing, CRC, and message semantics live above.
pub trait Channel: Send {
    /// Send the whole buffer (blocking).
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()>;
    /// Fill the whole buffer (blocking); `Err` on peer close/short stream.
    fn recv_exact(&mut self, buf: &mut [u8]) -> Result<()>;
}

const CRC_POLY: u32 = 0xEDB8_8320;

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 == 1 { CRC_POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        // lint:allow(r1): const-context table build — an out-of-bounds
        // index here is a compile error, never a runtime panic.
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

const CRC_INIT: u32 = 0xFFFF_FFFF;

/// Feed `data` through the CRC register (no init/finalize) — lets the
/// receive path checksum header and payload without concatenating them.
fn crc32_feed(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        // The `& 0xFF` mask proves the index < 256, so the `unwrap_or`
        // arm is dead; the KAT test pins the register semantics.
        let entry = CRC_TABLE.get(((state ^ b as u32) & 0xFF) as usize).copied().unwrap_or(0);
        state = entry ^ (state >> 8);
    }
    state
}

/// CRC-32 (IEEE 802.3) of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    crc32_feed(CRC_INIT, data) ^ CRC_INIT
}

/// Encode one frame (header + payload + trailing CRC) into a byte
/// vector — the building block shared by the blocking [`Framed::send`]
/// path and the nonblocking reactor write buffers ([`crate::net`]).
pub fn encode_frame(msg_type: MsgType, payload: &[u8]) -> Result<Vec<u8>> {
    ensure!(payload.len() <= MAX_FRAME_LEN, "frame payload too large: {}", payload.len());
    let len32 = u32::try_from(payload.len()).context("frame LEN overflows u32")?;
    let mut buf = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len() + FRAME_CRC_BYTES);
    buf.push(msg_type as u8);
    buf.extend_from_slice(&len32.to_le_bytes());
    buf.extend_from_slice(payload);
    // CRC covers header + payload (everything written so far).
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    Ok(buf)
}

/// Framing layer over a boxed [`Channel`], with byte accounting for the
/// coordinator's offline-traffic ledger.
pub struct Framed {
    chan: Box<dyn Channel>,
    mac: Option<Cmac>,
    bytes_sent: u64,
    bytes_received: u64,
    max_frame_received: u64,
}

impl Framed {
    pub fn new(chan: Box<dyn Channel>) -> Self {
        Self { chan, mac: None, bytes_sent: 0, bytes_received: 0, max_frame_received: 0 }
    }

    /// An authenticated framing layer: every sent frame carries an
    /// AES-128-CMAC tag keyed by `psk` over `MSG_TYPE | LEN | payload`,
    /// and every received frame must carry a valid one.
    pub fn with_psk(chan: Box<dyn Channel>, psk: [u8; 16]) -> Self {
        Self {
            chan,
            mac: Some(Cmac::new(psk)),
            bytes_sent: 0,
            bytes_received: 0,
            max_frame_received: 0,
        }
    }

    /// Send one frame (header + payload + CRC — plus the MAC tag on a
    /// keyed link — in a single write).
    pub fn send(&mut self, msg_type: MsgType, payload: &[u8]) -> Result<()> {
        let mut buf = encode_frame(msg_type, payload)?;
        if let Some(mac) = &self.mac {
            let body_len = buf.len().saturating_sub(FRAME_CRC_BYTES);
            let tag = mac.tag(buf.get(..body_len).unwrap_or_default());
            buf.extend_from_slice(&tag);
        }
        self.chan.send_bytes(&buf)?;
        self.bytes_sent += buf.len() as u64;
        Ok(())
    }

    /// Receive one frame, validating type, LEN bound, and the
    /// header-covering CRC.
    pub fn recv(&mut self) -> Result<Frame> {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        self.chan.recv_exact(&mut header)?;
        let (type_byte, len_bytes) = header.split_at(1);
        let msg_type = MsgType::from_u8(type_byte.first().copied().context("empty header")?)?;
        let len = le_u32(len_bytes) as usize;
        ensure!(len <= MAX_FRAME_LEN, "oversized frame LEN {len}");
        // Grow the payload in bounded steps so a corrupt LEN with no data
        // behind it fails after at most one step's allocation.
        const RECV_STEP: usize = 1 << 22;
        let mut payload: Vec<u8> = Vec::new();
        while payload.len() < len {
            let start = payload.len();
            payload.resize(start + RECV_STEP.min(len - start), 0);
            self.chan.recv_exact(payload.get_mut(start..).context("frame read range")?)?;
        }
        let mut crc = [0u8; FRAME_CRC_BYTES];
        self.chan.recv_exact(&mut crc)?;
        let want = crc32_feed(crc32_feed(CRC_INIT, &header), &payload) ^ CRC_INIT;
        ensure!(
            u32::from_le_bytes(crc) == want,
            "frame CRC mismatch ({:?}, {len} B payload)",
            msg_type
        );
        let mut tag_bytes = 0u64;
        if let Some(mac) = &self.mac {
            let mut tag = [0u8; FRAME_TAG_BYTES];
            self.chan.recv_exact(&mut tag)?;
            let want_tag = mac.tag_parts(&[&header, &payload]);
            ensure!(
                tags_equal(&tag, &want_tag),
                "frame MAC mismatch ({:?}, {len} B payload) — PSK disagreement or tampering",
                msg_type
            );
            tag_bytes = FRAME_TAG_BYTES as u64;
        }
        let frame_bytes = (FRAME_HEADER_BYTES + len + FRAME_CRC_BYTES) as u64 + tag_bytes;
        self.bytes_received += frame_bytes;
        self.max_frame_received = self.max_frame_received.max(frame_bytes);
        Ok(Frame { msg_type, payload })
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    /// Largest single frame received so far (header + payload + CRC) —
    /// the number the layer-streaming acceptance bound is about: for a
    /// multi-layer plan it must track the largest *layer*, not the
    /// session.
    pub fn max_frame_received(&self) -> u64 {
        self.max_frame_received
    }
}

/// In-memory duplex byte channel (the test/demo transport).
pub struct MemChannel {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    pending: Vec<u8>,
    pos: usize,
}

impl MemChannel {
    /// A connected endpoint pair.
    pub fn pair() -> (MemChannel, MemChannel) {
        let (tx_ab, rx_ab) = mpsc_channel();
        let (tx_ba, rx_ba) = mpsc_channel();
        (
            MemChannel { tx: tx_ab, rx: rx_ba, pending: Vec::new(), pos: 0 },
            MemChannel { tx: tx_ba, rx: rx_ab, pending: Vec::new(), pos: 0 },
        )
    }
}

impl Channel for MemChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        self.tx.send(buf.to_vec()).map_err(|_| Error::msg("in-memory peer closed"))
    }

    fn recv_exact(&mut self, out: &mut [u8]) -> Result<()> {
        let mut filled = 0;
        while filled < out.len() {
            if self.pos >= self.pending.len() {
                self.pending =
                    self.rx.recv().map_err(|_| Error::msg("in-memory peer closed"))?;
                self.pos = 0;
                continue;
            }
            let take = (self.pending.len() - self.pos).min(out.len() - filled);
            let src = self.pending.get(self.pos..self.pos + take).context("pending range")?;
            let dst = out.get_mut(filled..filled + take).context("out range")?;
            dst.copy_from_slice(src);
            self.pos += take;
            filled += take;
        }
        Ok(())
    }
}

/// Blocking TCP byte channel (the two-process transport).
pub struct TcpChannel {
    stream: TcpStream,
}

impl TcpChannel {
    pub fn new(stream: TcpStream) -> Self {
        // Frames are latency-sensitive request/response pairs.
        let _ = stream.set_nodelay(true);
        Self { stream }
    }

    /// Connect as a client, with a read timeout so a dead peer surfaces
    /// as a transport error (the pool's reconnect path) instead of
    /// blocking a dealer thread — and the pool's shutdown join — forever.
    /// Generous enough for a dealer garbling a multi-session batch on
    /// demand; the server side deliberately stays blocking (an idle
    /// coordinator holding a connection open is normal: its bank is
    /// full).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(120)));
        Ok(Self::new(stream))
    }
}

impl Channel for TcpChannel {
    fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
        self.stream.write_all(buf).context("tcp send")
    }

    fn recv_exact(&mut self, out: &mut [u8]) -> Result<()> {
        self.stream.read_exact(out).context("tcp recv")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn framed_pair() -> (Framed, Framed) {
        let (a, b) = MemChannel::pair();
        (Framed::new(Box::new(a)), Framed::new(Box::new(b)))
    }

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_frames_and_byte_accounting() {
        let (mut a, mut b) = framed_pair();
        a.send(MsgType::Hello, b"manifest").unwrap();
        a.send(MsgType::Bye, b"").unwrap();
        let f1 = b.recv().unwrap();
        assert_eq!(f1.msg_type, MsgType::Hello);
        assert_eq!(f1.payload, b"manifest");
        let f2 = b.recv().unwrap();
        assert_eq!(f2.msg_type, MsgType::Bye);
        assert!(f2.payload.is_empty());
        // Two frames: (9-byte overhead + 8-byte payload) + (9 + 0).
        assert_eq!(a.bytes_sent(), 26);
        assert_eq!(b.bytes_received(), a.bytes_sent());
        assert_eq!(b.max_frame_received(), 17);
    }

    /// A valid one-byte-payload frame with the header-covering CRC.
    fn valid_raw_frame() -> Vec<u8> {
        let mut raw = vec![MsgType::Session as u8];
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.push(b'x');
        let crc = crc32(&raw);
        raw.extend_from_slice(&crc.to_le_bytes());
        raw
    }

    #[test]
    fn flipped_crc_is_rejected() {
        let (mut a, b) = MemChannel::pair();
        // A valid frame with its payload byte flipped after the CRC was
        // computed over header + payload.
        let mut raw = valid_raw_frame();
        raw[FRAME_HEADER_BYTES] ^= 0xFF;
        a.send_bytes(&raw).unwrap();
        let mut b = Framed::new(Box::new(b));
        let err = b.recv().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn header_type_flip_is_rejected() {
        // The CRC covers the header: flipping the type byte between two
        // *valid* message types (Session → Error) must surface as a CRC
        // mismatch, not silently misroute the payload.
        let (mut a, b) = MemChannel::pair();
        let mut raw = valid_raw_frame();
        raw[0] = MsgType::Error as u8;
        a.send_bytes(&raw).unwrap();
        let err = Framed::new(Box::new(b)).recv().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn header_len_flip_is_rejected() {
        // A LEN flip that still frames within the delivered bytes (1 →
        // 0: the payload byte is misread as the CRC's first byte) must
        // fail the header-covering CRC instead of yielding a bogus
        // empty-payload frame.
        let (mut a, b) = MemChannel::pair();
        let mut raw = valid_raw_frame();
        raw[1] = 0;
        a.send_bytes(&raw).unwrap();
        let err = Framed::new(Box::new(b)).recv().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn bad_msg_type_is_rejected() {
        let (mut a, b) = MemChannel::pair();
        let mut raw = vec![0xEEu8];
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.extend_from_slice(&crc32(b"").to_le_bytes());
        a.send_bytes(&raw).unwrap();
        let err = Framed::new(Box::new(b)).recv().unwrap_err();
        assert!(err.to_string().contains("unknown message type"), "{err}");
    }

    #[test]
    fn oversized_len_is_rejected_before_allocation() {
        let (mut a, b) = MemChannel::pair();
        let mut raw = vec![MsgType::Session as u8];
        raw.extend_from_slice(&u32::MAX.to_le_bytes());
        a.send_bytes(&raw).unwrap();
        let err = Framed::new(Box::new(b)).recv().unwrap_err();
        assert!(err.to_string().contains("oversized frame LEN"), "{err}");
    }

    #[test]
    fn truncated_stream_errors_cleanly() {
        let (mut a, b) = MemChannel::pair();
        // Header promises 100 payload bytes; only 3 arrive, then close.
        let mut raw = vec![MsgType::Session as u8];
        raw.extend_from_slice(&100u32.to_le_bytes());
        raw.extend_from_slice(b"abc");
        a.send_bytes(&raw).unwrap();
        drop(a);
        assert!(Framed::new(Box::new(b)).recv().is_err());
    }

    #[test]
    fn psk_roundtrip_and_byte_accounting() {
        let (a, b) = MemChannel::pair();
        let psk = [7u8; 16];
        let mut a = Framed::with_psk(Box::new(a), psk);
        let mut b = Framed::with_psk(Box::new(b), psk);
        a.send(MsgType::Hello, b"manifest").unwrap();
        let f = b.recv().unwrap();
        assert_eq!(f.msg_type, MsgType::Hello);
        assert_eq!(f.payload, b"manifest");
        // 9-byte plain overhead + 8-byte payload + 16-byte tag.
        assert_eq!(a.bytes_sent(), 33);
        assert_eq!(b.bytes_received(), a.bytes_sent());
    }

    #[test]
    fn psk_mismatch_is_rejected_as_mac_error() {
        let (a, b) = MemChannel::pair();
        let mut a = Framed::with_psk(Box::new(a), [1u8; 16]);
        let mut b = Framed::with_psk(Box::new(b), [2u8; 16]);
        a.send(MsgType::Hello, b"manifest").unwrap();
        let err = b.recv().unwrap_err();
        assert!(err.to_string().contains("PSK"), "{err}");
    }

    #[test]
    fn plain_sender_to_keyed_receiver_is_rejected() {
        let (a, b) = MemChannel::pair();
        let mut a = Framed::new(Box::new(a));
        let mut b = Framed::with_psk(Box::new(b), [3u8; 16]);
        // Two back-to-back frames: the keyed receiver consumes the second
        // frame's first 16 bytes as the missing tag and must reject.
        a.send(MsgType::Hello, b"manifest").unwrap();
        a.send(MsgType::Bye, b"").unwrap();
        let err = b.recv().unwrap_err();
        assert!(err.to_string().contains("PSK"), "{err}");
    }

    #[test]
    fn keyed_sender_to_plain_receiver_fails_on_next_frame() {
        let (a, b) = MemChannel::pair();
        let mut a = Framed::with_psk(Box::new(a), [4u8; 16]);
        let mut b = Framed::new(Box::new(b));
        a.send(MsgType::Hello, b"manifest").unwrap();
        a.send(MsgType::Bye, b"").unwrap();
        // Close the sender so a stray-tag byte that happens to parse as
        // a plausible header errors (peer closed) instead of blocking.
        drop(a);
        // First frame parses (tag not yet consumed)…
        let f = b.recv().unwrap();
        assert_eq!(f.msg_type, MsgType::Hello);
        // …but the stray tag bytes desynchronize the stream: the next
        // header read lands inside the tag and the link fails closed.
        assert!(b.recv().is_err());
    }

    #[test]
    fn works_across_threads_over_mem_channel() {
        let (mut a, b) = framed_pair();
        let h = std::thread::spawn(move || {
            let mut b = b;
            let f = b.recv().unwrap();
            b.send(f.msg_type, &f.payload).unwrap();
        });
        a.send(MsgType::Request, &7u32.to_le_bytes()).unwrap();
        let echo = a.recv().unwrap();
        assert_eq!(echo.msg_type, MsgType::Request);
        assert_eq!(echo.payload, 7u32.to_le_bytes());
        h.join().unwrap();
    }
}

//! Versioned binary codec for offline material.
//!
//! Everything the dealer ships is already contiguous SoA
//! ([`crate::gc::batch`]), so encoding is length-prefixed memcpys: the
//! table buffer, label arenas, and decode bits of a ReLU layer go on the
//! wire as single flat runs. Circuits are **not** shipped — the receiver
//! rebuilds the layer's template from the [`VariantSpec`] in the session
//! manifest and validates the declared strides against it, which both
//! shrinks the wire format to the paper's `offline_bytes` shape and
//! gives decode a structural cross-check for free.
//!
//! Decoding is hardened for untrusted input: every length is
//! overflow-checked against the remaining buffer before allocation,
//! every field element is range-checked against `p`, every delta must
//! carry its color bit, and layer shapes must match the plan. All
//! failures are [`Result`] errors — never panics.
//!
//! Versioning: [`MAGIC`]/[`VERSION`] are carried once per connection in
//! the handshake's **manifest set** ([`encode_manifest_set`] — one
//! [`SessionManifest`] per model the sender serves). Any layout change
//! to the material encodings below requires a `VERSION` bump; decoders
//! reject manifests with a different version outright (no cross-version
//! compatibility is attempted at this stage). `VERSION` 3 is the
//! multi-model round: material payloads lead with the fingerprint of the
//! model they belong to, and the manifest carries a weight digest.

use crate::beaver::TripleShare;
use crate::circuits::spec::{FaultMode, ReluVariant, VariantSpec};
use crate::coordinator::pool::Session;
use crate::field::{Fp, PRIME};
use crate::gc::batch::{LayerEncodingBatch, LayerGcBatch};
use crate::prf::{Delta, Label};
use crate::protocol::client::{ClientLayer, ClientNet};
use crate::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::protocol::server::{LinearSlot, LinearSpine, NetworkPlan, ServerLayer, ServerNet};
use crate::util::bytes::{le_u128, le_u32, Reader, Writer};
use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// `b"CIRW"` as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"CIRW");

/// Wire-format version; bump on any layout change. v2: layer-granular
/// streaming (the `LayerBatch`/`Spine` payloads below) and the frame
/// CRC extended to cover the frame header. v3 (one-time, multi-model
/// round): the `Hello` payload is a **manifest set**
/// ([`encode_manifest_set`]) instead of a single manifest, the manifest
/// body carries a behavioral weight digest
/// ([`SessionManifest::weight_hash`], folded into the fingerprint), and
/// `Request`, `RequestLayers`, `LayerBatch`, and `Spine` payloads lead
/// with the model fingerprint so one connection serves any registered
/// plan. v4 (one-time, material-squeeze round): circuit templates are
/// CSE-built and [`crate::gc::circuit::Circuit::optimize`]d, so the
/// garbled-table strides both ends derive from `VariantSpec` shrank —
/// same encodings, different material layout, hence the bump (a v3
/// dealer's tables would fail the stride cross-check with a confusing
/// error instead of a clean version mismatch).
pub const VERSION: u16 = 4;

/// Upper bound on manifests per handshake set (decode guard).
pub const MAX_MANIFESTS: u32 = 1024;

// ---------------------------------------------------------------- scalars

fn put_fp_vec(w: &mut Writer, v: &[Fp]) {
    w.u64(v.len() as u64);
    w.buf.reserve(v.len() * 4);
    for &x in v {
        w.u32(x.raw() as u32);
    }
}

fn get_fp_vec(r: &mut Reader) -> Result<Vec<Fp>> {
    let n = r.len_u64()?;
    let raw = r.take(n.checked_mul(4).context("fp vec length overflows")?)?;
    raw.chunks_exact(4)
        .map(|c| {
            let v = le_u32(c) as u64;
            ensure!(v < PRIME, "field element {v} out of range");
            Ok(Fp::new(v))
        })
        .collect()
}

fn put_label_vec(w: &mut Writer, v: &[Label]) {
    w.u64(v.len() as u64);
    w.buf.reserve(v.len() * 16);
    for &l in v {
        w.u128(l.0);
    }
}

fn get_label_vec(r: &mut Reader) -> Result<Vec<Label>> {
    Ok(r.u128_vec().context("label vec")?.into_iter().map(Label).collect())
}

fn put_table_vec(w: &mut Writer, v: &[[Label; 2]]) {
    w.u64(v.len() as u64);
    w.buf.reserve(v.len() * 32);
    for &[lo, hi] in v {
        w.u128(lo.0);
        w.u128(hi.0);
    }
}

fn get_table_vec(r: &mut Reader) -> Result<Vec<[Label; 2]>> {
    let n = r.len_u64()?;
    let raw = r.take(n.checked_mul(32).context("table vec length overflows")?)?;
    Ok(raw
        .chunks_exact(32)
        .map(|c| {
            let (lo, hi) = c.split_at(16);
            [Label(le_u128(lo)), Label(le_u128(hi))]
        })
        .collect())
}

// ---------------------------------------------------------------- variant

const MODE_POS_ZERO: u8 = 0;
const MODE_NEG_PASS: u8 = 1;

fn mode_tag(mode: FaultMode) -> u8 {
    match mode {
        FaultMode::PosZero => MODE_POS_ZERO,
        FaultMode::NegPass => MODE_NEG_PASS,
    }
}

fn mode_from_tag(tag: u8) -> Result<FaultMode> {
    match tag {
        MODE_POS_ZERO => Ok(FaultMode::PosZero),
        MODE_NEG_PASS => Ok(FaultMode::NegPass),
        other => bail!("unknown fault mode tag {other}"),
    }
}

/// Encode a variant as `tag u8 | mode u8 | k u32` (zeros where unused, so
/// the encoding is canonical and fingerprint-stable).
pub fn put_variant(w: &mut Writer, v: ReluVariant) {
    let (tag, mode, k) = match v {
        ReluVariant::BaselineRelu => (0u8, 0u8, 0u32),
        ReluVariant::NaiveSign => (1, 0, 0),
        ReluVariant::StochasticSign { mode } => (2, mode_tag(mode), 0),
        ReluVariant::TruncatedSign { k, mode } => (3, mode_tag(mode), k),
    };
    w.u8(tag);
    w.u8(mode);
    w.u32(k);
}

pub fn get_variant(r: &mut Reader) -> Result<ReluVariant> {
    let tag = r.u8()?;
    let mode = r.u8()?;
    let k = r.u32()?;
    let v = match tag {
        0 | 1 => {
            ensure!(mode == 0 && k == 0, "non-canonical variant encoding");
            if tag == 0 {
                ReluVariant::BaselineRelu
            } else {
                ReluVariant::NaiveSign
            }
        }
        2 => {
            ensure!(k == 0, "non-canonical variant encoding");
            ReluVariant::StochasticSign { mode: mode_from_tag(mode)? }
        }
        3 => {
            ensure!(k < 31, "truncation k={k} exceeds the field width");
            ReluVariant::TruncatedSign { k, mode: mode_from_tag(mode)? }
        }
        other => bail!("unknown variant tag {other}"),
    };
    Ok(v)
}

// --------------------------------------------------------- layer batches

/// Encode a layer's garbled tables: `n | and_stride | out_stride |
/// tables | decode bits`. The circuit itself stays off the wire.
pub fn put_gc_batch(w: &mut Writer, b: &LayerGcBatch) {
    w.u64(b.len() as u64);
    // lint:allow(r5): strides come from the local circuit template (tens of
    // gates per ReLU), bounded far below u32 — never from wire input.
    let (and_stride, out_stride) = (b.and_stride() as u32, b.out_stride() as u32);
    w.u32(and_stride);
    w.u32(out_stride);
    put_table_vec(w, b.tables());
    w.bool_vec(b.output_decode());
}

/// Decode a layer's garbled tables against the variant's circuit
/// template, validating every stride.
pub fn get_gc_batch(r: &mut Reader, spec: &VariantSpec) -> Result<LayerGcBatch> {
    let n = r.len_u64()?;
    let and_stride = r.u32()? as usize;
    let out_stride = r.u32()? as usize;
    // Memoized template lookup (`circuits::template`): decode validates
    // strides against the shared optimized circuit without a rebuild.
    let circuit = spec.circuit();
    ensure!(
        and_stride == circuit.n_and(),
        "and stride {and_stride} != circuit {} for {:?}",
        circuit.n_and(),
        spec.variant
    );
    ensure!(
        out_stride == circuit.outputs.len(),
        "out stride {out_stride} != circuit {} for {:?}",
        circuit.outputs.len(),
        spec.variant
    );
    let tables = get_table_vec(r)?;
    let decode = r.bool_vec()?;
    LayerGcBatch::from_parts(circuit, n, tables, decode)
}

/// Encode a layer's input-encoding arena: `stride | label0 | deltas`.
pub fn put_encoding_batch(w: &mut Writer, e: &LayerEncodingBatch) {
    w.u64(e.stride() as u64);
    put_label_vec(w, e.label0());
    w.u64(e.deltas().len() as u64);
    w.buf.reserve(e.deltas().len() * 16);
    for d in e.deltas() {
        w.u128(d.0 .0);
    }
}

pub fn get_encoding_batch(r: &mut Reader, spec: &VariantSpec) -> Result<LayerEncodingBatch> {
    let stride = r.len_u64()?;
    ensure!(
        stride == spec.n_inputs(),
        "encoding stride {stride} != {} inputs for {:?}",
        spec.n_inputs(),
        spec.variant
    );
    let label0 = get_label_vec(r)?;
    let deltas: Vec<Delta> = get_label_vec(r)?.into_iter().map(Delta).collect();
    LayerEncodingBatch::from_parts(stride, label0, deltas)
}

// ---------------------------------------------------------------- triples

/// Encode per-layer Beaver triple shares as one flat field column
/// (`a, b, ab` per triple).
pub fn put_triples(w: &mut Writer, triples: &[TripleShare]) {
    let mut flat = Vec::with_capacity(triples.len() * 3);
    for t in triples {
        flat.push(t.a);
        flat.push(t.b);
        flat.push(t.ab);
    }
    put_fp_vec(w, &flat);
}

pub fn get_triples(r: &mut Reader) -> Result<Vec<TripleShare>> {
    let flat = get_fp_vec(r)?;
    ensure!(flat.len() % 3 == 0, "triple column length {} not divisible by 3", flat.len());
    let mut out = Vec::with_capacity(flat.len() / 3);
    for c in flat.chunks_exact(3) {
        if let &[a, b, ab] = c {
            out.push(TripleShare { a, b, ab });
        }
    }
    Ok(out)
}

// ------------------------------------------------------- layer materials

/// Encode one layer's client-side ReLU material.
pub fn put_client_relu(w: &mut Writer, m: &ClientReluMaterial) {
    put_variant(w, m.spec.variant);
    put_gc_batch(w, &m.gc);
    put_label_vec(w, &m.client_labels);
    put_fp_vec(w, &m.r_v);
    put_fp_vec(w, &m.r_out);
    put_triples(w, &m.triples);
    w.u64(m.offline_bytes);
}

pub fn get_client_relu(r: &mut Reader) -> Result<ClientReluMaterial> {
    let spec = get_variant(r)?.spec();
    let gc = get_gc_batch(r, &spec)?;
    let n = gc.len();
    let client_labels = get_label_vec(r)?;
    let want_labels = n.checked_mul(spec.n_client_inputs).unwrap_or(usize::MAX);
    ensure!(
        client_labels.len() == want_labels,
        "client label arena {} != {n} x {}",
        client_labels.len(),
        spec.n_client_inputs
    );
    let r_v = get_fp_vec(r)?;
    ensure!(r_v.len() == n, "r_v column {} != {n}", r_v.len());
    let r_out = get_fp_vec(r)?;
    ensure!(r_out.len() == n, "r_out column {} != {n}", r_out.len());
    let triples = get_triples(r)?;
    let want_triples = if spec.uses_beaver() { n } else { 0 };
    ensure!(triples.len() == want_triples, "triples {} != {want_triples}", triples.len());
    let offline_bytes = r.u64()?;
    Ok(ClientReluMaterial { spec, gc, client_labels, r_v, r_out, triples, offline_bytes })
}

/// Encode one layer's server-side ReLU material.
pub fn put_server_relu(w: &mut Writer, m: &ServerReluMaterial) {
    put_variant(w, m.spec.variant);
    put_encoding_batch(w, &m.encodings);
    w.bool_vec(&m.output_decode);
    put_triples(w, &m.triples);
}

pub fn get_server_relu(r: &mut Reader) -> Result<ServerReluMaterial> {
    let spec = get_variant(r)?.spec();
    let encodings = get_encoding_batch(r, &spec)?;
    let n = encodings.len();
    let output_decode = r.bool_vec()?;
    let want_decode = n.checked_mul(spec.n_outputs).unwrap_or(usize::MAX);
    ensure!(
        output_decode.len() == want_decode,
        "decode buffer {} != {n} x {}",
        output_decode.len(),
        spec.n_outputs
    );
    let triples = get_triples(r)?;
    let want_triples = if spec.uses_beaver() { n } else { 0 };
    ensure!(triples.len() == want_triples, "triples {} != {want_triples}", triples.len());
    Ok(ServerReluMaterial { spec, encodings, output_decode, triples })
}

// ------------------------------------------------- layer-granular units

/// Encode one ReLU layer of one session — both parties' halves, keyed by
/// the model fingerprint, layer index, and session sequence number. This
/// is the payload of a `LayerBatch` frame: the unit layer-granular
/// streaming ships, sized by the *layer*, never the session.
pub fn put_layer_batch(
    w: &mut Writer,
    fingerprint: u64,
    layer_idx: u32,
    seq: u64,
    cm: &ClientReluMaterial,
    sm: &ServerReluMaterial,
) {
    w.u64(fingerprint);
    w.u32(layer_idx);
    w.u64(seq);
    put_client_relu(w, cm);
    put_server_relu(w, sm);
}

/// Decode a `LayerBatch` payload against a plan: the layer index must
/// name a ReLU layer of `plan`, and both halves must match the plan's
/// variant and that layer's width. The leading model fingerprint is
/// returned for the *caller* to check against the plan it resolved —
/// multi-model receivers read the fingerprint first (it is the payload's
/// first 8 bytes), pick the plan it names, then decode against it.
pub fn get_layer_batch(
    r: &mut Reader,
    plan: &NetworkPlan,
) -> Result<(u64, u32, u64, ClientReluMaterial, ServerReluMaterial)> {
    let fingerprint = r.u64()?;
    let layer_idx = r.u32()?;
    let li = layer_idx as usize;
    ensure!(
        li < plan.n_relu_layers(),
        "layer index {li} out of range ({} relu layers)",
        plan.n_relu_layers()
    );
    let seq = r.u64()?;
    let want_n =
        plan.linears.get(li).with_context(|| format!("layer {li} out of plan"))?.out_dim();
    let cm = get_client_relu(r)?;
    ensure!(
        cm.variant() == plan.variant,
        "layer {li}: client variant {:?} != plan {:?}",
        cm.variant(),
        plan.variant
    );
    ensure!(cm.n() == want_n, "layer {li}: {} client ReLUs != {want_n}", cm.n());
    let sm = get_server_relu(r)?;
    ensure!(
        sm.variant() == plan.variant,
        "layer {li}: server variant {:?} != plan {:?}",
        sm.variant(),
        plan.variant
    );
    ensure!(sm.n() == want_n, "layer {li}: {} server ReLUs != {want_n}", sm.n());
    Ok((fingerprint, layer_idx, seq, cm, sm))
}

/// Encode a session's linear-precompute spine (the payload of a `Spine`
/// frame): the model fingerprint, then per linear layer the client mask,
/// client x-share, and server blind, plus the modeled HE byte ledger.
pub fn put_spine(w: &mut Writer, fingerprint: u64, seq: u64, spine: &LinearSpine) {
    w.u64(fingerprint);
    w.u64(seq);
    w.u64(spine.slots.len() as u64);
    for slot in &spine.slots {
        put_fp_vec(w, &slot.r);
        put_fp_vec(w, &slot.x_share);
        put_fp_vec(w, &slot.s);
    }
    w.u64(spine.he_bytes);
}

/// Decode a `Spine` payload, validating every slot's dimensions against
/// the plan's layer chain. As with [`get_layer_batch`], the leading
/// fingerprint is returned for the caller to bind to the plan it chose.
pub fn get_spine(r: &mut Reader, plan: &NetworkPlan) -> Result<(u64, u64, LinearSpine)> {
    let fingerprint = r.u64()?;
    let seq = r.u64()?;
    let n = r.len_u64()?;
    ensure!(n == plan.linears.len(), "spine {n} slots != plan {}", plan.linears.len());
    let mut slots = Vec::with_capacity(n);
    for (li, op) in plan.linears.iter().enumerate() {
        let mask = get_fp_vec(r)?;
        ensure!(
            mask.len() == op.in_dim(),
            "spine slot {li}: mask dim {} != {}",
            mask.len(),
            op.in_dim()
        );
        let x_share = get_fp_vec(r)?;
        ensure!(
            x_share.len() == op.out_dim(),
            "spine slot {li}: share dim {} != {}",
            x_share.len(),
            op.out_dim()
        );
        let s = get_fp_vec(r)?;
        ensure!(
            s.len() == op.out_dim(),
            "spine slot {li}: blind dim {} != {}",
            s.len(),
            op.out_dim()
        );
        slots.push(LinearSlot { r: mask, x_share, s });
    }
    let he_bytes = r.u64()?;
    Ok((fingerprint, seq, LinearSpine { slots, he_bytes }))
}

// --------------------------------------------------------------- manifest

/// Identity of a served plan, exchanged during the dealer handshake.
/// Covers variant, layer dimensions, rescale schedule, and a behavioral
/// **weight digest**: [`crate::protocol::linear::LinearOp`] is
/// deliberately opaque, so instead of hashing raw weights each layer is
/// probed with a fixed pseudorandom input vector and the output is
/// hashed — a mutated weight changes its row's probe response with
/// overwhelming probability, so mismatched weights are a *handshake
/// error*, never silently wrong material. The digest is folded into the
/// fingerprint, which therefore keys complete model identity (the
/// registry/pool/wire key): same architecture, different weights ⇒
/// different model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionManifest {
    pub variant: ReluVariant,
    /// `(in_dim, out_dim)` of each linear layer, in order.
    pub dims: Vec<(u32, u32)>,
    pub rescale_bits: Vec<u32>,
    /// FNV-1a over each linear layer's response to a fixed probe vector.
    pub weight_hash: u64,
    /// FNV-1a over the encoded body (weight digest included) — the model
    /// key used by the registry, the pool shards, and the wire round.
    pub fingerprint: u64,
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Behavioral weight digest: hash every layer's response to a fixed
/// seeded probe vector (one matvec per linear layer).
fn weight_digest(plan: &NetworkPlan) -> u64 {
    let mut w = Writer::new();
    for (li, op) in plan.linears.iter().enumerate() {
        let mut rng =
            crate::util::Rng::new(0x5747_D161 ^ (li as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let probe: Vec<Fp> =
            (0..op.in_dim()).map(|_| crate::field::random_fp(&mut rng)).collect();
        for y in op.apply(&probe) {
            w.u32(y.raw() as u32);
        }
    }
    fnv1a64(&w.buf)
}

impl SessionManifest {
    pub fn of_plan(plan: &NetworkPlan) -> Self {
        let dims =
            plan.linears.iter().map(|l| (l.in_dim() as u32, l.out_dim() as u32)).collect();
        let mut m = SessionManifest {
            variant: plan.variant,
            dims,
            rescale_bits: plan.rescale_bits.clone(),
            weight_hash: weight_digest(plan),
            fingerprint: 0,
        };
        let mut w = Writer::new();
        m.put_body(&mut w);
        m.fingerprint = fnv1a64(&w.buf);
        m
    }

    /// `true` when two manifests describe the same architecture (variant,
    /// dims, rescale schedule), whatever their weights — the distinction
    /// that turns a handshake mismatch into a *weight digest* error
    /// instead of an unknown-model error.
    pub fn same_architecture(&self, other: &SessionManifest) -> bool {
        self.variant == other.variant
            && self.dims == other.dims
            && self.rescale_bits == other.rescale_bits
    }

    fn put_body(&self, w: &mut Writer) {
        put_variant(w, self.variant);
        w.u64(self.dims.len() as u64);
        for &(i, o) in &self.dims {
            w.u32(i);
            w.u32(o);
        }
        w.u64(self.rescale_bits.len() as u64);
        for &b in &self.rescale_bits {
            w.u32(b);
        }
        w.u64(self.weight_hash);
    }

    /// Encode with the `MAGIC | VERSION` preamble (the handshake payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u16(VERSION);
        self.put_body(&mut w);
        w.u64(self.fingerprint);
        w.buf
    }

    /// Decode and validate a handshake payload.
    pub fn decode(bytes: &[u8]) -> Result<SessionManifest> {
        let mut r = Reader::new(bytes);
        let magic = r.u32()?;
        ensure!(magic == MAGIC, "bad magic {magic:#010x}");
        let version = r.u16()?;
        ensure!(version == VERSION, "unsupported wire version {version} (want {VERSION})");
        let body_start = bytes.len() - r.remaining();
        let variant = get_variant(&mut r)?;
        let n_dims = r.len_u64()?;
        let raw = r.take(n_dims.checked_mul(8).context("dims length overflows")?)?;
        let dims: Vec<(u32, u32)> = raw
            .chunks_exact(8)
            .map(|c| {
                let (i, o) = c.split_at(4);
                (le_u32(i), le_u32(o))
            })
            .collect();
        let n_rescale = r.len_u64()?;
        let raw = r.take(n_rescale.checked_mul(4).context("rescale length overflows")?)?;
        let rescale_bits: Vec<u32> = raw.chunks_exact(4).map(le_u32).collect();
        let weight_hash = r.u64()?;
        let body_end = bytes.len() - r.remaining();
        let fingerprint = r.u64()?;
        ensure!(r.remaining() == 0, "trailing bytes after manifest");
        let body = bytes.get(body_start..body_end).context("manifest body range")?;
        let want = fnv1a64(body);
        ensure!(fingerprint == want, "manifest fingerprint mismatch");
        Ok(SessionManifest { variant, dims, rescale_bits, weight_hash, fingerprint })
    }
}

/// Encode a handshake manifest set: `MAGIC | VERSION | count | (len |
/// manifest) × count`. Each entry is a full [`SessionManifest::encode`]
/// payload, so every per-manifest validation (magic, version,
/// fingerprint-covers-body) applies to every set member on decode.
/// Fallible since the count and per-entry length fields are `u32` (lint
/// rule R5: length fields are checked, never truncated with `as`).
pub fn encode_manifest_set(set: &[SessionManifest]) -> Result<Vec<u8>> {
    let mut w = Writer::new();
    w.u32(MAGIC);
    w.u16(VERSION);
    let count = u32::try_from(set.len()).context("manifest count overflows u32")?;
    w.u32(count);
    for m in set {
        let bytes = m.encode();
        w.u32(u32::try_from(bytes.len()).context("manifest length overflows u32")?);
        w.buf.extend_from_slice(&bytes);
    }
    Ok(w.buf)
}

/// Decode and validate a handshake manifest set (at least one manifest,
/// no duplicate fingerprints, nothing trailing).
pub fn decode_manifest_set(bytes: &[u8]) -> Result<Vec<SessionManifest>> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    ensure!(magic == MAGIC, "bad magic {magic:#010x}");
    let version = r.u16()?;
    ensure!(version == VERSION, "unsupported wire version {version} (want {VERSION})");
    let count = r.u32()?;
    ensure!((1..=MAX_MANIFESTS).contains(&count), "bad manifest count {count}");
    let mut set = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let len = r.u32()? as usize;
        let entry = r.take(len)?;
        let m = SessionManifest::decode(entry)?;
        ensure!(
            set.iter().all(|prev: &SessionManifest| prev.fingerprint != m.fingerprint),
            "duplicate fingerprint {:#018x} in manifest set",
            m.fingerprint
        );
        set.push(m);
    }
    ensure!(r.remaining() == 0, "trailing bytes after manifest set");
    Ok(set)
}

// ---------------------------------------------------------------- session

const LAYER_LINEAR: u8 = 0;
const LAYER_RELU: u8 = 1;

/// Encode a fully-dealt session (both parties' nets + the offline byte
/// ledger) as one payload.
pub fn encode_session(s: &Session) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(s.client.layers.len() as u64);
    for layer in &s.client.layers {
        match layer {
            ClientLayer::Linear { r, x_share } => {
                w.u8(LAYER_LINEAR);
                put_fp_vec(&mut w, r);
                put_fp_vec(&mut w, x_share);
            }
            ClientLayer::Relu(m) => {
                w.u8(LAYER_RELU);
                put_client_relu(&mut w, m);
            }
        }
    }
    w.u64(s.server.layers.len() as u64);
    for layer in &s.server.layers {
        match layer {
            ServerLayer::Linear { s: blind, .. } => {
                w.u8(LAYER_LINEAR);
                put_fp_vec(&mut w, blind);
            }
            ServerLayer::Relu { mat, rescale } => {
                w.u8(LAYER_RELU);
                put_server_relu(&mut w, mat);
                w.u32(*rescale);
            }
        }
    }
    w.u64(s.offline_bytes);
    w.buf
}

/// Decode a session against the local plan: linear ops are re-attached
/// from `plan` by position, and every layer's shape is validated against
/// the plan's dimension chain.
pub fn decode_session(bytes: &[u8], plan: &NetworkPlan) -> Result<Session> {
    let n_linears = plan.linears.len();
    ensure!(n_linears > 0, "plan has no layers");
    let want_layers = 2 * n_linears - 1;
    let mut r = Reader::new(bytes);

    // --- Client net: Linear, Relu, Linear, ..., Linear. ---
    let n_client = r.len_u64()?;
    ensure!(n_client == want_layers, "client net {n_client} layers != plan {want_layers}");
    let mut client_layers = Vec::with_capacity(want_layers);
    for idx in 0..n_client {
        let tag = r.u8()?;
        let li = idx / 2;
        let op = plan.linears.get(li).with_context(|| format!("layer {li} out of plan"))?;
        if idx % 2 == 0 {
            ensure!(tag == LAYER_LINEAR, "client layer {idx}: expected linear tag, got {tag}");
            let mask = get_fp_vec(&mut r)?;
            ensure!(
                mask.len() == op.in_dim(),
                "client linear {li}: mask dim {} != {}",
                mask.len(),
                op.in_dim()
            );
            let x_share = get_fp_vec(&mut r)?;
            ensure!(
                x_share.len() == op.out_dim(),
                "client linear {li}: share dim {} != {}",
                x_share.len(),
                op.out_dim()
            );
            client_layers.push(ClientLayer::Linear { r: mask, x_share });
        } else {
            ensure!(tag == LAYER_RELU, "client layer {idx}: expected relu tag, got {tag}");
            let m = get_client_relu(&mut r)?;
            ensure!(
                m.variant() == plan.variant,
                "client relu {li}: variant {:?} != plan {:?}",
                m.variant(),
                plan.variant
            );
            ensure!(
                m.n() == op.out_dim(),
                "client relu {li}: {} ReLUs != {}",
                m.n(),
                op.out_dim()
            );
            client_layers.push(ClientLayer::Relu(Box::new(m)));
        }
    }

    // --- Server net: same alternation, ops re-attached from the plan. ---
    let n_server = r.len_u64()?;
    ensure!(n_server == want_layers, "server net {n_server} layers != plan {want_layers}");
    let mut server_layers = Vec::with_capacity(want_layers);
    for idx in 0..n_server {
        let tag = r.u8()?;
        let li = idx / 2;
        let op = plan.linears.get(li).with_context(|| format!("layer {li} out of plan"))?;
        if idx % 2 == 0 {
            ensure!(tag == LAYER_LINEAR, "server layer {idx}: expected linear tag, got {tag}");
            let blind = get_fp_vec(&mut r)?;
            ensure!(
                blind.len() == op.out_dim(),
                "server linear {li}: blind dim {} != {}",
                blind.len(),
                op.out_dim()
            );
            server_layers.push(ServerLayer::Linear { op: std::sync::Arc::clone(op), s: blind });
        } else {
            ensure!(tag == LAYER_RELU, "server layer {idx}: expected relu tag, got {tag}");
            let mat = get_server_relu(&mut r)?;
            ensure!(
                mat.variant() == plan.variant,
                "server relu {li}: variant {:?} != plan {:?}",
                mat.variant(),
                plan.variant
            );
            ensure!(
                mat.n() == op.out_dim(),
                "server relu {li}: {} ReLUs != {}",
                mat.n(),
                op.out_dim()
            );
            let rescale = r.u32()?;
            ensure!(
                rescale == plan.rescale_of(li),
                "server relu {li}: rescale {rescale} != plan {}",
                plan.rescale_of(li)
            );
            server_layers.push(ServerLayer::Relu { mat: Box::new(mat), rescale });
        }
    }

    let offline_bytes = r.u64()?;
    ensure!(r.remaining() == 0, "trailing bytes after session");
    Ok(Session {
        client: ClientNet { layers: client_layers },
        server: ServerNet { layers: server_layers },
        offline_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::offline::{circa_variant, offline_relu_layer};
    use crate::util::Rng;

    fn all_variants() -> Vec<ReluVariant> {
        vec![
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            ReluVariant::StochasticSign { mode: FaultMode::NegPass },
            circa_variant(0),
            circa_variant(8),
            circa_variant(12),
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass },
        ]
    }

    #[test]
    fn variant_roundtrip() {
        for v in all_variants() {
            let mut w = Writer::new();
            put_variant(&mut w, v);
            assert_eq!(w.buf.len(), 6);
            let got = get_variant(&mut Reader::new(&w.buf)).unwrap();
            assert_eq!(got, v, "{v:?}");
        }
    }

    #[test]
    fn variant_rejects_garbage() {
        let cases: [&[u8]; 6] = [
            &[9, 0, 0, 0, 0, 0],  // unknown tag
            &[2, 7, 0, 0, 0, 0],  // unknown mode
            &[0, 1, 0, 0, 0, 0],  // non-canonical mode for baseline
            &[1, 0, 5, 0, 0, 0],  // non-canonical k for naive sign
            &[3, 0, 40, 0, 0, 0], // k wider than the field
            &[3, 0],              // truncated
        ];
        for bad in cases {
            assert!(get_variant(&mut Reader::new(bad)).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn layer_material_roundtrip_is_bit_identical() {
        for (i, variant) in all_variants().into_iter().enumerate() {
            let mut rng = Rng::new(500 + i as u64);
            let xc: Vec<Fp> =
                (0..9).map(|_| crate::field::random_fp(&mut rng)).collect();
            let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);

            let mut w = Writer::new();
            put_client_relu(&mut w, &cm);
            let got = get_client_relu(&mut Reader::new(&w.buf)).unwrap();
            assert_eq!(got.spec, cm.spec, "{variant:?}");
            assert_eq!(got.gc.tables(), cm.gc.tables(), "{variant:?} tables");
            assert_eq!(got.gc.output_decode(), cm.gc.output_decode(), "{variant:?} decode");
            assert_eq!(got.client_labels, cm.client_labels, "{variant:?} labels");
            assert_eq!(got.r_v, cm.r_v, "{variant:?} r_v");
            assert_eq!(got.r_out, cm.r_out, "{variant:?} r_out");
            assert_eq!(got.offline_bytes, cm.offline_bytes, "{variant:?} bytes");
            assert_eq!(got.triples.len(), cm.triples.len());
            for (a, b) in got.triples.iter().zip(&cm.triples) {
                assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab));
            }

            let mut w = Writer::new();
            put_server_relu(&mut w, &sm);
            let got = get_server_relu(&mut Reader::new(&w.buf)).unwrap();
            assert_eq!(got.encodings.label0(), sm.encodings.label0(), "{variant:?} label0");
            assert_eq!(
                got.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
                sm.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
                "{variant:?} deltas"
            );
            assert_eq!(got.output_decode, sm.output_decode, "{variant:?} server decode");
        }
    }

    #[test]
    fn layer_batch_and_spine_roundtrip() {
        use crate::protocol::linear::{LinearOp, Matrix};
        use crate::protocol::server::{deal_relu_layer_mt, deal_spine, session_rng};
        use std::sync::Arc;
        let mut rng = Rng::new(8);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(4, 5, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        let plan =
            NetworkPlan { linears, variant: circa_variant(8), rescale_bits: vec![2, 1] };

        let fp = SessionManifest::of_plan(&plan).fingerprint;
        let (cm, sm) = deal_relu_layer_mt(&plan, &mut session_rng(0xFACE, 3), 1, 1);
        let mut w = Writer::new();
        put_layer_batch(&mut w, fp, 1, 3, &cm, &sm);
        let mut r = Reader::new(&w.buf);
        let (fp2, li, seq, c2, s2) = get_layer_batch(&mut r, &plan).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!((fp2, li, seq), (fp, 1, 3));
        assert_eq!(c2.gc.tables(), cm.gc.tables());
        assert_eq!(c2.client_labels, cm.client_labels);
        assert_eq!(c2.r_v, cm.r_v);
        assert_eq!(c2.r_out, cm.r_out);
        assert_eq!(s2.encodings.label0(), sm.encodings.label0());
        assert_eq!(s2.output_decode, sm.output_decode);

        // Out-of-range layer index is rejected.
        let mut w2 = Writer::new();
        put_layer_batch(&mut w2, fp, 7, 3, &cm, &sm);
        assert!(get_layer_batch(&mut Reader::new(&w2.buf), &plan).is_err());

        let spine = deal_spine(&plan, &mut session_rng(0xFACE, 3));
        let mut w = Writer::new();
        put_spine(&mut w, fp, 3, &spine);
        let mut r = Reader::new(&w.buf);
        let (fp2, seq, sp2) = get_spine(&mut r, &plan).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!((fp2, seq), (fp, 3));
        assert_eq!(sp2.he_bytes, spine.he_bytes);
        assert_eq!(sp2.slots.len(), spine.slots.len());
        for (a, b) in sp2.slots.iter().zip(&spine.slots) {
            assert_eq!(a.r, b.r);
            assert_eq!(a.x_share, b.x_share);
            assert_eq!(a.s, b.s);
        }

        // Truncation errors cleanly, never panics.
        for cut in (0..w.buf.len()).step_by(13) {
            assert!(get_spine(&mut Reader::new(&w.buf[..cut]), &plan).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn manifest_roundtrip_and_magic_version_checks() {
        use crate::protocol::linear::{LinearOp, Matrix};
        use std::sync::Arc;
        let mut rng = Rng::new(3);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(2, 4, 10, &mut rng)),
        ];
        let plan = NetworkPlan {
            linears,
            variant: circa_variant(12),
            rescale_bits: vec![3],
        };
        let m = SessionManifest::of_plan(&plan);
        assert_eq!(m.dims, vec![(6, 4), (4, 2)]);
        assert_ne!(m.weight_hash, 0);
        let bytes = m.encode();
        assert_eq!(SessionManifest::decode(&bytes).unwrap(), m);

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        let err = SessionManifest::decode(&bad).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        // Bad version.
        let mut bad = bytes.clone();
        bad[4] = 0xEE;
        let err = SessionManifest::decode(&bad).unwrap_err();
        assert!(err.to_string().contains("unsupported wire version"), "{err}");

        // Fingerprint covers the body: flip a dim byte.
        let mut bad = bytes.clone();
        bad[14] ^= 0x01;
        assert!(SessionManifest::decode(&bad).is_err());

        // Truncation anywhere must error, never panic.
        for cut in 0..bytes.len() {
            assert!(SessionManifest::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn weight_digest_separates_same_shaped_plans() {
        use crate::protocol::linear::{LinearOp, Matrix};
        use std::sync::Arc;
        let mk = |seed: u64| {
            let mut rng = Rng::new(seed);
            let linears: Vec<Arc<dyn LinearOp>> = vec![
                Arc::new(Matrix::random(4, 6, 10, &mut rng)),
                Arc::new(Matrix::random(2, 4, 10, &mut rng)),
            ];
            NetworkPlan { linears, variant: circa_variant(8), rescale_bits: vec![1] }
        };
        let a = SessionManifest::of_plan(&mk(1));
        let a2 = SessionManifest::of_plan(&mk(1));
        let b = SessionManifest::of_plan(&mk(2));
        assert_eq!(a, a2, "digest is deterministic");
        assert!(a.same_architecture(&b), "same dims/variant/rescale");
        assert_ne!(a.weight_hash, b.weight_hash, "different weights, different digest");
        assert_ne!(a.fingerprint, b.fingerprint, "digest is folded into the fingerprint");
    }

    #[test]
    fn manifest_set_roundtrip_and_guards() {
        use crate::protocol::linear::{LinearOp, Matrix};
        use std::sync::Arc;
        let mk = |seed: u64, variant| {
            let mut rng = Rng::new(seed);
            let linears: Vec<Arc<dyn LinearOp>> = vec![
                Arc::new(Matrix::random(4, 6, 10, &mut rng)),
                Arc::new(Matrix::random(2, 4, 10, &mut rng)),
            ];
            SessionManifest::of_plan(&NetworkPlan::unscaled(linears, variant))
        };
        let a = mk(1, circa_variant(12));
        let b = mk(1, ReluVariant::BaselineRelu);
        let bytes = encode_manifest_set(&[a.clone(), b.clone()]).unwrap();
        let set = decode_manifest_set(&bytes).unwrap();
        assert_eq!(set, vec![a.clone(), b]);

        // Empty sets, duplicates, and truncation are rejected.
        assert!(decode_manifest_set(&encode_manifest_set(&[]).unwrap()).is_err());
        assert!(decode_manifest_set(&encode_manifest_set(&[a.clone(), a]).unwrap()).is_err());
        for cut in (0..bytes.len()).step_by(9) {
            assert!(decode_manifest_set(&bytes[..cut]).is_err(), "cut={cut}");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode_manifest_set(&padded).is_err());
    }

    #[test]
    fn corrupt_material_never_panics() {
        // Byte-flip and truncation sweeps over a valid client-material
        // encoding: decode must return (Ok | Err), never panic. Flips in
        // label payload bytes legitimately decode Ok (labels are opaque);
        // flips in the structural header must not bring the process down.
        let mut rng = Rng::new(77);
        let xc: Vec<Fp> = (0..4).map(|_| crate::field::random_fp(&mut rng)).collect();
        let (cm, sm) = offline_relu_layer(circa_variant(8), &xc, &mut rng);
        let mut w = Writer::new();
        put_client_relu(&mut w, &cm);
        let valid = w.buf;

        for pos in (0..valid.len()).step_by(7) {
            let mut mutated = valid.clone();
            mutated[pos] ^= 0xA5;
            let _ = get_client_relu(&mut Reader::new(&mutated));
        }
        for cut in (0..valid.len()).step_by(11) {
            assert!(get_client_relu(&mut Reader::new(&valid[..cut])).is_err(), "cut={cut}");
        }

        let mut w = Writer::new();
        put_server_relu(&mut w, &sm);
        let valid = w.buf;
        for pos in (0..valid.len()).step_by(7) {
            let mut mutated = valid.clone();
            mutated[pos] ^= 0xA5;
            let _ = get_server_relu(&mut Reader::new(&mutated));
        }
    }
}

//! Wire subsystem: binary codec + framed transport for offline material,
//! and the standalone dealer service.
//!
//! Circa's offline material (garbled sign-test tables, label arenas,
//! Beaver triples) dominates storage and must be produced ahead of time
//! by a dealer and shipped to the serving parties — the 4.7× storage
//! savings of the paper only matter once material crosses a process or
//! machine boundary. Since the layer-batch refactor all ReLU material is
//! contiguous SoA buffers, so this module's codec is memcpy-shaped: a
//! layer goes on the wire as a handful of length-prefixed flat runs.
//!
//! ## Frame layout ([`frame`])
//!
//! ```text
//! MSG_TYPE (1 B) | LEN (4 B le) | payload (LEN B) | CRC32 (4 B le)
//! ```
//!
//! `CRC32` is IEEE 802.3 over the **header and payload** (`MSG_TYPE |
//! LEN | payload`), so a flipped type byte cannot misroute a valid
//! payload between two known message types (a one-time format change —
//! see the [`frame`] module doc). `LEN` is bounded by
//! [`frame::MAX_FRAME_LEN`]; anything larger is rejected before
//! allocation. The byte transport is the [`frame::Channel`] trait:
//! [`frame::MemChannel`] (in-process duplex, tests/demos) or
//! [`frame::TcpChannel`] (blocking `std::net::TcpStream`).
//!
//! Dealer links can additionally be **authenticated** with a pre-shared
//! key ([`frame::Framed::with_psk`]): each frame then carries a trailing
//! 16-byte AES-128-CMAC tag ([`auth`]) over the same `MSG_TYPE | LEN |
//! payload` bytes. The CRC stays (cheap corruption triage); the tag is
//! what makes forgery infeasible. Key disagreement — either direction —
//! fails the link closed at the first frame, i.e. at the handshake. The
//! dealer remains *trusted* for material correctness (it knows every
//! secret it deals); the PSK authenticates the transport between hosts,
//! not the dealing party — see [`auth`] for the full threat-model note.
//!
//! ## Message types ([`frame::MsgType`])
//!
//! | type          | dir            | payload                                |
//! |---------------|----------------|----------------------------------------|
//! | Hello         | both           | manifest set (one per served model)    |
//! | Request       | coord → dealer | model fingerprint, `u32` count         |
//! | Session       | dealer → coord | one encoded session                    |
//! | RequestLayers | coord → dealer | fingerprint, kind, layer, seqs         |
//! | LayerBatch    | dealer → coord | fingerprint + one session's ReLU layer |
//! | Spine         | dealer → coord | fingerprint + one session's precompute |
//! | Bye           | coord → dealer | empty                                  |
//! | Error         | dealer → coord | UTF-8 rejection message                |
//! | ClientHello   | both           | client protocol handshake ([`crate::net::proto`]) |
//! | Infer         | client → coord | req id, fingerprint, input vector      |
//! | Logits        | coord → client | req id, logits, serving stats          |
//! | Busy          | coord → client | req id, retry-after hint, reason       |
//!
//! `Request`/`Session` is the legacy whole-session round;
//! `RequestLayers`/`LayerBatch`/`Spine` is the layer-granular streaming
//! round ([`dealer`]), which keeps the largest frame bounded by the
//! largest single layer batch or the linear spine (masks and blinds
//! only — no GC material, so orders of magnitude below the session) —
//! giant models never need GiB-scale frames. Every round is
//! **model-addressed**: the requested fingerprint picks the plan, the
//! answered unit carries the fingerprint it was dealt for, and an
//! unknown fingerprint is answered with an `Error` frame (the
//! connection survives; handshake errors are fatal).
//!
//! `ClientHello`/`Infer`/`Logits`/`Busy` belong to the client-facing
//! serving tier: same frame layout, different port and payload schema.
//! Their payloads (and the `Bye`/`Error` reuse on that link) live in
//! [`crate::net::proto`]; the nonblocking server side re-assembles
//! frames incrementally with [`crate::net::frames::FrameBuf`].
//!
//! ## Versioning rules
//!
//! The `MAGIC | VERSION` preamble rides in the `Hello` manifest set
//! once per connection; material payloads carry no per-message version.
//! Any change to a payload layout in [`codec`] requires bumping
//! [`codec::VERSION`]; decoders reject other versions outright.
//! Evolution happens behind new message types and the version field;
//! the one reshaping of the frame itself (CRC coverage) is documented
//! in [`frame`] and rode a `VERSION` bump, and `VERSION` 3 is the
//! one-time multi-model reshape (manifest-set `Hello`, weight digest in
//! the manifest body, fingerprint-led `Request`/`RequestLayers`/
//! `LayerBatch`/`Spine` payloads).
//!
//! ## Trust model
//!
//! Everything read off a channel is untrusted until decoded: lengths
//! are overflow-checked against the remaining buffer before allocation,
//! field elements are range-checked, deltas must carry their color bit,
//! and layer shapes must match the local plan. Decoders return
//! [`crate::util::error::Result`] — corrupt input never panics.
//!
//! These properties are enforced statically, not just by convention:
//! the repo lint (`cargo run -p circa-lint -- check`, blocking in CI)
//! forbids panicking calls, bare indexing, and truncating length casts
//! in the decode paths here, and checks the wire constants for
//! duplicate values and missing decoder arms. See `docs/INVARIANTS.md`
//! for the full rule statements and the waiver policy.

pub mod auth;
pub mod codec;
pub mod dealer;
pub mod frame;

pub use auth::{parse_psk_hex, Cmac};
pub use codec::{
    decode_manifest_set, decode_session, encode_manifest_set, encode_session, SessionManifest,
};
pub use dealer::{
    spawn_mem_dealer, spawn_mem_dealer_multi, spawn_tcp_dealer, spawn_tcp_dealer_multi,
    spawn_tcp_dealer_multi_psk, DealerHandle, RemoteDealer,
};
pub use frame::{Channel, Framed, MemChannel, MsgType, TcpChannel};

//! The standalone dealer: garbles offline material on demand for **any
//! registered model** and streams it to a coordinator over the framed
//! transport — whole sessions (legacy round) or single layers
//! (streaming round), every unit addressed by model fingerprint.
//!
//! Protocol (one connection):
//!
//! ```text
//! coordinator → dealer : Hello          (manifest set of every local model)
//! dealer      → coord  : Hello          (its own manifest set) — or Error + close
//!
//! ── legacy whole-session round ──────────────────────────────────────
//! coordinator → dealer : Request        (fingerprint u64 | u32 session count)
//! dealer      → coord  : Session × count (one encoded session each)
//!
//! ── layer-granular round ────────────────────────────────────────────
//! coordinator → dealer : RequestLayers  (fingerprint u64 | kind u8
//!                                        | layer u32 | count u32
//!                                        | seq u64 × count)
//! dealer      → coord  : LayerBatch × count   (kind = REQ_RELU_LAYER)
//!              — or —  : Spine × count        (kind = REQ_SPINE)
//!
//! ...                    (rounds of either kind, freely mixed, for any
//!                         registered model)
//! coordinator → dealer : Bye
//! ```
//!
//! The handshake compares manifest **sets**: every model the coordinator
//! names must be registered on the dealer with an *equal* manifest —
//! variant, layer dims, rescale schedule, and the behavioral weight
//! digest ([`SessionManifest::weight_hash`]) all match, or the
//! connection is rejected before any material moves. A dealer restarted
//! with mutated weights is therefore a handshake error, never silently
//! wrong material. The dealer may serve *more* models than one
//! coordinator asks about (its registry is a superset), which is what
//! lets one dealer fleet feed heterogeneous coordinator pools.
//!
//! A `Request`/`RequestLayers` naming a fingerprint the dealer does not
//! serve is answered with an `Error` frame and the connection stays up
//! (the coordinator may race a registration or be misconfigured for one
//! model only); malformed frames and protocol violations still tear the
//! connection down.
//!
//! The legacy round deals with
//! [`crate::protocol::server::offline_network_mt`] from the connection's
//! sequential RNG stream. The layer round is **seq-addressed per
//! model**: each requested unit is dealt from
//! [`session_rng`]`(entry.base_seed, seq)` — a pure function of that
//! model's base seed (its registry entry) and the session sequence
//! number — via [`crate::protocol::server::deal_relu_layer_mt`] /
//! [`crate::protocol::server::deal_spine`]. Per-model base seeds keep
//! two models' seq spaces disjoint even though both count sessions
//! 0, 1, 2, …, and the per-layer forked session schedule makes a
//! standalone layer bit-identical to the same layer inside a
//! whole-session deal from the same session RNG, so a coordinator can
//! assemble sessions from independently fetched layers (across any
//! number of connections to dealers sharing the registry) and the
//! largest frame on the wire is bounded by the largest single layer
//! batch or the spine, never the session.
//!
//! ## Dealer fleets
//!
//! That seq-addressed purity is what makes a dealer **fleet** work:
//! since `(model, layer, seq)` fully determines the unit's bytes, any
//! dealer sharing the registry can serve any unit, and the
//! coordinator's pool ([`crate::coordinator::pool`]) is free to
//! partition claimed seq-ranges across however many dealer links it
//! holds, steal outstanding claims from a slow link, and re-issue a
//! dead link's claims elsewhere — the staged bank is bit-identical
//! regardless of which dealer produced which seq. A link in this module
//! is one connection; fleet membership, per-link health (reconnect,
//! backoff, quarantine), and claim accounting live in the pool's fleet
//! scheduler. Each dealer process is just `spawn_tcp_dealer_multi` on
//! its own host: dealers never talk to each other and hold no state a
//! restart could lose.
//!
//! ## Trust model: trusted dealer, authenticated link
//!
//! The dealer is *trusted by construction* in Circa's deployment model:
//! it generates every secret it deals (GC label pairs, Beaver triples,
//! mask shares), so there is nothing to hide from it and no way to
//! verify its output cryptographically — correctness is pinned instead
//! by the manifest handshake (architecture + behavioral weight digest)
//! and the seq/fingerprint checks at staging. What is **not** assumed
//! trusted is the network between hosts: dealer links accept an
//! optional pre-shared key ([`spawn_tcp_dealer_multi_psk`],
//! [`RemoteDealer::connect_tcp_psk`]) that switches the framing to
//! AES-128-CMAC-tagged frames ([`super::auth`]) so an on-path attacker
//! can neither inject nor tamper with material; key disagreement fails
//! the handshake. The PSK authenticates the transport, not the party —
//! removing the trusted-dealer assumption itself (OT-based label
//! transfer) is a separate, per-model threat-model axis (see ROADMAP).

use super::codec::{self, SessionManifest};
use super::frame::{Channel, Framed, MemChannel, MsgType, TcpChannel};
use crate::coordinator::pool::Session;
use crate::coordinator::registry::ModelRegistry;
use crate::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::protocol::server::{
    deal_relu_layer_mt, deal_spine, session_rng, LinearSpine, NetworkPlan,
};
use crate::util::bytes::{Reader, Writer};
use crate::util::error::{Context, Result};
use crate::util::Rng;
use crate::{bail, ensure};
use crate::net::accept::{stop_nudge, PollingListener};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Upper bound on sessions per Request (keeps a rogue coordinator from
/// pinning a dealer thread forever).
pub const MAX_SESSIONS_PER_REQUEST: u32 = 4096;

/// Upper bound on units per RequestLayers round.
pub const MAX_UNITS_PER_REQUEST: u32 = 4096;

/// RequestLayers kind: deal ReLU layer `layer` of each listed seq.
pub const REQ_RELU_LAYER: u8 = 0;

/// RequestLayers kind: deal the linear-precompute spine of each listed
/// seq (`layer` must be 0).
pub const REQ_SPINE: u8 = 1;

/// Deal one full session (both parties' nets) from the dealer's RNG on
/// one thread.
pub fn deal_session(plan: &NetworkPlan, rng: &mut Rng) -> Session {
    deal_session_mt(plan, rng, 1)
}

/// [`deal_session`] with the per-layer garble columns split across up to
/// `deal_threads` threads (the column-wise schedule in
/// [`crate::protocol::offline`]). Bit-identical output for every thread
/// count, so a multi-core dealer ships exactly what an inline
/// single-threaded deal from the same seed would.
pub fn deal_session_mt(plan: &NetworkPlan, rng: &mut Rng, deal_threads: usize) -> Session {
    let (client, server, offline_bytes) =
        crate::protocol::server::offline_network_mt(plan, rng, deal_threads);
    Session { client, server, offline_bytes }
}

/// Check that `wanted` appears (as an equal manifest) in `offered`. The
/// failure distinguishes *weight-digest* mismatches — same architecture,
/// different weights — from entirely unknown models, so an operator
/// pointing a coordinator at a dealer with stale weights sees exactly
/// that in the handshake error.
fn manifest_covered(wanted: &SessionManifest, offered: &[SessionManifest]) -> Result<()> {
    if offered.iter().any(|m| m == wanted) {
        return Ok(());
    }
    if let Some(m) = offered.iter().find(|m| m.same_architecture(wanted)) {
        bail!(
            "weight digest mismatch for plan {:#018x}: peer weight hash {:#018x} != \
             local {:#018x} (same architecture, different weights)",
            wanted.fingerprint,
            m.weight_hash,
            wanted.weight_hash
        );
    }
    bail!(
        "peer serves no plan with fingerprint {:#018x} ({} plans offered)",
        wanted.fingerprint,
        offered.len()
    )
}

/// Serve one dealer connection until `Bye` or peer close, dealing each
/// unit across up to `deal_threads` threads from any model in
/// `registry`. Legacy `Request` rounds draw from `rng` (the connection's
/// sequential stream); `RequestLayers` rounds are seq-addressed from the
/// named model's registry base seed, so every connection to dealers
/// sharing a registry serves mutually consistent layers. Unknown
/// fingerprints in a round are answered with an `Error` frame (the
/// connection survives); returns `Ok` on an orderly goodbye, `Err` on
/// protocol violations or transport failure (callers serving many
/// connections just log and move on).
pub fn serve_connection(
    mut framed: Framed,
    registry: &ModelRegistry,
    rng: &mut Rng,
    deal_threads: usize,
) -> Result<()> {
    ensure!(!registry.is_empty(), "dealer registry is empty");
    let local_set = registry.manifests();
    let hello = framed.recv()?;
    ensure!(hello.msg_type == MsgType::Hello, "expected Hello, got {:?}", hello.msg_type);
    match codec::decode_manifest_set(&hello.payload) {
        Ok(remotes) => {
            if let Err(e) = remotes.iter().try_for_each(|m| manifest_covered(m, &local_set)) {
                let msg = format!("plan set mismatch: {e}");
                let _ = framed.send(MsgType::Error, msg.as_bytes());
                bail!("{msg}");
            }
            framed.send(MsgType::Hello, &codec::encode_manifest_set(&local_set)?)?;
        }
        Err(e) => {
            let _ = framed.send(MsgType::Error, e.to_string().as_bytes());
            return Err(e);
        }
    }

    loop {
        let frame = framed.recv()?;
        match frame.msg_type {
            MsgType::Request => {
                let mut r = Reader::new(&frame.payload);
                let fp = r.u64()?;
                let count = r.u32()?;
                ensure!(
                    (1..=MAX_SESSIONS_PER_REQUEST).contains(&count),
                    "bad session count {count}"
                );
                let Some(entry) = registry.get(fp) else {
                    let msg = format!("unknown model fingerprint {fp:#018x}");
                    framed.send(MsgType::Error, msg.as_bytes())?;
                    continue;
                };
                for _ in 0..count {
                    let session = deal_session_mt(&entry.plan, rng, deal_threads);
                    framed.send(MsgType::Session, &codec::encode_session(&session))?;
                }
            }
            MsgType::RequestLayers => {
                let mut r = Reader::new(&frame.payload);
                let fp = r.u64()?;
                let kind = r.u8()?;
                let layer = r.u32()? as usize;
                let count = r.u32()?;
                ensure!(
                    (1..=MAX_UNITS_PER_REQUEST).contains(&count),
                    "bad unit count {count}"
                );
                let mut seqs = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    seqs.push(r.u64()?);
                }
                ensure!(r.remaining() == 0, "trailing bytes in RequestLayers");
                let Some(entry) = registry.get(fp) else {
                    let msg = format!("unknown model fingerprint {fp:#018x}");
                    framed.send(MsgType::Error, msg.as_bytes())?;
                    continue;
                };
                let (plan, base_seed) = (&entry.plan, entry.base_seed);
                match kind {
                    REQ_RELU_LAYER => {
                        ensure!(
                            layer < plan.n_relu_layers(),
                            "layer {layer} out of range ({} relu layers)",
                            plan.n_relu_layers()
                        );
                        for seq in seqs {
                            let (cm, sm) = deal_relu_layer_mt(
                                plan,
                                &mut session_rng(base_seed, seq),
                                layer,
                                deal_threads,
                            );
                            let mut w = Writer::new();
                            codec::put_layer_batch(&mut w, fp, layer as u32, seq, &cm, &sm);
                            framed.send(MsgType::LayerBatch, &w.buf)?;
                        }
                    }
                    REQ_SPINE => {
                        ensure!(layer == 0, "spine request names layer {layer}");
                        for seq in seqs {
                            let spine = deal_spine(plan, &mut session_rng(base_seed, seq));
                            let mut w = Writer::new();
                            codec::put_spine(&mut w, fp, seq, &spine);
                            framed.send(MsgType::Spine, &w.buf)?;
                        }
                    }
                    other => bail!("unknown RequestLayers kind {other}"),
                }
            }
            MsgType::Bye => return Ok(()),
            other => bail!("unexpected {other:?} frame"),
        }
    }
}

/// Coordinator-side handle to a connected dealer. Holds the local
/// [`ModelRegistry`]; every fetch names a model fingerprint and every
/// answered unit is decoded against the plan *its own* fingerprint tag
/// names, so a unit for the wrong model surfaces as a tagged mismatch
/// the pool can drop and count, not as silently mis-shaped material.
pub struct RemoteDealer {
    framed: Framed,
    registry: Arc<ModelRegistry>,
    /// Set after any transport/decode error: request/response pairing on
    /// the stream may be desynced (e.g. undrained Session frames), so
    /// the handle refuses further fetches — reconnect instead.
    poisoned: bool,
}

impl RemoteDealer {
    /// Handshake over an established byte channel: ships the registry's
    /// manifest set; every local model must be covered by the dealer's
    /// reply set (weight digests included).
    pub fn connect(chan: Box<dyn Channel>, registry: Arc<ModelRegistry>) -> Result<RemoteDealer> {
        Self::connect_framed(Framed::new(chan), registry)
    }

    /// [`Self::connect`] over an authenticated framing layer: every
    /// frame both ways carries an AES-128-CMAC tag keyed by `psk`. A
    /// dealer without the same key fails the handshake (MAC mismatch or
    /// desynced stream — see [`super::frame`]).
    pub fn connect_psk(
        chan: Box<dyn Channel>,
        registry: Arc<ModelRegistry>,
        psk: [u8; 16],
    ) -> Result<RemoteDealer> {
        Self::connect_framed(Framed::with_psk(chan, psk), registry)
    }

    fn connect_framed(mut framed: Framed, registry: Arc<ModelRegistry>) -> Result<RemoteDealer> {
        ensure!(!registry.is_empty(), "local registry is empty");
        let local = registry.manifests();
        framed.send(MsgType::Hello, &codec::encode_manifest_set(&local)?)?;
        let reply = framed.recv()?;
        match reply.msg_type {
            MsgType::Hello => {
                let offered = codec::decode_manifest_set(&reply.payload)?;
                for m in &local {
                    manifest_covered(m, &offered)
                        .with_context(|| "dealer manifest set does not cover local models")?;
                }
                Ok(RemoteDealer { framed, registry, poisoned: false })
            }
            MsgType::Error => {
                bail!("dealer rejected handshake: {}", String::from_utf8_lossy(&reply.payload))
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }

    /// Connect to a dealer over TCP.
    pub fn connect_tcp(addr: &str, registry: Arc<ModelRegistry>) -> Result<RemoteDealer> {
        Self::connect(Box::new(TcpChannel::connect(addr)?), registry)
    }

    /// Connect to a dealer over TCP, with PSK-authenticated framing when
    /// `psk` is set (the fleet-config form: one option covers both
    /// deployments).
    pub fn connect_tcp_psk(
        addr: &str,
        registry: Arc<ModelRegistry>,
        psk: Option<[u8; 16]>,
    ) -> Result<RemoteDealer> {
        let chan: Box<dyn Channel> = Box::new(TcpChannel::connect(addr)?);
        match psk {
            Some(key) => Self::connect_psk(chan, registry, key),
            None => Self::connect(chan, registry),
        }
    }

    /// Fetch freshly dealt sessions of model `model` (blocking round
    /// trip). `count` is clamped to `1..=MAX_SESSIONS_PER_REQUEST`; the
    /// returned vec's length is the clamped count. Any error poisons the
    /// handle (the stream may hold undrained frames) — drop it and
    /// reconnect.
    pub fn fetch(&mut self, model: u64, count: usize) -> Result<Vec<Session>> {
        ensure!(!self.poisoned, "connection poisoned by an earlier error; reconnect");
        let res = self.fetch_inner(model, count);
        if res.is_err() {
            self.poisoned = true;
        }
        res
    }

    fn fetch_inner(&mut self, model: u64, count: usize) -> Result<Vec<Session>> {
        let plan = self
            .registry
            .get(model)
            .with_context(|| format!("model {model:#018x} not in local registry"))?
            .plan
            .clone();
        let count = count.clamp(1, MAX_SESSIONS_PER_REQUEST as usize) as u32;
        let mut w = Writer::new();
        w.u64(model);
        w.u32(count);
        self.framed.send(MsgType::Request, &w.buf)?;
        let mut out = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let frame = self.framed.recv()?;
            match frame.msg_type {
                MsgType::Session => out.push(codec::decode_session(&frame.payload, &plan)?),
                MsgType::Error => {
                    bail!("dealer error: {}", String::from_utf8_lossy(&frame.payload))
                }
                other => bail!("expected Session, got {other:?}"),
            }
        }
        Ok(out)
    }

    /// Fetch ReLU layer `layer` of each session in `seqs` for model
    /// `model` (blocking round trip). Returned in request order as
    /// `(fingerprint, seq, client half, server half)` — the fingerprint
    /// is the one *the dealer tagged the unit with*; the caller must
    /// check it against the model it asked for (the pool drops and
    /// counts mismatches instead of banking them). Any error poisons the
    /// handle — reconnect.
    pub fn fetch_layers(
        &mut self,
        model: u64,
        layer: usize,
        seqs: &[u64],
    ) -> Result<Vec<(u64, u64, ClientReluMaterial, ServerReluMaterial)>> {
        ensure!(!self.poisoned, "connection poisoned by an earlier error; reconnect");
        let res = self.fetch_layers_inner(model, layer, seqs);
        if res.is_err() {
            self.poisoned = true;
        }
        res
    }

    /// Resolve the plan a received unit's leading fingerprint tag names
    /// (units decode against *their own* model's shapes — the caller
    /// then compares the tag to the model it asked for).
    fn resolve_unit_plan(&self, payload: &[u8]) -> Result<Arc<NetworkPlan>> {
        let fp = Reader::new(payload).u64()?;
        Ok(self
            .registry
            .get(fp)
            .with_context(|| format!("dealer answered unregistered fingerprint {fp:#018x}"))?
            .plan
            .clone())
    }

    fn fetch_layers_inner(
        &mut self,
        model: u64,
        layer: usize,
        seqs: &[u64],
    ) -> Result<Vec<(u64, u64, ClientReluMaterial, ServerReluMaterial)>> {
        self.send_layer_request(model, REQ_RELU_LAYER, layer as u32, seqs)?;
        let mut out = Vec::with_capacity(seqs.len());
        for &want_seq in seqs {
            let frame = self.recv_unit(MsgType::LayerBatch)?;
            let plan = self.resolve_unit_plan(&frame.payload)?;
            let mut r = Reader::new(&frame.payload);
            let (fp, li, seq, cm, sm) = codec::get_layer_batch(&mut r, &plan)?;
            ensure!(r.remaining() == 0, "trailing bytes after layer batch");
            ensure!(
                li as usize == layer && seq == want_seq,
                "dealer answered layer {li} seq {seq}, wanted layer {layer} seq {want_seq}"
            );
            out.push((fp, seq, cm, sm));
        }
        Ok(out)
    }

    /// Fetch the linear-precompute spine of each session in `seqs` for
    /// model `model`. Returned in request order as `(fingerprint, seq,
    /// spine)` — same fingerprint-tag contract as [`Self::fetch_layers`].
    /// Any error poisons the handle.
    pub fn fetch_spines(
        &mut self,
        model: u64,
        seqs: &[u64],
    ) -> Result<Vec<(u64, u64, LinearSpine)>> {
        ensure!(!self.poisoned, "connection poisoned by an earlier error; reconnect");
        let res = self.fetch_spines_inner(model, seqs);
        if res.is_err() {
            self.poisoned = true;
        }
        res
    }

    fn fetch_spines_inner(
        &mut self,
        model: u64,
        seqs: &[u64],
    ) -> Result<Vec<(u64, u64, LinearSpine)>> {
        self.send_layer_request(model, REQ_SPINE, 0, seqs)?;
        let mut out = Vec::with_capacity(seqs.len());
        for &want_seq in seqs {
            let frame = self.recv_unit(MsgType::Spine)?;
            let plan = self.resolve_unit_plan(&frame.payload)?;
            let mut r = Reader::new(&frame.payload);
            let (fp, seq, spine) = codec::get_spine(&mut r, &plan)?;
            ensure!(r.remaining() == 0, "trailing bytes after spine");
            ensure!(seq == want_seq, "dealer answered seq {seq}, wanted {want_seq}");
            out.push((fp, seq, spine));
        }
        Ok(out)
    }

    fn send_layer_request(&mut self, model: u64, kind: u8, layer: u32, seqs: &[u64]) -> Result<()> {
        ensure!(
            !seqs.is_empty() && seqs.len() <= MAX_UNITS_PER_REQUEST as usize,
            "bad unit count {}",
            seqs.len()
        );
        let mut w = Writer::new();
        w.u64(model);
        w.u8(kind);
        w.u32(layer);
        w.u32(seqs.len() as u32);
        for &seq in seqs {
            w.u64(seq);
        }
        self.framed.send(MsgType::RequestLayers, &w.buf)
    }

    fn recv_unit(&mut self, want: MsgType) -> Result<super::frame::Frame> {
        let frame = self.framed.recv()?;
        let got = frame.msg_type;
        if got == want {
            return Ok(frame);
        }
        if got == MsgType::Error {
            bail!("dealer error: {}", String::from_utf8_lossy(&frame.payload));
        }
        bail!("expected {want:?}, got {got:?}")
    }

    /// Total bytes received over this connection (frames included).
    pub fn bytes_received(&self) -> u64 {
        self.framed.bytes_received()
    }

    /// Largest single frame received (the layer-streaming size bound).
    pub fn max_frame_received(&self) -> u64 {
        self.framed.max_frame_received()
    }

    /// Orderly goodbye (best effort).
    pub fn close(mut self) {
        let _ = self.framed.send(MsgType::Bye, &[]);
    }
}

/// Spawn a dealer thread serving one in-memory duplex channel from a
/// full model registry, dealing each unit across up to `deal_threads`
/// threads. `conn_seed` seeds the connection's legacy-round RNG stream.
/// Returns the coordinator-side endpoint and the dealer thread handle.
pub fn spawn_mem_dealer_multi(
    registry: Arc<ModelRegistry>,
    conn_seed: u64,
    deal_threads: usize,
) -> (Box<dyn Channel>, JoinHandle<()>) {
    let (coord_end, dealer_end) = MemChannel::pair();
    let handle = std::thread::spawn(move || {
        let mut rng = Rng::new(conn_seed);
        let _ = serve_connection(
            Framed::new(Box::new(dealer_end)),
            &registry,
            &mut rng,
            deal_threads,
        );
    });
    (Box::new(coord_end), handle)
}

/// Single-model [`spawn_mem_dealer_multi`]: a registry of one plan whose
/// seq namespace is exactly `seed` (dealt bytes identical to the
/// pre-registry dealer for the same `(seed, plan)`).
pub fn spawn_mem_dealer(
    plan: Arc<NetworkPlan>,
    seed: u64,
    deal_threads: usize,
) -> (Box<dyn Channel>, JoinHandle<()>) {
    spawn_mem_dealer_multi(ModelRegistry::single(plan, seed), seed, deal_threads)
}

/// A running TCP dealer (accept loop + per-connection threads).
pub struct DealerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Cloned handles to every accepted connection's socket, so
    /// [`Self::kill`] can sever in-flight connections (a `stop()` lets
    /// them run to completion).
    conns: Arc<Mutex<Vec<std::net::TcpStream>>>,
}

impl DealerHandle {
    /// The bound address (useful with a `:0` ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. Connections already being
    /// served run to completion on their own threads.
    ///
    /// The accept loop polls a non-blocking listener with a short sleep,
    /// so this returns promptly even if the shared wake-up nudge
    /// ([`crate::net::accept::stop_nudge`]) cannot connect — the nudge
    /// only shortens the wait below one poll interval.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        stop_nudge(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Simulate process death: stop accepting **and** sever every
    /// accepted connection mid-stream (both directions shut down, so a
    /// peer blocked in a read sees EOF immediately instead of waiting
    /// out its read timeout). This is what the fleet failover tests and
    /// benches use to measure dealer-kill recovery without spawning OS
    /// processes.
    pub fn kill(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        stop_nudge(self.addr);
        for conn in self.conns.lock().unwrap().iter() {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:0`) and serve dealer connections for
/// every model in `registry` until stopped. For the legacy whole-session
/// round, connection `c` deals from `Rng::new(seed ^ c·φ)` — a
/// reproducible per-connection stream. Layer-granular rounds are
/// seq-addressed from each model's registry base seed, so every
/// connection serves mutually consistent per-layer material.
pub fn spawn_tcp_dealer_multi(
    addr: &str,
    registry: Arc<ModelRegistry>,
    seed: u64,
    deal_threads: usize,
) -> Result<DealerHandle> {
    spawn_tcp_dealer_multi_psk(addr, registry, seed, deal_threads, None)
}

/// [`spawn_tcp_dealer_multi`] with optional PSK-authenticated framing:
/// when `psk` is set, every connection is served over CMAC-tagged
/// frames and a coordinator without the same key fails the handshake.
pub fn spawn_tcp_dealer_multi_psk(
    addr: &str,
    registry: Arc<ModelRegistry>,
    seed: u64,
    deal_threads: usize,
    psk: Option<[u8; 16]>,
) -> Result<DealerHandle> {
    // Non-blocking accept, polled with a short sleep: the loop observes
    // the stop flag within one poll interval even when no nudge
    // connection can reach the listener (see [`DealerHandle::stop`]).
    let listener = PollingListener::bind(addr)?;
    let local = listener.local_addr();
    let stop = Arc::new(AtomicBool::new(false));
    let stop_accept = stop.clone();
    let conns = Arc::new(Mutex::new(Vec::new()));
    let conns_accept = conns.clone();
    let accept_thread = std::thread::spawn(move || {
        let mut conn_id = 0u64;
        loop {
            if stop_accept.load(Ordering::Relaxed) {
                return;
            }
            match listener.accept() {
                Ok(Some((stream, _))) => {
                    // The connection itself is served blocking.
                    let _ = stream.set_nonblocking(false);
                    if let Ok(dup) = stream.try_clone() {
                        conns_accept.lock().unwrap().push(dup);
                    }
                    conn_id += 1;
                    let registry = registry.clone();
                    let mut rng = Rng::new(seed ^ conn_id.wrapping_mul(0x9E3779B97F4A7C15));
                    std::thread::spawn(move || {
                        let chan: Box<dyn Channel> = Box::new(TcpChannel::new(stream));
                        let framed = match psk {
                            Some(key) => Framed::with_psk(chan, key),
                            None => Framed::new(chan),
                        };
                        let _ = serve_connection(framed, &registry, &mut rng, deal_threads);
                    });
                }
                Ok(None) | Err(_) => std::thread::sleep(Duration::from_millis(25)),
            }
        }
    });
    Ok(DealerHandle { addr: local, stop, accept_thread: Some(accept_thread), conns })
}

/// Single-model [`spawn_tcp_dealer_multi`] (seq namespace = `seed`).
pub fn spawn_tcp_dealer(
    addr: &str,
    plan: Arc<NetworkPlan>,
    seed: u64,
    deal_threads: usize,
) -> Result<DealerHandle> {
    spawn_tcp_dealer_multi(addr, ModelRegistry::single(plan, seed), seed, deal_threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::protocol::server::run_inference;

    fn tiny_plan(seed: u64) -> Arc<NetworkPlan> {
        let mut rng = Rng::new(seed);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    fn fp_of(plan: &NetworkPlan) -> u64 {
        SessionManifest::of_plan(plan).fingerprint
    }

    #[test]
    fn mem_dealer_sessions_match_inline_deal() {
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        // Multi-threaded dealer vs single-threaded inline deal: the
        // column schedule makes them bit-identical.
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 42, 4);
        let mut dealer =
            RemoteDealer::connect(chan, ModelRegistry::single(plan.clone(), 42)).unwrap();
        let sessions = dealer.fetch(fp, 2).unwrap();
        assert_eq!(sessions.len(), 2);
        assert!(dealer.bytes_received() > 0);
        dealer.close();
        dealer_thread.join().unwrap();

        // Same RNG stream inline ⇒ bit-identical material ⇒ identical
        // inference transcripts.
        let mut rng = Rng::new(42);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(100 + i)).collect();
        for session in sessions {
            let inline = deal_session(&plan, &mut rng);
            assert_eq!(session.offline_bytes, inline.offline_bytes);
            let (wire_logits, _) = run_inference(&session.client, &session.server, &input);
            let (inline_logits, _) = run_inference(&inline.client, &inline.server, &input);
            assert_eq!(wire_logits, inline_logits);
        }
    }

    #[test]
    fn layer_round_matches_standalone_deal_and_mixes_with_legacy() {
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 0xABC, 2);
        let mut dealer =
            RemoteDealer::connect(chan, ModelRegistry::single(plan.clone(), 0xABC)).unwrap();
        let spines = dealer.fetch_spines(fp, &[0, 1]).unwrap();
        let layers = dealer.fetch_layers(fp, 0, &[1, 0]).unwrap();
        // The legacy whole-session round still works on the same
        // connection, interleaved with layer rounds.
        let sessions = dealer.fetch(fp, 1).unwrap();
        assert_eq!(sessions.len(), 1);
        dealer.close();
        let _ = dealer_thread.join();

        // Everything fetched is seq-addressed: re-derivable locally from
        // (base seed, seq) alone — and tagged with the model fingerprint.
        for (ufp, seq, spine) in &spines {
            assert_eq!(*ufp, fp, "seq {seq}: fingerprint tag");
            let local = deal_spine(&plan, &mut session_rng(0xABC, *seq));
            assert_eq!(spine.he_bytes, local.he_bytes, "seq {seq}");
            for (a, b) in spine.slots.iter().zip(&local.slots) {
                assert_eq!(a.r, b.r, "seq {seq}");
                assert_eq!(a.x_share, b.x_share, "seq {seq}");
                assert_eq!(a.s, b.s, "seq {seq}");
            }
        }
        for (ufp, seq, cm, sm) in &layers {
            assert_eq!(*ufp, fp, "seq {seq}: fingerprint tag");
            let (lc, ls) = deal_relu_layer_mt(&plan, &mut session_rng(0xABC, *seq), 0, 1);
            assert_eq!(cm.gc.tables(), lc.gc.tables(), "seq {seq}");
            assert_eq!(cm.client_labels, lc.client_labels, "seq {seq}");
            assert_eq!(cm.r_out, lc.r_out, "seq {seq}");
            assert_eq!(sm.encodings.label0(), ls.encodings.label0(), "seq {seq}");
        }
    }

    #[test]
    fn one_connection_serves_every_registered_model() {
        // Two models (different depths, different variants) over one
        // dealer link: each model's units come back addressed with its
        // own fingerprint and dealt from its own base-seed namespace.
        let plan_a = tiny_plan(1);
        let mut rng = Rng::new(5);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(4, 5, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        let plan_b = Arc::new(NetworkPlan::unscaled(
            linears,
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ));
        let mut reg = ModelRegistry::new();
        let fa = reg.register(plan_a.clone(), 0xA0, 1.0).unwrap();
        let fb = reg.register(plan_b.clone(), 0xB0, 1.0).unwrap();
        let registry = Arc::new(reg);

        let (chan, dealer_thread) = spawn_mem_dealer_multi(registry.clone(), 3, 1);
        let mut dealer = RemoteDealer::connect(chan, registry).unwrap();
        let la = dealer.fetch_layers(fa, 0, &[0]).unwrap();
        let lb = dealer.fetch_layers(fb, 1, &[0]).unwrap();
        dealer.close();
        let _ = dealer_thread.join();

        assert_eq!(la[0].0, fa);
        assert_eq!(lb[0].0, fb);
        let (ca, _) = deal_relu_layer_mt(&plan_a, &mut session_rng(0xA0, 0), 0, 1);
        let (cb, _) = deal_relu_layer_mt(&plan_b, &mut session_rng(0xB0, 0), 1, 1);
        assert_eq!(la[0].2.gc.tables(), ca.gc.tables(), "model A from A's namespace");
        assert_eq!(lb[0].2.gc.tables(), cb.gc.tables(), "model B from B's namespace");
    }

    #[test]
    fn unknown_fingerprint_is_an_error_frame_not_a_dead_connection() {
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 9, 1);
        let mut dealer =
            RemoteDealer::connect(chan, ModelRegistry::single(plan.clone(), 9)).unwrap();
        // A fetch for an unregistered model errors (Error frame)...
        let err = dealer.fetch_layers_inner(fp ^ 0xFFFF, 0, &[0]).unwrap_err();
        assert!(err.to_string().contains("unknown model fingerprint"), "{err}");
        // ...and the connection itself survived: the next round works.
        // (fetch_layers_inner bypasses the poison latch deliberately —
        // the pool's public path reconnects instead.)
        let layers = dealer.fetch_layers_inner(fp, 0, &[0]).unwrap();
        assert_eq!(layers.len(), 1);
        dealer.close();
        let _ = dealer_thread.join();
    }

    #[test]
    fn tcp_dealer_on_unspecified_bind_stops_promptly() {
        // The regression this pins: a 0.0.0.0 bind whose stop() nudge
        // cannot connect must still stop within the accept-poll interval
        // instead of joining a blocked accept() forever.
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        let handle = spawn_tcp_dealer("0.0.0.0:0", plan.clone(), 2, 1).expect("bind");
        // Prove it serves via loopback first.
        let addr = format!("127.0.0.1:{}", handle.addr().port());
        let mut dealer =
            RemoteDealer::connect_tcp(&addr, ModelRegistry::single(plan, 2)).unwrap();
        let sessions = dealer.fetch(fp, 1).unwrap();
        assert_eq!(sessions.len(), 1);
        dealer.close();
        let t = std::time::Instant::now();
        handle.stop();
        assert!(t.elapsed() < Duration::from_secs(5), "stop() hung");
    }

    #[test]
    fn psk_dealer_serves_keyed_peers_and_rejects_others() {
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        let psk = [0x5Au8; 16];
        let reg = ModelRegistry::single(plan.clone(), 11);
        let handle =
            spawn_tcp_dealer_multi_psk("127.0.0.1:0", reg, 11, 1, Some(psk)).expect("bind");
        let addr = handle.addr().to_string();
        let registry = ModelRegistry::single(plan, 11);

        // Matching key: full handshake + a layer round.
        let mut ok = RemoteDealer::connect_tcp_psk(&addr, registry.clone(), Some(psk)).unwrap();
        let layers = ok.fetch_layers(fp, 0, &[0]).unwrap();
        assert_eq!(layers.len(), 1);
        ok.close();

        // Wrong key: the dealer's MAC check fails on our Hello, it drops
        // the connection, and our reply read sees EOF — handshake error.
        // (Key-present-vs-absent mismatches also fail closed but may
        // first wait out a read timeout; those directions are pinned
        // fast over MemChannel in the frame tests.)
        let mut wrong = psk;
        wrong[0] ^= 1;
        assert!(RemoteDealer::connect_tcp_psk(&addr, registry, Some(wrong)).is_err());
        handle.stop();
    }

    #[test]
    fn handshake_rejects_mismatched_plan() {
        let plan_a = tiny_plan(1);
        let mut rng = Rng::new(9);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)), // different dims
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        let plan_b = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));

        let (chan, dealer_thread) = spawn_mem_dealer(plan_a, 7, 1);
        let err =
            RemoteDealer::connect(chan, ModelRegistry::single(plan_b, 7)).unwrap_err();
        assert!(err.to_string().contains("rejected"), "{err}");
        let _ = dealer_thread.join();
    }

    #[test]
    fn handshake_rejects_mutated_weights_with_a_weight_digest_error() {
        // Same architecture, different weights: the behavioral weight
        // digest must turn this into a *handshake* error naming the
        // digest — never silently wrong material.
        let plan_a = tiny_plan(1);
        let plan_mutated = tiny_plan(2); // same dims/variant, other weights
        assert!(SessionManifest::of_plan(&plan_a)
            .same_architecture(&SessionManifest::of_plan(&plan_mutated)));

        let (chan, dealer_thread) = spawn_mem_dealer(plan_a, 7, 1);
        let err =
            RemoteDealer::connect(chan, ModelRegistry::single(plan_mutated, 7)).unwrap_err();
        assert!(err.to_string().contains("weight digest mismatch"), "{err}");
        let _ = dealer_thread.join();
    }

    #[test]
    fn request_count_bounds_enforced() {
        let plan = tiny_plan(1);
        let fp = fp_of(&plan);
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 5, 1);
        let mut framed = Framed::new(chan);
        let manifest = SessionManifest::of_plan(&plan);
        framed
            .send(MsgType::Hello, &codec::encode_manifest_set(&[manifest]).unwrap())
            .unwrap();
        assert_eq!(framed.recv().unwrap().msg_type, MsgType::Hello);
        // Zero-count request is a protocol violation; the dealer drops us.
        let mut w = Writer::new();
        w.u64(fp);
        w.u32(0);
        framed.send(MsgType::Request, &w.buf).unwrap();
        assert!(framed.recv().is_err());
        let _ = dealer_thread.join();
    }
}

//! The standalone dealer: garbles full sessions on demand and streams
//! them to a coordinator over the framed transport.
//!
//! Protocol (one connection):
//!
//! ```text
//! coordinator → dealer : Hello   (SessionManifest of the local plan)
//! dealer      → coord  : Hello   (its own manifest)  — or Error + close
//! coordinator → dealer : Request (u32 session count)
//! dealer      → coord  : Session × count (one encoded session each)
//! ...                    (any number of Request rounds)
//! coordinator → dealer : Bye
//! ```
//!
//! The handshake compares manifests structurally (variant, layer dims,
//! rescale schedule, fingerprint); a mismatch is rejected before any
//! material moves. Sessions are dealt with
//! [`crate::protocol::server::offline_network_mt`] — the exact same code
//! path as the inline pool deal — and the column-wise RNG schedule makes
//! the material a function of the seed alone, so a dealer fanning one
//! session across many threads still ships bits identical to an inline
//! single-threaded deal from the same RNG stream.

use super::codec::{self, SessionManifest};
use super::frame::{Channel, Framed, MemChannel, MsgType, TcpChannel};
use crate::coordinator::pool::Session;
use crate::protocol::server::NetworkPlan;
use crate::util::bytes::{Reader, Writer};
use crate::util::error::{Context, Result};
use crate::util::Rng;
use crate::{bail, ensure};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Upper bound on sessions per Request (keeps a rogue coordinator from
/// pinning a dealer thread forever).
pub const MAX_SESSIONS_PER_REQUEST: u32 = 4096;

/// Deal one full session (both parties' nets) from the dealer's RNG on
/// one thread.
pub fn deal_session(plan: &NetworkPlan, rng: &mut Rng) -> Session {
    deal_session_mt(plan, rng, 1)
}

/// [`deal_session`] with the per-layer garble columns split across up to
/// `deal_threads` threads (the column-wise schedule in
/// [`crate::protocol::offline`]). Bit-identical output for every thread
/// count, so a multi-core dealer ships exactly what an inline
/// single-threaded deal from the same seed would.
pub fn deal_session_mt(plan: &NetworkPlan, rng: &mut Rng, deal_threads: usize) -> Session {
    let (client, server, offline_bytes) =
        crate::protocol::server::offline_network_mt(plan, rng, deal_threads);
    Session { client, server, offline_bytes }
}

/// Serve one dealer connection until `Bye` or peer close, dealing each
/// session across up to `deal_threads` threads. Returns `Ok` on an
/// orderly goodbye, `Err` on protocol violations or transport failure
/// (callers serving many connections just log and move on).
pub fn serve_connection(
    mut framed: Framed,
    plan: &NetworkPlan,
    rng: &mut Rng,
    deal_threads: usize,
) -> Result<()> {
    let local = SessionManifest::of_plan(plan);
    let hello = framed.recv()?;
    ensure!(hello.msg_type == MsgType::Hello, "expected Hello, got {:?}", hello.msg_type);
    match SessionManifest::decode(&hello.payload) {
        Ok(remote) if remote == local => framed.send(MsgType::Hello, &local.encode())?,
        Ok(remote) => {
            let msg = format!(
                "plan mismatch: dealer fingerprint {:#018x}, coordinator {:#018x}",
                local.fingerprint, remote.fingerprint
            );
            let _ = framed.send(MsgType::Error, msg.as_bytes());
            bail!("{msg}");
        }
        Err(e) => {
            let _ = framed.send(MsgType::Error, e.to_string().as_bytes());
            return Err(e);
        }
    }

    loop {
        let frame = framed.recv()?;
        match frame.msg_type {
            MsgType::Request => {
                let count = Reader::new(&frame.payload).u32()?;
                ensure!(
                    (1..=MAX_SESSIONS_PER_REQUEST).contains(&count),
                    "bad session count {count}"
                );
                for _ in 0..count {
                    let session = deal_session_mt(plan, rng, deal_threads);
                    framed.send(MsgType::Session, &codec::encode_session(&session))?;
                }
            }
            MsgType::Bye => return Ok(()),
            other => bail!("unexpected {other:?} frame"),
        }
    }
}

/// Coordinator-side handle to a connected dealer.
pub struct RemoteDealer {
    framed: Framed,
    plan: Arc<NetworkPlan>,
    /// Set after any transport/decode error: request/response pairing on
    /// the stream may be desynced (e.g. undrained Session frames), so
    /// the handle refuses further fetches — reconnect instead.
    poisoned: bool,
}

impl RemoteDealer {
    /// Handshake over an established byte channel.
    pub fn connect(chan: Box<dyn Channel>, plan: Arc<NetworkPlan>) -> Result<RemoteDealer> {
        let mut framed = Framed::new(chan);
        let manifest = SessionManifest::of_plan(&plan);
        framed.send(MsgType::Hello, &manifest.encode())?;
        let reply = framed.recv()?;
        match reply.msg_type {
            MsgType::Hello => {
                let remote = SessionManifest::decode(&reply.payload)?;
                ensure!(
                    remote == manifest,
                    "dealer serves a different plan (fingerprint {:#018x} != {:#018x})",
                    remote.fingerprint,
                    manifest.fingerprint
                );
                Ok(RemoteDealer { framed, plan, poisoned: false })
            }
            MsgType::Error => {
                bail!("dealer rejected handshake: {}", String::from_utf8_lossy(&reply.payload))
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }

    /// Connect to a dealer over TCP.
    pub fn connect_tcp(addr: &str, plan: Arc<NetworkPlan>) -> Result<RemoteDealer> {
        Self::connect(Box::new(TcpChannel::connect(addr)?), plan)
    }

    /// Fetch freshly dealt sessions (blocking round trip). `count` is
    /// clamped to `1..=MAX_SESSIONS_PER_REQUEST`; the returned vec's
    /// length is the clamped count. Any error poisons the handle (the
    /// stream may hold undrained frames) — drop it and reconnect.
    pub fn fetch(&mut self, count: usize) -> Result<Vec<Session>> {
        ensure!(!self.poisoned, "connection poisoned by an earlier error; reconnect");
        let res = self.fetch_inner(count);
        if res.is_err() {
            self.poisoned = true;
        }
        res
    }

    fn fetch_inner(&mut self, count: usize) -> Result<Vec<Session>> {
        let count = count.clamp(1, MAX_SESSIONS_PER_REQUEST as usize) as u32;
        let mut w = Writer::new();
        w.u32(count);
        self.framed.send(MsgType::Request, &w.buf)?;
        let mut out = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let frame = self.framed.recv()?;
            match frame.msg_type {
                MsgType::Session => {
                    out.push(codec::decode_session(&frame.payload, &self.plan)?)
                }
                MsgType::Error => {
                    bail!("dealer error: {}", String::from_utf8_lossy(&frame.payload))
                }
                other => bail!("expected Session, got {other:?}"),
            }
        }
        Ok(out)
    }

    /// Total bytes received over this connection (frames included).
    pub fn bytes_received(&self) -> u64 {
        self.framed.bytes_received()
    }

    /// Orderly goodbye (best effort).
    pub fn close(mut self) {
        let _ = self.framed.send(MsgType::Bye, &[]);
    }
}

/// Spawn a dealer thread serving one in-memory duplex channel, dealing
/// each session across up to `deal_threads` threads. Returns the
/// coordinator-side endpoint and the dealer thread handle.
pub fn spawn_mem_dealer(
    plan: Arc<NetworkPlan>,
    seed: u64,
    deal_threads: usize,
) -> (Box<dyn Channel>, JoinHandle<()>) {
    let (coord_end, dealer_end) = MemChannel::pair();
    let handle = std::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        let _ = serve_connection(Framed::new(Box::new(dealer_end)), &plan, &mut rng, deal_threads);
    });
    (Box::new(coord_end), handle)
}

/// A running TCP dealer (accept loop + per-connection threads).
pub struct DealerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl DealerHandle {
    /// The bound address (useful with a `:0` ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. Connections already being
    /// served run to completion on their own threads.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:0`) and serve dealer connections until
/// stopped. Connection `c` deals from `Rng::new(seed ^ c·φ)` — the same
/// per-thread stream derivation the inline pool uses, so a given
/// connection's material is reproducible from the seed (and, under the
/// column schedule, independent of `deal_threads`).
pub fn spawn_tcp_dealer(
    addr: &str,
    plan: Arc<NetworkPlan>,
    seed: u64,
    deal_threads: usize,
) -> Result<DealerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr().context("local addr")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_accept = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        let mut conn_id = 0u64;
        for stream in listener.incoming() {
            if stop_accept.load(Ordering::Relaxed) {
                return;
            }
            let Ok(stream) = stream else { continue };
            conn_id += 1;
            let plan = plan.clone();
            let mut rng = Rng::new(seed ^ conn_id.wrapping_mul(0x9E3779B97F4A7C15));
            std::thread::spawn(move || {
                let framed = Framed::new(Box::new(TcpChannel::new(stream)));
                let _ = serve_connection(framed, &plan, &mut rng, deal_threads);
            });
        }
    });
    Ok(DealerHandle { addr: local, stop, accept_thread: Some(accept_thread) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::protocol::server::run_inference;

    fn tiny_plan(seed: u64) -> Arc<NetworkPlan> {
        let mut rng = Rng::new(seed);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    #[test]
    fn mem_dealer_sessions_match_inline_deal() {
        let plan = tiny_plan(1);
        // Multi-threaded dealer vs single-threaded inline deal: the
        // column schedule makes them bit-identical.
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 42, 4);
        let mut dealer = RemoteDealer::connect(chan, plan.clone()).unwrap();
        let sessions = dealer.fetch(2).unwrap();
        assert_eq!(sessions.len(), 2);
        assert!(dealer.bytes_received() > 0);
        dealer.close();
        dealer_thread.join().unwrap();

        // Same RNG stream inline ⇒ bit-identical material ⇒ identical
        // inference transcripts.
        let mut rng = Rng::new(42);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(100 + i)).collect();
        for session in sessions {
            let inline = deal_session(&plan, &mut rng);
            assert_eq!(session.offline_bytes, inline.offline_bytes);
            let (wire_logits, _) = run_inference(&session.client, &session.server, &input);
            let (inline_logits, _) = run_inference(&inline.client, &inline.server, &input);
            assert_eq!(wire_logits, inline_logits);
        }
    }

    #[test]
    fn handshake_rejects_mismatched_plan() {
        let plan_a = tiny_plan(1);
        let mut rng = Rng::new(9);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)), // different dims
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        let plan_b = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));

        let (chan, dealer_thread) = spawn_mem_dealer(plan_a, 7, 1);
        let err = RemoteDealer::connect(chan, plan_b).unwrap_err();
        assert!(err.to_string().contains("rejected"), "{err}");
        let _ = dealer_thread.join();
    }

    #[test]
    fn request_count_bounds_enforced() {
        let plan = tiny_plan(1);
        let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), 5, 1);
        let mut framed = Framed::new(chan);
        let manifest = SessionManifest::of_plan(&plan);
        framed.send(MsgType::Hello, &manifest.encode()).unwrap();
        assert_eq!(framed.recv().unwrap().msg_type, MsgType::Hello);
        // Zero-count request is a protocol violation; the dealer drops us.
        let mut w = Writer::new();
        w.u32(0);
        framed.send(MsgType::Request, &w.buf).unwrap();
        assert!(framed.recv().is_err());
        let _ = dealer_thread.join();
    }
}

//! Wall-clock timing helpers for benches and metrics.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::new();
    let out = f();
    let s = t.elapsed_s();
    (out, s)
}

/// Run a closure repeatedly until at least `min_time_s` has elapsed and at
/// least `min_iters` iterations have run; returns seconds-per-iteration.
///
/// This is the measurement core of the hand-rolled bench harness
/// (criterion is not in the offline vendor set).
pub fn bench_seconds_per_iter(min_time_s: f64, min_iters: u64, mut f: impl FnMut()) -> f64 {
    // Warmup.
    f();
    let t = Timer::new();
    let mut iters = 0u64;
    loop {
        f();
        iters += 1;
        if iters >= min_iters && t.elapsed_s() >= min_time_s {
            break;
        }
    }
    t.elapsed_s() / iters as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
        assert!(t.elapsed_us() >= 4000);
    }

    #[test]
    fn time_returns_result() {
        let (v, s) = time(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn bench_runs_min_iters() {
        let mut n = 0;
        bench_seconds_per_iter(0.0, 10, || n += 1);
        assert!(n >= 10);
    }
}

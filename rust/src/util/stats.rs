//! Summary statistics and latency histograms for benches and metrics.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-bucket latency histogram (power-of-two microsecond buckets).
///
/// Used by the coordinator's metrics: recording is O(1) and lock-free when
/// wrapped in atomics by the caller.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket `i` counts samples in `[2^i, 2^(i+1))` microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 40], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record_us(&mut self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from the bucket boundaries (upper bound).
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q / 100.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((median(&xs) - 3.0).abs() < 1e-12);
        assert!((stddev(&xs) - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
    }

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = Histogram::new();
        for us in [100, 200, 400, 800] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 375.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 800);
    }

    #[test]
    fn histogram_percentile_monotone() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_us(i);
        }
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p99);
        assert!(p50 >= 256 && p50 <= 1024, "p50={p50}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_us(10);
        b.record_us(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000);
    }
}

//! Minimal error plumbing (`anyhow` is not guaranteed in the offline
//! vendor set): a string-backed [`Error`], a [`Result`] alias, the
//! [`Context`] extension trait, and the [`crate::bail!`] /
//! [`crate::ensure!`] macros. Call sites read exactly like the `anyhow`
//! equivalents they replace.

use std::fmt;

/// A boxed-string error with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error { msg: m }
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error { msg: m.to_string() }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters for results and options.
pub trait Context<T> {
    /// Replace/prefix the error with `msg` (lazily formatted errors keep
    /// their text as a suffix).
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Like [`Context::context`] but the message is built only on error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (the `anyhow::bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Bail unless a condition holds (the `anyhow::ensure!` shape).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke at {}", 7);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "v too big: 30");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i32>().map(|_| ());
        let e = r.context("parsing config").unwrap_err();
        assert!(e.to_string().starts_with("parsing config: "));

        let o: Option<u32> = None;
        assert_eq!(o.context("missing key").unwrap_err().to_string(), "missing key");

        let o2: Option<u32> = Some(4);
        assert_eq!(o2.with_context(|| "unused").unwrap(), 4);
    }

    #[test]
    fn question_mark_conversions() {
        fn io_path() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
            Ok(s)
        }
        assert!(io_path().is_err());
    }
}

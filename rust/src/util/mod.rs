//! Small shared utilities: PRNG, statistics, timers, CLI args, byte I/O.
//!
//! The offline vendor set has no `rand`, `clap`, or `criterion`, so this
//! module carries the minimal replacements the rest of the crate needs.

pub mod args;
pub mod bytes;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

//! Small shared utilities: PRNG, statistics, timers, CLI args, byte I/O,
//! error plumbing.
//!
//! The offline vendor set has no `rand`, `clap`, `criterion`, or
//! (guaranteed) `anyhow`, so this module carries the minimal replacements
//! the rest of the crate needs.

pub mod args;
pub mod bytes;
pub mod error;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

//! Deterministic PRNG used throughout the crate.
//!
//! `rand` is not in the offline vendor set, so we carry a small
//! xoshiro256** implementation (Blackman & Vigna). It is used for
//! *simulation* randomness (share sampling, workload generation, tests).
//! Wire-label secrecy in the garbling engine additionally passes through
//! the fixed-key AES PRF in [`crate::prf`], so GC security does not rest
//! on this generator alone.

/// xoshiro256** PRNG. Deterministic, seedable, `Send`.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Derive an independent stream for a subcomponent (e.g. per layer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random boolean.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fill a byte slice.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        for chunk in out.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// 128 random bits.
    #[inline]
    pub fn next_u128(&mut self) -> u128 {
        ((self.next_u64() as u128) << 64) | self.next_u64() as u128
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below_usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fill_bytes_partial_chunks() {
        let mut r = Rng::new(9);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}

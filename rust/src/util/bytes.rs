//! Tiny binary (de)serialization helpers.
//!
//! `serde` is not in the offline vendor set, so artifacts (weights,
//! datasets) and protocol messages use this explicit little-endian format.
//! The Python side (`python/compile/aot.py`) writes the same layouts.
//!
//! This module sits on the untrusted-input decode path, so it is held to
//! the repo's **decode-no-panic** invariant (`docs/INVARIANTS.md`, rule
//! R1, enforced by `circa-lint`): no `unwrap`/`expect`/indexing — every
//! failure is an `Err`, and the [`le_u16`]/[`le_u32`]/[`le_u64`]/
//! [`le_u128`] assemblers below exist so callers never need a panicking
//! slice-to-array conversion.

use crate::util::error::{Context, Result};

/// Assemble a little-endian `u16` from up to 2 bytes (missing high bytes
/// read as zero). The copy loop compiles to a plain load; unlike
/// `try_into().unwrap()` it has no panic path on a short slice.
pub fn le_u16(b: &[u8]) -> u16 {
    let mut out = [0u8; 2];
    for (o, &x) in out.iter_mut().zip(b) {
        *o = x;
    }
    u16::from_le_bytes(out)
}

/// Assemble a little-endian `u32` from up to 4 bytes (see [`le_u16`]).
pub fn le_u32(b: &[u8]) -> u32 {
    let mut out = [0u8; 4];
    for (o, &x) in out.iter_mut().zip(b) {
        *o = x;
    }
    u32::from_le_bytes(out)
}

/// Assemble a little-endian `u64` from up to 8 bytes (see [`le_u16`]).
pub fn le_u64(b: &[u8]) -> u64 {
    let mut out = [0u8; 8];
    for (o, &x) in out.iter_mut().zip(b) {
        *o = x;
    }
    u64::from_le_bytes(out)
}

/// Assemble a little-endian `u128` from up to 16 bytes (see [`le_u16`]).
pub fn le_u128(b: &[u8]) -> u128 {
    let mut out = [0u8; 16];
    for (o, &x) in out.iter_mut().zip(b) {
        *o = x;
    }
    u128::from_le_bytes(out)
}

/// A cursor over a byte slice with checked little-endian reads.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("read range overflows usize")?;
        let out = self
            .buf
            .get(self.pos..end)
            .with_context(|| format!("short read: want {n} bytes, have {}", self.remaining()))?;
        self.pos = end;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        self.take(1)?.first().copied().context("empty read")
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(le_u16(self.take(2)?))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(le_u32(self.take(4)?))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(le_u32(self.take(4)?) as i32)
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(le_u64(self.take(8)?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(le_u32(self.take(4)?)))
    }

    pub fn u128(&mut self) -> Result<u128> {
        Ok(le_u128(self.take(16)?))
    }

    /// Read a `u64` length prefix and check that it fits in `usize`. On
    /// 32-bit targets a hostile 8-byte length would otherwise truncate
    /// silently before any of the size guards run (lint rule R5).
    pub fn len_u64(&mut self) -> Result<usize> {
        let n = self.u64()?;
        usize::try_from(n).with_context(|| format!("length {n} exceeds usize"))
    }

    /// Length-prefixed element count with overflow-checked byte sizing —
    /// the guard every untrusted vec read goes through: an absurd length
    /// fails in `take` before any allocation happens.
    fn vec_bytes(&mut self, elem_bytes: usize) -> Result<(usize, &'a [u8])> {
        let n = self.len_u64()?;
        let nbytes = n.checked_mul(elem_bytes).context("vec length overflows")?;
        Ok((n, self.take(nbytes)?))
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let (_, raw) = self.vec_bytes(4)?;
        Ok(raw.chunks_exact(4).map(|c| le_u32(c) as i32).collect())
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let (_, raw) = self.vec_bytes(4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_bits(le_u32(c))).collect())
    }

    /// Length-prefixed `u128` vector (the wire shape of label arenas and
    /// free-XOR deltas).
    pub fn u128_vec(&mut self) -> Result<Vec<u128>> {
        let (_, raw) = self.vec_bytes(16)?;
        Ok(raw.chunks_exact(16).map(le_u128).collect())
    }

    /// Length-prefixed raw bytes, borrowed straight out of the input
    /// buffer (zero-copy; the caller decides whether to own them).
    pub fn byte_slice(&mut self) -> Result<&'a [u8]> {
        let n = self.len_u64()?;
        self.take(n)
    }

    /// Length-prefixed bit-packed bool vector (LSB-first within each
    /// byte) — the wire shape of decode-bit buffers.
    pub fn bool_vec(&mut self) -> Result<Vec<bool>> {
        let n = self.len_u64()?;
        let raw = self.take(n.div_ceil(8))?;
        Ok(raw
            .iter()
            .flat_map(|&byte| (0..8).map(move |bit| byte >> bit & 1 == 1))
            .take(n)
            .collect())
    }

    pub fn string(&mut self) -> Result<String> {
        let n = self.len_u64()?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).context("invalid utf8 in string field")
    }
}

/// A growable little-endian writer mirroring [`Reader`].
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32_vec(&mut self, v: &[i32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.i32(x);
        }
    }

    pub fn f32_vec(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn string(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed `u128` vector: one 16-byte memcpy per element into
    /// the output buffer (reserved up front).
    pub fn u128_vec(&mut self, v: &[u128]) {
        self.u64(v.len() as u64);
        self.buf.reserve(v.len() * 16);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed raw bytes.
    pub fn byte_slice(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed bit-packed bool vector (LSB-first within each byte).
    pub fn bool_vec(&mut self, v: &[bool]) {
        self.u64(v.len() as u64);
        let mut byte = 0u8;
        for (i, &b) in v.iter().enumerate() {
            byte |= (b as u8) << (i % 8);
            if i % 8 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if v.len() % 8 != 0 {
            self.buf.push(byte);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.i32(-42);
        w.u64(1 << 40);
        w.f32(1.5);
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_vecs_and_strings() {
        let mut w = Writer::new();
        w.i32_vec(&[-1, 0, 1, i32::MAX]);
        w.f32_vec(&[0.5, -2.25]);
        w.string("circa");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.i32_vec().unwrap(), vec![-1, 0, 1, i32::MAX]);
        assert_eq!(r.f32_vec().unwrap(), vec![0.5, -2.25]);
        assert_eq!(r.string().unwrap(), "circa");
    }

    #[test]
    fn short_read_errors() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn le_assemblers_match_from_le_bytes() {
        assert_eq!(le_u16(&[0x01, 0x02]), 0x0201);
        assert_eq!(le_u32(&[0x01, 0x02, 0x03, 0x04]), 0x0403_0201);
        assert_eq!(le_u64(&[1, 2, 3, 4, 5, 6, 7, 8]), u64::from_le_bytes([1, 2, 3, 4, 5, 6, 7, 8]));
        let b: [u8; 16] = [9, 8, 7, 6, 5, 4, 3, 2, 1, 0, 11, 12, 13, 14, 15, 16];
        assert_eq!(le_u128(&b), u128::from_le_bytes(b));
        // Short input zero-pads the missing high bytes instead of panicking.
        assert_eq!(le_u32(&[0xFF]), 0xFF);
        assert_eq!(le_u64(&[]), 0);
    }

    #[test]
    fn roundtrip_u16_u128() {
        let mut w = Writer::new();
        w.u16(0xBEEF);
        w.u128(u128::MAX - 3);
        w.u128(1 << 100);
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u128().unwrap(), u128::MAX - 3);
        assert_eq!(r.u128().unwrap(), 1 << 100);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_u128_vec_and_byte_slice() {
        let labels: Vec<u128> = vec![0, 1, u128::MAX, 0x1234_5678_9ABC_DEF0];
        let mut w = Writer::new();
        w.u128_vec(&labels);
        w.u128_vec(&[]);
        w.byte_slice(b"circa-wire");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u128_vec().unwrap(), labels);
        assert_eq!(r.u128_vec().unwrap(), Vec::<u128>::new());
        assert_eq!(r.byte_slice().unwrap(), b"circa-wire");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_bool_vec_all_tail_lengths() {
        // Exercise every packing remainder 0..8.
        for n in 0..=17usize {
            let bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let mut w = Writer::new();
            w.bool_vec(&bits);
            assert_eq!(w.buf.len(), 8 + n.div_ceil(8));
            let mut r = Reader::new(&w.buf);
            assert_eq!(r.bool_vec().unwrap(), bits, "n={n}");
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn absurd_vec_length_errors_without_allocating() {
        // A length field claiming usize::MAX elements must fail cleanly
        // (checked multiply + short read), never panic or OOM.
        let mut w = Writer::new();
        w.u64(u64::MAX);
        w.u32(7);
        for err in [
            Reader::new(&w.buf).u128_vec().err(),
            Reader::new(&w.buf).i32_vec().err(),
            Reader::new(&w.buf).f32_vec().err(),
            Reader::new(&w.buf).bool_vec().err(),
            Reader::new(&w.buf).byte_slice().err(),
        ] {
            assert!(err.is_some());
        }
    }

    #[test]
    fn truncated_vec_payload_errors() {
        let mut w = Writer::new();
        w.u128_vec(&[1, 2, 3]);
        let mut r = Reader::new(&w.buf[..w.buf.len() - 1]);
        assert!(r.u128_vec().is_err());
    }
}

//! Tiny binary (de)serialization helpers.
//!
//! `serde` is not in the offline vendor set, so artifacts (weights,
//! datasets) and protocol messages use this explicit little-endian format.
//! The Python side (`python/compile/aot.py`) writes the same layouts.

use crate::bail;
use crate::util::error::{Context, Result};

/// A cursor over a byte slice with checked little-endian reads.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("short read: want {n} bytes, have {}", self.remaining());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn string(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).context("invalid utf8 in string field")
    }
}

/// A growable little-endian writer mirroring [`Reader`].
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32_vec(&mut self, v: &[i32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.i32(x);
        }
    }

    pub fn f32_vec(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn string(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.i32(-42);
        w.u64(1 << 40);
        w.f32(1.5);
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_vecs_and_strings() {
        let mut w = Writer::new();
        w.i32_vec(&[-1, 0, 1, i32::MAX]);
        w.f32_vec(&[0.5, -2.25]);
        w.string("circa");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.i32_vec().unwrap(), vec![-1, 0, 1, i32::MAX]);
        assert_eq!(r.f32_vec().unwrap(), vec![0.5, -2.25]);
        assert_eq!(r.string().unwrap(), "circa");
    }

    #[test]
    fn short_read_errors() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }
}

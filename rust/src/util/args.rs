//! Minimal CLI argument parser (`clap` is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, which covers everything the `circa` binary and examples need.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of tokens.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("serve --verbose --port 8080 extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("port"), Some("8080"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("--k=12 --mode=poszero");
        assert_eq!(a.get_usize("k", 0), 12);
        assert_eq!(a.get("mode"), Some("poszero"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("k", 12), 12);
        assert_eq!(a.get_or("mode", "poszero"), "poszero");
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
    }
}

//! Runtime-dispatched batched AES backends for the garbling PRF.
//!
//! The fixed-key garbling construction (Bellare et al.) was designed so
//! the hash is nothing but raw AES throughput — which makes the block
//! cipher backend the single hottest dial in the offline phase. This
//! module puts every way of turning the crank behind one safe API:
//!
//! * [`Backend::AesNi`] — hardware AES via `std::arch::x86_64`
//!   intrinsics, 8 blocks in flight so the `aesenc` pipeline stays full.
//!   Selected at runtime when `cpuid` reports the `aes` feature; the
//!   unsafe kernels are reachable only through that check.
//! * [`Backend::SoftPipelined`] — the portable fallback: the T-table
//!   column form of [`Aes128`](super::softaes::Aes128), round-interleaved
//!   [`PIPELINE`](super::softaes::PIPELINE) blocks at a time.
//! * [`Backend::SoftScalar`] — the byte-wise FIPS reference path. Never
//!   auto-selected; kept addressable so benches can measure the scalar
//!   baseline and tests can cross-check the fast paths against it.
//!
//! All three are bit-identical (AES-128 is AES-128); the cross-backend
//! equivalence tests below and the KAT vectors in
//! [`super::softaes`] make silent drift impossible. Dispatch happens once
//! per [`BatchCipher`], not per call.

use super::softaes::Aes128;

/// Blocks per hash flight: the gather-then-hash gate loops and
/// [`super::GarbleHash::hash_many`] feed the backend at most this many
/// blocks at once (matches the soft path's pipeline width and keeps the
/// AES-NI kernel's register working set bounded).
pub const MAX_BATCH: usize = 8;

/// A block-encryption strategy for the batched PRF.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Hardware AES (x86_64 AES-NI), detected at runtime.
    AesNi,
    /// Round-interleaved T-table software AES (portable default).
    SoftPipelined,
    /// Byte-wise reference software AES (benchmarks/tests only).
    SoftScalar,
}

impl Backend {
    /// The fastest backend this CPU can run (what [`BatchCipher::new`]
    /// dispatches to).
    pub fn detect() -> Backend {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_64_feature_detected!("aes") {
                return Backend::AesNi;
            }
        }
        Backend::SoftPipelined
    }

    /// Can the current CPU run this backend?
    pub fn available(self) -> bool {
        match self {
            Backend::SoftPipelined | Backend::SoftScalar => true,
            Backend::AesNi => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_64_feature_detected!("aes")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::AesNi => "aes-ni",
            Backend::SoftPipelined => "soft-pipelined",
            Backend::SoftScalar => "soft-scalar",
        }
    }
}

/// Batched AES-128 encryptor: one key schedule, one dispatch decision,
/// and a safe `encrypt_many` whatever the CPU. Every backend produces
/// output bit-identical to [`Aes128::encrypt_u128`].
#[derive(Clone)]
pub struct BatchCipher {
    soft: Aes128,
    backend: Backend,
}

impl BatchCipher {
    /// Key-schedule with the best backend the CPU supports.
    pub fn new(key: [u8; 16]) -> Self {
        Self::with_backend(key, Backend::detect()).expect("detected backend is available")
    }

    /// Force a specific backend; `None` when the CPU can't run it (lets
    /// cross-backend tests auto-skip instead of crashing on SIGILL).
    pub fn with_backend(key: [u8; 16], backend: Backend) -> Option<Self> {
        backend.available().then(|| Self { soft: Aes128::new(key), backend })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Encrypt `blocks` in place (independent blocks, ECB-shaped — the
    /// fixed-key hash never chains).
    #[inline]
    pub fn encrypt_many(&self, blocks: &mut [u128]) {
        match self.backend {
            Backend::SoftPipelined => self.soft.encrypt_blocks(blocks),
            Backend::SoftScalar => {
                for b in blocks {
                    *b = self.soft.encrypt_u128(*b);
                }
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `with_backend` admits AesNi only when cpuid reports
            // the `aes` feature, so the target_feature kernel is runnable.
            Backend::AesNi => unsafe { encrypt_many_ni(self.soft.round_keys(), blocks) },
            #[cfg(not(target_arch = "x86_64"))]
            Backend::AesNi => unreachable!("AesNi gated by Backend::available"),
        }
    }
}

/// AES-NI kernel: up to [`MAX_BATCH`] blocks in flight per chunk. The
/// `aesenc` instruction fuses ShiftRows/SubBytes/MixColumns/AddRoundKey
/// over the same byte layout the soft path uses (the u128 is the
/// little-endian state byte string), so outputs are bit-identical.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "aes")]
// SAFETY: contract — the caller must have verified `aes` support via
// cpuid before calling (the only call site is gated by
// `Backend::available`); executing `aesenc` on a CPU without the
// feature is undefined behavior, not merely a SIGILL.
unsafe fn encrypt_many_ni(rk: &[u8; 176], blocks: &mut [u128]) {
    use std::arch::x86_64::*;
    // SAFETY: pointer validity — round-key loads read 16 B at offsets
    // 0, 16, ..., 160 of the 176-B `rk` array; block loads/stores use
    // `chunk.as_ptr().add(i)` with `i < chunk.len()`, so every 16-B
    // access stays inside the borrowed slice. `loadu`/`storeu` carry
    // no alignment requirement.
    let mut keys = [_mm_setzero_si128(); 11];
    for (i, k) in keys.iter_mut().enumerate() {
        *k = _mm_loadu_si128(rk.as_ptr().add(16 * i) as *const __m128i);
    }
    for chunk in blocks.chunks_mut(MAX_BATCH) {
        let n = chunk.len();
        let mut st = [_mm_setzero_si128(); MAX_BATCH];
        for (i, s) in st.iter_mut().take(n).enumerate() {
            let x = _mm_loadu_si128(chunk.as_ptr().add(i) as *const __m128i);
            *s = _mm_xor_si128(x, keys[0]);
        }
        for key in &keys[1..10] {
            for s in st.iter_mut().take(n) {
                *s = _mm_aesenc_si128(*s, *key);
            }
        }
        for (i, s) in st.iter().take(n).enumerate() {
            let out = _mm_aesenclast_si128(*s, keys[10]);
            _mm_storeu_si128(chunk.as_mut_ptr().add(i) as *mut __m128i, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const KEY: [u8; 16] = *b"CIRCA-PIgarble01";

    fn random_blocks(n: usize, seed: u64) -> Vec<u128> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_u128()).collect()
    }

    #[test]
    fn soft_backends_match_scalar_reference() {
        let scalar = Aes128::new(KEY);
        let pipelined = BatchCipher::with_backend(KEY, Backend::SoftPipelined).unwrap();
        for n in [1, 7, 8, 9, 64] {
            let blocks = random_blocks(n, 1000 + n as u64);
            let mut got = blocks.clone();
            pipelined.encrypt_many(&mut got);
            for (g, &b) in got.iter().zip(&blocks) {
                assert_eq!(*g, scalar.encrypt_u128(b), "n {n}");
            }
        }
    }

    #[test]
    fn aesni_matches_soft_backends() {
        // Cross-backend drift gate. Auto-skips on CPUs without AES-NI
        // (the portable CI leg) and runs on the native leg.
        let Some(ni) = BatchCipher::with_backend(KEY, Backend::AesNi) else {
            eprintln!("aesni_matches_soft_backends: no AES-NI, skipping");
            return;
        };
        let soft = BatchCipher::with_backend(KEY, Backend::SoftPipelined).unwrap();
        let scalar = Aes128::new(KEY);
        for n in [1, 2, 8, 11, 16, 100] {
            let blocks = random_blocks(n, 2000 + n as u64);
            let mut ni_out = blocks.clone();
            let mut soft_out = blocks.clone();
            ni.encrypt_many(&mut ni_out);
            soft.encrypt_many(&mut soft_out);
            assert_eq!(ni_out, soft_out, "n {n}");
            for (g, &b) in ni_out.iter().zip(&blocks) {
                assert_eq!(*g, scalar.encrypt_u128(b), "n {n}");
            }
        }
    }

    #[test]
    fn detect_reports_an_available_backend() {
        let b = Backend::detect();
        assert!(b.available());
        assert_ne!(b, Backend::SoftScalar, "scalar must never be auto-picked");
    }

    #[test]
    fn unavailable_backend_is_refused_not_crashed() {
        // On x86_64 with AES-NI every backend is constructible; the
        // contract under test is that with_backend never hands out a
        // cipher whose kernel would fault.
        for b in [Backend::AesNi, Backend::SoftPipelined, Backend::SoftScalar] {
            match BatchCipher::with_backend(KEY, b) {
                Some(c) => {
                    let mut blocks = random_blocks(3, 7);
                    c.encrypt_many(&mut blocks); // must not crash
                    assert_eq!(c.backend(), b);
                }
                None => assert!(!b.available()),
            }
        }
    }

    #[test]
    fn backend_names_distinct() {
        assert_ne!(Backend::AesNi.name(), Backend::SoftPipelined.name());
        assert_ne!(Backend::SoftPipelined.name(), Backend::SoftScalar.name());
    }
}

//! Wire labels and the garbling PRF.
//!
//! Labels are 128-bit values; the lowest bit is the *point-and-permute*
//! color bit. The garbling hash is the standard fixed-key-AES
//! construction `H(L, t) = AES_k(2L ⊕ t) ⊕ (2L ⊕ t)` (Bellare et al.,
//! "Efficient Garbling from a Fixed-Key Blockcipher"), which is what
//! half-gates assumes for its security proof. The block cipher is the
//! crate's own [`softaes`] (the `aes` crate is not guaranteed in the
//! offline vendor set).

pub mod softaes;

use crate::util::Rng;
use softaes::Aes128;

/// A 128-bit wire label.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Label(pub u128);

impl std::fmt::Debug for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Label({:032x})", self.0)
    }
}

impl Label {
    pub const ZERO: Label = Label(0);

    /// Random label.
    pub fn random(rng: &mut Rng) -> Label {
        Label(rng.next_u128())
    }

    /// The point-and-permute color bit (LSB).
    #[inline]
    pub fn color(self) -> bool {
        self.0 & 1 == 1
    }

    /// XOR (free-XOR group operation).
    #[inline]
    pub fn xor(self, other: Label) -> Label {
        Label(self.0 ^ other.0)
    }

    /// Doubling in GF(2^128) (the `2L` in the fixed-key hash); standard
    /// carry-less shift with the GCM reduction polynomial.
    #[inline]
    pub fn double(self) -> Label {
        let carry = self.0 >> 127;
        let mut v = self.0 << 1;
        if carry == 1 {
            v ^= 0x87; // x^128 = x^7 + x^2 + x + 1
        }
        Label(v)
    }

    pub fn to_bytes(self) -> [u8; 16] {
        self.0.to_le_bytes()
    }

    pub fn from_bytes(b: [u8; 16]) -> Label {
        Label(u128::from_le_bytes(b))
    }
}

impl std::ops::BitXor for Label {
    type Output = Label;
    fn bitxor(self, rhs: Label) -> Label {
        self.xor(rhs)
    }
}

/// The global free-XOR offset Δ. Its color bit is forced to 1 so that the
/// two labels of every wire have opposite colors.
#[derive(Clone, Copy, Debug)]
pub struct Delta(pub Label);

impl Delta {
    pub fn random(rng: &mut Rng) -> Delta {
        Delta(Label(rng.next_u128() | 1))
    }
}

/// Fixed-key AES hasher used by the garbler and evaluator.
///
/// One instance is created per garbling session; the key is public (the
/// security comes from the random labels, per the fixed-key model).
pub struct GarbleHash {
    cipher: Aes128,
}

impl GarbleHash {
    /// Process-wide shared instance — the key is a public constant, so
    /// one AES key schedule serves every garble/evaluate call (§Perf
    /// iteration 1: removes a per-circuit `Aes128::new`).
    pub fn shared() -> &'static GarbleHash {
        static SHARED: std::sync::OnceLock<GarbleHash> = std::sync::OnceLock::new();
        SHARED.get_or_init(GarbleHash::new)
    }

    /// Standard instantiation with a fixed public key.
    pub fn new() -> Self {
        // Any fixed constant works in the fixed-key model.
        let key = [
            0x43, 0x49, 0x52, 0x43, 0x41, 0x2d, 0x50, 0x49, // "CIRCA-PI"
            0x67, 0x61, 0x72, 0x62, 0x6c, 0x65, 0x30, 0x31, // "garble01"
        ];
        Self { cipher: Aes128::new(key) }
    }

    /// `H(L, tweak) = AES(2L ⊕ tweak) ⊕ (2L ⊕ tweak)`.
    #[inline]
    pub fn hash(&self, label: Label, tweak: u64) -> Label {
        let x = label.double().0 ^ (tweak as u128);
        Label(self.cipher.encrypt_u128(x) ^ x)
    }

    /// Hash four labels with explicit tweaks in one call (hot path of
    /// garbling: the four hashes of one half-gates AND gate).
    #[inline]
    pub fn hash4(&self, labels: [Label; 4], tweaks: [u64; 4]) -> [Label; 4] {
        core::array::from_fn(|i| self.hash(labels[i], tweaks[i]))
    }

    /// Hash two labels in one call (the two hashes of one AND-gate
    /// evaluation).
    #[inline]
    pub fn hash2(&self, l0: Label, t0: u64, l1: Label, t1: u64) -> [Label; 2] {
        [self.hash(l0, t0), self.hash(l1, t1)]
    }
}

impl Default for GarbleHash {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_xor_group() {
        let mut rng = Rng::new(1);
        let a = Label::random(&mut rng);
        let b = Label::random(&mut rng);
        assert_eq!(a ^ b ^ b, a);
        assert_eq!(a ^ Label::ZERO, a);
    }

    #[test]
    fn delta_color_is_one() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let d = Delta::random(&mut rng);
            assert!(d.0.color());
        }
    }

    #[test]
    fn opposite_colors_under_delta() {
        let mut rng = Rng::new(3);
        let d = Delta::random(&mut rng);
        for _ in 0..100 {
            let l0 = Label::random(&mut rng);
            let l1 = l0 ^ d.0;
            assert_ne!(l0.color(), l1.color());
        }
    }

    #[test]
    fn hash_deterministic_and_tweak_sensitive() {
        let h = GarbleHash::new();
        let l = Label(0x1234_5678_9abc_def0_1111_2222_3333_4444);
        assert_eq!(h.hash(l, 7), h.hash(l, 7));
        assert_ne!(h.hash(l, 7), h.hash(l, 8));
        assert_ne!(h.hash(l, 7), h.hash(Label(l.0 ^ 1), 7));
    }

    #[test]
    fn hash4_matches_hash() {
        let h = GarbleHash::new();
        let mut rng = Rng::new(4);
        let ls = [
            Label::random(&mut rng),
            Label::random(&mut rng),
            Label::random(&mut rng),
            Label::random(&mut rng),
        ];
        let batch = h.hash4(ls, [100, 101, 102, 103]);
        for i in 0..4 {
            assert_eq!(batch[i], h.hash(ls[i], 100 + i as u64));
        }
    }

    #[test]
    fn double_is_linear_shift() {
        // Doubling twice == shifting twice with reduction; spot-check
        // against a known small value.
        let l = Label(1u128 << 126);
        let d = l.double(); // 1<<127
        assert_eq!(d.0, 1u128 << 127);
        let dd = d.double(); // overflow -> 0x87
        assert_eq!(dd.0, 0x87);
    }

    #[test]
    fn hash_output_bits_balanced() {
        let h = GarbleHash::new();
        let mut rng = Rng::new(5);
        let mut ones = 0u32;
        let n = 200;
        for _ in 0..n {
            let out = h.hash(Label::random(&mut rng), 1);
            ones += out.0.count_ones();
        }
        let frac = ones as f64 / (n as f64 * 128.0);
        assert!((frac - 0.5).abs() < 0.03, "biased hash output: {frac}");
    }
}

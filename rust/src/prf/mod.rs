//! Wire labels and the garbling PRF.
//!
//! Labels are 128-bit values; the lowest bit is the *point-and-permute*
//! color bit. The garbling hash is the standard fixed-key-AES
//! construction `H(L, t) = AES_k(2L ⊕ t) ⊕ (2L ⊕ t)` (Bellare et al.,
//! "Efficient Garbling from a Fixed-Key Blockcipher"), which is what
//! half-gates assumes for its security proof. The key `k` is a public
//! constant — all the secrecy lives in the random labels — so one key
//! schedule serves the whole process and the cipher can be swapped for
//! whatever runs fastest without touching the security argument.
//!
//! # Dual-backend design
//!
//! The AES itself lives behind two layers:
//!
//! * [`softaes`] — the crate's own AES-128 (the `aes` crate is not
//!   guaranteed in the offline vendor set): a byte-wise FIPS reference
//!   path plus a round-interleaved T-table fast path.
//! * [`backend`] — the batched dispatch layer: [`backend::BatchCipher`]
//!   picks AES-NI (`cpuid`-detected, `std::arch` kernels behind a safe
//!   API) or the pipelined soft path once at construction, and encrypts
//!   whole flights of blocks per call.
//!
//! Dispatch rules: [`backend::Backend::detect`] returns AES-NI whenever
//! the CPU reports the `aes` feature, else the pipelined soft path; the
//! scalar reference path is never auto-selected. Every backend computes
//! the same function — AES-128 — so garbled material is **bit-identical**
//! across backends and machines; the KAT vectors in [`softaes`] and the
//! cross-backend tests in [`backend`] pin that down, which is what lets a
//! dealer with hardware AES serve an evaluator without it.
//!
//! Hot paths hash whole flights: [`GarbleHash::hash_many`] consumes
//! caller-gathered pre-images (`2L ⊕ t` blocks, see
//! [`GarbleHash::input_block`]) so the gate loops in [`crate::gc`] can
//! gather-hash-scatter across gates instead of hashing one gate at a
//! time; [`GarbleHash::hash4`]/[`GarbleHash::hash2`] ride the same
//! batched cipher.

pub mod backend;
pub mod softaes;

use crate::util::Rng;
use backend::BatchCipher;
use softaes::Aes128;

/// A 128-bit wire label.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Label(pub u128);

impl std::fmt::Debug for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Label({:032x})", self.0)
    }
}

impl Label {
    pub const ZERO: Label = Label(0);

    /// Random label.
    pub fn random(rng: &mut Rng) -> Label {
        Label(rng.next_u128())
    }

    /// The point-and-permute color bit (LSB).
    #[inline]
    pub fn color(self) -> bool {
        self.0 & 1 == 1
    }

    /// XOR (free-XOR group operation).
    #[inline]
    pub fn xor(self, other: Label) -> Label {
        Label(self.0 ^ other.0)
    }

    /// Doubling in GF(2^128) (the `2L` in the fixed-key hash); standard
    /// carry-less shift with the GCM reduction polynomial. Branchless:
    /// the reduction constant is selected by a mask computed from the
    /// carried-out bit (constant-time hygiene, and one less branch in the
    /// hottest inline of the garbling loop).
    #[inline]
    pub fn double(self) -> Label {
        let carry = self.0 >> 127; // 0 or 1
        // x^128 = x^7 + x^2 + x + 1; 0u128 - 1 = all-ones mask.
        Label((self.0 << 1) ^ (0x87 & 0u128.wrapping_sub(carry)))
    }

    pub fn to_bytes(self) -> [u8; 16] {
        self.0.to_le_bytes()
    }

    pub fn from_bytes(b: [u8; 16]) -> Label {
        Label(u128::from_le_bytes(b))
    }
}

impl std::ops::BitXor for Label {
    type Output = Label;
    fn bitxor(self, rhs: Label) -> Label {
        self.xor(rhs)
    }
}

/// The global free-XOR offset Δ. Its color bit is forced to 1 so that the
/// two labels of every wire have opposite colors.
#[derive(Clone, Copy, Debug)]
pub struct Delta(pub Label);

impl Delta {
    pub fn random(rng: &mut Rng) -> Delta {
        Delta(Label(rng.next_u128() | 1))
    }
}

/// Fixed-key AES hasher used by the garbler and evaluator.
///
/// The key is public (the security comes from the random labels, per the
/// fixed-key model). Holds two forms of the same cipher: a scalar
/// reference path for single hashes (also the oracle the batched paths
/// are tested against) and a [`BatchCipher`] that the flight-hashing
/// paths dispatch through.
pub struct GarbleHash {
    /// Scalar reference cipher (single-block [`GarbleHash::hash`]).
    scalar: Aes128,
    /// Batched cipher behind the runtime-dispatched backend.
    batch: BatchCipher,
}

/// The fixed public garbling key ("CIRCA-PIgarble01"). Any constant works
/// in the fixed-key model; changing it invalidates all garbled material.
const GARBLE_KEY: [u8; 16] = *b"CIRCA-PIgarble01";

impl GarbleHash {
    /// Process-wide shared instance — the key is a public constant, so
    /// one AES key schedule serves every garble/evaluate call (§Perf
    /// iteration 1: removes a per-circuit `Aes128::new`).
    pub fn shared() -> &'static GarbleHash {
        static SHARED: std::sync::OnceLock<GarbleHash> = std::sync::OnceLock::new();
        SHARED.get_or_init(GarbleHash::new)
    }

    /// Standard instantiation with the fixed public key and the fastest
    /// backend the CPU supports.
    pub fn new() -> Self {
        Self { scalar: Aes128::new(GARBLE_KEY), batch: BatchCipher::new(GARBLE_KEY) }
    }

    /// Instantiation with a forced backend (benchmarks and cross-backend
    /// tests); `None` when the CPU can't run it.
    pub fn with_backend(b: backend::Backend) -> Option<Self> {
        Some(Self {
            scalar: Aes128::new(GARBLE_KEY),
            batch: BatchCipher::with_backend(GARBLE_KEY, b)?,
        })
    }

    /// The backend the batched paths dispatch to.
    pub fn backend(&self) -> backend::Backend {
        self.batch.backend()
    }

    /// The hash pre-image `2L ⊕ tweak` — what callers gather into flight
    /// buffers for [`GarbleHash::hash_many`].
    #[inline]
    pub fn input_block(label: Label, tweak: u64) -> u128 {
        label.double().0 ^ (tweak as u128)
    }

    /// `H(L, tweak) = AES(2L ⊕ tweak) ⊕ (2L ⊕ tweak)`, through the scalar
    /// reference path.
    #[inline]
    pub fn hash(&self, label: Label, tweak: u64) -> Label {
        let x = Self::input_block(label, tweak);
        Label(self.scalar.encrypt_u128(x) ^ x)
    }

    /// Batched Davies–Meyer over caller-gathered pre-images, in place:
    /// `xs[i] ← AES(xs[i]) ⊕ xs[i]`. Feed it `input_block(L, t)` values;
    /// each [`backend::MAX_BATCH`]-block flight goes through the batched
    /// cipher in one call. This is the engine under the gather-then-hash
    /// gate loops in [`crate::gc::garble`] and [`crate::gc::eval`].
    pub fn hash_many(&self, xs: &mut [u128]) {
        let mut save = [0u128; backend::MAX_BATCH];
        for chunk in xs.chunks_mut(backend::MAX_BATCH) {
            save[..chunk.len()].copy_from_slice(chunk);
            self.batch.encrypt_many(chunk);
            for (y, x) in chunk.iter_mut().zip(&save) {
                *y ^= *x;
            }
        }
    }

    /// Hash four labels with explicit tweaks in one call (the four hashes
    /// of one half-gates AND gate), through the batched backend.
    #[inline]
    pub fn hash4(&self, labels: [Label; 4], tweaks: [u64; 4]) -> [Label; 4] {
        let mut xs: [u128; 4] = core::array::from_fn(|i| Self::input_block(labels[i], tweaks[i]));
        self.hash_many(&mut xs);
        core::array::from_fn(|i| Label(xs[i]))
    }

    /// Hash two labels in one call (the two hashes of one AND-gate
    /// evaluation), through the batched backend.
    #[inline]
    pub fn hash2(&self, l0: Label, t0: u64, l1: Label, t1: u64) -> [Label; 2] {
        let mut xs = [Self::input_block(l0, t0), Self::input_block(l1, t1)];
        self.hash_many(&mut xs);
        [Label(xs[0]), Label(xs[1])]
    }
}

impl Default for GarbleHash {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_xor_group() {
        let mut rng = Rng::new(1);
        let a = Label::random(&mut rng);
        let b = Label::random(&mut rng);
        assert_eq!(a ^ b ^ b, a);
        assert_eq!(a ^ Label::ZERO, a);
    }

    #[test]
    fn delta_color_is_one() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let d = Delta::random(&mut rng);
            assert!(d.0.color());
        }
    }

    #[test]
    fn opposite_colors_under_delta() {
        let mut rng = Rng::new(3);
        let d = Delta::random(&mut rng);
        for _ in 0..100 {
            let l0 = Label::random(&mut rng);
            let l1 = l0 ^ d.0;
            assert_ne!(l0.color(), l1.color());
        }
    }

    #[test]
    fn hash_deterministic_and_tweak_sensitive() {
        let h = GarbleHash::new();
        let l = Label(0x1234_5678_9abc_def0_1111_2222_3333_4444);
        assert_eq!(h.hash(l, 7), h.hash(l, 7));
        assert_ne!(h.hash(l, 7), h.hash(l, 8));
        assert_ne!(h.hash(l, 7), h.hash(Label(l.0 ^ 1), 7));
    }

    #[test]
    fn hash4_matches_hash() {
        let h = GarbleHash::new();
        let mut rng = Rng::new(4);
        let ls = [
            Label::random(&mut rng),
            Label::random(&mut rng),
            Label::random(&mut rng),
            Label::random(&mut rng),
        ];
        let batch = h.hash4(ls, [100, 101, 102, 103]);
        for i in 0..4 {
            assert_eq!(batch[i], h.hash(ls[i], 100 + i as u64));
        }
    }

    #[test]
    fn hash_many_matches_hash() {
        // The batched flight path (whatever backend was detected) against
        // the scalar reference path, across ragged flight boundaries.
        let h = GarbleHash::new();
        let mut rng = Rng::new(8);
        let labels: Vec<Label> = (0..37).map(|_| Label::random(&mut rng)).collect();
        let mut xs: Vec<u128> = labels
            .iter()
            .enumerate()
            .map(|(i, &l)| GarbleHash::input_block(l, i as u64))
            .collect();
        h.hash_many(&mut xs);
        for (i, (&x, &l)) in xs.iter().zip(&labels).enumerate() {
            assert_eq!(Label(x), h.hash(l, i as u64), "block {i}");
        }
    }

    #[test]
    fn forced_backends_hash_identically() {
        use super::backend::Backend;
        let reference = GarbleHash::with_backend(Backend::SoftScalar).unwrap();
        let mut rng = Rng::new(9);
        let labels: Vec<Label> = (0..20).map(|_| Label::random(&mut rng)).collect();
        for b in [Backend::SoftPipelined, Backend::AesNi] {
            let Some(h) = GarbleHash::with_backend(b) else {
                eprintln!("forced_backends_hash_identically: {} unavailable, skipping", b.name());
                continue;
            };
            let mut xs: Vec<u128> = labels
                .iter()
                .enumerate()
                .map(|(i, &l)| GarbleHash::input_block(l, i as u64))
                .collect();
            h.hash_many(&mut xs);
            for (i, (&x, &l)) in xs.iter().zip(&labels).enumerate() {
                assert_eq!(Label(x), reference.hash(l, i as u64), "{} block {i}", b.name());
            }
        }
    }

    #[test]
    fn double_is_linear_shift() {
        // Doubling twice == shifting twice with reduction; spot-check
        // against a known small value.
        let l = Label(1u128 << 126);
        let d = l.double(); // 1<<127
        assert_eq!(d.0, 1u128 << 127);
        let dd = d.double(); // overflow -> 0x87
        assert_eq!(dd.0, 0x87);
    }

    #[test]
    fn hash_output_bits_balanced() {
        let h = GarbleHash::new();
        let mut rng = Rng::new(5);
        let mut ones = 0u32;
        let n = 200;
        for _ in 0..n {
            let out = h.hash(Label::random(&mut rng), 1);
            ones += out.0.count_ones();
        }
        let frac = ones as f64 / (n as f64 * 128.0);
        assert!((frac - 0.5).abs() < 0.03, "biased hash output: {frac}");
    }
}

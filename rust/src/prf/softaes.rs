//! Software AES-128 encryption (FIPS-197).
//!
//! The `aes` crate is not guaranteed in the offline vendor set, so the
//! garbling PRF carries its own block cipher. Only encryption is needed
//! (the fixed-key hash never decrypts), the key is public, and inputs are
//! uniformly random wire labels — so table lookups keyed by the state are
//! side-channel-irrelevant here (nothing secret flows through them).
//!
//! Two code paths share one key schedule and are bit-identical:
//!
//! * [`Aes128::encrypt_block`] — the byte-wise FIPS reference form. Slow,
//!   obviously correct, and the oracle everything else is tested against.
//! * [`Aes128::encrypt_blocks`] — the throughput form used by the batched
//!   garbling backends ([`super::backend`]): the state is held as four
//!   little-endian `u32` columns, a round is 16 T-table lookups, and up to
//!   [`PIPELINE`] blocks are round-interleaved so the table loads of
//!   independent blocks overlap (software pipelining, the same trick the
//!   fixed-key garbling construction was designed to exploit on AES-NI).
//!
//! Verified against the FIPS-197 appendix B / C.1 and SP 800-38A ECB
//! vectors below, on both paths.

/// The AES S-box.
const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab,
    0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4,
    0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71,
    0xd8, 0x31, 0x15, 0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2,
    0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6,
    0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb,
    0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf, 0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45,
    0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8, 0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5,
    0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44,
    0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73, 0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a,
    0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49,
    0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d,
    0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08, 0xba, 0x78, 0x25,
    0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e,
    0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e, 0xe1,
    0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb,
    0x16,
];

/// Round constants for the key schedule.
const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

/// Multiply by x in GF(2^8) mod x^8 + x^4 + x^3 + x + 1.
#[inline(always)]
const fn xtime(b: u8) -> u8 {
    (b << 1) ^ (0x1b & (((b >> 7) & 1).wrapping_neg()))
}

/// Blocks round-interleaved per flight in [`Aes128::encrypt_blocks`].
pub const PIPELINE: usize = 8;

/// Combined SubBytes+MixColumns table for the column form: `T0[x]` packs
/// the column `(2s, s, s, 3s)` with `s = SBOX[x]` as a little-endian u32
/// (byte `r` = state row `r`). The other three tables are byte rotations:
/// `T_r = T0.rotate_left(8·r)`.
const fn build_t0() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let s = SBOX[i];
        let s2 = xtime(s);
        let s3 = s2 ^ s;
        t[i] = (s2 as u32) | ((s as u32) << 8) | ((s as u32) << 16) | ((s3 as u32) << 24);
        i += 1;
    }
    t
}

static T0: [u32; 256] = build_t0();

/// AES-128 encryptor with a precomputed key schedule.
#[derive(Clone)]
pub struct Aes128 {
    /// 11 round keys, flat, in FIPS byte order.
    rk: [u8; 176],
    /// The same round keys as little-endian u32 columns (`rk32[4r + c]` =
    /// column `c` of round `r`), for the column-form fast path.
    rk32: [u32; 44],
}

impl Aes128 {
    /// Expand a 16-byte key into the 11 round keys.
    pub fn new(key: [u8; 16]) -> Self {
        let mut rk = [0u8; 176];
        rk[..16].copy_from_slice(&key);
        for i in 4..44 {
            let mut t = [
                rk[4 * (i - 1)],
                rk[4 * (i - 1) + 1],
                rk[4 * (i - 1) + 2],
                rk[4 * (i - 1) + 3],
            ];
            if i % 4 == 0 {
                t = [
                    SBOX[t[1] as usize],
                    SBOX[t[2] as usize],
                    SBOX[t[3] as usize],
                    SBOX[t[0] as usize],
                ];
                t[0] ^= RCON[i / 4 - 1];
            }
            for j in 0..4 {
                rk[4 * i + j] = rk[4 * (i - 4) + j] ^ t[j];
            }
        }
        let mut rk32 = [0u32; 44];
        for (i, c) in rk32.iter_mut().enumerate() {
            *c = u32::from_le_bytes([rk[4 * i], rk[4 * i + 1], rk[4 * i + 2], rk[4 * i + 3]]);
        }
        Self { rk, rk32 }
    }

    /// The expanded key schedule (the key is a public constant in the
    /// fixed-key garbling model); the AES-NI backend loads its round keys
    /// from here so both backends share one schedule.
    pub(crate) fn round_keys(&self) -> &[u8; 176] {
        &self.rk
    }

    /// Encrypt one block in place. State layout: `s[r + 4c]` (the FIPS
    /// input order — bytes fill columns).
    #[inline]
    pub fn encrypt_block(&self, block: &mut [u8; 16]) {
        let mut s = *block;
        for i in 0..16 {
            s[i] ^= self.rk[i];
        }
        for round in 1..=10 {
            // SubBytes + ShiftRows fused: new[r + 4c] = S(old[r + 4((c+r)%4)]).
            let mut t = [0u8; 16];
            for i in 0..16 {
                let (r, c) = (i % 4, i / 4);
                t[i] = SBOX[s[r + 4 * ((c + r) % 4)] as usize];
            }
            if round != 10 {
                // MixColumns on each 4-byte column.
                for c in 0..4 {
                    let a = [t[4 * c], t[4 * c + 1], t[4 * c + 2], t[4 * c + 3]];
                    s[4 * c] = xtime(a[0]) ^ xtime(a[1]) ^ a[1] ^ a[2] ^ a[3];
                    s[4 * c + 1] = a[0] ^ xtime(a[1]) ^ xtime(a[2]) ^ a[2] ^ a[3];
                    s[4 * c + 2] = a[0] ^ a[1] ^ xtime(a[2]) ^ xtime(a[3]) ^ a[3];
                    s[4 * c + 3] = xtime(a[0]) ^ a[0] ^ a[1] ^ a[2] ^ xtime(a[3]);
                }
            } else {
                s = t;
            }
            for i in 0..16 {
                s[i] ^= self.rk[16 * round + i];
            }
        }
        *block = s;
    }

    /// Encrypt a u128 (little-endian byte mapping, matching the label
    /// serialization in [`super::Label::to_bytes`]).
    #[inline]
    pub fn encrypt_u128(&self, x: u128) -> u128 {
        let mut b = x.to_le_bytes();
        self.encrypt_block(&mut b);
        u128::from_le_bytes(b)
    }

    /// Encrypt a slice of blocks in place through the column/T-table fast
    /// path, round-interleaving up to [`PIPELINE`] blocks per flight.
    /// Bit-identical to calling [`Aes128::encrypt_u128`] per block.
    pub fn encrypt_blocks(&self, blocks: &mut [u128]) {
        for chunk in blocks.chunks_mut(PIPELINE) {
            self.encrypt_flight(chunk);
        }
    }

    /// One flight of at most [`PIPELINE`] blocks, rounds outermost so the
    /// per-block table loads of a round can overlap.
    fn encrypt_flight(&self, blocks: &mut [u128]) {
        debug_assert!(blocks.len() <= PIPELINE);
        let n = blocks.len();
        // State: four little-endian u32 columns per block. The u128 is the
        // little-endian byte string of the FIPS state (bytes fill
        // columns), so column `c` is simply bits `32c..32c+32`.
        let mut st = [[0u32; 4]; PIPELINE];
        for (s, &b) in st.iter_mut().zip(blocks.iter()) {
            *s = [b as u32, (b >> 32) as u32, (b >> 64) as u32, (b >> 96) as u32];
        }
        for s in st.iter_mut().take(n) {
            for (c, k) in s.iter_mut().zip(&self.rk32[..4]) {
                *c ^= *k;
            }
        }
        for round in 1..10 {
            let rk = &self.rk32[4 * round..4 * round + 4];
            for s in st.iter_mut().take(n) {
                // New column j mixes the shifted rows: row r comes from
                // old column (j+r)%4; T_r = rotl8^r(T0) (see build_t0).
                let old = *s;
                for (j, c) in s.iter_mut().enumerate() {
                    *c = T0[(old[j] & 0xff) as usize]
                        ^ T0[((old[(j + 1) & 3] >> 8) & 0xff) as usize].rotate_left(8)
                        ^ T0[((old[(j + 2) & 3] >> 16) & 0xff) as usize].rotate_left(16)
                        ^ T0[(old[(j + 3) & 3] >> 24) as usize].rotate_left(24)
                        ^ rk[j];
                }
            }
        }
        // Final round: SubBytes + ShiftRows + AddRoundKey, no MixColumns.
        let rk = &self.rk32[40..44];
        for s in st.iter_mut().take(n) {
            let old = *s;
            for (j, c) in s.iter_mut().enumerate() {
                *c = (SBOX[(old[j] & 0xff) as usize] as u32)
                    | ((SBOX[((old[(j + 1) & 3] >> 8) & 0xff) as usize] as u32) << 8)
                    | ((SBOX[((old[(j + 2) & 3] >> 16) & 0xff) as usize] as u32) << 16)
                    | ((SBOX[(old[(j + 3) & 3] >> 24) as usize] as u32) << 24);
                *c ^= rk[j];
            }
        }
        for (b, s) in blocks.iter_mut().zip(&st) {
            *b = (s[0] as u128)
                | ((s[1] as u128) << 32)
                | ((s[2] as u128) << 64)
                | ((s[3] as u128) << 96);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips197_c1_vector() {
        let key: [u8; 16] = core::array::from_fn(|i| i as u8);
        let aes = Aes128::new(key);
        let mut block = [
            0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88, 0x99, 0xaa, 0xbb, 0xcc, 0xdd,
            0xee, 0xff,
        ];
        aes.encrypt_block(&mut block);
        assert_eq!(hex(&block), "69c4e0d86a7b0430d8cdb78070b4c55a");
    }

    #[test]
    fn fips197_appendix_b_vector() {
        // The worked example of the spec body (appendix B).
        let key = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let aes = Aes128::new(key);
        let mut block = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        aes.encrypt_block(&mut block);
        assert_eq!(hex(&block), "3925841d02dc09fbdc118597196a0b32");
    }

    /// The four NIST SP 800-38A F.1.1 ECB-AES128 plaintext blocks.
    const SP800_38A_PLAIN: [[u8; 16]; 4] = [
        [
            0x6b, 0xc1, 0xbe, 0xe2, 0x2e, 0x40, 0x9f, 0x96, 0xe9, 0x3d, 0x7e, 0x11, 0x73, 0x93,
            0x17, 0x2a,
        ],
        [
            0xae, 0x2d, 0x8a, 0x57, 0x1e, 0x03, 0xac, 0x9c, 0x9e, 0xb7, 0x6f, 0xac, 0x45, 0xaf,
            0x8e, 0x51,
        ],
        [
            0x30, 0xc8, 0x1c, 0x46, 0xa3, 0x5c, 0xe4, 0x11, 0xe5, 0xfb, 0xc1, 0x19, 0x1a, 0x0a,
            0x52, 0xef,
        ],
        [
            0xf6, 0x9f, 0x24, 0x45, 0xdf, 0x4f, 0x9b, 0x17, 0xad, 0x2b, 0x41, 0x7b, 0xe6, 0x6c,
            0x37, 0x10,
        ],
    ];

    const SP800_38A_CIPHER: [&str; 4] = [
        "3ad77bb40d7a3660a89ecaf32466ef97",
        "f5d3d58503b9699de785895a96fdbaaf",
        "43b1cd7f598ece23881b00e3ed030688",
        "7b0c785e27e8ad3f8223207104725dd4",
    ];

    fn sp800_38a_key() -> [u8; 16] {
        [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ]
    }

    #[test]
    fn sp800_38a_ecb_vectors() {
        let aes = Aes128::new(sp800_38a_key());
        for (plain, want) in SP800_38A_PLAIN.iter().zip(SP800_38A_CIPHER) {
            let mut block = *plain;
            aes.encrypt_block(&mut block);
            assert_eq!(hex(&block), want);
        }
    }

    #[test]
    fn pipelined_path_matches_kat_vectors() {
        // The whole SP 800-38A set through one round-interleaved flight.
        let aes = Aes128::new(sp800_38a_key());
        let mut blocks: Vec<u128> =
            SP800_38A_PLAIN.iter().map(|p| u128::from_le_bytes(*p)).collect();
        aes.encrypt_blocks(&mut blocks);
        for (got, want) in blocks.iter().zip(SP800_38A_CIPHER) {
            assert_eq!(hex(&got.to_le_bytes()), want);
        }
    }

    #[test]
    fn pipelined_path_matches_scalar_on_random_blocks() {
        // Every flight size 1..=PIPELINE plus a ragged multi-flight slice
        // must agree with the byte-wise reference path bit for bit.
        let aes = Aes128::new(*b"CIRCA-PIgarble01");
        let mut rng = crate::util::Rng::new(0xAE5);
        for len in (1..=PIPELINE).chain([PIPELINE + 3, 3 * PIPELINE + 7]) {
            let blocks: Vec<u128> = (0..len).map(|_| rng.next_u128()).collect();
            let mut fast = blocks.clone();
            aes.encrypt_blocks(&mut fast);
            for (f, &b) in fast.iter().zip(&blocks) {
                assert_eq!(*f, aes.encrypt_u128(b), "len {len}");
            }
        }
    }

    #[test]
    fn garbling_key_zero_block_vector() {
        // Pins the crate's fixed garbling key against the reference
        // implementation (any change here silently invalidates every
        // previously garbled table).
        let key = *b"CIRCA-PIgarble01";
        let aes = Aes128::new(key);
        let mut block = [0u8; 16];
        aes.encrypt_block(&mut block);
        assert_eq!(hex(&block), "f8365bbd5358b6db0b114d9ad68968c6");
    }

    #[test]
    fn encryption_is_a_permutation_sample() {
        // Distinct inputs must map to distinct outputs; u128 mapping must
        // round-trip through the byte form consistently.
        let aes = Aes128::new([7u8; 16]);
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000u128 {
            assert!(seen.insert(aes.encrypt_u128(i)), "collision at {i}");
        }
    }

    #[test]
    fn xtime_matches_gf256_doubling() {
        assert_eq!(xtime(0x57), 0xae);
        assert_eq!(xtime(0xae), 0x47);
        assert_eq!(xtime(0x80), 0x1b);
        assert_eq!(xtime(0x01), 0x02);
    }
}

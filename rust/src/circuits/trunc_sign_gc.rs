//! Truncated stochastic sign — Eq. 3 (Circa optimization #3, the big one).
//!
//! Thin wrapper over [`stoch_sign_gc`](super::stoch_sign_gc) with `k > 0`:
//! the parties truncate their own shares at plaintext speed, so the GC
//! comparator *and* the online label traffic shrink from `m` to `m − k`
//! bits. Truncation adds a second fault mode (Thm 3.2): values with
//! `|x| < 2^k` flip with probability `(2^k − |x|)/2^k` — positives under
//! PosZero, negatives under NegPass. The `(−r, 1−r)` MUX stays m-bit.

use super::spec::FaultMode;
use super::stoch_sign_gc;
use crate::field::{Fp, FIELD_BITS};
use crate::gc::circuit::Circuit;

/// Build the Eq. 3 circuit: `(m−k)`-bit comparator + m-bit MUX.
pub fn build(k: u32, mode: FaultMode) -> Circuit {
    stoch_sign_gc::build_truncated(k, mode)
}

pub use super::stoch_sign_gc::{
    client_input_bits, encode_inputs, negate_share, reference, server_input_bits,
};

/// AND-gate count as a function of k — used by Fig. 5 and sanity checks.
pub fn expected_ands(k: u32) -> usize {
    (FIELD_BITS - k as usize) + FIELD_BITS // comparator + MUX
}

/// Closed-form truncation fault probability (Thm 3.2) for a value `x`,
/// *conditioned on* the stochastic sign being correct.
pub fn trunc_fault_prob(x: Fp, k: u32, mode: FaultMode) -> f64 {
    let two_k = 1u64 << k;
    let mag = x.magnitude();
    let side_hit = match mode {
        FaultMode::PosZero => x.is_nonneg(),
        FaultMode::NegPass => !x.is_nonneg(),
    };
    if side_hit && mag < two_k {
        (two_k - mag) as f64 / two_k as f64
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::bits_fp;
    use crate::field::random_fp;
    use crate::ss::SharePair;
    use crate::util::Rng;

    fn sign_via_gc(c: &Circuit, x: Fp, t: Fp, r: Fp, k: u32) -> i64 {
        let sh = SharePair::share_with_t(x, t);
        let out = bits_fp(&c.eval_plain(&encode_inputs(sh.client, sh.server, r, k)));
        (out + r).to_i64()
    }

    #[test]
    fn k0_equals_stochastic_sign() {
        let mut rng = Rng::new(1);
        let c0 = build(0, FaultMode::PosZero);
        let cs = stoch_sign_gc::build(FaultMode::PosZero);
        for _ in 0..100 {
            let x = random_fp(&mut rng);
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            assert_eq!(sign_via_gc(&c0, x, t, r, 0), sign_via_gc(&cs, x, t, r, 0));
        }
    }

    #[test]
    fn and_count_shrinks_with_k() {
        for k in [0u32, 4, 12, 18, 24] {
            let c = build(k, FaultMode::PosZero);
            assert_eq!(c.n_and(), expected_ands(k), "k={k}");
        }
    }

    #[test]
    fn online_label_count_shrinks_with_k() {
        // The server's online labels per ReLU drop from m to m−k.
        assert_eq!(stoch_sign_gc::n_server_inputs(0), FIELD_BITS);
        assert_eq!(stoch_sign_gc::n_server_inputs(12), FIELD_BITS - 12);
    }

    #[test]
    fn large_values_never_trunc_fault() {
        // |x| >= 2^k: truncated compare must equal untruncated compare.
        let mut rng = Rng::new(2);
        let k = 12;
        let ck = build(k, FaultMode::PosZero);
        let c0 = build(0, FaultMode::PosZero);
        for _ in 0..400 {
            let mag = (1u64 << k) + rng.below(1 << 20);
            let sign = if rng.bool() { 1 } else { -1 };
            let x = Fp::from_i64(sign * mag as i64);
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            assert_eq!(
                sign_via_gc(&ck, x, t, r, k),
                sign_via_gc(&c0, x, t, r, 0),
                "x={} t={}",
                x.to_i64(),
                t.raw()
            );
        }
    }

    #[test]
    fn poszero_fault_rate_matches_thm_3_2() {
        // x = 2^k / 4 should trunc-fault with prob (2^k − x)/2^k = 0.75.
        let mut rng = Rng::new(3);
        let k = 16;
        let c = build(k, FaultMode::PosZero);
        let x = Fp::from_i64((1i64 << k) / 4);
        let n = 3000;
        let mut faults = 0;
        for _ in 0..n {
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            if sign_via_gc(&c, x, t, r, k) != 1 {
                faults += 1;
            }
        }
        let rate = faults as f64 / n as f64;
        let want = trunc_fault_prob(x, k, FaultMode::PosZero);
        assert!((want - 0.75).abs() < 1e-9);
        assert!((rate - want).abs() < 0.04, "rate {rate} want {want}");
    }

    #[test]
    fn poszero_never_faults_negatives_in_trunc_range() {
        // Thm 3.2: in PosZero, negatives do not get extra faults.
        let mut rng = Rng::new(4);
        let k = 16;
        let ck = build(k, FaultMode::PosZero);
        let c0 = build(0, FaultMode::PosZero);
        for _ in 0..500 {
            let mag = 1 + rng.below((1 << k) - 1);
            let x = Fp::from_i64(-(mag as i64));
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            assert_eq!(sign_via_gc(&ck, x, t, r, k), sign_via_gc(&c0, x, t, r, 0));
        }
    }

    #[test]
    fn negpass_faults_negatives_not_positives() {
        let mut rng = Rng::new(5);
        let k = 16;
        let ck = build(k, FaultMode::NegPass);
        let c0 = build(0, FaultMode::NegPass);
        // Positives in trunc range: unchanged vs k=0.
        for _ in 0..300 {
            let mag = 1 + rng.below((1 << k) - 1);
            let x = Fp::from_i64(mag as i64);
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            assert_eq!(sign_via_gc(&ck, x, t, r, k), sign_via_gc(&c0, x, t, r, 0));
        }
        // Negative x = −2^k/4: passes as positive ~75% of the time.
        let x = Fp::from_i64(-((1i64 << k) / 4));
        let n = 3000;
        let mut faults = 0;
        for _ in 0..n {
            let t = random_fp(&mut rng);
            let r = random_fp(&mut rng);
            if sign_via_gc(&ck, x, t, r, k) != 0 {
                faults += 1;
            }
        }
        let rate = faults as f64 / n as f64;
        let want = trunc_fault_prob(x, k, FaultMode::NegPass);
        assert!((rate - want).abs() < 0.04, "rate {rate} want {want}");
    }

    #[test]
    fn fault_prob_formula_edges() {
        let k = 12;
        assert_eq!(trunc_fault_prob(Fp::from_i64(0), k, FaultMode::PosZero), 1.0);
        assert_eq!(trunc_fault_prob(Fp::from_i64(1 << k), k, FaultMode::PosZero), 0.0);
        assert_eq!(trunc_fault_prob(Fp::from_i64(-5), k, FaultMode::PosZero), 0.0);
        assert_eq!(trunc_fault_prob(Fp::from_i64(5), k, FaultMode::NegPass), 0.0);
        assert!(trunc_fault_prob(Fp::from_i64(-5), k, FaultMode::NegPass) > 0.99);
    }
}

//! Shared conventions for the ReLU circuit family.
//!
//! All circuits operate on `m = 31`-bit little-endian buses of field
//! elements. Inputs always arrive in the order the figures draw them:
//! client inputs first (so the OT accounting can split them off), then
//! server inputs.

use crate::field::{Fp, FIELD_BITS, PRIME};
use crate::gc::build::{bits_to_u64, u64_to_bits};

/// Truncation fault mode (§3.2, "Putting it All Together").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultMode {
    /// Small positives `x ∈ [0, 2^k)` zeroed with prob `(2^k−|x|)/2^k`
    /// (non-strict comparator `⟨x⟩_s ≤ t`).
    PosZero,
    /// Small negatives `x ∈ (−2^k, 0)` passed through with the same
    /// probability (strict comparator `⟨x⟩_s < t`).
    NegPass,
}

impl FaultMode {
    pub fn parse(s: &str) -> Option<FaultMode> {
        match s.to_ascii_lowercase().as_str() {
            "poszero" | "pos_zero" | "pz" => Some(FaultMode::PosZero),
            "negpass" | "neg_pass" | "np" => Some(FaultMode::NegPass),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultMode::PosZero => "PosZero",
            FaultMode::NegPass => "NegPass",
        }
    }
}

/// Which generation of the Fig. 2 family a protocol instance uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReluVariant {
    /// Fig. 2(a): the Gazelle/Delphi ReLU GC. No Beaver multiply needed.
    BaselineRelu,
    /// Fig. 2(b): exact sign in GC + Beaver multiply.
    NaiveSign,
    /// Fig. 2(c): stochastic sign (no mod-reconstruct) + Beaver multiply.
    StochasticSign { mode: FaultMode },
    /// Eq. 3: truncated stochastic sign + Beaver multiply.
    TruncatedSign { k: u32, mode: FaultMode },
}

impl ReluVariant {
    pub fn name(self) -> String {
        match self {
            ReluVariant::BaselineRelu => "ReLU".into(),
            ReluVariant::NaiveSign => "Sign".into(),
            ReluVariant::StochasticSign { mode } => format!("~Sign[{}]", mode.name()),
            ReluVariant::TruncatedSign { k, mode } => {
                format!("~Sign_k[k={k},{}]", mode.name())
            }
        }
    }

    /// Does this variant consume a Beaver triple per ReLU?
    pub fn uses_beaver(self) -> bool {
        !matches!(self, ReluVariant::BaselineRelu)
    }
}

/// Encode a field element onto an m-bit bus (little-endian bools).
pub fn fp_bits(x: Fp) -> Vec<bool> {
    u64_to_bits(x.raw(), FIELD_BITS)
}

/// Decode an m-bit bus back to a field element (reduces mod p).
pub fn bits_fp(bits: &[bool]) -> Fp {
    Fp::reduce(bits_to_u64(bits))
}

/// Sanity: p must fit the declared bus width.
pub const _ASSERT_WIDTH: () = assert!(PRIME < (1 << FIELD_BITS as u64));

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_bits_roundtrip() {
        for v in [0u64, 1, 12345, PRIME - 1] {
            let x = Fp::new(v);
            assert_eq!(bits_fp(&fp_bits(x)), x);
        }
    }

    #[test]
    fn mode_parse() {
        assert_eq!(FaultMode::parse("poszero"), Some(FaultMode::PosZero));
        assert_eq!(FaultMode::parse("NegPass"), Some(FaultMode::NegPass));
        assert_eq!(FaultMode::parse("np"), Some(FaultMode::NegPass));
        assert_eq!(FaultMode::parse("bogus"), None);
    }

    #[test]
    fn variant_names_distinct() {
        let names: Vec<String> = [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ]
        .iter()
        .map(|v| v.name())
        .collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn beaver_usage() {
        assert!(!ReluVariant::BaselineRelu.uses_beaver());
        assert!(ReluVariant::NaiveSign.uses_beaver());
    }
}

//! Shared conventions for the ReLU circuit family, and the single point
//! of truth for per-variant behavior ([`VariantSpec`]).
//!
//! All circuits operate on `m = 31`-bit little-endian buses of field
//! elements. Inputs always arrive in the order the figures draw them:
//! client inputs first (so the OT accounting can split them off), then
//! server inputs.
//!
//! Everything the protocol layers need to know about a variant — circuit
//! builder, input layout and base offsets, truncation level `k`, and the
//! client/server bit encoders — lives on [`VariantSpec`]. The protocol
//! phases dispatch through it instead of re-matching on [`ReluVariant`],
//! so adding a variant touches exactly this file plus its circuit module.

use crate::field::{Fp, FIELD_BITS, PRIME};
use crate::gc::build::{bits_to_u64, u64_to_bits};
use crate::gc::circuit::Circuit;

/// Truncation fault mode (§3.2, "Putting it All Together").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultMode {
    /// Small positives `x ∈ [0, 2^k)` zeroed with prob `(2^k−|x|)/2^k`
    /// (non-strict comparator `⟨x⟩_s ≤ t`).
    PosZero,
    /// Small negatives `x ∈ (−2^k, 0)` passed through with the same
    /// probability (strict comparator `⟨x⟩_s < t`).
    NegPass,
}

impl FaultMode {
    pub fn parse(s: &str) -> Option<FaultMode> {
        match s.to_ascii_lowercase().as_str() {
            "poszero" | "pos_zero" | "pz" => Some(FaultMode::PosZero),
            "negpass" | "neg_pass" | "np" => Some(FaultMode::NegPass),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultMode::PosZero => "PosZero",
            FaultMode::NegPass => "NegPass",
        }
    }
}

/// Which generation of the Fig. 2 family a protocol instance uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReluVariant {
    /// Fig. 2(a): the Gazelle/Delphi ReLU GC. No Beaver multiply needed.
    BaselineRelu,
    /// Fig. 2(b): exact sign in GC + Beaver multiply.
    NaiveSign,
    /// Fig. 2(c): stochastic sign (no mod-reconstruct) + Beaver multiply.
    StochasticSign { mode: FaultMode },
    /// Eq. 3: truncated stochastic sign + Beaver multiply.
    TruncatedSign { k: u32, mode: FaultMode },
}

impl ReluVariant {
    pub fn name(self) -> String {
        match self {
            ReluVariant::BaselineRelu => "ReLU".into(),
            ReluVariant::NaiveSign => "Sign".into(),
            ReluVariant::StochasticSign { mode } => format!("~Sign[{}]", mode.name()),
            ReluVariant::TruncatedSign { k, mode } => {
                format!("~Sign_k[k={k},{}]", mode.name())
            }
        }
    }

    /// Does this variant consume a Beaver triple per ReLU?
    pub fn uses_beaver(self) -> bool {
        !matches!(self, ReluVariant::BaselineRelu)
    }

    /// The variant's resolved layout + behavior table.
    pub fn spec(self) -> VariantSpec {
        let (k, n_client_inputs) = match self {
            ReluVariant::BaselineRelu => (0, super::relu_gc::N_CLIENT_INPUTS),
            ReluVariant::NaiveSign => (0, super::sign_gc::N_CLIENT_INPUTS),
            ReluVariant::StochasticSign { .. } => (0, super::stoch_sign_gc::n_client_inputs(0)),
            ReluVariant::TruncatedSign { k, .. } => (k, super::stoch_sign_gc::n_client_inputs(k)),
        };
        let n_server_inputs = match self {
            ReluVariant::BaselineRelu => super::relu_gc::N_SERVER_INPUTS,
            ReluVariant::NaiveSign => super::sign_gc::N_SERVER_INPUTS,
            ReluVariant::StochasticSign { .. } | ReluVariant::TruncatedSign { .. } => {
                super::stoch_sign_gc::n_server_inputs(k)
            }
        };
        VariantSpec { variant: self, k, n_client_inputs, n_server_inputs, n_outputs: FIELD_BITS }
    }
}

/// Resolved per-variant behavior: circuit construction, input layout and
/// base offsets, truncation level, and the two parties' bit encoders.
/// This replaces the free-floating `match variant` ladders that used to
/// be smeared across the protocol phase modules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantSpec {
    pub variant: ReluVariant,
    /// Truncation level (`0` for the non-truncated variants).
    pub k: u32,
    /// Client input bits per ReLU (the OT'd block at the front of the
    /// circuit's input layout).
    pub n_client_inputs: usize,
    /// Server input bits per ReLU (the online-label block).
    pub n_server_inputs: usize,
    /// Circuit output bits per ReLU (always one field bus).
    pub n_outputs: usize,
}

impl VariantSpec {
    /// Index of the first server input bit within the input layout.
    pub fn server_input_base(&self) -> usize {
        self.n_client_inputs
    }

    /// Total circuit inputs per ReLU.
    pub fn n_inputs(&self) -> usize {
        self.n_client_inputs + self.n_server_inputs
    }

    /// Does this variant consume a Beaver triple per ReLU?
    pub fn uses_beaver(&self) -> bool {
        self.variant.uses_beaver()
    }

    /// Build the variant's circuit (one template per *layer* — every ReLU
    /// in a layer garbles the same structure with fresh labels): the
    /// hash-consing CSE build followed by [`Circuit::optimize`]. Hot
    /// paths should prefer [`VariantSpec::circuit`], which memoizes this
    /// per process.
    pub fn build_circuit(&self) -> Circuit {
        let raw = match self.variant {
            ReluVariant::BaselineRelu => super::relu_gc::build(),
            ReluVariant::NaiveSign => super::sign_gc::build(),
            ReluVariant::StochasticSign { mode } => super::stoch_sign_gc::build(mode),
            ReluVariant::TruncatedSign { k, mode } => {
                super::stoch_sign_gc::build_truncated(k, mode)
            }
        };
        raw.optimize()
    }

    /// The pre-CSE, pre-optimizer circuit the seed builder produced —
    /// the reference point for equivalence and gate-count regression
    /// tests (identical `eval_plain`, never fewer gates).
    pub fn build_circuit_naive(&self) -> Circuit {
        use crate::gc::build::Builder;
        match self.variant {
            ReluVariant::BaselineRelu => super::relu_gc::build_with(Builder::new_naive()),
            ReluVariant::NaiveSign => super::sign_gc::build_with(Builder::new_naive()),
            ReluVariant::StochasticSign { mode } => {
                super::stoch_sign_gc::build_truncated_with(0, mode, Builder::new_naive())
            }
            ReluVariant::TruncatedSign { k, mode } => {
                super::stoch_sign_gc::build_truncated_with(k, mode, Builder::new_naive())
            }
        }
    }

    /// The process-wide memoized `Arc` of [`VariantSpec::build_circuit`]
    /// (see [`super::template`]): per-layer deals and material decodes
    /// share one template instead of rebuilding per call.
    pub fn circuit(&self) -> std::sync::Arc<Circuit> {
        super::template::circuit_for(self)
    }

    /// The client's GC input bits for one ReLU, given its offline-known
    /// share `xc` and its chosen randomness (`r_v` feeds the sign
    /// variants, `r_out` the baseline's output mask).
    pub fn client_bits(&self, xc: Fp, r_v: Fp, r_out: Fp) -> Vec<bool> {
        match self.variant {
            ReluVariant::BaselineRelu => {
                // Fig 2(a): ⟨x⟩_c then r (the output mask).
                let mut bits = fp_bits(xc);
                bits.extend(fp_bits(r_out));
                bits
            }
            ReluVariant::NaiveSign => {
                // Fig 2(b): ⟨x⟩_c, −r_v, 1−r_v.
                let mut bits = fp_bits(xc);
                bits.extend(fp_bits(-r_v));
                bits.extend(fp_bits(Fp::ONE - r_v));
                bits
            }
            ReluVariant::StochasticSign { .. } | ReluVariant::TruncatedSign { .. } => {
                super::stoch_sign_gc::client_input_bits(xc, r_v, self.k)
            }
        }
    }

    /// The server's GC input bits for one ReLU, given its online share.
    pub fn server_bits(&self, xs: Fp) -> Vec<bool> {
        match self.variant {
            ReluVariant::BaselineRelu | ReluVariant::NaiveSign => {
                u64_to_bits(xs.raw(), FIELD_BITS)
            }
            ReluVariant::StochasticSign { .. } | ReluVariant::TruncatedSign { .. } => {
                super::stoch_sign_gc::server_input_bits(xs, self.k)
            }
        }
    }
}

/// Encode a field element onto an m-bit bus (little-endian bools).
pub fn fp_bits(x: Fp) -> Vec<bool> {
    u64_to_bits(x.raw(), FIELD_BITS)
}

/// Decode an m-bit bus back to a field element (reduces mod p).
pub fn bits_fp(bits: &[bool]) -> Fp {
    Fp::reduce(bits_to_u64(bits))
}

/// Sanity: p must fit the declared bus width.
pub const _ASSERT_WIDTH: () = assert!(PRIME < (1 << FIELD_BITS as u64));

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_bits_roundtrip() {
        for v in [0u64, 1, 12345, PRIME - 1] {
            let x = Fp::new(v);
            assert_eq!(bits_fp(&fp_bits(x)), x);
        }
    }

    #[test]
    fn mode_parse() {
        assert_eq!(FaultMode::parse("poszero"), Some(FaultMode::PosZero));
        assert_eq!(FaultMode::parse("NegPass"), Some(FaultMode::NegPass));
        assert_eq!(FaultMode::parse("np"), Some(FaultMode::NegPass));
        assert_eq!(FaultMode::parse("bogus"), None);
    }

    #[test]
    fn variant_names_distinct() {
        let names: Vec<String> = [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ]
        .iter()
        .map(|v| v.name())
        .collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn beaver_usage() {
        assert!(!ReluVariant::BaselineRelu.uses_beaver());
        assert!(ReluVariant::NaiveSign.uses_beaver());
    }

    fn all_variants() -> Vec<ReluVariant> {
        vec![
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            ReluVariant::StochasticSign { mode: FaultMode::NegPass },
            ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero },
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass },
        ]
    }

    #[test]
    fn spec_layout_matches_built_circuit() {
        for v in all_variants() {
            let spec = v.spec();
            let c = spec.build_circuit();
            assert_eq!(c.n_inputs as usize, spec.n_inputs(), "{v:?}");
            assert_eq!(c.outputs.len(), spec.n_outputs, "{v:?}");
        }
    }

    #[test]
    fn spec_encoders_match_layout_widths() {
        let mut rng = crate::util::Rng::new(9);
        for v in all_variants() {
            let spec = v.spec();
            let (xc, rv, rout) = (
                crate::field::random_fp(&mut rng),
                crate::field::random_fp(&mut rng),
                crate::field::random_fp(&mut rng),
            );
            assert_eq!(spec.client_bits(xc, rv, rout).len(), spec.n_client_inputs, "{v:?}");
            assert_eq!(spec.server_bits(xc).len(), spec.n_server_inputs, "{v:?}");
            assert_eq!(spec.server_input_base(), spec.n_client_inputs);
        }
    }

    #[test]
    fn spec_k_zero_unless_truncated() {
        assert_eq!(ReluVariant::BaselineRelu.spec().k, 0);
        assert_eq!(ReluVariant::StochasticSign { mode: FaultMode::PosZero }.spec().k, 0);
        assert_eq!(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }.spec().k, 12);
    }
}

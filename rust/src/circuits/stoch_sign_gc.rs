//! Stochastic sign garbled circuit — Fig. 2(c), Eq. 2 (Circa opt. #2),
//! generalized with truncation (Eq. 3) via `k` (`k = 0` is Eq. 2).
//!
//! Drops the exact mod-p reconstruction: the GC contains only a
//! `(m−k)`-bit comparator and an m-bit MUX. Two things happen *outside*
//! the GC at plaintext speed:
//!
//! * the client negates its share and sends `p − ⟨x⟩_c`;
//! * both parties truncate their comparator operands to the top `m−k`
//!   bits, so the circuit has `m−k`-bit share inputs — fewer AND gates
//!   *and* fewer online labels.
//!
//! ```text
//! s̃ign_k(⌊p−⟨x⟩_c⌋_k, ⌊⟨x⟩_s⌋_k, −r, 1−r) = −r   if ⌊⟨x⟩_s⌋_k ≤ ⌊p−⟨x⟩_c⌋_k
//!                                            1−r  otherwise
//! ```
//!
//! NegPass uses strict `<` (§3.2): truncation faults then land on small
//! negatives instead of small positives. Fault probabilities: `|x|/p`
//! (Thm 3.1) plus, for `|x| < 2^k`, `(2^k−|x|)/2^k` (Thm 3.2) —
//! validated in the tests and at scale by `cargo bench --bench fig3`.

use super::spec::FaultMode;
use crate::field::{Fp, FIELD_BITS, PRIME};
use crate::gc::build::{u64_to_bits, Builder};
use crate::gc::circuit::Circuit;

/// Client input bits for truncation level `k`:
/// `⌊p−⟨x⟩_c⌋_k` (m−k bits), `−r` (m bits), `1−r` (m bits).
pub fn n_client_inputs(k: u32) -> usize {
    (FIELD_BITS - k as usize) + 2 * FIELD_BITS
}

/// Server input bits: `⌊⟨x⟩_s⌋_k` (m−k bits).
pub fn n_server_inputs(k: u32) -> usize {
    FIELD_BITS - k as usize
}

/// Build the Fig. 2(c) circuit (`k = 0`).
pub fn build(mode: FaultMode) -> Circuit {
    build_truncated(0, mode)
}

/// Build the Eq. 3 circuit for truncation `k` (shares pre-truncated by
/// the parties, so the comparator buses are `m−k` bits wide).
pub fn build_truncated(k: u32, mode: FaultMode) -> Circuit {
    build_truncated_with(k, mode, Builder::new())
}

/// Build with a caller-supplied (fresh) builder — lets equivalence and
/// gate-count tests construct the pre-CSE reference via
/// [`Builder::new_naive`].
pub fn build_truncated_with(k: u32, mode: FaultMode, mut bld: Builder) -> Circuit {
    let m = FIELD_BITS;
    let k = k as usize;
    assert!(k < m, "truncation must leave at least one bit");
    let w = m - k;
    let neg_xc_t = bld.input_bus(w); // ⌊p − ⟨x⟩_c⌋_k, truncated by client
    let neg_r = bld.input_bus(m);
    let one_minus_r = bld.input_bus(m);
    let xs_t = bld.input_bus(w); // ⌊⟨x⟩_s⌋_k, truncated by server

    // PosZero: negative iff ⌊⟨x⟩_s⌋ ≤ ⌊p−⟨x⟩_c⌋; NegPass: strict <.
    let is_neg = match mode {
        FaultMode::PosZero => bld.leq(&xs_t, &neg_xc_t),
        FaultMode::NegPass => bld.gt(&neg_xc_t, &xs_t),
    };
    let out = bld.mux_bus(is_neg, &neg_r, &one_minus_r);
    bld.output_bus(&out);
    bld.build()
}

/// Plaintext reference of the *stochastic* computation (matches the GC
/// bit-for-bit, including its faults). Returns the server's sign share.
pub fn reference(neg_xc: Fp, xs: Fp, r: Fp, k: u32, mode: FaultMode) -> Fp {
    let a = xs.raw() >> k;
    let b = neg_xc.raw() >> k;
    let is_neg = match mode {
        FaultMode::PosZero => a <= b,
        FaultMode::NegPass => a < b,
    };
    let sign = if is_neg { Fp::ZERO } else { Fp::ONE };
    sign - r
}

/// The client's negated share, computed at plaintext speed.
pub fn negate_share(xc: Fp) -> Fp {
    Fp::new((PRIME - xc.raw()) % PRIME)
}

/// Client input bits in circuit order for truncation `k`.
pub fn client_input_bits(xc: Fp, r: Fp, k: u32) -> Vec<bool> {
    let w = FIELD_BITS - k as usize;
    let mut bits = u64_to_bits(negate_share(xc).raw() >> k, w);
    bits.extend(super::spec::fp_bits(-r));
    bits.extend(super::spec::fp_bits(Fp::ONE - r));
    bits
}

/// Server input bits in circuit order for truncation `k`.
pub fn server_input_bits(xs: Fp, k: u32) -> Vec<bool> {
    u64_to_bits(xs.raw() >> k, FIELD_BITS - k as usize)
}

/// Full input assignment (client block then server block).
pub fn encode_inputs(xc: Fp, xs: Fp, r: Fp, k: u32) -> Vec<bool> {
    let mut bits = client_input_bits(xc, r, k);
    bits.extend(server_input_bits(xs, k));
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::bits_fp;
    use crate::field::random_fp;
    use crate::ss::SharePair;
    use crate::util::Rng;

    fn run_gc(c: &Circuit, xc: Fp, xs: Fp, r: Fp, k: u32) -> Fp {
        bits_fp(&c.eval_plain(&encode_inputs(xc, xs, r, k)))
    }

    #[test]
    fn gc_matches_stochastic_reference() {
        let mut rng = Rng::new(1);
        for mode in [FaultMode::PosZero, FaultMode::NegPass] {
            for k in [0u32, 8, 12, 18] {
                let c = build_truncated(k, mode);
                for _ in 0..150 {
                    let x = random_fp(&mut rng);
                    let t = random_fp(&mut rng);
                    let sh = SharePair::share_with_t(x, t);
                    let r = random_fp(&mut rng);
                    let got = run_gc(&c, sh.client, sh.server, r, k);
                    let want = reference(negate_share(sh.client), sh.server, r, k, mode);
                    assert_eq!(got, want, "k={k} mode={mode:?}");
                }
            }
        }
    }

    #[test]
    fn input_layout_matches_constants() {
        for k in [0u32, 12, 20] {
            let c = build_truncated(k, FaultMode::PosZero);
            assert_eq!(c.n_inputs as usize, n_client_inputs(k) + n_server_inputs(k), "k={k}");
        }
    }

    #[test]
    fn fault_rate_tracks_thm_3_1() {
        // For |x| around p/8 the sign flips with probability ≈ 1/8.
        let mut rng = Rng::new(2);
        let c = build(FaultMode::PosZero);
        let mag = (PRIME / 8) as i64;
        let mut faults = 0u32;
        let n = 2000;
        for _ in 0..n {
            let x = Fp::from_i64(mag);
            let t = random_fp(&mut rng);
            let sh = SharePair::share_with_t(x, t);
            let r = random_fp(&mut rng);
            let v = (run_gc(&c, sh.client, sh.server, r, 0) + r).to_i64();
            if v != 1 {
                faults += 1;
            }
        }
        let rate = faults as f64 / n as f64;
        assert!((rate - 0.125).abs() < 0.03, "rate {rate} != 0.125");
    }

    #[test]
    fn small_magnitudes_rarely_fault_at_k0() {
        let mut rng = Rng::new(3);
        let c = build(FaultMode::PosZero);
        let mut faults = 0;
        let n = 2000;
        for i in 0..n {
            let x = Fp::from_i64(if i % 2 == 0 { 1000 } else { -1000 });
            let t = random_fp(&mut rng);
            let sh = SharePair::share_with_t(x, t);
            let r = random_fp(&mut rng);
            let v = (run_gc(&c, sh.client, sh.server, r, 0) + r).to_i64();
            let want = x.is_nonneg() as i64;
            if v != want {
                faults += 1;
            }
        }
        // P(fault) = 1000/p ≈ 5e-7, so ~zero faults in 2000 trials.
        assert_eq!(faults, 0);
    }

    #[test]
    fn much_cheaper_than_naive_sign() {
        let naive = crate::circuits::sign_gc::build();
        let stoch = build(FaultMode::PosZero);
        assert!(stoch.n_and() * 2 < naive.n_and(), "{} vs {}", stoch.n_and(), naive.n_and());
    }

    #[test]
    fn garbled_roundtrip() {
        let mut rng = Rng::new(4);
        for k in [0u32, 12] {
            let c = build_truncated(k, FaultMode::NegPass);
            let (gc, enc) = crate::gc::garble(&c, &mut rng);
            let x = Fp::from_i64(777_777);
            let t = random_fp(&mut rng);
            let sh = SharePair::share_with_t(x, t);
            let r = random_fp(&mut rng);
            let labels = enc.encode_all(&encode_inputs(sh.client, sh.server, r, k));
            let out = gc.decode(&crate::gc::evaluate(&c, &gc, &labels));
            assert_eq!(
                bits_fp(&out),
                reference(negate_share(sh.client), sh.server, r, k, FaultMode::NegPass)
            );
        }
    }
}

//! Baseline ReLU garbled circuit — Fig. 2(a), the Gazelle/Delphi design.
//!
//! Inputs (in order): client share `⟨x⟩_c`, client randomness `r`, server
//! share `⟨x⟩_s`. The circuit:
//!
//! 1. reconstructs `x = ⟨x⟩_c + ⟨x⟩_s mod p` — an (m+1)-bit add, a
//!    subtract of `p`, and a MUX on the overflow check;
//! 2. compares `x` against `p/2` and MUXes `0` or `x` (the ReLU);
//! 3. outputs the *server's share* of the result: `ReLU(x) − r mod p` —
//!    another subtract / conditional-add-p pair.
//!
//! This is the cost Circa attacks; everything here runs inside the GC.

use crate::field::{Fp, FIELD_BITS, HALF, PRIME};
use crate::gc::build::Builder;
use crate::gc::circuit::Circuit;

/// Input layout of the baseline ReLU circuit.
pub const N_CLIENT_INPUTS: usize = 2 * FIELD_BITS; // ⟨x⟩_c, r
pub const N_SERVER_INPUTS: usize = FIELD_BITS; // ⟨x⟩_s

/// Build the Fig. 2(a) circuit. Output: m-bit bus of `ReLU(x) − r mod p`.
pub fn build() -> Circuit {
    build_with(Builder::new())
}

/// Build with a caller-supplied (fresh) builder — lets equivalence and
/// gate-count tests construct the pre-CSE reference via
/// [`Builder::new_naive`].
pub fn build_with(mut bld: Builder) -> Circuit {
    let m = FIELD_BITS;
    let xc = bld.input_bus(m); // client share
    let r = bld.input_bus(m); // client randomness
    let xs = bld.input_bus(m); // server share

    // x = xc + xs mod p: compute z (m+1 bits) and z - p; select on borrow.
    let xc_ext = bld.zext(&xc, m + 1);
    let xs_ext = bld.zext(&xs, m + 1);
    let (z, _) = bld.add(&xc_ext, &xs_ext);
    let p_bus = bld.const_bus(PRIME, m + 1);
    let (z_minus_p, no_wrap_needed) = bld.sub(&z, &p_bus);
    // If z >= p (no borrow from z-p), take z-p, else z.
    let wrap = bld.not(no_wrap_needed); // wrap==true means z >= p? borrow==1 means z<p
    let x = bld.mux_bus(wrap, &z_minus_p[..m], &z[..m]);

    // ReLU select: x is "negative" iff x ≥ (p−1)/2 in field encoding.
    let half_bus = bld.const_bus(HALF, m);
    let is_neg = bld.geq(&x, &half_bus);
    let is_pos = bld.not(is_neg);
    let zero = bld.const_bus(0, m);
    let relu = bld.mux_bus(is_pos, &x, &zero);

    // Server share: relu - r mod p = relu - r, plus p if it borrowed.
    let (d, borrow) = bld.sub(&relu, &r);
    let d_ext = bld.zext(&d, m + 1);
    let p_bus_m1 = bld.const_bus(PRIME, m + 1);
    let (d_plus_p, _) = bld.add(&d_ext, &p_bus_m1);
    let out = bld.mux_bus(borrow, &d_plus_p[..m], &d);
    bld.output_bus(&out);
    bld.build()
}

/// Plaintext reference of what the circuit computes (for tests and the
/// fault model: the baseline is exact).
pub fn reference(xc: Fp, r: Fp, xs: Fp) -> Fp {
    let x = xc + xs;
    let relu = if x.is_nonneg() { x } else { Fp::ZERO };
    relu - r
}

/// Encode the inputs in circuit order.
pub fn encode_inputs(xc: Fp, r: Fp, xs: Fp) -> Vec<bool> {
    let mut bits = super::spec::fp_bits(xc);
    bits.extend(super::spec::fp_bits(r));
    bits.extend(super::spec::fp_bits(xs));
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::bits_fp;
    use crate::field::random_fp;
    use crate::ss::SharePair;
    use crate::util::Rng;

    #[test]
    fn matches_reference_on_random_shares() {
        let c = build();
        let mut rng = Rng::new(1);
        for _ in 0..300 {
            let x = random_fp(&mut rng);
            let sh = SharePair::share(x, &mut rng);
            let r = random_fp(&mut rng);
            let out = c.eval_plain(&encode_inputs(sh.client, r, sh.server));
            let got = bits_fp(&out);
            assert_eq!(got, reference(sh.client, r, sh.server));
        }
    }

    #[test]
    fn relu_semantics_end_to_end() {
        // Reconstruct client+server outputs: client sets ⟨y⟩_c = r, so
        // y = (ReLU(x) − r) + r must equal ReLU(x).
        let c = build();
        let mut rng = Rng::new(2);
        for signed in [-500_000i64, -77, -1, 0, 1, 42, 123_456] {
            let x = Fp::from_i64(signed);
            let sh = SharePair::share(x, &mut rng);
            let r = random_fp(&mut rng);
            let out_share = bits_fp(&c.eval_plain(&encode_inputs(sh.client, r, sh.server)));
            let y = out_share + r;
            assert_eq!(y.to_i64(), signed.max(0), "x={signed}");
        }
    }

    #[test]
    fn is_exact_for_boundary_values() {
        let c = build();
        let mut rng = Rng::new(3);
        for raw in [0u64, 1, HALF - 1, HALF, HALF + 1, PRIME - 1] {
            let x = Fp::new(raw);
            for _ in 0..20 {
                let sh = SharePair::share(x, &mut rng);
                let r = random_fp(&mut rng);
                let out = bits_fp(&c.eval_plain(&encode_inputs(sh.client, r, sh.server)));
                assert_eq!(out, reference(sh.client, r, sh.server), "raw={raw}");
            }
        }
    }

    #[test]
    fn input_layout_constants() {
        let c = build();
        assert_eq!(c.n_inputs as usize, N_CLIENT_INPUTS + N_SERVER_INPUTS);
        assert_eq!(c.outputs.len(), FIELD_BITS);
    }

    #[test]
    fn garbles_and_evaluates() {
        let c = build();
        let mut rng = Rng::new(4);
        let (gc, enc) = crate::gc::garble(&c, &mut rng);
        let x = Fp::from_i64(-12345);
        let sh = SharePair::share(x, &mut rng);
        let r = random_fp(&mut rng);
        let labels = enc.encode_all(&encode_inputs(sh.client, r, sh.server));
        let out = crate::gc::evaluate(&c, &gc, &labels);
        let got = bits_fp(&gc.decode(&out));
        assert_eq!((got + r).to_i64(), 0);
    }
}

//! Naive sign garbled circuit — Fig. 2(b), Eq. 1 (Circa optimization #1).
//!
//! The ReLU is refactored to `x · sign(x)`; only `sign` stays in the GC
//! and the multiply moves to Beaver triples. The client *pre-computes*
//! `−r` and `1−r` outside the GC (it knows `r` in plaintext), saving two
//! ADD/SUB modules relative to Fig. 2(a). The GC still reconstructs
//! `x = ⟨x⟩_c + ⟨x⟩_s mod p` exactly, so it is fault-free:
//!
//! ```text
//! sign(⟨x⟩_c, ⟨x⟩_s, −r, 1−r) = −r     if x mod p > p/2   (negative)
//!                               1−r    otherwise           (non-negative)
//! ```

use crate::field::{Fp, FIELD_BITS, HALF, PRIME};
use crate::gc::build::Builder;
use crate::gc::circuit::Circuit;

/// Input layout: client `⟨x⟩_c`, `−r`, `1−r`; then server `⟨x⟩_s`.
pub const N_CLIENT_INPUTS: usize = 3 * FIELD_BITS;
pub const N_SERVER_INPUTS: usize = FIELD_BITS;

/// Build the Fig. 2(b) circuit. Output: m-bit bus of `⟨v⟩_s = sign(x) − r`.
pub fn build() -> Circuit {
    build_with(Builder::new())
}

/// Build with a caller-supplied (fresh) builder — lets equivalence and
/// gate-count tests construct the pre-CSE reference via
/// [`Builder::new_naive`].
pub fn build_with(mut bld: Builder) -> Circuit {
    let m = FIELD_BITS;
    let xc = bld.input_bus(m);
    let neg_r = bld.input_bus(m); // −r mod p, precomputed by client
    let one_minus_r = bld.input_bus(m); // 1−r mod p, precomputed by client
    let xs = bld.input_bus(m);

    // Exact reconstruction x = xc + xs mod p (as in the baseline).
    let xc_ext = bld.zext(&xc, m + 1);
    let xs_ext = bld.zext(&xs, m + 1);
    let (z, _) = bld.add(&xc_ext, &xs_ext);
    let p_bus = bld.const_bus(PRIME, m + 1);
    let (z_minus_p, borrow) = bld.sub(&z, &p_bus);
    let wrap = bld.not(borrow);
    let x = bld.mux_bus(wrap, &z_minus_p[..m], &z[..m]);

    // sign select: negative iff x ≥ (p−1)/2.
    let half_bus = bld.const_bus(HALF, m);
    let is_neg = bld.geq(&x, &half_bus);

    // Output −r when negative, 1−r otherwise (Eq. 1).
    let out = bld.mux_bus(is_neg, &neg_r, &one_minus_r);
    bld.output_bus(&out);
    bld.build()
}

/// Plaintext reference: the server's sign share (exact — no faults).
pub fn reference(xc: Fp, xs: Fp, r: Fp) -> Fp {
    let x = xc + xs;
    let sign = if x.is_nonneg() { Fp::ONE } else { Fp::ZERO };
    sign - r
}

/// Encode inputs in circuit order given the plaintext `r`.
pub fn encode_inputs(xc: Fp, xs: Fp, r: Fp) -> Vec<bool> {
    let mut bits = super::spec::fp_bits(xc);
    bits.extend(super::spec::fp_bits(-r));
    bits.extend(super::spec::fp_bits(Fp::ONE - r));
    bits.extend(super::spec::fp_bits(xs));
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::bits_fp;
    use crate::field::random_fp;
    use crate::ss::SharePair;
    use crate::util::Rng;

    #[test]
    fn matches_reference() {
        let c = build();
        let mut rng = Rng::new(1);
        for _ in 0..300 {
            let x = random_fp(&mut rng);
            let sh = SharePair::share(x, &mut rng);
            let r = random_fp(&mut rng);
            let out = bits_fp(&c.eval_plain(&encode_inputs(sh.client, sh.server, r)));
            assert_eq!(out, reference(sh.client, sh.server, r));
        }
    }

    #[test]
    fn sign_reconstructs_to_zero_or_one() {
        let c = build();
        let mut rng = Rng::new(2);
        for signed in [-1_000_000i64, -2, -1, 0, 1, 2, 999_999] {
            let x = Fp::from_i64(signed);
            let sh = SharePair::share(x, &mut rng);
            let r = random_fp(&mut rng);
            let vs = bits_fp(&c.eval_plain(&encode_inputs(sh.client, sh.server, r)));
            let v = vs + r; // client share is r
            let want = if signed >= 0 { 1 } else { 0 };
            assert_eq!(v.to_i64(), want, "x={signed}");
        }
    }

    #[test]
    fn exact_no_faults_exhaustive_small() {
        // The naive sign must be exact for every share split of small x.
        let c = build();
        let mut rng = Rng::new(3);
        for mag in [0i64, 1, 3] {
            for &signv in &[1i64, -1] {
                let x = Fp::from_i64(mag * signv);
                for _ in 0..50 {
                    let t = random_fp(&mut rng);
                    let sh = crate::ss::SharePair::share_with_t(x, t);
                    let r = random_fp(&mut rng);
                    let vs = bits_fp(&c.eval_plain(&encode_inputs(sh.client, sh.server, r)));
                    let v = (vs + r).to_i64();
                    assert_eq!(v, (x.is_nonneg()) as i64);
                }
            }
        }
    }

    #[test]
    fn cheaper_than_baseline() {
        let baseline = crate::circuits::relu_gc::build();
        let sign = build();
        assert!(
            sign.n_and() < baseline.n_and(),
            "sign {} !< baseline {}",
            sign.n_and(),
            baseline.n_and()
        );
    }
}

//! The paper's Fig. 2 ReLU circuit variants.
//!
//! Four generations, each strictly smaller than the last:
//!
//! | variant | module | GC contents | faults |
//! |---|---|---|---|
//! | baseline ReLU (Fig. 2a) | [`relu_gc`] | mod-reconstruct + compare + MUX(0,x) + mod-share | none |
//! | naive sign (Fig. 2b) | [`sign_gc`] | mod-reconstruct + compare + MUX(−r, 1−r) | none |
//! | stochastic sign (Fig. 2c) | [`stoch_sign_gc`] | share compare + MUX | `|x|/p` (Thm 3.1) |
//! | truncated stochastic sign (Eq. 3) | [`trunc_sign_gc`] | (m−k)-bit compare + MUX | + `(2^k−|x|)/2^k` for `|x|<2^k` (Thm 3.2) |
//!
//! [`spec`] carries the shared input/output conventions, the
//! [`spec::ReluVariant`] enum, and the resolved [`spec::VariantSpec`]
//! behavior table the protocol layers dispatch through (circuit builder,
//! input layout, `k`, and both parties' bit encoders). [`template`]
//! memoizes the optimized circuit per variant shape as a process-wide
//! `Arc<Circuit>` cache, so layer deals and material decodes never
//! rebuild a circuit.

pub mod relu_gc;
pub mod sign_gc;
pub mod spec;
pub mod stoch_sign_gc;
pub mod template;
pub mod trunc_sign_gc;

pub use spec::{FaultMode, ReluVariant, VariantSpec};

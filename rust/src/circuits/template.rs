//! Process-wide memoized circuit templates.
//!
//! A layer deal garbles thousands of ReLUs against *one* circuit
//! structure, and a decode of remote material rebuilds the same circuit
//! to derive strides — so the circuit for a [`ReluVariant`] is a pure
//! function of the variant shape and worth building exactly once per
//! process. [`circuit_for`] hands out `Arc<Circuit>` clones of the
//! CSE-built, [`Circuit::optimize`]d template; `gc::batch::LayerGcBatch`
//! holds the shared `Arc` instead of a cloned circuit.
//!
//! This module sits on the decode path (`wire/codec.rs` resolves strides
//! through it for untrusted input), so it is covered by circa-lint r1:
//! no panicking calls — the lock is taken poison-tolerantly and the map
//! is only ever accessed through non-indexing APIs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::spec::{ReluVariant, VariantSpec};
use crate::gc::circuit::Circuit;

static CACHE: OnceLock<Mutex<HashMap<ReluVariant, Arc<Circuit>>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static RAW_FOR_TESTS: AtomicBool = AtomicBool::new(false);

/// Cache hit/miss counters since process start (for benches and metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TemplateStats {
    pub hits: u64,
    pub misses: u64,
}

impl TemplateStats {
    /// Fraction of lookups served from the cache (1.0 when no lookups).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The memoized optimized circuit for a variant shape. First lookup per
/// variant builds (CSE builder + optimizer) and caches; later lookups
/// are a map probe returning a shared `Arc`.
pub fn circuit_for(spec: &VariantSpec) -> Arc<Circuit> {
    if RAW_FOR_TESTS.load(Ordering::Relaxed) {
        // Equivalence-test mode: fresh pre-CSE, pre-optimizer circuits,
        // bypassing (and not polluting) the cache.
        return Arc::new(spec.build_circuit_naive());
    }
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = match cache.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if let Some(c) = map.get(&spec.variant) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return Arc::clone(c);
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let built = Arc::new(spec.build_circuit());
    map.insert(spec.variant, Arc::clone(&built));
    built
}

/// Snapshot the lookup counters.
pub fn stats() -> TemplateStats {
    TemplateStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
    }
}

/// Test/bench hook: when enabled, [`circuit_for`] returns freshly built
/// naive (pre-CSE, unoptimized) circuits, so end-to-end tests can run the
/// whole protocol "before" the optimizer and pin bit-identical logits
/// against the optimized path. Process-global — tests that flip it must
/// serialize among themselves.
pub fn set_raw_templates_for_tests(on: bool) {
    RAW_FOR_TESTS.store(on, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::FaultMode;

    #[test]
    fn lookups_share_one_arc_per_variant() {
        let spec = ReluVariant::StochasticSign { mode: FaultMode::NegPass }.spec();
        let a = circuit_for(&spec);
        let b = circuit_for(&spec);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.validate().is_ok());
        // Cached content matches a fresh optimized build.
        let fresh = spec.build_circuit();
        assert_eq!(a.wires, fresh.wires);
        assert_eq!(a.outputs, fresh.outputs);
    }

    #[test]
    fn stats_move_on_lookup() {
        let spec = ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero }.spec();
        let before = stats();
        let _a = circuit_for(&spec);
        let _b = circuit_for(&spec);
        let after = stats();
        assert!(after.hits + after.misses >= before.hits + before.misses + 2);
        assert!(after.hits > before.hits, "second lookup must hit");
    }
}

//! Loading build-time artifacts: trained demo-model weights and the
//! synthetic evaluation dataset.
//!
//! `python/compile/train.py` trains the demo CNN/MLP at artifact-build
//! time and `aot.py` dumps:
//!
//! * `weights.bin` — magic `CIRCAW01`, then per layer: kind, dims,
//!   quantized int32 weights/bias, rescale bits (see [`load_weights`]);
//! * `dataset.bin` — magic `CIRCAD01`, flattened quantized images +
//!   labels (see [`load_dataset`]).
//!
//! Both use the little-endian framing of [`crate::util::bytes`] —
//! `serde` is not in the offline vendor set.

use crate::bail;
use crate::field::Fp;
use crate::nn::layers::{Conv2d, Dense};
use crate::protocol::linear::LinearOp;
use crate::util::bytes::Reader;
use crate::util::error::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// One loaded (quantized) layer with its post-layer rescale.
pub struct LoadedLayer {
    pub op: Arc<dyn LinearOp>,
    pub rescale_bits: u32,
    pub macs: u64,
    /// Raw quantized tensors + dims as stored on disk — kept so the PJRT
    /// runtime can feed them back as HLO parameters in ABI order.
    pub w_raw: Vec<i32>,
    pub b_raw: Vec<i32>,
    pub w_dims: Vec<i64>,
    pub b_dims: Vec<i64>,
}

/// A loaded network: alternating linear/ReLU with final linear.
pub struct LoadedNet {
    pub name: String,
    pub layers: Vec<LoadedLayer>,
}

impl LoadedNet {
    /// Exact plaintext forward pass (quantized arithmetic, exact ReLU) —
    /// the accuracy baseline the stochastic variants are compared to.
    pub fn forward_exact(&self, input: &[Fp]) -> Vec<Fp> {
        let mut y = input.to_vec();
        for (i, layer) in self.layers.iter().enumerate() {
            y = layer.op.apply(&y);
            if i + 1 < self.layers.len() {
                y = crate::nn::layers::relu_vec(&y);
                y = crate::nn::layers::rescale_vec(&y, layer.rescale_bits);
            }
        }
        y
    }

    /// The linear ops + rescales as a protocol [`NetworkPlan`]
    /// ingredient.
    pub fn linears(&self) -> Vec<Arc<dyn LinearOp>> {
        self.layers.iter().map(|l| l.op.clone()).collect()
    }

    pub fn rescale_bits(&self) -> Vec<u32> {
        // One entry per ReLU layer = all but the last linear.
        self.layers[..self.layers.len() - 1].iter().map(|l| l.rescale_bits).collect()
    }

    pub fn total_relus(&self) -> u64 {
        self.layers[..self.layers.len() - 1].iter().map(|l| l.op.out_dim() as u64).sum()
    }
}

fn fp_from_i32(v: i32) -> Fp {
    Fp::from_i64(v as i64)
}

/// Load `weights.bin`.
pub fn load_weights(path: &Path) -> Result<LoadedNet> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut r = Reader::new(&raw);
    let magic = r.take(8)?;
    if magic != b"CIRCAW01" {
        bail!("bad weights magic {:?}", magic);
    }
    let name = r.string()?;
    let n_layers = r.u32()? as usize;
    let mut layers = Vec::with_capacity(n_layers);
    for li in 0..n_layers {
        let kind = r.u8()?;
        match kind {
            0 => {
                let in_c = r.u32()? as usize;
                let in_h = r.u32()? as usize;
                let in_w = r.u32()? as usize;
                let out_c = r.u32()? as usize;
                let k = r.u32()? as usize;
                let stride = r.u32()? as usize;
                let pad = r.u32()? as usize;
                let w_raw = r.i32_vec()?;
                let b_raw = r.i32_vec()?;
                let rescale_bits = r.u32()?;
                if w_raw.len() != out_c * in_c * k * k {
                    bail!("layer {li}: conv weight size mismatch");
                }
                let weight: Vec<Fp> = w_raw.iter().map(|&v| fp_from_i32(v)).collect();
                let bias: Vec<Fp> = b_raw.iter().map(|&v| fp_from_i32(v)).collect();
                let conv = Conv2d { in_c, in_h, in_w, out_c, k, stride, pad, weight, bias };
                let macs = conv.macs();
                layers.push(LoadedLayer {
                    op: Arc::new(conv),
                    rescale_bits,
                    macs,
                    w_dims: vec![out_c as i64, in_c as i64, k as i64, k as i64],
                    b_dims: vec![out_c as i64],
                    w_raw,
                    b_raw,
                });
            }
            1 => {
                let in_dim = r.u32()? as usize;
                let out_dim = r.u32()? as usize;
                let w_raw = r.i32_vec()?;
                let b_raw = r.i32_vec()?;
                let rescale_bits = r.u32()?;
                if w_raw.len() != in_dim * out_dim {
                    bail!("layer {li}: dense weight size mismatch");
                }
                let weight: Vec<Fp> = w_raw.iter().map(|&v| fp_from_i32(v)).collect();
                let bias: Vec<Fp> = b_raw.iter().map(|&v| fp_from_i32(v)).collect();
                let dense = Dense { in_dim, out_dim, weight, bias };
                let macs = dense.macs();
                layers.push(LoadedLayer {
                    op: Arc::new(dense),
                    rescale_bits,
                    macs,
                    w_dims: vec![out_dim as i64, in_dim as i64],
                    b_dims: vec![out_dim as i64],
                    w_raw,
                    b_raw,
                });
            }
            other => bail!("layer {li}: unknown kind {other}"),
        }
    }
    Ok(LoadedNet { name, layers })
}

/// The evaluation dataset: quantized flattened images + labels.
pub struct Dataset {
    pub n: usize,
    pub dim: usize,
    pub n_classes: usize,
    /// Row-major `n × dim` quantized field elements.
    pub images: Vec<Fp>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[Fp] {
        &self.images[i * self.dim..(i + 1) * self.dim]
    }
}

/// Load `dataset.bin`.
pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut r = Reader::new(&raw);
    let magic = r.take(8)?;
    if magic != b"CIRCAD01" {
        bail!("bad dataset magic {:?}", magic);
    }
    let n = r.u32()? as usize;
    let dim = r.u32()? as usize;
    let n_classes = r.u32()? as usize;
    let images_raw = r.i32_vec()?;
    if images_raw.len() != n * dim {
        bail!("dataset image block size mismatch");
    }
    let images = images_raw.into_iter().map(fp_from_i32).collect();
    let labels: Vec<u32> = (0..n).map(|_| r.u32()).collect::<Result<_>>()?;
    Ok(Dataset { n, dim, n_classes, images, labels })
}

/// Classification accuracy of logits against labels.
pub fn accuracy(logits: &[Vec<Fp>], labels: &[u32]) -> f64 {
    let correct = logits
        .iter()
        .zip(labels)
        .filter(|(l, &y)| {
            let pred = l
                .iter()
                .enumerate()
                .max_by_key(|(_, v)| v.to_i64())
                .map(|(i, _)| i as u32)
                .unwrap();
            pred == y
        })
        .count();
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::Writer;

    fn write_tiny_weights() -> Vec<u8> {
        let mut w = Writer::new();
        w.buf.extend_from_slice(b"CIRCAW01");
        w.string("tiny");
        w.u32(2);
        // conv 1->2, 4x4, k3 s1 p1
        w.u8(0);
        for v in [1u32, 4, 4, 2, 3, 1, 1] {
            w.u32(v);
        }
        w.i32_vec(&vec![1; 2 * 1 * 3 * 3]);
        w.i32_vec(&[0, 0]);
        w.u32(2);
        // dense 32 -> 3
        w.u8(1);
        w.u32(32);
        w.u32(3);
        w.i32_vec(&vec![1; 96]);
        w.i32_vec(&[0, 0, 0]);
        w.u32(0);
        w.buf
    }

    #[test]
    fn weights_roundtrip() {
        let dir = std::env::temp_dir().join("circa_test_weights.bin");
        std::fs::write(&dir, write_tiny_weights()).unwrap();
        let net = load_weights(&dir).unwrap();
        assert_eq!(net.name, "tiny");
        assert_eq!(net.layers.len(), 2);
        assert_eq!(net.layers[0].op.out_dim(), 32);
        assert_eq!(net.rescale_bits(), vec![2]);
        assert_eq!(net.total_relus(), 32);
        let out = net.forward_exact(&vec![Fp::from_i64(4); 16]);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("circa_test_badmagic.bin");
        std::fs::write(&dir, b"NOTMAGIC").unwrap();
        assert!(load_weights(&dir).is_err());
    }

    #[test]
    fn dataset_roundtrip() {
        let mut w = Writer::new();
        w.buf.extend_from_slice(b"CIRCAD01");
        w.u32(2); // n
        w.u32(4); // dim
        w.u32(3); // classes
        w.i32_vec(&[1, 2, 3, 4, 5, 6, 7, 8]);
        w.u32(0);
        w.u32(2);
        let path = std::env::temp_dir().join("circa_test_dataset.bin");
        std::fs::write(&path, &w.buf).unwrap();
        let ds = load_dataset(&path).unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.image(1).iter().map(|v| v.to_i64()).collect::<Vec<_>>(), vec![5, 6, 7, 8]);
        assert_eq!(ds.labels, vec![0, 2]);
    }

    #[test]
    fn accuracy_computation() {
        let logits = vec![
            vec![Fp::from_i64(10), Fp::from_i64(5)],  // pred 0
            vec![Fp::from_i64(-3), Fp::from_i64(2)],  // pred 1
            vec![Fp::from_i64(7), Fp::from_i64(-1)],  // pred 0
        ];
        assert!((accuracy(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-12);
    }
}

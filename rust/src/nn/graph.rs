//! Architecture specs: shapes + ReLU counts, independent of weights.
//!
//! The Tables 1–3 experiments need each network's exact per-layer ReLU
//! counts and MAC counts (the protocol's online ReLU cost is per-element;
//! the linear cost is per-MAC). Specs are cheap descriptions; actual
//! `LinearOp` instances are only materialized for networks small enough
//! to run end-to-end (the demo CNN and unit-test nets).

/// One layer of an architecture spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    Conv {
        in_c: usize,
        in_h: usize,
        in_w: usize,
        out_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
    },
    Dense { in_dim: usize, out_dim: usize },
    Pool2 { c: usize, h: usize, w: usize },
    /// ReLU over `n` elements.
    Relu { n: usize },
}

impl LayerSpec {
    pub fn macs(&self) -> u64 {
        match *self {
            LayerSpec::Conv { in_c, in_h, in_w, out_c, k, stride, pad } => {
                let oh = (in_h + 2 * pad - k) / stride + 1;
                let ow = (in_w + 2 * pad - k) / stride + 1;
                (out_c * oh * ow * in_c * k * k) as u64
            }
            LayerSpec::Dense { in_dim, out_dim } => (in_dim * out_dim) as u64,
            LayerSpec::Pool2 { c, h, w } => (c * h * w) as u64,
            LayerSpec::Relu { .. } => 0,
        }
    }

    pub fn out_dim(&self) -> usize {
        match *self {
            LayerSpec::Conv { in_h, in_w, out_c, k, stride, pad, .. } => {
                let oh = (in_h + 2 * pad - k) / stride + 1;
                let ow = (in_w + 2 * pad - k) / stride + 1;
                out_c * oh * ow
            }
            LayerSpec::Dense { out_dim, .. } => out_dim,
            LayerSpec::Pool2 { c, h, w } => c * (h / 2) * (w / 2),
            LayerSpec::Relu { n } => n,
        }
    }
}

/// A named architecture.
#[derive(Clone, Debug)]
pub struct NetworkSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl NetworkSpec {
    /// Total ReLU count — the paper's headline per-network figure.
    pub fn total_relus(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| if let LayerSpec::Relu { n } = l { *n as u64 } else { 0 })
            .sum()
    }

    /// Total multiply-accumulates in linear layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Sizes of each ReLU layer, in order.
    pub fn relu_layer_sizes(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter_map(|l| if let LayerSpec::Relu { n } = l { Some(*n) } else { None })
            .collect()
    }

    /// ReLU count in thousands, as the paper prints it.
    pub fn relus_k(&self) -> f64 {
        self.total_relus() as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_spec_dims() {
        let c = LayerSpec::Conv { in_c: 3, in_h: 32, in_w: 32, out_c: 64, k: 3, stride: 1, pad: 1 };
        assert_eq!(c.out_dim(), 64 * 32 * 32);
        assert_eq!(c.macs(), 64 * 32 * 32 * 3 * 3 * 3);
    }

    #[test]
    fn strided_conv_dims() {
        let c =
            LayerSpec::Conv { in_c: 64, in_h: 32, in_w: 32, out_c: 128, k: 3, stride: 2, pad: 1 };
        assert_eq!(c.out_dim(), 128 * 16 * 16);
    }

    #[test]
    fn relu_accounting() {
        let net = NetworkSpec {
            name: "t".into(),
            layers: vec![
                LayerSpec::Conv { in_c: 3, in_h: 8, in_w: 8, out_c: 4, k: 3, stride: 1, pad: 1 },
                LayerSpec::Relu { n: 4 * 64 },
                LayerSpec::Dense { in_dim: 256, out_dim: 10 },
            ],
        };
        assert_eq!(net.total_relus(), 256);
        assert_eq!(net.relu_layer_sizes(), vec![256]);
        assert!((net.relus_k() - 0.256).abs() < 1e-12);
    }
}

//! Quantized layers over `F_p`, each implementing
//! [`LinearOp`](crate::protocol::linear::LinearOp) on flattened CHW
//! vectors so the protocol can run them on secret shares.
//!
//! Rescaling: products of two scale-`2^s` fixed-point values carry scale
//! `2^{2s}`. After each multiplying layer the parties truncate their
//! *shares locally* (SecureML / Mohassel–Zhang): correct up to ±1 with
//! probability `1 − |x|·2^{ℓ+1}/p` — see [`truncate_share_local`]. The
//! protocol applies it share-wise; plaintext forward passes apply the
//! exact arithmetic shift.

use crate::field::{Fp, HALF, PRIME};
use crate::protocol::linear::LinearOp;

/// SecureML local share truncation by `d` bits.
///
/// Party 1 (client convention: holds `r`-style shares) computes
/// `⌊z/2^d⌋` on the raw representative; party 2 computes
/// `p − ⌊(p − z)/2^d⌋`. Reconstruction yields `⌊x/2^d⌋ + e`,
/// `e ∈ {−1, 0, +1}`, except with probability ≈ `2^{ℓ_x+1}/p` where
/// `ℓ_x` bounds `|x|` (the same fault-tolerance budget Circa exploits).
pub fn truncate_share_local(share: Fp, d: u32, is_party1: bool) -> Fp {
    if is_party1 {
        Fp::new(share.raw() >> d)
    } else {
        let neg = (PRIME - share.raw()) % PRIME;
        Fp::new((PRIME - (neg >> d)) % PRIME)
    }
}

/// 2-D convolution, stride `s`, zero padding `pad`, no bias folding
/// (bias is added as a public constant server-side — see
/// [`Conv2d::bias`]). Weight layout: `[out_c][in_c][kh][kw]`.
pub struct Conv2d {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub weight: Vec<Fp>,
    pub bias: Vec<Fp>,
}

impl Conv2d {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// MAC count (for the linear cost model of the big-network benches).
    pub fn macs(&self) -> u64 {
        (self.out_c * self.out_h() * self.out_w() * self.in_c * self.k * self.k) as u64
    }
}

impl Conv2d {
    fn apply_inner(&self, input: &[Fp], with_bias: bool) -> Vec<Fp> {
        assert_eq!(input.len(), self.in_dim());
        let (oh, ow) = (self.out_h(), self.out_w());
        let mut out = vec![Fp::ZERO; self.out_c * oh * ow];
        for oc in 0..self.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    // Accumulate in u128 to amortize the modulo: each
                    // product < p² ≈ 2^62; u128 holds ~2^64 of them.
                    let mut acc: u128 = 0;
                    for ic in 0..self.in_c {
                        for ky in 0..self.k {
                            let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                            if iy < 0 || iy >= self.in_h as isize {
                                continue;
                            }
                            for kx in 0..self.k {
                                let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                                if ix < 0 || ix >= self.in_w as isize {
                                    continue;
                                }
                                let w = self.weight
                                    [((oc * self.in_c + ic) * self.k + ky) * self.k + kx];
                                let x = input
                                    [(ic * self.in_h + iy as usize) * self.in_w + ix as usize];
                                acc += w.raw() as u128 * x.raw() as u128;
                            }
                        }
                    }
                    let mut v = Fp::reduce((acc % PRIME as u128) as u64);
                    if with_bias {
                        v = v + self.bias[oc];
                    }
                    out[(oc * oh + oy) * ow + ox] = v;
                }
            }
        }
        out
    }
}

impl LinearOp for Conv2d {
    fn in_dim(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    fn out_dim(&self) -> usize {
        self.out_c * self.out_h() * self.out_w()
    }

    fn apply(&self, input: &[Fp]) -> Vec<Fp> {
        self.apply_inner(input, true)
    }

    fn apply_no_bias(&self, input: &[Fp]) -> Vec<Fp> {
        self.apply_inner(input, false)
    }
}

/// Fully-connected layer; weight layout `[out][in]`, row-major.
pub struct Dense {
    pub in_dim: usize,
    pub out_dim: usize,
    pub weight: Vec<Fp>,
    pub bias: Vec<Fp>,
}

impl Dense {
    pub fn macs(&self) -> u64 {
        (self.in_dim * self.out_dim) as u64
    }
}

impl Dense {
    fn apply_inner(&self, input: &[Fp], with_bias: bool) -> Vec<Fp> {
        assert_eq!(input.len(), self.in_dim);
        let mut out = Vec::with_capacity(self.out_dim);
        for o in 0..self.out_dim {
            let row = &self.weight[o * self.in_dim..(o + 1) * self.in_dim];
            let mut acc: u128 = 0;
            for (w, x) in row.iter().zip(input) {
                acc += w.raw() as u128 * x.raw() as u128;
            }
            let mut v = Fp::reduce((acc % PRIME as u128) as u64);
            if with_bias {
                v = v + self.bias[o];
            }
            out.push(v);
        }
        out
    }
}

impl LinearOp for Dense {
    fn in_dim(&self) -> usize {
        self.in_dim
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }

    fn apply(&self, input: &[Fp]) -> Vec<Fp> {
        self.apply_inner(input, true)
    }

    fn apply_no_bias(&self, input: &[Fp]) -> Vec<Fp> {
        self.apply_inner(input, false)
    }
}

/// 2×2 sum-pool (avg-pool × 4, keeping arithmetic in the field; the ÷4
/// folds into the next layer's weight scale at training time).
pub struct SumPool2 {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl LinearOp for SumPool2 {
    fn in_dim(&self) -> usize {
        self.c * self.h * self.w
    }

    fn out_dim(&self) -> usize {
        self.c * (self.h / 2) * (self.w / 2)
    }

    fn apply(&self, input: &[Fp]) -> Vec<Fp> {
        assert_eq!(input.len(), self.in_dim());
        let (oh, ow) = (self.h / 2, self.w / 2);
        let mut out = vec![Fp::ZERO; self.c * oh * ow];
        for c in 0..self.c {
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = Fp::ZERO;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            acc = acc + input[(c * self.h + 2 * y + dy) * self.w + 2 * x + dx];
                        }
                    }
                    out[(c * oh + y) * ow + x] = acc;
                }
            }
        }
        out
    }
}

/// Exact plaintext ReLU over a vector (reference semantics).
pub fn relu_vec(xs: &[Fp]) -> Vec<Fp> {
    xs.iter().map(|&x| crate::field::relu_exact(x)).collect()
}

/// Exact plaintext rescale over a vector.
pub fn rescale_vec(xs: &[Fp], d: u32) -> Vec<Fp> {
    xs.iter().map(|&x| x.rescale(d)).collect()
}

/// Sanity bound used by tests: a |x| bound for which local share
/// truncation is near-certainly correct (wrap-failure probability
/// ≈ 2·MAG/p ≈ 1.5e-5 per truncation at 2^14).
pub const TRUNC_SAFE_MAG: u64 = 1 << 14;
const _: () = assert!(TRUNC_SAFE_MAG < HALF);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ss::SharePair;
    use crate::util::Rng;

    fn small_conv(rng: &mut Rng) -> Conv2d {
        let (in_c, out_c, k) = (2, 3, 3);
        let weight =
            (0..out_c * in_c * k * k).map(|_| Fp::from_i64(rng.below(9) as i64 - 4)).collect();
        let bias = (0..out_c).map(|_| Fp::from_i64(rng.below(5) as i64 - 2)).collect();
        Conv2d { in_c, in_h: 6, in_w: 6, out_c, k, stride: 1, pad: 1, weight, bias }
    }

    /// Naive i128 reference convolution (signed domain).
    fn conv_ref(c: &Conv2d, input: &[i64]) -> Vec<i64> {
        let (oh, ow) = (c.out_h(), c.out_w());
        let mut out = vec![0i64; c.out_c * oh * ow];
        for oc in 0..c.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for ic in 0..c.in_c {
                        for ky in 0..c.k {
                            for kx in 0..c.k {
                                let iy = (oy * c.stride + ky) as isize - c.pad as isize;
                                let ix = (ox * c.stride + kx) as isize - c.pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= c.in_h as isize
                                    || ix >= c.in_w as isize
                                {
                                    continue;
                                }
                                let w = c.weight[((oc * c.in_c + ic) * c.k + ky) * c.k + kx]
                                    .to_i64();
                                let x = input[(ic * c.in_h + iy as usize) * c.in_w + ix as usize];
                                acc += w * x;
                            }
                        }
                    }
                    out[(oc * oh + oy) * ow + ox] = acc + c.bias[oc].to_i64();
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_signed_reference() {
        let mut rng = Rng::new(1);
        let c = small_conv(&mut rng);
        let input_i: Vec<i64> = (0..c.in_dim()).map(|_| rng.below(41) as i64 - 20).collect();
        let input: Vec<Fp> = input_i.iter().map(|&v| Fp::from_i64(v)).collect();
        let got: Vec<i64> = c.apply(&input).iter().map(|v| v.to_i64()).collect();
        assert_eq!(got, conv_ref(&c, &input_i));
    }

    #[test]
    fn conv_shapes() {
        let mut rng = Rng::new(2);
        let c = small_conv(&mut rng);
        assert_eq!(c.out_h(), 6);
        assert_eq!(c.out_dim(), 3 * 36);
        assert_eq!(c.macs(), (3 * 6 * 6 * 2 * 3 * 3) as u64);
    }

    #[test]
    fn conv_is_linear_over_shares() {
        // apply(c_share) + apply(s_share) − bias must equal apply(x): the
        // bias is added on both shares, so subtract one copy.
        let mut rng = Rng::new(3);
        let c = small_conv(&mut rng);
        let xs: Vec<Fp> =
            (0..c.in_dim()).map(|_| Fp::from_i64(rng.below(21) as i64 - 10)).collect();
        let shares: Vec<SharePair> = xs.iter().map(|&x| SharePair::share(x, &mut rng)).collect();
        let cs: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let ss_: Vec<Fp> = shares.iter().map(|s| s.server).collect();
        let out_c = c.apply(&cs);
        let out_s = c.apply(&ss_);
        let whole = c.apply(&xs);
        for i in 0..whole.len() {
            let oc = i / (c.out_h() * c.out_w());
            let rec = out_c[i] + out_s[i] - c.bias[oc];
            assert_eq!(rec, whole[i]);
        }
    }

    #[test]
    fn dense_matches_reference() {
        let mut rng = Rng::new(4);
        let d = Dense {
            in_dim: 8,
            out_dim: 3,
            weight: (0..24).map(|_| Fp::from_i64(rng.below(9) as i64 - 4)).collect(),
            bias: vec![Fp::from_i64(1); 3],
        };
        let x: Vec<i64> = (0..8).map(|_| rng.below(21) as i64 - 10).collect();
        let xf: Vec<Fp> = x.iter().map(|&v| Fp::from_i64(v)).collect();
        let got = d.apply(&xf);
        for o in 0..3 {
            let want: i64 =
                (0..8).map(|i| d.weight[o * 8 + i].to_i64() * x[i]).sum::<i64>() + 1;
            assert_eq!(got[o].to_i64(), want);
        }
    }

    #[test]
    fn sumpool_sums_quads() {
        let p = SumPool2 { c: 1, h: 4, w: 4 };
        let input: Vec<Fp> = (0..16).map(|i| Fp::from_i64(i as i64)).collect();
        let out = p.apply(&input);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].to_i64(), 0 + 1 + 4 + 5);
        assert_eq!(out[3].to_i64(), 10 + 11 + 14 + 15);
    }

    #[test]
    fn local_truncation_within_one_ulp() {
        let mut rng = Rng::new(5);
        let d = 8u32;
        let mut exact = 0;
        for _ in 0..2000 {
            let mag = rng.below(TRUNC_SAFE_MAG) as i64;
            let x = Fp::from_i64(if rng.bool() { mag } else { -mag });
            let sh = SharePair::share(x, &mut rng);
            let t1 = truncate_share_local(sh.client, d, true);
            let t2 = truncate_share_local(sh.server, d, false);
            let got = (t1 + t2).to_i64();
            let want = x.to_i64() >> d;
            let err = (got - want).abs();
            assert!(err <= 1, "x={} got={got} want={want}", x.to_i64());
            if err == 0 {
                exact += 1;
            }
        }
        assert!(exact > 900, "truncation almost never exact: {exact}/2000");
    }

    #[test]
    fn relu_and_rescale_vec() {
        let xs = vec![Fp::from_i64(-3), Fp::from_i64(5), Fp::from_i64(-1024), Fp::from_i64(1024)];
        let got: Vec<i64> = relu_vec(&xs).iter().map(|v| v.to_i64()).collect();
        assert_eq!(got, vec![0, 5, 0, 1024]);
        // Arithmetic shift: −3 >> 2 = −1 (rounds toward −∞).
        assert_eq!(
            rescale_vec(&xs, 2).iter().map(|v| v.to_i64()).collect::<Vec<_>>(),
            vec![-1, 1, -256, 256]
        );
    }
}

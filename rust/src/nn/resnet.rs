//! ResNet-18 and ResNet-32 architecture specs (He et al. 2016), CIFAR
//! (32×32) and TinyImageNet (64×64) variants, with ReLU counts matching
//! the paper's Table 1 exactly:
//!
//! | network | dataset | #ReLUs |
//! |---|---|---|
//! | ResNet-32 | C10/C100 | 303.1 K |
//! | ResNet-18 | C10/C100 | 557.1 K |
//! | ResNet-32 | Tiny | 1212.4 K |
//! | ResNet-18 | Tiny | 2228.2 K |

use super::graph::{LayerSpec, NetworkSpec};

/// A basic residual block: two 3×3 convs, two ReLUs (one post-add), plus
/// a 1×1 projection shortcut when shape changes.
fn basic_block(layers: &mut Vec<LayerSpec>, in_c: usize, out_c: usize, hw: usize, stride: usize) {
    let out_hw = hw / stride;
    layers.push(LayerSpec::Conv { in_c, in_h: hw, in_w: hw, out_c, k: 3, stride, pad: 1 });
    layers.push(LayerSpec::Relu { n: out_c * out_hw * out_hw });
    layers.push(LayerSpec::Conv {
        in_c: out_c,
        in_h: out_hw,
        in_w: out_hw,
        out_c,
        k: 3,
        stride: 1,
        pad: 1,
    });
    if stride != 1 || in_c != out_c {
        layers.push(LayerSpec::Conv { in_c, in_h: hw, in_w: hw, out_c, k: 1, stride, pad: 0 });
    }
    // Post-addition ReLU.
    layers.push(LayerSpec::Relu { n: out_c * out_hw * out_hw });
}

/// ImageNet-style ResNet-18 adapted to small inputs (3×3 stem, no
/// max-pool), the standard CIFAR adaptation. `hw` is the input spatial
/// size (32 for CIFAR, 64 for Tiny). `scale` multiplies channel widths
/// (used by the DeepReDuce variants); `relu_stage_mask[i]` keeps the
/// ReLUs of stage `i` (0 = stem, 1..=4 = residual stages).
pub fn resnet18_masked(
    hw: usize,
    classes: usize,
    scale: f64,
    relu_stage_mask: [bool; 5],
    name: &str,
) -> NetworkSpec {
    let ch = |c: usize| -> usize { ((c as f64 * scale).round() as usize).max(1) };
    let mut layers = Vec::new();
    let stem_c = ch(64);
    layers.push(LayerSpec::Conv {
        in_c: 3,
        in_h: hw,
        in_w: hw,
        out_c: stem_c,
        k: 3,
        stride: 1,
        pad: 1,
    });
    layers.push(LayerSpec::Relu { n: stem_c * hw * hw });

    let mut cur_hw = hw;
    let mut in_c = stem_c;
    let stage_channels = [64, 128, 256, 512];
    for (si, &c) in stage_channels.iter().enumerate() {
        let out_c = ch(c);
        let stride = if si == 0 { 1 } else { 2 };
        basic_block(&mut layers, in_c, out_c, cur_hw, stride);
        cur_hw /= stride;
        basic_block(&mut layers, out_c, out_c, cur_hw, 1);
        in_c = out_c;
    }

    // Global average pool (sum-pool chain) + classifier.
    layers.push(LayerSpec::Dense { in_dim: in_c, out_dim: classes });

    // Apply the stage mask by deleting Relu entries belonging to masked
    // stages. Stage boundaries: stem relu is index 1; each stage has 4
    // relus (2 blocks × 2).
    let spec = NetworkSpec { name: name.into(), layers };
    apply_stage_mask(spec, relu_stage_mask)
}

/// Standard ResNet-18.
pub fn resnet18(hw: usize, classes: usize) -> NetworkSpec {
    resnet18_masked(hw, classes, 1.0, [true; 5], &format!("ResNet18-{hw}"))
}

/// CIFAR-style ResNet-32: 3 stages × 5 basic blocks, 16/32/64 channels.
pub fn resnet32(hw: usize, classes: usize) -> NetworkSpec {
    let mut layers = Vec::new();
    layers
        .push(LayerSpec::Conv { in_c: 3, in_h: hw, in_w: hw, out_c: 16, k: 3, stride: 1, pad: 1 });
    layers.push(LayerSpec::Relu { n: 16 * hw * hw });
    let mut cur_hw = hw;
    let mut in_c = 16;
    for (si, &c) in [16usize, 32, 64].iter().enumerate() {
        for b in 0..5 {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            basic_block(&mut layers, in_c, c, cur_hw, stride);
            cur_hw /= stride;
            in_c = c;
        }
    }
    layers.push(LayerSpec::Dense { in_dim: 64, out_dim: classes });
    NetworkSpec { name: format!("ResNet32-{hw}"), layers }
}

/// Remove the ReLU layers of masked-out stages (DeepReDuce-style culling:
/// the convs stay, the activations become identity).
fn apply_stage_mask(spec: NetworkSpec, mask: [bool; 5]) -> NetworkSpec {
    // Relu entries in resnet18 order: stem (1), then 4 per stage.
    let mut relu_idx = 0usize;
    let layers = spec
        .layers
        .into_iter()
        .filter(|l| {
            if let LayerSpec::Relu { .. } = l {
                let stage = if relu_idx == 0 { 0 } else { 1 + (relu_idx - 1) / 4 };
                relu_idx += 1;
                mask[stage]
            } else {
                true
            }
        })
        .collect();
    NetworkSpec { name: spec.name, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_cifar_relu_count_matches_table1() {
        assert_eq!(resnet18(32, 10).total_relus(), 557_056); // 557.1K
    }

    #[test]
    fn resnet18_tiny_relu_count_matches_table1() {
        assert_eq!(resnet18(64, 200).total_relus(), 2_228_224); // 2228.2K
    }

    #[test]
    fn resnet32_cifar_relu_count_matches_table1() {
        assert_eq!(resnet32(32, 10).total_relus(), 303_104); // 303.1K
    }

    #[test]
    fn resnet32_tiny_relu_count_matches_table1() {
        assert_eq!(resnet32(64, 200).total_relus(), 1_212_416); // 1212.4K
    }

    #[test]
    fn stage_mask_removes_relus_only() {
        let full = resnet18(32, 10);
        let masked = resnet18_masked(32, 10, 1.0, [true, false, true, false, true], "m");
        assert!(masked.total_relus() < full.total_relus());
        // Linear structure unchanged: same MACs.
        assert_eq!(masked.total_macs(), full.total_macs());
    }

    #[test]
    fn relu_layer_count_structure() {
        // ResNet-18: 1 stem + 8 blocks × 2 = 17 ReLU layers.
        assert_eq!(resnet18(32, 10).relu_layer_sizes().len(), 17);
        // ResNet-32: 1 stem + 15 blocks × 2 = 31 ReLU layers.
        assert_eq!(resnet32(32, 10).relu_layer_sizes().len(), 31);
    }

    #[test]
    fn macs_are_plausible() {
        // ResNet-18 CIFAR ≈ 0.56 GMACs (standard figure ±shortcuts).
        let macs = resnet18(32, 10).total_macs();
        assert!(macs > 400_000_000 && macs < 700_000_000, "{macs}");
    }
}

//! Quantized neural networks over `F_p` and the paper's network zoo.
//!
//! Two distinct consumers:
//!
//! * the **protocol path** (Tables 1–3): [`layers`] implement
//!   [`crate::protocol::linear::LinearOp`] so real conv/dense layers run
//!   inside the 2-party protocol; [`graph`] chains them and counts ReLUs;
//!   [`resnet`]/[`vgg`]/[`deepreduce`] give the *architecture specs* with
//!   the paper's exact ReLU counts (§4.1: ResNet-18/32, VGG-16 on
//!   CIFAR/Tiny shapes, DeepReDuce D1–D6);
//! * the **accuracy path** (Figs. 3–4): weights trained at build time by
//!   `python/compile/train.py` are loaded by [`weights`] and either run
//!   through the protocol (demo CNN) or through the PJRT runtime.
//!
//! Fixed-point semantics follow Delphi (15-bit signed quantization,
//! 31-bit prime), with SecureML-style *local share truncation* after each
//! multiplying layer — a stochastic rescale whose ±1 off-by-one faults
//! are exactly the class of noise Circa's fault-tolerance argument
//! already embraces (DESIGN.md §4).

pub mod deepreduce;
pub mod graph;
pub mod layers;
pub mod resnet;
pub mod tensor;
pub mod vgg;
pub mod weights;

pub use graph::{LayerSpec, NetworkSpec};
pub use tensor::Tensor;

//! DeepReDuce-optimized ResNet-18 variants (Jha et al., ICML 2021) — the
//! state-of-the-art ReLU-culled models Circa stacks on in Table 2.
//!
//! DeepReDuce removes whole ReLU *stages* (convs stay; activations become
//! identity) and optionally scales channel widths. The six configurations
//! below reproduce the paper's Table 2 ReLU counts exactly:
//!
//! | model | mask (stem, s1..s4) | width | C100 #ReLUs | Tiny #ReLUs |
//! |---|---|---|---|---|
//! | D1 | stem+s2+s4 | 1.0  | 229.4 K | 917.5 K |
//! | D2 | stem+s2+s4 | 0.5  | 114.7 K | 458.8 K |
//! | D3 | stem+s2    | 1.0  | 196.6 K | —       |
//! | D4 | stem+s2    | 0.5  |  98.3 K | —       |
//! | D5 | stem+s4    | 1.0  | —       | 393.2 K |
//! | D6 | stem+s2+s4 | 0.25 | —       | 229.4 K |

use super::graph::NetworkSpec;
use super::resnet::resnet18_masked;

/// Configuration of one DeepReDuce variant.
#[derive(Clone, Copy, Debug)]
pub struct DeepReDuceCfg {
    pub id: u32,
    pub mask: [bool; 5],
    pub scale: f64,
}

/// The six Table 2 configurations.
pub const CONFIGS: [DeepReDuceCfg; 6] = [
    DeepReDuceCfg { id: 1, mask: [true, false, true, false, true], scale: 1.0 },
    DeepReDuceCfg { id: 2, mask: [true, false, true, false, true], scale: 0.5 },
    DeepReDuceCfg { id: 3, mask: [true, false, true, false, false], scale: 1.0 },
    DeepReDuceCfg { id: 4, mask: [true, false, true, false, false], scale: 0.5 },
    DeepReDuceCfg { id: 5, mask: [true, false, false, false, true], scale: 1.0 },
    DeepReDuceCfg { id: 6, mask: [true, false, true, false, true], scale: 0.25 },
];

/// Build DeepReDuce variant `id` (1–6) at input size `hw`.
pub fn deepreduce(id: u32, hw: usize, classes: usize) -> NetworkSpec {
    let cfg = CONFIGS
        .iter()
        .find(|c| c.id == id)
        .unwrap_or_else(|| panic!("no DeepReDuce variant {id}"));
    resnet18_masked(hw, classes, cfg.scale, cfg.mask, &format!("DeepReD{id}-{hw}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c100_relu_counts_match_table2() {
        assert_eq!(deepreduce(1, 32, 100).total_relus(), 229_376); // 229.4K
        assert_eq!(deepreduce(2, 32, 100).total_relus(), 114_688); // 114.7K
        assert_eq!(deepreduce(3, 32, 100).total_relus(), 196_608); // 196.6K
        assert_eq!(deepreduce(4, 32, 100).total_relus(), 98_304); // 98.3K
    }

    #[test]
    fn tiny_relu_counts_match_table2() {
        assert_eq!(deepreduce(1, 64, 200).total_relus(), 917_504); // 917.5K
        assert_eq!(deepreduce(2, 64, 200).total_relus(), 458_752); // 458.8K
        assert_eq!(deepreduce(5, 64, 200).total_relus(), 393_216); // 393.2K
        assert_eq!(deepreduce(6, 64, 200).total_relus(), 229_376); // 229.4K
    }

    #[test]
    #[should_panic]
    fn unknown_variant_panics() {
        deepreduce(9, 32, 100);
    }

    #[test]
    fn width_scaling_shrinks_macs() {
        let d1 = deepreduce(1, 32, 100).total_macs();
        let d2 = deepreduce(2, 32, 100).total_macs();
        assert!(d2 < d1 / 3, "half-width should be ~¼ MACs: {d2} vs {d1}");
    }
}

//! VGG-16 spec (Simonyan & Zisserman), CIFAR/Tiny adaptation with two
//! 4096-wide FC layers — ReLU counts match Table 1:
//! 284.7 K at 32×32, 1114.1 K at 64×64.

use super::graph::{LayerSpec, NetworkSpec};

const CFG: [&[usize]; 5] =
    [&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];

/// VGG-16 at input size `hw` (32 for CIFAR, 64 for Tiny).
pub fn vgg16(hw: usize, classes: usize) -> NetworkSpec {
    let mut layers = Vec::new();
    let mut in_c = 3;
    let mut cur = hw;
    for block in CFG {
        for &c in block {
            layers.push(LayerSpec::Conv {
                in_c,
                in_h: cur,
                in_w: cur,
                out_c: c,
                k: 3,
                stride: 1,
                pad: 1,
            });
            layers.push(LayerSpec::Relu { n: c * cur * cur });
            in_c = c;
        }
        layers.push(LayerSpec::Pool2 { c: in_c, h: cur, w: cur });
        cur /= 2;
    }
    let flat = in_c * cur * cur;
    layers.push(LayerSpec::Dense { in_dim: flat, out_dim: 4096 });
    layers.push(LayerSpec::Relu { n: 4096 });
    layers.push(LayerSpec::Dense { in_dim: 4096, out_dim: 4096 });
    layers.push(LayerSpec::Relu { n: 4096 });
    layers.push(LayerSpec::Dense { in_dim: 4096, out_dim: classes });
    NetworkSpec { name: format!("VGG16-{hw}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar_relu_count_matches_table1() {
        assert_eq!(vgg16(32, 10).total_relus(), 284_672); // 284.7K
    }

    #[test]
    fn tiny_relu_count_matches_table1() {
        assert_eq!(vgg16(64, 200).total_relus(), 1_114_112); // 1114.1K
    }

    #[test]
    fn thirteen_conv_plus_two_fc_relus() {
        assert_eq!(vgg16(32, 10).relu_layer_sizes().len(), 15);
    }
}

//! Minimal dense tensor of field elements (NCHW conventions, N folded
//! out — the protocol processes one example at a time).

use crate::field::Fp;

/// A shaped buffer of field elements.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<Fp>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![Fp::ZERO; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<Fp>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// CHW indexing.
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> Fp {
        let (ch, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        debug_assert!(c < ch && h < hh && w < ww);
        self.data[(c * hh + h) * ww + w]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, h: usize, w: usize, v: Fp) {
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w] = v;
    }

    /// Elementwise signed decode (for assertions/metrics).
    pub fn to_i64(&self) -> Vec<i64> {
        self.data.iter().map(|x| x.to_i64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::zeros(&[3, 4, 5]);
        assert_eq!(t.len(), 60);
        assert!(!t.is_empty());
    }

    #[test]
    fn chw_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, Fp::from_i64(7));
        assert_eq!(t.at3(1, 2, 3).to_i64(), 7);
        assert_eq!(t.data[(1 * 3 + 2) * 4 + 3].to_i64(), 7);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        Tensor::from_vec(&[2, 2], vec![Fp::ZERO; 3]);
    }
}

//! Monte-Carlo fault-rate measurement — the "implementation" points of
//! Fig. 3(b), measured through the same decision rule as the garbled
//! comparator (and cross-checked against the *actual* GC evaluator in
//! the integration tests).

use super::{fault_prob, sample_sign};
use crate::circuits::spec::FaultMode;
use crate::field::Fp;
use crate::util::Rng;

/// Empirical vs model fault rates over a population of activations.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultRates {
    /// Fraction of all activations that faulted.
    pub total_measured: f64,
    /// Fraction of positive activations that faulted.
    pub positive_measured: f64,
    /// Model predictions for the same population.
    pub total_model: f64,
    pub positive_model: f64,
}

/// Measure fault rates of `s̃ign_k` over the given activations,
/// `reps` share-samplings per activation.
pub fn measure(xs: &[Fp], k: u32, mode: FaultMode, reps: usize, rng: &mut Rng) -> FaultRates {
    let mut total_faults = 0u64;
    let mut pos_faults = 0u64;
    let mut pos_count = 0u64;
    let mut total_model = 0.0;
    let mut pos_model = 0.0;

    for &x in xs {
        let p = fault_prob(x, k, mode);
        total_model += p;
        let is_pos = x.is_nonneg();
        if is_pos {
            pos_model += p;
            pos_count += reps as u64;
        }
        for _ in 0..reps {
            let got = sample_sign(x, k, mode, rng);
            if got != is_pos {
                total_faults += 1;
                if is_pos {
                    pos_faults += 1;
                }
            }
        }
    }

    let n = (xs.len() * reps) as f64;
    FaultRates {
        total_measured: total_faults as f64 / n,
        positive_measured: if pos_count > 0 { pos_faults as f64 / pos_count as f64 } else { 0.0 },
        total_model: total_model / xs.len() as f64,
        positive_model: if xs.iter().any(|x| x.is_nonneg()) {
            pos_model / xs.iter().filter(|x| x.is_nonneg()).count() as f64
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A plausible activation population: mixed signs, mostly small.
    fn population(rng: &mut Rng) -> Vec<Fp> {
        (0..2000)
            .map(|_| {
                let mag = (rng.f64().powi(3) * (1 << 20) as f64) as i64;
                Fp::from_i64(if rng.bool() { mag } else { -mag })
            })
            .collect()
    }

    #[test]
    fn measured_tracks_model() {
        let mut rng = Rng::new(1);
        let xs = population(&mut rng);
        for k in [10u32, 14, 18] {
            let rates = measure(&xs, k, FaultMode::PosZero, 4, &mut rng);
            assert!(
                (rates.total_measured - rates.total_model).abs() < 0.02,
                "k={k}: {rates:?}"
            );
            assert!(
                (rates.positive_measured - rates.positive_model).abs() < 0.03,
                "k={k}: {rates:?}"
            );
        }
    }

    #[test]
    fn rates_increase_with_k() {
        let mut rng = Rng::new(2);
        let xs = population(&mut rng);
        let lo = measure(&xs, 8, FaultMode::PosZero, 2, &mut rng);
        let hi = measure(&xs, 20, FaultMode::PosZero, 2, &mut rng);
        assert!(hi.total_measured > lo.total_measured);
    }

    #[test]
    fn poszero_faults_are_mostly_positive() {
        // With symmetric activations, PosZero's faults concentrate on the
        // positive side: positive rate > total rate.
        let mut rng = Rng::new(3);
        let xs = population(&mut rng);
        let r = measure(&xs, 16, FaultMode::PosZero, 2, &mut rng);
        assert!(r.positive_measured > r.total_measured);
    }
}

//! Closed-form stochastic-ReLU fault model (Thms 3.1 & 3.2) and the
//! functional fault simulator used by the accuracy experiments.
//!
//! Two fault sources compose:
//!
//! * **sign fault** (truncation-independent): probability `|x|/p` for all
//!   `x` — the share comparison misfires when `x + t` wraps;
//! * **truncation fault**: for `|x| < 2^k`, probability `(2^k − |x|)/2^k`
//!   on the PosZero side (positives zeroed) or NegPass side (negatives
//!   passed through).
//!
//! [`fault_prob`] is the model line plotted in Fig. 3; [`apply`] is the
//! bit-exact sampler (identical decision rule to the GC comparator —
//! validated against the real evaluator in `rust/tests/fault_model.rs`
//! and at scale by `cargo bench --bench fig3`); [`montecarlo`] measures
//! empirical rates for the model-vs-implementation overlay.

pub mod montecarlo;

use crate::circuits::spec::FaultMode;
use crate::field::{random_fp, Fp, PRIME};
use crate::util::Rng;

/// Closed-form fault probability of `s̃ign_k` for input `x` (Fig. 3a's
/// model line): sign fault + truncation fault (disjoint events to first
/// order; the truncation term only applies inside `[0, 2^k)`).
pub fn fault_prob(x: Fp, k: u32, mode: FaultMode) -> f64 {
    let sign_term = x.magnitude() as f64 / PRIME as f64;
    let trunc_term = crate::circuits::trunc_sign_gc::trunc_fault_prob(x, k, mode);
    (sign_term + trunc_term).min(1.0)
}

/// Sample the stochastic sign of `x` exactly as the GC computes it:
/// draw `t`, form shares, compare truncated raw shares.
/// Returns the computed sign bit (`true` = non-negative).
pub fn sample_sign(x: Fp, k: u32, mode: FaultMode, rng: &mut Rng) -> bool {
    let t = random_fp(rng);
    sample_sign_with_t(x, t, k, mode)
}

/// Deterministic core of [`sample_sign`] (also used to cross-check the
/// GC evaluator on identical `t`).
pub fn sample_sign_with_t(x: Fp, t: Fp, k: u32, mode: FaultMode) -> bool {
    // ⟨x⟩_s = x + t, ⟨x⟩_c = p − t, client sends p − ⟨x⟩_c = t.
    let xs = (x.raw() + t.raw()) % PRIME;
    let a = xs >> k;
    let b = t.raw() >> k;
    let is_neg = match mode {
        FaultMode::PosZero => a <= b,
        FaultMode::NegPass => a < b,
    };
    !is_neg
}

/// Apply the stochastic ReLU to one value: `y = x · s̃ign_k(x)`.
pub fn apply(x: Fp, k: u32, mode: FaultMode, rng: &mut Rng) -> Fp {
    if sample_sign(x, k, mode, rng) {
        x
    } else {
        Fp::ZERO
    }
}

/// Apply over a slice, counting faults against the exact sign.
pub fn apply_vec(xs: &[Fp], k: u32, mode: FaultMode, rng: &mut Rng) -> (Vec<Fp>, u64) {
    let mut faults = 0;
    let out = xs
        .iter()
        .map(|&x| {
            let s = sample_sign(x, k, mode, rng);
            if s != x.is_nonneg() {
                faults += 1;
            }
            if s {
                x
            } else {
                Fp::ZERO
            }
        })
        .collect();
    (out, faults)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_input_sign_always_flips_but_relu_is_correct() {
        // x = 0 under PosZero: the comparison `t ≤ t` always fires, so the
        // *sign* is formally wrong with probability 1 (the model says so),
        // yet ReLU(0) = 0·v = 0 is the correct value either way.
        assert_eq!(fault_prob(Fp::ZERO, 0, FaultMode::PosZero), 1.0);
        assert_eq!(fault_prob(Fp::ZERO, 12, FaultMode::PosZero), 1.0);
        let mut rng = Rng::new(9);
        assert_eq!(apply(Fp::ZERO, 12, FaultMode::PosZero, &mut rng), Fp::ZERO);
        // NegPass uses strict `<`: x = 0 compares t < t = false ⇒ sign
        // correct ⇒ no fault at k = 0.
        assert_eq!(fault_prob(Fp::ZERO, 0, FaultMode::NegPass), 0.0);
    }

    #[test]
    fn model_symmetry() {
        // Sign term symmetric in |x|; trunc term side-dependent.
        let k = 12;
        let pos = Fp::from_i64(100);
        let neg = Fp::from_i64(-100);
        assert!(fault_prob(pos, k, FaultMode::PosZero) > 0.9);
        assert!(fault_prob(neg, k, FaultMode::PosZero) < 1e-3);
        assert!(fault_prob(neg, k, FaultMode::NegPass) > 0.9);
        assert!(fault_prob(pos, k, FaultMode::NegPass) < 1e-3);
    }

    #[test]
    fn sampler_matches_model_probability() {
        let mut rng = Rng::new(1);
        let k = 14;
        for &mag in &[100i64, 4000, 16000, 1 << 14, 1 << 20] {
            let x = Fp::from_i64(mag);
            let want = fault_prob(x, k, FaultMode::PosZero);
            let n = 4000;
            let mut faults = 0;
            for _ in 0..n {
                if sample_sign(x, k, FaultMode::PosZero, &mut rng) != x.is_nonneg() {
                    faults += 1;
                }
            }
            let got = faults as f64 / n as f64;
            assert!((got - want).abs() < 0.03, "mag={mag} got={got} want={want}");
        }
    }

    #[test]
    fn apply_zeroes_or_passes() {
        let mut rng = Rng::new(2);
        let x = Fp::from_i64(123_456);
        let y = apply(x, 12, FaultMode::PosZero, &mut rng);
        assert!(y == x || y == Fp::ZERO);
    }

    #[test]
    fn apply_vec_fault_count_consistency() {
        let mut rng = Rng::new(3);
        // All values deep inside the truncation range: ~100% faults.
        let xs = vec![Fp::from_i64(1); 256];
        let (out, faults) = apply_vec(&xs, 16, FaultMode::PosZero, &mut rng);
        assert!(faults > 250, "faults={faults}");
        assert!(out.iter().filter(|v| **v == Fp::ZERO).count() > 250);
    }
}

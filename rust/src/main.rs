//! `circa` — the PI serving coordinator CLI.
//!
//! ```text
//! circa serve   [--requests N] [--workers W] [--k K] [--mode poszero|negpass|baseline]
//! circa sizes                       # Fig. 5 circuit sizes
//! circa sweep   [--batches N]       # Fig. 4 truncation sweep (PJRT)
//! circa info                        # artifact + network zoo summary
//! ```
//!
//! The experiment drivers live in `cargo bench` (one per paper table /
//! figure) and `examples/`; this binary is the long-running service
//! entrypoint plus quick introspection.

use circa::util::error::Result;
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{PiService, ServiceConfig};
use circa::nn::weights::{load_dataset, load_weights};
use circa::protocol::server::NetworkPlan;
use circa::runtime::ArtifactDir;
use circa::util::args::Args;
use circa::util::{Rng, Timer};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => serve(&args),
        Some("sizes") => {
            sizes();
            Ok(())
        }
        Some("info") => info(),
        Some("sweep") => {
            println!("run: cargo run --release --example sweep_truncation");
            Ok(())
        }
        Some("perf") => {
            perf(&args);
            Ok(())
        }
        _ => {
            println!("usage: circa <serve|sizes|sweep|info> [options]");
            println!("  serve  --requests N --workers W --k K --mode poszero|negpass|baseline");
            println!("  sizes  (Fig. 5 per-ReLU GC sizes)");
            println!("  info   (artifacts + network zoo)");
            Ok(())
        }
    }
}

fn variant_from(args: &Args) -> ReluVariant {
    let k = args.get_u64("k", 12) as u32;
    match args.get_or("mode", "poszero") {
        "baseline" => ReluVariant::BaselineRelu,
        "sign" => ReluVariant::NaiveSign,
        m => ReluVariant::TruncatedSign {
            k,
            mode: FaultMode::parse(m).unwrap_or(FaultMode::PosZero),
        },
    }
}

fn serve(args: &Args) -> Result<()> {
    let dir = ArtifactDir::discover()?;
    let net = load_weights(&dir.path("weights.bin"))?;
    let ds = load_dataset(&dir.path("dataset.bin"))?;
    let variant = variant_from(args);
    let n = args.get_usize("requests", 32);
    let workers = args.get_usize("workers", 4);
    println!(
        "serving {} with {} ({} ReLUs/inference) — {n} requests, {workers} workers",
        net.name,
        variant.name(),
        net.total_relus()
    );

    let plan = Arc::new(NetworkPlan {
        linears: net.linears(),
        variant,
        rescale_bits: net.rescale_bits(),
    });
    let svc = PiService::start(
        plan,
        ServiceConfig { workers, pool_target: 32, pool_dealers: workers, ..Default::default() },
    );
    svc.warmup(8);

    let t = Timer::new();
    let mut rng = Rng::new(1);
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let idx = rng.below_usize(ds.n);
            (idx, svc.submit(ds.image(idx).to_vec()).expect("submit"))
        })
        .collect();
    let mut correct = 0;
    for (idx, rx) in rxs {
        let resp = rx.recv().expect("service");
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.to_i64())
            .map(|(c, _)| c as u32)
            .unwrap();
        if pred == ds.labels[idx] {
            correct += 1;
        }
    }
    let wall = t.elapsed_s();
    let snap = svc.metrics.snapshot();
    println!("done: {n} inferences in {wall:.2}s ({:.1} inf/s)", n as f64 / wall);
    println!("accuracy {:.1}%", 100.0 * correct as f64 / n as f64);
    println!(
        "latency: online p50 {:.1} ms, p99 {:.1} ms; queue mean {:.1} ms; dry leases {}",
        snap.online_p50_us as f64 / 1e3,
        snap.online_p99_us as f64 / 1e3,
        snap.queue_mean_us / 1e3,
        snap.pool_dry_events
    );
    svc.shutdown();
    Ok(())
}

/// Hot-path microbenchmark used by the §Perf iteration log.
fn perf(args: &Args) {
    use circa::bench_harness::relu_cost;
    let sample = args.get_usize("sample", 20_000);
    let mut rng = Rng::new(0xBEEF);
    for (name, variant) in [
        ("baseline ReLU GC", ReluVariant::BaselineRelu),
        ("circa ~sign_12", ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }),
    ] {
        let c = relu_cost(variant, sample, &mut rng);
        println!(
            "{name:<18} offline {:>7.2} us/ReLU   online {:>6.2} us/ReLU   {:>5.0} B online",
            c.offline_s * 1e6,
            c.online_s * 1e6,
            c.online_bytes
        );
    }
}

fn sizes() {
    use circa::circuits::{relu_gc, sign_gc, stoch_sign_gc};
    use circa::gc::size::CircuitCost;
    println!("per-ReLU garbled circuit sizes (31-bit field):");
    let rows: Vec<(String, CircuitCost)> = vec![
        ("ReLU (baseline)".into(), CircuitCost::of(&relu_gc::build())),
        ("Sign (naive)".into(), CircuitCost::of(&sign_gc::build())),
        ("~Sign".into(), CircuitCost::of(&stoch_sign_gc::build(FaultMode::PosZero))),
        (
            "~Sign_12".into(),
            CircuitCost::of(&stoch_sign_gc::build_truncated(12, FaultMode::PosZero)),
        ),
    ];
    for (name, c) in rows {
        println!("  {name:<18} {c}");
    }
}

fn info() -> Result<()> {
    match ArtifactDir::discover() {
        Ok(dir) => {
            println!("artifacts: {}", dir.root.display());
            let net = load_weights(&dir.path("weights.bin"))?;
            let ds = load_dataset(&dir.path("dataset.bin"))?;
            println!(
                "  demo model {}: {} layers, {} ReLUs; dataset: {} images, {} classes",
                net.name,
                net.layers.len(),
                net.total_relus(),
                ds.n,
                ds.n_classes
            );
            println!(
                "  quantized exact-ReLU accuracy: {:.2}%",
                100.0 * dir.manifest_f64("cnn_quantized_acc").unwrap_or(0.0)
            );
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    println!("\nnetwork zoo (paper ReLU counts):");
    for row in circa::bench_harness::tables::table1() {
        let spec = (row.spec)();
        println!(
            "  {:<16} {:>9.1}K ReLUs  {:>6.2} GMACs",
            row.name,
            spec.total_relus() as f64 / 1e3,
            spec.total_macs() as f64 / 1e9
        );
    }
    for row in circa::bench_harness::tables::table2() {
        let spec = (row.spec)();
        println!(
            "  {:<16} {:>9.1}K ReLUs  {:>6.2} GMACs",
            row.name,
            spec.total_relus() as f64 / 1e3,
            spec.total_macs() as f64 / 1e9
        );
    }
    Ok(())
}

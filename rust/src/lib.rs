//! # Circa: Stochastic ReLUs for Private Deep Learning
//!
//! Full-system reproduction of *Circa* (Ghodsi, Jha, Reagen, Garg — NeurIPS
//! 2021) as a three-layer Rust + JAX + Pallas stack.
//!
//! Circa reduces the dominant cost of hybrid private inference (PI) — the
//! per-ReLU garbled circuit — with three composable optimizations:
//!
//! 1. **Refactor** `ReLU(x) = x · sign(x)`: only `sign` stays in the garbled
//!    circuit, the multiply moves to Beaver triples ([`beaver`]).
//! 2. **Stochastic sign**: drop exact mod-p reconstruction inside the GC and
//!    compare shares directly; faults with probability `|x|/p` (Thm 3.1).
//! 3. **Truncated stochastic sign**: compare only the top `m−k` bits; adds
//!    faults only for `|x| < 2^k` (Thm 3.2), in one of two modes —
//!    **PosZero** (small positives zeroed) or **NegPass** (small negatives
//!    passed through).
//!
//! ## Crate layout
//!
//! * [`field`] — arithmetic over `F_p`, `p = 2138816513`, plus Delphi-style
//!   15-bit fixed-point quantization.
//! * [`ss`] — additive secret sharing.
//! * [`beaver`] — Beaver multiplication triples (dealer + online protocol).
//! * [`prf`] — fixed-key AES garbling PRF and 128-bit wire labels.
//! * [`gc`] — boolean circuit IR, bus combinators, and a free-XOR +
//!   point-and-permute + half-gates garbling engine.
//! * [`circuits`] — the four ReLU circuit variants of the paper's Fig. 2.
//! * [`ot`] — (simulated) oblivious transfer for input-label delivery.
//! * [`protocol`] — the Delphi-style layered 2-party protocol: offline
//!   (randomness, HE-simulated linear precompute, garbling, triples) and
//!   online (SS linear, GC ReLU, Beaver multiply) phases.
//! * [`nn`] — field tensors, quantized layers, and the network zoo with the
//!   paper's exact ReLU counts (ResNet-18/32, VGG-16, DeepReDuce D1–D6).
//! * [`simfault`] — closed-form fault model (Thms 3.1/3.2) + Monte-Carlo
//!   validation against the real GC evaluator.
//! * [`coordinator`] — the PI serving front-end: offline-material pool,
//!   request batcher, router, metrics.
//! * [`wire`] — binary codec + framed transport for offline material and
//!   the standalone dealer service (dealer/server process separation).
//! * [`net`] — the client-facing serving tier: a std-only nonblocking
//!   readiness reactor, the versioned client protocol, and bank-depth
//!   admission control (queue when healthy, shed `Busy` when dry).
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled JAX
//!   model (`artifacts/*.hlo.txt`) for accuracy experiments.
//! * [`bench_harness`] — shared measurement/reporting used by
//!   `cargo bench` to regenerate every table and figure in the paper.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench_harness;
pub mod beaver;
pub mod circuits;
pub mod coordinator;
pub mod field;
pub mod gc;
pub mod net;
pub mod nn;
pub mod ot;
pub mod prf;
pub mod protocol;
pub mod runtime;
pub mod simfault;
pub mod ss;
pub mod util;
pub mod wire;

pub use field::{Fp, PRIME};

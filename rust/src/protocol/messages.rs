//! Wire messages between the two parties, with exact size accounting.
//!
//! Serialization is structural (the parties share an address space), but
//! [`Message::wire_bytes`] reports what each message would cost on a real
//! wire so the byte ledger matches a 2-machine deployment.

use crate::field::Fp;
use crate::prf::Label;

/// Messages exchanged during the online phase.
#[derive(Debug, Clone)]
pub enum Message {
    /// Wire labels (16 B each): the server's input labels for a GC batch.
    Labels(Vec<Label>),
    /// Point-and-permute colors of output labels (1 bit each, byte-packed
    /// on the wire; we charge ceil(n/8)).
    Colors(Vec<bool>),
    /// Field elements (4 B each on a 31-bit field): shares, Beaver
    /// openings, resharing deltas.
    FieldVec(Vec<Fp>),
    /// Raw bytes (already-serialized payloads, e.g. garbled tables in the
    /// offline phase).
    Bytes(Vec<u8>),
}

impl Message {
    /// Serialized size on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Message::Labels(v) => v.len() * 16,
            Message::Colors(v) => v.len().div_ceil(8),
            Message::FieldVec(v) => v.len() * 4,
            Message::Bytes(v) => v.len(),
        }
    }

    pub fn into_labels(self) -> Vec<Label> {
        match self {
            Message::Labels(v) => v,
            other => panic!("expected Labels, got {other:?}"),
        }
    }

    pub fn into_colors(self) -> Vec<bool> {
        match self {
            Message::Colors(v) => v,
            other => panic!("expected Colors, got {other:?}"),
        }
    }

    pub fn into_fields(self) -> Vec<Fp> {
        match self {
            Message::FieldVec(v) => v,
            other => panic!("expected FieldVec, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes() {
        assert_eq!(Message::Labels(vec![Label::ZERO; 31]).wire_bytes(), 496);
        assert_eq!(Message::Colors(vec![false; 31]).wire_bytes(), 4);
        assert_eq!(Message::FieldVec(vec![Fp::ZERO; 3]).wire_bytes(), 12);
        assert_eq!(Message::Bytes(vec![0; 100]).wire_bytes(), 100);
    }

    #[test]
    #[should_panic]
    fn wrong_variant_panics() {
        Message::Colors(vec![]).into_labels();
    }
}

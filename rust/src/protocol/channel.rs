//! Byte-accounted duplex channels between the two parties.
//!
//! Both parties live in-process (DESIGN.md §5), so the "wire" is an mpsc
//! queue; what the experiments need from it is the *byte ledger* — every
//! message records its serialized size so benches report communication
//! exactly as a 2-machine deployment would see it.

use super::messages::Message;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel as mpsc_channel, Receiver, Sender};
use std::sync::Arc;

/// Shared byte counters for one direction of a duplex link.
#[derive(Debug, Default)]
pub struct ByteLedger {
    pub to_server: AtomicU64,
    pub to_client: AtomicU64,
}

impl ByteLedger {
    pub fn total(&self) -> u64 {
        self.to_server.load(Ordering::Relaxed) + self.to_client.load(Ordering::Relaxed)
    }
}

/// One party's endpoint of the duplex channel.
pub struct Channel {
    tx: Sender<Message>,
    rx: Receiver<Message>,
    ledger: Arc<ByteLedger>,
    /// True if this endpoint belongs to the client party.
    is_client: bool,
}

impl Channel {
    /// Create a connected (client, server) endpoint pair.
    pub fn pair() -> (Channel, Channel) {
        let (tx_cs, rx_cs) = mpsc_channel(); // client -> server
        let (tx_sc, rx_sc) = mpsc_channel(); // server -> client
        let ledger = Arc::new(ByteLedger::default());
        let client = Channel { tx: tx_cs, rx: rx_sc, ledger: ledger.clone(), is_client: true };
        let server = Channel { tx: tx_sc, rx: rx_cs, ledger, is_client: false };
        (client, server)
    }

    /// Send a message, charging its serialized size to the ledger.
    pub fn send(&self, msg: Message) {
        let bytes = msg.wire_bytes() as u64;
        if self.is_client {
            self.ledger.to_server.fetch_add(bytes, Ordering::Relaxed);
        } else {
            self.ledger.to_client.fetch_add(bytes, Ordering::Relaxed);
        }
        // Receiver dropped means the peer finished/aborted; that's only
        // reachable in tests that drop one endpoint early.
        let _ = self.tx.send(msg);
    }

    /// Blocking receive.
    pub fn recv(&self) -> Message {
        self.rx.recv().expect("peer hung up")
    }

    /// Total bytes seen in both directions.
    pub fn bytes_total(&self) -> u64 {
        self.ledger.total()
    }

    pub fn bytes_to_server(&self) -> u64 {
        self.ledger.to_server.load(Ordering::Relaxed)
    }

    pub fn bytes_to_client(&self) -> u64 {
        self.ledger.to_client.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Fp;

    #[test]
    fn ping_pong_and_ledger() {
        let (c, s) = Channel::pair();
        c.send(Message::FieldVec(vec![Fp::ONE; 10]));
        match s.recv() {
            Message::FieldVec(v) => assert_eq!(v.len(), 10),
            other => panic!("unexpected {other:?}"),
        }
        s.send(Message::Colors(vec![true; 8]));
        match c.recv() {
            Message::Colors(v) => assert_eq!(v.len(), 8),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.bytes_to_server(), 10 * 4);
        assert_eq!(c.bytes_to_client(), 1);
        assert_eq!(s.bytes_total(), 41);
    }

    #[test]
    fn works_across_threads() {
        let (c, s) = Channel::pair();
        let h = std::thread::spawn(move || {
            let m = s.recv();
            s.send(m);
        });
        c.send(Message::FieldVec(vec![Fp::from_i64(7)]));
        match c.recv() {
            Message::FieldVec(v) => assert_eq!(v[0].to_i64(), 7),
            other => panic!("unexpected {other:?}"),
        }
        h.join().unwrap();
    }
}

//! The server party: holds the model weights, blinds, garbling secrets,
//! and runs the online phase over a [`Channel`]. Also provides
//! [`offline_network`] (the full-network offline phase for both parties)
//! and [`run_inference`] (two-thread end-to-end driver used by tests,
//! examples, and the serving coordinator).

use super::channel::Channel;
use super::client::{run_client, ClientLayer, ClientNet};
use super::linear::{forward_multi, offline_linear, online_linear, LinearOp};
use super::messages::Message;
use super::offline::{ClientReluMaterial, ServerReluMaterial};
use super::online::{
    decode_server_shares, encode_server_labels, online_relu_layer_multi, OnlineReluStats,
    OnlineScratch,
};
use crate::beaver;
use crate::circuits::spec::ReluVariant;
use crate::field::{random_fp, Fp};
use crate::ss::Share;
use crate::util::{Rng, Timer};
use std::sync::Arc;

/// One server-side layer.
pub enum ServerLayer {
    Linear { op: Arc<dyn LinearOp>, s: Vec<Share> },
    Relu { mat: Box<ServerReluMaterial>, rescale: u32 },
}

/// The server's offline-prepared network.
pub struct ServerNet {
    pub layers: Vec<ServerLayer>,
}

impl ServerNet {
    /// Total ReLUs across the network — the denominator of the dealer's
    /// throughput metric (ReLUs are *the* offline cost axis).
    pub fn n_relus(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                ServerLayer::Relu { mat, .. } => mat.n(),
                ServerLayer::Linear { .. } => 0,
            })
            .sum()
    }
}

/// Statistics of one online inference, measured server-side.
#[derive(Clone, Copy, Debug, Default)]
pub struct InferenceStats {
    pub online_s: f64,
    pub bytes_to_client: u64,
    pub bytes_to_server: u64,
    pub relu_stats: OnlineReluStats,
    pub offline_bytes: u64,
}

/// A network description for the offline phase: linear ops with a ReLU
/// between consecutive pairs (the standard CNN alternation; the last
/// linear layer has no ReLU).
pub struct NetworkPlan {
    pub linears: Vec<Arc<dyn LinearOp>>,
    pub variant: ReluVariant,
    /// Fixed-point rescale (bits) applied to the shares of each ReLU
    /// layer's *output* via SecureML local truncation
    /// ([`crate::nn::layers::truncate_share_local`]). One entry per ReLU
    /// layer (i.e. `linears.len() − 1` entries); empty = no rescaling
    /// (unit-test nets with small magnitudes).
    pub rescale_bits: Vec<u32>,
}

impl NetworkPlan {
    /// Plan without fixed-point rescaling.
    pub fn unscaled(linears: Vec<Arc<dyn LinearOp>>, variant: ReluVariant) -> Self {
        NetworkPlan { linears, variant, rescale_bits: Vec::new() }
    }

    /// Rescale bits of ReLU layer `relu_idx` (0 when unspecified). Also
    /// used by `wire::codec` to validate dealer-supplied sessions.
    pub fn rescale_of(&self, relu_idx: usize) -> u32 {
        self.rescale_bits.get(relu_idx).copied().unwrap_or(0)
    }

    /// Number of ReLU layers (one between each consecutive linear pair).
    pub fn n_relu_layers(&self) -> usize {
        self.linears.len().saturating_sub(1)
    }
}

// ------------------------------------------------- per-layer schedule
//
// The session-level RNG schedule is *per-layer forked*: the session RNG
// is forked once per layer slot, in fixed order (linear 0, relu 0,
// linear 1, relu 1, …), and each layer's draws come only from its own
// fork. Inside a ReLU fork the column schedule of
// [`super::offline::offline_relu_layer_mt`] applies unchanged. The only
// cross-layer data dependency — a ReLU's `r_out` column becoming the
// next linear layer's input mask — is recoverable without garbling via
// [`super::offline::peek_r_out`], so any single ReLU layer of a session
// is a pure function of (session RNG, layer index): a dealer can deal
// one layer standalone, spending matvecs (not garbling) on the chain
// prefix, and ship bits identical to the same layer inside a
// whole-session deal. This is what layer-granular streaming
// ([`crate::wire::dealer`]) and the layer-sharded bank
// ([`crate::coordinator::pool`]) are built on.

/// Derive the session RNG of sequence number `seq` under `base_seed`.
///
/// Seq-addressed dealing: session `seq`'s material is a pure function of
/// `(base_seed, seq)`, so independent dealer threads/connections sharing
/// a base seed produce mutually consistent per-layer material, and a
/// coordinator can ask for any layer of any future session by number.
pub fn session_rng(base_seed: u64, seq: u64) -> Rng {
    Rng::new(base_seed).fork(seq)
}

/// One linear layer's offline precompute: the client's input mask and
/// output share, and the server's blind.
pub struct LinearSlot {
    /// Client mask `r` of this layer's input.
    pub r: Vec<Fp>,
    /// Client's (offline-known) share of the layer output `W·r − s`.
    pub x_share: Vec<Fp>,
    /// Server's additive blind `s`.
    pub s: Vec<Fp>,
}

/// The cheap scalar spine of a session: every linear layer's
/// [`LinearSlot`] plus the modeled HE byte ledger. Dealt in one unit
/// (masks chain across layers, so the slots are not independent of each
/// other — only of the heavy garbled material).
pub struct LinearSpine {
    pub slots: Vec<LinearSlot>,
    pub he_bytes: u64,
}

fn linear_fork_tag(li: usize) -> u64 {
    2 * li as u64
}

fn relu_fork_tag(li: usize) -> u64 {
    2 * li as u64 + 1
}

/// What a session walk needs to produce.
#[derive(Clone, Copy)]
enum WalkMode {
    /// Every linear slot and every ReLU layer (the whole-session deal).
    Full,
    /// Every linear slot; ReLU layers only peeked (the spine deal).
    SpineOnly,
    /// One ReLU layer: non-target linear slots are skipped entirely
    /// (their forks still advance the schedule, but no matvec runs —
    /// the mask chain needs only the `r_out` peeks), only the target's
    /// `x_share` is computed, and the walk stops after the target. This
    /// keeps standalone layer dealing at one matvec per request instead
    /// of one per chain-prefix layer.
    Layer(usize),
}

/// Walk the session schedule under `mode`. The fork order — linear 0,
/// relu 0, linear 1, … — is the session-level RNG contract; every mode
/// forks identically, so the pieces each mode produces are bit-identical
/// across modes.
fn walk_session(
    plan: &NetworkPlan,
    rng: &mut Rng,
    deal_threads: usize,
    mode: WalkMode,
) -> (LinearSpine, Vec<Option<(ClientReluMaterial, ServerReluMaterial)>>) {
    let n_lin = plan.linears.len();
    assert!(n_lin > 0, "plan has no layers");
    let mut slots = Vec::with_capacity(n_lin);
    let mut relus = Vec::with_capacity(n_lin.saturating_sub(1));
    let mut he_bytes = 0u64;
    // The client's mask for the *input* of the next linear layer.
    let mut r: Vec<Fp> = Vec::new();

    for (li, op) in plan.linears.iter().enumerate() {
        let mut lin_rng = rng.fork(linear_fork_tag(li));
        let need_linear = match mode {
            WalkMode::Full | WalkMode::SpineOnly => true,
            WalkMode::Layer(t) => li == t,
        };
        if need_linear {
            if li == 0 {
                r = (0..op.in_dim()).map(|_| random_fp(&mut lin_rng)).collect();
            }
            assert_eq!(op.in_dim(), r.len(), "layer {li} dimension chain");
            let off = offline_linear(op.as_ref(), &r, &mut lin_rng);
            he_bytes += off.he_bytes;
            slots.push(LinearSlot {
                r: std::mem::take(&mut r),
                x_share: off.client_x_share,
                s: off.s,
            });
        }

        if li + 1 == n_lin {
            break;
        }
        // ReLU layer: the client's x-share is offline-known, so all
        // offline ReLU material can be prepared now.
        let mut relu_rng = rng.fork(relu_fork_tag(li));
        let deal_this = match mode {
            WalkMode::Full => true,
            WalkMode::SpineOnly => false,
            WalkMode::Layer(t) => li == t,
        };
        let r_out = if deal_this {
            let x_share = &slots.last().expect("target slot computed").x_share;
            let (cm, sm) = super::offline::offline_relu_layer_mt(
                plan.variant,
                x_share,
                &mut relu_rng,
                deal_threads,
            );
            let r_out = cm.r_out.clone();
            relus.push(Some((cm, sm)));
            r_out
        } else {
            relus.push(None);
            super::offline::peek_r_out(op.out_dim(), &mut relu_rng)
        };
        // The client's output share of this ReLU (r_out) becomes the
        // mask of the next linear layer's input — after the client's
        // half of the fixed-point rescale (SecureML local share
        // truncation; the server truncates its own half online).
        let rescale = plan.rescale_of(li);
        r = r_out
            .iter()
            .map(|&y| crate::nn::layers::truncate_share_local(y, rescale, true))
            .collect();
        if matches!(mode, WalkMode::Layer(t) if t == li) {
            break;
        }
    }
    (LinearSpine { slots, he_bytes }, relus)
}

/// Deal only the linear spine of a session (masks, HE precomputes,
/// blinds) — no garbling, just matvecs and the cheap `r_out` peeks that
/// carry the mask chain across ReLU layers.
pub fn deal_spine(plan: &NetworkPlan, rng: &mut Rng) -> LinearSpine {
    walk_session(plan, rng, 1, WalkMode::SpineOnly).0
}

/// Deal only ReLU layer `li` of a session, bit-identical to the same
/// layer inside a whole-session deal from the same session RNG. The
/// chain prefix costs only the earlier layers' `r_out` peeks plus one
/// matvec for the target layer's `x_share`; garbling effort is spent on
/// layer `li` alone.
pub fn deal_relu_layer_mt(
    plan: &NetworkPlan,
    rng: &mut Rng,
    li: usize,
    deal_threads: usize,
) -> (ClientReluMaterial, ServerReluMaterial) {
    assert!(li + 1 < plan.linears.len(), "relu layer {li} out of range");
    let (_, mut relus) = walk_session(plan, rng, deal_threads, WalkMode::Layer(li));
    relus.pop().flatten().expect("requested layer dealt")
}

/// Assemble a full session from a spine and one dealt ReLU layer per
/// gap. All parts must come from the *same* session RNG (the pool keys
/// them by sequence number): a ReLU layer's OT'd client labels bake in
/// the spine's `x_share` chain, so mixing sequences would silently
/// desynchronize the material.
pub fn assemble_session(
    plan: &NetworkPlan,
    spine: LinearSpine,
    relus: Vec<(ClientReluMaterial, ServerReluMaterial)>,
) -> (ClientNet, ServerNet, u64) {
    let n_lin = plan.linears.len();
    assert_eq!(spine.slots.len(), n_lin, "spine covers every linear layer");
    assert_eq!(relus.len(), n_lin - 1, "one ReLU layer per linear gap");
    let mut client_layers = Vec::with_capacity(2 * n_lin - 1);
    let mut server_layers = Vec::with_capacity(2 * n_lin - 1);
    let mut offline_bytes = spine.he_bytes;
    let mut relus = relus.into_iter();
    for (li, slot) in spine.slots.into_iter().enumerate() {
        client_layers.push(ClientLayer::Linear { r: slot.r, x_share: slot.x_share });
        server_layers.push(ServerLayer::Linear { op: plan.linears[li].clone(), s: slot.s });
        if li + 1 < n_lin {
            let (cm, sm) = relus.next().expect("relu layer per gap");
            offline_bytes += cm.offline_bytes;
            client_layers.push(ClientLayer::Relu(Box::new(cm)));
            server_layers
                .push(ServerLayer::Relu { mat: Box::new(sm), rescale: plan.rescale_of(li) });
        }
    }
    (ClientNet { layers: client_layers }, ServerNet { layers: server_layers }, offline_bytes)
}

/// Run the full offline phase for a network: generates client masks,
/// HE-simulated linear precomputes, garbled circuits, OTs, and triples
/// for every layer. Returns both parties' materials plus offline bytes.
pub fn offline_network(plan: &NetworkPlan, rng: &mut Rng) -> (ClientNet, ServerNet, u64) {
    offline_network_mt(plan, rng, 1)
}

/// [`offline_network`] with each ReLU layer's garble column split across
/// up to `deal_threads` threads
/// ([`super::offline::offline_relu_layer_mt`]'s column-wise schedule).
/// Output is bit-identical for every thread count, so dealers can scale
/// across cores without changing what they ship — and, per the
/// per-layer forked schedule above, identical to a session assembled
/// from [`deal_spine`] plus one [`deal_relu_layer_mt`] per ReLU layer
/// from the same session RNG.
pub fn offline_network_mt(
    plan: &NetworkPlan,
    rng: &mut Rng,
    deal_threads: usize,
) -> (ClientNet, ServerNet, u64) {
    let (spine, relus) = walk_session(plan, rng, deal_threads, WalkMode::Full);
    let relus = relus.into_iter().map(|o| o.expect("all layers dealt")).collect();
    assemble_session(plan, spine, relus)
}

/// Server's half of the fixed-point rescale (no-op when `bits == 0`).
fn rescale_shares(shares: Vec<Fp>, bits: u32) -> Vec<Fp> {
    if bits == 0 {
        return shares;
    }
    shares
        .into_iter()
        .map(|y| crate::nn::layers::truncate_share_local(y, bits, false))
        .collect()
}

/// Run the server's online protocol for one inference.
pub fn run_server(net: &ServerNet, chan: &Channel) -> InferenceStats {
    let timer = Timer::new();
    // Round 0: receive the blinded input (the server's share of y₁).
    let mut y_share = chan.recv().into_fields();

    let mut x_share: Vec<Fp> = Vec::new();
    for layer in &net.layers {
        match layer {
            ServerLayer::Linear { op, s } => {
                x_share = online_linear(op.as_ref(), &y_share, s);
            }
            ServerLayer::Relu { mat, rescale } => {
                let n = mat.n();
                assert_eq!(x_share.len(), n);
                // Send input labels for this batch of ReLUs (one arena).
                chan.send(Message::Labels(encode_server_labels(mat, &x_share)));
                // Receive output colors; decode the sign/ReLU share.
                let colors = chan.recv().into_colors();
                let decoded = decode_server_shares(mat, &colors);

                if !mat.spec.uses_beaver() {
                    // Baseline: decoded IS the masked ReLU output share.
                    y_share = rescale_shares(decoded, *rescale);
                    continue;
                }

                // Circa: Beaver multiply y = x·v, then apply resharing Δ.
                let client_open = chan.recv().into_fields();
                let mut openings = Vec::with_capacity(2 * n);
                for i in 0..n {
                    let o = beaver::open(x_share[i], decoded[i], &mat.triples[i]);
                    openings.push(o.e);
                    openings.push(o.f);
                }
                chan.send(Message::FieldVec(openings.clone()));
                let deltas = chan.recv().into_fields();
                y_share = rescale_shares(
                    (0..n)
                        .map(|i| {
                            let e = client_open[2 * i] + openings[2 * i];
                            let f = client_open[2 * i + 1] + openings[2 * i + 1];
                            beaver::mul_share(e, f, &mat.triples[i], false) + deltas[i]
                        })
                        .collect(),
                    *rescale,
                );
            }
        }
    }

    // Send the final linear share to the client.
    chan.send(Message::FieldVec(x_share));

    InferenceStats {
        online_s: timer.elapsed_s(),
        bytes_to_client: chan.bytes_to_client(),
        bytes_to_server: chan.bytes_to_server(),
        ..Default::default()
    }
}

/// End-to-end driver: run one private inference across two threads.
/// Returns the reconstructed logits (client side) and server-side stats.
pub fn run_inference(
    client_net: &ClientNet,
    server_net: &ServerNet,
    input: &[Fp],
) -> (Vec<Fp>, InferenceStats) {
    std::thread::scope(|scope| {
        let (c_chan, s_chan) = Channel::pair();
        let server_handle = scope.spawn(move || run_server(server_net, &s_chan));
        let logits = run_client(client_net, &c_chan, input);
        let stats = server_handle.join().expect("server thread");
        (logits, stats)
    })
}

/// Run R private inferences — one leased session each, same model — as a
/// single batched walk: every linear layer is one [`forward_multi`] pass
/// across all R share vectors (optionally chunk-parallel over
/// `lin_threads`), every ReLU layer one fused
/// [`online_relu_layer_multi`] call whose GC evaluation strides across
/// requests. In-process lockstep (no channels/threads per request), with
/// every message byte-accounted exactly as the per-request
/// [`run_inference`] channel ledger — the aggregated `bytes_*` equal the
/// sums of R independent runs, and each request's logits are
/// bit-identical to its own `run_inference` (`relu_stats`/
/// `offline_bytes` stay `Default`, as in [`run_server`]).
///
/// Sessions must be homogeneous — same plan shape, variant, and rescale
/// schedule — which the coordinator's model-keyed batches guarantee.
pub fn run_inference_multi(
    sessions: &[(&ClientNet, &ServerNet)],
    inputs: &[&[Fp]],
    lin_threads: usize,
) -> (Vec<Vec<Fp>>, InferenceStats) {
    let r_count = sessions.len();
    assert!(r_count > 0, "empty inference batch");
    assert_eq!(inputs.len(), r_count, "one input per session");
    let timer = Timer::new();
    let mut stats = InferenceStats::default();
    let n_layers = sessions[0].1.layers.len();
    for (cn, sn) in sessions {
        assert_eq!(cn.layers.len(), n_layers, "homogeneous batch");
        assert_eq!(sn.layers.len(), n_layers, "homogeneous batch");
    }

    // Round 0: each client blinds its input with its own session's mask.
    let mut server_y: Vec<Vec<Fp>> = sessions
        .iter()
        .zip(inputs)
        .map(|((cn, _), input)| {
            let r1 = cn.input_mask();
            assert_eq!(input.len(), r1.len(), "input dimension");
            input.iter().zip(r1).map(|(&y, &r)| y - r).collect()
        })
        .collect();
    for input in inputs {
        stats.bytes_to_server += input.len() as u64 * 4;
    }

    let mut scratch = OnlineScratch::default();
    let mut client_x: Vec<&[Fp]> = vec![&[]; r_count];
    let mut server_x: Vec<Vec<Fp>> = Vec::new();

    for li in 0..n_layers {
        match &sessions[0].1.layers[li] {
            ServerLayer::Linear { op, .. } => {
                let mut ss: Vec<&[Fp]> = Vec::with_capacity(r_count);
                for (r, (cn, sn)) in sessions.iter().enumerate() {
                    match &sn.layers[li] {
                        ServerLayer::Linear { op: o, s } => {
                            assert_eq!(o.in_dim(), op.in_dim(), "layer {li} shape");
                            assert_eq!(o.out_dim(), op.out_dim(), "layer {li} shape");
                            ss.push(s);
                        }
                        _ => panic!("layer {li}: shape mismatch across batch"),
                    }
                    match &cn.layers[li] {
                        ClientLayer::Linear { x_share, .. } => client_x[r] = x_share,
                        _ => panic!("layer {li}: client/server mismatch"),
                    }
                }
                let ys: Vec<&[Fp]> = server_y.iter().map(|v| v.as_slice()).collect();
                server_x = forward_multi(op.as_ref(), &ys, &ss, lin_threads);
            }
            ServerLayer::Relu { rescale, .. } => {
                let mut cms: Vec<&ClientReluMaterial> = Vec::with_capacity(r_count);
                let mut sms: Vec<&ServerReluMaterial> = Vec::with_capacity(r_count);
                for (cn, sn) in sessions {
                    match &cn.layers[li] {
                        ClientLayer::Relu(m) => cms.push(m.as_ref()),
                        _ => panic!("layer {li}: client/server mismatch"),
                    }
                    match &sn.layers[li] {
                        ServerLayer::Relu { mat, rescale: r2 } => {
                            assert_eq!(r2, rescale, "layer {li}: rescale schedule");
                            sms.push(mat.as_ref());
                        }
                        _ => panic!("layer {li}: shape mismatch across batch"),
                    }
                }
                let xss: Vec<&[Fp]> = server_x.iter().map(|v| v.as_slice()).collect();
                let (_, ys_out, rstats) =
                    online_relu_layer_multi(&cms, &sms, &client_x, &xss, &mut scratch);
                stats.bytes_to_client += rstats.bytes_to_client;
                stats.bytes_to_server += rstats.bytes_to_server;
                server_y = ys_out.into_iter().map(|v| rescale_shares(v, *rescale)).collect();
            }
        }
    }

    // Final round: each server ships its share of the last linear
    // output; each client reconstructs its logits.
    let mut logits = Vec::with_capacity(r_count);
    for (cx, sx) in client_x.iter().zip(&server_x) {
        stats.bytes_to_client += sx.len() as u64 * 4;
        logits.push(cx.iter().zip(sx).map(|(&c, &s)| c + s).collect());
    }
    stats.online_s = timer.elapsed_s();
    (logits, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::FaultMode;
    use crate::protocol::linear::Matrix;

    fn tiny_plan(variant: ReluVariant, rng: &mut Rng) -> NetworkPlan {
        // 6 -> 5 -> relu -> 5 -> 4 -> relu -> 4 -> 3
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 20, rng)),
            Arc::new(Matrix::random(4, 5, 20, rng)),
            Arc::new(Matrix::random(3, 4, 20, rng)),
        ];
        NetworkPlan::unscaled(linears, variant)
    }

    /// Plaintext oracle for the same network with *exact* ReLU.
    fn plaintext_forward(plan: &NetworkPlan, input: &[Fp]) -> Vec<Fp> {
        let mut y = input.to_vec();
        for (i, op) in plan.linears.iter().enumerate() {
            y = op.apply(&y);
            if i + 1 < plan.linears.len() {
                y = y.iter().map(|&v| crate::field::relu_exact(v)).collect();
            }
        }
        y
    }

    #[test]
    fn e2e_matches_plaintext_for_all_variants() {
        for (seed, variant) in [
            (10u64, ReluVariant::BaselineRelu),
            (11, ReluVariant::NaiveSign),
            (12, ReluVariant::StochasticSign { mode: FaultMode::PosZero }),
            // k=4 keeps trunc faults confined to |x|<16, and the input
            // below keeps activations well above that.
            (13, ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero }),
        ] {
            let mut rng = Rng::new(seed);
            let plan = tiny_plan(variant, &mut rng);
            let (cn, sn, off_bytes) = offline_network(&plan, &mut rng);
            assert!(off_bytes > 0);
            let input: Vec<Fp> =
                (0..6).map(|_| Fp::from_i64(rng.below(2000) as i64 + 1000)).collect();
            let (logits, stats) = run_inference(&cn, &sn, &input);
            let want = plaintext_forward(&plan, &input);
            assert_eq!(logits, want, "variant {variant:?}");
            assert!(stats.online_s > 0.0);
            assert!(stats.bytes_to_client > 0);
        }
    }

    #[test]
    fn material_is_consumed_per_inference_semantics() {
        // Two inferences need two offline materializations (GCs are
        // single-use); running the same material twice reuses labels and
        // would be insecure — the API makes the caller re-run offline.
        let mut rng = Rng::new(20);
        let plan = tiny_plan(ReluVariant::BaselineRelu, &mut rng);
        let (cn1, sn1, _) = offline_network(&plan, &mut rng);
        let (cn2, sn2, _) = offline_network(&plan, &mut rng);
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(100 + i as i64)).collect();
        let (l1, _) = run_inference(&cn1, &sn1, &input);
        let (l2, _) = run_inference(&cn2, &sn2, &input);
        assert_eq!(l1, l2, "same input, fresh material, same result");
    }

    #[test]
    fn batched_inference_matches_per_request_runs() {
        let mut rng = Rng::new(22);
        let variant = ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero };
        let plan = tiny_plan(variant, &mut rng);
        let r_count = 3;
        let sessions: Vec<_> = (0..r_count).map(|_| offline_network(&plan, &mut rng)).collect();
        let inputs: Vec<Vec<Fp>> = (0..r_count)
            .map(|r| (0..6).map(|j| Fp::from_i64(1000 + 37 * r as i64 + j)).collect())
            .collect();
        let mut want = Vec::new();
        let (mut sum_c, mut sum_s) = (0u64, 0u64);
        for ((cn, sn, _), input) in sessions.iter().zip(&inputs) {
            let (logits, st) = run_inference(cn, sn, input);
            sum_c += st.bytes_to_client;
            sum_s += st.bytes_to_server;
            want.push(logits);
        }
        let refs: Vec<(&ClientNet, &ServerNet)> =
            sessions.iter().map(|(cn, sn, _)| (cn, sn)).collect();
        let in_refs: Vec<&[Fp]> = inputs.iter().map(|v| v.as_slice()).collect();
        let (got, st) = run_inference_multi(&refs, &in_refs, 1);
        assert_eq!(got, want, "logits per request");
        assert_eq!(st.bytes_to_client, sum_c);
        assert_eq!(st.bytes_to_server, sum_s);
    }

    #[test]
    fn online_bytes_dominated_by_labels() {
        let mut rng = Rng::new(21);
        let plan = tiny_plan(ReluVariant::BaselineRelu, &mut rng);
        let (cn, sn, _) = offline_network(&plan, &mut rng);
        let input: Vec<Fp> = (0..6).map(|_| Fp::from_i64(500)).collect();
        let (_, stats) = run_inference(&cn, &sn, &input);
        // 9 ReLUs × 31 labels × 16 B = 4464 B minimum to client.
        assert!(stats.bytes_to_client >= 9 * 31 * 16);
    }
}

//! The server party: holds the model weights, blinds, garbling secrets,
//! and runs the online phase over a [`Channel`]. Also provides
//! [`offline_network`] (the full-network offline phase for both parties)
//! and [`run_inference`] (two-thread end-to-end driver used by tests,
//! examples, and the serving coordinator).

use super::channel::Channel;
use super::client::{run_client, ClientLayer, ClientNet};
use super::linear::{offline_linear, online_linear, LinearOp};
use super::messages::Message;
use super::offline::ServerReluMaterial;
use super::online::{decode_server_shares, encode_server_labels, OnlineReluStats};
use crate::beaver;
use crate::circuits::spec::ReluVariant;
use crate::field::{random_fp, Fp};
use crate::ss::Share;
use crate::util::{Rng, Timer};
use std::sync::Arc;

/// One server-side layer.
pub enum ServerLayer {
    Linear { op: Arc<dyn LinearOp>, s: Vec<Share> },
    Relu { mat: Box<ServerReluMaterial>, rescale: u32 },
}

/// The server's offline-prepared network.
pub struct ServerNet {
    pub layers: Vec<ServerLayer>,
}

impl ServerNet {
    /// Total ReLUs across the network — the denominator of the dealer's
    /// throughput metric (ReLUs are *the* offline cost axis).
    pub fn n_relus(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                ServerLayer::Relu { mat, .. } => mat.n(),
                ServerLayer::Linear { .. } => 0,
            })
            .sum()
    }
}

/// Statistics of one online inference, measured server-side.
#[derive(Clone, Copy, Debug, Default)]
pub struct InferenceStats {
    pub online_s: f64,
    pub bytes_to_client: u64,
    pub bytes_to_server: u64,
    pub relu_stats: OnlineReluStats,
    pub offline_bytes: u64,
}

/// A network description for the offline phase: linear ops with a ReLU
/// between consecutive pairs (the standard CNN alternation; the last
/// linear layer has no ReLU).
pub struct NetworkPlan {
    pub linears: Vec<Arc<dyn LinearOp>>,
    pub variant: ReluVariant,
    /// Fixed-point rescale (bits) applied to the shares of each ReLU
    /// layer's *output* via SecureML local truncation
    /// ([`crate::nn::layers::truncate_share_local`]). One entry per ReLU
    /// layer (i.e. `linears.len() − 1` entries); empty = no rescaling
    /// (unit-test nets with small magnitudes).
    pub rescale_bits: Vec<u32>,
}

impl NetworkPlan {
    /// Plan without fixed-point rescaling.
    pub fn unscaled(linears: Vec<Arc<dyn LinearOp>>, variant: ReluVariant) -> Self {
        NetworkPlan { linears, variant, rescale_bits: Vec::new() }
    }

    /// Rescale bits of ReLU layer `relu_idx` (0 when unspecified). Also
    /// used by `wire::codec` to validate dealer-supplied sessions.
    pub fn rescale_of(&self, relu_idx: usize) -> u32 {
        self.rescale_bits.get(relu_idx).copied().unwrap_or(0)
    }
}

/// Run the full offline phase for a network: generates client masks,
/// HE-simulated linear precomputes, garbled circuits, OTs, and triples
/// for every layer. Returns both parties' materials plus offline bytes.
pub fn offline_network(plan: &NetworkPlan, rng: &mut Rng) -> (ClientNet, ServerNet, u64) {
    offline_network_mt(plan, rng, 1)
}

/// [`offline_network`] with each ReLU layer's garble column split across
/// up to `deal_threads` threads
/// ([`super::offline::offline_relu_layer_mt`]'s column-wise schedule).
/// Output is bit-identical for every thread count, so dealers can scale
/// across cores without changing what they ship.
pub fn offline_network_mt(
    plan: &NetworkPlan,
    rng: &mut Rng,
    deal_threads: usize,
) -> (ClientNet, ServerNet, u64) {
    let mut client_layers = Vec::new();
    let mut server_layers = Vec::new();
    let mut offline_bytes = 0u64;

    // The client's mask for the *input* of the next linear layer.
    let mut r: Vec<Fp> = (0..plan.linears[0].in_dim()).map(|_| random_fp(rng)).collect();

    for (li, op) in plan.linears.iter().enumerate() {
        assert_eq!(op.in_dim(), r.len(), "layer {li} dimension chain");
        let off = offline_linear(op.as_ref(), &r, rng);
        offline_bytes += off.he_bytes;
        let x_share = off.client_x_share.clone();
        client_layers.push(ClientLayer::Linear { r: r.clone(), x_share: x_share.clone() });
        server_layers.push(ServerLayer::Linear { op: op.clone(), s: off.s });

        let is_last = li + 1 == plan.linears.len();
        if !is_last {
            // ReLU layer: the client's x-share is offline-known, so all
            // offline ReLU material can be prepared now.
            let (cm, sm) =
                super::offline::offline_relu_layer_mt(plan.variant, &x_share, rng, deal_threads);
            offline_bytes += cm.offline_bytes;
            // The client's output share of this ReLU (r_out) becomes the
            // mask of the next linear layer's input — after the client's
            // half of the fixed-point rescale (SecureML local share
            // truncation; the server truncates its own half online).
            let rescale = plan.rescale_of(li);
            r = cm
                .r_out
                .iter()
                .map(|&y| crate::nn::layers::truncate_share_local(y, rescale, true))
                .collect();
            client_layers.push(ClientLayer::Relu(Box::new(cm)));
            server_layers.push(ServerLayer::Relu { mat: Box::new(sm), rescale });
        }
    }

    (ClientNet { layers: client_layers }, ServerNet { layers: server_layers }, offline_bytes)
}

/// Server's half of the fixed-point rescale (no-op when `bits == 0`).
fn rescale_shares(shares: Vec<Fp>, bits: u32) -> Vec<Fp> {
    if bits == 0 {
        return shares;
    }
    shares
        .into_iter()
        .map(|y| crate::nn::layers::truncate_share_local(y, bits, false))
        .collect()
}

/// Run the server's online protocol for one inference.
pub fn run_server(net: &ServerNet, chan: &Channel) -> InferenceStats {
    let timer = Timer::new();
    // Round 0: receive the blinded input (the server's share of y₁).
    let mut y_share = chan.recv().into_fields();

    let mut x_share: Vec<Fp> = Vec::new();
    for layer in &net.layers {
        match layer {
            ServerLayer::Linear { op, s } => {
                x_share = online_linear(op.as_ref(), &y_share, s);
            }
            ServerLayer::Relu { mat, rescale } => {
                let n = mat.n();
                assert_eq!(x_share.len(), n);
                // Send input labels for this batch of ReLUs (one arena).
                chan.send(Message::Labels(encode_server_labels(mat, &x_share)));
                // Receive output colors; decode the sign/ReLU share.
                let colors = chan.recv().into_colors();
                let decoded = decode_server_shares(mat, &colors);

                if !mat.spec.uses_beaver() {
                    // Baseline: decoded IS the masked ReLU output share.
                    y_share = rescale_shares(decoded, *rescale);
                    continue;
                }

                // Circa: Beaver multiply y = x·v, then apply resharing Δ.
                let client_open = chan.recv().into_fields();
                let mut openings = Vec::with_capacity(2 * n);
                for i in 0..n {
                    let o = beaver::open(x_share[i], decoded[i], &mat.triples[i]);
                    openings.push(o.e);
                    openings.push(o.f);
                }
                chan.send(Message::FieldVec(openings.clone()));
                let deltas = chan.recv().into_fields();
                y_share = rescale_shares(
                    (0..n)
                        .map(|i| {
                            let e = client_open[2 * i] + openings[2 * i];
                            let f = client_open[2 * i + 1] + openings[2 * i + 1];
                            beaver::mul_share(e, f, &mat.triples[i], false) + deltas[i]
                        })
                        .collect(),
                    *rescale,
                );
            }
        }
    }

    // Send the final linear share to the client.
    chan.send(Message::FieldVec(x_share));

    InferenceStats {
        online_s: timer.elapsed_s(),
        bytes_to_client: chan.bytes_to_client(),
        bytes_to_server: chan.bytes_to_server(),
        ..Default::default()
    }
}

/// End-to-end driver: run one private inference across two threads.
/// Returns the reconstructed logits (client side) and server-side stats.
pub fn run_inference(
    client_net: &ClientNet,
    server_net: &ServerNet,
    input: &[Fp],
) -> (Vec<Fp>, InferenceStats) {
    std::thread::scope(|scope| {
        let (c_chan, s_chan) = Channel::pair();
        let server_handle = scope.spawn(move || run_server(server_net, &s_chan));
        let logits = run_client(client_net, &c_chan, input);
        let stats = server_handle.join().expect("server thread");
        (logits, stats)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::FaultMode;
    use crate::protocol::linear::Matrix;

    fn tiny_plan(variant: ReluVariant, rng: &mut Rng) -> NetworkPlan {
        // 6 -> 5 -> relu -> 5 -> 4 -> relu -> 4 -> 3
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 20, rng)),
            Arc::new(Matrix::random(4, 5, 20, rng)),
            Arc::new(Matrix::random(3, 4, 20, rng)),
        ];
        NetworkPlan::unscaled(linears, variant)
    }

    /// Plaintext oracle for the same network with *exact* ReLU.
    fn plaintext_forward(plan: &NetworkPlan, input: &[Fp]) -> Vec<Fp> {
        let mut y = input.to_vec();
        for (i, op) in plan.linears.iter().enumerate() {
            y = op.apply(&y);
            if i + 1 < plan.linears.len() {
                y = y.iter().map(|&v| crate::field::relu_exact(v)).collect();
            }
        }
        y
    }

    #[test]
    fn e2e_matches_plaintext_for_all_variants() {
        for (seed, variant) in [
            (10u64, ReluVariant::BaselineRelu),
            (11, ReluVariant::NaiveSign),
            (12, ReluVariant::StochasticSign { mode: FaultMode::PosZero }),
            // k=4 keeps trunc faults confined to |x|<16, and the input
            // below keeps activations well above that.
            (13, ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero }),
        ] {
            let mut rng = Rng::new(seed);
            let plan = tiny_plan(variant, &mut rng);
            let (cn, sn, off_bytes) = offline_network(&plan, &mut rng);
            assert!(off_bytes > 0);
            let input: Vec<Fp> =
                (0..6).map(|_| Fp::from_i64(rng.below(2000) as i64 + 1000)).collect();
            let (logits, stats) = run_inference(&cn, &sn, &input);
            let want = plaintext_forward(&plan, &input);
            assert_eq!(logits, want, "variant {variant:?}");
            assert!(stats.online_s > 0.0);
            assert!(stats.bytes_to_client > 0);
        }
    }

    #[test]
    fn material_is_consumed_per_inference_semantics() {
        // Two inferences need two offline materializations (GCs are
        // single-use); running the same material twice reuses labels and
        // would be insecure — the API makes the caller re-run offline.
        let mut rng = Rng::new(20);
        let plan = tiny_plan(ReluVariant::BaselineRelu, &mut rng);
        let (cn1, sn1, _) = offline_network(&plan, &mut rng);
        let (cn2, sn2, _) = offline_network(&plan, &mut rng);
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(100 + i as i64)).collect();
        let (l1, _) = run_inference(&cn1, &sn1, &input);
        let (l2, _) = run_inference(&cn2, &sn2, &input);
        assert_eq!(l1, l2, "same input, fresh material, same result");
    }

    #[test]
    fn online_bytes_dominated_by_labels() {
        let mut rng = Rng::new(21);
        let plan = tiny_plan(ReluVariant::BaselineRelu, &mut rng);
        let (cn, sn, _) = offline_network(&plan, &mut rng);
        let input: Vec<Fp> = (0..6).map(|_| Fp::from_i64(500)).collect();
        let (_, stats) = run_inference(&cn, &sn, &input);
        // 9 ReLUs × 31 labels × 16 B = 4464 B minimum to client.
        assert!(stats.bytes_to_client >= 9 * 31 * 16);
    }
}

//! The client party: holds its input, per-layer masks, garbled-circuit
//! evaluation material, and drives the online phase over a [`Channel`].

use super::channel::Channel;
use super::messages::Message;
use super::offline::ClientReluMaterial;
use crate::beaver;
use crate::field::Fp;

use crate::ss::Share;

/// One client-side layer of the offline-prepared network.
pub enum ClientLayer {
    /// Linear layer: the input mask `r` this layer consumed offline and
    /// the client's (offline-known) share of the layer output.
    Linear { r: Vec<Fp>, x_share: Vec<Share> },
    /// ReLU layer material.
    Relu(Box<ClientReluMaterial>),
}

/// The client's offline-prepared network.
pub struct ClientNet {
    pub layers: Vec<ClientLayer>,
}

impl ClientNet {
    /// The mask `r_1` of the network input (first linear layer).
    pub fn input_mask(&self) -> &[Fp] {
        match &self.layers[0] {
            ClientLayer::Linear { r, .. } => r,
            _ => panic!("network must start with a linear layer"),
        }
    }
}

/// Run the client's online protocol for one inference.
///
/// Sends `y₁ − r₁`, then per ReLU layer evaluates the GCs and completes
/// the Beaver/resharing rounds; finally receives the server's share of
/// the last linear output and reconstructs the logits.
pub fn run_client(net: &ClientNet, chan: &Channel, input: &[Fp]) -> Vec<Fp> {
    // Round 0: blind the input with the first layer's mask.
    let r1 = net.input_mask();
    assert_eq!(input.len(), r1.len(), "input dimension");
    let blinded: Vec<Fp> = input.iter().zip(r1).map(|(&y, &r)| y - r).collect();
    chan.send(Message::FieldVec(blinded));

    let mut last_x_share: &[Share] = &[];
    for layer in &net.layers {
        match layer {
            ClientLayer::Linear { x_share, .. } => {
                // Nothing to do online — the server computes its share.
                last_x_share = x_share;
            }
            ClientLayer::Relu(mat) => {
                let n = mat.n();
                let xc = last_x_share;
                assert_eq!(xc.len(), n);

                // Receive the server's input labels (one flat arena).
                let labels = chan.recv().into_labels();

                // Batched evaluation: walk the layer's shared circuit
                // once per ReLU over the contiguous table buffer, with
                // scratch reused across the layer (§Perf iteration 3).
                let mut colors = Vec::with_capacity(n * mat.spec.n_outputs);
                mat.gc.eval_layer_colors(&mat.client_labels, &labels, &mut colors);

                if !mat.spec.uses_beaver() {
                    chan.send(Message::Colors(colors));
                    // Baseline: client's output share is its mask r_out,
                    // already wired into the next layer's offline phase.
                    continue;
                }

                // Circa: send colors together with this party's Beaver
                // openings (they depend only on client-held values).
                let mut openings = Vec::with_capacity(2 * n);
                for i in 0..n {
                    let o = beaver::open(xc[i], mat.r_v[i], &mat.triples[i]);
                    openings.push(o.e);
                    openings.push(o.f);
                }
                chan.send(Message::Colors(colors));
                chan.send(Message::FieldVec(openings.clone()));

                // Receive the server's openings; finish the multiply.
                let server_open = chan.recv().into_fields();
                let mut deltas = Vec::with_capacity(n);
                for i in 0..n {
                    let e = openings[2 * i] + server_open[2 * i];
                    let f = openings[2 * i + 1] + server_open[2 * i + 1];
                    let y_c = beaver::mul_share(e, f, &mat.triples[i], true);
                    deltas.push(y_c - mat.r_out[i]);
                }
                chan.send(Message::FieldVec(deltas));
                // Client's share of y is now r_out (pre-wired offline).
            }
        }
    }

    // Final layer: server sends its share of the last linear output.
    let server_share = chan.recv().into_fields();
    last_x_share.iter().zip(&server_share).map(|(&c, &s)| c + s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic]
    fn input_mask_requires_linear_first() {
        let net = ClientNet { layers: vec![] };
        let _ = net.layers.is_empty();
        // Constructing an invalid net and asking for the mask panics.
        let bad = ClientNet {
            layers: vec![ClientLayer::Relu(Box::new(make_dummy_material()))],
        };
        bad.input_mask();
    }

    fn make_dummy_material() -> ClientReluMaterial {
        use crate::protocol::offline::{circa_variant, offline_relu_layer};
        let mut rng = crate::util::Rng::new(1);
        let (c, _) = offline_relu_layer(circa_variant(12), &[Fp::ZERO], &mut rng);
        c
    }
}

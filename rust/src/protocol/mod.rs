//! The Delphi-style two-party PI protocol with Circa's ReLU variants,
//! built around a layer-batched data plane.
//!
//! A network inference alternates linear layers (additive secret sharing,
//! [`linear`]) and ReLU layers (garbled circuits + Beaver triples,
//! [`online`]). Everything input-independent happens in [`offline`]:
//! client randomness, the HE-simulated `W·r − s` precomputation, circuit
//! garbling, input-label OTs, and triple generation.
//!
//! Offline material is **layer-level SoA** ([`crate::gc::batch`]): each
//! ReLU layer holds exactly one [`crate::gc::Circuit`] template, one
//! contiguous garbled-table buffer, one contiguous label arena per role,
//! and flat `r_v`/`r_out`/triple columns — never per-ReLU heap objects.
//! Variant behavior (circuit builder, input layout, truncation `k`, bit
//! encoders) is resolved once into a
//! [`crate::circuits::spec::VariantSpec`] that every phase dispatches
//! through; there are no per-phase `match variant` ladders.
//!
//! The online phase — the paper's headline metric — moves only what it
//! must: one flat label arena for the server's inputs, one batched
//! circuit walk on the client, the color stream back, and (for Circa
//! variants) one Beaver round plus a resharing element. Byte counts fall
//! out of buffer lengths. The phase is additionally **batch-native
//! across requests**: [`online::online_relu_layer_multi`] fuses R
//! concurrent requests' label arenas, GC walks (hash flights strided
//! across requests), and Beaver rounds into single flat passes, and
//! [`server::run_inference_multi`] drives whole model-homogeneous
//! request batches through it with one [`linear::forward_multi`] pass
//! per linear layer — bit-identical per request to independent runs.
//!
//! [`channel`] gives byte-accounted duplex pipes so every experiment can
//! report communication alongside latency; [`client`]/[`server`] wrap the
//! per-party state machines used by the serving coordinator.

pub mod channel;
pub mod client;
pub mod linear;
pub mod messages;
pub mod offline;
pub mod online;
pub mod server;

pub use channel::Channel;
pub use offline::{
    offline_relu_layer, offline_relu_layer_mt, ClientReluMaterial, ServerReluMaterial,
};
pub use online::{online_relu_layer, online_relu_layer_multi, OnlineReluStats, OnlineScratch};

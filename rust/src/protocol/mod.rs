//! The Delphi-style two-party PI protocol with Circa's ReLU variants.
//!
//! A network inference alternates linear layers (additive secret sharing,
//! [`linear`]) and ReLU layers (garbled circuits + Beaver triples,
//! [`online`]). Everything input-independent happens in [`offline`]:
//! client randomness, the HE-simulated `W·r − s` precomputation, circuit
//! garbling, input-label OTs, and triple generation. The online phase —
//! the paper's headline metric — moves only what it must: the server's
//! input labels, the GC evaluation, output colors, and (for Circa
//! variants) one Beaver round plus a resharing element.
//!
//! [`channel`] gives byte-accounted duplex pipes so every experiment can
//! report communication alongside latency; [`client`]/[`server`] wrap the
//! per-party state machines used by the serving coordinator.

pub mod channel;
pub mod client;
pub mod linear;
pub mod messages;
pub mod offline;
pub mod online;
pub mod server;

pub use channel::Channel;
pub use offline::{offline_relu_layer, ClientReluMaterial, ServerReluMaterial};
pub use online::{online_relu_layer, OnlineReluStats};

//! Linear-layer protocol (Delphi §2.3, reused verbatim by Circa).
//!
//! Offline: the client holds mask `r` for the layer input and obtains
//! `W·r − s` without the server learning `r` (HE in the paper — here an
//! HE-*simulated* dealer with an attached cost model, see DESIGN.md §5).
//! Online: the server computes `W·(y − r) + s` on its share — one
//! plaintext-speed linear application — after which the parties hold
//! additive shares of `x = W·y`.

use crate::field::Fp;
use crate::ss::Share;
use crate::util::Rng;

/// A plaintext-linear operation over field vectors (dense layer, conv,
/// average-pool…). Implemented by [`crate::nn`] layers.
pub trait LinearOp: Send + Sync {
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// Apply to a full vector, *including* any bias term. Used on the
    /// server's online share so the bias enters the sum exactly once.
    fn apply(&self, input: &[Fp]) -> Vec<Fp>;
    /// Apply WITHOUT the bias term — used on the client's offline share
    /// (`W·r − s`); the affine part must not be double-counted across
    /// the two shares. Default: same as `apply` (bias-free ops).
    fn apply_no_bias(&self, input: &[Fp]) -> Vec<Fp> {
        self.apply(input)
    }

    /// Apply to R request vectors in one pass (with bias, like
    /// [`LinearOp::apply`]). The default loops `apply`; dense ops
    /// override to load each weight row once and stream it across all
    /// requests. Must be bit-identical to per-vector `apply`.
    fn apply_multi(&self, inputs: &[&[Fp]]) -> Vec<Vec<Fp>> {
        inputs.iter().map(|x| self.apply(x)).collect()
    }
}

/// Dense matrix `W` (row-major `out × in`) — the reference LinearOp.
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<Fp>,
}

impl Matrix {
    pub fn random(rows: usize, cols: usize, max_mag: i64, rng: &mut Rng) -> Self {
        let data = (0..rows * cols)
            .map(|_| Fp::from_i64(rng.below(2 * max_mag as u64 + 1) as i64 - max_mag))
            .collect();
        Matrix { rows, cols, data }
    }
}

impl LinearOp for Matrix {
    fn in_dim(&self) -> usize {
        self.cols
    }

    fn out_dim(&self) -> usize {
        self.rows
    }

    fn apply(&self, input: &[Fp]) -> Vec<Fp> {
        assert_eq!(input.len(), self.cols);
        let mut out = Vec::with_capacity(self.rows);
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            let mut acc = Fp::ZERO;
            for (w, x) in row.iter().zip(input) {
                acc = acc + *w * *x;
            }
            out.push(acc);
        }
        out
    }

    /// Row-outer, request-inner: each weight row is loaded once and
    /// dotted against every request's vector while it is hot. The
    /// per-(row, request) fold order is exactly [`LinearOp::apply`]'s,
    /// so results are bit-identical to R independent applications.
    fn apply_multi(&self, inputs: &[&[Fp]]) -> Vec<Vec<Fp>> {
        for x in inputs {
            assert_eq!(x.len(), self.cols);
        }
        let mut out: Vec<Vec<Fp>> =
            inputs.iter().map(|_| Vec::with_capacity(self.rows)).collect();
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (x, o) in inputs.iter().zip(out.iter_mut()) {
                let mut acc = Fp::ZERO;
                for (w, v) in row.iter().zip(*x) {
                    acc = acc + *w * *v;
                }
                o.push(acc);
            }
        }
        out
    }
}

/// HE cost model for the offline linear precompute (Delphi-style packed
/// RLWE): one ciphertext per `HE_SLOTS` values each direction, `HE_CT_BYTES`
/// per ciphertext. Only bytes are modeled — the offline phase is not on
/// the latency path this repo measures.
pub const HE_SLOTS: usize = 4096;
pub const HE_CT_BYTES: usize = 1 << 17; // 128 KiB per ciphertext (n=4096, 2 moduli)

/// Result of the offline linear phase.
pub struct LinearOffline {
    /// Client's (offline-known) share of the layer output `⟨x⟩_c = W·r − s`.
    pub client_x_share: Vec<Share>,
    /// Server's additive blind `s`.
    pub s: Vec<Share>,
    /// Modeled HE traffic for this layer.
    pub he_bytes: u64,
}

/// Run the offline linear phase for one layer with client mask `r`.
pub fn offline_linear(op: &dyn LinearOp, r: &[Fp], rng: &mut Rng) -> LinearOffline {
    assert_eq!(r.len(), op.in_dim());
    let s: Vec<Fp> = (0..op.out_dim()).map(|_| crate::field::random_fp(rng)).collect();
    let wr = op.apply_no_bias(r);
    let client_x_share: Vec<Fp> = wr.iter().zip(&s).map(|(&a, &b)| a - b).collect();
    let ct_in = r.len().div_ceil(HE_SLOTS);
    let ct_out = s.len().div_ceil(HE_SLOTS);
    LinearOffline { client_x_share, s, he_bytes: ((ct_in + ct_out) * HE_CT_BYTES) as u64 }
}

/// Online linear phase: the server applies the layer to its share of the
/// input and adds its blind: `⟨x⟩_s = W·(y − r) + s`.
pub fn online_linear(op: &dyn LinearOp, y_server_share: &[Fp], s: &[Fp]) -> Vec<Fp> {
    let mut out = op.apply(y_server_share);
    for (o, &b) in out.iter_mut().zip(s) {
        *o = *o + b;
    }
    out
}

/// One contiguous chunk of the batched online linear phase: apply the
/// layer across the chunk's request vectors in one cache-friendly pass,
/// then fold in each request's blind.
fn forward_chunk(op: &dyn LinearOp, ys: &[&[Fp]], ss: &[&[Fp]]) -> Vec<Vec<Fp>> {
    let mut outs = op.apply_multi(ys);
    for (out, s) in outs.iter_mut().zip(ss) {
        assert_eq!(out.len(), s.len());
        for (o, &b) in out.iter_mut().zip(*s) {
            *o = *o + b;
        }
    }
    outs
}

/// Batched [`online_linear`]: apply one layer's weights across R
/// requests' server shares (each with its own blind `s`) in one pass,
/// optionally chunk-parallel across `n_threads` workers like the offline
/// garble column. Output order follows input order and every element is
/// bit-identical to the per-request path regardless of thread count.
pub fn forward_multi(
    op: &dyn LinearOp,
    y_shares: &[&[Fp]],
    s: &[&[Fp]],
    n_threads: usize,
) -> Vec<Vec<Fp>> {
    let r_count = y_shares.len();
    assert_eq!(s.len(), r_count, "one blind vector per request");
    let n_chunks = n_threads.max(1).min(r_count.max(1));
    if n_chunks <= 1 {
        return forward_chunk(op, y_shares, s);
    }
    let per = r_count.div_ceil(n_chunks);
    std::thread::scope(|scope| {
        let handles: Vec<_> = y_shares
            .chunks(per)
            .zip(s.chunks(per))
            .map(|(ys, ss)| scope.spawn(move || forward_chunk(op, ys, ss)))
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("linear worker")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::random_fp;
    use crate::ss::reconstruct_vec;
    use crate::util::Rng;

    #[test]
    fn shares_reconstruct_to_matmul() {
        let mut rng = Rng::new(1);
        let w = Matrix::random(8, 16, 100, &mut rng);
        // True input y, client mask r.
        let y: Vec<Fp> = (0..16).map(|_| Fp::from_i64(rng.below(2001) as i64 - 1000)).collect();
        let r: Vec<Fp> = (0..16).map(|_| random_fp(&mut rng)).collect();
        let off = offline_linear(&w, &r, &mut rng);
        // Server's online input share: y − r.
        let ys: Vec<Fp> = y.iter().zip(&r).map(|(&a, &b)| a - b).collect();
        let server_x = online_linear(&w, &ys, &off.s);
        let got = reconstruct_vec(&off.client_x_share, &server_x);
        assert_eq!(got, w.apply(&y));
    }

    #[test]
    fn client_share_is_blinded() {
        // ⟨x⟩_c = W·r − s with uniform s must be ~uniform: check the low
        // bit balance across repetitions.
        let mut rng = Rng::new(2);
        let w = Matrix::random(1, 4, 10, &mut rng);
        let r: Vec<Fp> = (0..4).map(|_| random_fp(&mut rng)).collect();
        let mut low = 0;
        let n = 2000;
        for _ in 0..n {
            let off = offline_linear(&w, &r, &mut rng);
            if off.client_x_share[0].raw() % 2 == 0 {
                low += 1;
            }
        }
        let frac = low as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "biased: {frac}");
    }

    #[test]
    fn he_bytes_scale_with_dims() {
        let mut rng = Rng::new(3);
        let small = Matrix::random(4, 4, 10, &mut rng);
        let big = Matrix::random(4096, 8192, 10, &mut rng);
        let r_small: Vec<Fp> = (0..4).map(|_| random_fp(&mut rng)).collect();
        let r_big: Vec<Fp> = (0..8192).map(|_| random_fp(&mut rng)).collect();
        let off_small = offline_linear(&small, &r_small, &mut rng);
        let off_big = offline_linear(&big, &r_big, &mut rng);
        assert!(off_big.he_bytes > off_small.he_bytes);
    }

    #[test]
    #[should_panic]
    fn dimension_mismatch_panics() {
        let mut rng = Rng::new(4);
        let w = Matrix::random(2, 3, 10, &mut rng);
        w.apply(&[Fp::ZERO; 5]);
    }

    #[test]
    fn apply_multi_matches_per_vector_apply() {
        let mut rng = Rng::new(5);
        let w = Matrix::random(7, 9, 50, &mut rng);
        for r_count in [1usize, 2, 8] {
            let xs: Vec<Vec<Fp>> = (0..r_count)
                .map(|_| (0..9).map(|_| random_fp(&mut rng)).collect())
                .collect();
            let refs: Vec<&[Fp]> = xs.iter().map(|x| x.as_slice()).collect();
            let got = w.apply_multi(&refs);
            for (x, g) in xs.iter().zip(&got) {
                assert_eq!(g, &w.apply(x), "R={r_count}");
            }
        }
    }

    #[test]
    fn forward_multi_matches_online_linear_any_thread_count() {
        let mut rng = Rng::new(6);
        let w = Matrix::random(6, 11, 30, &mut rng);
        let r_count = 5;
        let ys: Vec<Vec<Fp>> =
            (0..r_count).map(|_| (0..11).map(|_| random_fp(&mut rng)).collect()).collect();
        let ss: Vec<Vec<Fp>> =
            (0..r_count).map(|_| (0..6).map(|_| random_fp(&mut rng)).collect()).collect();
        let y_refs: Vec<&[Fp]> = ys.iter().map(|v| v.as_slice()).collect();
        let s_refs: Vec<&[Fp]> = ss.iter().map(|v| v.as_slice()).collect();
        let want: Vec<Vec<Fp>> =
            ys.iter().zip(&ss).map(|(y, s)| online_linear(&w, y, s)).collect();
        for threads in [1usize, 2, 3, 16] {
            let got = forward_multi(&w, &y_refs, &s_refs, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }
}

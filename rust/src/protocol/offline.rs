//! Offline (input-independent) phase for one ReLU layer.
//!
//! Per ReLU the server garbles a fresh instance of the layer's shared
//! circuit template (GCs cannot be reused across inferences — paper
//! footnote 2) and sends the tables to the client; the client's input
//! labels are delivered by offline OT (all client GC inputs are
//! offline-known in Delphi: `⟨x⟩_c = W·r − s` comes from the HE
//! precomputation and `r` is client-chosen). Circa variants additionally
//! draw one Beaver triple per ReLU.
//!
//! Material is layer-level SoA ([`crate::gc::batch`]): one circuit + one
//! contiguous table buffer + one contiguous label arena per layer, so
//! `offline_bytes` falls straight out of buffer lengths and the dealer
//! loop allocates O(#layer), not O(#ReLU).

use crate::beaver::{self, TripleShare};
use crate::circuits::spec::{FaultMode, ReluVariant, VariantSpec};
use crate::field::{random_fp, Fp};
use crate::gc::batch::{LayerEncodingBatch, LayerGcBatch};
use crate::ot;
use crate::prf::Label;
use crate::util::Rng;

/// Client-side offline material for one ReLU layer of `n` elements.
pub struct ClientReluMaterial {
    /// Resolved variant behavior (layout, encoders, circuit builder).
    pub spec: VariantSpec,
    /// The layer's shared circuit + contiguous garbled tables and decode
    /// bits (received from the server).
    pub gc: LayerGcBatch,
    /// Contiguous client-input label arena, stride =
    /// `spec.n_client_inputs` (via offline OT).
    pub client_labels: Vec<Label>,
    /// Client's share of the sign value v (it chose r_v) — sign variants.
    pub r_v: Vec<Fp>,
    /// Client's share of the layer output (r for baseline, r_y for sign
    /// variants after resharing).
    pub r_out: Vec<Fp>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
    /// Offline bytes charged to this layer (tables + OT + triples).
    pub offline_bytes: u64,
}

impl ClientReluMaterial {
    /// ReLUs in the layer.
    pub fn n(&self) -> usize {
        self.gc.len()
    }

    pub fn variant(&self) -> ReluVariant {
        self.spec.variant
    }

    /// Instance `i`'s stride of the client-label arena.
    pub fn client_labels_of(&self, i: usize) -> &[Label] {
        let s = self.spec.n_client_inputs;
        &self.client_labels[i * s..(i + 1) * s]
    }
}

/// Server-side offline material for one ReLU layer.
pub struct ServerReluMaterial {
    pub spec: VariantSpec,
    /// Contiguous full-input encoding arena (to produce online labels for
    /// ⟨x⟩_s), one free-XOR delta per ReLU.
    pub encodings: LayerEncodingBatch,
    /// Contiguous output decode bits, stride = `spec.n_outputs` (the
    /// server decodes the colors the client returns — the GC output is
    /// the *server's* share).
    pub output_decode: Vec<bool>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
}

impl ServerReluMaterial {
    /// ReLUs in the layer.
    pub fn n(&self) -> usize {
        self.encodings.len()
    }

    pub fn variant(&self) -> ReluVariant {
        self.spec.variant
    }

    /// Instance `i`'s stride of the decode-bit buffer.
    pub fn decode_of(&self, i: usize) -> &[bool] {
        let s = self.spec.n_outputs;
        &self.output_decode[i * s..(i + 1) * s]
    }
}

/// Run the offline phase for one ReLU layer.
///
/// `xc`: the client's (offline-known) shares of the layer's ReLU inputs.
/// Returns both parties' material; the byte ledger for offline traffic is
/// embedded in the client material (tables + OT + triple shares).
pub fn offline_relu_layer(
    variant: ReluVariant,
    xc: &[Fp],
    rng: &mut Rng,
) -> (ClientReluMaterial, ServerReluMaterial) {
    let n = xc.len();
    let spec = variant.spec();
    let circuit = spec.build_circuit();

    let mut gc = LayerGcBatch::new(circuit, n);
    let mut encodings = LayerEncodingBatch::new(spec.n_inputs(), n);
    let mut client_labels: Vec<Label> = Vec::with_capacity(n * spec.n_client_inputs);
    let mut server_decode: Vec<bool> = Vec::with_capacity(n * spec.n_outputs);
    let mut r_v = Vec::with_capacity(n);
    let mut r_out = Vec::with_capacity(n);
    let mut triples_c = Vec::new();
    let mut triples_s = Vec::new();
    let mut scratch = Vec::new();

    for i in 0..n {
        // One garbling of the shared template per ReLU (fresh labels).
        gc.garble_next(&mut encodings, rng, &mut scratch);

        let rv = random_fp(rng);
        let rout = random_fp(rng);
        let bits = spec.client_bits(xc[i], rv, rout);
        ot::ot_choose_into(encodings.view(i), 0, &bits, &mut client_labels);

        if spec.uses_beaver() {
            let t = beaver::gen_triple(rng);
            triples_c.push(t.p1);
            triples_s.push(t.p2);
        }

        server_decode.extend_from_slice(gc.decode_of(i));
        r_v.push(rv);
        r_out.push(rout);
    }

    // The byte ledger falls out of the buffer lengths: garbled tables +
    // OT'd client labels + dealer-shipped triples (3 field elems/party).
    let offline_bytes = gc.table_bytes() as u64
        + (client_labels.len() * ot::OT_BYTES_PER_BIT) as u64
        + (triples_c.len() * 6 * 4) as u64;

    (
        ClientReluMaterial {
            spec,
            gc,
            client_labels,
            r_v,
            r_out,
            triples: triples_c,
            offline_bytes,
        },
        ServerReluMaterial { spec, encodings, output_decode: server_decode, triples: triples_s },
    )
}

/// Convenience used by tests/benches: PosZero truncated variant.
pub fn circa_variant(k: u32) -> ReluVariant {
    ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ss::SharePair;

    #[test]
    fn material_shapes() {
        let mut rng = Rng::new(1);
        let xc: Vec<Fp> = (0..8).map(|_| random_fp(&mut rng)).collect();
        for variant in [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            circa_variant(12),
        ] {
            let (c, s) = offline_relu_layer(variant, &xc, &mut rng);
            assert_eq!(c.n(), 8);
            assert_eq!(s.n(), 8);
            assert_eq!(c.triples.len(), if variant.uses_beaver() { 8 } else { 0 });
            assert!(c.offline_bytes > 0);
            // Client labels cover exactly the client's input block.
            assert_eq!(c.client_labels_of(0).len(), c.spec.server_input_base());
            assert_eq!(c.client_labels.len(), 8 * c.spec.n_client_inputs);
            // Flat decode buffer covers every output bit of the layer.
            assert_eq!(s.output_decode.len(), 8 * s.spec.n_outputs);
        }
    }

    #[test]
    fn layer_material_is_one_buffer_per_kind() {
        // The acceptance shape: one Circuit, one contiguous table buffer,
        // one contiguous label arena — strides multiply out exactly.
        let mut rng = Rng::new(5);
        let xc: Vec<Fp> = (0..6).map(|_| random_fp(&mut rng)).collect();
        let (c, s) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        assert_eq!(c.gc.table_bytes(), 6 * c.gc.and_stride() * 32);
        assert_eq!(s.encodings.label_bytes(), 6 * c.spec.n_inputs() * 16);
        assert_eq!(c.gc.output_decode().len(), 6 * c.spec.n_outputs);
    }

    #[test]
    fn fresh_material_per_relu() {
        let mut rng = Rng::new(2);
        let x = Fp::from_i64(5);
        let sh = SharePair::share(x, &mut rng);
        let (c, _) = offline_relu_layer(circa_variant(12), &[sh.client, sh.client], &mut rng);
        assert_ne!(c.gc.table_of(0)[0][0], c.gc.table_of(1)[0][0]);
        assert_ne!(c.r_v[0], c.r_v[1]);
    }

    #[test]
    fn offline_bytes_scale_with_circuit() {
        let mut rng = Rng::new(3);
        let xc: Vec<Fp> = (0..4).map(|_| random_fp(&mut rng)).collect();
        let (base, _) = offline_relu_layer(ReluVariant::BaselineRelu, &xc, &mut rng);
        let (circa, _) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        // Tables shrink ~5× (50 vs 248 ANDs); OT bytes dilute the total
        // ratio to ~2.2× — Fig. 5's storage claim is about tables only.
        assert!(
            circa.offline_bytes * 2 < base.offline_bytes,
            "circa {} vs baseline {}",
            circa.offline_bytes,
            base.offline_bytes
        );
    }
}

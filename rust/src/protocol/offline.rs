//! Offline (input-independent) phase for one ReLU layer.
//!
//! Per ReLU the server garbles a fresh instance of the layer's shared
//! circuit template (GCs cannot be reused across inferences — paper
//! footnote 2) and sends the tables to the client; the client's input
//! labels are delivered by offline OT (all client GC inputs are
//! offline-known in Delphi: `⟨x⟩_c = W·r − s` comes from the HE
//! precomputation and `r` is client-chosen). Circa variants additionally
//! draw one Beaver triple per ReLU.
//!
//! Material is layer-level SoA ([`crate::gc::batch`]): one circuit + one
//! contiguous table buffer + one contiguous label arena per layer, so
//! `offline_bytes` falls straight out of buffer lengths and the dealer
//! loop allocates O(#layer), not O(#ReLU).
//!
//! # Column-wise RNG schedule
//!
//! Randomness is drawn **column by column**, not ReLU by ReLU: the
//! layer's parent RNG is forked once per material column — garbled labels
//! ([`COL_GARBLE`]), the client's sign shares ([`COL_RV`]), output masks
//! ([`COL_ROUT`]), OT ([`COL_OT`], reserved), Beaver triples
//! ([`COL_TRIPLE`]) — in that fixed order, and each column's draws come
//! only from its own fork. That makes whole-layer dealing parallel *and*
//! reproducible: the garble column rides
//! [`LayerGcBatch::garble_chunked`]'s per-chunk forks across dealer
//! threads, the Beaver-triple column is chunk-forked the same way (one
//! sub-fork of the triple fork per [`GARBLE_CHUNK`] instances, filled
//! across up to the same thread count), the remaining scalar columns
//! fill sequentially, and the material is a function of the seed alone —
//! bit-identical for every thread count (the contract `garble_chunked`
//! established, now extended to the whole layer deal via
//! [`offline_relu_layer_mt`]).

use crate::beaver::{self, TripleShare};
use crate::circuits::spec::{FaultMode, ReluVariant, VariantSpec};
use crate::field::{random_fp, Fp};
use crate::gc::batch::{LayerEncodingBatch, LayerGcBatch, GARBLE_CHUNK};
use crate::ot;
use crate::prf::Label;
use crate::util::Rng;

/// Client-side offline material for one ReLU layer of `n` elements.
pub struct ClientReluMaterial {
    /// Resolved variant behavior (layout, encoders, circuit builder).
    pub spec: VariantSpec,
    /// The layer's shared circuit + contiguous garbled tables and decode
    /// bits (received from the server).
    pub gc: LayerGcBatch,
    /// Contiguous client-input label arena, stride =
    /// `spec.n_client_inputs` (via offline OT).
    pub client_labels: Vec<Label>,
    /// Client's share of the sign value v (it chose r_v) — sign variants.
    pub r_v: Vec<Fp>,
    /// Client's share of the layer output (r for baseline, r_y for sign
    /// variants after resharing).
    pub r_out: Vec<Fp>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
    /// Offline bytes charged to this layer (tables + OT + triples).
    pub offline_bytes: u64,
}

impl ClientReluMaterial {
    /// ReLUs in the layer.
    pub fn n(&self) -> usize {
        self.gc.len()
    }

    pub fn variant(&self) -> ReluVariant {
        self.spec.variant
    }

    /// Instance `i`'s stride of the client-label arena.
    pub fn client_labels_of(&self, i: usize) -> &[Label] {
        let s = self.spec.n_client_inputs;
        &self.client_labels[i * s..(i + 1) * s]
    }
}

/// Server-side offline material for one ReLU layer.
pub struct ServerReluMaterial {
    pub spec: VariantSpec,
    /// Contiguous full-input encoding arena (to produce online labels for
    /// ⟨x⟩_s), one free-XOR delta per ReLU.
    pub encodings: LayerEncodingBatch,
    /// Contiguous output decode bits, stride = `spec.n_outputs` (the
    /// server decodes the colors the client returns — the GC output is
    /// the *server's* share).
    pub output_decode: Vec<bool>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
}

impl ServerReluMaterial {
    /// ReLUs in the layer.
    pub fn n(&self) -> usize {
        self.encodings.len()
    }

    pub fn variant(&self) -> ReluVariant {
        self.spec.variant
    }

    /// Instance `i`'s stride of the decode-bit buffer.
    pub fn decode_of(&self, i: usize) -> &[bool] {
        let s = self.spec.n_outputs;
        &self.output_decode[i * s..(i + 1) * s]
    }
}

/// Fork tag of the garbled-label column (feeds
/// [`LayerGcBatch::garble_chunked`]'s per-chunk sub-forks).
pub const COL_GARBLE: u64 = 1;
/// Fork tag of the client sign-share column (`r_v`).
pub const COL_RV: u64 = 2;
/// Fork tag of the output-mask column (`r_out`).
pub const COL_ROUT: u64 = 3;
/// Fork tag of the OT column. The simulated offline OT draws no
/// randomness today, but the stream is reserved so a real OT (e.g. IKNP
/// sender randomness) can consume it later without shifting the other
/// columns' draws.
pub const COL_OT: u64 = 4;
/// Fork tag of the Beaver-triple column.
pub const COL_TRIPLE: u64 = 5;

/// Run the offline phase for one ReLU layer on one thread.
///
/// `xc`: the client's (offline-known) shares of the layer's ReLU inputs.
/// Returns both parties' material; the byte ledger for offline traffic is
/// embedded in the client material (tables + OT + triple shares).
pub fn offline_relu_layer(
    variant: ReluVariant,
    xc: &[Fp],
    rng: &mut Rng,
) -> (ClientReluMaterial, ServerReluMaterial) {
    offline_relu_layer_mt(variant, xc, rng, 1)
}

/// [`offline_relu_layer`] with the garble column split across up to
/// `n_threads` dealer threads. Output is **bit-identical for every
/// thread count** (the column-wise RNG schedule above): a dealer box can
/// use all its cores and still ship the exact material a single-threaded
/// inline deal from the same seed would produce.
pub fn offline_relu_layer_mt(
    variant: ReluVariant,
    xc: &[Fp],
    rng: &mut Rng,
    n_threads: usize,
) -> (ClientReluMaterial, ServerReluMaterial) {
    let n = xc.len();
    let spec = variant.spec();
    // Memoized optimized template — one build per variant per process,
    // shared by every layer batch via `Arc`.
    let circuit = spec.circuit();

    // Column forks, drawn from the parent in this fixed order — the
    // schedule contract that `tests/batch_equivalence.rs` re-derives.
    let mut rng_garble = rng.fork(COL_GARBLE);
    let mut rng_rv = rng.fork(COL_RV);
    let mut rng_rout = rng.fork(COL_ROUT);
    let _rng_ot = rng.fork(COL_OT);
    let mut rng_triple = rng.fork(COL_TRIPLE);

    // Garble column: the layer's one heavy column, chunk-parallel.
    let mut gc = LayerGcBatch::new(circuit, n);
    let mut encodings = LayerEncodingBatch::new(spec.n_inputs(), n);
    gc.garble_chunked(&mut encodings, n, &mut rng_garble, n_threads);

    // Scalar columns: one contiguous draw run per column.
    let r_v: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng_rv)).collect();
    let r_out: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng_rout)).collect();

    // OT column: label selection is deterministic given the encodings
    // (the simulated OT draws nothing — see COL_OT).
    let mut client_labels: Vec<Label> = Vec::with_capacity(n * spec.n_client_inputs);
    for i in 0..n {
        let bits = spec.client_bits(xc[i], r_v[i], r_out[i]);
        ot::ot_choose_into(encodings.view(i), 0, &bits, &mut client_labels);
    }

    // Triple column: chunk-forked like the garble column, so triple
    // generation scales across the same dealer threads.
    let (triples_c, triples_s): (Vec<TripleShare>, Vec<TripleShare>) = if spec.uses_beaver() {
        triple_column_chunked(n, &mut rng_triple, n_threads)
    } else {
        (Vec::new(), Vec::new())
    };

    let server_decode = gc.output_decode().to_vec();

    // The byte ledger falls out of the buffer lengths: garbled tables +
    // OT'd client labels + dealer-shipped triples (3 field elems/party).
    let offline_bytes = gc.table_bytes() as u64
        + (client_labels.len() * ot::OT_BYTES_PER_BIT) as u64
        + (triples_c.len() * 6 * 4) as u64;

    (
        ClientReluMaterial {
            spec,
            gc,
            client_labels,
            r_v,
            r_out,
            triples: triples_c,
            offline_bytes,
        },
        ServerReluMaterial { spec, encodings, output_decode: server_decode, triples: triples_s },
    )
}

/// Fill the Beaver-triple column with the same chunk-fork discipline as
/// [`LayerGcBatch::garble_chunked`]: sub-fork the column fork once per
/// [`GARBLE_CHUNK`] instances (forks drawn sequentially up front, so the
/// stream of chunk `c` never depends on scheduling), then fill disjoint
/// chunk ranges across up to `n_threads` threads. Output is
/// **bit-identical for every thread count** — pinned by
/// `tests/offline_schedule.rs`, with the schedule itself re-derived in
/// `tests/batch_equivalence.rs` (a one-time re-anchor from the old
/// sequential triple draw, exactly like the garble column's move).
fn triple_column_chunked(
    n: usize,
    rng_triple: &mut Rng,
    n_threads: usize,
) -> (Vec<TripleShare>, Vec<TripleShare>) {
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let n_chunks = n.div_ceil(GARBLE_CHUNK);
    let mut forks: Vec<Rng> = (0..n_chunks).map(|c| rng_triple.fork(c as u64)).collect();
    let n_groups = n_threads.max(1).min(n_chunks);
    let zero = TripleShare { a: Fp::ZERO, b: Fp::ZERO, ab: Fp::ZERO };
    let mut tc = vec![zero; n];
    let mut ts = vec![zero; n];
    if n_groups == 1 {
        // Single group: fill in place, no thread spawn.
        for (chunk_idx, mut frng) in forks.into_iter().enumerate() {
            let lo = chunk_idx * GARBLE_CHUNK;
            let hi = (lo + GARBLE_CHUNK).min(n);
            for i in lo..hi {
                let t = beaver::gen_triple(&mut frng);
                tc[i] = t.p1;
                ts[i] = t.p2;
            }
        }
        return (tc, ts);
    }
    let chunks_per_group = n_chunks.div_ceil(n_groups);
    std::thread::scope(|scope| {
        let mut tc_rest = &mut tc[..];
        let mut ts_rest = &mut ts[..];
        let mut chunk0 = 0usize;
        while chunk0 < n_chunks {
            let g_chunks = chunks_per_group.min(n_chunks - chunk0);
            let lo = chunk0 * GARBLE_CHUNK;
            let hi = ((chunk0 + g_chunks) * GARBLE_CHUNK).min(n);
            let m = hi - lo;
            let g_forks: Vec<Rng> = forks.drain(..g_chunks).collect();
            let (c_slice, rest) = std::mem::take(&mut tc_rest).split_at_mut(m);
            tc_rest = rest;
            let (s_slice, rest) = std::mem::take(&mut ts_rest).split_at_mut(m);
            ts_rest = rest;
            scope.spawn(move || {
                let mut off = 0usize;
                for mut frng in g_forks {
                    let c_count = GARBLE_CHUNK.min(m - off);
                    for i in off..off + c_count {
                        let t = beaver::gen_triple(&mut frng);
                        c_slice[i] = t.p1;
                        s_slice[i] = t.p2;
                    }
                    off += c_count;
                }
            });
            chunk0 += g_chunks;
        }
    });
    (tc, ts)
}

/// Peek only the `r_out` column of a layer deal — the one cross-layer
/// data dependency (the client's ReLU output mask becomes the next
/// linear layer's input mask).
///
/// Forks the parent exactly as [`offline_relu_layer_mt`]'s column
/// schedule would ([`COL_GARBLE`], [`COL_RV`], then [`COL_ROUT`] — the
/// later columns never feed back into the parent, so stopping there is
/// safe) and draws the `r_out` column alone. This is what lets a dealer
/// produce the mask chain *through* a layer without garbling it:
/// standalone per-layer dealing walks the chain with peeks and spends
/// garbling effort only on the requested layer, yet stays bit-identical
/// to the same layer inside a whole-session deal.
pub fn peek_r_out(n: usize, rng: &mut Rng) -> Vec<Fp> {
    let _ = rng.fork(COL_GARBLE);
    let _ = rng.fork(COL_RV);
    let mut rng_rout = rng.fork(COL_ROUT);
    (0..n).map(|_| random_fp(&mut rng_rout)).collect()
}

/// Convenience used by tests/benches: PosZero truncated variant.
pub fn circa_variant(k: u32) -> ReluVariant {
    ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ss::SharePair;

    #[test]
    fn material_shapes() {
        let mut rng = Rng::new(1);
        let xc: Vec<Fp> = (0..8).map(|_| random_fp(&mut rng)).collect();
        for variant in [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            circa_variant(12),
        ] {
            let (c, s) = offline_relu_layer(variant, &xc, &mut rng);
            assert_eq!(c.n(), 8);
            assert_eq!(s.n(), 8);
            assert_eq!(c.triples.len(), if variant.uses_beaver() { 8 } else { 0 });
            assert!(c.offline_bytes > 0);
            // Client labels cover exactly the client's input block.
            assert_eq!(c.client_labels_of(0).len(), c.spec.server_input_base());
            assert_eq!(c.client_labels.len(), 8 * c.spec.n_client_inputs);
            // Flat decode buffer covers every output bit of the layer.
            assert_eq!(s.output_decode.len(), 8 * s.spec.n_outputs);
        }
    }

    #[test]
    fn layer_material_is_one_buffer_per_kind() {
        // The acceptance shape: one Circuit, one contiguous table buffer,
        // one contiguous label arena — strides multiply out exactly.
        let mut rng = Rng::new(5);
        let xc: Vec<Fp> = (0..6).map(|_| random_fp(&mut rng)).collect();
        let (c, s) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        assert_eq!(c.gc.table_bytes(), 6 * c.gc.and_stride() * 32);
        assert_eq!(s.encodings.label_bytes(), 6 * c.spec.n_inputs() * 16);
        assert_eq!(c.gc.output_decode().len(), 6 * c.spec.n_outputs);
    }

    #[test]
    fn column_schedule_thread_invariant_smoke() {
        // Full sweep lives in tests/offline_schedule.rs; this pins the
        // contract next to the code.
        let mut rng = Rng::new(77);
        let xc: Vec<Fp> = (0..10).map(|_| random_fp(&mut rng)).collect();
        let (c1, s1) = offline_relu_layer_mt(circa_variant(8), &xc, &mut Rng::new(5), 1);
        let (c4, s4) = offline_relu_layer_mt(circa_variant(8), &xc, &mut Rng::new(5), 4);
        assert_eq!(c1.gc.tables(), c4.gc.tables());
        assert_eq!(c1.client_labels, c4.client_labels);
        assert_eq!(c1.r_v, c4.r_v);
        assert_eq!(c1.r_out, c4.r_out);
        assert_eq!(s1.encodings.label0(), s4.encodings.label0());
    }

    #[test]
    fn peek_r_out_matches_full_deal() {
        // The chain peek must reproduce the real deal's r_out column
        // exactly (same parent state, same forks) for every variant —
        // it is the contract standalone layer dealing stands on.
        let mut data_rng = Rng::new(41);
        let xc: Vec<Fp> = (0..7).map(|_| random_fp(&mut data_rng)).collect();
        for variant in [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::NegPass },
            circa_variant(8),
        ] {
            let (c, _) = offline_relu_layer(variant, &xc, &mut Rng::new(0xBEE5));
            let peeked = peek_r_out(xc.len(), &mut Rng::new(0xBEE5));
            assert_eq!(peeked, c.r_out, "{variant:?}");
        }
    }

    #[test]
    fn fresh_material_per_relu() {
        let mut rng = Rng::new(2);
        let x = Fp::from_i64(5);
        let sh = SharePair::share(x, &mut rng);
        let (c, _) = offline_relu_layer(circa_variant(12), &[sh.client, sh.client], &mut rng);
        assert_ne!(c.gc.table_of(0)[0][0], c.gc.table_of(1)[0][0]);
        assert_ne!(c.r_v[0], c.r_v[1]);
    }

    #[test]
    fn offline_bytes_scale_with_circuit() {
        let mut rng = Rng::new(3);
        let xc: Vec<Fp> = (0..4).map(|_| random_fp(&mut rng)).collect();
        let (base, _) = offline_relu_layer(ReluVariant::BaselineRelu, &xc, &mut rng);
        let (circa, _) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        // Tables shrink ~5× (50 vs 248 ANDs); OT bytes dilute the total
        // ratio to ~2.2× — Fig. 5's storage claim is about tables only.
        assert!(
            circa.offline_bytes * 2 < base.offline_bytes,
            "circa {} vs baseline {}",
            circa.offline_bytes,
            base.offline_bytes
        );
    }
}

//! Offline (input-independent) phase for one ReLU layer.
//!
//! Per ReLU the server garbles a fresh circuit instance (GCs cannot be
//! reused across inferences — paper footnote 2) and sends the tables to
//! the client; the client's input labels are delivered by offline OT
//! (all client GC inputs are offline-known in Delphi: `⟨x⟩_c = W·r − s`
//! comes from the HE precomputation and `r` is client-chosen). Circa
//! variants additionally draw one Beaver triple per ReLU.

use crate::beaver::{self, TripleShare};
use crate::circuits::spec::{fp_bits, FaultMode, ReluVariant};
use crate::circuits::{relu_gc, stoch_sign_gc};
use crate::field::{random_fp, Fp};
use crate::gc::circuit::Circuit;
use crate::gc::garble::{GarbledCircuit, InputEncoding};
use crate::ot;
use crate::prf::Label;
use crate::util::Rng;

/// Client-side offline material for one ReLU layer of `n` elements.
pub struct ClientReluMaterial {
    pub variant: ReluVariant,
    /// Circuit structure (public).
    pub circuit: Circuit,
    /// Per-ReLU garbled tables + decode info (received from server).
    pub gcs: Vec<GarbledCircuit>,
    /// Per-ReLU labels for the client's own input block (via offline OT).
    pub client_labels: Vec<Vec<Label>>,
    /// Client's share of the sign value v (it chose r_v) — sign variants.
    pub r_v: Vec<Fp>,
    /// Client's share of the layer output (r for baseline, r_y for sign
    /// variants after resharing).
    pub r_out: Vec<Fp>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
    /// Offline bytes charged to this layer (tables + OT + triples).
    pub offline_bytes: u64,
}

/// Server-side offline material for one ReLU layer.
pub struct ServerReluMaterial {
    pub variant: ReluVariant,
    pub circuit: Circuit,
    /// Per-ReLU full input encodings (to produce online labels for ⟨x⟩_s).
    pub encodings: Vec<InputEncoding>,
    /// Per-ReLU output decode bits (server decodes the colors the client
    /// returns — the GC output is the *server's* share).
    pub output_decode: Vec<Vec<bool>>,
    /// Beaver triple shares (sign variants).
    pub triples: Vec<TripleShare>,
}

/// Index of the first server input bit within the circuit input layout.
pub fn server_input_base(variant: ReluVariant) -> usize {
    match variant {
        ReluVariant::BaselineRelu => relu_gc::N_CLIENT_INPUTS,
        ReluVariant::NaiveSign => crate::circuits::sign_gc::N_CLIENT_INPUTS,
        ReluVariant::StochasticSign { .. } => stoch_sign_gc::n_client_inputs(0),
        ReluVariant::TruncatedSign { k, .. } => stoch_sign_gc::n_client_inputs(k),
    }
}

/// Truncation level of a variant (0 when not truncated).
pub fn variant_k(variant: ReluVariant) -> u32 {
    match variant {
        ReluVariant::TruncatedSign { k, .. } => k,
        _ => 0,
    }
}

/// Build the circuit for a variant.
pub fn build_circuit(variant: ReluVariant) -> Circuit {
    match variant {
        ReluVariant::BaselineRelu => relu_gc::build(),
        ReluVariant::NaiveSign => crate::circuits::sign_gc::build(),
        ReluVariant::StochasticSign { mode } => stoch_sign_gc::build(mode),
        ReluVariant::TruncatedSign { k, mode } => stoch_sign_gc::build_truncated(k, mode),
    }
}

/// The client's GC input bits for one ReLU, given its offline-known share
/// `xc` and its chosen randomness.
fn client_bits(variant: ReluVariant, xc: Fp, r_v: Fp, r_out: Fp) -> Vec<bool> {
    match variant {
        ReluVariant::BaselineRelu => {
            // Fig 2(a): ⟨x⟩_c then r (the output mask).
            let mut bits = fp_bits(xc);
            bits.extend(fp_bits(r_out));
            bits
        }
        ReluVariant::NaiveSign => {
            // Fig 2(b): ⟨x⟩_c, −r_v, 1−r_v.
            let mut bits = fp_bits(xc);
            bits.extend(fp_bits(-r_v));
            bits.extend(fp_bits(Fp::ONE - r_v));
            bits
        }
        ReluVariant::StochasticSign { .. } => stoch_sign_gc::client_input_bits(xc, r_v, 0),
        ReluVariant::TruncatedSign { k, .. } => stoch_sign_gc::client_input_bits(xc, r_v, k),
    }
}

/// Run the offline phase for one ReLU layer.
///
/// `xc`: the client's (offline-known) shares of the layer's ReLU inputs.
/// Returns both parties' material; the byte ledger for offline traffic is
/// embedded in the client material (tables + OT + triple shares).
pub fn offline_relu_layer(
    variant: ReluVariant,
    xc: &[Fp],
    rng: &mut Rng,
) -> (ClientReluMaterial, ServerReluMaterial) {
    let n = xc.len();
    let circuit = build_circuit(variant);
    let mut gcs = Vec::with_capacity(n);
    let mut encodings = Vec::with_capacity(n);
    let mut client_labels = Vec::with_capacity(n);
    let mut output_decode = Vec::with_capacity(n);
    let mut r_v = Vec::with_capacity(n);
    let mut r_out = Vec::with_capacity(n);
    let mut triples_c = Vec::with_capacity(n);
    let mut triples_s = Vec::with_capacity(n);
    let mut offline_bytes = 0u64;
    let mut scratch = Vec::new();

    for i in 0..n {
        let (gc, enc) = crate::gc::garble::garble_with_scratch(&circuit, rng, &mut scratch);
        offline_bytes += gc.table_bytes() as u64;

        let rv = random_fp(rng);
        let rout = random_fp(rng);
        let bits = client_bits(variant, xc[i], rv, rout);
        let batch = ot::ot_choose(&enc, 0, &bits);
        offline_bytes += batch.bytes_on_wire as u64;

        if variant.uses_beaver() {
            let t = beaver::gen_triple(rng);
            triples_c.push(t.p1);
            triples_s.push(t.p2);
            offline_bytes += 6 * 4; // dealer ships 3 field elements/party
        }

        output_decode.push(gc.output_decode.clone());
        client_labels.push(batch.labels);
        gcs.push(gc);
        encodings.push(enc);
        r_v.push(rv);
        r_out.push(rout);
    }

    (
        ClientReluMaterial {
            variant,
            circuit: circuit.clone(),
            gcs,
            client_labels,
            r_v,
            r_out,
            triples: triples_c,
            offline_bytes,
        },
        ServerReluMaterial { variant, circuit, encodings, output_decode, triples: triples_s },
    )
}

/// Convenience used by tests/benches: PosZero truncated variant.
pub fn circa_variant(k: u32) -> ReluVariant {
    ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ss::SharePair;

    #[test]
    fn material_shapes() {
        let mut rng = Rng::new(1);
        let xc: Vec<Fp> = (0..8).map(|_| random_fp(&mut rng)).collect();
        for variant in [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            ReluVariant::StochasticSign { mode: FaultMode::PosZero },
            circa_variant(12),
        ] {
            let (c, s) = offline_relu_layer(variant, &xc, &mut rng);
            assert_eq!(c.gcs.len(), 8);
            assert_eq!(s.encodings.len(), 8);
            assert_eq!(c.triples.len(), if variant.uses_beaver() { 8 } else { 0 });
            assert!(c.offline_bytes > 0);
            // Client labels cover exactly the client's input block.
            assert_eq!(c.client_labels[0].len(), server_input_base(variant));
        }
    }

    #[test]
    fn fresh_material_per_relu() {
        let mut rng = Rng::new(2);
        let x = Fp::from_i64(5);
        let sh = SharePair::share(x, &mut rng);
        let (c, _) = offline_relu_layer(circa_variant(12), &[sh.client, sh.client], &mut rng);
        assert_ne!(c.gcs[0].table[0][0], c.gcs[1].table[0][0]);
        assert_ne!(c.r_v[0], c.r_v[1]);
    }

    #[test]
    fn offline_bytes_scale_with_circuit() {
        let mut rng = Rng::new(3);
        let xc: Vec<Fp> = (0..4).map(|_| random_fp(&mut rng)).collect();
        let (base, _) = offline_relu_layer(ReluVariant::BaselineRelu, &xc, &mut rng);
        let (circa, _) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        // Tables shrink ~5× (50 vs 248 ANDs); OT bytes dilute the total
        // ratio to ~2.2× — Fig. 5's storage claim is about tables only.
        assert!(
            circa.offline_bytes * 2 < base.offline_bytes,
            "circa {} vs baseline {}",
            circa.offline_bytes,
            base.offline_bytes
        );
    }
}

//! Online phase for ReLU layers — the paper's headline cost — batched
//! across concurrent requests.
//!
//! Message flow per layer (n ReLUs per request, R requests per batch,
//! every round one message window):
//!
//! ```text
//! server → client : R·n·(m−k) input labels for ⟨x⟩_s     (16 B each)
//! client          : ONE cross-request strided GC walk    (the hot loop)
//! client → server : R color streams, n·m bits each
//! — Circa variants additionally —
//! both   ⇄ both   : Beaver openings, one flat R·n pass each way
//! client → server : resharing deltas (1 field elem per ReLU)
//! ```
//!
//! The baseline (Fig. 2a) skips the Beaver round entirely — its GC
//! already outputs the masked ReLU — but pays ~5× more AND gates per
//! evaluation.
//!
//! [`online_relu_layer_multi`] is the batch-native core: all R requests'
//! server labels are encoded into one arena, the GC evaluation is a
//! single strided walk over the shared circuit template
//! ([`crate::gc::batch::eval_layer_colors_multi`]) whose hash flights
//! fill with the same gate position *across requests*, and the Beaver
//! open / multiply / reshare loops are flat passes over `R·n` elements.
//! Output shares are bit-identical to R independent single-request runs
//! — the protocol is deterministic given material and inputs, only the
//! scheduling changes — and the aggregated [`OnlineReluStats`] byte
//! ledger is exactly the sum of the per-request ledgers.
//! [`online_relu_layer`] is the R = 1 convenience wrapper.
//!
//! The hot loops are allocation-free per ReLU: one [`OnlineScratch`]
//! (label arena, color streams, wire scratch, opening buffers) serves a
//! whole inference batch, reused across layers the way
//! [`crate::gc::eval::evaluate_with_scratch`] reuses its wire buffer,
//! and color decoding folds bits straight into a field element with no
//! per-ReLU bit buffer.

use super::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::beaver;
use crate::field::Fp;
use crate::gc::batch::{eval_layer_colors_multi, LayerEvalSource};
use crate::prf::Label;
use crate::util::Timer;

/// Measurements from one online ReLU layer execution (aggregated over
/// the whole request batch when R > 1: bytes sum across requests, rounds
/// count each fused message window once).
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineReluStats {
    /// Wall time of the whole online exchange (both parties' compute).
    pub wall_s: f64,
    /// Bytes server → client (labels).
    pub bytes_to_client: u64,
    /// Bytes client → server (colors, openings, deltas).
    pub bytes_to_server: u64,
    /// Communication rounds.
    pub rounds: u32,
}

impl OnlineReluStats {
    pub fn bytes_total(&self) -> u64 {
        self.bytes_to_client + self.bytes_to_server
    }
}

/// Reusable buffers for the online hot loops. One instance serves a
/// whole inference — or a whole batch of inferences — with every layer
/// reusing the same allocations.
#[derive(Default)]
pub struct OnlineScratch {
    /// Fused server-label arena (all requests' labels, request-major).
    labels: Vec<Label>,
    /// Per-request color streams of the current layer.
    colors: Vec<Vec<bool>>,
    /// Wire-label scratch of the strided GC walk.
    eval: Vec<Label>,
    /// Fused Beaver opening buffers (`2·R·n` elements each).
    open_c: Vec<Fp>,
    open_s: Vec<Fp>,
}

/// Encode the server's online shares into one flat label arena (stride =
/// server inputs per ReLU). Shared by the in-process path below and the
/// channel-driven [`super::server`].
pub fn encode_server_labels(mat: &ServerReluMaterial, xs: &[Fp]) -> Vec<Label> {
    let mut out = Vec::new();
    encode_server_labels_into(mat, xs, &mut out);
    out
}

/// [`encode_server_labels`] appending into a caller-owned arena — the
/// batched path packs all R requests' labels into one buffer.
pub fn encode_server_labels_into(mat: &ServerReluMaterial, xs: &[Fp], out: &mut Vec<Label>) {
    let spec = mat.spec;
    let base = spec.server_input_base();
    out.reserve(xs.len() * spec.n_server_inputs);
    for (i, &x) in xs.iter().enumerate() {
        let bits = spec.server_bits(x);
        let view = mat.encodings.view(i);
        out.extend(bits.iter().enumerate().map(|(j, &b)| view.encode(base + j, b)));
    }
}

/// Fold one ReLU's color stride against its decode bits straight into a
/// field element — the little-endian bit fold of
/// [`crate::circuits::spec::bits_fp`] without the intermediate bit
/// buffer the decode loop used to collect per ReLU.
#[inline]
fn decode_share(colors: &[bool], decode: &[bool]) -> Fp {
    debug_assert_eq!(colors.len(), decode.len());
    let mut v = 0u64;
    for (j, (&c, &d)) in colors.iter().zip(decode).enumerate() {
        v |= ((c ^ d) as u64) << j;
    }
    Fp::reduce(v)
}

/// Decode the client's color stream into the server's output shares using
/// the layer's flat decode buffer.
pub fn decode_server_shares(mat: &ServerReluMaterial, colors: &[bool]) -> Vec<Fp> {
    let mut out = Vec::new();
    decode_server_shares_into(mat, colors, &mut out);
    out
}

/// [`decode_server_shares`] appending into a caller-owned buffer,
/// allocation-free in the per-ReLU loop.
pub fn decode_server_shares_into(mat: &ServerReluMaterial, colors: &[bool], out: &mut Vec<Fp>) {
    let m = mat.spec.n_outputs;
    let n = mat.n();
    assert_eq!(colors.len(), n * m, "color stream arity");
    out.reserve(n);
    for i in 0..n {
        out.push(decode_share(&colors[i * m..(i + 1) * m], mat.decode_of(i)));
    }
}

/// Run the online phase of one ReLU layer, in-process but with every
/// message byte-accounted as if on the wire.
///
/// Inputs: each party's shares of `x` (from the linear layer). Outputs:
/// each party's shares of `y = ReLU(x)` (stochastic under Circa), with
/// the client's share equal to its pre-chosen randomness (`r_out`),
/// ready for the next linear layer.
pub fn online_relu_layer(
    client: &ClientReluMaterial,
    server: &ServerReluMaterial,
    xc: &[Fp],
    xs: &[Fp],
) -> (Vec<Fp>, Vec<Fp>, OnlineReluStats) {
    let mut scratch = OnlineScratch::default();
    let (mut yc, mut ys, stats) =
        online_relu_layer_multi(&[client], &[server], &[xc], &[xs], &mut scratch);
    (yc.pop().expect("R = 1"), ys.pop().expect("R = 1"), stats)
}

/// Run the online phase of one ReLU layer for `R` concurrent requests as
/// one fused walk (see the module doc). Each request brings its own
/// offline material and its own shares; all requests must run the same
/// circuit template (same variant and layer width — the coordinator's
/// model-homogeneous batches guarantee it).
///
/// Returns per-request `(client shares, server shares)` plus stats
/// aggregated over the batch. Shares are bit-identical to R independent
/// [`online_relu_layer`] calls; `bytes_*` are the exact sums of the
/// per-request ledgers; `rounds` counts the fused message windows (the
/// same count a single request pays — that fusion is the point).
pub fn online_relu_layer_multi(
    clients: &[&ClientReluMaterial],
    servers: &[&ServerReluMaterial],
    xc: &[&[Fp]],
    xs: &[&[Fp]],
    scratch: &mut OnlineScratch,
) -> (Vec<Vec<Fp>>, Vec<Vec<Fp>>, OnlineReluStats) {
    let r_count = clients.len();
    assert!(r_count > 0, "empty request batch");
    assert!(
        servers.len() == r_count && xc.len() == r_count && xs.len() == r_count,
        "batch arity"
    );
    let n = clients[0].n();
    let spec = clients[0].spec;
    for r in 0..r_count {
        assert_eq!(clients[r].n(), n, "offline material arity");
        assert_eq!(servers[r].n(), n, "offline material arity");
        assert_eq!(xc[r].len(), n, "client share arity");
        assert_eq!(xs[r].len(), n, "server share arity");
        assert_eq!(clients[r].spec, spec, "one circuit template per batch");
        assert_eq!(servers[r].spec, spec, "one circuit template per batch");
    }
    let timer = Timer::new();
    let mut stats = OnlineReluStats::default();
    let OnlineScratch { labels, colors, eval, open_c, open_s } = scratch;

    // --- Round 1: every request's server labels into one arena. ---
    labels.clear();
    for (sm, x) in servers.iter().zip(xs) {
        encode_server_labels_into(sm, x, labels);
    }
    stats.bytes_to_client += labels.len() as u64 * 16;
    stats.rounds += 1;

    // --- Client: one cross-request strided walk over the shared
    // template; hash flights fill with gates across requests. ---
    let s_len = n * spec.n_server_inputs;
    if colors.len() < r_count {
        colors.resize_with(r_count, Vec::new);
    }
    let sources: Vec<LayerEvalSource<'_>> = clients
        .iter()
        .enumerate()
        .map(|(r, cm)| LayerEvalSource {
            gc: &cm.gc,
            client_labels: &cm.client_labels,
            server_labels: &labels[r * s_len..(r + 1) * s_len],
        })
        .collect();
    eval_layer_colors_multi(&sources, &mut colors[..r_count], eval);
    for c in colors[..r_count].iter() {
        stats.bytes_to_server += (c.len() as u64).div_ceil(8);
    }
    stats.rounds += 1;

    // --- Server: decode its output shares from each color stream. ---
    let mut server_out: Vec<Vec<Fp>> = Vec::with_capacity(r_count);
    for (sm, c) in servers.iter().zip(colors[..r_count].iter()) {
        let mut v = Vec::new();
        decode_server_shares_into(sm, c, &mut v);
        server_out.push(v);
    }

    if !spec.uses_beaver() {
        // Baseline: GC output *is* the masked ReLU share.
        let client_out: Vec<Vec<Fp>> = clients.iter().map(|cm| cm.r_out.clone()).collect();
        stats.wall_s = timer.elapsed_s();
        return (client_out, server_out, stats);
    }

    // --- Circa variants: y = x·v, all R·n multiplies in one fused
    // Beaver round — flat open pass, one exchange, flat mul/reshare
    // pass. Client share of v is r_v; server share came out of the GC.
    open_c.clear();
    open_s.clear();
    open_c.reserve(2 * r_count * n);
    open_s.reserve(2 * r_count * n);
    for r in 0..r_count {
        let (cm, sm) = (clients[r], servers[r]);
        let so = &server_out[r];
        for i in 0..n {
            let oc = beaver::open(xc[r][i], cm.r_v[i], &cm.triples[i]);
            let os = beaver::open(xs[r][i], so[i], &sm.triples[i]);
            open_c.push(oc.e);
            open_c.push(oc.f);
            open_s.push(os.e);
            open_s.push(os.f);
        }
    }
    // Exchange all openings (one round, both directions).
    stats.bytes_to_server += open_c.len() as u64 * 4;
    stats.bytes_to_client += open_s.len() as u64 * 4;
    stats.rounds += 1;

    // Flat multiply + resharing: the client's delta (y_c − r_out) folds
    // into the server share in the same pass, leaving the client holding
    // its pre-chosen r_out.
    let mut client_out: Vec<Vec<Fp>> = Vec::with_capacity(r_count);
    for r in 0..r_count {
        let (cm, sm) = (clients[r], servers[r]);
        let base = 2 * r * n;
        let server_y = &mut server_out[r];
        for i in 0..n {
            let e = open_c[base + 2 * i] + open_s[base + 2 * i];
            let f = open_c[base + 2 * i + 1] + open_s[base + 2 * i + 1];
            let y_c = beaver::mul_share(e, f, &cm.triples[i], true);
            let y_s = beaver::mul_share(e, f, &sm.triples[i], false);
            server_y[i] = y_s + (y_c - cm.r_out[i]);
        }
        stats.bytes_to_server += n as u64 * 4;
        client_out.push(cm.r_out.clone());
    }
    stats.rounds += 1;

    stats.wall_s = timer.elapsed_s();
    (client_out, server_out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::field::random_fp;
    use crate::protocol::offline::{circa_variant, offline_relu_layer};
    use crate::ss::{reconstruct_vec, SharePair};
    use crate::util::Rng;

    fn run_layer(variant: ReluVariant, xs_signed: &[i64], seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        let shares: Vec<SharePair> =
            xs_signed.iter().map(|&v| SharePair::share(Fp::from_i64(v), &mut rng)).collect();
        let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let xsrv: Vec<Fp> = shares.iter().map(|s| s.server).collect();
        let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
        let (yc, ys, stats) = online_relu_layer(&cm, &sm, &xc, &xsrv);
        assert!(stats.bytes_total() > 0);
        reconstruct_vec(&yc, &ys).iter().map(|y| y.to_i64()).collect()
    }

    #[test]
    fn baseline_is_exact_relu() {
        let vals = [-1_000_000i64, -321, -1, 0, 1, 7, 55_555, 1_000_000];
        let got = run_layer(ReluVariant::BaselineRelu, &vals, 1);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn naive_sign_is_exact_relu() {
        let vals = [-999_999i64, -5, -1, 0, 1, 2, 123_456];
        let got = run_layer(ReluVariant::NaiveSign, &vals, 2);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn stochastic_sign_correct_for_moderate_values() {
        // |x| ≪ p ⇒ fault probability ~0; must match exact ReLU.
        let vals = [-800_000i64, -1000, -1, 1, 1000, 800_000];
        let got = run_layer(ReluVariant::StochasticSign { mode: FaultMode::PosZero }, &vals, 3);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn truncated_sign_exact_above_2k() {
        let k = 12u32;
        let vals = [-(1i64 << 20), -(1 << 13), 1 << 13, 1 << 20];
        let got = run_layer(circa_variant(k), &vals, 4);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn truncated_poszero_zeroes_small_positives_probabilistically() {
        // x = 16 with k = 12: fault prob (2^12 − 16)/2^12 ≈ 0.996 ⇒ output
        // should be 0 almost always; run several instances.
        let k = 12u32;
        let vals = vec![16i64; 64];
        let got = run_layer(circa_variant(k), &vals, 5);
        let zeros = got.iter().filter(|&&v| v == 0).count();
        assert!(zeros >= 60, "only {zeros}/64 zeroed");
    }

    #[test]
    fn truncated_negpass_passes_small_negatives() {
        // x = −16, k = 12, NegPass: output ≈ x (passed through) with
        // prob ≈ 0.996 — i.e. y = x·1 = x, NOT zero.
        let k = 12u32;
        let variant = ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass };
        let vals = vec![-16i64; 64];
        let got = run_layer(variant, &vals, 6);
        let passed = got.iter().filter(|&&v| v == -16).count();
        assert!(passed >= 60, "only {passed}/64 passed through");
    }

    #[test]
    fn online_bytes_smaller_for_circa() {
        let mut rng = Rng::new(7);
        let vals: Vec<Fp> = (0..32).map(|_| random_fp(&mut rng)).collect();
        let shares: Vec<SharePair> = vals.iter().map(|&v| SharePair::share(v, &mut rng)).collect();
        let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();

        let (cm_b, sm_b) = offline_relu_layer(ReluVariant::BaselineRelu, &xc, &mut rng);
        let (_, _, st_b) = online_relu_layer(&cm_b, &sm_b, &xc, &xs);

        let (cm_t, sm_t) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        let (_, _, st_t) = online_relu_layer(&cm_t, &sm_t, &xc, &xs);

        // Labels dominate; Circa sends m−k=19 labels vs 31 + pays small
        // Beaver/resharing overhead. Net must still be smaller.
        assert!(
            st_t.bytes_total() < st_b.bytes_total(),
            "circa {} !< baseline {}",
            st_t.bytes_total(),
            st_b.bytes_total()
        );
    }

    #[test]
    fn client_output_share_is_prechosen_randomness() {
        // The resharing step must leave the client holding exactly r_out,
        // which the *next* layer's offline phase assumed.
        let mut rng = Rng::new(8);
        let x = Fp::from_i64(424_242);
        let sh = SharePair::share(x, &mut rng);
        let (cm, sm) = offline_relu_layer(circa_variant(12), &[sh.client], &mut rng);
        let (yc, ys, _) = online_relu_layer(&cm, &sm, &[sh.client], &[sh.server]);
        assert_eq!(yc[0], cm.r_out[0]);
        assert_eq!((yc[0] + ys[0]).to_i64(), 424_242);
    }

    #[test]
    fn multi_request_layer_matches_per_request_runs() {
        // The fused batch walk must produce bit-identical shares and an
        // exact byte-ledger sum vs independent per-request runs, for
        // every variant class and R above and below the group width.
        let variants = [
            ReluVariant::BaselineRelu,
            ReluVariant::NaiveSign,
            circa_variant(8),
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass },
        ];
        for (vi, variant) in variants.into_iter().enumerate() {
            for r_count in [1usize, 2, 8] {
                let mut rng = Rng::new(0xBA7C + (vi * 10 + r_count) as u64);
                let n = 5;
                let mut mats = Vec::new();
                let mut shares: Vec<(Vec<Fp>, Vec<Fp>)> = Vec::new();
                for _ in 0..r_count {
                    let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng)).collect();
                    let xs: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng)).collect();
                    mats.push(offline_relu_layer(variant, &xc, &mut rng));
                    shares.push((xc, xs));
                }
                let mut want = Vec::new();
                let mut sum_to_client = 0u64;
                let mut sum_to_server = 0u64;
                let mut single_rounds = 0u32;
                for ((cm, sm), (xc, xs)) in mats.iter().zip(&shares) {
                    let (yc, ys, st) = online_relu_layer(cm, sm, xc, xs);
                    sum_to_client += st.bytes_to_client;
                    sum_to_server += st.bytes_to_server;
                    single_rounds = st.rounds;
                    want.push((yc, ys));
                }
                let cms: Vec<_> = mats.iter().map(|(cm, _)| cm).collect();
                let sms: Vec<_> = mats.iter().map(|(_, sm)| sm).collect();
                let xcs: Vec<&[Fp]> = shares.iter().map(|(xc, _)| xc.as_slice()).collect();
                let xss: Vec<&[Fp]> = shares.iter().map(|(_, xs)| xs.as_slice()).collect();
                let mut scratch = OnlineScratch::default();
                let (yc, ys, st) = online_relu_layer_multi(&cms, &sms, &xcs, &xss, &mut scratch);
                for r in 0..r_count {
                    assert_eq!(yc[r], want[r].0, "{variant:?} R={r_count} client shares {r}");
                    assert_eq!(ys[r], want[r].1, "{variant:?} R={r_count} server shares {r}");
                }
                assert_eq!(st.bytes_to_client, sum_to_client, "{variant:?} R={r_count}");
                assert_eq!(st.bytes_to_server, sum_to_server, "{variant:?} R={r_count}");
                assert_eq!(st.rounds, single_rounds, "{variant:?} R={r_count}: fused rounds");
            }
        }
    }

    #[test]
    fn scratch_reuse_across_layers_is_clean() {
        // One OnlineScratch across two different layers (different n):
        // no state may leak between calls.
        let mut rng = Rng::new(9);
        let variant = circa_variant(12);
        let mut scratch = OnlineScratch::default();
        for n in [7usize, 3] {
            let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng)).collect();
            let xs: Vec<Fp> = (0..n).map(|_| random_fp(&mut rng)).collect();
            let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
            let (want_c, want_s, _) = online_relu_layer(&cm, &sm, &xc, &xs);
            let (mut got_c, mut got_s, _) =
                online_relu_layer_multi(&[&cm], &[&sm], &[&xc], &[&xs], &mut scratch);
            assert_eq!(got_c.pop().unwrap(), want_c, "n={n}");
            assert_eq!(got_s.pop().unwrap(), want_s, "n={n}");
        }
    }
}

//! Online phase for one ReLU layer — the paper's headline cost.
//!
//! Message flow per layer (n ReLUs, batched into single messages):
//!
//! ```text
//! server → client : n·(m−k) input labels for ⟨x⟩_s        (16 B each)
//! client          : evaluates the layer's garbled batch    (the hot loop)
//! client → server : n·m output colors                      (1 bit each)
//! — Circa variants additionally —
//! both   ⇄ both   : Beaver openings (2 field elems each way per ReLU)
//! client → server : resharing delta (1 field elem per ReLU)
//! ```
//!
//! The baseline (Fig. 2a) skips the Beaver round entirely — its GC already
//! outputs the masked ReLU — but pays ~5× more AND gates per evaluation.
//!
//! Both hot loops are layer-batched: the server encodes its labels into
//! one flat arena, and the client walks the layer's shared circuit once
//! per ReLU over the contiguous table buffer
//! ([`crate::gc::batch::LayerGcBatch::eval_layer_colors`]).

use super::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::beaver;
use crate::circuits::spec::bits_fp;
use crate::field::Fp;
use crate::prf::Label;
use crate::util::Timer;

/// Measurements from one online ReLU layer execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineReluStats {
    /// Wall time of the whole online exchange (both parties' compute).
    pub wall_s: f64,
    /// Bytes server → client (labels).
    pub bytes_to_client: u64,
    /// Bytes client → server (colors, openings, deltas).
    pub bytes_to_server: u64,
    /// Communication rounds.
    pub rounds: u32,
}

impl OnlineReluStats {
    pub fn bytes_total(&self) -> u64 {
        self.bytes_to_client + self.bytes_to_server
    }
}

/// Encode the server's online shares into one flat label arena (stride =
/// server inputs per ReLU). Shared by the in-process path below and the
/// channel-driven [`super::server`].
pub fn encode_server_labels(mat: &ServerReluMaterial, xs: &[Fp]) -> Vec<Label> {
    let spec = mat.spec;
    let base = spec.server_input_base();
    let mut out = Vec::with_capacity(xs.len() * spec.n_server_inputs);
    for (i, &x) in xs.iter().enumerate() {
        let bits = spec.server_bits(x);
        let view = mat.encodings.view(i);
        out.extend(bits.iter().enumerate().map(|(j, &b)| view.encode(base + j, b)));
    }
    out
}

/// Decode the client's color stream into the server's output shares using
/// the layer's flat decode buffer.
pub fn decode_server_shares(mat: &ServerReluMaterial, colors: &[bool]) -> Vec<Fp> {
    let m = mat.spec.n_outputs;
    let n = mat.n();
    assert_eq!(colors.len(), n * m, "color stream arity");
    (0..n)
        .map(|i| {
            let bits: Vec<bool> = colors[i * m..(i + 1) * m]
                .iter()
                .zip(mat.decode_of(i))
                .map(|(&c, &d)| c ^ d)
                .collect();
            bits_fp(&bits)
        })
        .collect()
}

/// Run the online phase of one ReLU layer, in-process but with every
/// message byte-accounted as if on the wire.
///
/// Inputs: each party's shares of `x` (from the linear layer). Outputs:
/// each party's shares of `y = ReLU(x)` (stochastic under Circa), with
/// the client's share equal to its pre-chosen randomness (`r_out`),
/// ready for the next linear layer.
pub fn online_relu_layer(
    client: &ClientReluMaterial,
    server: &ServerReluMaterial,
    xc: &[Fp],
    xs: &[Fp],
) -> (Vec<Fp>, Vec<Fp>, OnlineReluStats) {
    let n = xc.len();
    assert_eq!(n, xs.len());
    assert_eq!(n, client.n(), "offline material arity");
    let spec = client.spec;
    let timer = Timer::new();
    let mut stats = OnlineReluStats::default();

    // --- Round 1: server encodes + sends its input labels (one arena). ---
    let server_labels = encode_server_labels(server, xs);
    stats.bytes_to_client += server_labels.len() as u64 * 16;
    stats.rounds += 1;

    // --- Client: batched evaluation — shared circuit template, outer
    // stride loop over the contiguous table buffer. ---
    let mut colors: Vec<bool> = Vec::with_capacity(n * spec.n_outputs);
    client.gc.eval_layer_colors(&client.client_labels, &server_labels, &mut colors);
    stats.bytes_to_server += (colors.len() as u64).div_ceil(8);
    stats.rounds += 1;

    // --- Server: decode its output share from the colors. ---
    let server_out = decode_server_shares(server, &colors);

    if !spec.uses_beaver() {
        // Baseline: GC output *is* the masked ReLU share.
        let client_out = client.r_out.clone();
        stats.wall_s = timer.elapsed_s();
        return (client_out, server_out, stats);
    }

    // --- Circa variants: y = x · v via one batched Beaver round. ---
    // Client share of v is r_v; server share came out of the GC.
    let mut open_c = Vec::with_capacity(2 * n);
    let mut open_s = Vec::with_capacity(2 * n);
    for i in 0..n {
        let oc = beaver::open(xc[i], client.r_v[i], &client.triples[i]);
        let os = beaver::open(xs[i], server_out[i], &server.triples[i]);
        open_c.push(oc.e);
        open_c.push(oc.f);
        open_s.push(os.e);
        open_s.push(os.f);
    }
    // Exchange openings (one round, both directions).
    stats.bytes_to_server += open_c.len() as u64 * 4;
    stats.bytes_to_client += open_s.len() as u64 * 4;
    stats.rounds += 1;

    let mut client_y = Vec::with_capacity(n);
    let mut server_y = Vec::with_capacity(n);
    for i in 0..n {
        let e = open_c[2 * i] + open_s[2 * i];
        let f = open_c[2 * i + 1] + open_s[2 * i + 1];
        client_y.push(beaver::mul_share(e, f, &client.triples[i], true));
        server_y.push(beaver::mul_share(e, f, &server.triples[i], false));
    }

    // --- Resharing: client share becomes its pre-chosen r_out. ---
    let deltas: Vec<Fp> =
        (0..n).map(|i| client_y[i] - client.r_out[i]).collect();
    stats.bytes_to_server += deltas.len() as u64 * 4;
    stats.rounds += 1;
    for i in 0..n {
        server_y[i] = server_y[i] + deltas[i];
    }

    stats.wall_s = timer.elapsed_s();
    (client.r_out.clone(), server_y, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::field::random_fp;
    use crate::protocol::offline::{circa_variant, offline_relu_layer};
    use crate::ss::{reconstruct_vec, SharePair};
    use crate::util::Rng;

    fn run_layer(variant: ReluVariant, xs_signed: &[i64], seed: u64) -> Vec<i64> {
        let mut rng = Rng::new(seed);
        let shares: Vec<SharePair> =
            xs_signed.iter().map(|&v| SharePair::share(Fp::from_i64(v), &mut rng)).collect();
        let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let xsrv: Vec<Fp> = shares.iter().map(|s| s.server).collect();
        let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
        let (yc, ys, stats) = online_relu_layer(&cm, &sm, &xc, &xsrv);
        assert!(stats.bytes_total() > 0);
        reconstruct_vec(&yc, &ys).iter().map(|y| y.to_i64()).collect()
    }

    #[test]
    fn baseline_is_exact_relu() {
        let vals = [-1_000_000i64, -321, -1, 0, 1, 7, 55_555, 1_000_000];
        let got = run_layer(ReluVariant::BaselineRelu, &vals, 1);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn naive_sign_is_exact_relu() {
        let vals = [-999_999i64, -5, -1, 0, 1, 2, 123_456];
        let got = run_layer(ReluVariant::NaiveSign, &vals, 2);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn stochastic_sign_correct_for_moderate_values() {
        // |x| ≪ p ⇒ fault probability ~0; must match exact ReLU.
        let vals = [-800_000i64, -1000, -1, 1, 1000, 800_000];
        let got = run_layer(ReluVariant::StochasticSign { mode: FaultMode::PosZero }, &vals, 3);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn truncated_sign_exact_above_2k() {
        let k = 12u32;
        let vals = [-(1i64 << 20), -(1 << 13), 1 << 13, 1 << 20];
        let got = run_layer(circa_variant(k), &vals, 4);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn truncated_poszero_zeroes_small_positives_probabilistically() {
        // x = 16 with k = 12: fault prob (2^12 − 16)/2^12 ≈ 0.996 ⇒ output
        // should be 0 almost always; run several instances.
        let k = 12u32;
        let vals = vec![16i64; 64];
        let got = run_layer(circa_variant(k), &vals, 5);
        let zeros = got.iter().filter(|&&v| v == 0).count();
        assert!(zeros >= 60, "only {zeros}/64 zeroed");
    }

    #[test]
    fn truncated_negpass_passes_small_negatives() {
        // x = −16, k = 12, NegPass: output ≈ x (passed through) with
        // prob ≈ 0.996 — i.e. y = x·1 = x, NOT zero.
        let k = 12u32;
        let variant = ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass };
        let vals = vec![-16i64; 64];
        let got = run_layer(variant, &vals, 6);
        let passed = got.iter().filter(|&&v| v == -16).count();
        assert!(passed >= 60, "only {passed}/64 passed through");
    }

    #[test]
    fn online_bytes_smaller_for_circa() {
        let mut rng = Rng::new(7);
        let vals: Vec<Fp> = (0..32).map(|_| random_fp(&mut rng)).collect();
        let shares: Vec<SharePair> = vals.iter().map(|&v| SharePair::share(v, &mut rng)).collect();
        let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();

        let (cm_b, sm_b) = offline_relu_layer(ReluVariant::BaselineRelu, &xc, &mut rng);
        let (_, _, st_b) = online_relu_layer(&cm_b, &sm_b, &xc, &xs);

        let (cm_t, sm_t) = offline_relu_layer(circa_variant(12), &xc, &mut rng);
        let (_, _, st_t) = online_relu_layer(&cm_t, &sm_t, &xc, &xs);

        // Labels dominate; Circa sends m−k=19 labels vs 31 + pays small
        // Beaver/resharing overhead. Net must still be smaller.
        assert!(
            st_t.bytes_total() < st_b.bytes_total(),
            "circa {} !< baseline {}",
            st_t.bytes_total(),
            st_b.bytes_total()
        );
    }

    #[test]
    fn client_output_share_is_prechosen_randomness() {
        // The resharing step must leave the client holding exactly r_out,
        // which the *next* layer's offline phase assumed.
        let mut rng = Rng::new(8);
        let x = Fp::from_i64(424_242);
        let sh = SharePair::share(x, &mut rng);
        let (cm, sm) = offline_relu_layer(circa_variant(12), &[sh.client], &mut rng);
        let (yc, ys, _) = online_relu_layer(&cm, &sm, &[sh.client], &[sh.server]);
        assert_eq!(yc[0], cm.r_out[0]);
        assert_eq!((yc[0] + ys[0]).to_i64(), 424_242);
    }
}

//! Beaver multiplication triples (§2.2) — the SS-side half of Circa's
//! refactored ReLU (`y = x · sign(x)` runs here, not in the GC).
//!
//! Offline a dealer samples `(a, b, ab)` and hands each party additive
//! shares. Online, to multiply shared `x` and `y`, the parties open
//! `e = x − a` and `f = y − b` (which leak nothing since `a, b` are
//! uniform) and each computes its share of
//! `xy = ef + e·b + f·a + ab`, with the public `ef` added by one side.

use crate::field::{random_fp, Fp};
use crate::ss::{Share, SharePair};
use crate::util::Rng;

/// One party's portion of a Beaver triple.
#[derive(Clone, Copy, Debug)]
pub struct TripleShare {
    pub a: Share,
    pub b: Share,
    pub ab: Share,
}

/// Dealer-generated triple: shares for both parties.
#[derive(Clone, Copy, Debug)]
pub struct Triple {
    pub p1: TripleShare,
    pub p2: TripleShare,
}

/// Generate one triple (trusted-dealer / offline phase).
pub fn gen_triple(rng: &mut Rng) -> Triple {
    let a = random_fp(rng);
    let b = random_fp(rng);
    let ab = a * b;
    let sa = SharePair::share(a, rng);
    let sb = SharePair::share(b, rng);
    let sab = SharePair::share(ab, rng);
    Triple {
        p1: TripleShare { a: sa.client, b: sb.client, ab: sab.client },
        p2: TripleShare { a: sa.server, b: sb.server, ab: sab.server },
    }
}

/// Generate a batch of triples.
pub fn gen_triples(n: usize, rng: &mut Rng) -> Vec<Triple> {
    (0..n).map(|_| gen_triple(rng)).collect()
}

/// The opening message each party broadcasts in the online phase.
#[derive(Clone, Copy, Debug)]
pub struct Opening {
    pub e: Fp, // share of x - a
    pub f: Fp, // share of y - b
}

/// Step 1 (each party): compute its opening shares from its input shares
/// and its triple share.
pub fn open(x: Share, y: Share, t: &TripleShare) -> Opening {
    Opening { e: x - t.a, f: y - t.b }
}

/// Step 2 (each party): given both openings (now public `e`, `f`), produce
/// this party's share of `x·y`. Exactly one party must set `add_ef`.
pub fn mul_share(e: Fp, f: Fp, t: &TripleShare, add_ef: bool) -> Share {
    let mut out = e * t.b + f * t.a + t.ab;
    if add_ef {
        out = out + e * f;
    }
    out
}

/// Convenience: run the whole 2-party multiply locally (used by the
/// simulator and tests; the protocol layer splits the steps across the
/// channel).
pub fn mul_pair(x: SharePair, y: SharePair, triple: &Triple) -> SharePair {
    let o1 = open(x.client, y.client, &triple.p1);
    let o2 = open(x.server, y.server, &triple.p2);
    let e = o1.e + o2.e;
    let f = o1.f + o2.f;
    SharePair {
        client: mul_share(e, f, &triple.p1, true),
        server: mul_share(e, f, &triple.p2, false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ss::SharePair;

    #[test]
    fn triple_consistency() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let t = gen_triple(&mut rng);
            let a = t.p1.a + t.p2.a;
            let b = t.p1.b + t.p2.b;
            let ab = t.p1.ab + t.p2.ab;
            assert_eq!(a * b, ab);
        }
    }

    #[test]
    fn multiply_correct() {
        let mut rng = Rng::new(2);
        for _ in 0..500 {
            let x = random_fp(&mut rng);
            let y = random_fp(&mut rng);
            let sx = SharePair::share(x, &mut rng);
            let sy = SharePair::share(y, &mut rng);
            let t = gen_triple(&mut rng);
            let out = mul_pair(sx, sy, &t);
            assert_eq!(out.reconstruct(), x * y);
        }
    }

    #[test]
    fn multiply_signed_semantics() {
        // ReLU refactoring multiplies x by a {0,1} sign bit in the field.
        let mut rng = Rng::new(3);
        for xv in [-1234i64, -1, 0, 1, 98765] {
            let x = Fp::from_i64(xv);
            let sign = if xv >= 0 { Fp::ONE } else { Fp::ZERO };
            let sx = SharePair::share(x, &mut rng);
            let ss_ = SharePair::share(sign, &mut rng);
            let t = gen_triple(&mut rng);
            let out = mul_pair(sx, ss_, &t).reconstruct();
            assert_eq!(out.to_i64(), xv.max(0));
        }
    }

    #[test]
    fn openings_leak_nothing_statistically() {
        // e = x - a with uniform a is uniform: check rough uniformity.
        let mut rng = Rng::new(4);
        let x = Fp::from_i64(42);
        let n = 4000;
        let mut low = 0;
        for _ in 0..n {
            let sx = SharePair::share(x, &mut rng);
            let sy = SharePair::share(x, &mut rng);
            let t = gen_triple(&mut rng);
            let o1 = open(sx.client, sy.client, &t.p1);
            let o2 = open(sx.server, sy.server, &t.p2);
            let e = (o1.e + o2.e).raw();
            if e < crate::field::PRIME / 2 {
                low += 1;
            }
        }
        let frac = low as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "opening biased: {frac}");
    }

    #[test]
    fn batch_generation() {
        let mut rng = Rng::new(5);
        let ts = gen_triples(64, &mut rng);
        assert_eq!(ts.len(), 64);
    }
}

//! Oblivious transfer for client input-label delivery.
//!
//! In Delphi/Circa the client's GC inputs are all known **offline**
//! (`⟨x⟩_c = W·r − s` comes out of the HE precomputation; `r`, `−r`,
//! `1−r` are client-chosen), so the label OTs run entirely in the offline
//! phase and never touch online latency.
//!
//! **Substitution (see DESIGN.md §5):** a real deployment would run
//! IKNP-style OT extension. Both parties live in this process, so we use a
//! *dealer-assisted* OT that is correct-by-construction and charges the
//! OT-extension asymptote — 2 label-sized ciphertexts per selection bit —
//! to the offline byte ledger. The online protocol is unaffected: every
//! byte and every hash on the request path is real.

pub mod iknp;

use crate::gc::garble::{EncodingView, InputEncoding};
use crate::prf::Label;

/// Bytes a 1-of-2 OT of one label costs under OT extension (two masked
/// labels on the wire).
pub const OT_BYTES_PER_BIT: usize = 32;

/// Result of a batch of OTs: the chooser's labels plus the bytes the
/// exchange would have cost on the wire.
#[derive(Debug, Clone)]
pub struct OtBatch {
    pub labels: Vec<Label>,
    pub bytes_on_wire: usize,
}

/// Dealer-assisted batch OT: for each selection bit `b_i` the chooser
/// receives `enc.encode(base + i, b_i)` and learns nothing about the
/// other label; the sender learns nothing about `b_i`.
///
/// `base` is the first input index of the chooser's contiguous input
/// block within the circuit's input layout.
pub fn ot_choose(enc: &InputEncoding, base: usize, bits: &[bool]) -> OtBatch {
    let mut labels = Vec::with_capacity(bits.len());
    let bytes_on_wire = ot_choose_into(enc.view(), base, bits, &mut labels);
    OtBatch { labels, bytes_on_wire }
}

/// Arena-friendly dealer OT: encode the chooser's labels for one ReLU's
/// [`EncodingView`] directly into a caller-owned flat label buffer (the
/// layer's client-label arena). Returns the wire bytes charged.
pub fn ot_choose_into(
    enc: EncodingView<'_>,
    base: usize,
    bits: &[bool],
    out: &mut Vec<Label>,
) -> usize {
    out.extend(bits.iter().enumerate().map(|(i, &b)| enc.encode(base + i, b)));
    bits.len() * OT_BYTES_PER_BIT
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::build::Builder;
    use crate::gc::garble::garble;
    use crate::util::Rng;

    #[test]
    fn chooser_gets_correct_labels() {
        let mut bld = Builder::new();
        let a = bld.input_bus(8);
        let b = bld.input_bus(8);
        let (s, _) = bld.add(&a, &b);
        bld.output_bus(&s);
        let c = bld.build();
        let mut rng = Rng::new(1);
        let (_, enc) = garble(&c, &mut rng);
        let bits = vec![true, false, true, true, false, false, true, false];
        let batch = ot_choose(&enc, 8, &bits); // choose the b-bus block
        for (i, &bit) in bits.iter().enumerate() {
            assert_eq!(batch.labels[i], enc.encode(8 + i, bit));
        }
        assert_eq!(batch.bytes_on_wire, 8 * OT_BYTES_PER_BIT);
    }

    #[test]
    fn labels_differ_between_choices() {
        let mut bld = Builder::new();
        let _ = bld.input();
        let a = bld.input();
        bld.output(a);
        let c = bld.build();
        let mut rng = Rng::new(2);
        let (_, enc) = garble(&c, &mut rng);
        let l0 = ot_choose(&enc, 1, &[false]).labels[0];
        let l1 = ot_choose(&enc, 1, &[true]).labels[0];
        assert_ne!(l0, l1);
    }
}

//! IKNP OT extension (Ishai–Kilian–Nissim–Petrank 2003, semi-honest).
//!
//! The dealer-assisted OT in [`super`] charges the OT-extension
//! asymptote without running it; this module is the real protocol, used
//! to validate that accounting and available as the label-delivery path
//! for deployments that want the full machinery. Only the κ = 128 *base*
//! OTs are dealer-seeded (exactly how production stacks bootstrap from a
//! base-OT primitive).
//!
//! Roles for GC input-label delivery: the *garbler* (server) is the OT
//! sender with message pairs `(label0_i, label1_i)`; the *client* is the
//! receiver with its input bits as choices.
//!
//! ```text
//! base OTs:  sender holds s ∈ {0,1}^κ and seed k_i^{s_i};
//!            receiver holds both seeds k_i^0, k_i^1.
//! receiver:  t_i = PRG(k_i^0), sends u_i = t_i ⊕ PRG(k_i^1) ⊕ r
//! sender:    q_i = PRG(k_i^{s_i}) ⊕ s_i·u_i        (columns)
//!            after transpose: q_j = t_j ⊕ r_j·s     (rows)
//!            sends y0_j = x0_j ⊕ H(j, q_j), y1_j = x1_j ⊕ H(j, q_j ⊕ s)
//! receiver:  x_{r_j} = y_{r_j} ⊕ H(j, t_j)
//! ```

use crate::prf::{GarbleHash, Label};
use crate::util::Rng;

/// Security parameter: number of base OTs / matrix width.
pub const KAPPA: usize = 128;

/// The κ base-OT seeds. `receiver_seeds[i] = (k_i^0, k_i^1)`;
/// `sender_seeds[i] = k_i^{s_i}` per the sender's random `s`.
pub struct BaseOts {
    pub s: u128,
    pub sender_seeds: [u128; KAPPA],
    pub receiver_seeds: [(u128, u128); KAPPA],
}

/// Dealer-seeded base OTs (bootstrap primitive; see module docs).
pub fn base_ots(rng: &mut Rng) -> BaseOts {
    let s = rng.next_u128();
    let mut sender_seeds = [0u128; KAPPA];
    let mut receiver_seeds = [(0u128, 0u128); KAPPA];
    for i in 0..KAPPA {
        let k0 = rng.next_u128();
        let k1 = rng.next_u128();
        receiver_seeds[i] = (k0, k1);
        sender_seeds[i] = if (s >> i) & 1 == 1 { k1 } else { k0 };
    }
    BaseOts { s, sender_seeds, receiver_seeds }
}

/// Expand a seed into `blocks` 128-bit PRG outputs (fixed-key AES in a
/// counter construction over the seed).
fn prg(seed: u128, blocks: usize) -> Vec<u128> {
    let h = GarbleHash::shared();
    (0..blocks).map(|c| h.hash(Label(seed), c as u64).0).collect()
}

/// Transpose a 128×128 bit matrix given as 128 u128 rows.
fn transpose128(m: &[u128; KAPPA]) -> [u128; KAPPA] {
    let mut out = [0u128; KAPPA];
    for (r, &row) in m.iter().enumerate() {
        let mut bits = row;
        while bits != 0 {
            let c = bits.trailing_zeros() as usize;
            out[c] |= 1u128 << r;
            bits &= bits - 1;
        }
    }
    out
}

/// Receiver step 1: derive the T matrix and the correction message `u`.
/// `choices` are the receiver's selection bits (length m). Returns
/// `(t_rows, u_columns)` where `t_rows[j]` is the row the receiver
/// hashes for output j, and `u_columns` crosses the wire (κ × ⌈m/128⌉
/// blocks — the protocol's main bandwidth).
pub fn receiver_extend(
    base: &BaseOts,
    choices: &[bool],
    _rng: &mut Rng,
) -> (Vec<u128>, Vec<Vec<u128>>) {
    let m = choices.len();
    let chunks = m.div_ceil(KAPPA);
    // Choice bits packed into 128-bit blocks.
    let mut r_blocks = vec![0u128; chunks];
    for (j, &c) in choices.iter().enumerate() {
        if c {
            r_blocks[j / KAPPA] |= 1u128 << (j % KAPPA);
        }
    }

    let mut t_rows = vec![0u128; chunks * KAPPA];
    let mut u_cols: Vec<Vec<u128>> = Vec::with_capacity(KAPPA);
    // Column i of T (length m bits) from PRG(k_i^0).
    let t_cols: Vec<Vec<u128>> =
        (0..KAPPA).map(|i| prg(base.receiver_seeds[i].0, chunks)).collect();
    for i in 0..KAPPA {
        let g1 = prg(base.receiver_seeds[i].1, chunks);
        let u: Vec<u128> =
            (0..chunks).map(|b| t_cols[i][b] ^ g1[b] ^ r_blocks[b]).collect();
        u_cols.push(u);
    }
    // Transpose per 128-row chunk to get t_rows.
    for b in 0..chunks {
        let mut block = [0u128; KAPPA];
        for (i, col) in t_cols.iter().enumerate() {
            block[i] = col[b];
        }
        // block[i] holds bits j (within chunk) of column i; transpose so
        // row j collects bit i of each column.
        let tr = transpose128(&block);
        t_rows[b * KAPPA..(b + 1) * KAPPA].copy_from_slice(&tr);
    }
    (t_rows, u_cols)
}

/// Sender step: derive Q rows and encrypt both messages per OT.
/// Returns the ciphertext pairs `(y0_j, y1_j)` sent to the receiver.
pub fn sender_extend(
    base: &BaseOts,
    u_cols: &[Vec<u128>],
    pairs: &[(Label, Label)],
) -> Vec<(Label, Label)> {
    let m = pairs.len();
    let chunks = m.div_ceil(KAPPA);
    let h = GarbleHash::shared();

    // Column i of Q.
    let q_cols: Vec<Vec<u128>> = (0..KAPPA)
        .map(|i| {
            let g = prg(base.sender_seeds[i], chunks);
            let si = (base.s >> i) & 1 == 1;
            (0..chunks).map(|b| if si { g[b] ^ u_cols[i][b] } else { g[b] }).collect()
        })
        .collect();

    // Transpose to rows, then encrypt.
    let mut out = Vec::with_capacity(m);
    for b in 0..chunks {
        let mut block = [0u128; KAPPA];
        for (i, col) in q_cols.iter().enumerate() {
            block[i] = col[b];
        }
        let rows = transpose128(&block);
        for j_in in 0..KAPPA {
            let j = b * KAPPA + j_in;
            if j >= m {
                break;
            }
            let q = rows[j_in];
            let y0 = pairs[j].0 .0 ^ h.hash(Label(q), (1 << 40) + j as u64).0;
            let y1 = pairs[j].1 .0 ^ h.hash(Label(q ^ base.s), (1 << 40) + j as u64).0;
            out.push((Label(y0), Label(y1)));
        }
    }
    out
}

/// Receiver step 2: decrypt the chosen message of each OT.
pub fn receiver_finish(
    t_rows: &[u128],
    choices: &[bool],
    cts: &[(Label, Label)],
) -> Vec<Label> {
    let h = GarbleHash::shared();
    choices
        .iter()
        .enumerate()
        .map(|(j, &c)| {
            let y = if c { cts[j].1 } else { cts[j].0 };
            Label(y.0 ^ h.hash(Label(t_rows[j]), (1 << 40) + j as u64).0)
        })
        .collect()
}

/// Wire bytes of one extension batch of `m` OTs: the U matrix plus both
/// ciphertexts per OT (matches [`super::OT_BYTES_PER_BIT`] asymptote as
/// m grows).
pub fn wire_bytes(m: usize) -> usize {
    let chunks = m.div_ceil(KAPPA);
    KAPPA * chunks * 16 + m * 32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(m: usize, seed: u64) -> (Vec<(Label, Label)>, Vec<bool>, Vec<Label>) {
        let mut rng = Rng::new(seed);
        let base = base_ots(&mut rng);
        let pairs: Vec<(Label, Label)> =
            (0..m).map(|_| (Label::random(&mut rng), Label::random(&mut rng))).collect();
        let choices: Vec<bool> = (0..m).map(|_| rng.bool()).collect();
        let (t_rows, u_cols) = receiver_extend(&base, &choices, &mut rng);
        let cts = sender_extend(&base, &u_cols, &pairs);
        let got = receiver_finish(&t_rows, &choices, &cts);
        (pairs, choices, got)
    }

    #[test]
    fn receiver_gets_chosen_messages() {
        for m in [1usize, 5, 128, 131, 500] {
            let (pairs, choices, got) = run(m, 42 + m as u64);
            for j in 0..m {
                let want = if choices[j] { pairs[j].1 } else { pairs[j].0 };
                assert_eq!(got[j], want, "m={m} j={j}");
            }
        }
    }

    #[test]
    fn receiver_cannot_decrypt_other_message() {
        // Decrypting the unchosen ciphertext with t must NOT yield the
        // other message (it is masked by H(q ⊕ s) ≠ H(t)).
        let (pairs, choices, _) = run(64, 7);
        let mut rng = Rng::new(7);
        let base = base_ots(&mut rng);
        let pairs2: Vec<(Label, Label)> =
            (0..64).map(|_| (Label::random(&mut rng), Label::random(&mut rng))).collect();
        let _ = (pairs, choices);
        let choices2: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        let (t_rows, u_cols) = receiver_extend(&base, &choices2, &mut rng);
        let cts = sender_extend(&base, &u_cols, &pairs2);
        let h = GarbleHash::shared();
        for j in 0..64 {
            let other = if choices2[j] { cts[j].0 } else { cts[j].1 };
            let guess = Label(other.0 ^ h.hash(Label(t_rows[j]), (1 << 40) + j as u64).0);
            let want_other = if choices2[j] { pairs2[j].0 } else { pairs2[j].1 };
            assert_ne!(guess, want_other, "j={j}: unchosen message leaked");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let mut m = [0u128; KAPPA];
        for r in m.iter_mut() {
            *r = rng.next_u128();
        }
        assert_eq!(transpose128(&transpose128(&m)), m);
    }

    #[test]
    fn wire_bytes_asymptote() {
        // Per-bit cost approaches 16 B (U) + 32 B (cts) = 48 B/OT; the
        // dealer model charges 32 B/OT — same order, documented.
        let per_bit = wire_bytes(100_000) as f64 / 100_000.0;
        assert!(per_bit < 50.0, "{per_bit}");
    }

    #[test]
    fn integrates_with_garbled_inputs() {
        // Deliver GC input labels via IKNP and evaluate the circuit.
        use crate::gc::build::{bits_to_u64, u64_to_bits, Builder};
        use crate::gc::{evaluate, garble};
        let mut rng = Rng::new(9);
        let mut bld = Builder::new();
        let a = bld.input_bus(8);
        let b = bld.input_bus(8);
        let (sum, _) = bld.add(&a, &b);
        bld.output_bus(&sum);
        let c = bld.build();
        let (gc, enc) = garble(&c, &mut rng);

        let mut inputs = u64_to_bits(77, 8);
        inputs.extend(u64_to_bits(88, 8));
        let pairs: Vec<(Label, Label)> =
            (0..16).map(|i| (enc.encode(i, false), enc.encode(i, true))).collect();
        let base = base_ots(&mut rng);
        let (t_rows, u_cols) = receiver_extend(&base, &inputs, &mut rng);
        let cts = sender_extend(&base, &u_cols, &pairs);
        let labels = receiver_finish(&t_rows, &inputs, &cts);

        let out = gc.decode(&evaluate(&c, &gc, &labels));
        assert_eq!(bits_to_u64(&out), 77 + 88);
    }
}

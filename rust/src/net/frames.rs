//! Incremental frame decoding for nonblocking reads.
//!
//! The blocking [`crate::wire::frame::Framed`] pulls exactly one frame
//! per `recv()` because it can park the thread on `recv_exact`. A
//! reactor cannot: a nonblocking read delivers whatever bytes the
//! kernel has — half a header, three frames and a tail, anything — so
//! each connection owns a [`FrameBuf`] that accumulates bytes and pops
//! complete frames as they materialize. The wire format is byte-for-
//! byte the dealer-link framing (`MSG_TYPE | LEN (4 B le) | payload |
//! CRC32 (4 B le)`, CRC over header + payload), so a blocking
//! [`Framed`] peer interoperates with a reactor endpoint unchanged.
//!
//! Everything buffered is untrusted client input: unknown message
//! types, LEN fields over the connection's cap, and CRC mismatches all
//! surface as `Err` — after which the stream offset is unreliable and
//! the caller must drop the connection (there is no resync marker in
//! the format).

use crate::util::bytes::le_u32;
use crate::util::error::{Context, Result};
use crate::wire::frame::{crc32, Frame, MsgType, FRAME_CRC_BYTES, FRAME_HEADER_BYTES};
use crate::{bail, ensure};

/// Per-connection accumulation buffer turning a nonblocking byte stream
/// into whole frames.
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted away once frames are popped).
    pos: usize,
    /// Per-connection payload cap — client-facing listeners set this far
    /// below [`crate::wire::frame::MAX_FRAME_LEN`] so one connection
    /// cannot balloon reactor memory.
    max_len: usize,
}

impl FrameBuf {
    /// A fresh buffer enforcing `max_len` as the payload-size cap.
    pub fn new(max_len: usize) -> Self {
        Self { buf: Vec::new(), pos: 0, max_len }
    }

    /// Append freshly read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pop one complete frame if the buffer holds it: `Ok(None)` means
    /// "need more bytes", `Err` means the stream is corrupt and the
    /// connection must be dropped.
    pub fn try_frame(&mut self) -> Result<Option<Frame>> {
        if self.buffered() < FRAME_HEADER_BYTES {
            self.compact();
            return Ok(None);
        }
        let start = self.pos;
        let header =
            self.buf.get(start..start + FRAME_HEADER_BYTES).context("frame header range")?;
        let (type_byte, len_bytes) = header.split_at(1);
        let msg_type = MsgType::from_u8(type_byte.first().copied().context("empty header")?)?;
        let len = le_u32(len_bytes) as usize;
        if len > self.max_len {
            bail!("oversized frame LEN {len} (connection cap {})", self.max_len);
        }
        let total = FRAME_HEADER_BYTES + len + FRAME_CRC_BYTES;
        if self.buffered() < total {
            self.compact();
            return Ok(None);
        }
        let crc_off = start + FRAME_HEADER_BYTES + len;
        // CRC covers header + payload, exactly like the blocking path.
        let want = crc32(self.buf.get(start..crc_off).context("frame body range")?);
        let got = le_u32(self.buf.get(crc_off..crc_off + 4).context("frame CRC range")?);
        ensure!(got == want, "frame CRC mismatch ({msg_type:?}, {len} B payload)");
        let payload = self
            .buf
            .get(start + FRAME_HEADER_BYTES..crc_off)
            .context("frame payload range")?
            .to_vec();
        self.pos += total;
        self.compact();
        Ok(Some(Frame { msg_type, payload }))
    }

    /// Drop the consumed prefix once it is either the whole buffer or
    /// big enough that the memmove beats carrying dead bytes around.
    fn compact(&mut self) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::frame::encode_frame;

    #[test]
    fn single_frame_in_dribbled_bytes() {
        let raw = encode_frame(MsgType::Infer, b"hello-payload").unwrap();
        let mut fb = FrameBuf::new(1 << 16);
        for chunk in raw.chunks(3) {
            fb.extend(chunk);
        }
        // Until the final chunk arrived, intermediate polls were None.
        let f = fb.try_frame().unwrap().expect("complete frame");
        assert_eq!(f.msg_type, MsgType::Infer);
        assert_eq!(f.payload, b"hello-payload");
        assert!(fb.try_frame().unwrap().is_none());
        assert_eq!(fb.buffered(), 0);
    }

    #[test]
    fn partial_header_and_partial_payload_return_none() {
        let raw = encode_frame(MsgType::Logits, &[7u8; 64]).unwrap();
        let mut fb = FrameBuf::new(1 << 16);
        fb.extend(&raw[..3]);
        assert!(fb.try_frame().unwrap().is_none());
        fb.extend(&raw[3..raw.len() - 1]);
        assert!(fb.try_frame().unwrap().is_none());
        fb.extend(&raw[raw.len() - 1..]);
        assert!(fb.try_frame().unwrap().is_some());
    }

    #[test]
    fn multiple_frames_in_one_read() {
        let mut bytes = encode_frame(MsgType::ClientHello, b"a").unwrap();
        bytes.extend(encode_frame(MsgType::Infer, b"bb").unwrap());
        bytes.extend(encode_frame(MsgType::Bye, b"").unwrap());
        let mut fb = FrameBuf::new(1 << 16);
        fb.extend(&bytes);
        assert_eq!(fb.try_frame().unwrap().unwrap().msg_type, MsgType::ClientHello);
        assert_eq!(fb.try_frame().unwrap().unwrap().msg_type, MsgType::Infer);
        assert_eq!(fb.try_frame().unwrap().unwrap().msg_type, MsgType::Bye);
        assert!(fb.try_frame().unwrap().is_none());
    }

    #[test]
    fn crc_flip_is_rejected() {
        let mut raw = encode_frame(MsgType::Infer, b"payload").unwrap();
        let n = raw.len();
        raw[n - 1] ^= 0xFF;
        let mut fb = FrameBuf::new(1 << 16);
        fb.extend(&raw);
        let err = fb.try_frame().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn payload_flip_is_rejected() {
        let mut raw = encode_frame(MsgType::Infer, b"payload").unwrap();
        raw[FRAME_HEADER_BYTES] ^= 0x01;
        let mut fb = FrameBuf::new(1 << 16);
        fb.extend(&raw);
        assert!(fb.try_frame().is_err());
    }

    #[test]
    fn unknown_type_and_oversized_len_are_rejected() {
        let mut fb = FrameBuf::new(1 << 16);
        fb.extend(&[0xEE, 0, 0, 0, 0]);
        assert!(fb.try_frame().unwrap_err().to_string().contains("unknown message type"));

        let mut fb = FrameBuf::new(64);
        let mut raw = vec![MsgType::Infer as u8];
        raw.extend_from_slice(&1000u32.to_le_bytes());
        fb.extend(&raw);
        assert!(fb.try_frame().unwrap_err().to_string().contains("oversized"));
    }

    #[test]
    fn compaction_keeps_buffer_bounded() {
        let raw = encode_frame(MsgType::Infer, &[3u8; 1024]).unwrap();
        let mut fb = FrameBuf::new(1 << 16);
        for _ in 0..64 {
            fb.extend(&raw);
            assert!(fb.try_frame().unwrap().is_some());
            // Fully drained after every frame ⇒ the compaction path
            // resets instead of growing the dead prefix forever.
            assert_eq!(fb.buffered(), 0);
            assert!(fb.buf.is_empty());
        }
    }
}

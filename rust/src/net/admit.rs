//! Bank-depth admission control: turn the pool's material gauges into
//! an explicit queue-or-shed decision.
//!
//! Circa's serving economics invert the usual picture: the online phase
//! is cheap, so the scarce resource is **pre-dealt offline material**
//! (one session per inference). When a model's banks run dry, serving
//! it anyway means a dry inline deal on the worker — tail latency
//! quietly explodes. The admission controller samples each model's
//! assemblable-session depth ([`MaterialPool::banked_model`]) and the
//! ingress queue gauge ([`Metrics::ingress_depth`]) and decides, per
//! request, *before* queueing:
//!
//! * **Admit** while the model's bank is above the low watermark and
//!   the ingress queue is under its limit — the request queues with
//!   bounded depth.
//! * **Shed** with an explicit [`Decision::Shed`] (the reactor answers
//!   a `Busy` frame carrying a retry-after hint) when the model's bank
//!   has drained to the low watermark or the queue is over limit.
//!   Hysteresis: once shedding, a model readmits only when its bank
//!   recovers to the high watermark, so the controller doesn't flap on
//!   the lease/refill race at the boundary.
//!
//! Bank depths are sampled at most once per `sample_interval` per model
//! (the depth read takes the pool's shard lock; the reactor asks on
//! every request), and the whole decision path is nonblocking — the
//! reactor thread never waits on dealing.
//!
//! `low_watermark` semantics: shed while `depth < low_watermark`, so
//! `0` disables bank-depth shedding entirely (depth is never negative)
//! and the default `1` sheds exactly when the bank is empty.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::MaterialPool;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Watermarks and limits for [`AdmissionController`].
#[derive(Clone, Copy, Debug)]
pub struct AdmitConfig {
    /// Shed a model's requests while its assemblable-session depth is
    /// **below** this (0 disables bank-depth shedding; the default 1
    /// sheds exactly the dry bank).
    pub low_watermark: usize,
    /// Once shedding, readmit only at or above this depth (≥
    /// `low_watermark`; the gap is the hysteresis band).
    pub high_watermark: usize,
    /// Shed any request while the ingress queue gauge is at or over
    /// this. Keep it at or under the service's `max_queue` so shedding
    /// engages before `try_send` starts failing.
    pub max_queue: usize,
    /// Retry hint carried on `Busy` frames, milliseconds.
    pub retry_after_ms: u32,
    /// Bank-depth sampling throttle (per model).
    pub sample_interval: Duration,
}

impl Default for AdmitConfig {
    fn default() -> Self {
        Self {
            low_watermark: 1,
            high_watermark: 2,
            max_queue: 1024,
            retry_after_ms: 50,
            sample_interval: Duration::from_millis(2),
        }
    }
}

/// The verdict for one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Queue it (bounded by the service's `max_queue`).
    Admit,
    /// Refuse it with an explicit `Busy` carrying this hint.
    Shed { retry_after_ms: u32, reason: &'static str },
}

#[derive(Default)]
struct ModelAdmit {
    last_sample: Option<Instant>,
    depth: usize,
    /// Hysteresis latch: true between "fell below low" and "recovered
    /// to high".
    shedding: bool,
}

/// Per-model admission state + counters. One instance per reactor;
/// internally locked so stats readers on other threads stay safe.
pub struct AdmissionController {
    cfg: AdmitConfig,
    state: Mutex<BTreeMap<u64, ModelAdmit>>,
    admits: AtomicU64,
    sheds: AtomicU64,
}

impl AdmissionController {
    pub fn new(cfg: AdmitConfig) -> Self {
        let cfg = AdmitConfig { high_watermark: cfg.high_watermark.max(cfg.low_watermark), ..cfg };
        Self {
            cfg,
            state: Mutex::new(BTreeMap::new()),
            admits: AtomicU64::new(0),
            sheds: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &AdmitConfig {
        &self.cfg
    }

    /// Requests admitted so far.
    pub fn admits(&self) -> u64 {
        self.admits.load(Ordering::Relaxed)
    }

    /// Requests shed so far (queue-limit and bank-dry combined).
    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    /// Decide one request for `model`, sampling the pool's bank depth
    /// (throttled) and the metrics queue gauge. Nonblocking apart from
    /// two short uncontended locks.
    pub fn decide(&self, model: u64, pool: &MaterialPool, metrics: &Metrics) -> Decision {
        if metrics.ingress_depth.load(Ordering::Relaxed) >= self.cfg.max_queue as u64 {
            self.sheds.fetch_add(1, Ordering::Relaxed);
            return Decision::Shed {
                retry_after_ms: self.cfg.retry_after_ms,
                reason: "ingress queue over limit",
            };
        }
        if self.cfg.low_watermark > 0 {
            let mut state = self.state.lock().unwrap();
            let m = state.entry(model).or_default();
            let stale = match m.last_sample {
                None => true,
                Some(t) => t.elapsed() >= self.cfg.sample_interval,
            };
            if stale {
                m.depth = pool.banked_model(model);
                m.last_sample = Some(Instant::now());
            }
            if m.shedding {
                if m.depth >= self.cfg.high_watermark {
                    m.shedding = false;
                }
            } else if m.depth < self.cfg.low_watermark {
                m.shedding = true;
            }
            if m.shedding {
                self.sheds.fetch_add(1, Ordering::Relaxed);
                return Decision::Shed {
                    retry_after_ms: self.cfg.retry_after_ms,
                    reason: "model material bank dry",
                };
            }
        }
        self.admits.fetch_add(1, Ordering::Relaxed);
        Decision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::protocol::server::NetworkPlan;
    use crate::util::Rng;
    use std::sync::Arc;

    fn pool_with_bank(target: usize) -> (Arc<MaterialPool>, u64) {
        let mut rng = Rng::new(3);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
        let pool = Arc::new(MaterialPool::start(plan, target, 1, 7));
        let fp = pool.registry().entries()[0].fingerprint();
        (pool, fp)
    }

    fn zero_interval() -> AdmitConfig {
        // Sample every decision: the tests drain the bank and expect the
        // controller to see it immediately.
        AdmitConfig { sample_interval: Duration::from_secs(0), ..Default::default() }
    }

    #[test]
    fn admits_with_banked_material_then_sheds_dry() {
        let (pool, fp) = pool_with_bank(4);
        pool.wait_ready(4);
        // Freeze refill so the drain below is permanent.
        pool.stop();
        let ctl = AdmissionController::new(zero_interval());
        let metrics = Metrics::default();
        assert_eq!(ctl.decide(fp, &pool, &metrics), Decision::Admit);

        let mut rng = Rng::new(11);
        while pool.banked_model(fp) > 0 {
            let lease = pool.lease_model(fp, &mut rng);
            assert!(!lease.was_dry);
        }
        match ctl.decide(fp, &pool, &metrics) {
            Decision::Shed { reason, retry_after_ms } => {
                assert!(reason.contains("dry"), "{reason}");
                assert!(retry_after_ms > 0);
            }
            d => panic!("dry bank admitted: {d:?}"),
        }
        assert_eq!(ctl.admits(), 1);
        assert_eq!(ctl.sheds(), 1);
    }

    #[test]
    fn hysteresis_blocks_flapping_at_the_boundary() {
        let (pool, fp) = pool_with_bank(1);
        pool.wait_ready(1);
        pool.stop();
        let ctl = AdmissionController::new(AdmitConfig {
            low_watermark: 1,
            high_watermark: 3,
            ..zero_interval()
        });
        let metrics = Metrics::default();
        assert_eq!(ctl.decide(fp, &pool, &metrics), Decision::Admit);
        let mut rng = Rng::new(13);
        let _ = pool.lease_model(fp, &mut rng); // depth 1 → 0
        assert!(matches!(ctl.decide(fp, &pool, &metrics), Decision::Shed { .. }));
        // Depth 0 < high_watermark 3: still shedding even though a
        // depth-1 recovery would have been above the low watermark.
        assert!(matches!(ctl.decide(fp, &pool, &metrics), Decision::Shed { .. }));
    }

    #[test]
    fn queue_over_limit_sheds_regardless_of_banks() {
        let (pool, fp) = pool_with_bank(4);
        pool.wait_ready(4);
        let ctl =
            AdmissionController::new(AdmitConfig { max_queue: 2, ..zero_interval() });
        let metrics = Metrics::default();
        metrics.ingress_depth.store(2, Ordering::Relaxed);
        match ctl.decide(fp, &pool, &metrics) {
            Decision::Shed { reason, .. } => assert!(reason.contains("queue"), "{reason}"),
            d => panic!("over-limit queue admitted: {d:?}"),
        }
        metrics.ingress_depth.store(0, Ordering::Relaxed);
        assert_eq!(ctl.decide(fp, &pool, &metrics), Decision::Admit);
        pool.stop();
    }

    #[test]
    fn zero_low_watermark_disables_bank_shedding() {
        let (pool, fp) = pool_with_bank(1);
        pool.wait_ready(1);
        pool.stop();
        let mut rng = Rng::new(17);
        let _ = pool.lease_model(fp, &mut rng);
        assert_eq!(pool.banked_model(fp), 0);
        let ctl = AdmissionController::new(AdmitConfig {
            low_watermark: 0,
            ..zero_interval()
        });
        let metrics = Metrics::default();
        assert_eq!(ctl.decide(fp, &pool, &metrics), Decision::Admit, "dry but not shedding");
    }
}

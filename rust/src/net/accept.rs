//! Shared nonblocking-accept idiom: a polled listener plus the
//! stop-nudge that makes `stop()` prompt even on unspecified binds.
//!
//! Both TCP accept loops in the crate — the dealer's thread-per-
//! connection loop ([`crate::wire::dealer::spawn_tcp_dealer_multi`])
//! and the serving reactor ([`super::reactor`]) — need the same three
//! things: a listener that never blocks the owning thread, a
//! `WouldBlock`-is-not-an-error accept, and a way for `stop()` to wake
//! a loop that might otherwise sleep through its poll interval. This
//! module is that idiom, written once.

use crate::util::error::{Context, Result};
use std::net::{Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// A nonblocking `TcpListener` with poll-style accept semantics.
pub struct PollingListener {
    listener: TcpListener,
    local: SocketAddr,
}

impl PollingListener {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and switch the listener to
    /// nonblocking mode.
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local addr")?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        Ok(Self { listener, local })
    }

    /// The bound address (useful with a `:0` ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Accept one pending connection, or `Ok(None)` when none is queued
    /// (`WouldBlock`). The accepted stream inherits nothing: callers
    /// decide blocking vs nonblocking per connection.
    pub fn accept(&self) -> Result<Option<(TcpStream, SocketAddr)>> {
        match self.listener.accept() {
            Ok(pair) => Ok(Some(pair)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e).context("accept"),
        }
    }
}

/// Poke a listener's accept queue so a poll loop parked in its sleep
/// re-checks its stop flag promptly. The nudge targets loopback
/// explicitly when the bind address is unspecified: `0.0.0.0` (or `::`)
/// is not a connectable destination on every platform, and a failed
/// nudge against a *blocking* accept historically left `stop()` joined
/// forever. Best-effort: the connect result is discarded because the
/// polled loops observe the stop flag within one interval regardless.
pub fn stop_nudge(addr: SocketAddr) {
    let nudge = if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => SocketAddr::from((Ipv4Addr::LOCALHOST, addr.port())),
            SocketAddr::V6(_) => SocketAddr::from((Ipv6Addr::LOCALHOST, addr.port())),
        }
    } else {
        addr
    };
    let _ = TcpStream::connect_timeout(&nudge, Duration::from_millis(200));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_is_nonblocking_and_delivers_connections() {
        let l = PollingListener::bind("127.0.0.1:0").unwrap();
        // Nothing queued: Ok(None), immediately.
        assert!(l.accept().unwrap().is_none());
        let addr = l.local_addr();
        let _client = TcpStream::connect(addr).unwrap();
        // The connection lands within a bounded number of polls.
        let mut got = None;
        for _ in 0..200 {
            if let Some(pair) = l.accept().unwrap() {
                got = Some(pair);
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(got.is_some(), "queued connection never surfaced");
    }

    #[test]
    fn stop_nudge_reaches_unspecified_bind() {
        let l = PollingListener::bind("0.0.0.0:0").unwrap();
        stop_nudge(l.local_addr());
        let mut got = false;
        for _ in 0..200 {
            if l.accept().unwrap().is_some() {
                got = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(got, "nudge connection never reached the unspecified bind");
    }
}

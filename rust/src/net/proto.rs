//! The versioned client protocol of the serving tier.
//!
//! Frames ride the dealer-link framing ([`crate::wire::frame`], message
//! types `ClientHello`/`Infer`/`Logits`/`Busy` plus the shared
//! `Error`/`Bye`); this module defines the payloads. A session:
//!
//! ```text
//! client → server : ClientHello   (magic | version)
//! server → client : ClientHello   (magic | version | model ads)
//!
//! client → server : Infer         (req_id | model fp | input)
//! server → client : Logits        (req_id | model | logits | stats)
//!            — or : Busy          (req_id | retry-after hint | reason)
//!            — or : Error         (req_id | message)
//! ...               (requests pipeline freely; responses may reorder,
//!                    which is what the client-chosen req_id is for)
//! client → server : Bye
//! ```
//!
//! The handshake advertises every registered model as a [`ModelAd`]
//! (fingerprint + I/O dims), so a load generator can build inputs
//! without out-of-band plan knowledge. `Busy` is the admission
//! controller's explicit backpressure ([`super::admit`]): the request
//! was not queued, the connection survives, and the client should retry
//! after the hint. An `Error` with [`CONN_FATAL`] as its req_id is
//! connection-level (handshake rejection, corrupt framing) and the
//! server closes after sending it.
//!
//! All decodes treat the payload as untrusted input: wrong magic,
//! version skew, out-of-range field elements, oversized vectors, and
//! trailing bytes are `Err`, never panics — same contract as
//! [`crate::wire::codec`].

use crate::ensure;
use crate::field::{Fp, PRIME};
use crate::util::bytes::{le_u32, Reader, Writer};
use crate::util::error::{Context, Result};

/// Protocol magic (`b"CIRP"`, little-endian) — distinct from the dealer
/// codec's `b"CIRW"` so a client dialed at a dealer port (or vice versa)
/// fails loudly at the handshake.
pub const PROTO_MAGIC: u32 = u32::from_le_bytes(*b"CIRP");

/// Client protocol version. Bump on any payload layout change.
pub const PROTO_VERSION: u16 = 1;

/// Upper bound on input/logit vector length — far above any served
/// plan, far below an allocation attack.
pub const MAX_VEC_ELEMS: usize = 1 << 20;

/// Upper bound on advertised models in the server hello.
pub const MAX_MODEL_ADS: usize = 4096;

/// `req_id` sentinel on an [`ProtoError`] that concerns the connection
/// rather than one request; the server closes after sending it.
pub const CONN_FATAL: u64 = u64::MAX;

/// One advertised model in the server hello: enough for a client to
/// address it and to size inputs without out-of-band plan knowledge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelAd {
    pub fingerprint: u64,
    pub in_dim: u32,
    pub out_dim: u32,
}

/// Server side of the handshake: the registered model set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServerHello {
    pub models: Vec<ModelAd>,
}

/// One inference request. `req_id` is client-chosen and echoed verbatim
/// on the response, so requests can pipeline on one connection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Infer {
    pub req_id: u64,
    pub model: u64,
    pub input: Vec<Fp>,
}

/// Serving stats carried on every [`Logits`] frame (mirrors
/// [`crate::coordinator::router::Response`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InferStats {
    pub queue_us: u64,
    pub online_us: u64,
    pub bytes: u64,
    pub served_from_bank: bool,
}

/// One inference result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Logits {
    pub req_id: u64,
    pub model: u64,
    pub logits: Vec<Fp>,
    pub stats: InferStats,
}

/// Explicit admission-control shed: retry after the hint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Busy {
    pub req_id: u64,
    pub retry_after_ms: u32,
    pub reason: String,
}

/// Per-request or connection-fatal error (see [`CONN_FATAL`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoError {
    pub req_id: u64,
    pub message: String,
}

fn put_fp_vec(w: &mut Writer, v: &[Fp]) {
    w.u64(v.len() as u64);
    w.buf.reserve(v.len() * 4);
    for &x in v {
        w.u32(x.raw() as u32);
    }
}

fn get_fp_vec(r: &mut Reader) -> Result<Vec<Fp>> {
    let n = r.len_u64()?;
    ensure!(n <= MAX_VEC_ELEMS, "field vector of {n} elements exceeds cap {MAX_VEC_ELEMS}");
    let raw = r.take(n.checked_mul(4).context("fp vec length overflows")?)?;
    raw.chunks_exact(4)
        .map(|c| {
            let v = le_u32(c) as u64;
            ensure!(v < PRIME, "field element {v} out of range");
            Ok(Fp::new(v))
        })
        .collect()
}

fn check_version(r: &mut Reader, what: &str) -> Result<()> {
    let magic = r.u32()?;
    ensure!(magic == PROTO_MAGIC, "{what}: bad protocol magic {magic:#010x}");
    let version = r.u16()?;
    ensure!(
        version == PROTO_VERSION,
        "{what}: protocol version {version} (this side speaks {PROTO_VERSION})"
    );
    Ok(())
}

fn check_drained(r: &Reader, what: &str) -> Result<()> {
    ensure!(r.remaining() == 0, "{what}: {} trailing bytes", r.remaining());
    Ok(())
}

/// Client → server hello payload (a version probe).
pub fn encode_client_hello() -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(PROTO_MAGIC);
    w.u16(PROTO_VERSION);
    w.buf
}

/// Validate a client hello (magic + version only).
pub fn decode_client_hello(payload: &[u8]) -> Result<()> {
    let mut r = Reader::new(payload);
    check_version(&mut r, "client hello")?;
    check_drained(&r, "client hello")
}

/// Server → client hello payload: version + model advertisements.
/// Fallible since the advertisement count field is `u32` (lint rule R5:
/// length fields are checked, never truncated with `as`).
pub fn encode_server_hello(hello: &ServerHello) -> Result<Vec<u8>> {
    let mut w = Writer::new();
    w.u32(PROTO_MAGIC);
    w.u16(PROTO_VERSION);
    w.u32(u32::try_from(hello.models.len()).context("model ad count overflows u32")?);
    for ad in &hello.models {
        w.u64(ad.fingerprint);
        w.u32(ad.in_dim);
        w.u32(ad.out_dim);
    }
    Ok(w.buf)
}

pub fn decode_server_hello(payload: &[u8]) -> Result<ServerHello> {
    let mut r = Reader::new(payload);
    check_version(&mut r, "server hello")?;
    let n = r.u32()? as usize;
    ensure!(n <= MAX_MODEL_ADS, "server hello advertises {n} models (cap {MAX_MODEL_ADS})");
    let mut models = Vec::with_capacity(n);
    for _ in 0..n {
        models.push(ModelAd { fingerprint: r.u64()?, in_dim: r.u32()?, out_dim: r.u32()? });
    }
    check_drained(&r, "server hello")?;
    Ok(ServerHello { models })
}

pub fn encode_infer(msg: &Infer) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(msg.req_id);
    w.u64(msg.model);
    put_fp_vec(&mut w, &msg.input);
    w.buf
}

pub fn decode_infer(payload: &[u8]) -> Result<Infer> {
    let mut r = Reader::new(payload);
    let req_id = r.u64()?;
    let model = r.u64()?;
    let input = get_fp_vec(&mut r)?;
    check_drained(&r, "infer")?;
    Ok(Infer { req_id, model, input })
}

pub fn encode_logits(msg: &Logits) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(msg.req_id);
    w.u64(msg.model);
    put_fp_vec(&mut w, &msg.logits);
    w.u64(msg.stats.queue_us);
    w.u64(msg.stats.online_us);
    w.u64(msg.stats.bytes);
    w.u8(msg.stats.served_from_bank as u8);
    w.buf
}

pub fn decode_logits(payload: &[u8]) -> Result<Logits> {
    let mut r = Reader::new(payload);
    let req_id = r.u64()?;
    let model = r.u64()?;
    let logits = get_fp_vec(&mut r)?;
    let queue_us = r.u64()?;
    let online_us = r.u64()?;
    let bytes = r.u64()?;
    let from_bank = r.u8()?;
    ensure!(from_bank <= 1, "served_from_bank flag {from_bank} is not a bool");
    check_drained(&r, "logits")?;
    Ok(Logits {
        req_id,
        model,
        logits,
        stats: InferStats {
            queue_us,
            online_us,
            bytes,
            served_from_bank: from_bank == 1,
        },
    })
}

pub fn encode_busy(msg: &Busy) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(msg.req_id);
    w.u32(msg.retry_after_ms);
    w.string(&msg.reason);
    w.buf
}

pub fn decode_busy(payload: &[u8]) -> Result<Busy> {
    let mut r = Reader::new(payload);
    let req_id = r.u64()?;
    let retry_after_ms = r.u32()?;
    let reason = r.string()?;
    check_drained(&r, "busy")?;
    Ok(Busy { req_id, retry_after_ms, reason })
}

pub fn encode_error(msg: &ProtoError) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(msg.req_id);
    w.string(&msg.message);
    w.buf
}

pub fn decode_error(payload: &[u8]) -> Result<ProtoError> {
    let mut r = Reader::new(payload);
    let req_id = r.u64()?;
    let message = r.string()?;
    check_drained(&r, "error")?;
    Ok(ProtoError { req_id, message })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip_and_version_gate() {
        decode_client_hello(&encode_client_hello()).unwrap();

        let hello = ServerHello {
            models: vec![
                ModelAd { fingerprint: 0xABCD, in_dim: 784, out_dim: 10 },
                ModelAd { fingerprint: 0x1234, in_dim: 6, out_dim: 3 },
            ],
        };
        assert_eq!(decode_server_hello(&encode_server_hello(&hello).unwrap()).unwrap(), hello);

        // Wrong magic / version skew / trailing bytes all reject.
        let mut bad = encode_client_hello();
        bad[0] ^= 0xFF;
        assert!(decode_client_hello(&bad).unwrap_err().to_string().contains("magic"));
        let mut skew = encode_client_hello();
        skew[4] = PROTO_VERSION as u8 + 1;
        assert!(decode_client_hello(&skew).unwrap_err().to_string().contains("version"));
        let mut trailing = encode_server_hello(&hello).unwrap();
        trailing.push(0);
        assert!(decode_server_hello(&trailing).is_err());
    }

    #[test]
    fn infer_roundtrip_and_range_check() {
        let msg = Infer {
            req_id: 42,
            model: 0xFEED,
            input: (0..17).map(Fp::from_i64).collect(),
        };
        assert_eq!(decode_infer(&encode_infer(&msg)).unwrap(), msg);

        // An out-of-range raw element must be rejected, not wrapped.
        let mut w = Writer::new();
        w.u64(1);
        w.u64(2);
        w.u64(1); // one element
        w.u32(u32::MAX); // >= PRIME
        assert!(decode_infer(&w.buf).unwrap_err().to_string().contains("out of range"));
    }

    #[test]
    fn infer_vector_cap_is_enforced() {
        let mut w = Writer::new();
        w.u64(1);
        w.u64(2);
        w.u64((MAX_VEC_ELEMS + 1) as u64);
        assert!(decode_infer(&w.buf).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn logits_busy_error_roundtrip() {
        let msg = Logits {
            req_id: 7,
            model: 9,
            logits: vec![Fp::from_i64(-5), Fp::from_i64(123456)],
            stats: InferStats {
                queue_us: 10,
                online_us: 2000,
                bytes: 4096,
                served_from_bank: true,
            },
        };
        assert_eq!(decode_logits(&encode_logits(&msg)).unwrap(), msg);

        let busy = Busy { req_id: 8, retry_after_ms: 50, reason: "banks dry".into() };
        assert_eq!(decode_busy(&encode_busy(&busy)).unwrap(), busy);

        let err = ProtoError { req_id: CONN_FATAL, message: "handshake first".into() };
        assert_eq!(decode_error(&encode_error(&err)).unwrap(), err);
    }

    #[test]
    fn truncated_payloads_err_not_panic() {
        let full = encode_logits(&Logits {
            req_id: 1,
            model: 2,
            logits: vec![Fp::from_i64(3)],
            stats: InferStats {
                queue_us: 0,
                online_us: 1,
                bytes: 2,
                served_from_bank: false,
            },
        });
        for cut in 0..full.len() {
            assert!(decode_logits(&full[..cut]).is_err(), "cut at {cut}");
        }
    }
}

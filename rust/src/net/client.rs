//! Blocking client for the serving tier — the counterpart of
//! [`super::reactor`] used by the `pi_client` load generator and the
//! two-process tests.
//!
//! The client reuses the blocking transport the dealer link already
//! trusts ([`crate::wire::frame::TcpChannel`] under a
//! [`crate::wire::frame::Framed`]): the nonblocking machinery lives
//! server-side, where one thread multiplexes every connection; a client
//! has exactly one connection and blocking reads are the simple,
//! correct tool.
//!
//! Requests pipeline: [`PiClient::send_infer`] fires without waiting and
//! [`PiClient::recv_outcome`] collects results in server-completion
//! order, matching them back by the echoed `req_id`. A shed request
//! surfaces as [`Outcome::Busy`] — an expected signal under overload,
//! not an `Err` — while protocol-level failures (unknown model, stopped
//! service, corrupt frames) are real errors.

use super::proto::{self, Busy, Logits, ModelAd};
use crate::bail;
use crate::field::Fp;
use crate::util::error::{Context, Result};
use crate::wire::frame::{Framed, MsgType, TcpChannel};

/// The server's answer to one inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Served: logits plus serving stats.
    Logits(Logits),
    /// Shed by admission control: retry after the hint.
    Busy(Busy),
}

/// One connected, handshaken client session.
pub struct PiClient {
    link: Framed,
    models: Vec<ModelAd>,
    next_id: u64,
}

impl PiClient {
    /// Connect, complete the version handshake, and learn the served
    /// model set.
    pub fn connect(addr: &str) -> Result<Self> {
        let chan = TcpChannel::connect(addr).with_context(|| format!("pi client {addr}"))?;
        let mut link = Framed::new(Box::new(chan));
        link.send(MsgType::ClientHello, &proto::encode_client_hello())?;
        let frame = link.recv()?;
        match frame.msg_type {
            MsgType::ClientHello => {
                let hello = proto::decode_server_hello(&frame.payload)?;
                Ok(Self { link, models: hello.models, next_id: 0 })
            }
            MsgType::Busy => {
                let busy = proto::decode_busy(&frame.payload)?;
                bail!("server busy at connect: {} (retry {} ms)", busy.reason, busy.retry_after_ms)
            }
            MsgType::Error => {
                let err = proto::decode_error(&frame.payload)?;
                bail!("server rejected handshake: {}", err.message)
            }
            other => bail!("unexpected {other:?} frame in handshake"),
        }
    }

    /// Models the server advertised in its hello.
    pub fn models(&self) -> &[ModelAd] {
        &self.models
    }

    /// Fire one request without waiting (pipelining); returns the
    /// client-chosen `req_id` echoed on the eventual response.
    pub fn send_infer(&mut self, model: u64, input: &[Fp]) -> Result<u64> {
        let req_id = self.next_id;
        self.next_id += 1;
        let payload =
            proto::encode_infer(&proto::Infer { req_id, model, input: input.to_vec() });
        self.link.send(MsgType::Infer, &payload)?;
        Ok(req_id)
    }

    /// Block for the next response frame (server-completion order, not
    /// send order — match by [`Logits::req_id`]/[`Busy::req_id`]).
    pub fn recv_outcome(&mut self) -> Result<Outcome> {
        let frame = self.link.recv()?;
        match frame.msg_type {
            MsgType::Logits => Ok(Outcome::Logits(proto::decode_logits(&frame.payload)?)),
            MsgType::Busy => Ok(Outcome::Busy(proto::decode_busy(&frame.payload)?)),
            MsgType::Error => {
                let err = proto::decode_error(&frame.payload)?;
                bail!("server error (req {}): {}", err.req_id, err.message)
            }
            other => bail!("unexpected {other:?} frame awaiting a response"),
        }
    }

    /// Send one request and wait for its answer (depth-1 convenience).
    pub fn infer(&mut self, model: u64, input: &[Fp]) -> Result<Outcome> {
        self.send_infer(model, input)?;
        self.recv_outcome()
    }

    /// Orderly goodbye. Best-effort: the server also tolerates a plain
    /// disconnect.
    pub fn bye(mut self) -> Result<()> {
        self.link.send(MsgType::Bye, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::coordinator::service::{PiService, ServiceConfig};
    use crate::net::reactor::{Reactor, ReactorConfig};
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::protocol::server::NetworkPlan;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn pipelined_requests_roundtrip_by_req_id() {
        let mut rng = Rng::new(2);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
        let svc = Arc::new(PiService::start(plan, ServiceConfig {
            workers: 2,
            pool_target: 8,
            pool_dealers: 1,
            ..Default::default()
        }));
        svc.warmup(4);
        let reactor =
            Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default()).unwrap();

        let mut client = PiClient::connect(&reactor.local_addr().to_string()).unwrap();
        let ad = client.models()[0];
        let inputs: Vec<Vec<Fp>> = (0..4u64)
            .map(|r| (0..ad.in_dim as i64).map(|i| Fp::from_i64(100 * r as i64 + i)).collect())
            .collect();
        let want: Vec<Vec<Fp>> =
            inputs.iter().map(|inp| svc.infer(inp.clone()).unwrap().logits).collect();

        // Fire all four before reading anything, then match replies by id.
        let ids: Vec<u64> =
            inputs.iter().map(|inp| client.send_infer(ad.fingerprint, inp).unwrap()).collect();
        let mut got = vec![None; inputs.len()];
        for _ in 0..inputs.len() {
            match client.recv_outcome().unwrap() {
                Outcome::Logits(l) => {
                    let slot = ids.iter().position(|&id| id == l.req_id).unwrap();
                    got[slot] = Some(l.logits);
                }
                Outcome::Busy(b) => panic!("warm bank shed a request: {}", b.reason),
            }
        }
        for (slot, logits) in got.into_iter().enumerate() {
            assert_eq!(logits.unwrap(), want[slot], "request {slot}");
        }
        client.bye().unwrap();
        reactor.shutdown();
        match Arc::try_unwrap(svc) {
            Ok(svc) => svc.shutdown(),
            Err(_) => panic!("reactor kept a service reference after shutdown"),
        }
    }
}

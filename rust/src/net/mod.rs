//! The client-facing serving tier: nonblocking reactor, framed client
//! protocol, and bank-depth admission control — std-only, zero
//! dependencies.
//!
//! [`crate::coordinator`] turns Circa's offline/online split into an
//! in-process service; this module puts that service on a socket. The
//! design follows the serving profile of private inference: the online
//! phase is cheap and the scarce resource is pre-dealt offline material,
//! so the network edge must (a) multiplex many mostly-idle client
//! connections without a thread apiece, and (b) refuse work *early and
//! explicitly* when a model's material bank runs dry, instead of letting
//! dry inline deals destroy tail latency.
//!
//! * [`accept`] — the shared nonblocking listener
//!   ([`accept::PollingListener`]) and the loopback
//!   [`accept::stop_nudge`] that wakes an accept poll for shutdown.
//!   Used by both the reactor and the dealer's accept loop
//!   ([`crate::wire::dealer`]).
//! * [`frames`] — [`frames::FrameBuf`], the incremental re-assembler of
//!   the dealer-link frame format (`MSG_TYPE | LEN | payload | CRC32`,
//!   [`crate::wire::frame`]) across arbitrary TCP segmentation.
//! * [`proto`] — the versioned client protocol payloads: hello
//!   handshake with model advertisements, pipelined
//!   `Infer`/`Logits`, and explicit `Busy`/`Error`. All decodes treat
//!   input as untrusted (`Err`, never panic).
//! * [`admit`] — [`admit::AdmissionController`]: samples per-model bank
//!   depths and the ingress-queue gauge against low/high watermarks and
//!   answers queue-or-shed per request, with hysteresis so the decision
//!   doesn't flap at the refill boundary.
//! * [`reactor`] — [`reactor::Reactor`]: one thread owning the
//!   listener, every connection state machine (partial-frame reads,
//!   backpressure-bounded buffered writes, idle timeouts, connection
//!   cap), the admission gate, and the nonblocking completion poll over
//!   [`crate::coordinator::service::ResponseHandle`]s.
//! * [`client`] — [`client::PiClient`], the blocking client used by the
//!   `pi_client` load generator and the two-process tests.
//!
//! The untrusted-input guarantees in [`proto`] and [`frames`]
//! (no panics, no truncating length casts, tag namespaces unique and
//! decode-covered) and the reactor's no-blocking-under-lock rule are
//! enforced by the repo lint (`cargo run -p circa-lint -- check`,
//! blocking in CI) — see `docs/INVARIANTS.md`.

pub mod accept;
pub mod admit;
pub mod client;
pub mod frames;
pub mod proto;
pub mod reactor;

pub use accept::PollingListener;
pub use admit::{AdmissionController, AdmitConfig, Decision};
pub use client::{Outcome, PiClient};
pub use frames::FrameBuf;
pub use reactor::{NetStats, Reactor, ReactorConfig};

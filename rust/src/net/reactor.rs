//! The serving tier's readiness loop: one thread, many connections,
//! zero blocking.
//!
//! The reactor owns a nonblocking [`PollingListener`] plus a per-
//! connection state machine and multiplexes every client over a single
//! thread:
//!
//! * **Accept** — new connections are admitted up to
//!   [`ReactorConfig::max_connections`]; past the cap the reactor sends
//!   a best-effort `Busy` frame and closes immediately (an explicit
//!   signal beats a silent SYN backlog).
//! * **Read** — bytes drain into a per-connection [`FrameBuf`], which
//!   re-assembles the dealer-link frame format across arbitrary TCP
//!   segmentation. A corrupt frame (bad CRC, unknown type, oversized
//!   LEN) kills only that connection; the reactor and its other clients
//!   are unaffected.
//! * **State machine** — a connection must complete the
//!   `ClientHello`/server-hello version handshake before its first
//!   `Infer`; afterwards requests pipeline freely and responses may
//!   reorder (the client's `req_id` is echoed on every reply).
//! * **Admission** — each `Infer` consults the
//!   [`AdmissionController`] *before* queueing: a dry model bank or an
//!   over-limit ingress queue is an immediate `Busy` frame, and the
//!   bounded-queue `try_send` backstop ([`SubmitError::QueueFull`])
//!   maps to `Busy` as well. The reactor thread never blocks on
//!   dealing or queue space.
//! * **Completion** — admitted requests park as
//!   [`ResponseHandle`]s; the loop polls `try_recv` and turns each
//!   arrival into a `Logits` frame on the owning connection.
//! * **Write** — responses queue into a per-connection write buffer
//!   flushed as the socket accepts bytes; a client that stops reading
//!   past [`ReactorConfig::max_write_buf`] is disconnected rather than
//!   ballooning server memory.
//! * **Idle** — connections with no traffic and no in-flight requests
//!   for [`ReactorConfig::idle_timeout`] are reaped.
//!
//! Shutdown mirrors the dealer listener: a stop flag plus a loopback
//! [`stop_nudge`] so the accept poll wakes immediately.

use super::accept::{stop_nudge, PollingListener};
use super::admit::{AdmissionController, AdmitConfig, Decision};
use super::frames::FrameBuf;
use super::proto::{
    self, Busy, InferStats, Logits, ModelAd, ProtoError, ServerHello, CONN_FATAL,
};
use crate::coordinator::service::{PiService, ResponseHandle, SubmitError};
use crate::protocol::linear::LinearOp;
use crate::util::error::Result;
use crate::wire::frame::{encode_frame, MsgType};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Reactor tuning knobs.
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Hard cap on concurrently open client connections; over-cap
    /// accepts get a `Busy` frame and an immediate close.
    pub max_connections: usize,
    /// Per-connection bound on a single frame's payload LEN (tighter
    /// than the wire-format maximum: client frames are requests, not
    /// layer batches).
    pub max_frame_len: usize,
    /// Per-connection bound on buffered unsent response bytes; a client
    /// that stops reading past this is disconnected.
    pub max_write_buf: usize,
    /// Reap connections idle (no traffic, nothing in flight) this long.
    pub idle_timeout: Duration,
    /// Sleep when a full pass over accept/read/poll/write moved no
    /// bytes.
    pub poll_interval: Duration,
    /// Admission-control watermarks ([`super::admit`]).
    pub admit: AdmitConfig,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        Self {
            max_connections: 1024,
            max_frame_len: 1 << 24,
            max_write_buf: 1 << 23,
            idle_timeout: Duration::from_secs(30),
            poll_interval: Duration::from_micros(500),
            admit: AdmitConfig::default(),
        }
    }
}

/// Reactor counters, updated live from the loop thread.
#[derive(Default)]
pub struct NetStats {
    /// Connections accepted into the loop.
    pub accepted: AtomicU64,
    /// Connections refused at the `max_connections` cap.
    pub rejected_over_cap: AtomicU64,
    /// Currently open connections (gauge).
    pub open: AtomicU64,
    /// Valid frames received / frames queued for send.
    pub frames_rx: AtomicU64,
    pub frames_tx: AtomicU64,
    /// Requests answered `Busy` (admission shed + queue-full backstop).
    pub sheds: AtomicU64,
    /// Corrupt frames or protocol violations (each also closes its
    /// connection).
    pub proto_errors: AtomicU64,
    /// Connections closed for any reason.
    pub closed: AtomicU64,
    /// Subset of `closed` reaped by the idle timeout.
    pub idle_closed: AtomicU64,
}

/// Handle to a running reactor thread.
pub struct Reactor {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    pub stats: Arc<NetStats>,
}

impl Reactor {
    /// Bind `addr` and start the loop thread serving `svc`. Bind errors
    /// surface here; everything after is reported per connection.
    pub fn spawn(addr: &str, svc: Arc<PiService>, cfg: ReactorConfig) -> Result<Self> {
        let listener = PollingListener::bind(addr)?;
        let local = listener.local_addr();
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        // The handshake reply is identical for every client: build it
        // once from the registered model set.
        let ads: Vec<ModelAd> = svc
            .pool
            .registry()
            .entries()
            .iter()
            .map(|e| ModelAd {
                fingerprint: e.fingerprint(),
                in_dim: e.plan.linears[0].in_dim() as u32,
                out_dim: e.plan.linears.last().expect("non-empty plan").out_dim() as u32,
            })
            .collect();
        let hello_reply = proto::encode_server_hello(&ServerHello { models: ads })?;
        let thread = {
            let stats = stats.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                run_loop(listener, svc, cfg, hello_reply, stats, stop);
            })
        };
        Ok(Self { addr: local, stop, thread: Some(thread), stats })
    }

    /// The bound address (with the OS-assigned port when spawned on
    /// port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the loop and join the thread. Open connections are dropped
    /// (clients observe EOF); the service itself is left running.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        stop_nudge(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

enum Phase {
    AwaitHello,
    Ready,
}

struct Pending {
    req_id: u64,
    model: u64,
    handle: ResponseHandle,
}

struct Conn {
    stream: TcpStream,
    inbuf: FrameBuf,
    /// Unsent response bytes; `wpos` is the flush cursor.
    out: Vec<u8>,
    wpos: usize,
    phase: Phase,
    pending: Vec<Pending>,
    last_activity: Instant,
    /// Flush what's buffered, then close (Bye, fatal protocol error).
    closing: bool,
    /// Remove this connection at the end of the pass.
    dead: bool,
}

/// Append one encoded frame to a connection's write buffer.
fn queue_frame(out: &mut Vec<u8>, stats: &NetStats, msg_type: MsgType, payload: &[u8]) {
    match encode_frame(msg_type, payload) {
        Ok(buf) => {
            out.extend_from_slice(&buf);
            stats.frames_tx.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => { /* oversized response payload: drop the frame */ }
    }
}

fn queue_error(out: &mut Vec<u8>, stats: &NetStats, req_id: u64, message: String) {
    let payload = proto::encode_error(&ProtoError { req_id, message });
    queue_frame(out, stats, MsgType::Error, &payload);
}

fn queue_busy(out: &mut Vec<u8>, stats: &NetStats, req_id: u64, retry_after_ms: u32, reason: &str) {
    let payload =
        proto::encode_busy(&Busy { req_id, retry_after_ms, reason: reason.to_string() });
    queue_frame(out, stats, MsgType::Busy, &payload);
    stats.sheds.fetch_add(1, Ordering::Relaxed);
}

fn run_loop(
    listener: PollingListener,
    svc: Arc<PiService>,
    cfg: ReactorConfig,
    hello_reply: Vec<u8>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) {
    let admit = AdmissionController::new(cfg.admit);
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; 16 * 1024];

    while !stop.load(Ordering::Relaxed) {
        let mut moved = false;

        // -- Accept --------------------------------------------------
        loop {
            match listener.accept() {
                Ok(Some((stream, _peer))) => {
                    moved = true;
                    if conns.len() >= cfg.max_connections {
                        stats.rejected_over_cap.fetch_add(1, Ordering::Relaxed);
                        reject_over_cap(stream, &stats, cfg.admit.retry_after_ms);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    stats.accepted.fetch_add(1, Ordering::Relaxed);
                    conns.push(Conn {
                        stream,
                        inbuf: FrameBuf::new(cfg.max_frame_len),
                        out: Vec::new(),
                        wpos: 0,
                        phase: Phase::AwaitHello,
                        pending: Vec::new(),
                        last_activity: Instant::now(),
                        closing: false,
                        dead: false,
                    });
                }
                Ok(None) | Err(_) => break,
            }
        }

        // -- Per-connection read / decode / dispatch ------------------
        for conn in conns.iter_mut() {
            if conn.dead {
                continue;
            }
            if read_into(conn, &mut scratch, &stats) {
                moved = true;
            }
            if drain_frames(conn, &svc, &admit, &cfg, &hello_reply, &stats) {
                moved = true;
            }
            if poll_pending(conn, &stats) {
                moved = true;
            }
            if flush(conn, &cfg, &stats) {
                moved = true;
            }
            if !conn.dead
                && !conn.closing
                && conn.pending.is_empty()
                && conn.last_activity.elapsed() >= cfg.idle_timeout
            {
                conn.dead = true;
                stats.idle_closed.fetch_add(1, Ordering::Relaxed);
            }
        }

        // -- Reap ----------------------------------------------------
        let before = conns.len();
        conns.retain(|c| !c.dead);
        let reaped = (before - conns.len()) as u64;
        if reaped > 0 {
            stats.closed.fetch_add(reaped, Ordering::Relaxed);
            moved = true;
        }
        stats.open.store(conns.len() as u64, Ordering::Relaxed);

        if !moved {
            std::thread::sleep(cfg.poll_interval);
        }
    }
    stats.closed.fetch_add(conns.len() as u64, Ordering::Relaxed);
    stats.open.store(0, Ordering::Relaxed);
}

/// Best-effort `Busy` to a connection refused at the cap; never blocks
/// the loop (the socket is switched to nonblocking first, and a full
/// kernel buffer just drops the courtesy frame).
fn reject_over_cap(stream: TcpStream, stats: &NetStats, retry_after_ms: u32) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    let mut stream = stream;
    let payload = proto::encode_busy(&Busy {
        req_id: CONN_FATAL,
        retry_after_ms,
        reason: "server at connection capacity".to_string(),
    });
    if let Ok(buf) = encode_frame(MsgType::Busy, &payload) {
        let _ = stream.write(&buf);
        stats.frames_tx.fetch_add(1, Ordering::Relaxed);
    }
}

/// Drain readable bytes into the connection's frame buffer. Returns
/// true if any bytes arrived; EOF and hard errors mark the connection
/// dead.
fn read_into(conn: &mut Conn, scratch: &mut [u8], _stats: &NetStats) -> bool {
    let mut any = false;
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.inbuf.extend(&scratch[..n]);
                conn.last_activity = Instant::now();
                any = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    any
}

/// Pop and handle every complete frame buffered on the connection.
fn drain_frames(
    conn: &mut Conn,
    svc: &Arc<PiService>,
    admit: &AdmissionController,
    cfg: &ReactorConfig,
    hello_reply: &[u8],
    stats: &NetStats,
) -> bool {
    let mut any = false;
    while !conn.dead && !conn.closing {
        let frame = match conn.inbuf.try_frame() {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                // Unrecoverable: framing is lost (CRC/type/LEN). Tell
                // the client why, flush, close. Only this connection
                // dies.
                stats.proto_errors.fetch_add(1, Ordering::Relaxed);
                queue_error(&mut conn.out, stats, CONN_FATAL, e.to_string());
                conn.closing = true;
                break;
            }
        };
        any = true;
        stats.frames_rx.fetch_add(1, Ordering::Relaxed);
        handle_frame(conn, frame.msg_type, &frame.payload, svc, admit, cfg, hello_reply, stats);
    }
    any
}

#[allow(clippy::too_many_arguments)]
fn handle_frame(
    conn: &mut Conn,
    msg_type: MsgType,
    payload: &[u8],
    svc: &Arc<PiService>,
    admit: &AdmissionController,
    cfg: &ReactorConfig,
    hello_reply: &[u8],
    stats: &NetStats,
) {
    match msg_type {
        MsgType::ClientHello => match proto::decode_client_hello(payload) {
            Ok(()) => {
                queue_frame(&mut conn.out, stats, MsgType::ClientHello, hello_reply);
                conn.phase = Phase::Ready;
            }
            Err(e) => {
                stats.proto_errors.fetch_add(1, Ordering::Relaxed);
                queue_error(&mut conn.out, stats, CONN_FATAL, e.to_string());
                conn.closing = true;
            }
        },
        MsgType::Bye => conn.closing = true,
        MsgType::Infer => {
            if matches!(conn.phase, Phase::AwaitHello) {
                stats.proto_errors.fetch_add(1, Ordering::Relaxed);
                queue_error(
                    &mut conn.out,
                    stats,
                    CONN_FATAL,
                    "handshake required before Infer".to_string(),
                );
                conn.closing = true;
                return;
            }
            let infer = match proto::decode_infer(payload) {
                Ok(m) => m,
                Err(e) => {
                    stats.proto_errors.fetch_add(1, Ordering::Relaxed);
                    queue_error(&mut conn.out, stats, CONN_FATAL, e.to_string());
                    conn.closing = true;
                    return;
                }
            };
            // Unknown fingerprints answer per-request (a client bug, not
            // a transport fault) and must not reach the admission
            // controller's per-model state.
            if svc.pool.registry().get(infer.model).is_none() {
                queue_error(
                    &mut conn.out,
                    stats,
                    infer.req_id,
                    SubmitError::UnknownModel(infer.model).to_string(),
                );
                return;
            }
            if let Decision::Shed { retry_after_ms, reason } =
                admit.decide(infer.model, &svc.pool, &svc.metrics)
            {
                svc.metrics.record_shed(infer.model);
                queue_busy(&mut conn.out, stats, infer.req_id, retry_after_ms, reason);
                return;
            }
            match svc.submit_to(infer.model, infer.input) {
                Ok(handle) => {
                    conn.pending.push(Pending { req_id: infer.req_id, model: infer.model, handle });
                }
                Err(SubmitError::QueueFull { .. }) => {
                    // The bounded channel beat the gauge to the punch:
                    // same client-visible contract as an admission shed.
                    svc.metrics.record_shed(infer.model);
                    queue_busy(
                        &mut conn.out,
                        stats,
                        infer.req_id,
                        cfg.admit.retry_after_ms,
                        "ingress queue full",
                    );
                }
                Err(e @ SubmitError::Stopped) => {
                    queue_error(&mut conn.out, stats, CONN_FATAL, e.to_string());
                    conn.closing = true;
                }
                Err(e @ SubmitError::UnknownModel(_)) => {
                    queue_error(&mut conn.out, stats, infer.req_id, e.to_string());
                }
            }
        }
        other => {
            stats.proto_errors.fetch_add(1, Ordering::Relaxed);
            queue_error(
                &mut conn.out,
                stats,
                CONN_FATAL,
                format!("unexpected {other:?} frame on a client connection"),
            );
            conn.closing = true;
        }
    }
}

/// Poll every in-flight inference on the connection; completed ones
/// become `Logits` frames (or an `Error` if the service died mid-
/// flight).
fn poll_pending(conn: &mut Conn, stats: &NetStats) -> bool {
    if conn.pending.is_empty() {
        return false;
    }
    let mut any = false;
    let pending = std::mem::take(&mut conn.pending);
    for p in pending {
        match p.handle.try_recv() {
            Ok(None) => conn.pending.push(p),
            Ok(Some(resp)) => {
                any = true;
                conn.last_activity = Instant::now();
                let payload = proto::encode_logits(&Logits {
                    req_id: p.req_id,
                    model: p.model,
                    logits: resp.logits,
                    stats: InferStats {
                        queue_us: resp.queue_us,
                        online_us: resp.online_us,
                        bytes: resp.bytes,
                        served_from_bank: resp.served_from_bank,
                    },
                });
                queue_frame(&mut conn.out, stats, MsgType::Logits, &payload);
            }
            Err(e) => {
                any = true;
                queue_error(&mut conn.out, stats, p.req_id, e.to_string());
            }
        }
    }
    any
}

/// Write as much buffered output as the socket accepts. Enforces the
/// backpressure cap and finishes a deferred close once drained.
fn flush(conn: &mut Conn, cfg: &ReactorConfig, stats: &NetStats) -> bool {
    let mut any = false;
    while conn.wpos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.wpos += n;
                conn.last_activity = Instant::now();
                any = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.wpos >= conn.out.len() {
        conn.out.clear();
        conn.wpos = 0;
        if conn.closing {
            conn.dead = true;
        }
    } else if conn.out.len() - conn.wpos > cfg.max_write_buf {
        // The client stopped reading; cut it loose instead of buffering
        // without bound.
        stats.proto_errors.fetch_add(1, Ordering::Relaxed);
        conn.dead = true;
    }
    any
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::coordinator::service::ServiceConfig;
    use crate::field::Fp;
    use crate::protocol::linear::Matrix;
    use crate::protocol::server::NetworkPlan;
    use crate::util::Rng;
    use crate::wire::frame::{Framed, TcpChannel};

    fn tiny_service() -> Arc<PiService> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
        Arc::new(PiService::start(plan, ServiceConfig {
            workers: 2,
            pool_target: 4,
            pool_dealers: 1,
            ..Default::default()
        }))
    }

    fn connect(addr: SocketAddr) -> Framed {
        Framed::new(Box::new(TcpChannel::connect(&addr.to_string()).unwrap()))
    }

    #[test]
    fn hello_infer_logits_roundtrip() {
        let svc = tiny_service();
        svc.warmup(2);
        let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default())
            .unwrap();
        let mut link = connect(reactor.local_addr());

        link.send(MsgType::ClientHello, &proto::encode_client_hello()).unwrap();
        let frame = link.recv().unwrap();
        assert_eq!(frame.msg_type, MsgType::ClientHello);
        let hello = proto::decode_server_hello(&frame.payload).unwrap();
        assert_eq!(hello.models.len(), 1);
        let ad = hello.models[0];
        assert_eq!((ad.in_dim, ad.out_dim), (6, 3));

        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(200 + i)).collect();
        let want = svc.infer(input.clone()).unwrap().logits;
        link.send(
            MsgType::Infer,
            &proto::encode_infer(&proto::Infer {
                req_id: 77,
                model: ad.fingerprint,
                input,
            }),
        )
        .unwrap();
        let frame = link.recv().unwrap();
        assert_eq!(frame.msg_type, MsgType::Logits);
        let logits = proto::decode_logits(&frame.payload).unwrap();
        assert_eq!(logits.req_id, 77);
        assert_eq!(logits.logits, want, "network path bit-identical to in-process");
        assert!(logits.stats.online_us > 0);

        link.send(MsgType::Bye, &[]).unwrap();
        reactor.shutdown();
        match Arc::try_unwrap(svc) {
            Ok(svc) => svc.shutdown(),
            Err(_) => panic!("reactor kept a service reference after shutdown"),
        }
    }

    #[test]
    fn infer_before_hello_is_rejected() {
        let svc = tiny_service();
        let model = svc.models()[0];
        let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default())
            .unwrap();
        let mut link = connect(reactor.local_addr());
        link.send(
            MsgType::Infer,
            &proto::encode_infer(&proto::Infer { req_id: 1, model, input: Vec::new() }),
        )
        .unwrap();
        let frame = link.recv().unwrap();
        assert_eq!(frame.msg_type, MsgType::Error);
        let err = proto::decode_error(&frame.payload).unwrap();
        assert_eq!(err.req_id, CONN_FATAL);
        assert!(err.message.contains("handshake"), "{}", err.message);
        // The server closes after a connection-fatal error.
        assert!(link.recv().is_err());
        assert_eq!(reactor.stats.proto_errors.load(Ordering::Relaxed), 1);
        reactor.shutdown();
        match Arc::try_unwrap(svc) {
            Ok(svc) => svc.shutdown(),
            Err(_) => panic!("reactor kept a service reference after shutdown"),
        }
    }

    #[test]
    fn unknown_model_errors_per_request_and_connection_survives() {
        let svc = tiny_service();
        svc.warmup(2);
        let model = svc.models()[0];
        let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default())
            .unwrap();
        let mut link = connect(reactor.local_addr());
        link.send(MsgType::ClientHello, &proto::encode_client_hello()).unwrap();
        let _ = link.recv().unwrap();

        link.send(
            MsgType::Infer,
            &proto::encode_infer(&proto::Infer {
                req_id: 5,
                model: model ^ 0xDEAD,
                input: Vec::new(),
            }),
        )
        .unwrap();
        let frame = link.recv().unwrap();
        assert_eq!(frame.msg_type, MsgType::Error);
        let err = proto::decode_error(&frame.payload).unwrap();
        assert_eq!(err.req_id, 5, "per-request error, not connection-fatal");

        // Same connection still serves real requests.
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(300 + i)).collect();
        link.send(
            MsgType::Infer,
            &proto::encode_infer(&proto::Infer { req_id: 6, model, input }),
        )
        .unwrap();
        let frame = link.recv().unwrap();
        assert_eq!(frame.msg_type, MsgType::Logits);
        assert_eq!(proto::decode_logits(&frame.payload).unwrap().req_id, 6);

        reactor.shutdown();
        match Arc::try_unwrap(svc) {
            Ok(svc) => svc.shutdown(),
            Err(_) => panic!("reactor kept a service reference after shutdown"),
        }
    }
}

//! The paper's published numbers (Tables 1–3, Fig. 5), kept verbatim so
//! every bench prints paper-vs-measured side by side.

use crate::nn::graph::NetworkSpec;
use crate::nn::{deepreduce, resnet, vgg};

/// One row of Table 1 (baseline networks).
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: &'static str,
    pub relus_k: f64,
    pub baseline_acc: f64,
    pub negpass_acc: f64,
    pub negpass_bits: u32,
    pub poszero_acc: f64,
    pub poszero_bits: u32,
    pub baseline_runtime_s: f64,
    pub circa_runtime_s: f64,
    pub speedup: f64,
    /// Builder for the architecture spec (exact ReLU counts + MACs).
    pub spec: fn() -> NetworkSpec,
}

#[rustfmt::skip]
pub fn table1() -> Vec<Table1Row> {
    vec![
        Table1Row { name: "ResNet32-C10", relus_k: 303.1, baseline_acc: 92.43, negpass_acc: 91.47, negpass_bits: 12, poszero_acc: 91.85, poszero_bits: 12, baseline_runtime_s: 6.32, circa_runtime_s: 2.47, speedup: 2.6, spec: || resnet::resnet32(32, 10) },
        Table1Row { name: "ResNet18-C10", relus_k: 557.1, baseline_acc: 94.66, negpass_acc: 93.77, negpass_bits: 11, poszero_acc: 94.24, poszero_bits: 11, baseline_runtime_s: 11.05, circa_runtime_s: 3.89, speedup: 2.8, spec: || resnet::resnet18(32, 10) },
        Table1Row { name: "VGG16-C10", relus_k: 284.7, baseline_acc: 94.00, negpass_acc: 93.77, negpass_bits: 12, poszero_acc: 93.61, poszero_bits: 13, baseline_runtime_s: 5.89, circa_runtime_s: 2.25, speedup: 2.6, spec: || vgg::vgg16(32, 10) },
        Table1Row { name: "ResNet32-C100", relus_k: 303.1, baseline_acc: 67.32, negpass_acc: 66.41, negpass_bits: 14, poszero_acc: 66.32, poszero_bits: 13, baseline_runtime_s: 6.32, circa_runtime_s: 2.47, speedup: 2.6, spec: || resnet::resnet32(32, 100) },
        Table1Row { name: "ResNet18-C100", relus_k: 557.1, baseline_acc: 74.24, negpass_acc: 73.80, negpass_bits: 13, poszero_acc: 73.76, poszero_bits: 12, baseline_runtime_s: 11.05, circa_runtime_s: 4.15, speedup: 2.7, spec: || resnet::resnet18(32, 100) },
        Table1Row { name: "VGG16-C100", relus_k: 284.7, baseline_acc: 73.94, negpass_acc: 73.25, negpass_bits: 12, poszero_acc: 73.19, poszero_bits: 12, baseline_runtime_s: 5.89, circa_runtime_s: 2.25, speedup: 2.6, spec: || vgg::vgg16(32, 100) },
        Table1Row { name: "ResNet32-Tiny", relus_k: 1212.4, baseline_acc: 55.53, negpass_acc: 55.15, negpass_bits: 16, poszero_acc: 54.56, poszero_bits: 15, baseline_runtime_s: 24.24, circa_runtime_s: 9.04, speedup: 2.7, spec: || resnet::resnet32(64, 200) },
        Table1Row { name: "ResNet18-Tiny", relus_k: 2228.2, baseline_acc: 61.60, negpass_acc: 60.60, negpass_bits: 13, poszero_acc: 60.65, poszero_bits: 12, baseline_runtime_s: 44.55, circa_runtime_s: 14.28, speedup: 3.1, spec: || resnet::resnet18(64, 200) },
        Table1Row { name: "VGG16-Tiny", relus_k: 1114.1, baseline_acc: 50.85, negpass_acc: 50.73, negpass_bits: 12, poszero_acc: 50.30, poszero_bits: 12, baseline_runtime_s: 21.41, circa_runtime_s: 6.96, speedup: 3.1, spec: || vgg::vgg16(64, 200) },
    ]
}

/// One row of Table 2 (DeepReDuce models).
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub name: &'static str,
    pub relus_k: f64,
    pub baseline_acc: f64,
    pub negpass_bits: u32,
    pub poszero_bits: u32,
    pub baseline_runtime_s: f64,
    pub circa_runtime_s: f64,
    pub speedup: f64,
    pub spec: fn() -> NetworkSpec,
}

#[rustfmt::skip]
pub fn table2() -> Vec<Table2Row> {
    vec![
        Table2Row { name: "DeepReD1-C100", relus_k: 229.4, baseline_acc: 76.22, negpass_bits: 13, poszero_bits: 12, baseline_runtime_s: 3.18, circa_runtime_s: 1.84, speedup: 1.7, spec: || deepreduce::deepreduce(1, 32, 100) },
        Table2Row { name: "DeepReD2-C100", relus_k: 114.7, baseline_acc: 74.72, negpass_bits: 13, poszero_bits: 13, baseline_runtime_s: 1.71, circa_runtime_s: 1.05, speedup: 1.6, spec: || deepreduce::deepreduce(2, 32, 100) },
        Table2Row { name: "DeepReD3-C100", relus_k: 196.6, baseline_acc: 75.51, negpass_bits: 13, poszero_bits: 13, baseline_runtime_s: 2.76, circa_runtime_s: 1.65, speedup: 1.7, spec: || deepreduce::deepreduce(3, 32, 100) },
        Table2Row { name: "DeepReD4-C100", relus_k: 98.3, baseline_acc: 71.95, negpass_bits: 13, poszero_bits: 13, baseline_runtime_s: 1.48, circa_runtime_s: 0.903, speedup: 1.6, spec: || deepreduce::deepreduce(4, 32, 100) },
        Table2Row { name: "DeepReD1-Tiny", relus_k: 917.5, baseline_acc: 64.66, negpass_bits: 14, poszero_bits: 14, baseline_runtime_s: 12.27, circa_runtime_s: 6.68, speedup: 1.8, spec: || deepreduce::deepreduce(1, 64, 200) },
        Table2Row { name: "DeepReD2-Tiny", relus_k: 458.8, baseline_acc: 62.26, negpass_bits: 15, poszero_bits: 15, baseline_runtime_s: 6.50, circa_runtime_s: 3.94, speedup: 1.6, spec: || deepreduce::deepreduce(2, 64, 200) },
        Table2Row { name: "DeepReD5-Tiny", relus_k: 393.2, baseline_acc: 61.65, negpass_bits: 15, poszero_bits: 15, baseline_runtime_s: 5.38, circa_runtime_s: 3.21, speedup: 1.7, spec: || deepreduce::deepreduce(5, 64, 200) },
        Table2Row { name: "DeepReD6-Tiny", relus_k: 229.4, baseline_acc: 59.18, negpass_bits: 15, poszero_bits: 15, baseline_runtime_s: 3.18, circa_runtime_s: 2.01, speedup: 1.6, spec: || deepreduce::deepreduce(6, 64, 200) },
    ]
}

/// One row of Table 3 (runtime per optimization stage).
#[derive(Clone, Debug)]
pub struct Table3Row {
    pub name: &'static str,
    pub relus_k: f64,
    pub relu_s: f64,
    pub sign_s: f64,
    pub stoch_sign_s: f64,
    pub trunc_sign_s: f64,
    pub trunc_bits: u32,
    pub spec: fn() -> NetworkSpec,
}

#[rustfmt::skip]
pub fn table3() -> Vec<Table3Row> {
    vec![
        Table3Row { name: "Res32-C100", relus_k: 303.10, relu_s: 6.32, sign_s: 5.51, stoch_sign_s: 4.50, trunc_sign_s: 2.47, trunc_bits: 13, spec: || resnet::resnet32(32, 100) },
        Table3Row { name: "Res18-C100", relus_k: 557.00, relu_s: 11.05, sign_s: 9.83, stoch_sign_s: 8.15, trunc_sign_s: 4.15, trunc_bits: 12, spec: || resnet::resnet18(32, 100) },
        Table3Row { name: "VGG16-C100", relus_k: 284.67, relu_s: 5.89, sign_s: 5.01, stoch_sign_s: 4.59, trunc_sign_s: 2.25, trunc_bits: 12, spec: || vgg::vgg16(32, 100) },
        Table3Row { name: "Res32-Tiny", relus_k: 1212.42, relu_s: 24.24, sign_s: 19.45, stoch_sign_s: 16.00, trunc_sign_s: 9.04, trunc_bits: 15, spec: || resnet::resnet32(64, 200) },
        Table3Row { name: "Res18-Tiny", relus_k: 2228.24, relu_s: 44.55, sign_s: 35.74, stoch_sign_s: 29.40, trunc_sign_s: 14.28, trunc_bits: 12, spec: || resnet::resnet18(64, 200) },
        Table3Row { name: "VGG16-Tiny", relus_k: 1114.10, relu_s: 21.41, sign_s: 17.91, stoch_sign_s: 14.68, trunc_sign_s: 6.96, trunc_bits: 12, spec: || vgg::vgg16(64, 200) },
    ]
}

/// Fig. 5's published GC sizes (KB per ReLU) for the 31-bit field.
#[derive(Clone, Copy, Debug)]
pub struct Fig5Paper {
    pub baseline_kb: f64,
    pub sign_kb: f64,
    pub stoch_kb: f64,
    pub trunc12_kb: f64,
}

/// Fig. 5 as printed (17.2 KB baseline; 1.4× / 1.9× / 4.7× reductions).
pub const FIG5_PAPER: Fig5Paper =
    Fig5Paper { baseline_kb: 17.2, sign_kb: 12.3, stoch_kb: 9.05, trunc12_kb: 3.66 };

/// Write a flat JSON object of numeric benchmark results under
/// `bench_out/` (no serde in the offline vendor set; a single flat map is
/// all the perf-trajectory tooling reads). Used by
/// `cargo bench --bench layer_batch` to emit `BENCH_layer_batch.json`.
pub fn write_bench_json(name: &str, entries: &[(&str, f64)]) {
    let dir = std::path::Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(name);
    let body: Vec<String> =
        entries.iter().map(|(k, v)| format!("  \"{k}\": {v}")).collect();
    let json = format!("{{\n{}\n}}\n", body.join(",\n"));
    std::fs::write(&path, json).expect("write bench json");
    eprintln!("  [json] wrote {}", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_is_flat_and_parseable_shape() {
        write_bench_json("test_bench.json", &[("a_us", 1.5), ("b_ratio", 2.0)]);
        let text = std::fs::read_to_string("bench_out/test_bench.json").unwrap();
        assert!(text.starts_with("{\n"));
        assert!(text.contains("\"a_us\": 1.5"));
        assert!(text.contains("\"b_ratio\": 2"));
        assert!(text.trim_end().ends_with('}'));
        let _ = std::fs::remove_file("bench_out/test_bench.json");
    }

    #[test]
    fn specs_match_published_relu_counts() {
        for row in table1() {
            let spec = (row.spec)();
            let got_k = spec.total_relus() as f64 / 1000.0;
            assert!(
                (got_k - row.relus_k).abs() < 0.15,
                "{}: spec {} vs paper {}",
                row.name,
                got_k,
                row.relus_k
            );
        }
        for row in table2() {
            let spec = (row.spec)();
            let got_k = spec.total_relus() as f64 / 1000.0;
            assert!(
                (got_k - row.relus_k).abs() < 0.15,
                "{}: spec {} vs paper {}",
                row.name,
                got_k,
                row.relus_k
            );
        }
    }

    #[test]
    fn paper_speedups_consistent() {
        for row in table1() {
            let implied = row.baseline_runtime_s / row.circa_runtime_s;
            assert!((implied - row.speedup).abs() < 0.2, "{}", row.name);
        }
    }
}

//! Shared measurement/reporting used by `cargo bench` to regenerate every
//! table and figure of the paper (criterion is not in the offline vendor
//! set, so benches are `harness = false` binaries built on this module).
//!
//! * [`relu_cost`] — measured per-ReLU offline/online cost of a variant;
//! * [`mac_cost`] — measured per-MAC cost of the SS linear layer;
//! * [`tables`] — the network roster with the paper's published numbers
//!   (ReLU counts, runtimes, accuracy, chosen truncation bits) so every
//!   bench prints paper-vs-measured side by side;
//! * CSV emission under `bench_out/`.

pub mod tables;

use crate::circuits::spec::ReluVariant;
use crate::field::{random_fp, Fp};
use crate::protocol::linear::{LinearOp, Matrix};
use crate::protocol::offline::offline_relu_layer;
use crate::protocol::online::online_relu_layer;
use crate::ss::SharePair;
use crate::util::{Rng, Timer};
use std::io::Write;
use std::path::Path;

/// Measured cost of one ReLU under a protocol variant.
#[derive(Clone, Copy, Debug)]
pub struct PerReluCost {
    /// Offline: garble + OT + triples, per ReLU (seconds).
    pub offline_s: f64,
    /// Online: labels + GC eval + decode + Beaver + resharing (seconds).
    pub online_s: f64,
    /// Online bytes per ReLU (both directions).
    pub online_bytes: f64,
    /// Client-side storage per ReLU (garbled tables + labels, bytes).
    pub storage_bytes: f64,
}

/// Measure per-ReLU costs by running the real protocol on `sample`
/// ReLUs (shares of plausible activation magnitudes).
pub fn relu_cost(variant: ReluVariant, sample: usize, rng: &mut Rng) -> PerReluCost {
    let xs: Vec<Fp> = (0..sample)
        .map(|_| {
            let mag = rng.below(1 << 20) as i64;
            Fp::from_i64(if rng.bool() { mag } else { -mag })
        })
        .collect();
    let shares: Vec<SharePair> = xs.iter().map(|&x| SharePair::share(x, rng)).collect();
    let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
    let xsrv: Vec<Fp> = shares.iter().map(|s| s.server).collect();

    let t = Timer::new();
    let (cm, sm) = offline_relu_layer(variant, &xc, rng);
    let offline_s = t.elapsed_s() / sample as f64;

    let storage_bytes = cm.offline_bytes as f64 / sample as f64;

    let t = Timer::new();
    let (_, _, stats) = online_relu_layer(&cm, &sm, &xc, &xsrv);
    let online_s = t.elapsed_s() / sample as f64;

    PerReluCost {
        offline_s,
        online_s,
        online_bytes: stats.bytes_total() as f64 / sample as f64,
        storage_bytes,
    }
}

/// Measure the per-MAC cost of the online SS linear layer with a
/// representative dense matrix (the server-side `W·(y−r)+s`).
pub fn mac_cost(rng: &mut Rng) -> f64 {
    let (rows, cols) = (256, 1024);
    let w = Matrix::random(rows, cols, 1 << 14, rng);
    let x: Vec<Fp> = (0..cols).map(|_| random_fp(rng)).collect();
    // Warm + measure enough iterations to be stable.
    let mut sink = Fp::ZERO;
    let t = Timer::new();
    let iters = 20;
    for _ in 0..iters {
        let out = w.apply(&x);
        sink = sink + out[0];
    }
    let per_mac = t.elapsed_s() / (iters * rows * cols) as f64;
    std::hint::black_box(sink);
    per_mac
}

/// Estimated end-to-end online runtime of a network under a variant:
/// measured per-ReLU cost × ReLU count + measured per-MAC cost × MACs.
pub fn network_runtime_s(
    relus: u64,
    macs: u64,
    per_relu: &PerReluCost,
    per_mac_s: f64,
) -> f64 {
    relus as f64 * per_relu.online_s + macs as f64 * per_mac_s
}

/// Append rows to a CSV under `bench_out/` (created on demand).
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let dir = Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path).expect("create csv");
    writeln!(f, "{header}").unwrap();
    for r in rows {
        writeln!(f, "{r}").unwrap();
    }
    eprintln!("  [csv] wrote {}", path.display());
}

/// Fixed-width table printing.
pub fn print_row(cols: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{:>w$}  ", c, w = w));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::FaultMode;

    #[test]
    fn relu_cost_sane_and_ordered() {
        let mut rng = Rng::new(1);
        let base = relu_cost(ReluVariant::BaselineRelu, 64, &mut rng);
        let circa =
            relu_cost(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }, 64, &mut rng);
        assert!(base.online_s > 0.0 && circa.online_s > 0.0);
        // Circa must be meaningfully faster online and smaller at rest.
        assert!(circa.online_s < base.online_s, "{circa:?} vs {base:?}");
        assert!(circa.storage_bytes < base.storage_bytes);
    }

    #[test]
    fn mac_cost_positive_and_fast() {
        let mut rng = Rng::new(2);
        let c = mac_cost(&mut rng);
        assert!(c > 0.0 && c < 1e-6, "per-MAC {c}");
    }

    #[test]
    fn runtime_model_composes() {
        let per_relu = PerReluCost {
            offline_s: 1e-5,
            online_s: 1e-6,
            online_bytes: 400.0,
            storage_bytes: 2000.0,
        };
        let s = network_runtime_s(1000, 1_000_000, &per_relu, 1e-9);
        assert!((s - (1e-3 + 1e-3)).abs() < 1e-9);
    }
}

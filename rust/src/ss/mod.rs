//! Additive secret sharing over `F_p` (§2.2).
//!
//! A value `x` splits into `⟨x⟩_1 = r` and `⟨x⟩_2 = x − r` for uniform `r`;
//! reconstruction adds the shares. Addition and scalar/plaintext-linear
//! operations act share-wise, which is what makes Delphi's online linear
//! layers near-plaintext speed.

use crate::field::{random_fp, Fp};
use crate::util::Rng;

/// One party's share of a secret value.
pub type Share = Fp;

/// A pair of shares `(client, server)` reconstructing to a secret.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SharePair {
    pub client: Share,
    pub server: Share,
}

impl SharePair {
    /// Split `x` into uniform shares.
    pub fn share(x: Fp, rng: &mut Rng) -> Self {
        let r = random_fp(rng);
        SharePair { client: r, server: x - r }
    }

    /// Split with the *client-holds-r* convention Circa's ReLU uses:
    /// `⟨x⟩_s = x + t mod p`, `⟨x⟩_c = p − t` for the given `t`.
    pub fn share_with_t(x: Fp, t: Fp) -> Self {
        SharePair { client: -t, server: x + t }
    }

    /// Reconstruct the secret.
    pub fn reconstruct(&self) -> Fp {
        self.client + self.server
    }
}

/// Share a vector of values.
pub fn share_vec(xs: &[Fp], rng: &mut Rng) -> (Vec<Share>, Vec<Share>) {
    let mut client = Vec::with_capacity(xs.len());
    let mut server = Vec::with_capacity(xs.len());
    for &x in xs {
        let p = SharePair::share(x, rng);
        client.push(p.client);
        server.push(p.server);
    }
    (client, server)
}

/// Reconstruct a vector of values from share vectors.
pub fn reconstruct_vec(client: &[Share], server: &[Share]) -> Vec<Fp> {
    debug_assert_eq!(client.len(), server.len());
    client.iter().zip(server).map(|(&c, &s)| c + s).collect()
}

/// Share-wise addition: each party adds locally.
pub fn add_local(a: &[Share], b: &[Share]) -> Vec<Share> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Multiply shares by a public plaintext constant (each party locally).
pub fn scale_local(a: &[Share], c: Fp) -> Vec<Share> {
    a.iter().map(|&x| x * c).collect()
}

/// Add a public constant to a sharing: exactly one party adds it.
pub fn add_public_one_side(shares: &mut [Share], consts: &[Fp]) {
    for (s, &c) in shares.iter_mut().zip(consts) {
        *s = *s + c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PRIME;

    #[test]
    fn share_reconstruct_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let x = random_fp(&mut rng);
            let p = SharePair::share(x, &mut rng);
            assert_eq!(p.reconstruct(), x);
        }
    }

    #[test]
    fn share_with_t_convention() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let x = random_fp(&mut rng);
            let t = random_fp(&mut rng);
            let p = SharePair::share_with_t(x, t);
            assert_eq!(p.reconstruct(), x);
            // server share is x + t mod p, client is p - t
            assert_eq!(p.server.raw(), (x.raw() + t.raw()) % PRIME);
            assert_eq!(p.client.raw(), (PRIME - t.raw()) % PRIME);
        }
    }

    #[test]
    fn vector_roundtrip_and_addition() {
        let mut rng = Rng::new(3);
        let xs: Vec<Fp> = (0..64).map(|_| random_fp(&mut rng)).collect();
        let ys: Vec<Fp> = (0..64).map(|_| random_fp(&mut rng)).collect();
        let (xc, xs_srv) = share_vec(&xs, &mut rng);
        let (yc, ys_srv) = share_vec(&ys, &mut rng);
        let sum_c = add_local(&xc, &yc);
        let sum_s = add_local(&xs_srv, &ys_srv);
        let got = reconstruct_vec(&sum_c, &sum_s);
        let want: Vec<Fp> = xs.iter().zip(&ys).map(|(&a, &b)| a + b).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn scalar_and_public_ops() {
        let mut rng = Rng::new(4);
        let xs: Vec<Fp> = (0..32).map(|_| random_fp(&mut rng)).collect();
        let (c, s) = share_vec(&xs, &mut rng);
        let k = Fp::from_i64(7);
        let sc = scale_local(&c, k);
        let ss_ = scale_local(&s, k);
        let got = reconstruct_vec(&sc, &ss_);
        assert_eq!(got, xs.iter().map(|&x| x * k).collect::<Vec<_>>());

        let consts: Vec<Fp> = (0..32).map(|_| random_fp(&mut rng)).collect();
        let mut s2 = s.clone();
        add_public_one_side(&mut s2, &consts);
        let got = reconstruct_vec(&c, &s2);
        assert_eq!(got, xs.iter().zip(&consts).map(|(&x, &a)| x + a).collect::<Vec<_>>());
    }

    #[test]
    fn shares_look_uniform() {
        // Each individual share of a fixed secret should be ~uniform.
        let mut rng = Rng::new(5);
        let x = Fp::from_i64(12345);
        let n = 4000;
        let mut low = 0u32;
        for _ in 0..n {
            let p = SharePair::share(x, &mut rng);
            if p.client.raw() < PRIME / 2 {
                low += 1;
            }
        }
        let frac = low as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "client share biased: {frac}");
    }
}

//! Artifact directory discovery and validation.

use crate::bail;
use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// A validated artifacts directory.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub root: PathBuf,
}

/// Files `make artifacts` must have produced.
pub const REQUIRED: [&str; 7] = [
    "manifest.json",
    "demo_cnn.hlo.txt",
    "demo_mlp.hlo.txt",
    "stoch_relu.hlo.txt",
    "weights.bin",
    "weights_mlp.bin",
    "dataset.bin",
];

impl ArtifactDir {
    /// Open and validate a directory.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        for f in REQUIRED {
            let p = root.join(f);
            if !p.exists() {
                bail!("missing artifact {} — run `make artifacts`", p.display());
            }
        }
        let manifest = std::fs::read_to_string(root.join("manifest.json"))
            .context("reading manifest.json")?;
        if !manifest.contains("\"circa-artifacts-1\"") {
            bail!("unexpected artifact version in manifest.json");
        }
        Ok(Self { root })
    }

    /// Search upward from CWD (and the `ARTIFACTS_DIR` env var) — keeps
    /// `cargo test`/`cargo bench` working from any workspace subdir.
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("ARTIFACTS_DIR") {
            return Self::open(dir);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::open(cand);
            }
            if !cur.pop() {
                bail!("no artifacts/ directory found — run `make artifacts`");
            }
        }
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Pull a numeric field out of the (flat) manifest without a JSON
    /// dependency — fields are written by our own aot.py.
    pub fn manifest_f64(&self, key: &str) -> Result<f64> {
        let text = std::fs::read_to_string(self.path("manifest.json"))?;
        let needle = format!("\"{key}\":");
        let idx = text.find(&needle).with_context(|| format!("manifest key {key}"))?;
        let rest = &text[idx + needle.len()..];
        let val: String = rest
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-' || *c == 'e')
            .collect();
        val.parse().with_context(|| format!("parsing manifest {key}={val}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_rejects_missing_dir() {
        assert!(ArtifactDir::open("/nonexistent/path").is_err());
    }

    #[test]
    fn manifest_parse_helper() {
        let dir = std::env::temp_dir().join("circa_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        for f in REQUIRED {
            std::fs::write(dir.join(f), "x").unwrap();
        }
        std::fs::write(
            dir.join("manifest.json"),
            "{\"version\": \"circa-artifacts-1\", \"batch\": 128, \"cnn_quantized_acc\": 0.93}",
        )
        .unwrap();
        let a = ArtifactDir::open(&dir).unwrap();
        assert_eq!(a.manifest_f64("batch").unwrap(), 128.0);
        assert!((a.manifest_f64("cnn_quantized_acc").unwrap() - 0.93).abs() < 1e-9);
        assert!(a.manifest_f64("nope").is_err());
    }
}

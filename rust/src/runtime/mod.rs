//! PJRT runtime: load the AOT-compiled JAX model from `artifacts/` and
//! execute it from Rust — no Python on this path.
//!
//! The interchange format is HLO **text** (`HloModuleProto::from_text_file`),
//! not serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that the
//! image's xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md).
//!
//! * [`artifacts`] — locate the artifact directory, check the manifest.
//! * [`model_exec`] — compiled-executable wrappers for the three entry
//!   points (`demo_cnn`, `demo_mlp`, `stoch_relu`) with typed call
//!   signatures; each executable is compiled once and reused across the
//!   whole sweep (k/mode are runtime scalars by design).

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod model_exec;

pub use artifacts::ArtifactDir;
#[cfg(feature = "pjrt")]
pub use model_exec::{CnnExecutable, ModelOutput, StochReluExecutable};

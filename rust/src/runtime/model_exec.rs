//! Typed wrappers around the compiled PJRT executables.
//!
//! Each wrapper compiles its HLO once (`PjRtClient::cpu` →
//! `HloModuleProto::from_text_file` → `compile`) and then serves any
//! number of `run` calls; `k` and `mode` are runtime scalar inputs so a
//! whole Fig. 4 sweep reuses one compilation.

use super::artifacts::ArtifactDir;
use crate::util::error::{Context, Error, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::msg(e)
    }
}

/// Fault modes on the artifact ABI (matches python kernels/ref.py).
pub const MODE_POSZERO: i32 = 0;
pub const MODE_NEGPASS: i32 = 1;
pub const MODE_EXACT: i32 = 2;

/// Output of one model batch execution.
#[derive(Clone, Debug)]
pub struct ModelOutput {
    /// Row-major `[batch][classes]` logits (ACT-scale fixed point).
    pub logits: Vec<i32>,
    pub n_classes: usize,
    /// Per-ReLU-layer fault counts.
    pub faults: Vec<i64>,
}

impl ModelOutput {
    pub fn argmax(&self, row: usize) -> usize {
        let r = &self.logits[row * self.n_classes..(row + 1) * self.n_classes];
        r.iter().enumerate().max_by_key(|(_, v)| **v).map(|(i, _)| i).unwrap()
    }

    pub fn total_faults(&self) -> i64 {
        self.faults.iter().sum()
    }
}

fn compile(client: &PjRtClient, dir: &ArtifactDir, name: &str) -> Result<PjRtLoadedExecutable> {
    let path = dir.path(name);
    let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
        .with_context(|| format!("parsing {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {name}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    Ok(Literal::vec1(data).reshape(dims)?)
}

fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// The demo CNN/MLP executable (`demo_cnn.hlo.txt` / `demo_mlp.hlo.txt`).
pub struct CnnExecutable {
    exe: PjRtLoadedExecutable,
    /// (shape dims of images, t1, t2) — per artifact ABI.
    images_dims: Vec<i64>,
    t1_dims: Vec<i64>,
    t2_dims: Vec<i64>,
    pub batch: usize,
    n_classes: usize,
    /// Quantized parameters in ABI order (w1,b1,w2,b2,w3,b3).
    params: Vec<Literal>,
}

impl CnnExecutable {
    /// Load the CNN entry with parameters from `weights.bin`.
    pub fn load_cnn(client: &PjRtClient, dir: &ArtifactDir) -> Result<Self> {
        let net = crate::nn::weights::load_weights(&dir.path("weights.bin"))?;
        let batch = dir.manifest_f64("batch")? as usize;
        Self::new(
            compile(client, dir, "demo_cnn.hlo.txt")?,
            vec![batch as i64, 1, 16, 16],
            vec![batch as i64, 8, 8, 8],
            vec![batch as i64, 16, 4, 4],
            batch,
            10,
            &net,
        )
    }

    /// Load the MLP entry with parameters from `weights_mlp.bin`.
    pub fn load_mlp(client: &PjRtClient, dir: &ArtifactDir) -> Result<Self> {
        let net = crate::nn::weights::load_weights(&dir.path("weights_mlp.bin"))?;
        let batch = dir.manifest_f64("batch")? as usize;
        Self::new(
            compile(client, dir, "demo_mlp.hlo.txt")?,
            vec![batch as i64, 256],
            vec![batch as i64, 128],
            vec![batch as i64, 64],
            batch,
            10,
            &net,
        )
    }

    fn new(
        exe: PjRtLoadedExecutable,
        images_dims: Vec<i64>,
        t1_dims: Vec<i64>,
        t2_dims: Vec<i64>,
        batch: usize,
        n_classes: usize,
        net: &crate::nn::weights::LoadedNet,
    ) -> Result<Self> {
        // Flatten the loaded layers back to the ABI parameter tensors.
        let mut params = Vec::new();
        for layer in &net.layers {
            params.push(lit_i32(&layer.w_raw, &layer.w_dims)?);
            params.push(lit_i32(&layer.b_raw, &layer.b_dims)?);
        }
        Ok(Self { exe, images_dims, t1_dims, t2_dims, batch, n_classes, params })
    }

    /// Number of ReLU elements per example (t1 + t2 sizes / batch).
    pub fn relus_per_example(&self) -> usize {
        let n1: i64 = self.t1_dims.iter().product();
        let n2: i64 = self.t2_dims.iter().product();
        ((n1 + n2) as usize) / self.batch
    }

    /// Run one batch: `images` is row-major flattened (batch × dim),
    /// `t1`/`t2` uniform field randomness, `k` truncation bits, `mode`
    /// 0/1/2 (PosZero/NegPass/exact).
    pub fn run(
        &self,
        images: &[i32],
        t1: &[i32],
        t2: &[i32],
        k: i32,
        mode: i32,
    ) -> Result<ModelOutput> {
        let mut args: Vec<Literal> = Vec::with_capacity(5 + self.params.len());
        args.push(lit_i32(images, &self.images_dims)?);
        args.push(lit_i32(t1, &self.t1_dims)?);
        args.push(lit_i32(t2, &self.t2_dims)?);
        args.push(scalar_i32(k));
        args.push(scalar_i32(mode));
        for p in &self.params {
            // Literal has no cheap clone in this crate version; round-trip
            // through raw data only once per call (params are small).
            args.push(clone_literal(p)?);
        }
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits_l, faults_l) = result.to_tuple2()?;
        let logits = logits_l.to_vec::<i32>()?;
        let faults_i32: Vec<i64> = faults_l.to_vec::<i64>()?;
        Ok(ModelOutput { logits, n_classes: self.n_classes, faults: faults_i32 })
    }
}

fn clone_literal(l: &Literal) -> Result<Literal> {
    // Shape-preserving copy via raw data.
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let data = l.to_vec::<i32>()?;
    lit_i32(&data, &dims)
}

/// The standalone stochastic-ReLU kernel executable.
pub struct StochReluExecutable {
    exe: PjRtLoadedExecutable,
    pub n: usize,
}

impl StochReluExecutable {
    pub fn load(client: &PjRtClient, dir: &ArtifactDir) -> Result<Self> {
        let n = dir.manifest_f64("relu_n")? as usize;
        Ok(Self { exe: compile(client, dir, "stoch_relu.hlo.txt")?, n })
    }

    /// Run the kernel: returns (y, fault mask).
    pub fn run(&self, x: &[i32], t: &[i32], k: i32, mode: i32) -> Result<(Vec<i32>, Vec<i32>)> {
        crate::ensure!(x.len() == self.n && t.len() == self.n, "kernel arity is {}", self.n);
        let args = vec![
            lit_i32(x, &[self.n as i64])?,
            lit_i32(t, &[self.n as i64])?,
            scalar_i32(k),
            scalar_i32(mode),
        ];
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let (y, f) = result.to_tuple2()?;
        Ok((y.to_vec::<i32>()?, f.to_vec::<i32>()?))
    }
}

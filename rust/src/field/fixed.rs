//! Delphi-style fixed-point quantization into `F_p`.
//!
//! The paper scales and quantizes model parameters and inputs to 15 bits
//! (§4.1): a real `v` maps to `round(v · 2^SCALE_BITS)` clamped to 15-bit
//! magnitude, so the product of two quantized values stays below the 31-bit
//! prime. After each multiply-accumulate layer the result is rescaled by
//! `2^-SCALE_BITS` (arithmetic shift on the signed decoding).

use super::{Fp, HALF};

/// Fractional bits of the fixed-point representation.
pub const SCALE_BITS: u32 = 8;

/// Magnitude cap for quantized *parameters/inputs*: 15-bit signed as in
/// Delphi (1 sign bit + 14 magnitude bits), so a product of two quantized
/// values stays below `p/2` and the signed decode is exact.
pub const QUANT_MAX: i64 = (1 << 14) - 1;

/// Quantize a real value to a field element (15-bit clamped).
pub fn quantize(v: f32) -> Fp {
    let scaled = (v as f64 * (1i64 << SCALE_BITS) as f64).round() as i64;
    Fp::from_i64(scaled.clamp(-QUANT_MAX, QUANT_MAX))
}

/// Dequantize a field element back to a real value.
pub fn dequantize(x: Fp) -> f32 {
    (x.to_i64() as f64 / (1i64 << SCALE_BITS) as f64) as f32
}

/// Quantize a slice.
pub fn quantize_all(vs: &[f32]) -> Vec<Fp> {
    vs.iter().map(|&v| quantize(v)).collect()
}

/// Largest signed magnitude an *accumulator* may reach before decode breaks.
pub const ACC_MAX: i64 = (HALF - 1) as i64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_step() {
        let step = 1.0 / (1i64 << SCALE_BITS) as f32;
        // Representable range is ±QUANT_MAX/2^SCALE_BITS ≈ ±63.99.
        for v in [-3.25f32, -0.5, 0.0, 0.004, 1.0, 60.5] {
            let q = quantize(v);
            assert!((dequantize(q) - v).abs() <= step, "v={v}");
        }
    }

    #[test]
    fn clamps_large_values() {
        let q = quantize(1e9);
        assert_eq!(q.to_i64(), QUANT_MAX);
        let q = quantize(-1e9);
        assert_eq!(q.to_i64(), -QUANT_MAX);
    }

    #[test]
    fn product_fits_field() {
        // Two max-magnitude quantized values must multiply without wrapping
        // the signed decode: |a*b| = (2^15-1)^2 < p/2.
        let prod = QUANT_MAX * QUANT_MAX;
        assert!(prod < ACC_MAX);
        let a = Fp::from_i64(QUANT_MAX);
        let b = Fp::from_i64(-QUANT_MAX);
        assert_eq!((a * b).to_i64(), -prod);
    }

    #[test]
    fn quantize_all_length() {
        assert_eq!(quantize_all(&[0.0, 1.0, 2.0]).len(), 3);
    }
}

//! Arithmetic over the prime field `F_p` used by Delphi and Circa.
//!
//! The paper fixes `p = 2138816513` (a 31-bit prime) so that products of two
//! 15-bit fixed-point values never exceed the field (§4.1). Values are
//! encoded with positives in `[0, (p−1)/2)` and negatives in
//! `[(p−1)/2, p)` (§2.2), so `sign(x) = 1 ⟺ x < p/2` in field encoding.

pub mod fixed;

/// The paper's 31-bit prime, `p = 2138816513`.
pub const PRIME: u64 = 2_138_816_513;

/// Bit width `m = ⌈log2 p⌉` of a field element.
pub const FIELD_BITS: usize = 31;

/// Half of the field: the positive/negative encoding boundary.
pub const HALF: u64 = PRIME / 2; // floor((p-1)/2)

/// An element of `F_p`, stored canonically in `[0, p)`.
///
/// All arithmetic is wrapping in the field. The representation fits in a
/// `u32` but we store `u64` to keep intermediate products single-width
/// (`u64 * u64` products are taken via `u128` in [`Fp::mul`]).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fp(u64);

impl std::fmt::Debug for Fp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Fp({} = {})", self.0, self.to_i64())
    }
}

impl Fp {
    pub const ZERO: Fp = Fp(0);
    pub const ONE: Fp = Fp(1);

    /// Construct from a canonical value; debug-asserts range.
    #[inline]
    pub fn new(v: u64) -> Self {
        debug_assert!(v < PRIME);
        Fp(v)
    }

    /// Construct from any u64 by reduction.
    #[inline]
    pub fn reduce(v: u64) -> Self {
        Fp(v % PRIME)
    }

    /// Encode a signed integer; `x` must satisfy `|x| < p/2`.
    #[inline]
    pub fn from_i64(x: i64) -> Self {
        debug_assert!(x.unsigned_abs() < HALF, "magnitude too large for field: {x}");
        if x >= 0 {
            Fp(x as u64)
        } else {
            Fp(PRIME - x.unsigned_abs())
        }
    }

    /// Decode to a signed integer using the paper's encoding:
    /// values `< (p−1)/2` are positive, the rest negative.
    #[inline]
    pub fn to_i64(self) -> i64 {
        if self.0 < HALF {
            self.0 as i64
        } else {
            -((PRIME - self.0) as i64)
        }
    }

    /// Raw canonical representative in `[0, p)`.
    #[inline]
    pub fn raw(self) -> u64 {
        self.0
    }

    /// `|x|` in the signed encoding.
    #[inline]
    pub fn magnitude(self) -> u64 {
        if self.0 < HALF {
            self.0
        } else {
            PRIME - self.0
        }
    }

    /// Exact sign in the field encoding: `true` for non-negative.
    #[inline]
    pub fn is_nonneg(self) -> bool {
        self.0 < HALF
    }

    #[inline]
    pub fn add(self, rhs: Fp) -> Fp {
        let s = self.0 + rhs.0;
        Fp(if s >= PRIME { s - PRIME } else { s })
    }

    #[inline]
    pub fn sub(self, rhs: Fp) -> Fp {
        Fp(if self.0 >= rhs.0 { self.0 - rhs.0 } else { self.0 + PRIME - rhs.0 })
    }

    #[inline]
    pub fn neg(self) -> Fp {
        if self.0 == 0 {
            Fp(0)
        } else {
            Fp(PRIME - self.0)
        }
    }

    #[inline]
    pub fn mul(self, rhs: Fp) -> Fp {
        Fp(((self.0 as u128 * rhs.0 as u128) % PRIME as u128) as u64)
    }

    /// Modular exponentiation by squaring.
    pub fn pow(self, mut e: u64) -> Fp {
        let mut base = self;
        let mut acc = Fp::ONE;
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }

    /// Multiplicative inverse via Fermat (p is prime). Panics on zero.
    pub fn inv(self) -> Fp {
        assert!(self.0 != 0, "inverse of zero");
        self.pow(PRIME - 2)
    }

    /// Delphi-style truncation after a fixed-point multiply: divide the
    /// *signed* value by `2^s` (rounding toward zero) and re-encode.
    #[inline]
    pub fn rescale(self, s: u32) -> Fp {
        Fp::from_i64(self.to_i64() >> s)
    }
}

impl std::ops::Add for Fp {
    type Output = Fp;
    fn add(self, rhs: Fp) -> Fp {
        Fp::add(self, rhs)
    }
}

impl std::ops::Sub for Fp {
    type Output = Fp;
    fn sub(self, rhs: Fp) -> Fp {
        Fp::sub(self, rhs)
    }
}

impl std::ops::Mul for Fp {
    type Output = Fp;
    fn mul(self, rhs: Fp) -> Fp {
        Fp::mul(self, rhs)
    }
}

impl std::ops::Neg for Fp {
    type Output = Fp;
    fn neg(self) -> Fp {
        Fp::neg(self)
    }
}

/// Sample a uniform field element.
#[inline]
pub fn random_fp(rng: &mut crate::util::Rng) -> Fp {
    Fp::new(rng.below(PRIME))
}

/// Exact plaintext ReLU in the field encoding.
#[inline]
pub fn relu_exact(x: Fp) -> Fp {
    if x.is_nonneg() {
        x
    } else {
        Fp::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn prime_is_prime_ish() {
        // Trial division by small primes (sanity; full primality in fixed.rs tests).
        for d in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
            assert_ne!(PRIME % d, 0, "divisible by {d}");
        }
        assert_eq!(64 - (PRIME - 1).leading_zeros() as usize, FIELD_BITS);
    }

    #[test]
    fn signed_roundtrip() {
        for x in [-1_000_000i64, -1, 0, 1, 12345, (HALF as i64) - 1, -(HALF as i64) + 1] {
            assert_eq!(Fp::from_i64(x).to_i64(), x, "x={x}");
        }
    }

    #[test]
    fn add_sub_inverse() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let a = random_fp(&mut rng);
            let b = random_fp(&mut rng);
            assert_eq!((a + b) - b, a);
            assert_eq!(a - a, Fp::ZERO);
            assert_eq!(a + (-a), Fp::ZERO);
        }
    }

    #[test]
    fn mul_matches_bigint() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let a = random_fp(&mut rng);
            let b = random_fp(&mut rng);
            let want = ((a.raw() as u128 * b.raw() as u128) % PRIME as u128) as u64;
            assert_eq!((a * b).raw(), want);
        }
    }

    #[test]
    fn field_axioms_sampled() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let a = random_fp(&mut rng);
            let b = random_fp(&mut rng);
            let c = random_fp(&mut rng);
            assert_eq!((a + b) + c, a + (b + c));
            assert_eq!((a * b) * c, a * (b * c));
            assert_eq!(a * (b + c), a * b + a * c);
            assert_eq!(a * Fp::ONE, a);
        }
    }

    #[test]
    fn inverse() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let a = random_fp(&mut rng);
            if a == Fp::ZERO {
                continue;
            }
            assert_eq!(a * a.inv(), Fp::ONE);
        }
    }

    #[test]
    #[should_panic]
    fn zero_has_no_inverse() {
        Fp::ZERO.inv();
    }

    #[test]
    fn sign_encoding() {
        assert!(Fp::from_i64(5).is_nonneg());
        assert!(Fp::ZERO.is_nonneg());
        assert!(!Fp::from_i64(-5).is_nonneg());
        assert_eq!(Fp::from_i64(-5).magnitude(), 5);
        assert_eq!(Fp::from_i64(7).magnitude(), 7);
    }

    #[test]
    fn relu_exact_matches_signed() {
        for x in [-100i64, -1, 0, 1, 100] {
            let want = x.max(0);
            assert_eq!(relu_exact(Fp::from_i64(x)).to_i64(), want);
        }
    }

    #[test]
    fn rescale_is_arithmetic_shift_on_signed() {
        for x in [-(1i64 << 20), -4097, -1, 0, 1, 4097, 1 << 20] {
            let f = Fp::from_i64(x).rescale(12);
            assert_eq!(f.to_i64(), x >> 12, "x={x}");
        }
    }

    #[test]
    fn pow_small_cases() {
        let a = Fp::from_i64(3);
        assert_eq!(a.pow(0), Fp::ONE);
        assert_eq!(a.pow(1), a);
        assert_eq!(a.pow(4).to_i64(), 81);
    }
}

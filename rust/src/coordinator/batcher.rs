//! Dynamic request batcher: max-size / max-delay grouping.
//!
//! PI requests are independent (each consumes its own material), so the
//! batcher's job is *dispatch shaping*: group arrivals so the router can
//! hand a worker a contiguous chunk, amortizing queue overhead and
//! letting the metrics attribute queueing vs protocol time — the same
//! role the batch scheduler plays in a clear-text serving stack.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_size: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_size: 8, max_delay: Duration::from_millis(2) }
    }
}

/// Pull one batch from `rx` under the policy. Returns `None` when the
/// channel is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    // Block for the first element.
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_delay;
    while batch.len() < policy.max_size {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max_size() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_size: 4, max_delay: Duration::from_millis(50) };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_delay() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_size: 100, max_delay: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_after_sender_drop() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_size: 10, max_delay: Duration::from_millis(1) };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![7, 8]);
        assert!(next_batch(&rx, policy).is_none());
    }
}

//! Dynamic request batcher: max-size / max-delay grouping, split by
//! model.
//!
//! PI requests are independent (each consumes its own material), so the
//! batcher's job is *dispatch shaping*: group arrivals so the router can
//! hand a worker a contiguous chunk, amortizing queue overhead and
//! letting the metrics attribute queueing vs protocol time — the same
//! role the batch scheduler plays in a clear-text serving stack. In a
//! multi-model coordinator a dispatch batch is additionally
//! **model-homogeneous** ([`ModelBatch`]): every request in it leases
//! from the same pool shard, so a worker touches one shard per batch and
//! the metrics row it feeds is unambiguous.

use super::router::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_size: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_size: 8, max_delay: Duration::from_millis(2) }
    }
}

impl BatchPolicy {
    /// Reject unusable policies up front: `max_size == 0` would make
    /// [`next_batch`] spin the delay window and return empty batches
    /// forever instead of failing loudly at service start.
    pub fn validate(&self) -> crate::util::error::Result<()> {
        crate::ensure!(self.max_size >= 1, "batch max_size must be >= 1 (got 0)");
        Ok(())
    }
}

/// One model-homogeneous dispatch batch: the router leases every
/// request in it from the shard `model` names.
pub struct ModelBatch {
    pub model: u64,
    pub requests: Vec<Request>,
}

/// Pull one arrival window from `rx` under the policy and split it into
/// model-homogeneous batches, preserving arrival order within each
/// model. Returns `None` when the channel is closed and drained.
pub fn next_model_batches(rx: &Receiver<Request>, policy: BatchPolicy) -> Option<Vec<ModelBatch>> {
    let window = next_batch(rx, policy)?;
    let mut out: Vec<ModelBatch> = Vec::new();
    for req in window {
        match out.iter_mut().find(|b| b.model == req.model) {
            Some(b) => b.requests.push(req),
            None => out.push(ModelBatch { model: req.model, requests: vec![req] }),
        }
    }
    Some(out)
}

/// Pull one batch from `rx` under the policy. Returns `None` when the
/// channel is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    // Block for the first element.
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_delay;
    while batch.len() < policy.max_size {
        // One clock read per iteration: the remaining window doubles as
        // the deadline check (zero ⇒ the window has closed).
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max_size() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_size: 4, max_delay: Duration::from_millis(50) };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_delay() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_size: 100, max_delay: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn splits_window_by_model_preserving_order() {
        let (reply, _keep) = channel();
        let (tx, rx) = channel();
        for (id, model) in [(0u64, 7u64), (1, 9), (2, 7), (3, 7), (4, 9)] {
            tx.send(Request {
                id,
                model,
                input: Vec::new(),
                enqueued: Instant::now(),
                reply: reply.clone(),
            })
            .unwrap();
        }
        let policy = BatchPolicy { max_size: 5, max_delay: Duration::from_millis(50) };
        let batches = next_model_batches(&rx, policy).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].model, 7);
        assert_eq!(batches[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(), [0, 2, 3]);
        assert_eq!(batches[1].model, 9);
        assert_eq!(batches[1].requests.iter().map(|r| r.id).collect::<Vec<_>>(), [1, 4]);
    }

    #[test]
    fn policy_validation_rejects_zero_max_size() {
        let bad = BatchPolicy { max_size: 0, max_delay: Duration::from_millis(1) };
        assert!(bad.validate().is_err());
        assert!(BatchPolicy::default().validate().is_ok());
        assert!(BatchPolicy { max_size: 1, max_delay: Duration::ZERO }.validate().is_ok());
    }

    #[test]
    fn drains_after_sender_drop() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_size: 10, max_delay: Duration::from_millis(1) };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![7, 8]);
        assert!(next_batch(&rx, policy).is_none());
    }
}

//! The model registry: fingerprint-keyed identity for every plan a
//! coordinator (or dealer) serves.
//!
//! A production PI fleet never serves one architecture — Circa's ReLU
//! savings compose with network-level ReLU reduction (CryptoNAS budget
//! networks, DeepReDuce-style culled ResNets), so one coordinator banks
//! and serves material for several [`NetworkPlan`]s at once. The
//! registry is the single source of model identity for that: each
//! registered plan is keyed by its [`SessionManifest::fingerprint`]
//! (which covers variant, layer dimensions, rescale schedule, *and* the
//! behavioral weight digest — two same-shaped models with different
//! weights are different models), and carries
//!
//! * the plan itself (`Arc`-shared with the pool shard, the dealer, and
//!   the codec's shape validation),
//! * a **per-model dealing base seed** — the namespace under which the
//!   model's session sequence numbers live. Seq-addressed dealing is a
//!   pure function of `(base_seed, seq)`
//!   ([`crate::protocol::server::session_rng`]), so giving every model
//!   its own base seed keeps two models' seq spaces from ever colliding
//!   even though both count sessions 0, 1, 2, …,
//! * a **demand weight** scaling the refill scheduler's deficit for this
//!   model's banks (a model taking 3× the traffic wants its banks
//!   refilled 3× as eagerly). Since the fleet-scheduler revision this
//!   static weight is only the **cold-start prior**: once a model has
//!   observed traffic, the pool derives effective weights from an EWMA
//!   of per-model lease rates ([`LeaseRate`]) so refill chases measured
//!   demand, not config guesses.
//!
//! Dealer and coordinator processes each hold their own registry; the
//! wire handshake ([`crate::wire::dealer`]) compares manifest *sets*, so
//! base seeds never need to agree across processes — only the dealer's
//! own seeds determine what it serves, and the coordinator's seeds only
//! drive its inline (dry-lease) deals.

use crate::protocol::server::NetworkPlan;
use crate::util::error::Result;
use crate::wire::codec::SessionManifest;
use crate::{bail, ensure};
use std::sync::Arc;
use std::time::Instant;

/// Exponentially-decayed lease counter: the traffic signal behind the
/// pool's adaptive refill weights.
///
/// Each [`Self::bump`] adds 1 to a score that decays continuously with
/// half-life `half_life` — so the score approximates "leases in the
/// last half-life or two", is cheap (one `Instant` + one `f64`), and
/// needs no ring buffers or tick threads. A model whose traffic stops
/// decays toward zero on its own; a traffic flip between two models
/// re-orders their scores within about one half-life, which is the
/// adaptation time constant the weight-shift test pins.
#[derive(Clone, Debug)]
pub struct LeaseRate {
    half_life: f64,
    score: f64,
    at: Instant,
}

impl LeaseRate {
    pub fn new(half_life: std::time::Duration) -> Self {
        Self { half_life: half_life.as_secs_f64().max(1e-6), score: 0.0, at: Instant::now() }
    }

    fn decayed(&self, now: Instant) -> f64 {
        let dt = now.duration_since(self.at).as_secs_f64();
        self.score * 0.5f64.powf(dt / self.half_life)
    }

    /// Record one lease at `now`.
    pub fn bump(&mut self, now: Instant) {
        self.score = self.decayed(now) + 1.0;
        self.at = now;
    }

    /// The decayed score as of `now` (no mutation).
    pub fn score(&self, now: Instant) -> f64 {
        self.decayed(now)
    }
}

/// Derive a model's dealing base seed from a root seed and the model's
/// manifest fingerprint (splitmix64-style mix). One fixed, documented
/// derivation so any party holding `(root_seed, plan)` lands on the same
/// per-model namespace; [`crate::coordinator::ModelConfig::base_seed`]
/// overrides it per model when explicit seeds are wanted (e.g. the
/// single-model wrapper, which pins the model seed to the service seed
/// to keep its dealt bytes identical to the pre-registry path).
pub fn model_base_seed(root_seed: u64, fingerprint: u64) -> u64 {
    let mut z = root_seed ^ fingerprint.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One registered model.
pub struct ModelEntry {
    /// Structural + weight identity (the registry key is
    /// `manifest.fingerprint`).
    pub manifest: SessionManifest,
    pub plan: Arc<NetworkPlan>,
    /// Base seed of this model's seq-addressed dealing namespace.
    pub base_seed: u64,
    /// Relative demand rate (refill-priority weight, `> 0`).
    pub demand: f64,
}

impl ModelEntry {
    pub fn fingerprint(&self) -> u64 {
        self.manifest.fingerprint
    }
}

/// Fingerprint-keyed set of served models, in registration order.
/// Registration order is load-bearing in one place: it is the pool's
/// shard order and the "default model" of the single-model convenience
/// APIs ([`crate::coordinator::PiService::submit`] and friends).
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a plan under its manifest fingerprint with an explicit
    /// dealing base seed. Returns the fingerprint (the model's key
    /// everywhere: wire frames, pool shards, request routing, metrics
    /// labels). Duplicate fingerprints are an error — one registry entry
    /// per model identity.
    pub fn register(
        &mut self,
        plan: Arc<NetworkPlan>,
        base_seed: u64,
        demand: f64,
    ) -> Result<u64> {
        let manifest = SessionManifest::of_plan(&plan);
        self.register_with(plan, manifest, base_seed, demand)
    }

    /// [`Self::register`] with a manifest the caller already computed
    /// (the weight digest probes every linear layer, so callers that
    /// need the fingerprint *before* registering — e.g. to derive the
    /// base seed — pass it back in instead of paying for it twice).
    pub fn register_with(
        &mut self,
        plan: Arc<NetworkPlan>,
        manifest: SessionManifest,
        base_seed: u64,
        demand: f64,
    ) -> Result<u64> {
        ensure!(!plan.linears.is_empty(), "cannot register an empty plan");
        ensure!(demand > 0.0, "demand weight must be positive, got {demand}");
        let fp = manifest.fingerprint;
        if self.get(fp).is_some() {
            bail!("fingerprint {fp:#018x} already registered");
        }
        self.entries.push(ModelEntry { manifest, plan, base_seed, demand });
        Ok(fp)
    }

    /// A one-model registry (the single-model wrappers' shape): the
    /// model's seq namespace is exactly `base_seed`, which preserves
    /// bit-identity of every dealt byte with the pre-registry
    /// single-model path for the same `(seed, plan)`.
    pub fn single(plan: Arc<NetworkPlan>, base_seed: u64) -> Arc<ModelRegistry> {
        let mut r = ModelRegistry::new();
        r.register(plan, base_seed, 1.0).expect("single-model registration");
        Arc::new(r)
    }

    pub fn get(&self, fingerprint: u64) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.fingerprint() == fingerprint)
    }

    /// Registration-order index of a fingerprint (the pool's shard
    /// index).
    pub fn index_of(&self, fingerprint: u64) -> Option<usize> {
        self.entries.iter().position(|e| e.fingerprint() == fingerprint)
    }

    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fingerprints in registration order.
    pub fn fingerprints(&self) -> Vec<u64> {
        self.entries.iter().map(|e| e.fingerprint()).collect()
    }

    /// The manifest set shipped in the wire handshake.
    pub fn manifests(&self) -> Vec<SessionManifest> {
        self.entries.iter().map(|e| e.manifest.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::util::Rng;

    fn plan(seed: u64, variant: ReluVariant) -> Arc<NetworkPlan> {
        let mut rng = Rng::new(seed);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, variant))
    }

    #[test]
    fn register_and_lookup() {
        let mut reg = ModelRegistry::new();
        let a = plan(1, ReluVariant::BaselineRelu);
        let b = plan(1, ReluVariant::NaiveSign);
        let fa = reg.register(a.clone(), 7, 1.0).unwrap();
        let fb = reg.register(b, 9, 2.0).unwrap();
        assert_ne!(fa, fb, "variant is part of the identity");
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.fingerprints(), vec![fa, fb]);
        assert_eq!(reg.index_of(fb), Some(1));
        let ea = reg.get(fa).unwrap();
        assert_eq!(ea.base_seed, 7);
        assert!(reg.get(fa ^ 1).is_none());
        // Same plan again: same fingerprint, rejected.
        assert!(reg.register(a, 8, 1.0).is_err());
    }

    #[test]
    fn same_shape_different_weights_are_distinct_models() {
        // The weight digest is part of the fingerprint: two structurally
        // equal plans with different weights register side by side.
        let mut reg = ModelRegistry::new();
        let fa = reg.register(plan(1, ReluVariant::BaselineRelu), 1, 1.0).unwrap();
        let fb = reg.register(plan(2, ReluVariant::BaselineRelu), 1, 1.0).unwrap();
        assert_ne!(fa, fb);
    }

    #[test]
    fn invalid_registrations_rejected() {
        let mut reg = ModelRegistry::new();
        assert!(reg.register(plan(1, ReluVariant::BaselineRelu), 1, 0.0).is_err());
        assert!(reg
            .register(
                Arc::new(NetworkPlan::unscaled(Vec::new(), ReluVariant::BaselineRelu)),
                1,
                1.0
            )
            .is_err());
    }

    #[test]
    fn lease_rate_accumulates_and_decays() {
        use std::time::Duration;
        let t0 = Instant::now();
        let mut r = LeaseRate::new(Duration::from_secs(10));
        assert_eq!(r.score(t0), 0.0);
        // Bumps accumulate (decay over zero elapsed time is a no-op).
        r.bump(t0);
        r.bump(t0);
        r.bump(t0);
        let s = r.score(t0);
        assert!((s - 3.0).abs() < 1e-9, "{s}");
        // One half-life later the score has halved; two, quartered.
        let s1 = r.score(t0 + Duration::from_secs(10));
        assert!((s1 - 1.5).abs() < 1e-6, "{s1}");
        let s2 = r.score(t0 + Duration::from_secs(20));
        assert!((s2 - 0.75).abs() < 1e-6, "{s2}");
        // A bump after decay starts from the decayed score.
        r.bump(t0 + Duration::from_secs(10));
        let s3 = r.score(t0 + Duration::from_secs(10));
        assert!((s3 - 2.5).abs() < 1e-6, "{s3}");
    }

    #[test]
    fn base_seed_derivation_is_stable_and_separating() {
        let s1 = model_base_seed(0xC1CA, 0x1111);
        let s2 = model_base_seed(0xC1CA, 0x2222);
        assert_eq!(s1, model_base_seed(0xC1CA, 0x1111), "deterministic");
        assert_ne!(s1, s2, "different models get different namespaces");
        assert_ne!(s1, model_base_seed(0xC1CB, 0x1111), "root seed matters");
    }
}

//! The PI serving coordinator — Circa as a deployable service.
//!
//! Private inference has an unusual serving profile: every inference
//! consumes single-use offline material (garbled circuits, OTs, Beaver
//! triples — paper footnote 2), so a production server must *bank*
//! material ahead of demand and spend it on the online path. Since the
//! layer-batch refactor, that material is flat SoA per layer
//! ([`crate::gc::batch`]): a banked session is a handful of contiguous
//! buffers per ReLU layer (one circuit template, one table buffer, one
//! label arena), which keeps dealer throughput allocation-light and makes
//! a session's byte footprint an exact sum of buffer lengths — the shape
//! wire serialization and cross-process session shipping need.
//!
//! The coordinator mirrors the vLLM-router shape adapted to that
//! constraint:
//!
//! * [`pool`] — the offline-material bank, sharded by layer: one bank of
//!   linear-precompute spines plus one bank per ReLU layer, each keyed
//!   by session sequence number; dealers refill the emptiest bank first
//!   and a lease assembles a session from the banks' seq-aligned fronts
//!   (bit-identical to a whole-session deal from the same session RNG).
//!   A dry lease deals inline and reports the measured deal latency
//!   ([`pool::Lease`]) so the shortfall lands in the latency histograms,
//!   not just a counter. Refills come from a [`pool::RefillSource`]:
//!   inline deal, or a standalone dealer process streaming layer batches
//!   over [`crate::wire`] (`ServiceConfig::dealer_addr`).
//! * [`batcher`] — groups incoming requests into dispatch batches
//!   (max-size / max-delay policy, the classic dynamic batcher).
//! * [`router`] — a worker pool running the 2-party online protocol for
//!   each leased session.
//! * [`metrics`] — latency histograms (online / queue / total /
//!   dry-deal), throughput counters, pool-dry counters.
//! * [`service`] — the assembled `PiService` front-end used by
//!   `examples/serve_pi.rs` and the `circa serve` CLI.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod router;
pub mod service;

pub use metrics::Metrics;
pub use pool::{Lease, MaterialPool, RefillSource};
pub use service::{PiService, ServiceConfig};

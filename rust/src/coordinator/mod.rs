//! The PI serving coordinator — Circa as a deployable **multi-model**
//! service.
//!
//! Private inference has an unusual serving profile: every inference
//! consumes single-use offline material (garbled circuits, OTs, Beaver
//! triples — paper footnote 2), so a production server must *bank*
//! material ahead of demand and spend it on the online path. And a
//! production fleet never serves one architecture: Circa's per-ReLU
//! savings compose with network-level ReLU reduction (CryptoNAS
//! ReLU-budget networks, DeepReDuce-style culled ResNets), so one
//! coordinator banks and serves material for several `NetworkPlan`s at
//! once. Model identity is a manifest **fingerprint**
//! ([`crate::wire::SessionManifest`] — variant, layer dims, rescale
//! schedule, and a behavioral weight digest), threaded through every
//! layer of the stack: the registry, the pool shards, the wire frames,
//! the request path, and the metrics labels.
//!
//! The coordinator mirrors the vLLM-router shape adapted to those
//! constraints:
//!
//! * [`registry`] — the [`ModelRegistry`]: fingerprint →
//!   plan + per-model dealing base seed (disjoint seq namespaces) +
//!   static demand weight (the cold-start refill prior; live refill
//!   weights come from the [`registry::LeaseRate`] EWMA). Shared by the
//!   pool, the service front-end, and the remote-dealer connectors.
//! * [`pool`] — the offline-material bank, sharded by **model and
//!   layer**: per registered model, one bank of linear-precompute
//!   spines plus one bank per ReLU layer, each keyed by session
//!   sequence number in that model's namespace; refill claims chase the
//!   emptiest `(model, layer)` bank first (deficits weighted by the
//!   lease-rate EWMA, demand priors before traffic exists) and a lease
//!   assembles a session from one shard's seq-aligned fronts
//!   (bit-identical to a whole-session deal from the same session
//!   RNG). Remote units are fingerprint-checked at staging — material
//!   for model B can never land in model A's shard. A dry lease deals
//!   inline and reports the measured deal latency ([`pool::Lease`]).
//!   Refills come from a [`pool::RefillSource`]: inline deal, or a
//!   **fleet** of standalone dealer processes
//!   ([`pool::DealerEndpoint`], `ServiceConfig::dealer_addrs`,
//!   optionally PSK-authenticated via [`crate::wire::auth`]) streaming
//!   model-addressed layer batches over [`crate::wire`]. Seq-addressed
//!   dealing purity lets the pool partition claims across links,
//!   work-steal stale claims onto idle links, and hand a failed link's
//!   claims off for re-issue — one claim ledger, exact accounting,
//!   bit-identical banks whichever link produced each piece
//!   ([`pool::PoolTuning`] holds the steal/EWMA knobs).
//! * [`batcher`] — groups incoming requests into dispatch batches
//!   (max-size / max-delay policy, validated at service start), split
//!   model-homogeneous ([`batcher::ModelBatch`]) so each batch leases
//!   from one shard — and, since the batched online phase, so each
//!   batch shares one circuit template.
//! * [`router`] — a worker pool executing each `ModelBatch` as **one
//!   batched walk**: R sessions leased from the model's shard, then a
//!   single [`crate::protocol::server::run_inference_multi`] whose GC
//!   evaluation strides across requests and whose Beaver rounds fuse
//!   into flat `R·n` passes, bit-identical per request to R independent
//!   `run_inference` calls (single-request batches fall back to the
//!   per-request path). `Request`/`Response` carry the model
//!   fingerprint.
//! * [`metrics`] — latency histograms (online / queue / total /
//!   dry-deal), throughput counters, pool-dry counters, batch-shape
//!   histograms (requests per dispatched batch, amortized per-request
//!   share of the batch wall), the live ingress-queue depth gauge and
//!   shed counters consumed by admission control, a **per-model row**
//!   (bank depths, refill counters, latency histograms, sheds, EWMA
//!   demand gauges) for every served plan, and a **per-link row**
//!   (fetches, bytes, failures, reconnects, steals, late drops) for
//!   every fleet link.
//! * [`service`] — the assembled `PiService` front-end:
//!   [`PiService::start_multi`] serves a list of plans;
//!   [`PiService::start`] is the single-plan thin wrapper (dealt bytes
//!   identical to the pre-registry path for the same seed). Intake is
//!   bounded and non-panicking: `submit_to` admits with `try_send`
//!   against `ServiceConfig::max_queue` (overload is an explicit
//!   [`service::SubmitError::QueueFull`], a stopped service an explicit
//!   [`service::SubmitError::Stopped`]) and returns a
//!   [`service::ResponseHandle`] with blocking *and* nonblocking
//!   completion — the latter is what the [`crate::net::reactor`] polls
//!   to multiplex thousands of in-flight inferences from one thread.
//!   Used by `examples/serve_pi.rs` (in-process or `--listen` network
//!   mode) and the `circa serve` CLI.
//!
//! The hot paths in [`pool`] and [`service`] hold shard mutexes; the
//! repo lint (`cargo run -p circa-lint -- check`, blocking in CI)
//! enforces that no blocking call — socket I/O, channel `recv`,
//! `sleep` — happens while a guard is live. The pattern to follow is
//! copy-out-then-drop; see `docs/INVARIANTS.md` for the rule statement.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod registry;
pub mod router;
pub mod service;

pub use metrics::{Metrics, ModelSnapshot};
pub use pool::{DealerEndpoint, Lease, MaterialPool, PoolTuning, RefillSource};
pub use registry::{model_base_seed, LeaseRate, ModelEntry, ModelRegistry};
pub use service::{ModelConfig, PiService, ResponseHandle, ServiceConfig, SubmitError};

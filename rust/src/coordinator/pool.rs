//! The offline-material bank.
//!
//! Each entry is a fully-prepared 2-party session (client + server nets:
//! masks, HE-precomputes, garbled circuits, OT'd labels, triples) for one
//! inference of a fixed network plan. Dealer threads refill toward
//! `target`; `lease()` pops a ready session or — if the bank is dry —
//! prepares one inline (counted, because it shows up as tail latency
//! exactly like a real deployment's offline-throughput shortfall).
//!
//! Refills come from a [`RefillSource`]: either the classic inline deal
//! (garble in-process) or a [`RemoteDealer`] — a separate dealer process
//! reached over [`crate::wire`], which is the paper's actual deployment
//! shape (offline material produced elsewhere, shipped to the server).
//! Remote refill latency and bytes-on-wire land in
//! [`super::metrics::Metrics`] next to the dry-deal histogram.

use super::metrics::Metrics;
use crate::protocol::client::ClientNet;
use crate::protocol::server::{offline_network_mt, NetworkPlan, ServerNet};
use crate::util::error::Result;
use crate::util::{Rng, Timer};
use crate::wire::dealer::RemoteDealer;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One ready-to-serve inference session.
pub struct Session {
    pub client: ClientNet,
    pub server: ServerNet,
    pub offline_bytes: u64,
}

impl Session {
    /// ReLUs of offline material in this session (the deal-throughput
    /// denominator).
    pub fn n_relus(&self) -> usize {
        self.server.n_relus()
    }
}

/// Outcome of [`MaterialPool::lease`]: the session plus where it came
/// from. A dry lease carries the inline-deal latency so the caller can
/// surface it as tail latency (the serving metrics record it).
pub struct Lease {
    pub session: Session,
    pub was_dry: bool,
    /// Microseconds spent dealing inline (0 for banked sessions).
    pub deal_us: u64,
}

struct Shared {
    queue: Mutex<VecDeque<Session>>,
    ready: Condvar,
    refill: Condvar,
    stop: AtomicBool,
    dry_leases: AtomicU64,
    produced: AtomicU64,
}

/// Where dealer threads get their sessions.
pub enum RefillSource {
    /// Deal sessions inline in local dealer threads (the default).
    Inline,
    /// Stream pre-dealt sessions from a remote dealer process. `connect`
    /// is called (and re-called after transport errors) to establish a
    /// [`RemoteDealer`]; `batch` caps sessions per round trip.
    Remote {
        connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
        batch: usize,
    },
}

/// Material bank with background dealer threads.
pub struct MaterialPool {
    plan: Arc<NetworkPlan>,
    shared: Arc<Shared>,
    target: usize,
    deal_threads: usize,
    dealers: Vec<JoinHandle<()>>,
}

impl MaterialPool {
    /// Spawn a pool refilling toward `target` with `n_dealers` inline
    /// dealer threads (the classic in-process deal, one thread per
    /// session).
    pub fn start(plan: Arc<NetworkPlan>, target: usize, n_dealers: usize, seed: u64) -> Self {
        Self::start_with_source(plan, target, n_dealers, seed, RefillSource::Inline, None, 1)
    }

    /// Spawn a pool with an explicit [`RefillSource`]. When `metrics` is
    /// given, remote refills record their latency and bytes-on-wire, and
    /// inline deals record their ReLU throughput. `deal_threads` splits
    /// each inline (and dry-lease) deal's garble columns across threads —
    /// the column-wise RNG schedule keeps the material bit-identical for
    /// every value.
    pub fn start_with_source(
        plan: Arc<NetworkPlan>,
        target: usize,
        n_dealers: usize,
        seed: u64,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        let deal_threads = deal_threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            refill: Condvar::new(),
            stop: AtomicBool::new(false),
            dry_leases: AtomicU64::new(0),
            produced: AtomicU64::new(0),
        });
        let mut dealers = Vec::new();
        for d in 0..n_dealers.max(1) {
            let shared = shared.clone();
            let plan = plan.clone();
            let metrics = metrics.clone();
            let mut rng = Rng::new(seed ^ (d as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let remote = match &source {
                RefillSource::Inline => None,
                RefillSource::Remote { connect, batch } => {
                    Some((connect.clone(), (*batch).max(1)))
                }
            };
            dealers.push(std::thread::spawn(move || {
                let mut conn: Option<RemoteDealer> = None;
                // Connect + fetch failures share one counter, reset only
                // on a successful fetch — a dealer that handshakes but
                // fails every fetch still gets surfaced.
                let mut failures = 0u64;
                loop {
                    // Wait until below target (or stopping).
                    {
                        let mut q = shared.queue.lock().unwrap();
                        while q.len() >= target && !shared.stop.load(Ordering::Relaxed) {
                            q = shared.refill.wait(q).unwrap();
                        }
                    }
                    if shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    match &remote {
                        None => {
                            // Produce outside the lock (garbling is slow);
                            // the deal itself fans out over deal_threads.
                            let t = Timer::new();
                            let (client, server, offline_bytes) =
                                offline_network_mt(&plan, &mut rng, deal_threads);
                            let session = Session { client, server, offline_bytes };
                            if let Some(m) = &metrics {
                                m.record_deal(session.n_relus() as u64, t.elapsed_us());
                            }
                            shared.produced.fetch_add(1, Ordering::Relaxed);
                            let mut q = shared.queue.lock().unwrap();
                            q.push_back(session);
                            shared.ready.notify_one();
                        }
                        Some((connect, batch)) => {
                            if conn.is_none() {
                                match connect() {
                                    Ok(d) => conn = Some(d),
                                    Err(e) => {
                                        // Surface the failure (throttled):
                                        // a dead/mismatched dealer would
                                        // otherwise hang warmup silently.
                                        failures += 1;
                                        if failures.is_power_of_two() {
                                            eprintln!(
                                                "[pool d{d}] dealer connect failed \
                                                 ({failures}x): {e}"
                                            );
                                        }
                                        std::thread::sleep(Duration::from_millis(50));
                                        continue;
                                    }
                                }
                            }
                            // Fetch only the current deficit (racy but
                            // bounded: worst-case overshoot is one batch
                            // per dealer thread).
                            let deficit =
                                target.saturating_sub(shared.queue.lock().unwrap().len());
                            let want = (*batch).min(deficit.max(1));
                            let (fetched, fetch_us, wire_bytes) = {
                                let dealer = conn.as_mut().unwrap();
                                let before = dealer.bytes_received();
                                let t = Timer::new();
                                let res = dealer.fetch(want);
                                (res, t.elapsed_us(), dealer.bytes_received() - before)
                            };
                            match fetched {
                                Ok(sessions) => {
                                    failures = 0;
                                    if let Some(m) = &metrics {
                                        m.record_remote_refill(
                                            fetch_us,
                                            wire_bytes,
                                            sessions.len() as u64,
                                        );
                                    }
                                    shared
                                        .produced
                                        .fetch_add(sessions.len() as u64, Ordering::Relaxed);
                                    let mut q = shared.queue.lock().unwrap();
                                    q.extend(sessions);
                                    shared.ready.notify_all();
                                }
                                Err(e) => {
                                    // Transport hiccup: surface it
                                    // (throttled), drop the link, and
                                    // reconnect on the next round.
                                    failures += 1;
                                    if failures.is_power_of_two() {
                                        eprintln!(
                                            "[pool d{d}] dealer fetch failed \
                                             ({failures}x): {e}"
                                        );
                                    }
                                    conn = None;
                                    std::thread::sleep(Duration::from_millis(50));
                                }
                            }
                        }
                    }
                }
            }));
        }
        Self { plan, shared, target, deal_threads, dealers }
    }

    /// Lease a session: pop a banked one, or deal inline when dry. The
    /// dry path measures the inline deal so callers can record it into
    /// the serving [`super::Metrics`] — pool-dry tail latency is exactly
    /// what a deployment's offline-throughput shortfall looks like.
    pub fn lease(&self, rng: &mut Rng) -> Lease {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if let Some(s) = q.pop_front() {
                self.shared.refill.notify_all();
                return Lease { session: s, was_dry: false, deal_us: 0 };
            }
        }
        // Dry: prepare inline, and time it.
        self.shared.dry_leases.fetch_add(1, Ordering::Relaxed);
        let t = Timer::new();
        let (client, server, offline_bytes) =
            offline_network_mt(&self.plan, rng, self.deal_threads);
        Lease {
            session: Session { client, server, offline_bytes },
            was_dry: true,
            deal_us: t.elapsed_us(),
        }
    }

    /// Block until at least `n` sessions are banked (warmup).
    pub fn wait_ready(&self, n: usize) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.len() < n.min(self.target) {
            q = self.shared.ready.wait(q).unwrap();
        }
    }

    pub fn banked(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn dry_leases(&self) -> u64 {
        self.shared.dry_leases.load(Ordering::Relaxed)
    }

    pub fn produced(&self) -> u64 {
        self.shared.produced.load(Ordering::Relaxed)
    }

    /// Stop dealers and drain.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.refill.notify_all();
        for d in self.dealers.drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};

    fn tiny_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    #[test]
    fn pool_fills_and_leases() {
        let pool = MaterialPool::start(tiny_plan(), 4, 2, 7);
        pool.wait_ready(4);
        assert!(pool.banked() >= 4);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert_eq!(lease.deal_us, 0);
        assert!(lease.session.offline_bytes > 0);
        pool.shutdown();
    }

    #[test]
    fn dry_lease_still_serves() {
        // Zero-target pool: every lease is dry but must still work.
        let pool = MaterialPool::start(tiny_plan(), 0, 1, 8);
        let mut rng = Rng::new(3);
        let lease = pool.lease(&mut rng);
        assert!(lease.was_dry);
        assert!(lease.deal_us > 0, "inline deal latency must be measured");
        assert_eq!(pool.dry_leases(), 1);
        pool.shutdown();
    }

    #[test]
    fn remote_refill_source_fills_bank() {
        // The deployment shape: material produced by a dealer "process"
        // (in-memory channel here), streamed in over the wire codec, and
        // banked like any inline deal — with latency/bytes recorded.
        let plan = tiny_plan();
        let metrics = Arc::new(Metrics::default());
        let plan_c = plan.clone();
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> = Arc::new(move || {
            let (chan, _dealer_thread) =
                crate::wire::dealer::spawn_mem_dealer(plan_c.clone(), 77, 1);
            RemoteDealer::connect(chan, plan_c.clone())
        });
        let pool = MaterialPool::start_with_source(
            plan,
            3,
            1,
            7,
            RefillSource::Remote { connect, batch: 2 },
            Some(metrics.clone()),
            1,
        );
        pool.wait_ready(3);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert!(lease.session.offline_bytes > 0);
        assert!(pool.produced() >= 3);
        let snap = metrics.snapshot();
        assert!(snap.remote_refills >= 1, "refill rounds recorded");
        assert!(snap.remote_sessions >= 3, "sessions recorded");
        assert!(snap.bytes_offline_wire > 0, "wire bytes recorded");
        assert!(snap.remote_refill_mean_us > 0.0, "fetch latency recorded");
        pool.shutdown();
    }

    #[test]
    fn inline_deals_record_throughput() {
        // tiny_plan has one ReLU layer of 4 → 4 ReLUs per session.
        let metrics = Arc::new(Metrics::default());
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            3,
            2,
            11,
            RefillSource::Inline,
            Some(metrics.clone()),
            2,
        );
        pool.wait_ready(3);
        let snap = metrics.snapshot();
        assert!(snap.deal_relus >= 12, "relus recorded: {}", snap.deal_relus);
        assert!(snap.deal_relus_per_s > 0.0, "throughput recorded");
        pool.shutdown();
    }

    #[test]
    fn refill_after_lease() {
        let pool = MaterialPool::start(tiny_plan(), 2, 1, 9);
        pool.wait_ready(2);
        let mut rng = Rng::new(4);
        let _ = pool.lease(&mut rng);
        // Dealer should replenish toward the target.
        pool.wait_ready(2);
        assert!(pool.banked() >= 1);
        assert!(pool.produced() >= 3);
        pool.shutdown();
    }
}

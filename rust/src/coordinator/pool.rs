//! The offline-material bank, sharded by layer.
//!
//! Real PI networks concentrate their ReLUs in a few wide layers
//! (CryptoNAS/DeepReShape-style budgets), so whole-session dealing
//! wastes dealer throughput on cold layers while the hot layers gate
//! session assembly. The bank therefore holds *per-layer* material: one
//! bank of linear-precompute spines ([`LinearSpine`] — masks, HE
//! precomputes, blinds; cheap) plus one bank per ReLU layer (garbled
//! tables, label arenas, triples; the expensive part), each keyed by a
//! session **sequence number**. Dealers refill the emptiest bank first,
//! and [`MaterialPool::lease`] assembles a [`Session`] from the front
//! entry of every bank.
//!
//! Seq-addressing is what makes the shards composable: entry `(bank,
//! seq)` is a pure function of `(base seed, seq, layer)` under the
//! per-layer forked session schedule
//! ([`crate::protocol::server::session_rng`]), so independently dealt
//! entries with equal seqs assemble into exactly the session a whole
//! inline deal from that session RNG would produce — bit-identical,
//! whichever dealer thread or connection produced each piece. Leases pop
//! every bank's front at once, so the fronts stay seq-aligned
//! structurally.
//!
//! Refills come from a [`RefillSource`]: the inline deal (garble
//! in-process) or a remote dealer process reached over [`crate::wire`]'s
//! layer-granular streaming round — the paper's deployment shape, with
//! the largest frame bounded by the largest single layer batch. Claim
//! accounting is exact: a bank's staged + in-flight entries never exceed
//! `target`, so racing dealer threads cannot overshoot the bank (the
//! old whole-session pool could bank up to `target + n_dealers − 1`).
//! Failed claims are abandoned back into a retry list, and
//! [`MaterialPool::wait_ready`] is stop-aware, so a dealer that never
//! connects cannot hang warmup or shutdown forever.

use super::metrics::Metrics;
use crate::protocol::client::ClientNet;
use crate::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::protocol::server::{
    assemble_session, deal_relu_layer_mt, deal_spine, offline_network_mt, session_rng,
    LinearSpine, NetworkPlan, ServerNet,
};
use crate::util::error::Result;
use crate::util::{Rng, Timer};
use crate::wire::dealer::RemoteDealer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One ready-to-serve inference session.
pub struct Session {
    pub client: ClientNet,
    pub server: ServerNet,
    pub offline_bytes: u64,
}

impl Session {
    /// ReLUs of offline material in this session (the deal-throughput
    /// denominator).
    pub fn n_relus(&self) -> usize {
        self.server.n_relus()
    }
}

/// Outcome of [`MaterialPool::lease`]: the session plus where it came
/// from. A dry lease carries the inline-deal latency so the caller can
/// surface it as tail latency (the serving metrics record it).
pub struct Lease {
    pub session: Session,
    pub was_dry: bool,
    /// Microseconds spent dealing inline (0 for banked sessions).
    pub deal_us: u64,
}

type ReluEntry = (ClientReluMaterial, ServerReluMaterial);

/// Count keys `head, head+1, …` present in `m` (the bank's ready run).
fn contiguous_from<V>(m: &BTreeMap<u64, V>, head: u64) -> usize {
    let mut n = 0u64;
    for (&k, _) in m.range(head..) {
        if k != head + n {
            break;
        }
        n += 1;
    }
    n as usize
}

/// The sharded bank. Bank index 0 holds linear spines; bank `1 + li`
/// holds ReLU layer `li`. Entries are staged in `BTreeMap`s keyed by
/// seq because completions can land out of order (racing dealers,
/// retried claims); contiguity from `head` is what counts as ready.
struct Bank {
    /// Seq of the next session [`MaterialPool::lease`] will assemble.
    head: u64,
    spines: BTreeMap<u64, LinearSpine>,
    relus: Vec<BTreeMap<u64, ReluEntry>>,
    /// Next fresh seq each bank hands out to a dealer claim.
    next_claim: Vec<u64>,
    /// Claims handed out but not yet completed or abandoned.
    in_flight: Vec<usize>,
    /// Abandoned claims, re-dealt before fresh seqs are claimed.
    retries: Vec<Vec<u64>>,
}

impl Bank {
    fn new(n_relu: usize) -> Self {
        Bank {
            head: 0,
            spines: BTreeMap::new(),
            relus: (0..n_relu).map(|_| BTreeMap::new()).collect(),
            next_claim: vec![0; 1 + n_relu],
            in_flight: vec![0; 1 + n_relu],
            retries: (0..n_relu + 1).map(|_| Vec::new()).collect(),
        }
    }

    fn n_banks(&self) -> usize {
        1 + self.relus.len()
    }

    fn staged(&self, b: usize) -> usize {
        if b == 0 {
            self.spines.len()
        } else {
            self.relus[b - 1].len()
        }
    }

    /// Entries committed against `target`: staged plus in-flight claims
    /// (abandoned retries are uncommitted — they need re-dealing).
    fn supply(&self, b: usize) -> usize {
        self.staged(b) + self.in_flight[b]
    }

    /// Claim up to `max` seqs from the bank with the largest deficit
    /// (the emptiest bank), retries first. `None` when every bank is at
    /// target — claim accounting is what makes overshoot impossible.
    fn claim_emptiest(&mut self, target: usize, max: usize) -> Option<(usize, Vec<u64>)> {
        let (mut best, mut best_deficit) = (0usize, 0usize);
        for b in 0..self.n_banks() {
            let deficit = target.saturating_sub(self.supply(b));
            if deficit > best_deficit {
                best = b;
                best_deficit = deficit;
            }
        }
        if best_deficit == 0 {
            return None;
        }
        let n = best_deficit.min(max.max(1));
        let seqs = (0..n)
            .map(|_| {
                self.in_flight[best] += 1;
                self.retries[best].pop().unwrap_or_else(|| {
                    let s = self.next_claim[best];
                    self.next_claim[best] += 1;
                    s
                })
            })
            .collect();
        Some((best, seqs))
    }

    fn abandon(&mut self, b: usize, seqs: &[u64]) {
        self.in_flight[b] -= seqs.len();
        self.retries[b].extend_from_slice(seqs);
    }

    fn complete_spine(&mut self, seq: u64, spine: LinearSpine) {
        self.in_flight[0] -= 1;
        self.spines.insert(seq, spine);
    }

    fn complete_relu(&mut self, li: usize, seq: u64, entry: ReluEntry) {
        self.in_flight[1 + li] -= 1;
        self.relus[li].insert(seq, entry);
    }

    /// Sessions assemblable right now: the shortest contiguous run from
    /// `head` across all banks.
    fn ready_run(&self) -> usize {
        let mut run = contiguous_from(&self.spines, self.head);
        for m in &self.relus {
            run = run.min(contiguous_from(m, self.head));
        }
        run
    }

    /// Pop the front entry of every bank (requires `ready_run() >= 1`).
    /// Popping all banks at once is what keeps the fronts seq-aligned.
    fn pop_head(&mut self) -> (LinearSpine, Vec<ReluEntry>) {
        let head = self.head;
        let spine = self.spines.remove(&head).expect("ready head spine");
        let relus: Vec<ReluEntry> = self
            .relus
            .iter_mut()
            .map(|m| m.remove(&head).expect("ready head layer"))
            .collect();
        self.head += 1;
        (spine, relus)
    }

    fn depths(&self) -> Vec<usize> {
        (0..self.n_banks()).map(|b| self.staged(b)).collect()
    }
}

struct Shared {
    bank: Mutex<Bank>,
    ready: Condvar,
    refill: Condvar,
    stop: AtomicBool,
    dry_leases: AtomicU64,
    /// High-water mark of `head + ready_run()` — sessions ever made
    /// assemblable from the banks.
    produced: AtomicU64,
}

/// Update the produced high-water mark and the metrics depth gauge after
/// completions land (caller holds the bank lock).
fn publish_progress(shared: &Shared, bank: &Bank, metrics: &Option<Arc<Metrics>>) {
    let high_water = bank.head + bank.ready_run() as u64;
    shared.produced.fetch_max(high_water, Ordering::Relaxed);
    if let Some(m) = metrics {
        m.set_bank_depths(bank.depths().iter().map(|&d| d as u64).collect());
    }
}

/// Cross-check that every ReLU layer's `r_out` chain binds to the
/// spine's mask chain (`truncate(r_out[li]) == spine.slots[li+1].r`).
/// Seq-aligned pops make mixed-seq assembly structurally impossible
/// *within* one pool, but a remote dealer restarted with a different
/// base seed mid-stream would fill later claims from a different RNG
/// universe — this O(#ReLU) check catches that before a silently-wrong
/// session is served.
fn spine_binds_layers(plan: &NetworkPlan, spine: &LinearSpine, relus: &[ReluEntry]) -> bool {
    for (li, (cm, _)) in relus.iter().enumerate() {
        let rescale = plan.rescale_of(li);
        let want = &spine.slots[li + 1].r;
        if cm.r_out.len() != want.len() {
            return false;
        }
        let bound = cm
            .r_out
            .iter()
            .zip(want.iter())
            .all(|(&y, &m)| crate::nn::layers::truncate_share_local(y, rescale, true) == m);
        if !bound {
            return false;
        }
    }
    true
}

/// Where dealer threads get their material.
pub enum RefillSource {
    /// Deal layer entries inline in local dealer threads (the default).
    Inline,
    /// Stream per-layer material from a remote dealer process over the
    /// layer-granular wire round. `connect` is called (and re-called
    /// after transport errors) to establish a [`RemoteDealer`]; `batch`
    /// caps entries per round trip. All connections must reach dealers
    /// sharing one base seed — seq-addressing makes their answers
    /// mutually consistent.
    Remote {
        connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
        batch: usize,
    },
}

enum Fetched {
    Spines(Vec<(u64, LinearSpine)>),
    Layers(Vec<(u64, ClientReluMaterial, ServerReluMaterial)>),
}

/// Material bank with background dealer threads.
pub struct MaterialPool {
    plan: Arc<NetworkPlan>,
    shared: Arc<Shared>,
    target: usize,
    deal_threads: usize,
    metrics: Option<Arc<Metrics>>,
    dealers: Vec<JoinHandle<()>>,
}

impl MaterialPool {
    /// Spawn a pool refilling every bank toward `target` with
    /// `n_dealers` inline dealer threads.
    pub fn start(plan: Arc<NetworkPlan>, target: usize, n_dealers: usize, seed: u64) -> Self {
        Self::start_with_source(plan, target, n_dealers, seed, RefillSource::Inline, None, 1)
    }

    /// Spawn a pool with an explicit [`RefillSource`]. When `metrics` is
    /// given, remote refills record their latency and bytes-on-wire,
    /// inline deals record their ReLU throughput, and the per-bank depth
    /// gauge is published. `deal_threads` splits each inline (and
    /// dry-lease) deal's garble columns across threads — the column-wise
    /// RNG schedule keeps the material bit-identical for every value.
    pub fn start_with_source(
        plan: Arc<NetworkPlan>,
        target: usize,
        n_dealers: usize,
        seed: u64,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        let deal_threads = deal_threads.max(1);
        let shared = Arc::new(Shared {
            bank: Mutex::new(Bank::new(plan.n_relu_layers())),
            ready: Condvar::new(),
            refill: Condvar::new(),
            stop: AtomicBool::new(false),
            dry_leases: AtomicU64::new(0),
            produced: AtomicU64::new(0),
        });
        let mut dealers = Vec::new();
        for d in 0..n_dealers.max(1) {
            let shared = shared.clone();
            let plan = plan.clone();
            let metrics = metrics.clone();
            let remote = match &source {
                RefillSource::Inline => None,
                RefillSource::Remote { connect, batch } => {
                    Some((connect.clone(), (*batch).max(1)))
                }
            };
            dealers.push(std::thread::spawn(move || {
                let mut conn: Option<RemoteDealer> = None;
                // Connect + fetch failures share one counter, reset only
                // on a successful fetch — a dealer that handshakes but
                // fails every fetch still gets surfaced.
                let mut failures = 0u64;
                let claim_max = remote.as_ref().map_or(1, |(_, batch)| *batch);
                loop {
                    // Claim work from the emptiest bank (waiting while
                    // all banks are at target).
                    let (bank_idx, seqs) = {
                        let mut bank = shared.bank.lock().unwrap();
                        loop {
                            if shared.stop.load(Ordering::Relaxed) {
                                return;
                            }
                            match bank.claim_emptiest(target, claim_max) {
                                Some(claim) => break claim,
                                None => bank = shared.refill.wait(bank).unwrap(),
                            }
                        }
                    };
                    match &remote {
                        None => {
                            // Inline: deal the claimed entry outside the
                            // lock (garbling is slow); the deal itself
                            // fans out over deal_threads.
                            let seq = seqs[0];
                            if bank_idx == 0 {
                                let spine = deal_spine(&plan, &mut session_rng(seed, seq));
                                let mut bank = shared.bank.lock().unwrap();
                                bank.complete_spine(seq, spine);
                                publish_progress(&shared, &bank, &metrics);
                            } else {
                                let li = bank_idx - 1;
                                let t = Timer::new();
                                let (cm, sm) = deal_relu_layer_mt(
                                    &plan,
                                    &mut session_rng(seed, seq),
                                    li,
                                    deal_threads,
                                );
                                if let Some(m) = &metrics {
                                    m.record_deal(cm.n() as u64, t.elapsed_us());
                                }
                                let mut bank = shared.bank.lock().unwrap();
                                bank.complete_relu(li, seq, (cm, sm));
                                publish_progress(&shared, &bank, &metrics);
                            }
                            shared.ready.notify_all();
                        }
                        Some((connect, _)) => {
                            if conn.is_none() {
                                match connect() {
                                    Ok(dealer) => conn = Some(dealer),
                                    Err(e) => {
                                        // Surface the failure (throttled):
                                        // a dead/mismatched dealer would
                                        // otherwise starve the banks
                                        // silently.
                                        failures += 1;
                                        if failures.is_power_of_two() {
                                            eprintln!(
                                                "[pool d{d}] dealer connect failed \
                                                 ({failures}x): {e}"
                                            );
                                        }
                                        let mut bank = shared.bank.lock().unwrap();
                                        bank.abandon(bank_idx, &seqs);
                                        drop(bank);
                                        std::thread::sleep(Duration::from_millis(50));
                                        continue;
                                    }
                                }
                            }
                            let dealer = conn.as_mut().unwrap();
                            let before = dealer.bytes_received();
                            let t = Timer::new();
                            let fetched: Result<Fetched> = if bank_idx == 0 {
                                dealer.fetch_spines(&seqs).map(Fetched::Spines)
                            } else {
                                dealer.fetch_layers(bank_idx - 1, &seqs).map(Fetched::Layers)
                            };
                            let fetch_us = t.elapsed_us();
                            let wire_bytes = dealer.bytes_received() - before;
                            match fetched {
                                Ok(units) => {
                                    failures = 0;
                                    let n_units = seqs.len() as u64;
                                    let n_spines = if bank_idx == 0 { n_units } else { 0 };
                                    if let Some(m) = &metrics {
                                        m.record_layer_refill(
                                            fetch_us.max(1),
                                            wire_bytes,
                                            n_units,
                                            n_spines,
                                        );
                                    }
                                    let mut bank = shared.bank.lock().unwrap();
                                    match units {
                                        Fetched::Spines(v) => {
                                            for (seq, spine) in v {
                                                bank.complete_spine(seq, spine);
                                            }
                                        }
                                        Fetched::Layers(v) => {
                                            for (seq, cm, sm) in v {
                                                bank.complete_relu(
                                                    bank_idx - 1,
                                                    seq,
                                                    (cm, sm),
                                                );
                                            }
                                        }
                                    }
                                    publish_progress(&shared, &bank, &metrics);
                                    drop(bank);
                                    shared.ready.notify_all();
                                }
                                Err(e) => {
                                    // Transport hiccup: surface it
                                    // (throttled), put the claims back,
                                    // drop the link, reconnect next
                                    // round.
                                    failures += 1;
                                    if failures.is_power_of_two() {
                                        eprintln!(
                                            "[pool d{d}] layer fetch failed \
                                             ({failures}x): {e}"
                                        );
                                    }
                                    let mut bank = shared.bank.lock().unwrap();
                                    bank.abandon(bank_idx, &seqs);
                                    drop(bank);
                                    conn = None;
                                    std::thread::sleep(Duration::from_millis(50));
                                }
                            }
                        }
                    }
                }
            }));
        }
        Self { plan, shared, target, deal_threads, metrics, dealers }
    }

    /// Lease a session: assemble one from the banks' front entries, or
    /// deal inline when no full session is ready. The dry path measures
    /// the inline deal so callers can record it into the serving
    /// [`super::Metrics`] — pool-dry tail latency is exactly what a
    /// deployment's offline-throughput shortfall looks like.
    pub fn lease(&self, rng: &mut Rng) -> Lease {
        let popped = {
            let mut bank = self.shared.bank.lock().unwrap();
            if bank.ready_run() >= 1 {
                let entry = bank.pop_head();
                // Keep the depth gauge honest while leases drain the
                // banks (the produced high-water update inside is a
                // monotone no-op on pops).
                publish_progress(&self.shared, &bank, &self.metrics);
                Some(entry)
            } else {
                None
            }
        };
        if let Some((spine, relus)) = popped {
            self.shared.refill.notify_all();
            if spine_binds_layers(&self.plan, &spine, &relus) {
                let (client, server, offline_bytes) =
                    assemble_session(&self.plan, spine, relus);
                return Lease {
                    session: Session { client, server, offline_bytes },
                    was_dry: false,
                    deal_us: 0,
                };
            }
            // Mixed-universe material (e.g. a remote dealer restarted
            // with a different base seed mid-stream): refuse to serve
            // it, surface loudly, and fall through to a dry deal.
            eprintln!(
                "[pool] discarding banked session: layer material does not bind to its \
                 spine (dealer base seed changed mid-stream?)"
            );
        }
        // Dry: prepare inline, and time it.
        self.shared.dry_leases.fetch_add(1, Ordering::Relaxed);
        let t = Timer::new();
        let (client, server, offline_bytes) =
            offline_network_mt(&self.plan, rng, self.deal_threads);
        Lease {
            session: Session { client, server, offline_bytes },
            was_dry: true,
            deal_us: t.elapsed_us(),
        }
    }

    /// Block until at least `n` full sessions are assemblable (warmup).
    /// Stop-aware: returns early once [`Self::stop`]/[`Self::shutdown`]
    /// is called, so a dealer that never connects cannot hang warmup
    /// forever.
    pub fn wait_ready(&self, n: usize) {
        let want = n.min(self.target);
        let mut bank = self.shared.bank.lock().unwrap();
        while bank.ready_run() < want && !self.shared.stop.load(Ordering::Relaxed) {
            bank = self.shared.ready.wait(bank).unwrap();
        }
    }

    /// Full sessions assemblable right now.
    pub fn banked(&self) -> usize {
        self.shared.bank.lock().unwrap().ready_run()
    }

    /// Staged entries per bank (index 0 = linear spines, `1 + li` =
    /// ReLU layer `li`).
    pub fn bank_depths(&self) -> Vec<usize> {
        self.shared.bank.lock().unwrap().depths()
    }

    pub fn dry_leases(&self) -> u64 {
        self.shared.dry_leases.load(Ordering::Relaxed)
    }

    /// Sessions ever made assemblable from the banks (high-water mark).
    pub fn produced(&self) -> u64 {
        self.shared.produced.load(Ordering::Relaxed)
    }

    /// Signal dealers and waiters to stop, without joining. The lock is
    /// held across the notify so a waiter between its predicate check
    /// and its wait cannot miss the wake-up.
    pub fn stop(&self) {
        let _bank = self.shared.bank.lock().unwrap();
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.refill.notify_all();
        self.shared.ready.notify_all();
    }

    /// Stop dealers and drain.
    pub fn shutdown(mut self) {
        self.stop();
        for d in self.dealers.drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};

    fn tiny_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    #[test]
    fn pool_fills_and_leases() {
        let pool = MaterialPool::start(tiny_plan(), 4, 2, 7);
        pool.wait_ready(4);
        assert!(pool.banked() >= 4);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert_eq!(lease.deal_us, 0);
        assert!(lease.session.offline_bytes > 0);
        pool.shutdown();
    }

    #[test]
    fn dry_lease_still_serves() {
        // Zero-target pool: every lease is dry but must still work.
        let pool = MaterialPool::start(tiny_plan(), 0, 1, 8);
        let mut rng = Rng::new(3);
        let lease = pool.lease(&mut rng);
        assert!(lease.was_dry);
        assert!(lease.deal_us > 0, "inline deal latency must be measured");
        assert_eq!(pool.dry_leases(), 1);
        pool.shutdown();
    }

    #[test]
    fn assembled_sessions_match_whole_session_deal() {
        // The sharding acceptance property, inline edition: a session
        // assembled from per-layer bank entries is bit-identical to a
        // whole-session deal from the same session RNG — identical
        // inference transcripts, not merely correct ones.
        use crate::protocol::server::run_inference;
        let plan = tiny_plan();
        let seed = 0x5EED;
        let pool = MaterialPool::start(plan.clone(), 3, 2, seed);
        pool.wait_ready(3);
        let mut rng = Rng::new(9);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(900 + i)).collect();
        for seq in 0..3u64 {
            let lease = pool.lease(&mut rng);
            assert!(!lease.was_dry);
            let (client, server, offline_bytes) =
                offline_network_mt(&plan, &mut session_rng(seed, seq), 1);
            assert_eq!(lease.session.offline_bytes, offline_bytes, "seq {seq}");
            let (bank_logits, _) =
                run_inference(&lease.session.client, &lease.session.server, &input);
            let (inline_logits, _) = run_inference(&client, &server, &input);
            assert_eq!(bank_logits, inline_logits, "seq {seq}");
        }
        pool.shutdown();
    }

    #[test]
    fn spine_binding_check_catches_mixed_seed_material() {
        // Same-seed pieces bind; pieces from a dealer restarted with a
        // different base seed must be detected before assembly.
        let plan = tiny_plan();
        let spine_a = deal_spine(&plan, &mut session_rng(1, 0));
        let layers_a: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(1, 0), li, 1))
            .collect();
        assert!(spine_binds_layers(&plan, &spine_a, &layers_a));
        let layers_b: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(2, 0), li, 1))
            .collect();
        assert!(!spine_binds_layers(&plan, &spine_a, &layers_b));
    }

    #[test]
    fn banks_never_overshoot_target() {
        // Claim accounting bounds every bank at exactly `target` even
        // with many racing dealers (the old pool could overshoot to
        // target + n_dealers − 1).
        let pool = MaterialPool::start(tiny_plan(), 3, 4, 11);
        let mut rng = Rng::new(4);
        for _ in 0..3 {
            pool.wait_ready(3);
            assert_eq!(pool.banked(), 3);
            for (b, depth) in pool.bank_depths().into_iter().enumerate() {
                assert!(depth <= 3, "bank {b} overshot: {depth}");
            }
            let _ = pool.lease(&mut rng);
        }
        pool.shutdown();
    }

    #[test]
    fn wait_ready_returns_on_stop_with_dead_dealer() {
        // A remote source that never connects must not hang warmup: once
        // stop() is called, wait_ready returns instead of waiting on the
        // ready condvar forever.
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> =
            Arc::new(|| Err(crate::util::error::Error::msg("dealer unreachable")));
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            2,
            1,
            5,
            RefillSource::Remote { connect, batch: 2 },
            None,
            1,
        );
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| pool.wait_ready(1));
            std::thread::sleep(Duration::from_millis(100));
            pool.stop();
            waiter.join().expect("wait_ready returned after stop");
        });
        assert_eq!(pool.banked(), 0);
        pool.shutdown();
    }

    #[test]
    fn remote_refill_source_fills_bank() {
        // The deployment shape: material produced by a dealer "process"
        // (in-memory channel here), streamed in layer-granularly over
        // the wire codec, and banked per layer — with latency/bytes and
        // bank depths recorded.
        let plan = tiny_plan();
        let metrics = Arc::new(Metrics::default());
        let plan_c = plan.clone();
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> = Arc::new(move || {
            let (chan, _dealer_thread) =
                crate::wire::dealer::spawn_mem_dealer(plan_c.clone(), 77, 1);
            RemoteDealer::connect(chan, plan_c.clone())
        });
        let pool = MaterialPool::start_with_source(
            plan,
            3,
            1,
            7,
            RefillSource::Remote { connect, batch: 2 },
            Some(metrics.clone()),
            1,
        );
        pool.wait_ready(3);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert!(lease.session.offline_bytes > 0);
        assert!(pool.produced() >= 3);
        let snap = metrics.snapshot();
        assert!(snap.remote_refills >= 1, "refill rounds recorded");
        assert!(snap.remote_sessions >= 3, "sessions' worth (spines) recorded");
        assert!(snap.layer_entries >= 6, "per-layer units recorded");
        assert!(snap.bytes_offline_wire > 0, "wire bytes recorded");
        assert!(snap.remote_refill_mean_us > 0.0, "fetch latency recorded");
        assert_eq!(snap.bank_depths.len(), 2, "spine bank + one relu bank");
        pool.shutdown();
    }

    #[test]
    fn inline_deals_record_throughput() {
        // tiny_plan has one ReLU layer of 4 → 4 ReLUs per session.
        let metrics = Arc::new(Metrics::default());
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            3,
            2,
            11,
            RefillSource::Inline,
            Some(metrics.clone()),
            2,
        );
        pool.wait_ready(3);
        let snap = metrics.snapshot();
        assert!(snap.deal_relus >= 12, "relus recorded: {}", snap.deal_relus);
        assert!(snap.deal_relus_per_s > 0.0, "throughput recorded");
        pool.shutdown();
    }

    #[test]
    fn refill_after_lease() {
        let pool = MaterialPool::start(tiny_plan(), 2, 1, 9);
        pool.wait_ready(2);
        let mut rng = Rng::new(4);
        let _ = pool.lease(&mut rng);
        // Dealer should replenish toward the target.
        pool.wait_ready(2);
        assert!(pool.banked() >= 1);
        assert!(pool.produced() >= 3);
        pool.shutdown();
    }
}
